//! The core soundness property of overlapping: an overlapped schedule
//! must compute EXACTLY what the unoverlapped schedule computes.
//! AG+GEMM outputs are compared bitwise (same per-tile K order => same
//! f32 rounding); reductions use tight fp tolerances.

use triton_dist_sim::config::{ClusterSpec, GemmShape};
use triton_dist_sim::coordinator::{self, ag_gemm, gemm_rs};
use triton_dist_sim::mem::Slice;
use triton_dist_sim::runtime::HybridExecutor;
use triton_dist_sim::topology::Topology;
use triton_dist_sim::util::prop::check;

/// All "ours" AG+GEMM variants must agree bitwise with the NCCL baseline
/// run (the unoverlapped gold path) on the same inputs.
#[test]
fn ag_gemm_variants_bitwise_identical() {
    let cluster = ClusterSpec::h800(1, 4);
    let shape = GemmShape::new(16, 8, 8);
    let outputs: Vec<Vec<f32>> = [
        ag_gemm::AgGemmVariant::Nccl,
        ag_gemm::AgGemmVariant::OursPush,
        ag_gemm::AgGemmVariant::OursPull,
        ag_gemm::AgGemmVariant::OursLL,
        ag_gemm::AgGemmVariant::NoSwizzle,
        ag_gemm::AgGemmVariant::Flux,
    ]
    .into_iter()
    .map(|v| {
        let (mut op, bufs) = ag_gemm::build(cluster, shape, v);
        ag_gemm::fill_inputs(&mut op.heap, &bufs, 42);
        let topo = Topology::build(cluster);
        let mut exec = HybridExecutor::native_only();
        coordinator::run_numeric(&mut op, &topo, &mut exec).unwrap();
        op.heap
            .read(Slice::new(0, bufs.output, 0, shape.m * shape.n))
            .to_vec()
    })
    .collect();
    for (i, o) in outputs.iter().enumerate().skip(1) {
        assert_eq!(o, &outputs[0], "variant {i} diverged bitwise");
    }
}

/// Property: random small AG+GEMM problems, random variant, random world
/// size — always bitwise equal to the single-device reference.
#[test]
fn ag_gemm_random_problems_property() {
    check("ag_gemm random", 20, |g| {
        let ws = *g.pick(&[2usize, 4, 8]);
        let m_pr = g.usize_in(1, 6);
        let k = g.usize_in(1, 12);
        let n = g.usize_in(1, 12);
        let variant = *g.pick(&[
            ag_gemm::AgGemmVariant::OursPush,
            ag_gemm::AgGemmVariant::OursPull,
            ag_gemm::AgGemmVariant::OursLL,
        ]);
        let cluster = ClusterSpec::h800(1, ws);
        let shape = GemmShape::new(m_pr * ws, n, k);
        let (mut op, bufs) = ag_gemm::build(cluster, shape, variant);
        ag_gemm::fill_inputs(&mut op.heap, &bufs, g.u64());
        let reference = ag_gemm::reference_output(&op.heap, &bufs);
        let topo = Topology::build(cluster);
        let mut exec = HybridExecutor::native_only();
        coordinator::run_numeric(&mut op, &topo, &mut exec).unwrap();
        ag_gemm::verify(&op.heap, &bufs, &reference).unwrap();
    });
}

/// Property: random GEMM+RS problems across variants and geometries.
#[test]
fn gemm_rs_random_problems_property() {
    check("gemm_rs random", 14, |g| {
        let (cluster, variant) = *g.pick(&[
            (ClusterSpec::h800(1, 4), gemm_rs::GemmRsVariant::OursIntra),
            (ClusterSpec::h800(1, 8), gemm_rs::GemmRsVariant::OursIntra),
            (ClusterSpec::h800(2, 4), gemm_rs::GemmRsVariant::OursInter),
            (
                ClusterSpec::mi308x(4),
                gemm_rs::GemmRsVariant::OursAmd { comm_tiles: 2 },
            ),
        ]);
        let ws = cluster.world_size();
        let m_pr = g.usize_in(1, 5);
        let k = g.usize_in(1, 10);
        let n = g.usize_in(1, 10);
        let shape = GemmShape::new(m_pr * ws, n, k);
        let (mut op, bufs) = gemm_rs::build(cluster, shape, variant);
        gemm_rs::fill_inputs(&mut op.heap, &bufs, g.u64());
        let expected = gemm_rs::reference_outputs(&op.heap, &bufs);
        let topo = Topology::build(cluster);
        let mut exec = HybridExecutor::native_only();
        coordinator::run_numeric(&mut op, &topo, &mut exec).unwrap();
        gemm_rs::verify(&op.heap, &bufs, &expected).unwrap();
    });
}

/// Timing sanity: overlap never *hurts* vs its own unoverlapped order on
/// comm-heavy shapes, and the overlapped makespan is at least the
/// critical-path lower bound (GEMM alone).
#[test]
fn overlap_timing_bounds() {
    let cluster = ClusterSpec::h800(1, 8);
    let topo = Topology::build(cluster);
    let shape = GemmShape::new(4096, 1536, 4096);
    let t = |v| {
        let (mut op, _b) = ag_gemm::build(cluster, shape, v);
        coordinator::run_timing(&mut op, &topo).unwrap()
    };
    let ours = t(ag_gemm::AgGemmVariant::OursPush);
    let nccl = t(ag_gemm::AgGemmVariant::Nccl);

    // lower bound: the GEMM compute alone on 132 SMs (triton eff)
    let hw = cluster.hw;
    let gemm_floor = shape.flops() / hw.triton_gemm_flops(hw.sms);
    assert!(ours >= gemm_floor * 0.99, "{ours} below compute floor {gemm_floor}");
    // upper bound: the serialized baseline
    assert!(ours <= nccl, "{ours} vs serialized {nccl}");
}
