//! Elastic degraded-world recovery invariants (ISSUE tentpole
//! acceptance):
//!
//! 1. **Survive + conserve**: a rank dying mid-EP-dispatch recovers to
//!    completion with exact token accounting — every (token, k) pair
//!    the original plan owed is either delivered by the survivor plan
//!    or counted dropped in the [`RecoveryLedger`]; survivor numerics
//!    are bit-exact against the survivor-world reference.
//! 2. **Structured, never bare**: without the recovery controller a
//!    death surfaces as a structured `DeadPeer` error (op name, dead
//!    set, detection path, virtual times) — never a hang and never a
//!    bare `Deadlock`.
//! 3. **Determinism**: the same (workload seed, fault plan) replays an
//!    identical timeline *including* the recovery ledger.
//! 4. **Bit-identity**: the elastic entry point with an empty plan is
//!    bit-for-bit the plain fault-free run, and `recovery` stays
//!    `None`.
//! 5. **Tier contract**: default-tier synthesized plans never engage
//!    the controller (kill-and-retry suffices); severe-tier plans may,
//!    but are always recoverable by it.

use triton_dist_sim::collectives::alltoall::A2aCfg;
use triton_dist_sim::config::{
    ClusterSpec, FabricSpec, FaultPlan, GemmShape, MoeShape, RailPolicy,
};
use triton_dist_sim::coordinator::{
    ag_gemm, ep_moe, flash_decode, gemm_rs, recover, run_numeric, run_timing_faults,
};
use triton_dist_sim::runtime::HybridExecutor;
use triton_dist_sim::sim::SimError;
use triton_dist_sim::topology::Topology;
use triton_dist_sim::util::prop::{check, Gen};

use triton_dist_sim::coordinator::recover::RecoverCfg;

fn railed_cluster(nodes: usize, gpus: usize) -> ClusterSpec {
    ClusterSpec::h800(nodes, gpus).with_fabric(
        FabricSpec::rail_optimized(2, 2.0)
            .with_spine_taper(2.0)
            .with_rail_policy(RailPolicy::Adaptive),
    )
}

fn small_shape() -> MoeShape {
    MoeShape {
        tokens_per_rank: 16,
        in_hidden: 32,
        out_hidden: 32,
        experts: 32,
        topk: 2,
        ..MoeShape::default()
    }
    .with_skew(1.2)
}

/// Run the elastic pipeline and return the result after asserting the
/// universal post-conditions: exact token conservation against what the
/// original full-world plan owed, and bit-exact survivor numerics.
fn run_and_audit(
    cluster: ClusterSpec,
    shape: MoeShape,
    seed: u64,
    plan: FaultPlan,
) -> recover::ElasticRun {
    let w0 = cluster.world_size();
    let run = recover::run_ep_moe_elastic(
        cluster,
        shape,
        seed,
        ep_moe::EpMoeVariant::TokenRouted,
        &A2aCfg::ours(),
        plan,
        &RecoverCfg::default(),
    )
    .unwrap_or_else(|e| panic!("elastic run must survive: {e}"));
    if let Some(rec) = &run.report.recovery {
        let owed = (w0 * shape.tokens_per_rank * shape.topk) as u64;
        assert_eq!(
            rec.tokens_delivered + rec.tokens_dropped,
            owed,
            "conservation: delivered + dropped must equal the {owed} owed pairs: {rec:?}"
        );
        assert!(
            rec.tokens_rerouted <= rec.tokens_delivered,
            "rerouted is a subset of delivered: {rec:?}"
        );
        assert!(rec.died_at <= rec.detected_at, "detection after death");
        assert!(
            rec.detected_at <= rec.drained_at
                && rec.drained_at <= rec.replanned_at
                && rec.replanned_at <= rec.resumed_at,
            "detect -> drain -> re-plan -> resume must be ordered: {rec:?}"
        );
        assert!(
            run.report.makespan >= rec.resumed_at,
            "the survivor epoch runs after the resume point"
        );
        assert!(!rec.via.is_empty(), "detection path must be named");
        assert_eq!(run.view.world(), w0 - rec.dead_ranks.len());
    }
    // survivor numerics: bit-exact vs the survivor-world reference
    let expected =
        ep_moe::reference_ep_moe_view(&run.op.heap, &run.bufs, &run.routing, &run.view);
    ep_moe::verify_ep_moe_view(&run.op.heap, &run.bufs, &run.routing, &expected, &run.view)
        .unwrap_or_else(|e| panic!("survivor numerics must stay exact: {e}"));
    run
}

#[test]
fn rank_death_mid_dispatch_recovers_with_exact_token_conservation() {
    // the headline scenario: rank 3 dies 1us in, mid EP dispatch
    let run = run_and_audit(
        railed_cluster(2, 4),
        small_shape(),
        5,
        FaultPlan::parse("die,3,1e-6").unwrap(),
    );
    let rec = run.report.recovery.as_ref().expect("death must be survived");
    assert_eq!(rec.dead_ranks, vec![3]);
    assert_eq!(rec.epochs, 1);
    assert_eq!(run.view.world(), 7);
    // rank 3's resident tokens are gone; the other 7/8 of the world's
    // pairs are candidates, so most of the owed pairs still land
    assert!(
        rec.tokens_delivered > 0,
        "survivors must keep delivering: {rec:?}"
    );
    assert!(
        rec.tokens_dropped >= small_shape().tokens_per_rank as u64,
        "at least the dead rank's resident pairs drop: {rec:?}"
    );
    // experts homed on rank 3 re-sharded onto survivors
    assert!(rec.tokens_rerouted > 0, "re-shard must move experts: {rec:?}");
}

#[test]
fn node_death_recovers_over_the_surviving_node() {
    let run = run_and_audit(
        railed_cluster(2, 4),
        small_shape(),
        5,
        FaultPlan::parse("nodedead,1,1e-6").unwrap(),
    );
    let rec = run.report.recovery.as_ref().expect("death must be survived");
    assert_eq!(rec.dead_ranks, vec![4, 5, 6, 7], "node 1 is ranks 4..8");
    assert_eq!(run.view.world(), 4);
    for l in 0..4 {
        assert_eq!(run.view.phys(l), l, "survivors keep their physical ranks");
    }
}

#[test]
fn cascading_deaths_recover_across_epochs() {
    // rank 3 dies almost immediately; rank 5's death lands on the clock
    // shortly after, so it is either folded into the same detection or
    // re-detected in the survivor epoch — both must converge
    let run = run_and_audit(
        railed_cluster(2, 4),
        small_shape(),
        7,
        FaultPlan::parse("die,3,1e-6; die,5,2e-6").unwrap(),
    );
    let rec = run.report.recovery.as_ref().expect("deaths must be survived");
    assert_eq!(rec.dead_ranks, vec![3, 5]);
    assert!(rec.epochs >= 1);
    assert_eq!(run.view.world(), 6);
}

#[test]
fn death_without_recovery_is_a_structured_dead_peer_never_bare_deadlock() {
    let cluster = railed_cluster(2, 4);
    let shape = small_shape();
    let routing = ep_moe::routing_for(cluster, &shape, 5);
    let topo = Topology::build(cluster);
    let (mut op, _b) =
        ep_moe::build_ep_moe(cluster, shape, &routing, ep_moe::EpMoeVariant::TokenRouted);
    let plan = FaultPlan::parse("die,3,1e-6").unwrap();
    let err = run_timing_faults(&mut op, &topo, plan).expect_err("dead peer must abort");
    match &err.source {
        SimError::DeadPeer(info) => {
            assert_eq!(info.dead, vec![3]);
            assert!(info.detected_at >= info.died_at);
            assert!(
                ["flow-kill", "launch-to-dead", "retry-to-dead", "watchdog", "queue-drain"]
                    .contains(&info.via.as_str()),
                "unknown detection path: {}",
                info.via
            );
        }
        other => panic!("expected DeadPeer, got {other}"),
    }
    assert!(err.at.is_some(), "detection time must surface on the error");
    assert!(err.to_string().contains("EP MoE"), "op name in error: {err}");
}

#[test]
fn same_seed_replay_is_identical_including_recovery_ledger() {
    let plan = FaultPlan::parse("flap,nic,1,0,2e-6,1e-5; die,3,1e-6; strag,2,1.3").unwrap();
    let run = || run_and_audit(railed_cluster(2, 4), small_shape(), 11, plan.clone());
    let a = run();
    let b = run();
    assert_eq!(
        a.report.makespan.to_bits(),
        b.report.makespan.to_bits(),
        "makespan bits"
    );
    assert_eq!(a.report.events, b.report.events);
    assert_eq!(a.report.flows, b.report.flows);
    assert_eq!(a.report.recovery, b.report.recovery, "recovery ledger");
}

#[test]
fn empty_plan_elastic_is_bit_identical_to_the_plain_run() {
    let cluster = railed_cluster(2, 4);
    let shape = small_shape();
    let seed = 5;
    let elastic = recover::run_ep_moe_elastic(
        cluster,
        shape,
        seed,
        ep_moe::EpMoeVariant::TokenRouted,
        &A2aCfg::ours(),
        FaultPlan::default(),
        &RecoverCfg::default(),
    )
    .unwrap();
    assert!(elastic.report.recovery.is_none(), "no death, no ledger");
    assert!(elastic.view.is_identity());

    let routing = ep_moe::routing_for(cluster, &shape, seed);
    let topo = Topology::build(cluster);
    let (mut op, bufs) = ep_moe::build_ep_moe_cfg(
        cluster,
        shape,
        &routing,
        ep_moe::EpMoeVariant::TokenRouted,
        &A2aCfg::ours(),
    );
    ep_moe::fill_ep_moe(&mut op.heap, &bufs, &routing, seed);
    let mut exec = HybridExecutor::native_only();
    let plain = run_numeric(&mut op, &topo, &mut exec).unwrap();

    assert_eq!(
        elastic.report.makespan.to_bits(),
        plain.makespan.to_bits(),
        "empty-plan elastic must be bit-identical to the plain engine"
    );
    assert_eq!(elastic.report.events, plain.events);
    assert_eq!(elastic.report.flows, plain.flows);
}

#[test]
fn default_tier_synthesized_plans_never_engage_the_controller() {
    // satellite contract: the default tier is always recoverable by
    // kill-and-retry alone — the run completes at full world, exactly
    let cluster = railed_cluster(2, 2);
    let shape = MoeShape {
        tokens_per_rank: 6,
        in_hidden: 8,
        out_hidden: 8,
        experts: 8,
        topk: 2,
        ..MoeShape::default()
    };
    check("default tier: full-world completion", 6, |g: &mut Gen| {
        let fault_seed = g.u64();
        let plan = FaultPlan::synthesize(fault_seed, 1.0, 4, 2, 1e-4);
        assert!(!plan.has_deaths(), "seed {fault_seed}: default tier emitted a death");
        let run = recover::run_ep_moe_elastic(
            cluster,
            shape,
            3,
            ep_moe::EpMoeVariant::TokenRouted,
            &A2aCfg::ours(),
            plan,
            &RecoverCfg::default(),
        )
        .unwrap_or_else(|e| panic!("seed {fault_seed}: must complete: {e}"));
        assert!(
            run.report.recovery.is_none(),
            "seed {fault_seed}: controller must stay idle on the default tier"
        );
        let expected =
            ep_moe::reference_ep_moe_view(&run.op.heap, &run.bufs, &run.routing, &run.view);
        ep_moe::verify_ep_moe_view(&run.op.heap, &run.bufs, &run.routing, &expected, &run.view)
            .unwrap_or_else(|e| panic!("seed {fault_seed}: {e}"));
    });
}

#[test]
fn severe_tier_death_plan_recovers_end_to_end() {
    // scan for the first severe-tier seed that actually escalates to a
    // permanent death, then survive it
    let cluster = railed_cluster(2, 4);
    let seed = (0..64u64)
        .find(|&s| FaultPlan::synthesize_severe(s, 1.0, 8, 2, 2, 2e-5).has_deaths())
        .expect("severe tier must escalate within 64 seeds");
    let plan = FaultPlan::synthesize_severe(seed, 1.0, 8, 2, 2, 2e-5);
    let run = run_and_audit(cluster, small_shape(), 5, plan);
    // the death may land before or after completion; either way the run
    // finished and the audit above held — pin that a fired death shrinks
    // the world
    if let Some(rec) = &run.report.recovery {
        assert!(!rec.dead_ranks.is_empty());
        assert!(run.view.world() < 8);
    }
}

#[test]
fn ag_gemm_death_replans_onto_the_flat_survivor_program() {
    let cluster = ClusterSpec::h800(2, 4);
    let (rep, view) = recover::run_ag_gemm_elastic(
        cluster,
        GemmShape::new(512, 256, 256),
        ag_gemm::AgGemmVariant::OursInter,
        FaultPlan::parse("die,2,1e-6").unwrap(),
        &RecoverCfg::default(),
    )
    .unwrap();
    let rec = rep.recovery.as_ref().expect("death must be survived");
    assert_eq!(rec.dead_ranks, vec![2]);
    assert_eq!(view.world(), 7);
    assert!(rep.makespan >= rec.resumed_at);
    assert_eq!(rec.epochs, 1);
    // timing-only path: the token ledger stays zero
    assert_eq!(rec.tokens_delivered + rec.tokens_rerouted + rec.tokens_dropped, 0);
}

#[test]
fn gemm_rs_death_replans_onto_the_flat_survivor_program() {
    let cluster = ClusterSpec::h800(2, 4);
    let (rep, view) = recover::run_gemm_rs_elastic(
        cluster,
        GemmShape::new(512, 256, 256),
        gemm_rs::GemmRsVariant::OursInter,
        FaultPlan::parse("die,5,1e-6").unwrap(),
        &RecoverCfg::default(),
    )
    .unwrap();
    let rec = rep.recovery.as_ref().expect("death must be survived");
    assert_eq!(rec.dead_ranks, vec![5]);
    assert_eq!(view.world(), 7);
    assert!(rep.makespan >= rec.resumed_at);
    assert_eq!(rec.epochs, 1);
    // timing-only path: the token ledger stays zero
    assert_eq!(rec.tokens_delivered + rec.tokens_rerouted + rec.tokens_dropped, 0);
}

#[test]
fn gemm_rs_elastic_without_deaths_is_the_plain_run() {
    // bit-identity: the elastic entry point with an empty plan must be
    // the plain fault-free run, recovery None
    let cluster = ClusterSpec::h800(2, 4);
    let shape = GemmShape::new(512, 256, 256);
    let (rep, view) = recover::run_gemm_rs_elastic(
        cluster,
        shape,
        gemm_rs::GemmRsVariant::OursInter,
        FaultPlan::default(),
        &RecoverCfg::default(),
    )
    .unwrap();
    assert!(rep.recovery.is_none());
    assert_eq!(view.world(), 8);
    let (mut op, _b) = gemm_rs::build(cluster, shape, gemm_rs::GemmRsVariant::OursInter);
    let topo = Topology::build(cluster);
    let plain = run_timing_faults(&mut op, &topo, FaultPlan::default()).unwrap();
    assert_eq!(
        rep.makespan.to_bits(),
        plain.makespan.to_bits(),
        "fault-free elastic must be bit-identical to the plain run"
    );
}

#[test]
fn flash_decode_death_replans_onto_the_degraded_survivor_program() {
    // decode-time death: rank 3 dies mid flash-decode; the controller
    // must re-plan the distributed attention onto the survivors' flat
    // combine with exact KV-shard accounting
    let cluster = railed_cluster(2, 4);
    let cfg = flash_decode::FlashDecodeCfg {
        heads: 8,
        head_dim: 64,
        kv_per_rank: 4096,
        numeric: false,
    };
    let plan = FaultPlan::parse("die,3,1e-6").unwrap();
    let (rep, view) =
        recover::run_flash_decode_elastic(cluster, cfg, plan.clone(), &RecoverCfg::default())
            .expect("decode-time death must be survived");
    let rec = rep.recovery.as_ref().expect("ledger must be on record");
    assert_eq!(rec.dead_ranks, vec![3]);
    assert_eq!(view.world(), 7);
    assert!(rep.makespan >= rec.resumed_at);
    assert!(
        rec.died_at <= rec.detected_at
            && rec.detected_at <= rec.drained_at
            && rec.drained_at <= rec.replanned_at
            && rec.replanned_at <= rec.resumed_at,
        "detect -> drain -> re-plan -> resume must be ordered: {rec:?}"
    );
    assert!(!rec.via.is_empty(), "detection path must be named");
    // exact conservation: every KV entry the full-world decode owed is
    // either attended by a survivor shard or counted dropped
    let owed = 8 * cfg.kv_per_rank as u64;
    assert_eq!(
        rec.tokens_delivered + rec.tokens_dropped,
        owed,
        "KV conservation: {rec:?}"
    );
    assert_eq!(
        rec.tokens_dropped,
        cfg.kv_per_rank as u64,
        "exactly the dead rank's shard drops: {rec:?}"
    );
    // determinism: same plan, same recovery, bit-for-bit
    let (rep2, _) =
        recover::run_flash_decode_elastic(cluster, cfg, plan, &RecoverCfg::default()).unwrap();
    assert_eq!(rep.makespan.to_bits(), rep2.makespan.to_bits());
    assert_eq!(rep.recovery, rep2.recovery);
    // empty plan: bit-identical to the plain engine, no ledger
    let (plain, v) = recover::run_flash_decode_elastic(
        cluster,
        cfg,
        FaultPlan::default(),
        &RecoverCfg::default(),
    )
    .unwrap();
    assert!(plain.recovery.is_none(), "no death, no ledger");
    assert!(v.is_identity());
}

// ---------------------------------------------------------------------
// fault-DSL robustness (satellite): structured errors, never panics,
// and parse -> display -> parse is the identity on valid plans
// ---------------------------------------------------------------------

#[test]
fn malformed_fault_dsl_returns_structured_errors_never_panics() {
    let kinds = [
        "flap", "deg", "raildead", "strag", "jitter", "die", "nodedead", "bogus", "",
    ];
    let targets = ["nic", "spine", "rail", "rank", "node", "gpu", ""];
    let nums = ["0", "3", "1e-3", "-1", "nan", "inf", "1.5", "x", "", "18446744073709551616"];
    check("fuzzed DSL: Ok or Err, never a panic", 256, |g: &mut Gen| {
        let clauses = g.usize_in(0, 5);
        let mut spec = String::new();
        for i in 0..clauses {
            if i > 0 {
                spec.push(';');
            }
            spec.push_str(g.pick(&kinds));
            let fields = g.usize_in(0, 7);
            for _ in 0..fields {
                spec.push(',');
                spec.push_str(if g.bool() { g.pick(&targets) } else { g.pick(&nums) });
            }
        }
        match FaultPlan::parse(&spec) {
            Ok(_) => {}
            Err(e) => assert!(!e.is_empty(), "error must describe the clause: {spec:?}"),
        }
    });
}

#[test]
fn generated_plans_round_trip_through_display() {
    check("parse(display(p)) == p", 64, |g: &mut Gen| {
        let mut spec = Vec::new();
        for _ in 0..g.usize_in(1, 6) {
            // dyadic times: exactly representable, so Display's
            // round-trippable f64 formatting is the identity
            let t0 = g.usize_in(0, 1 << 12) as f64 / (1 << 20) as f64;
            let dur = (1 + g.usize_in(0, 1 << 12)) as f64 / (1 << 20) as f64;
            let rank = g.usize_in(0, 16);
            let rail = g.usize_in(0, 2);
            spec.push(match g.usize_in(0, 6) {
                0 => format!("flap,nic,{rank},{rail},{t0},{dur}"),
                1 => format!("deg,spine,{rail},{t0},{dur},0.5"),
                2 => format!("raildead,{rail},{t0}"),
                3 => format!("die,{rank},{t0}"),
                4 => format!("nodedead,{},{t0}", rank % 4),
                _ => format!("strag,{rank},1.5"),
            });
        }
        let spec = spec.join("; ");
        let p = FaultPlan::parse(&spec).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
        let shown = p.to_string();
        let q = FaultPlan::parse(&shown)
            .unwrap_or_else(|e| panic!("display output must re-parse: {shown:?}: {e}"));
        assert_eq!(p, q, "round trip changed the plan:\n  {spec}\n  {shown}");
    });
}

// ---------------------------------------------------------------------
// chaos sweep (nightly / label-gated in CI; see .github/workflows)
// ---------------------------------------------------------------------

/// 32-seed severe-tier sweep: every plan either completes at full world
/// or is survived by the elastic controller with exact accounting. On
/// failure the panic message carries the seed so CI prints a minimal
/// repro (`--faults` via `FaultPlan::synthesize_severe(seed, ...)`).
#[test]
#[ignore = "chaos sweep: run explicitly (cargo test --test recovery -- --ignored)"]
fn chaos_sweep_severe_tier_32_seeds() {
    let cluster = railed_cluster(2, 4);
    let shape = small_shape();
    for seed in 0..32u64 {
        let mut plan = FaultPlan::synthesize_severe(seed, 1.5, 8, 2, 2, 2e-5);
        // backstop: any wedge becomes a structured error with the seed
        plan.lt_timeout = 50e-3;
        let w0 = cluster.world_size();
        let run = recover::run_ep_moe_elastic(
            cluster,
            shape,
            5,
            ep_moe::EpMoeVariant::TokenRouted,
            &A2aCfg::ours(),
            plan,
            &RecoverCfg::default(),
        )
        .unwrap_or_else(|e| panic!("chaos seed {seed}: must survive, got: {e}"));
        if let Some(rec) = &run.report.recovery {
            let owed = (w0 * shape.tokens_per_rank * shape.topk) as u64;
            assert_eq!(
                rec.tokens_delivered + rec.tokens_dropped,
                owed,
                "chaos seed {seed}: conservation broke: {rec:?}"
            );
        }
        let expected =
            ep_moe::reference_ep_moe_view(&run.op.heap, &run.bufs, &run.routing, &run.view);
        ep_moe::verify_ep_moe_view(&run.op.heap, &run.bufs, &run.routing, &expected, &run.view)
            .unwrap_or_else(|e| panic!("chaos seed {seed}: numerics broke: {e}"));
    }
}
