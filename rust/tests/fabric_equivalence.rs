//! Routed-fabric acceptance suite.
//!
//! 1. A `rails = 1, oversub = 1.0` fabric must reproduce the flat-NIC
//!    topology's makespans **bit-identically** on the fig13 (inter-node
//!    AG+GEMM), fig14 (inter-node GEMM+RS), and fig16 (low-latency
//!    AllToAll) workload shapes — the routed graph elides its switch
//!    tiers on non-blocking fabrics, so nothing may drift.
//! 2. With `oversub > 1` the shared spine planes must visibly contend:
//!    a 64-device AG+GEMM slows down vs the non-blocking fabric.
//! 3. Collectives must stay numerically correct when their traffic is
//!    rail-striped across a blocking multi-rail fabric.

use triton_dist_sim::collectives::alltoall::{a2a_ll, verify_alltoall, A2aBufs, A2aCfg};
use triton_dist_sim::collectives::ProgBuild;
use triton_dist_sim::config::{ClusterSpec, DType, FabricSpec, GemmShape};
use triton_dist_sim::coordinator::{ag_gemm, gemm_rs, run_timing};
use triton_dist_sim::mem::SymmetricHeap;
use triton_dist_sim::shmem::ShmemCtx;
use triton_dist_sim::sim::{NoopExecutor, Sim, SimConfig};
use triton_dist_sim::topology::{LinkKind, Topology};

fn a2a_makespan(cluster: ClusterSpec, chunk: usize) -> f64 {
    let ctx = ShmemCtx::new(cluster, DType::BF16);
    let topo = Topology::build(cluster);
    let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes().max(16));
    let bufs = A2aBufs::alloc(&mut heap, &ctx, chunk);
    let mut pb = ProgBuild::new();
    a2a_ll(&ctx, &bufs, &mut pb, &A2aCfg::ours());
    let sim = Sim::with_config(
        &topo,
        SimConfig {
            numerics: false,
            trace: false,
        },
    );
    sim.run(&pb.prog, &mut heap, &mut NoopExecutor)
        .unwrap()
        .makespan
}

fn ag_gemm_makespan(cluster: ClusterSpec, shape: GemmShape) -> f64 {
    let topo = Topology::build(cluster);
    let (mut op, _b) = ag_gemm::build(cluster, shape, ag_gemm::AgGemmVariant::OursInter);
    run_timing(&mut op, &topo)
}

fn gemm_rs_makespan(cluster: ClusterSpec, shape: GemmShape) -> f64 {
    let topo = Topology::build(cluster);
    let (mut op, _b) = gemm_rs::build(cluster, shape, gemm_rs::GemmRsVariant::OursInter);
    run_timing(&mut op, &topo)
}

/// fig13 shape (scaled down): inter-node AG+GEMM on 2x8 H800.
#[test]
fn flat_fabric_bit_identical_fig13_shape() {
    let flat = ClusterSpec::h800(2, 8);
    let routed = flat.with_fabric(FabricSpec::rail_optimized(1, 1.0));
    let shape = GemmShape::new(16 * 64, 128, 256);
    assert_eq!(
        ag_gemm_makespan(flat, shape).to_bits(),
        ag_gemm_makespan(routed, shape).to_bits()
    );
}

/// fig14 shape (scaled down): inter-node GEMM+RS on 2x8 H800.
#[test]
fn flat_fabric_bit_identical_fig14_shape() {
    let flat = ClusterSpec::h800(2, 8);
    let routed = flat.with_fabric(FabricSpec::rail_optimized(1, 1.0));
    let shape = GemmShape::new(16 * 32, 128, 256);
    assert_eq!(
        gemm_rs_makespan(flat, shape).to_bits(),
        gemm_rs_makespan(routed, shape).to_bits()
    );
}

/// fig16 shape (scaled down): 16-rank low-latency AllToAll.
#[test]
fn flat_fabric_bit_identical_fig16_shape() {
    let flat = ClusterSpec::h800(2, 8);
    let routed = flat.with_fabric(FabricSpec::rail_optimized(1, 1.0));
    assert_eq!(
        a2a_makespan(flat, 1024).to_bits(),
        a2a_makespan(routed, 1024).to_bits()
    );
}

/// Non-blocking fabrics elide switch-tier links entirely, so the link
/// sets (and therefore the whole flow network) match the seed model.
#[test]
fn nonblocking_fabric_has_no_tier_links() {
    let topo = Topology::build(
        ClusterSpec::h800(4, 8).with_fabric(FabricSpec::rail_optimized(2, 1.0)),
    );
    for l in 0..topo.link_count() {
        let kind = topo.link(triton_dist_sim::topology::LinkId(l)).kind;
        assert!(
            !matches!(kind, LinkKind::LeafUp | LinkKind::LeafDown | LinkKind::Spine),
            "non-blocking fabric materialized a {kind:?} tier link"
        );
    }
}

/// Acceptance: a 64-device AG+GEMM on an oversubscribed fabric shows
/// switch-tier contention — the thinned leaf up/down links throttle the
/// inter-node sends that a flat fabric would run at full NIC rate (with
/// the default spine taper the spine plane merges the flows but the
/// binding constraint is the leaf; see `tapered_spine_binds_when_leaf_
/// does_not` for the spine itself binding).
#[test]
fn oversubscribed_fabric_contends_64_device_ag_gemm() {
    let shape = GemmShape::new(64 * 128, 64, 256);
    let flat = ag_gemm_makespan(ClusterSpec::h800(8, 8), shape);
    let contended = ag_gemm_makespan(
        ClusterSpec::h800(8, 8).with_fabric(FabricSpec::rail_optimized(1, 4.0)),
        shape,
    );
    assert!(
        contended > flat * 1.05,
        "spine contention must show: contended {contended} vs flat {flat}"
    );
}

/// With leaf oversubscription at 1:1 but a thinned spine core, the
/// contention moves to the spine plane itself — the only constraint the
/// taper knob adds.
#[test]
fn tapered_spine_binds_when_leaf_does_not() {
    let shape = GemmShape::new(64 * 128, 64, 256);
    let flat = ag_gemm_makespan(ClusterSpec::h800(8, 8), shape);
    let tapered = ag_gemm_makespan(
        ClusterSpec::h800(8, 8)
            .with_fabric(FabricSpec::rail_optimized(1, 1.0).with_spine_taper(4.0)),
        shape,
    );
    assert!(
        tapered > flat * 1.05,
        "spine taper must bind: tapered {tapered} vs flat {flat}"
    );
}

/// Rail-striped AllToAll stays numerically correct on a blocking
/// multi-rail fabric (2 nodes, 2 rails, 2:1 oversubscription).
#[test]
fn a2a_correct_on_railed_blocking_fabric() {
    let cluster = ClusterSpec::h800(2, 8).with_fabric(FabricSpec::rail_optimized(2, 2.0));
    let ctx = ShmemCtx::new(cluster, DType::BF16);
    let topo = Topology::build(cluster);
    let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes());
    let bufs = A2aBufs::alloc(&mut heap, &ctx, 32);
    triton_dist_sim::collectives::alltoall::fill_a2a_inputs(&mut heap, &bufs, 5);
    let mut pb = ProgBuild::new();
    a2a_ll(&ctx, &bufs, &mut pb, &A2aCfg::ours());
    let sim = Sim::new(&topo);
    sim.run(&pb.prog, &mut heap, &mut NoopExecutor).unwrap();
    verify_alltoall(&heap, &bufs).unwrap();
}

/// Rail-striped inter-node AllGather stays correct on a blocking
/// multi-rail fabric, including the 4-node case where the round-robin
/// striping actually spreads across both planes.
#[test]
fn ag_inter_correct_on_railed_blocking_fabric() {
    use triton_dist_sim::collectives::allgather::ag_inter;
    use triton_dist_sim::collectives::{
        expected_allgather, fill_ag_inputs, verify_allgather, AgBufs,
    };
    let cluster = ClusterSpec::h800(4, 4).with_fabric(FabricSpec::rail_optimized(2, 2.0));
    let ctx = ShmemCtx::new(cluster, DType::BF16);
    let topo = Topology::build(cluster);
    let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes());
    let bufs = AgBufs::alloc(&mut heap, &ctx, 16);
    fill_ag_inputs(&mut heap, &bufs, 7);
    let expected = expected_allgather(&heap, &bufs);
    let mut pb = ProgBuild::new();
    ag_inter(&ctx, &bufs, &mut pb);
    let sim = Sim::new(&topo);
    sim.run(&pb.prog, &mut heap, &mut NoopExecutor).unwrap();
    verify_allgather(&heap, &bufs, &expected).unwrap();
}

/// Splitting the NIC into rails without oversubscription keeps aggregate
/// bandwidth: the striped AllToAll on 2 rails lands close to the flat
/// single-rail makespan (same total capacity, different plane layout).
#[test]
fn multi_rail_nonblocking_preserves_aggregate_bandwidth() {
    let flat = a2a_makespan(ClusterSpec::h800(2, 8), 4096);
    let railed = a2a_makespan(
        ClusterSpec::h800(2, 8).with_fabric(FabricSpec::rail_optimized(2, 1.0)),
        4096,
    );
    assert!(
        railed < flat * 1.5 && flat < railed * 1.5,
        "2-rail non-blocking fabric should stay in the flat ballpark: \
         railed {railed} vs flat {flat}"
    );
}
