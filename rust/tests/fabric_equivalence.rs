//! Routed-fabric acceptance suite.
//!
//! 1. A `rails = 1, oversub = 1.0` fabric must reproduce the flat-NIC
//!    topology's makespans **bit-identically** on the fig13 (inter-node
//!    AG+GEMM), fig14 (inter-node GEMM+RS), and fig16 (low-latency
//!    AllToAll) workload shapes — the routed graph elides its switch
//!    tiers on non-blocking fabrics, so nothing may drift.
//! 2. With `oversub > 1` the shared spine planes must visibly contend:
//!    a 64-device AG+GEMM slows down vs the non-blocking fabric.
//! 3. Collectives must stay numerically correct when their traffic is
//!    rail-striped across a blocking multi-rail fabric.
//! 4. The congestion-aware router (`RailPolicy::Adaptive`): with a single
//!    flow it reproduces `Static` makespans bit-identically (no
//!    contention means every plane is equivalent), it strictly beats
//!    `Static` on deliberately skewed traffic, it keeps collectives
//!    numerically correct, and the `a2a_ep_rails` asymmetric
//!    `Rails { tx, rx }` routes land on exactly the claimed planes.
//! 5. The variable-size (token-routed) AllToAll family: a uniform size
//!    table through `a2a_ll_var` is **bit-identical** to `a2a_ll` on
//!    flat and railed fabrics, randomized routing tables deliver every
//!    kept token exactly once (conservation), and the variable-size
//!    combine's spine-crossing `Rails { tx, rx }` classes land on the
//!    claimed planes under a tapered spine.

use triton_dist_sim::collectives::alltoall::{
    a2a_ep_rails, a2a_ep_rails_var, a2a_ll, a2a_ll_var, a2a_skew, verify_alltoall, A2aBufs,
    A2aCfg, A2aEpDir, A2aSizes, A2aVarBufs, EpRouting,
};
use triton_dist_sim::kernels::names::EpGeom;
use triton_dist_sim::collectives::ProgBuild;
use triton_dist_sim::config::{ClusterSpec, DType, FabricSpec, GemmShape, RailPolicy, TrafficClass};
use triton_dist_sim::coordinator::{ag_gemm, gemm_rs, run_timing};
use triton_dist_sim::mem::SymmetricHeap;
use triton_dist_sim::program::Op;
use triton_dist_sim::shmem::ShmemCtx;
use triton_dist_sim::sim::{NoopExecutor, Sim, SimConfig};
use triton_dist_sim::topology::{LinkKind, Topology};

fn a2a_makespan(cluster: ClusterSpec, chunk: usize) -> f64 {
    let ctx = ShmemCtx::new(cluster, DType::BF16);
    let topo = Topology::build(cluster);
    let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes().max(16));
    let bufs = A2aBufs::alloc(&mut heap, &ctx, chunk);
    let mut pb = ProgBuild::new();
    a2a_ll(&ctx, &bufs, &mut pb, &A2aCfg::ours());
    let sim = Sim::with_config(
        &topo,
        SimConfig {
            numerics: false,
            trace: false,
        },
    );
    sim.run(&pb.prog, &mut heap, &mut NoopExecutor)
        .unwrap()
        .makespan
}

fn ag_gemm_makespan(cluster: ClusterSpec, shape: GemmShape) -> f64 {
    let topo = Topology::build(cluster);
    let (mut op, _b) = ag_gemm::build(cluster, shape, ag_gemm::AgGemmVariant::OursInter);
    run_timing(&mut op, &topo).unwrap()
}

fn gemm_rs_makespan(cluster: ClusterSpec, shape: GemmShape) -> f64 {
    let topo = Topology::build(cluster);
    let (mut op, _b) = gemm_rs::build(cluster, shape, gemm_rs::GemmRsVariant::OursInter);
    run_timing(&mut op, &topo).unwrap()
}

/// fig13 shape (scaled down): inter-node AG+GEMM on 2x8 H800.
#[test]
fn flat_fabric_bit_identical_fig13_shape() {
    let flat = ClusterSpec::h800(2, 8);
    let routed = flat.with_fabric(FabricSpec::rail_optimized(1, 1.0));
    let shape = GemmShape::new(16 * 64, 128, 256);
    assert_eq!(
        ag_gemm_makespan(flat, shape).to_bits(),
        ag_gemm_makespan(routed, shape).to_bits()
    );
}

/// fig14 shape (scaled down): inter-node GEMM+RS on 2x8 H800.
#[test]
fn flat_fabric_bit_identical_fig14_shape() {
    let flat = ClusterSpec::h800(2, 8);
    let routed = flat.with_fabric(FabricSpec::rail_optimized(1, 1.0));
    let shape = GemmShape::new(16 * 32, 128, 256);
    assert_eq!(
        gemm_rs_makespan(flat, shape).to_bits(),
        gemm_rs_makespan(routed, shape).to_bits()
    );
}

/// fig16 shape (scaled down): 16-rank low-latency AllToAll.
#[test]
fn flat_fabric_bit_identical_fig16_shape() {
    let flat = ClusterSpec::h800(2, 8);
    let routed = flat.with_fabric(FabricSpec::rail_optimized(1, 1.0));
    assert_eq!(
        a2a_makespan(flat, 1024).to_bits(),
        a2a_makespan(routed, 1024).to_bits()
    );
}

/// Non-blocking fabrics elide switch-tier links entirely, so the link
/// sets (and therefore the whole flow network) match the seed model.
#[test]
fn nonblocking_fabric_has_no_tier_links() {
    let topo = Topology::build(
        ClusterSpec::h800(4, 8).with_fabric(FabricSpec::rail_optimized(2, 1.0)),
    );
    for l in 0..topo.link_count() {
        let kind = topo.link(triton_dist_sim::topology::LinkId(l)).kind;
        assert!(
            !matches!(kind, LinkKind::LeafUp | LinkKind::LeafDown | LinkKind::Spine),
            "non-blocking fabric materialized a {kind:?} tier link"
        );
    }
}

/// Acceptance: a 64-device AG+GEMM on an oversubscribed fabric shows
/// switch-tier contention — the thinned leaf up/down links throttle the
/// inter-node sends that a flat fabric would run at full NIC rate (with
/// the default spine taper the spine plane merges the flows but the
/// binding constraint is the leaf; see `tapered_spine_binds_when_leaf_
/// does_not` for the spine itself binding).
#[test]
fn oversubscribed_fabric_contends_64_device_ag_gemm() {
    let shape = GemmShape::new(64 * 128, 64, 256);
    let flat = ag_gemm_makespan(ClusterSpec::h800(8, 8), shape);
    let contended = ag_gemm_makespan(
        ClusterSpec::h800(8, 8).with_fabric(FabricSpec::rail_optimized(1, 4.0)),
        shape,
    );
    assert!(
        contended > flat * 1.05,
        "spine contention must show: contended {contended} vs flat {flat}"
    );
}

/// With leaf oversubscription at 1:1 but a thinned spine core, the
/// contention moves to the spine plane itself — the only constraint the
/// taper knob adds.
#[test]
fn tapered_spine_binds_when_leaf_does_not() {
    let shape = GemmShape::new(64 * 128, 64, 256);
    let flat = ag_gemm_makespan(ClusterSpec::h800(8, 8), shape);
    let tapered = ag_gemm_makespan(
        ClusterSpec::h800(8, 8)
            .with_fabric(FabricSpec::rail_optimized(1, 1.0).with_spine_taper(4.0)),
        shape,
    );
    assert!(
        tapered > flat * 1.05,
        "spine taper must bind: tapered {tapered} vs flat {flat}"
    );
}

/// Rail-striped AllToAll stays numerically correct on a blocking
/// multi-rail fabric (2 nodes, 2 rails, 2:1 oversubscription).
#[test]
fn a2a_correct_on_railed_blocking_fabric() {
    let cluster = ClusterSpec::h800(2, 8).with_fabric(FabricSpec::rail_optimized(2, 2.0));
    let ctx = ShmemCtx::new(cluster, DType::BF16);
    let topo = Topology::build(cluster);
    let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes());
    let bufs = A2aBufs::alloc(&mut heap, &ctx, 32);
    triton_dist_sim::collectives::alltoall::fill_a2a_inputs(&mut heap, &bufs, 5);
    let mut pb = ProgBuild::new();
    a2a_ll(&ctx, &bufs, &mut pb, &A2aCfg::ours());
    let sim = Sim::new(&topo);
    sim.run(&pb.prog, &mut heap, &mut NoopExecutor).unwrap();
    verify_alltoall(&heap, &bufs).unwrap();
}

/// Rail-striped inter-node AllGather stays correct on a blocking
/// multi-rail fabric, including the 4-node case where the round-robin
/// striping actually spreads across both planes.
#[test]
fn ag_inter_correct_on_railed_blocking_fabric() {
    use triton_dist_sim::collectives::allgather::ag_inter;
    use triton_dist_sim::collectives::{
        expected_allgather, fill_ag_inputs, verify_allgather, AgBufs,
    };
    let cluster = ClusterSpec::h800(4, 4).with_fabric(FabricSpec::rail_optimized(2, 2.0));
    let ctx = ShmemCtx::new(cluster, DType::BF16);
    let topo = Topology::build(cluster);
    let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes());
    let bufs = AgBufs::alloc(&mut heap, &ctx, 16);
    fill_ag_inputs(&mut heap, &bufs, 7);
    let expected = expected_allgather(&heap, &bufs);
    let mut pb = ProgBuild::new();
    ag_inter(&ctx, &bufs, &mut pb);
    let sim = Sim::new(&topo);
    sim.run(&pb.prog, &mut heap, &mut NoopExecutor).unwrap();
    verify_allgather(&heap, &bufs, &expected).unwrap();
}

// -- congestion-aware rail router -------------------------------------------

/// The `rail_policy` field must be inert under `Static`: a railed fabric
/// with the policy spelled out reproduces the PR-2 (policy-less) railed
/// makespans bit-identically on the fig13 AG+GEMM and fig16 AllToAll
/// shapes.
#[test]
fn explicit_static_policy_bit_identical_on_fig_shapes() {
    let railed = ClusterSpec::h800(2, 8).with_fabric(FabricSpec::rail_optimized(2, 2.0));
    let spelled = ClusterSpec::h800(2, 8)
        .with_fabric(FabricSpec::rail_optimized(2, 2.0).with_rail_policy(RailPolicy::Static));
    let shape = GemmShape::new(16 * 64, 128, 256);
    assert_eq!(
        ag_gemm_makespan(railed, shape).to_bits(),
        ag_gemm_makespan(spelled, shape).to_bits()
    );
    assert_eq!(
        a2a_makespan(railed, 1024).to_bits(),
        a2a_makespan(spelled, 1024).to_bits()
    );
}

/// A single flow can never contend, and every plane of a rail-split NIC
/// has identical capacity and latency — so the adaptive router's pick
/// (emptiest plane, tie-broken to rail 0) must produce the exact same
/// makespan bits as the static hash, whatever plane each chose.
#[test]
fn adaptive_single_flow_matches_static_bit_identically() {
    let makespan = |policy: RailPolicy| -> f64 {
        let cluster = ClusterSpec::h800(2, 8)
            .with_fabric(FabricSpec::rail_optimized(2, 2.0).with_rail_policy(policy));
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let mut heap = SymmetricHeap::new(ctx.n_pes(), 16);
        let buf = heap.alloc("x", 4096);
        let mut pb = ProgBuild::new();
        // auto_rail (the default modal state) exercises the policy path
        let mut t = ctx.task(0, "single_put").on_copy_engine();
        t.putmem(
            triton_dist_sim::mem::Slice::new(0, buf, 0, 4096),
            triton_dist_sim::mem::Slice::new(9, buf, 0, 4096),
        );
        pb.prog.push(t.build());
        let sim = Sim::with_config(
            &topo,
            SimConfig {
                numerics: false,
                trace: false,
            },
        );
        sim.run(&pb.prog, &mut heap, &mut NoopExecutor)
            .unwrap()
            .makespan
    };
    assert_eq!(
        makespan(RailPolicy::Static).to_bits(),
        makespan(RailPolicy::Adaptive).to_bits()
    );
}

fn skew_makespan(policy: RailPolicy) -> f64 {
    let cluster = ClusterSpec::h800(2, 8)
        .with_fabric(FabricSpec::rail_optimized(2, 1.0).with_rail_policy(policy));
    let ctx = ShmemCtx::new(cluster, DType::BF16);
    let topo = Topology::build(cluster);
    let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes());
    let bufs = A2aBufs::alloc(&mut heap, &ctx, 8192);
    let mut pb = ProgBuild::new();
    a2a_skew(&ctx, &bufs, &mut pb, &A2aCfg::ours(), 8.0);
    let sim = Sim::with_config(
        &topo,
        SimConfig {
            numerics: false,
            trace: false,
        },
    );
    sim.run(&pb.prog, &mut heap, &mut NoopExecutor)
        .unwrap()
        .makespan
}

/// Acceptance: on the size-skewed AllToAll (`alltoall-adaptive-skew`
/// scenario) the congestion-aware router is **strictly** faster — static
/// round-robin maps the size skew straight onto one plane while adaptive
/// re-balances from live committed bytes.
#[test]
fn adaptive_strictly_beats_static_on_skewed_alltoall() {
    let stat = skew_makespan(RailPolicy::Static);
    let adap = skew_makespan(RailPolicy::Adaptive);
    assert!(
        adap < stat,
        "adaptive {adap} must be strictly below static {stat}"
    );
    // and not by luck of a tie — the rebalancing is worth a real margin
    assert!(
        adap < stat * 0.95,
        "expected >= 5% win, got adaptive {adap} vs static {stat}"
    );
}

/// The adaptively-striped AllToAll stays numerically correct (the router
/// only picks planes; delivery and signaling are untouched).
#[test]
fn a2a_correct_under_adaptive_router() {
    let cluster = ClusterSpec::h800(2, 8)
        .with_fabric(FabricSpec::rail_optimized(2, 2.0).with_rail_policy(RailPolicy::Adaptive));
    let ctx = ShmemCtx::new(cluster, DType::BF16);
    let topo = Topology::build(cluster);
    let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes());
    let bufs = A2aBufs::alloc(&mut heap, &ctx, 32);
    triton_dist_sim::collectives::alltoall::fill_a2a_inputs(&mut heap, &bufs, 5);
    let mut pb = ProgBuild::new();
    a2a_ll(&ctx, &bufs, &mut pb, &A2aCfg::ours());
    let sim = Sim::new(&topo);
    sim.run(&pb.prog, &mut heap, &mut NoopExecutor).unwrap();
    verify_alltoall(&heap, &bufs).unwrap();
}

/// Acceptance: `a2a_ep_rails` combine emits at least one spine-crossing
/// `Rails { tx != rx }` class, and routing that class on a tapered
/// blocking fabric lands on exactly the claimed planes: the tx plane's
/// NIC/leaf on the send side, **both** spine planes, and the rx plane's
/// leaf/NIC on the receive side.
#[test]
fn ep_rails_asymmetric_routes_land_on_claimed_planes() {
    let cluster = ClusterSpec::h800(2, 8)
        .with_fabric(FabricSpec::rail_optimized(2, 2.0).with_spine_taper(2.0));
    let ctx = ShmemCtx::new(cluster, DType::BF16);
    let topo = Topology::build(cluster);
    let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes());
    let bufs = A2aBufs::alloc(&mut heap, &ctx, 16);
    let mut pb = ProgBuild::new();
    a2a_ep_rails(&ctx, &bufs, &mut pb, &A2aCfg::ours(), A2aEpDir::Combine);

    let mut crossing = 0usize;
    for task in &pb.prog.tasks {
        for op in &task.ops {
            let Op::LLPut { src, dst, tc, .. } = op else {
                continue;
            };
            if cluster.node_of(src.rank) == cluster.node_of(dst.rank) {
                continue;
            }
            let TrafficClass::Rails { tx, rx } = *tc else {
                panic!("inter-node EP message without explicit planes: {tc:?}");
            };
            // the claimed planes are the endpoints' home planes
            assert_eq!(tx as usize, cluster.local_rank(src.rank) % 2);
            assert_eq!(rx as usize, cluster.local_rank(dst.rank) % 2);
            if tx == rx {
                continue;
            }
            crossing += 1;
            let route = topo.route_tc(src.rank, dst.rank, *tc);
            let spine_owners: Vec<usize> = route
                .links
                .iter()
                .filter(|&&l| topo.link(l).kind == LinkKind::Spine)
                .map(|&l| topo.link(l).owner)
                .collect();
            assert_eq!(
                spine_owners,
                vec![tx as usize, rx as usize],
                "spine-crossing path must traverse tx then rx plane"
            );
            // NIC endpoints belong to the transfer's endpoints
            assert_eq!(topo.link(route.links[0]).kind, LinkKind::NicTx);
            assert_eq!(topo.link(route.links[0]).owner, src.rank);
            let last = *route.links.last().unwrap();
            assert_eq!(topo.link(last).kind, LinkKind::NicRx);
            assert_eq!(topo.link(last).owner, dst.rank);
        }
    }
    assert!(
        crossing > 0,
        "combine direction must produce spine-crossing routes"
    );
}

// -- variable-size (token-routed) AllToAll ----------------------------------

/// Acceptance: a **uniform** size table through the variable-size builder
/// reproduces `a2a_ll` bit-identically — on the flat default fabric and
/// on a railed blocking one. The token-routed generalization costs the
/// uniform path nothing.
#[test]
fn var_uniform_bit_identical_to_a2a_ll() {
    for fabric in [
        FabricSpec::flat(),
        FabricSpec::rail_optimized(2, 2.0),
        FabricSpec::rail_optimized(2, 2.0).with_rail_policy(RailPolicy::Adaptive),
    ] {
        let cluster = ClusterSpec::h800(2, 8).with_fabric(fabric);
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let run = |var: bool| -> f64 {
            let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes());
            let mut pb = ProgBuild::new();
            if var {
                let bufs = A2aVarBufs::alloc(&mut heap, A2aSizes::uniform(ctx.n_pes(), 1024));
                a2a_ll_var(&ctx, &bufs, &mut pb, &A2aCfg::ours(), None);
            } else {
                let bufs = A2aBufs::alloc(&mut heap, &ctx, 1024);
                a2a_ll(&ctx, &bufs, &mut pb, &A2aCfg::ours());
            }
            let sim = Sim::with_config(
                &topo,
                SimConfig {
                    numerics: false,
                    trace: false,
                },
            );
            sim.run(&pb.prog, &mut heap, &mut NoopExecutor)
                .unwrap()
                .makespan
        };
        assert_eq!(
            run(false).to_bits(),
            run(true).to_bits(),
            "uniform var path must be bit-identical under {fabric:?}"
        );
    }
}

/// Acceptance: randomized routing tables through the railed EP dispatch —
/// every kept (token, k) pair's row is delivered exactly once, every
/// arrival signal fires (zero-size chunks included), across seeds and
/// skews, on a blocking 2-rail fabric.
#[test]
fn randomized_routing_conserves_every_token() {
    for seed in [1u64, 7, 1234] {
        for skew in [0.0, 1.5] {
            let cluster =
                ClusterSpec::h800(2, 4).with_fabric(FabricSpec::rail_optimized(2, 2.0));
            let ctx = ShmemCtx::new(cluster, DType::BF16);
            let topo = Topology::build(cluster);
            let ws = ctx.n_pes();
            let geom = EpGeom {
                t: 12,
                h: 3,
                f: 2,
                e: 16,
                k: 2,
                c: 24,
                w: ws,
            };
            let routing = EpRouting::generate(geom, skew, seed);
            let mut heap = SymmetricHeap::new(ws, 4 * ws);
            let bufs = A2aVarBufs::alloc(&mut heap, routing.dispatch_sizes());
            for r in 0..ws {
                let n = bufs.sizes.send_total(r);
                let vals: Vec<f32> = (0..n).map(|i| (r * 1_000_000 + i + 1) as f32).collect();
                heap.write(triton_dist_sim::mem::Slice::new(r, bufs.send, 0, n), &vals);
            }
            let mut pb = ProgBuild::new();
            a2a_ep_rails_var(&ctx, &bufs, &mut pb, &A2aCfg::ours(), A2aEpDir::Dispatch, None);
            let sim = Sim::new(&topo);
            sim.run(&pb.prog, &mut heap, &mut NoopExecutor).unwrap();
            let mut delivered = 0usize;
            for on in 0..ws {
                for src in 0..ws {
                    let got = heap.read(bufs.recv_slot(src, on)).to_vec();
                    let want = heap.read(bufs.send_chunk(on, src)).to_vec();
                    assert_eq!(got, want, "chunk {src}->{on} (seed {seed}, skew {skew})");
                    delivered += got.len();
                    assert_eq!(heap.signal(on, bufs.sig(src)), 1);
                }
            }
            assert_eq!(
                delivered,
                routing.kept() * geom.h,
                "conservation (seed {seed}, skew {skew})"
            );
        }
    }
}

/// Acceptance: the variable-size combine emits `Rails { tx != rx }`
/// spine-crossing classes whose routes land on exactly the claimed
/// planes under a tapered spine — same check as the uniform
/// `a2a_ep_rails` test, now with routing-sized messages.
#[test]
fn ep_rails_var_combine_claims_planes_under_taper() {
    let cluster = ClusterSpec::h800(2, 8)
        .with_fabric(FabricSpec::rail_optimized(2, 2.0).with_spine_taper(2.0));
    let ctx = ShmemCtx::new(cluster, DType::BF16);
    let topo = Topology::build(cluster);
    let ws = ctx.n_pes();
    let geom = EpGeom {
        t: 8,
        h: 4,
        f: 4,
        e: 16,
        k: 2,
        c: usize::MAX,
        w: ws,
    };
    let routing = EpRouting::generate(geom, 0.8, 5);
    let mut heap = SymmetricHeap::new(ws, 4 * ws);
    let bufs = A2aVarBufs::alloc(&mut heap, routing.combine_sizes());
    let mut pb = ProgBuild::new();
    a2a_ep_rails_var(&ctx, &bufs, &mut pb, &A2aCfg::ours(), A2aEpDir::Combine, None);

    let mut crossing = 0usize;
    for task in &pb.prog.tasks {
        for op in &task.ops {
            let Op::LLPut { src, dst, tc, .. } = op else {
                continue;
            };
            if cluster.node_of(src.rank) == cluster.node_of(dst.rank) {
                continue;
            }
            let TrafficClass::Rails { tx, rx } = *tc else {
                panic!("inter-node EP message without explicit planes: {tc:?}");
            };
            assert_eq!(tx as usize, cluster.local_rank(src.rank) % 2);
            assert_eq!(rx as usize, cluster.local_rank(dst.rank) % 2);
            if tx == rx {
                continue;
            }
            crossing += 1;
            let route = topo.route_tc(src.rank, dst.rank, *tc);
            let spine_owners: Vec<usize> = route
                .links
                .iter()
                .filter(|&&l| topo.link(l).kind == LinkKind::Spine)
                .map(|&l| topo.link(l).owner)
                .collect();
            assert_eq!(spine_owners, vec![tx as usize, rx as usize]);
        }
    }
    assert!(
        crossing > 0,
        "routed combine must produce spine-crossing messages"
    );
}

/// Splitting the NIC into rails without oversubscription keeps aggregate
/// bandwidth: the striped AllToAll on 2 rails lands close to the flat
/// single-rail makespan (same total capacity, different plane layout).
#[test]
fn multi_rail_nonblocking_preserves_aggregate_bandwidth() {
    let flat = a2a_makespan(ClusterSpec::h800(2, 8), 4096);
    let railed = a2a_makespan(
        ClusterSpec::h800(2, 8).with_fabric(FabricSpec::rail_optimized(2, 1.0)),
        4096,
    );
    assert!(
        railed < flat * 1.5 && flat < railed * 1.5,
        "2-rail non-blocking fabric should stay in the flat ballpark: \
         railed {railed} vs flat {flat}"
    );
}
