//! Property tests on coordinator/engine invariants: routing, batching,
//! scheduling, signal state — randomized over world sizes, shard sizes,
//! swizzle configs and message sizes.

use triton_dist_sim::collectives::allgather::*;
use triton_dist_sim::collectives::alltoall::{a2a_ll, fill_a2a_inputs, verify_alltoall, A2aBufs, A2aCfg};
use triton_dist_sim::collectives::reduce_scatter::rs_push_intra;
use triton_dist_sim::collectives::*;
use triton_dist_sim::config::{ClusterSpec, DType};
use triton_dist_sim::mem::SymmetricHeap;
use triton_dist_sim::overlap::swizzle;
use triton_dist_sim::program::{Op, Program, SigCond, TaskBuilder};
use triton_dist_sim::shmem::ShmemCtx;
use triton_dist_sim::sim::{FlowNet, NoopExecutor, Sim};
use triton_dist_sim::topology::{LinkId, Topology};
use triton_dist_sim::util::prop::{check, Gen};

fn random_cluster(g: &mut Gen) -> ClusterSpec {
    match g.usize_in(0, 4) {
        0 => ClusterSpec::h800(1, *g.pick(&[2usize, 4, 8])),
        1 => ClusterSpec::h800(*g.pick(&[2usize, 4]), *g.pick(&[2usize, 4, 8])),
        2 => ClusterSpec::mi308x(*g.pick(&[4usize, 8])),
        _ => ClusterSpec::l20(1, *g.pick(&[4usize, 8])),
    }
}

#[test]
fn prop_allgather_always_concat() {
    check("allgather=concat", 30, |g| {
        let cluster = random_cluster(g);
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let shard = g.usize_in(1, 200);
        let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes().max(16));
        // pick a variant valid for the geometry
        let is_h800 = matches!(cluster.hw.kind, triton_dist_sim::config::HardwareKind::H800);
        let multi = cluster.nodes > 1;
        let variant = g.usize_in(0, 3);
        let bufs;
        let mut pb = ProgBuild::new();
        match (variant, is_h800, multi) {
            (0, _, false) | (0, _, true) if !multi => {
                bufs = AgBufs::alloc(&mut heap, &ctx, shard);
                fill_ag_inputs(&mut heap, &bufs, g.u64());
                ag_push_intra(&ctx, &bufs, &mut pb);
            }
            (1, true, true) => {
                bufs = AgBufs::alloc(&mut heap, &ctx, shard);
                fill_ag_inputs(&mut heap, &bufs, g.u64());
                ag_inter(&ctx, &bufs, &mut pb);
            }
            (2, true, false) => {
                bufs = AgBufs::alloc_ll(&mut heap, &ctx, shard);
                fill_ag_inputs(&mut heap, &bufs, g.u64());
                ag_ll_intra(&ctx, &bufs, &mut pb);
            }
            _ => {
                bufs = AgBufs::alloc(&mut heap, &ctx, shard);
                fill_ag_inputs(&mut heap, &bufs, g.u64());
                if multi {
                    ag_inter(&ctx, &bufs, &mut pb);
                } else {
                    ag_pull_intra(&ctx, &bufs, &mut pb);
                }
            }
        }
        let expected = expected_allgather(&heap, &bufs);
        let rep = Sim::new(&topo)
            .run(&pb.prog, &mut heap, &mut NoopExecutor)
            .unwrap();
        verify_allgather(&heap, &bufs, &expected).unwrap();
        assert!(rep.makespan.is_finite() && rep.makespan > 0.0);
    });
}

#[test]
fn prop_reduce_scatter_always_sums() {
    check("rs=reduce", 25, |g| {
        let ws = *g.pick(&[2usize, 4, 8]);
        let cluster = ClusterSpec::h800(1, ws);
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let shard = g.usize_in(1, 120);
        let mut heap = SymmetricHeap::new(ws, 8 * ws.max(16));
        let bufs = RsBufs::alloc(&mut heap, &ctx, shard);
        fill_rs_inputs(&mut heap, &bufs, g.u64());
        let expected = expected_reduce_scatter(&heap, &bufs);
        let mut pb = ProgBuild::new();
        let reduce_sms = g.usize_in(1, 33) as u32;
        rs_push_intra(&ctx, &bufs, &mut pb, reduce_sms, None);
        Sim::new(&topo)
            .run(&pb.prog, &mut heap, &mut NoopExecutor)
            .unwrap();
        verify_reduce_scatter(&heap, &bufs, &expected).unwrap();
    });
}

#[test]
fn prop_alltoall_roundtrip_identity() {
    check("a2a identity", 20, |g| {
        let cluster = if g.bool() {
            ClusterSpec::h800(1, *g.pick(&[2usize, 4, 8]))
        } else {
            ClusterSpec::h800(2, *g.pick(&[2usize, 4]))
        };
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let chunk = g.usize_in(1, 100);
        let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes());
        let bufs = A2aBufs::alloc(&mut heap, &ctx, chunk);
        fill_a2a_inputs(&mut heap, &bufs, g.u64());
        let mut pb = ProgBuild::new();
        a2a_ll(&ctx, &bufs, &mut pb, &A2aCfg::ours());
        Sim::new(&topo)
            .run(&pb.prog, &mut heap, &mut NoopExecutor)
            .unwrap();
        verify_alltoall(&heap, &bufs).unwrap();
    });
}

#[test]
fn prop_swizzles_are_permutations() {
    check("swizzle perms", 100, |g| {
        let ws = g.usize_in(1, 33);
        let r = g.usize_in(0, ws);
        assert!(swizzle::is_permutation(&swizzle::nv_push_order(r, ws), ws));
        assert!(swizzle::is_permutation(&swizzle::nv_pull_order(r, ws), ws));
        let nodes = *g.pick(&[2usize, 3, 4]);
        let lws = *g.pick(&[2usize, 4, 8]);
        let rank = g.usize_in(0, nodes * lws);
        assert!(swizzle::is_permutation(
            &swizzle::inter_rs_order(rank, nodes, lws),
            nodes * lws
        ));
        // sub-chunk order covers the full (chunk, sub) grid
        let subs = g.usize_in(1, 5);
        let order = swizzle::amd_subchunk_order(r, ws, subs);
        let mut set: Vec<_> = order.clone();
        set.sort_unstable();
        set.dedup();
        assert_eq!(set.len(), ws * subs);
    });
}

#[test]
fn prop_flow_network_never_oversubscribes() {
    check("flow capacity", 60, |g| {
        let nl = g.usize_in(1, 8);
        let caps: Vec<f64> = (0..nl).map(|_| 1.0 + g.f64() * 99.0).collect();
        let mut net = FlowNet::new(caps);
        let mut alive = Vec::new();
        let mut now = 0.0;
        for _ in 0..g.usize_in(1, 30) {
            now += g.f64();
            if !alive.is_empty() && g.bool() && g.bool() {
                let idx = g.usize_in(0, alive.len());
                let id = alive.swap_remove(idx);
                net.remove(now, id);
            } else {
                let mut links: Vec<LinkId> =
                    (0..nl).filter(|_| g.bool()).map(LinkId).collect();
                if links.is_empty() {
                    links.push(LinkId(g.usize_in(0, nl)));
                }
                let (id, _) = net.add(now, links, 1.0 + g.f64() * 1e6);
                alive.push(id);
            }
            net.check_capacity().unwrap();
        }
    });
}

#[test]
fn prop_engine_rejects_deadlocks_deterministically() {
    check("deadlock detect", 20, |g| {
        let ws = *g.pick(&[2usize, 4]);
        let cluster = ClusterSpec::h800(1, ws);
        let topo = Topology::build(cluster);
        let mut heap = SymmetricHeap::new(ws, 16);
        let mut prog = Program::new();
        // some healthy tasks
        for r in 0..ws {
            let mut t = TaskBuilder::new(r, format!("ok{r}"));
            t.op(Op::Sleep { secs: 1e-6 });
            prog.push(t.build());
        }
        // one stuck task waiting for a never-set signal
        let stuck_rank = g.usize_in(0, ws);
        let mut t = TaskBuilder::new(stuck_rank, "stuck");
        t.op(Op::WaitSignal {
            idx: 9,
            cond: SigCond::Eq,
            value: 1,
        });
        prog.push(t.build());
        let err = Sim::new(&topo)
            .run(&prog, &mut heap, &mut NoopExecutor)
            .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("stuck"), "{msg}");
    });
}

#[test]
fn prop_numa_interleave_preserves_multiset() {
    check("numa multiset", 60, |g| {
        let n = g.usize_in(1, 24);
        let peers: Vec<usize> = (0..n).map(|_| g.usize_in(0, 40)).collect();
        let domains = g.usize_in(1, 5);
        let out = swizzle::numa_interleave(&peers, |r| r % domains);
        let mut a = out.clone();
        let mut b = peers.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    });
}
