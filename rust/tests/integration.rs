//! Cross-module integration: fused ops on multiple cluster geometries,
//! CLI surface, tracing, and autotune-over-coordinator wiring.

use triton_dist_sim::autotune;
use triton_dist_sim::config::{ClusterSpec, GemmShape, MoeShape};
use triton_dist_sim::coordinator::{self, ag_gemm, ep_moe, flash_decode, gemm_rs, moe};
use triton_dist_sim::metrics;
use triton_dist_sim::overlap::features;
use triton_dist_sim::runtime::HybridExecutor;
use triton_dist_sim::topology::Topology;

#[test]
fn ag_gemm_all_variants_all_geometries() {
    // every (variant, geometry) pair must complete with correct numerics
    let cases: Vec<(ClusterSpec, ag_gemm::AgGemmVariant)> = vec![
        (ClusterSpec::h800(1, 2), ag_gemm::AgGemmVariant::OursPush),
        (ClusterSpec::h800(1, 4), ag_gemm::AgGemmVariant::OursPush),
        (ClusterSpec::h800(1, 8), ag_gemm::AgGemmVariant::OursPull),
        (ClusterSpec::h800(1, 8), ag_gemm::AgGemmVariant::OursLL),
        (ClusterSpec::h800(2, 4), ag_gemm::AgGemmVariant::OursInter),
        (ClusterSpec::h800(4, 2), ag_gemm::AgGemmVariant::OursInter),
        (ClusterSpec::h800(1, 8), ag_gemm::AgGemmVariant::Nccl),
        (ClusterSpec::h800(1, 8), ag_gemm::AgGemmVariant::Flux),
        (ClusterSpec::mi308x(4), ag_gemm::AgGemmVariant::OursAmd { sub_chunks: 2 }),
        (ClusterSpec::l20(1, 4), ag_gemm::AgGemmVariant::OursPush),
    ];
    for (cluster, variant) in cases {
        let ws = cluster.world_size();
        let shape = GemmShape::new(8 * ws, 8, 16);
        let (mut op, bufs) = ag_gemm::build(cluster, shape, variant);
        ag_gemm::fill_inputs(&mut op.heap, &bufs, 9);
        let reference = ag_gemm::reference_output(&op.heap, &bufs);
        let topo = Topology::build(cluster);
        let mut exec = HybridExecutor::native_only();
        coordinator::run_numeric(&mut op, &topo, &mut exec).unwrap();
        ag_gemm::verify(&op.heap, &bufs, &reference)
            .unwrap_or_else(|e| panic!("{}: {e}", op.name));
    }
}

#[test]
fn gemm_rs_all_variants() {
    let cases: Vec<(ClusterSpec, gemm_rs::GemmRsVariant)> = vec![
        (ClusterSpec::h800(1, 4), gemm_rs::GemmRsVariant::OursIntra),
        (ClusterSpec::h800(2, 4), gemm_rs::GemmRsVariant::OursInter),
        (ClusterSpec::h800(4, 2), gemm_rs::GemmRsVariant::OursInter),
        (ClusterSpec::mi308x(8), gemm_rs::GemmRsVariant::OursAmd { comm_tiles: 2 }),
        (ClusterSpec::h800(1, 8), gemm_rs::GemmRsVariant::Nccl),
        (ClusterSpec::h800(1, 8), gemm_rs::GemmRsVariant::Flux),
    ];
    for (cluster, variant) in cases {
        let ws = cluster.world_size();
        let shape = GemmShape::new(4 * ws, 8, 12);
        let (mut op, bufs) = gemm_rs::build(cluster, shape, variant);
        gemm_rs::fill_inputs(&mut op.heap, &bufs, 17);
        let expected = gemm_rs::reference_outputs(&op.heap, &bufs);
        let topo = Topology::build(cluster);
        let mut exec = HybridExecutor::native_only();
        coordinator::run_numeric(&mut op, &topo, &mut exec).unwrap();
        gemm_rs::verify(&op.heap, &bufs, &expected)
            .unwrap_or_else(|e| panic!("{}: {e}", op.name));
    }
}

#[test]
fn moe_both_directions_inter_node() {
    let shape = MoeShape {
        tokens_per_rank: 4,
        in_hidden: 8,
        out_hidden: 16,
        experts: 4,
        topk: 2,
        ..MoeShape::default()
    };
    for cluster in [ClusterSpec::h800(1, 8), ClusterSpec::h800(2, 4)] {
        let topo = Topology::build(cluster);
        let (mut op, bufs) = moe::build_ag_moe(cluster, shape, moe::MoeVariant::Ours);
        moe::fill_ag_moe(&mut op.heap, &bufs, 5);
        let exp = moe::reference_ag_moe(&op.heap, &bufs);
        let mut exec = HybridExecutor::native_only();
        coordinator::run_numeric(&mut op, &topo, &mut exec).unwrap();
        moe::verify_ag_moe(&op.heap, &bufs, &exp).unwrap();

        let (mut op2, bufs2) = moe::build_moe_rs(cluster, shape, moe::MoeVariant::Ours);
        moe::fill_moe_rs(&mut op2.heap, &bufs2, 6);
        let exp2 = moe::reference_moe_rs(&op2.heap, &bufs2);
        coordinator::run_numeric(&mut op2, &topo, &mut exec).unwrap();
        moe::verify_moe_rs(&op2.heap, &bufs2, &exp2).unwrap();
    }
}

#[test]
fn ep_moe_pipeline_across_geometries_and_skews() {
    // token-routed EP pipeline: exact numerics (token conservation +
    // bitwise output equality) across geometries, skews, and capacity
    // factors, including drop-inducing configurations
    let base = MoeShape {
        tokens_per_rank: 5,
        in_hidden: 6,
        out_hidden: 4,
        experts: 8,
        topk: 2,
        ..MoeShape::default()
    };
    let cases = [
        (ClusterSpec::h800(1, 4), base, 21u64),
        (ClusterSpec::h800(2, 2), base.with_skew(1.0), 22),
        (ClusterSpec::h800(2, 4), base.with_skew(2.0).with_capacity_factor(0.6), 23),
        (ClusterSpec::mi308x(4), base.with_skew(0.5), 24),
    ];
    for (cluster, shape, seed) in cases {
        let routing = ep_moe::routing_for(cluster, &shape, seed);
        let (mut op, bufs) =
            ep_moe::build_ep_moe(cluster, shape, &routing, ep_moe::EpMoeVariant::TokenRouted);
        ep_moe::fill_ep_moe(&mut op.heap, &bufs, &routing, seed);
        let expected = ep_moe::reference_ep_moe(&op.heap, &bufs, &routing);
        let topo = Topology::build(cluster);
        let mut exec = HybridExecutor::native_only();
        coordinator::run_numeric(&mut op, &topo, &mut exec).unwrap();
        ep_moe::verify_ep_moe(&op.heap, &bufs, &routing, &expected)
            .unwrap_or_else(|e| panic!("{}: {e}", op.name));
    }
}

#[test]
fn flash_decode_three_platforms() {
    for cluster in [
        ClusterSpec::h800(1, 4),
        ClusterSpec::h800(2, 2),
        ClusterSpec::l20(1, 4),
    ] {
        let cfg = flash_decode::FlashDecodeCfg {
            heads: 2,
            head_dim: 8,
            kv_per_rank: 16,
            numeric: true,
        };
        let (mut op, bufs) = flash_decode::build(cluster, cfg);
        flash_decode::fill_inputs(&mut op.heap, &bufs, 23);
        let exp = flash_decode::reference_output(&op.heap, &bufs);
        let topo = Topology::build(cluster);
        let mut exec = HybridExecutor::native_only();
        coordinator::run_numeric(&mut op, &topo, &mut exec).unwrap();
        flash_decode::verify(&op.heap, &bufs, &exp).unwrap();
    }
}

#[test]
fn traced_run_produces_coherent_timeline() {
    let cluster = ClusterSpec::h800(1, 4);
    let shape = GemmShape::new(32, 8, 16);
    let (mut op, bufs) = ag_gemm::build(cluster, shape, ag_gemm::AgGemmVariant::OursPush);
    ag_gemm::fill_inputs(&mut op.heap, &bufs, 2);
    let topo = Topology::build(cluster);
    let mut exec = HybridExecutor::native_only();
    let rep = coordinator::run_traced(&mut op, &topo, &mut exec).unwrap();
    assert!(!rep.op_spans.is_empty());
    for s in &rep.op_spans {
        assert!(s.t0 <= s.t1, "span goes backwards");
        assert!(s.t1 <= rep.makespan + 1e-12, "span exceeds makespan");
    }
    // timeline + chrome trace render
    let tl = metrics::ascii_timeline(&rep, 80);
    assert!(tl.contains("r0"));
    let trace = metrics::chrome_trace(&rep);
    assert!(triton_dist_sim::util::json::parse(&trace).is_ok());
}

#[test]
fn autotune_over_gemm_rs_partition() {
    // tune the reduce-SM budget on the real coordinator (ablation of the
    // §3.5 analysis): the analytic value should be near-optimal.
    let cluster = ClusterSpec::h800(1, 8);
    let topo = Topology::build(cluster);
    let shape = GemmShape::new(2048, 12288 / 8, 4096);
    let result = autotune::tune_rebuild("gemm_rs reduce sms", &[15u32], |_| {
        let (mut op, _b) = gemm_rs::build(cluster, shape, gemm_rs::GemmRsVariant::OursIntra);
        Ok(coordinator::run_timing(&mut op, &topo).unwrap())
    })
    .unwrap();
    assert!(result.best.latency > 0.0);
}

#[test]
fn feature_table_covers_paper_claims() {
    let s = features::render_table2();
    // Ours supports everything (13 rows of Y in the last column)
    let y_count = s
        .lines()
        .filter(|l| l.trim_end().ends_with('Y'))
        .count();
    assert!(y_count >= 13, "expected 13 'ours=Y' rows, table:\n{s}");
}

#[test]
fn determinism_across_runs() {
    let run = || {
        let cluster = ClusterSpec::h800(2, 4);
        let shape = GemmShape::new(64, 16, 16);
        let (mut op, bufs) = ag_gemm::build(cluster, shape, ag_gemm::AgGemmVariant::OursInter);
        ag_gemm::fill_inputs(&mut op.heap, &bufs, 77);
        let topo = Topology::build(cluster);
        let mut exec = HybridExecutor::native_only();
        let rep = coordinator::run_numeric(&mut op, &topo, &mut exec).unwrap();
        (rep.makespan, rep.events, rep.flows)
    };
    assert_eq!(run(), run());
}
