//! PJRT runtime round-trip: load every AOT artifact, execute through the
//! CPU PJRT client, and cross-check against the native Rust reference
//! math. Skips (loudly) when `make artifacts` hasn't been run.

use triton_dist_sim::kernels::exec::eval_named;
use triton_dist_sim::runtime::XlaRuntime;
use triton_dist_sim::util::Rng;

fn runtime() -> Option<XlaRuntime> {
    match XlaRuntime::try_default() {
        Some(rt) => Some(rt),
        None => {
            eprintln!("SKIP: no artifacts (run `make artifacts`)");
            None
        }
    }
}

fn close(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        if (x - y).abs() > tol {
            return Err(format!("elem {i}: {x} vs {y}"));
        }
    }
    Ok(())
}

#[test]
fn every_artifact_matches_native_reference() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = Rng::new(0xA0A0);
    let mut checked = 0;
    for name in rt.entry_names() {
        let Some(sig) = rt_sig(&rt, &name) else {
            continue;
        };
        // random f32 inputs (int32 args: small non-negative values)
        let args: Vec<Vec<f32>> = sig
            .iter()
            .map(|(len, is_int, int_cap)| {
                if *is_int {
                    (0..*len).map(|_| rng.usize_in(0, *int_cap) as f32).collect()
                } else {
                    rng.normal_vec(*len)
                }
            })
            .collect();
        let xla_out = rt
            .call_f32(&name, &args)
            .unwrap_or_else(|e| panic!("xla call '{name}' failed: {e:#}"));
        let native_out = eval_named(&name, &args)
            .unwrap_or_else(|e| panic!("native eval '{name}' failed: {e:#}"));
        assert_eq!(xla_out.len(), native_out.len(), "{name}: output arity");
        for (i, (x, n)) in xla_out.iter().zip(&native_out).enumerate() {
            close(x, n, 2e-3, 2e-3)
                .unwrap_or_else(|e| panic!("'{name}' output {i} mismatch: {e}"));
        }
        checked += 1;
    }
    assert!(checked >= 10, "only {checked} artifacts verified");
    println!("verified {checked} artifacts against native reference");
}

/// (len, is_int, int_value_cap) per argument, reading the manifest
/// through the public API; int caps derived from entry names (expert
/// counts for moe topk indices).
fn rt_sig(rt: &XlaRuntime, name: &str) -> Option<Vec<(usize, bool, usize)>> {
    use triton_dist_sim::kernels::names::Entry;
    let parsed = Entry::parse(name)?;
    let int_cap = match parsed {
        Entry::MoeFfn { e, .. } => e,
        _ => 1,
    };
    // arg lens from the native entry's expectations: probe the manifest
    // via a tiny helper — we re-derive from the parsed entry directly.
    let lens: Vec<(usize, bool, usize)> = match parsed {
        Entry::Gemm { m, k, n } => vec![(m * k, false, 0), (k * n, false, 0)],
        Entry::GroupGemm { e, c, h, f } => vec![(e * c * h, false, 0), (e * h * f, false, 0)],
        Entry::DecodePartial { h, s, d } => vec![
            (h * d, false, 0),
            (h * s * d, false, 0),
            (h * s * d, false, 0),
        ],
        Entry::DecodeCombine { h, p, d } => {
            vec![(h * p * d, false, 0), (h * p, false, 0), (h * p, false, 0)]
        }
        Entry::DecodeCombineSeg { h, p, d } => vec![(h * (d + 2), false, 0); p],
        Entry::MoeFfn { t, h, f, e, k, .. } => vec![
            (t * h, false, 0),
            (t * k, true, int_cap),
            (t * k, false, 0),
            (e * h * f, false, 0),
        ],
        Entry::TpMlpShard { t, h, f } => {
            vec![(t * h, false, 0), (h * f, false, 0), (f * h, false, 0)]
        }
        Entry::TpAttnShard { t, h, nh, hd, s } => vec![
            (t * h, false, 0),
            (h * nh * hd, false, 0),
            (h * nh * hd, false, 0),
            (h * nh * hd, false, 0),
            (nh * hd * h, false, 0),
            (nh * s * hd, false, 0),
            (nh * s * hd, false, 0),
        ],
    };
    let _ = rt;
    Some(lens)
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(mut rt) = runtime() else { return };
    let name = "gemm_64x64x64";
    if !rt.has_entry(name) {
        panic!("catalog must include {name}");
    }
    let mut rng = Rng::new(7);
    let x = rng.normal_vec(64 * 64);
    let w = rng.normal_vec(64 * 64);
    let a = rt.call_f32(name, &[x.clone(), w.clone()]).unwrap();
    let b = rt.call_f32(name, &[x, w]).unwrap();
    assert_eq!(a, b, "cached executable must be deterministic");
    assert_eq!(rt.calls, 2);
}

#[test]
fn hybrid_executor_prefers_xla_in_fused_op() {
    // Run a full AG+GEMM with shapes matching the artifact catalog and
    // confirm the consumer tiles went through PJRT.
    if XlaRuntime::try_default().is_none() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    use triton_dist_sim::config::{ClusterSpec, GemmShape};
    use triton_dist_sim::coordinator::{self, ag_gemm};
    use triton_dist_sim::runtime::HybridExecutor;
    use triton_dist_sim::topology::Topology;
    // catalog has gemm_64x64x64: m_per_rank=64 (ws=4, M=256), k=n=64
    let cluster = ClusterSpec::h800(1, 4);
    let shape = GemmShape::new(256, 64, 64);
    let (mut op, bufs) = ag_gemm::build(cluster, shape, ag_gemm::AgGemmVariant::OursPush);
    ag_gemm::fill_inputs(&mut op.heap, &bufs, 5);
    let reference = ag_gemm::reference_output(&op.heap, &bufs);
    let topo = Topology::build(cluster);
    let mut exec = HybridExecutor::auto();
    coordinator::run_numeric(&mut op, &topo, &mut exec).unwrap();
    assert!(exec.xla_calls > 0, "no tile went through PJRT");
    // PJRT f32 matmul on CPU may reassociate; tolerance check vs reference
    let got = op
        .heap
        .read(triton_dist_sim::mem::Slice::new(0, bufs.output, 0, reference.len()));
    for (i, (g, e)) in got.iter().zip(&reference).enumerate() {
        assert!(
            (g - e).abs() <= 1e-3 + 1e-3 * e.abs(),
            "elem {i}: {g} vs {e}"
        );
    }
    println!("AG+GEMM numerics via PJRT: {} xla calls", exec.xla_calls);
}
