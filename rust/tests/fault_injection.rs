//! Fault-injection invariants (ISSUE tentpole acceptance):
//!
//! 1. **Bit-identity**: an empty [`FaultPlan`] is bit-for-bit identical
//!    to the fault-free engine on every workload shape — same makespan
//!    bits, same event/flow counts, same task spans, all-zero ledger.
//! 2. **Determinism**: the same (workload, fault seed) replays the
//!    identical timeline.
//! 3. **Liveness**: under arbitrary synthesized flap schedules a run
//!    either completes (with exact token conservation on the EP MoE
//!    numerics) or terminates with a structured watchdog error — it
//!    never hangs.
//! 4. **The headline contrast**: under a mid-dispatch rail flap,
//!    Adaptive + retry strictly beats Static + retry (the self-healing
//!    pinned-rail reroute vs the backoff ladder).

use triton_dist_sim::collectives::alltoall::{a2a_ll, A2aBufs, A2aCfg};
use triton_dist_sim::collectives::ProgBuild;
use triton_dist_sim::config::{
    ClusterSpec, DType, FabricSpec, FaultPlan, GemmShape, MoeShape, RailPolicy,
};
use triton_dist_sim::coordinator::{ag_gemm, ep_moe, run_timing_faults};
use triton_dist_sim::mem::SymmetricHeap;
use triton_dist_sim::shmem::ShmemCtx;
use triton_dist_sim::sim::{NoopExecutor, Sim, SimConfig, SimError, SimReport};
use triton_dist_sim::topology::Topology;
use triton_dist_sim::util::prop::{check, Gen};

fn timing_sim(topo: &Topology) -> Sim<'_> {
    Sim::with_config(
        topo,
        SimConfig {
            numerics: false,
            trace: false,
        },
    )
}

/// Run one of the three workload shapes twice — fault-free engine vs an
/// engine with an (empty or given) plan attached — and return both
/// reports.
fn bit_identity_pair(shape: usize, plan: FaultPlan) -> (SimReport, SimReport) {
    match shape {
        // fig13 shape: inter-node AG+GEMM
        0 => {
            let cluster = ClusterSpec::h800(2, 4);
            let topo = Topology::build(cluster);
            let gemm = GemmShape::new(1024, 512, 512);
            let run = |faults: Option<FaultPlan>| {
                let (mut op, _b) =
                    ag_gemm::build(cluster, gemm, ag_gemm::AgGemmVariant::OursInter);
                let mut sim = timing_sim(&topo);
                if let Some(p) = faults {
                    sim = sim.with_faults(p);
                }
                sim.run(&op.prog, &mut op.heap, &mut NoopExecutor).unwrap()
            };
            (run(None), run(Some(plan)))
        }
        // fig16 shape: railed LL AllToAll
        1 => {
            let cluster = ClusterSpec::h800(2, 4).with_fabric(
                FabricSpec::rail_optimized(2, 2.0).with_rail_policy(RailPolicy::Adaptive),
            );
            let ctx = ShmemCtx::new(cluster, DType::BF16);
            let topo = Topology::build(cluster);
            let run = |faults: Option<FaultPlan>| {
                let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes());
                let bufs = A2aBufs::alloc(&mut heap, &ctx, 512);
                let mut pb = ProgBuild::new();
                a2a_ll(&ctx, &bufs, &mut pb, &A2aCfg::ours());
                let mut sim = timing_sim(&topo);
                if let Some(p) = faults {
                    sim = sim.with_faults(p);
                }
                sim.run(&pb.prog, &mut heap, &mut NoopExecutor).unwrap()
            };
            (run(None), run(Some(plan)))
        }
        // EP MoE shape: token-routed over the tapered railed fabric
        _ => {
            let cluster = ClusterSpec::h800(2, 4)
                .with_fabric(FabricSpec::rail_optimized(2, 2.0).with_spine_taper(2.0));
            let shape = MoeShape {
                tokens_per_rank: 16,
                in_hidden: 64,
                out_hidden: 64,
                experts: 8,
                topk: 2,
                ..MoeShape::default()
            }
            .with_skew(1.2);
            let routing = ep_moe::routing_for(cluster, &shape, 5);
            let topo = Topology::build(cluster);
            let run = |faults: Option<FaultPlan>| {
                let (mut op, _b) = ep_moe::build_ep_moe(
                    cluster,
                    shape,
                    &routing,
                    ep_moe::EpMoeVariant::TokenRouted,
                );
                let mut sim = timing_sim(&topo);
                if let Some(p) = faults {
                    sim = sim.with_faults(p);
                }
                sim.run(&op.prog, &mut op.heap, &mut NoopExecutor).unwrap()
            };
            (run(None), run(Some(plan)))
        }
    }
}

fn assert_reports_identical(a: &SimReport, b: &SimReport) {
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "makespan bits");
    assert_eq!(a.events, b.events, "event count");
    assert_eq!(a.flows, b.flows, "flow count");
    assert_eq!(a.ledger, b.ledger, "ledger");
    assert_eq!(a.task_spans.len(), b.task_spans.len());
    for (x, y) in a.task_spans.iter().zip(&b.task_spans) {
        assert_eq!(x.0, y.0);
        assert_eq!(x.1, y.1);
        assert_eq!(x.2.to_bits(), y.2.to_bits(), "task start bits ({})", x.0);
        assert_eq!(x.3.to_bits(), y.3.to_bits(), "task end bits ({})", x.0);
    }
}

#[test]
fn empty_fault_plan_is_bit_identical_across_shapes() {
    check("empty plan == fault-free engine", 9, |g: &mut Gen| {
        let shape = g.usize_in(0, 3);
        let (clean, attached) = bit_identity_pair(shape, FaultPlan::default());
        assert_reports_identical(&clean, &attached);
        assert_eq!(attached.ledger, Default::default(), "ledger must be zero");
    });
}

#[test]
fn randomized_flap_schedules_never_hang_and_conserve_tokens() {
    use triton_dist_sim::runtime::HybridExecutor;
    let cluster = ClusterSpec::h800(2, 2)
        .with_fabric(FabricSpec::rail_optimized(2, 2.0).with_rail_policy(RailPolicy::Adaptive));
    let shape = MoeShape {
        tokens_per_rank: 6,
        in_hidden: 8,
        out_hidden: 8,
        experts: 8,
        topk: 2,
        ..MoeShape::default()
    };
    let topo = Topology::build(cluster);
    check("flaps: terminate + conserve tokens", 8, |g: &mut Gen| {
        let fault_seed = g.u64();
        let mut plan = FaultPlan::synthesize(fault_seed, 1.0, 4, 2, 1e-3);
        // arm the watchdog: a wedged wait must become a structured
        // error, never a hang
        plan.lt_timeout = 50e-3;
        let routing = ep_moe::routing_for(cluster, &shape, 3);
        let (mut op, bufs) = ep_moe::build_ep_moe(
            cluster,
            shape,
            &routing,
            ep_moe::EpMoeVariant::TokenRouted,
        );
        ep_moe::fill_ep_moe(&mut op.heap, &bufs, &routing, 3);
        let expected = ep_moe::reference_ep_moe(&op.heap, &bufs, &routing);
        let sim = Sim::with_config(
            &topo,
            SimConfig {
                numerics: true,
                trace: false,
            },
        )
        .with_faults(plan);
        let mut exec = HybridExecutor::native_only();
        match sim.run(&op.prog, &mut op.heap, &mut exec) {
            Ok(_rep) => {
                // the retried wire still delivered every routed row
                // exactly once, bit-exactly
                ep_moe::verify_ep_moe(&op.heap, &bufs, &routing, &expected)
                    .unwrap_or_else(|e| panic!("seed {fault_seed}: {e}"));
            }
            Err(SimError::WatchdogTimeout { at, .. }) => {
                assert!(at.is_finite(), "watchdog must carry the failure time");
            }
            Err(e) => panic!("seed {fault_seed}: non-watchdog failure: {e}"),
        }
    });
}

#[test]
fn same_fault_seed_replays_identical_timeline() {
    let plan = {
        let mut p = FaultPlan::synthesize(42, 1.5, 8, 2, 1e-3);
        p.lt_timeout = 50e-3;
        p
    };
    let run = || bit_identity_pair(1, plan.clone()).1;
    let a = run();
    let b = run();
    assert_reports_identical(&a, &b);
}

#[test]
fn adaptive_retry_strictly_beats_static_retry_on_mid_dispatch_flap() {
    // spine plane 0 dies at t=5us and returns at t=505us, mid-dispatch.
    // Static honors the EP rail pins and climbs the retry backoff ladder
    // until the plane returns; Adaptive self-heals the pinned routes onto
    // the surviving plane at the first retry. This is the perf suite's
    // `moe-ep-rail-flap` contrast, pinned.
    let shape = MoeShape {
        tokens_per_rank: 32,
        in_hidden: 128,
        out_hidden: 128,
        experts: 8,
        topk: 2,
        ..MoeShape::default()
    }
    .with_skew(1.2);
    let run = |policy: RailPolicy| -> SimReport {
        let cluster = ClusterSpec::h800(2, 4).with_fabric(
            FabricSpec::rail_optimized(2, 2.0)
                .with_spine_taper(2.0)
                .with_rail_policy(policy),
        );
        let routing = ep_moe::routing_for(cluster, &shape, 7);
        let topo = Topology::build(cluster);
        let (mut op, _b) = ep_moe::build_ep_moe(
            cluster,
            shape,
            &routing,
            ep_moe::EpMoeVariant::TokenRouted,
        );
        let plan = FaultPlan::parse("flap,spine,0,5e-6,5e-4").unwrap();
        run_timing_faults(&mut op, &topo, plan).unwrap()
    };
    let stat = run(RailPolicy::Static);
    let adap = run(RailPolicy::Adaptive);
    assert!(
        adap.makespan < stat.makespan,
        "adaptive+retry ({}) must strictly beat static+retry ({})",
        adap.makespan,
        stat.makespan
    );
    // static visibly stalled: flows died on the downed plane and climbed
    // the backoff ladder past the flap window
    assert!(stat.ledger.flows_killed > 0, "static must lose flows");
    assert!(stat.ledger.retries > 1, "static must climb the ladder");
    assert!(
        stat.makespan > 500e-6,
        "static must stall past the flap window, got {}",
        stat.makespan
    );
    // adaptive recovered: whatever was killed got rerouted, nothing
    // exhausted its retry budget
    assert_eq!(adap.ledger.retries_exhausted, 0);
}

#[test]
fn watchdog_surfaces_structured_coordinator_error() {
    // both planes permanently dead from t=0: every inter-node wait is
    // unsatisfiable, so the watchdog must turn the run into a structured
    // CoordError carrying the op name and virtual failure time
    let cluster = ClusterSpec::h800(2, 4)
        .with_fabric(FabricSpec::rail_optimized(2, 2.0).with_rail_policy(RailPolicy::Adaptive));
    let shape = MoeShape {
        tokens_per_rank: 8,
        in_hidden: 16,
        out_hidden: 16,
        experts: 8,
        topk: 2,
        ..MoeShape::default()
    };
    let routing = ep_moe::routing_for(cluster, &shape, 2);
    let topo = Topology::build(cluster);
    let (mut op, _b) =
        ep_moe::build_ep_moe(cluster, shape, &routing, ep_moe::EpMoeVariant::TokenRouted);
    let mut plan = FaultPlan::parse("raildead,0,0; raildead,1,0").unwrap();
    plan.lt_timeout = 1e-3;
    let err = run_timing_faults(&mut op, &topo, plan).expect_err("must time out");
    assert!(err.at.is_some(), "watchdog failure time must surface");
    let msg = err.to_string();
    assert!(msg.contains("EP MoE"), "op name in error: {msg}");
    assert!(msg.contains("timed out") || msg.contains("watchdog"), "{msg}");
}
