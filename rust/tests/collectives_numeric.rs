//! Numeric grids: every collective variant x topology x world size must
//! produce exactly the reference result through the DES.

use triton_dist_sim::collectives::allgather::*;
use triton_dist_sim::collectives::alltoall::{
    a2a_deepep, a2a_ll, fill_a2a_inputs, roundtrip_check, verify_alltoall, A2aBufs, A2aCfg,
};
use triton_dist_sim::collectives::baseline::*;
use triton_dist_sim::collectives::reduce_scatter::*;
use triton_dist_sim::collectives::*;
use triton_dist_sim::config::{ClusterSpec, DType};
use triton_dist_sim::mem::SymmetricHeap;
use triton_dist_sim::shmem::ShmemCtx;
use triton_dist_sim::sim::{NoopExecutor, Sim};
use triton_dist_sim::topology::Topology;

fn run_ag(
    cluster: ClusterSpec,
    shard: usize,
    ll: bool,
    build: impl Fn(&ShmemCtx, &AgBufs, &mut ProgBuild),
) {
    let ctx = ShmemCtx::new(cluster, DType::BF16);
    let topo = Topology::build(cluster);
    let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes().max(16));
    let bufs = if ll {
        AgBufs::alloc_ll(&mut heap, &ctx, shard)
    } else {
        AgBufs::alloc(&mut heap, &ctx, shard)
    };
    fill_ag_inputs(&mut heap, &bufs, 1234);
    let expected = expected_allgather(&heap, &bufs);
    let mut pb = ProgBuild::new();
    build(&ctx, &bufs, &mut pb);
    Sim::new(&topo)
        .run(&pb.prog, &mut heap, &mut NoopExecutor)
        .unwrap();
    verify_allgather(&heap, &bufs, &expected).unwrap();
}

#[test]
fn allgather_grid_h800() {
    for gpn in [2usize, 4, 8] {
        run_ag(ClusterSpec::h800(1, gpn), 37, false, ag_push_intra);
        run_ag(ClusterSpec::h800(1, gpn), 37, false, ag_pull_intra);
        run_ag(ClusterSpec::h800(1, gpn), 37, true, ag_ll_intra);
    }
    for (nodes, gpn) in [(2usize, 4usize), (2, 8), (4, 4), (4, 8)] {
        run_ag(ClusterSpec::h800(nodes, gpn), 16, false, ag_inter);
        run_ag(ClusterSpec::h800(nodes, gpn), 16, true, ag_ll_inter);
    }
}

#[test]
fn allgather_grid_other_platforms() {
    for sub in [1usize, 2, 4] {
        run_ag(ClusterSpec::mi308x(8), 32, false, |c, b, p| {
            ag_amd_mesh(c, b, p, sub)
        });
    }
    run_ag(ClusterSpec::l20(1, 8), 32, true, ag_ll_pcie);
    run_ag(ClusterSpec::l20(2, 8), 32, true, ag_ll_pcie);
    // baselines too
    run_ag(ClusterSpec::h800(1, 8), 64, false, |c, b, p| {
        nccl_allgather_ring(c, b, p, 16)
    });
    run_ag(ClusterSpec::l20(1, 8), 64, false, |c, b, p| {
        nvshmem_fcollect(c, b, p, 0.2e-6)
    });
    run_ag(ClusterSpec::l20(1, 8), 64, false, |c, b, p| {
        nccl_allgather_smallmsg(c, b, p, true)
    });
}

fn run_rs(
    cluster: ClusterSpec,
    shard: usize,
    build: impl Fn(&ShmemCtx, &RsBufs, &mut ProgBuild),
) {
    let ctx = ShmemCtx::new(cluster, DType::BF16);
    let topo = Topology::build(cluster);
    let mut heap = SymmetricHeap::new(ctx.n_pes(), 8 * ctx.n_pes().max(16));
    let bufs = RsBufs::alloc(&mut heap, &ctx, shard);
    fill_rs_inputs(&mut heap, &bufs, 4321);
    let expected = expected_reduce_scatter(&heap, &bufs);
    let mut pb = ProgBuild::new();
    build(&ctx, &bufs, &mut pb);
    Sim::new(&topo)
        .run(&pb.prog, &mut heap, &mut NoopExecutor)
        .unwrap();
    verify_reduce_scatter(&heap, &bufs, &expected).unwrap();
}

#[test]
fn reduce_scatter_grid() {
    for gpn in [2usize, 3, 4, 8] {
        run_rs(ClusterSpec::h800(1, gpn), 19, |c, b, p| {
            rs_push_intra(c, b, p, 15, None)
        });
    }
    // deep-pipeline ring: ws=16 regressed once on slot flow control
    for gpn in [2usize, 4, 8, 16] {
        run_rs(ClusterSpec::h800(1, gpn), 19, |c, b, p| {
            nccl_reduce_scatter_ring(c, b, p, 16)
        });
    }
    run_rs(ClusterSpec::h800(2, 8), 19, |c, b, p| {
        nccl_reduce_scatter_ring(c, b, p, 16)
    });
    for (nodes, gpn) in [(2usize, 2usize), (2, 4), (2, 8), (4, 4)] {
        run_rs(ClusterSpec::h800(nodes, gpn), 8, |c, b, p| {
            rs_inter(c, b, p, 15, 120, None)
        });
    }
    for ct in [1usize, 2, 4] {
        run_rs(ClusterSpec::mi308x(8), 16, |c, b, p| {
            rs_fused_amd(c, b, p, ct, 16, None)
        });
    }
}

#[test]
fn alltoall_grid() {
    for cluster in [
        ClusterSpec::h800(1, 4),
        ClusterSpec::h800(1, 8),
        ClusterSpec::h800(2, 8),
        ClusterSpec::h800(4, 8),
    ] {
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        for cfg in [A2aCfg::ours(), A2aCfg::deepep()] {
            let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes());
            let bufs = A2aBufs::alloc(&mut heap, &ctx, 24);
            fill_a2a_inputs(&mut heap, &bufs, 777);
            let mut pb = ProgBuild::new();
            a2a_ll(&ctx, &bufs, &mut pb, &cfg);
            Sim::new(&topo)
                .run(&pb.prog, &mut heap, &mut NoopExecutor)
                .unwrap();
            verify_alltoall(&heap, &bufs).unwrap();
        }
        // deepep path
        let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes());
        let bufs = A2aBufs::alloc(&mut heap, &ctx, 24);
        fill_a2a_inputs(&mut heap, &bufs, 888);
        let mut pb = ProgBuild::new();
        a2a_deepep(&ctx, &bufs, &mut pb);
        Sim::new(&topo)
            .run(&pb.prog, &mut heap, &mut NoopExecutor)
            .unwrap();
        verify_alltoall(&heap, &bufs).unwrap();
    }
}

#[test]
fn alltoall_roundtrip_dispatch_combine() {
    for cluster in [ClusterSpec::h800(1, 8), ClusterSpec::h800(2, 4)] {
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let (dispatch_t, combine_t) = roundtrip_check(&ctx, &topo, 32, &A2aCfg::ours()).unwrap();
        assert!(dispatch_t > 0.0 && combine_t > 0.0);
    }
}

#[test]
fn ll_allgather_beats_ring_at_small_messages_everywhere() {
    // The Fig. 19 shape on PCIe: LL direct wins over NCCL ring for small
    // messages at both 8 and 16 ranks.
    for cluster in [ClusterSpec::l20(1, 8), ClusterSpec::l20(2, 8)] {
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let time = |ll: bool| {
            let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes());
            let bufs = if ll {
                AgBufs::alloc_ll(&mut heap, &ctx, 256)
            } else {
                AgBufs::alloc(&mut heap, &ctx, 256)
            };
            fill_ag_inputs(&mut heap, &bufs, 3);
            let mut pb = ProgBuild::new();
            if ll {
                ag_ll_pcie(&ctx, &bufs, &mut pb);
            } else {
                nccl_allgather_ring(&ctx, &bufs, &mut pb, 16);
            }
            Sim::new(&topo)
                .run(&pb.prog, &mut heap, &mut NoopExecutor)
                .unwrap()
                .makespan
        };
        let ll = time(true);
        let ring = time(false);
        assert!(ll < ring, "ll {ll} vs ring {ring} on {:?}", cluster.nodes);
    }
}
