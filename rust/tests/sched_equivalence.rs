//! Chunk-scheduler acceptance suite (ISSUE tentpole):
//!
//! 1. **Fifo bit-identity**: `ChunkSched::Fifo` (the default) must
//!    reproduce the pre-scheduler engine bit for bit on the fig13
//!    (inter-node AG+GEMM), fig14 (inter-node GEMM+RS), fig16
//!    (low-latency AllToAll), and EP-MoE workload shapes — under Fifo
//!    the divert point is disabled and no piece ever enters the ready
//!    queue, so nothing may drift.
//! 2. **Determinism**: same-seed replays of the scheduled engine are
//!    bit-identical, including across `--threads {1,4}` (a non-Fifo
//!    policy makes the parallel planner fall back to the sequential
//!    loop, so the thread knob stays a pure wall-clock knob).
//! 3. **Strict win**: on the pinned mixed-traffic scenario (concurrent
//!    EP-style gating stream + bulk backlog from one source over a
//!    tapered adaptive spine, `alltoall-sched-mixed`), `Srpf` and
//!    `Deadline` each beat adaptive-routing-alone (`Fifo`) by >= 5%
//!    makespan.
//! 4. **FIFO-per-stream safety**: the scheduler reorders *across*
//!    streams only. Builder tags never reorder pieces within a
//!    `(task, dst)` stream — remaining-work tags are non-increasing in
//!    program order — and tagged collectives stay numerically correct
//!    under `Srpf` on a blocking railed fabric.

use triton_dist_sim::collectives::allgather::ag_inter;
use triton_dist_sim::collectives::alltoall::{
    a2a_ll, run_sched_mixed, sched_mixed, verify_alltoall, A2aBufs, A2aCfg,
};
use triton_dist_sim::collectives::{
    expected_allgather, fill_ag_inputs, verify_allgather, AgBufs, ProgBuild,
};
use triton_dist_sim::config::{
    ChunkSched, ClusterSpec, DType, FabricSpec, FaultPlan, GemmShape, MoeShape, RailPolicy,
};
use triton_dist_sim::coordinator::{
    self, ag_gemm, ep_moe, gemm_rs, run_timing, run_timing_threads,
};
use triton_dist_sim::mem::SymmetricHeap;
use triton_dist_sim::program::Op;
use triton_dist_sim::shmem::ShmemCtx;
use triton_dist_sim::sim::{NoopExecutor, Sim, SimConfig};
use triton_dist_sim::topology::Topology;

/// A railed blocking fabric with the chunk scheduler spelled out.
fn railed(sched: ChunkSched) -> ClusterSpec {
    ClusterSpec::h800(2, 8).with_fabric(
        FabricSpec::rail_optimized(2, 2.0).with_chunk_sched(sched),
    )
}

fn ag_gemm_makespan(cluster: ClusterSpec, shape: GemmShape) -> f64 {
    let topo = Topology::build(cluster);
    let (mut op, _b) = ag_gemm::build(cluster, shape, ag_gemm::AgGemmVariant::OursInter);
    run_timing(&mut op, &topo).unwrap()
}

fn gemm_rs_makespan(cluster: ClusterSpec, shape: GemmShape) -> f64 {
    let topo = Topology::build(cluster);
    let (mut op, _b) = gemm_rs::build(cluster, shape, gemm_rs::GemmRsVariant::OursInter);
    run_timing(&mut op, &topo).unwrap()
}

fn a2a_makespan(cluster: ClusterSpec, chunk: usize) -> f64 {
    let ctx = ShmemCtx::new(cluster, DType::BF16);
    let topo = Topology::build(cluster);
    let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes().max(16));
    let bufs = A2aBufs::alloc(&mut heap, &ctx, chunk);
    let mut pb = ProgBuild::new();
    a2a_ll(&ctx, &bufs, &mut pb, &A2aCfg::ours().with_split(2));
    let sim = Sim::with_config(
        &topo,
        SimConfig {
            numerics: false,
            trace: false,
        },
    );
    sim.run(&pb.prog, &mut heap, &mut NoopExecutor)
        .unwrap()
        .makespan
}

fn ep_moe_makespan(cluster: ClusterSpec) -> f64 {
    let shape = MoeShape {
        tokens_per_rank: 32,
        in_hidden: 64,
        out_hidden: 64,
        experts: 32,
        topk: 2,
        ..MoeShape::default()
    };
    let routing = ep_moe::routing_for(cluster, &shape, 3);
    let cfg = A2aCfg::ours().with_split(2);
    let (mut op, _b) = ep_moe::build_ep_moe_cfg(
        cluster,
        shape,
        &routing,
        ep_moe::EpMoeVariant::TokenRouted,
        &cfg,
    );
    let topo = Topology::build(cluster);
    run_timing(&mut op, &topo).unwrap()
}

// -- 1. Fifo bit-identity ---------------------------------------------------

/// `chunk_sched` must be inert under `Fifo`: a railed fabric with the
/// policy spelled out reproduces the policy-less (default) railed
/// makespans bit-identically on the fig13/fig14/fig16 shapes.
#[test]
fn explicit_fifo_bit_identical_on_fig_shapes() {
    let default_fab = ClusterSpec::h800(2, 8).with_fabric(FabricSpec::rail_optimized(2, 2.0));
    let fifo = railed(ChunkSched::Fifo);
    let shape = GemmShape::new(16 * 64, 128, 256);
    assert_eq!(
        ag_gemm_makespan(default_fab, shape).to_bits(),
        ag_gemm_makespan(fifo, shape).to_bits(),
        "fig13 AG+GEMM must not drift under explicit Fifo"
    );
    let rs_shape = GemmShape::new(16 * 32, 128, 256);
    assert_eq!(
        gemm_rs_makespan(default_fab, rs_shape).to_bits(),
        gemm_rs_makespan(fifo, rs_shape).to_bits(),
        "fig14 GEMM+RS must not drift under explicit Fifo"
    );
    assert_eq!(
        a2a_makespan(default_fab, 1024).to_bits(),
        a2a_makespan(fifo, 1024).to_bits(),
        "fig16 AllToAll must not drift under explicit Fifo"
    );
}

/// Same bit-identity on the flagship EP-MoE pipeline, whose split
/// dispatch and combine legs carry chunk tags — inert under Fifo.
#[test]
fn explicit_fifo_bit_identical_on_ep_moe() {
    let default_fab = ClusterSpec::h800(2, 4).with_fabric(FabricSpec::rail_optimized(2, 2.0));
    let fifo = ClusterSpec::h800(2, 4)
        .with_fabric(FabricSpec::rail_optimized(2, 2.0).with_chunk_sched(ChunkSched::Fifo));
    assert_eq!(
        ep_moe_makespan(default_fab).to_bits(),
        ep_moe_makespan(fifo).to_bits()
    );
}

// -- 2. Determinism ---------------------------------------------------------

/// Same-seed replays of every policy are bit-identical.
#[test]
fn sched_replays_bit_identically() {
    for sched in [ChunkSched::Fifo, ChunkSched::Srpf, ChunkSched::Deadline] {
        let a = run_sched_mixed(sched).unwrap();
        let b = run_sched_mixed(sched).unwrap();
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{sched:?} must replay bit-for-bit"
        );
    }
}

/// The scheduled engine under `--threads {1,4}` stays bit-identical: a
/// non-Fifo policy forces the parallel planner's sequential fallback,
/// so the thread count remains a pure wall-clock knob.
#[test]
fn srpf_bit_identical_across_threads() {
    let run = |threads: usize| -> f64 {
        let cluster = ClusterSpec::h800(2, 2).with_fabric(
            FabricSpec::rail_optimized(2, 2.0)
                .with_spine_taper(2.0)
                .with_rail_policy(RailPolicy::Adaptive)
                .with_chunk_sched(ChunkSched::Srpf),
        );
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let mut heap = SymmetricHeap::new(ctx.n_pes(), 16);
        let mut pb = ProgBuild::new();
        let gemm_secs = ctx.bytes(32 << 19) / cluster.hw.nic_bw;
        sched_mixed(&ctx, &mut heap, &mut pb, 32, 1 << 19, 4, 1 << 17, gemm_secs);
        let mut op = coordinator::BuiltOp {
            ctx,
            heap,
            prog: pb.prog,
            name: "sched_mixed".into(),
        };
        run_timing_threads(&mut op, &topo, FaultPlan::default(), threads)
            .unwrap()
            .makespan
    };
    assert_eq!(run(1).to_bits(), run(4).to_bits());
}

// -- 3. Strict win on the pinned mixed-traffic scenario ---------------------

/// Acceptance: on concurrent gating + bulk traffic from one source over
/// a tapered adaptive spine, contention-aware issue is **strictly**
/// faster than adaptive routing alone — FIFO shares the egress planes
/// between the gating pieces and the whole bulk backlog, while `Srpf`
/// and `Deadline` issue the consumer-gating pieces first.
#[test]
fn contention_aware_policies_strictly_beat_fifo_on_mixed_traffic() {
    let fifo = run_sched_mixed(ChunkSched::Fifo).unwrap();
    let srpf = run_sched_mixed(ChunkSched::Srpf).unwrap();
    let deadline = run_sched_mixed(ChunkSched::Deadline).unwrap();
    assert!(
        srpf < fifo * 0.95,
        "expected >= 5% win, got srpf {srpf} vs fifo {fifo}"
    );
    assert!(
        deadline < fifo * 0.95,
        "expected >= 5% win, got deadline {deadline} vs fifo {fifo}"
    );
}

// -- 4. FIFO-per-stream safety ----------------------------------------------

/// The builders' remaining-work tags are non-increasing in program
/// order within every task — the invariant that makes SRPF starvation-
/// free *within* a stream: a stream's head is always its oldest piece,
/// and its priority only rises as the stream drains.
#[test]
fn stream_tags_are_nonincreasing_in_program_order() {
    let cluster = ClusterSpec::h800(2, 2).with_fabric(
        FabricSpec::rail_optimized(2, 2.0)
            .with_spine_taper(2.0)
            .with_chunk_sched(ChunkSched::Srpf),
    );
    let ctx = ShmemCtx::new(cluster, DType::BF16);
    let mut heap = SymmetricHeap::new(ctx.n_pes(), 16);
    let mut pb = ProgBuild::new();
    sched_mixed(&ctx, &mut heap, &mut pb, 8, 64, 4, 32, 1e-6);
    let mut tagged_tasks = 0usize;
    let mut saw_gating = false;
    let mut saw_bulk = false;
    for task in &pb.prog.tasks {
        let mut last: Option<(u32, f64)> = None;
        for op in &task.ops {
            let chunk = match op {
                Op::Put { chunk, .. } | Op::LLPut { chunk, .. } => *chunk,
                _ => None,
            };
            let Some(meta) = chunk else { continue };
            if let Some((deadline, remaining)) = last {
                assert_eq!(
                    meta.deadline, deadline,
                    "a stream's deadline class is constant"
                );
                assert!(
                    meta.remaining <= remaining,
                    "remaining work must drain monotonically within a stream: \
                     {} after {remaining}",
                    meta.remaining
                );
            }
            last = Some((meta.deadline, meta.remaining));
            if meta.deadline == 0 {
                saw_gating = true;
            }
            if meta.deadline == u32::MAX {
                saw_bulk = true;
            }
        }
        if last.is_some() {
            tagged_tasks += 1;
        }
    }
    assert_eq!(tagged_tasks, 2, "one gating and one bulk stream");
    assert!(saw_gating && saw_bulk, "both deadline classes present");
}

/// Tagged collectives stay numerically correct when the scheduler
/// actually reorders their pieces: the split low-latency AllToAll and
/// the gating-tagged inter-node AllGather on a blocking railed adaptive
/// fabric under `Srpf`. Per-(task, dst) delivery order is preserved by
/// the stream queues, so the data must land exactly.
#[test]
fn tagged_collectives_stay_correct_under_srpf() {
    let cluster = ClusterSpec::h800(2, 4).with_fabric(
        FabricSpec::rail_optimized(2, 2.0)
            .with_rail_policy(RailPolicy::Adaptive)
            .with_chunk_sched(ChunkSched::Srpf),
    );
    let ctx = ShmemCtx::new(cluster, DType::BF16);
    let topo = Topology::build(cluster);

    // split AllToAll: every dispatch chunk becomes multiple tagged pieces
    let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes());
    let bufs = A2aBufs::alloc(&mut heap, &ctx, 32);
    triton_dist_sim::collectives::alltoall::fill_a2a_inputs(&mut heap, &bufs, 5);
    let mut pb = ProgBuild::new();
    a2a_ll(&ctx, &bufs, &mut pb, &A2aCfg::ours().with_split(4));
    let sim = Sim::new(&topo);
    sim.run(&pb.prog, &mut heap, &mut NoopExecutor).unwrap();
    verify_alltoall(&heap, &bufs).unwrap();

    // gating-tagged inter-node AllGather
    let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes());
    let bufs = AgBufs::alloc(&mut heap, &ctx, 16);
    fill_ag_inputs(&mut heap, &bufs, 7);
    let expected = expected_allgather(&heap, &bufs);
    let mut pb = ProgBuild::new();
    ag_inter(&ctx, &bufs, &mut pb);
    let sim = Sim::new(&topo);
    sim.run(&pb.prog, &mut heap, &mut NoopExecutor).unwrap();
    verify_allgather(&heap, &bufs, &expected).unwrap();
}
