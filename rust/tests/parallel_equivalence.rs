//! Parallel-engine equivalence (ISSUE 7 acceptance): the
//! component-sharded event loop (`sim/par.rs`, engaged by
//! `Sim::with_threads(N)` for N > 1) must produce a **bit-identical**
//! [`SimReport`] to the sequential loop on every shape — same makespan
//! bits, same event/flow counts, same task-span bits, same fault ledger
//! — for every thread count, including fault-injected and same-seed
//! replay runs. `wall_ns` is the one measured (non-reproducible) field
//! and is deliberately not compared.
//!
//! Shapes mirror `tests/fault_injection.rs` (fig13 AG+GEMM, fig16 railed
//! AllToAll, token-routed EP MoE) but pin the **static** rail policy:
//! the sharded engine only engages when routes are static (the adaptive
//! router reads global link occupancy on every decision, which a shard
//! cannot see); an adaptive shape is still covered below to pin that the
//! fallback path stays bit-identical too.
//!
//! Fault plans here keep the default (infinite) `lt_timeout`: watchdog
//! *arming* is host-order-sensitive at equal virtual times, so a finite
//! timeout is the one knob the bit-identity contract excludes (see
//! `sim/par.rs` module docs).

use triton_dist_sim::collectives::alltoall::{a2a_ll, A2aBufs, A2aCfg};
use triton_dist_sim::collectives::ProgBuild;
use triton_dist_sim::config::{
    ClusterSpec, DType, FabricSpec, FaultPlan, GemmShape, MoeShape, RailPolicy,
};
use triton_dist_sim::coordinator::{ag_gemm, ep_moe};
use triton_dist_sim::mem::SymmetricHeap;
use triton_dist_sim::shmem::ShmemCtx;
use triton_dist_sim::sim::{NoopExecutor, Sim, SimConfig, SimReport};
use triton_dist_sim::topology::Topology;

const THREADS: [usize; 4] = [1, 2, 4, 8];

fn timing_sim(topo: &Topology) -> Sim<'_> {
    Sim::with_config(
        topo,
        SimConfig {
            numerics: false,
            trace: false,
        },
    )
}

fn assert_reports_identical(a: &SimReport, b: &SimReport, what: &str) {
    assert_eq!(
        a.makespan.to_bits(),
        b.makespan.to_bits(),
        "{what}: makespan bits ({} vs {})",
        a.makespan,
        b.makespan
    );
    assert_eq!(a.events, b.events, "{what}: event count");
    assert_eq!(a.flows, b.flows, "{what}: flow count");
    assert_eq!(a.ledger, b.ledger, "{what}: ledger");
    assert_eq!(a.task_spans.len(), b.task_spans.len(), "{what}: span count");
    for (x, y) in a.task_spans.iter().zip(&b.task_spans) {
        assert_eq!(x.0, y.0, "{what}: span name");
        assert_eq!(x.1, y.1, "{what}: span rank");
        assert_eq!(x.2.to_bits(), y.2.to_bits(), "{what}: start bits ({})", x.0);
        assert_eq!(x.3.to_bits(), y.3.to_bits(), "{what}: end bits ({})", x.0);
    }
}

/// Run `run_at(threads)` for every thread count and assert every report
/// matches the sequential (threads = 1) one bit-for-bit.
fn sweep_identical(what: &str, run_at: impl Fn(usize) -> SimReport) {
    let seq = run_at(1);
    assert!(seq.events > 0, "{what}: empty run proves nothing");
    for t in &THREADS[1..] {
        let par = run_at(*t);
        assert_reports_identical(&seq, &par, &format!("{what} @ threads={t}"));
    }
}

/// fig13 shape: inter-node AG+GEMM on the default (fat-tree) fabric.
fn run_fig13(threads: usize, plan: FaultPlan) -> SimReport {
    let cluster = ClusterSpec::h800(2, 4);
    let topo = Topology::build(cluster);
    let gemm = GemmShape::new(1024, 512, 512);
    let (mut op, _b) = ag_gemm::build(cluster, gemm, ag_gemm::AgGemmVariant::OursInter);
    timing_sim(&topo)
        .with_faults(plan)
        .with_threads(threads)
        .run(&op.prog, &mut op.heap, &mut NoopExecutor)
        .unwrap()
}

/// fig16 shape: railed LL AllToAll. Static policy (the canonical fig16
/// fabric is adaptive — covered separately as the fallback case).
fn run_fig16_static(threads: usize, plan: FaultPlan) -> SimReport {
    let cluster = ClusterSpec::h800(2, 4).with_fabric(FabricSpec::rail_optimized(2, 2.0));
    let ctx = ShmemCtx::new(cluster, DType::BF16);
    let topo = Topology::build(cluster);
    let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes());
    let bufs = A2aBufs::alloc(&mut heap, &ctx, 512);
    let mut pb = ProgBuild::new();
    a2a_ll(&ctx, &bufs, &mut pb, &A2aCfg::ours());
    timing_sim(&topo)
        .with_faults(plan)
        .with_threads(threads)
        .run(&pb.prog, &mut heap, &mut NoopExecutor)
        .unwrap()
}

/// EP MoE shape: token-routed over the tapered railed (static) fabric.
fn run_ep_moe(threads: usize, plan: FaultPlan) -> SimReport {
    let cluster = ClusterSpec::h800(2, 4)
        .with_fabric(FabricSpec::rail_optimized(2, 2.0).with_spine_taper(2.0));
    let shape = MoeShape {
        tokens_per_rank: 16,
        in_hidden: 64,
        out_hidden: 64,
        experts: 8,
        topk: 2,
        ..MoeShape::default()
    }
    .with_skew(1.2);
    let routing = ep_moe::routing_for(cluster, &shape, 5);
    let topo = Topology::build(cluster);
    let (mut op, _b) =
        ep_moe::build_ep_moe(cluster, shape, &routing, ep_moe::EpMoeVariant::TokenRouted);
    timing_sim(&topo)
        .with_faults(plan)
        .with_threads(threads)
        .run(&op.prog, &mut op.heap, &mut NoopExecutor)
        .unwrap()
}

#[test]
fn fig13_bit_identical_across_threads() {
    sweep_identical("fig13 AG+GEMM", |t| run_fig13(t, FaultPlan::default()));
}

#[test]
fn fig16_static_bit_identical_across_threads() {
    sweep_identical("fig16 railed AllToAll", |t| {
        run_fig16_static(t, FaultPlan::default())
    });
}

#[test]
fn ep_moe_bit_identical_across_threads() {
    sweep_identical("EP MoE token-routed", |t| run_ep_moe(t, FaultPlan::default()));
}

#[test]
fn rail_flap_bit_identical_across_threads() {
    // spine plane 0 dies mid-run and returns: the fault machinery (kill,
    // retry ladder, capacity retarget) all lives fabric-side, so the
    // sharded engine must replay it bit-for-bit
    let flap = || FaultPlan::parse("flap,spine,0,5e-6,5e-4").unwrap();
    sweep_identical("fig16 + rail flap", |t| run_fig16_static(t, flap()));
    sweep_identical("EP MoE + rail flap", |t| run_ep_moe(t, flap()));
}

#[test]
fn degraded_rail_bit_identical_across_threads() {
    // spine plane 0 at quarter capacity for the whole run: the water-fill
    // rates of every fabric component shift, shard wakeups move with them
    let deg = || FaultPlan::parse("deg,spine,0,0,1.0,0.25").unwrap();
    sweep_identical("fig16 + degraded rail", |t| run_fig16_static(t, deg()));
}

#[test]
fn same_seed_replay_identical_across_threads() {
    // a synthesized plan (default infinite lt_timeout) replayed at every
    // thread count: same seed -> same timeline, sequential or sharded
    let plan = || FaultPlan::synthesize(42, 1.5, 8, 2, 1e-3);
    sweep_identical("fig16 + synthesized plan", |t| run_fig16_static(t, plan()));
    let a = run_fig16_static(4, plan());
    let b = run_fig16_static(4, plan());
    assert_reports_identical(&a, &b, "threads=4 replay");
}

#[test]
fn adaptive_policy_falls_back_bit_identically() {
    // the adaptive router is a global observer, so `plan()` refuses to
    // shard and `--threads 8` must take the sequential path unchanged
    let run = |threads: usize| {
        let cluster = ClusterSpec::h800(2, 4).with_fabric(
            FabricSpec::rail_optimized(2, 2.0).with_rail_policy(RailPolicy::Adaptive),
        );
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes());
        let bufs = A2aBufs::alloc(&mut heap, &ctx, 512);
        let mut pb = ProgBuild::new();
        a2a_ll(&ctx, &bufs, &mut pb, &A2aCfg::ours());
        timing_sim(&topo)
            .with_threads(threads)
            .run(&pb.prog, &mut heap, &mut NoopExecutor)
            .unwrap()
    };
    assert_reports_identical(&run(1), &run(8), "adaptive fallback");
}

#[test]
fn single_node_falls_back_bit_identically() {
    // one node has no cross-partition latency to bound the lookahead, so
    // sharding is refused and the sequential loop runs
    let run = |threads: usize| {
        let cluster = ClusterSpec::h800(1, 8);
        let topo = Topology::build(cluster);
        let gemm = GemmShape::new(1024, 512, 512);
        let (mut op, _b) = ag_gemm::build(cluster, gemm, ag_gemm::AgGemmVariant::OursPush);
        timing_sim(&topo)
            .with_threads(threads)
            .run(&op.prog, &mut op.heap, &mut NoopExecutor)
            .unwrap()
    };
    assert_reports_identical(&run(1), &run(8), "single-node fallback");
}

#[test]
fn sharded_run_reports_wall_clock_throughput() {
    // satellite: SimReport carries measured wall_ns + events/s on both
    // engine paths (the one field equivalence must ignore)
    let seq = run_fig16_static(1, FaultPlan::default());
    let par = run_fig16_static(4, FaultPlan::default());
    assert!(seq.wall_ns > 0, "sequential run must stamp wall_ns");
    assert!(par.wall_ns > 0, "sharded run must stamp wall_ns");
    assert!(seq.events_per_s() > 0.0);
    assert!(par.events_per_s() > 0.0);
}
