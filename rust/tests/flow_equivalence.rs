//! Exact-equivalence suite for the incremental max–min flow solver.
//!
//! The `FlowNet` hot path recomputes rates incrementally, scoped to the
//! connected component of links reachable from the touched flows, with
//! per-flow lazy progress accrual. These properties pin the contract:
//! after **every** step of a randomized add/remove trace, the
//! incremental rates are **bit-identical** to a from-scratch global
//! water-fill over the whole network ([`FlowNet::reference_rates`]),
//! batched updates are bit-identical to sequential ones, and completion
//! events of untouched components survive updates elsewhere.

use triton_dist_sim::sim::FlowNet;
use triton_dist_sim::topology::LinkId;
use triton_dist_sim::util::prop::{check, Gen};

/// Random route: a non-empty subset of links drawn from `lo..hi`.
fn random_route(g: &mut Gen, lo: usize, hi: usize) -> Vec<LinkId> {
    let mut links: Vec<LinkId> = (lo..hi).filter(|_| g.bool()).map(LinkId).collect();
    if links.is_empty() {
        links.push(LinkId(lo + g.usize_in(0, hi - lo)));
    }
    links
}

fn assert_rates_match_reference(n: &FlowNet, step: usize) {
    for (id, want) in n.reference_rates() {
        assert_eq!(
            n.rate(id).to_bits(),
            want.to_bits(),
            "step {step}: flow {id:?} incremental rate {} != reference {want}",
            n.rate(id)
        );
    }
}

/// Incremental component-scoped refills are bit-identical to a global
/// from-scratch water-fill after every single step of a randomized
/// add/remove trace (40 cases x 30 steps = 1200 steps).
#[test]
fn prop_incremental_matches_global_refill() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static STEPS: AtomicUsize = AtomicUsize::new(0);
    STEPS.store(0, Ordering::SeqCst);
    check("incremental = global water-fill", 40, |g| {
        let nl = g.usize_in(2, 10);
        let caps: Vec<f64> = (0..nl).map(|_| 1.0 + g.f64() * 99.0).collect();
        let mut n = FlowNet::new(caps);
        let mut alive = Vec::new();
        let mut now = 0.0;
        for step in 0..30 {
            // time sometimes stands still (batch-like), sometimes moves
            if g.bool() {
                now += g.f64() * 2.0;
            }
            let do_remove = !alive.is_empty() && g.usize_in(0, 3) == 0;
            if do_remove {
                let k = g.usize_in(0, alive.len());
                let id = alive.swap_remove(k);
                n.remove(now, id);
            } else {
                let links = random_route(g, 0, nl);
                let bytes = 1.0 + g.f64() * 1e6;
                let (id, _) = n.add(now, links, bytes);
                alive.push(id);
            }
            assert_rates_match_reference(&n, step);
            n.check_capacity().unwrap();
            n.check_incidence().unwrap();
            STEPS.fetch_add(1, Ordering::SeqCst);
        }
    });
    let total = STEPS.load(Ordering::SeqCst);
    assert!(total >= 1000, "suite must cover >= 1000 steps, ran {total}");
}

/// One batched `update` is bit-identical (rates) to performing the same
/// removes and adds one at a time at the same timestamp.
#[test]
fn prop_batched_matches_sequential() {
    check("batched = sequential", 60, |g| {
        let nl = g.usize_in(2, 8);
        let caps: Vec<f64> = (0..nl).map(|_| 1.0 + g.f64() * 99.0).collect();
        let mut seq = FlowNet::new(caps.clone());
        let mut bat = FlowNet::new(caps);
        // identical preamble on both nets
        let mut seq_alive = Vec::new();
        let mut bat_alive = Vec::new();
        for _ in 0..g.usize_in(0, 10) {
            let links = random_route(g, 0, nl);
            let bytes = 1.0 + g.f64() * 1e6;
            let (a, _) = seq.add(0.0, links.clone(), bytes);
            let (b, _) = bat.add(0.0, links, bytes);
            seq_alive.push(a);
            bat_alive.push(b);
        }
        let now = g.f64() * 3.0;
        // pick removals (indices into the alive lists) and fresh adds
        let n_rm = g.usize_in(0, seq_alive.len() + 1);
        let mut rm_idx: Vec<usize> = (0..seq_alive.len()).collect();
        g.shuffle(&mut rm_idx);
        rm_idx.truncate(n_rm);
        rm_idx.sort_unstable();
        let adds: Vec<(Vec<LinkId>, f64)> = (0..g.usize_in(1, 6))
            .map(|_| (random_route(g, 0, nl), 1.0 + g.f64() * 1e6))
            .collect();

        // sequential: one FlowNet call per operation
        for &i in &rm_idx {
            seq.remove(now, seq_alive[i]);
        }
        let mut seq_new = Vec::new();
        for (links, bytes) in &adds {
            let (id, _) = seq.add(now, links.clone(), *bytes);
            seq_new.push(id);
        }
        // batched: everything in one update
        let bat_rm: Vec<_> = rm_idx.iter().map(|&i| bat_alive[i]).collect();
        let (bat_new, _) = bat.update(now, &bat_rm, adds);

        // survivors + new flows must agree bit-for-bit on rates
        for (k, (&s, &b)) in seq_alive.iter().zip(&bat_alive).enumerate() {
            if rm_idx.contains(&k) {
                continue;
            }
            assert_eq!(seq.rate(s).to_bits(), bat.rate(b).to_bits(), "survivor {k}");
            let db = (seq.remaining_at(s, now) - bat.remaining_at(b, now)).abs();
            assert!(db <= 1e-6 * seq.remaining_at(s, now).max(1.0), "bytes {k}: {db}");
        }
        for (k, (&s, &b)) in seq_new.iter().zip(&bat_new).enumerate() {
            assert_eq!(seq.rate(s).to_bits(), bat.rate(b).to_bits(), "new flow {k}");
        }
        assert_eq!(seq.n_active(), bat.n_active());
        bat.check_capacity().unwrap();
        bat.check_incidence().unwrap();
        assert_rates_match_reference(&bat, 0);
    });
}

/// With no elapsed virtual time, ETAs are exact: every update reports
/// `bytes / rate` computed from the same bits the reference fill yields.
#[test]
fn prop_same_time_etas_exact() {
    check("same-time etas exact", 40, |g| {
        let nl = g.usize_in(1, 6);
        let caps: Vec<f64> = (0..nl).map(|_| 1.0 + g.f64() * 99.0).collect();
        let mut n = FlowNet::new(caps);
        let mut bytes_of = std::collections::HashMap::new();
        let mut alive = Vec::new();
        for _ in 0..20 {
            let up = if !alive.is_empty() && g.usize_in(0, 3) == 0 {
                let k = g.usize_in(0, alive.len());
                let id = alive.swap_remove(k);
                bytes_of.remove(&id.0);
                n.remove(0.0, id)
            } else {
                let links = random_route(g, 0, nl);
                let bytes = 1.0 + g.f64() * 1e6;
                let (id, up) = n.add(0.0, links, bytes);
                bytes_of.insert(id.0, bytes);
                alive.push(id);
                up
            };
            for (id, _gen, eta) in &up.etas {
                let want = bytes_of[&id.0] / n.rate(*id);
                assert_eq!(eta.to_bits(), want.to_bits(), "flow {id:?} eta");
            }
        }
    });
}

/// Updates in one connected component never invalidate the scheduled
/// completion events of flows in another: their generation stays
/// current, so the DES engine keeps their events instead of churning
/// the queue.
#[test]
fn prop_untouched_component_events_survive() {
    check("untouched events survive", 40, |g| {
        // two halves of the link space never share a flow => at least
        // two independent component groups
        let half = g.usize_in(1, 4);
        let caps: Vec<f64> = (0..2 * half).map(|_| 1.0 + g.f64() * 99.0).collect();
        let mut n = FlowNet::new(caps);
        // population of the left half, recording each flow's latest gen
        let mut left = std::collections::HashMap::new();
        for _ in 0..g.usize_in(1, 5) {
            let (id, up) = n.add(0.0, random_route(g, 0, half), 1e5);
            for (f, gen, _) in &up.etas {
                if left.contains_key(&f.0) || *f == id {
                    left.insert(f.0, *gen);
                }
            }
        }
        // churn the right half
        let mut right = Vec::new();
        for _ in 0..10 {
            if !right.is_empty() && g.bool() {
                let k = g.usize_in(0, right.len());
                let id: triton_dist_sim::sim::FlowId = right.swap_remove(k);
                let up = n.remove(0.0, id);
                assert!(up.etas.iter().all(|(f, _, _)| !left.contains_key(&f.0)));
            } else {
                let (id, up) = n.add(0.0, random_route(g, half, 2 * half), 1e5);
                assert!(up.etas.iter().all(|(f, _, _)| !left.contains_key(&f.0)));
                right.push(id);
            }
            // every left-half completion event is still current
            for (&f, &gen) in &left {
                assert!(
                    n.is_current(triton_dist_sim::sim::FlowId(f), gen),
                    "left flow {f} event was invalidated by right-half churn"
                );
            }
        }
    });
}
