//! Trace-driven serving invariants (ISSUE tentpole acceptance):
//!
//! 1. **Replay**: the same `(trace seed, fault seed)` pair replays an
//!    identical [`ServingReport`] bit-for-bit — percentiles, queue
//!    samples, recovery log, everything.
//! 2. **Request conservation**: every admitted request completes, is
//!    dropped with a named reason, or is rerouted and *then* completes
//!    or drops — the counters reconcile exactly.
//! 3. **Percentile sanity**: p50 <= p99 on every metric, and TTFT <=
//!    total latency per request.
//! 4. **Empty-trace no-op**: serving an empty trace returns the
//!    `Default` report — bit-identical to never having run.
//! 5. **DSL robustness**: fuzzed trace specs parse to `Ok` or a
//!    structured `Err`, never a panic; valid plans round-trip through
//!    `Display` exactly.
//! 6. **Death = spike, not failure**: a mid-serving rank death yields a
//!    *completed* run whose p99 TTFT is measurably worse than the
//!    fault-free run of the same trace, with the recovery on record.

use triton_dist_sim::config::{ClusterSpec, FabricSpec, FaultPlan, RailPolicy, TracePlan};
use triton_dist_sim::coordinator::serve::{run_serve, ServeCfg, ServingReport};
use triton_dist_sim::util::prop::{check, Gen};

fn railed_cluster(nodes: usize, gpus: usize) -> ClusterSpec {
    ClusterSpec::h800(nodes, gpus).with_fabric(
        FabricSpec::rail_optimized(2, 2.0)
            .with_spine_taper(2.0)
            .with_rail_policy(RailPolicy::Adaptive),
    )
}

/// Small, fast fleet config for the suite (tiny MoE, small batch).
fn small_cfg() -> ServeCfg {
    ServeCfg {
        max_batch: 8,
        prefill_chunk: 128,
        moe_experts: 8,
        moe_hidden: 64,
        ..ServeCfg::default()
    }
}

/// Conservation + sanity audit every run must pass, with the seeds in
/// every message so a CI failure prints its own repro.
fn audit(rep: &ServingReport, tag: &str) {
    assert_eq!(
        rep.completed + rep.dropped,
        rep.requests,
        "{tag}: completed + dropped must equal admitted requests: {rep:?}"
    );
    assert_eq!(
        rep.completed,
        rep.per_request.len(),
        "{tag}: one latency record per completion"
    );
    let reasons: usize = rep.drop_reasons.iter().map(|(_, n)| n).sum();
    assert_eq!(reasons, rep.dropped, "{tag}: every drop carries a reason");
    let rec_rerouted: usize = rep.recoveries.iter().map(|r| r.rerouted).sum();
    assert_eq!(
        rec_rerouted, rep.rerouted,
        "{tag}: reroutes reconcile against the recovery log"
    );
    assert!(rep.p50_ttft <= rep.p99_ttft, "{tag}: ttft p50 > p99: {rep:?}");
    assert!(rep.p50_tpot <= rep.p99_tpot, "{tag}: tpot p50 > p99: {rep:?}");
    assert!(
        rep.p50_latency <= rep.p99_latency,
        "{tag}: latency p50 > p99: {rep:?}"
    );
    for r in &rep.per_request {
        assert!(
            r.ttft <= r.latency + 1e-15,
            "{tag}: req {} first token after its last: {r:?}",
            r.id
        );
        assert!(r.ttft >= 0.0 && r.latency >= 0.0, "{tag}: negative time: {r:?}");
    }
    if rep.completed > 0 {
        assert!(rep.makespan > 0.0 && rep.goodput > 0.0, "{tag}: {rep:?}");
        assert!(
            rep.p99_latency <= rep.makespan,
            "{tag}: no request outlives the run: {rep:?}"
        );
    }
    assert!(
        rep.queue_depth.len() <= 256,
        "{tag}: queue samples must be downsampled"
    );
    for (t, d) in &rep.queue_depth {
        assert!(*t <= rep.makespan && *d <= rep.max_queue_depth, "{tag}");
    }
}

#[test]
fn same_seeds_replay_the_report_bit_for_bit() {
    let cluster = railed_cluster(2, 2);
    let trace = TracePlan::parse("bursty,3e4,24,7,4,2e-3; lens,96,12")
        .unwrap()
        .materialize();
    let faults = FaultPlan::parse("flap,nic,1,0,5e-5,1e-4; strag,2,1.3").unwrap();
    let cfg = small_cfg();
    let a = run_serve(cluster, &trace, faults.clone(), &cfg).unwrap();
    let b = run_serve(cluster, &trace, faults, &cfg).unwrap();
    assert_eq!(
        a.makespan.to_bits(),
        b.makespan.to_bits(),
        "makespan must replay bit-for-bit"
    );
    assert_eq!(a, b, "the whole report must replay identically");
    audit(&a, "replay");
}

#[test]
fn synthesized_traces_conserve_requests_across_seeds() {
    let cluster = railed_cluster(1, 4);
    let cfg = small_cfg();
    check("serving conservation", 6, |g: &mut Gen| {
        let seed = g.u64();
        let plan = TracePlan::synthesize(seed, 2e4, 10);
        let trace = plan.materialize();
        let rep = run_serve(cluster, &trace, FaultPlan::default(), &cfg)
            .unwrap_or_else(|e| panic!("trace seed {seed}: serve failed: {e}"));
        assert_eq!(
            rep.requests,
            trace.len(),
            "trace seed {seed}: every arrival is accounted"
        );
        audit(&rep, &format!("trace seed {seed}"));
        assert_eq!(rep.dropped, 0, "trace seed {seed}: no deaths, no drops");
        assert_eq!(rep.rerouted, 0, "trace seed {seed}: no deaths, no reroutes");
        assert!(rep.recoveries.is_empty(), "trace seed {seed}");
    });
}

#[test]
fn empty_trace_is_a_bit_identical_noop() {
    let cluster = railed_cluster(2, 2);
    let empty = TracePlan::default().materialize();
    assert!(empty.is_empty());
    // even under a fault plan: nothing arrives, nothing runs
    let faults = FaultPlan::parse("die,3,1e-6; flap,nic,1,0,1e-5,1e-4").unwrap();
    let rep = run_serve(cluster, &empty, faults, &small_cfg()).unwrap();
    assert_eq!(rep, ServingReport::default(), "empty trace must be a no-op");
    assert_eq!(rep.makespan.to_bits(), 0f64.to_bits());
}

#[test]
fn mid_serving_rank_death_is_a_p99_spike_not_a_failed_run() {
    let cluster = railed_cluster(2, 2);
    let cfg = small_cfg();
    let trace = TracePlan::parse("poisson,2e4,48,11; lens,96,12")
        .unwrap()
        .materialize();
    let horizon = trace.horizon();
    let clean = run_serve(cluster, &trace, FaultPlan::default(), &cfg).unwrap();
    audit(&clean, "clean");
    assert_eq!(clean.completed, 48, "fault-free run completes everything");

    // kill rank 3 a quarter into the arrival window: the run must
    // complete (never error), absorb the death, and show the damage
    let die_at = horizon * 0.25;
    let faults = FaultPlan::parse(&format!("die,3,{die_at}")).unwrap();
    let dead = run_serve(cluster, &trace, faults, &cfg)
        .unwrap_or_else(|e| panic!("mid-serving death must be survived, got: {e}"));
    audit(&dead, "death");
    assert_eq!(
        dead.requests, 48,
        "death run still accounts every request exactly"
    );
    assert_eq!(dead.recoveries.len(), 1, "the death must be on record");
    let rec = &dead.recoveries[0];
    assert_eq!(rec.dead, vec![3]);
    assert!(
        rec.resumed_at > rec.died_at,
        "the recovery pause must cost virtual time: {rec:?}"
    );
    assert!(
        dead.p99_ttft > clean.p99_ttft,
        "a mid-serving death must surface as a p99 TTFT spike: \
         clean {:.6e}s vs dead {:.6e}s",
        clean.p99_ttft,
        dead.p99_ttft
    );
    assert!(
        dead.makespan > clean.makespan,
        "the pause + re-prefill must stretch the run"
    );
}

#[test]
fn world_collapse_drops_the_remainder_with_exact_accounting() {
    // 2 GPUs total: one death leaves a single survivor — the fleet
    // cannot host the collectives, so everything left is dropped with a
    // reason, and the run still completes
    let cluster = railed_cluster(1, 2);
    let trace = TracePlan::parse("poisson,2e4,16,3").unwrap().materialize();
    let rep = run_serve(
        cluster,
        &trace,
        FaultPlan::parse("die,1,1e-4").unwrap(),
        &small_cfg(),
    )
    .unwrap_or_else(|e| panic!("world collapse must still complete: {e}"));
    audit(&rep, "collapse");
    assert!(rep.dropped > 0, "the stranded requests must be dropped");
    assert!(
        rep.drop_reasons.iter().any(|(w, _)| w == "world-collapsed"),
        "the drop reason must be named: {:?}",
        rep.drop_reasons
    );
}

// ---------------------------------------------------------------------
// trace-DSL robustness (same contract as the fault DSL)
// ---------------------------------------------------------------------

#[test]
fn fuzzed_trace_dsl_returns_structured_errors_never_panics() {
    let kinds = ["poisson", "bursty", "diurnal", "req", "lens", "bogus", ""];
    let nums = ["0", "3", "1e-3", "2e4", "-1", "nan", "inf", "0.5", "x", ""];
    check("fuzzed trace DSL: Ok or Err, never a panic", 256, |g: &mut Gen| {
        let clauses = g.usize_in(0, 5);
        let mut spec = String::new();
        for i in 0..clauses {
            if i > 0 {
                spec.push(';');
            }
            spec.push_str(g.pick(&kinds));
            for _ in 0..g.usize_in(0, 7) {
                spec.push(',');
                spec.push_str(g.pick(&nums));
            }
        }
        match TracePlan::parse(&spec) {
            Ok(_) => {}
            Err(e) => assert!(!e.is_empty(), "error must describe the clause: {spec:?}"),
        }
    });
}

#[test]
fn synthesized_plans_round_trip_through_display() {
    check("parse(display(p)) == p", 128, |g: &mut Gen| {
        let seed = g.u64();
        let p = TracePlan::synthesize(seed, 1e4, 20);
        let shown = p.to_string();
        let q = TracePlan::parse(&shown)
            .unwrap_or_else(|e| panic!("seed {seed}: display must re-parse: {shown:?}: {e}"));
        assert_eq!(p, q, "seed {seed}: round trip changed the plan:\n  {shown}");
        // and the materialized trace is identical through the round trip
        assert_eq!(
            p.materialize(),
            q.materialize(),
            "seed {seed}: round-tripped plan must materialize identically"
        );
    });
}

// ---------------------------------------------------------------------
// long-trace soak (label-gated in CI; see .github/workflows)
// ---------------------------------------------------------------------

/// 10^5-request diurnal soak: conservation, percentile sanity, and
/// replay must hold at scale, with a node death landing mid-trace. Every
/// assertion carries the seed so CI prints a minimal repro.
#[test]
#[ignore = "long-trace soak: run explicitly (cargo test --test serving -- --ignored)"]
fn soak_100k_request_diurnal_trace_with_mid_trace_death() {
    let seed = 2026u64;
    let cluster = railed_cluster(2, 4);
    let cfg = ServeCfg {
        moe_experts: 8,
        moe_hidden: 64,
        ..ServeCfg::default()
    };
    let trace = TracePlan::parse(&format!("diurnal,2e5,100000,{seed},8e-3,0.75; lens,64,8"))
        .unwrap_or_else(|e| panic!("seed {seed}: {e}"))
        .materialize();
    assert_eq!(trace.len(), 100_000, "seed {seed}");
    let die_at = trace.horizon() * 0.5;
    let faults = FaultPlan::parse(&format!("die,5,{die_at}"))
        .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    let rep = run_serve(cluster, &trace, faults.clone(), &cfg)
        .unwrap_or_else(|e| panic!("seed {seed}: soak must complete: {e}"));
    audit(&rep, &format!("soak seed {seed}"));
    assert_eq!(rep.requests, 100_000, "seed {seed}");
    assert_eq!(rep.recoveries.len(), 1, "seed {seed}: the death must fire");
    // oversubscribed on purpose: queue-full shedding is fine (and
    // accounted), but the fleet must keep completing work throughout
    assert!(
        rep.completed >= 1000,
        "seed {seed}: the fleet must keep serving through the death \
         (completed {} of {})",
        rep.completed,
        rep.requests
    );
    let again = run_serve(cluster, &trace, faults, &cfg)
        .unwrap_or_else(|e| panic!("seed {seed}: replay must complete: {e}"));
    assert_eq!(
        rep.makespan.to_bits(),
        again.makespan.to_bits(),
        "seed {seed}: soak must replay bit-for-bit"
    );
    assert_eq!(rep, again, "seed {seed}: full report replay");
}
