//! Discrete-event cluster simulator.
//!
//! This is the substrate that replaces the paper's GPU cluster (DESIGN.md
//! §1): virtual time, capacity-shared links ([`flow`]), SM pools, copy
//! engines, signals, barriers — executing the same async-task programs the
//! paper runs on real hardware, and optionally carrying real numerics
//! through the symmetric heap.

pub mod engine;
pub mod flow;
pub(crate) mod par;

pub use engine::{
    ComputeExecutor, DeadPeerInfo, FaultLedger, NoopExecutor, OpSpan, RecoveryLedger, Sim,
    SimConfig, SimError, SimReport,
};
pub use flow::{FlowId, FlowNet, RateUpdate};
