//! Flow network: concurrent transfers sharing link capacity.
//!
//! Every in-flight transfer is a *flow* occupying a set of links (its
//! route). Rates are assigned by **max–min fairness** (progressive
//! water-filling): repeatedly find the most-contended link, give its flows
//! an equal share of its remaining capacity, freeze them, and continue.
//! This is the standard fluid model for switched fabrics and matches how
//! NVSwitch/PCIe/NIC bandwidth degrades under contention closely enough
//! for overlap analysis (the paper's own §3.5 back-of-envelope uses the
//! same linear bandwidth-sharing arithmetic).
//!
//! # Incremental recomputation
//!
//! Max–min allocation decomposes exactly over connected components of the
//! bipartite flow↔link incidence graph: water-filling never moves
//! capacity between links that share no flow (transitively), so an
//! add/remove can only change rates inside the component of the touched
//! flow. [`FlowNet::update`] exploits this:
//!
//! * a persistent link→flows incidence index (swap-remove with per-flow
//!   position back-pointers) makes component discovery O(component);
//! * progress accrual is lazy per flow (`last_settle` timestamps), so an
//!   update touches only the component instead of sweeping all F flows;
//! * the water-filling pass performs the identical floating-point
//!   operations as a from-scratch global pass restricted to the
//!   component, so rates are **bit-identical** to a full recompute —
//!   `tests/flow_equivalence.rs` proves this on randomized traces;
//! * flows in untouched components keep their rates *and* their scheduled
//!   completion events (the generation mechanism leaves them current).
//!
//! # Dirty-set priority refill
//!
//! Routed fabrics (leaf/spine tiers) put thousands of flows on a few
//! shared switch links, so one connected component can span the whole
//! world (a 512-rank AllToAll ≈ 260k flows on one spine plane). The
//! water-fill therefore avoids every per-component linear rescan:
//!
//! * bottleneck selection pops a **lazy min-heap** keyed
//!   `(share, link)` instead of scanning all component links per freeze
//!   round; entries are invalidated by comparing their recorded
//!   `(capacity, unfrozen)` against the link's current state;
//! * each freeze round re-arms only the **dirty set** — links whose fill
//!   level actually changed because one of their flows froze;
//! * freeze order within a bottleneck link follows the persistent
//!   incidence list directly (no per-update clone + sort): every flow of
//!   the round receives the same `share`, and the links they touch see
//!   the same chain of identical subtractions in any order, so the
//!   resulting rates are unchanged bit-for-bit.
//!
//! The heap pops the smallest share and breaks ties by link index —
//! exactly the link the ascending linear scan with a strict `<` would
//! have chosen — so incremental results remain bit-identical to
//! [`FlowNet::reference_rates`].
//!
//! Batching: the DES engine coalesces all adds/removes carrying the same
//! virtual timestamp into a single `update` call, so the N simultaneous
//! puts a collective issues cost one component recompute instead of N
//! global ones.
//!
//! # Component structure across the intra/fabric boundary
//!
//! The same decomposition property powers the sharded engine
//! (`sim/par.rs`): an intra-node route uses only one node's
//! NVLink/mesh/PCIe/HBM links, and an inter-node route uses only
//! NIC/leaf/spine links ([`Topology::is_fabric_link`]), so no flow — and
//! therefore no connected component — ever spans the boundary. Each node
//! partition runs its own `FlowNet` over its intra-node flows, and the
//! shared fabric runner owns every fabric flow; every per-component
//! water-fill performs the identical floating-point operations it would
//! inside one global net (link ids are global in all nets, and the
//! per-link incidence order is pinned by the engine's canonical
//! `(task, launch)` batch key), keeping the split bit-identical to the
//! single-threaded solve.
//!
//! [`Topology::is_fabric_link`]: crate::topology::Topology::is_fabric_link

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

use crate::topology::LinkId;

/// Handle to an active flow.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FlowId(pub usize);

#[derive(Debug, Clone, Default)]
struct Flow {
    links: Vec<LinkId>,
    /// Position of this flow inside `incidence[links[k]]` (swap-remove
    /// back-pointers; parallel to `links`).
    pos: Vec<u32>,
    bytes_left: f64,
    rate: f64,
    /// Time `bytes_left` was last accrued (per-flow lazy settle).
    last_settle: f64,
    /// Generation counter: completion events carry the generation they
    /// were scheduled under; rate changes bump it, invalidating stale
    /// events.
    gen: u64,
    alive: bool,
}

/// One lazy-heap entry of the priority refill: the fair share a link
/// offered when it was (re-)armed, plus the `(cap, unfrozen)` snapshot
/// that validates freshness at pop time. Ordered by `(share, link)` so
/// the pop order matches an ascending linear scan with a strict `<`.
#[derive(Debug, Clone, Copy)]
struct ShareEnt {
    share: f64,
    cap: f64,
    unfrozen: u32,
    link: u32,
}

impl PartialEq for ShareEnt {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for ShareEnt {}
impl PartialOrd for ShareEnt {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for ShareEnt {
    fn cmp(&self, other: &Self) -> Ordering {
        self.share
            .total_cmp(&other.share)
            .then(self.link.cmp(&other.link))
    }
}

/// The set of active flows plus link capacities.
pub struct FlowNet {
    link_bw: Vec<f64>,
    /// Alive flows currently occupying each link (unordered; positions
    /// are tracked by the flows themselves).
    incidence: Vec<Vec<u32>>,
    flows: Vec<Flow>,
    free: Vec<usize>,
    /// Latest update time seen (monotonicity checks only; progress is
    /// accrued per flow, not globally).
    last_now: f64,
    n_active: usize,
    // --- reusable scratch for update (hot path; avoids per-call allocs)
    scratch_cap: Vec<f64>,
    scratch_unfrozen: Vec<u32>,
    scratch_link_seen: Vec<bool>,
    scratch_flow_seen: Vec<bool>,
    scratch_frozen: Vec<bool>,
    scratch_comp_links: Vec<u32>,
    scratch_comp_flows: Vec<u32>,
    scratch_old_rates: Vec<(u32, f64)>,
    /// Lazy bottleneck heap of the priority refill.
    scratch_heap: BinaryHeap<Reverse<ShareEnt>>,
    /// Links whose fill level changed this freeze round (the dirty set).
    scratch_dirty: Vec<u32>,
    scratch_dirty_flag: Vec<bool>,
}

/// Result of a rate recomputation: each affected flow's new completion
/// ETA. Flows whose rate did not change are absent — their previously
/// scheduled completion events remain exact and current.
pub struct RateUpdate {
    /// (flow, generation, eta_seconds_from_now)
    pub etas: Vec<(FlowId, u64, f64)>,
}

impl FlowNet {
    pub fn new(link_bw: Vec<f64>) -> Self {
        let nl = link_bw.len();
        FlowNet {
            link_bw,
            incidence: (0..nl).map(|_| Vec::new()).collect(),
            flows: Vec::new(),
            free: Vec::new(),
            last_now: 0.0,
            n_active: 0,
            scratch_cap: vec![0.0; nl],
            scratch_unfrozen: vec![0; nl],
            scratch_link_seen: vec![false; nl],
            scratch_flow_seen: Vec::new(),
            scratch_frozen: Vec::new(),
            scratch_comp_links: Vec::new(),
            scratch_comp_flows: Vec::new(),
            scratch_old_rates: Vec::new(),
            scratch_heap: BinaryHeap::new(),
            scratch_dirty: Vec::new(),
            scratch_dirty_flag: vec![false; nl],
        }
    }

    pub fn n_active(&self) -> usize {
        self.n_active
    }

    /// Add a flow at `now`; returns its id and the rate update for every
    /// flow whose rate changed (the caller reschedules completion
    /// events).
    pub fn add(&mut self, now: f64, links: Vec<LinkId>, bytes: f64) -> (FlowId, RateUpdate) {
        let (ids, up) = self.update(now, &[], vec![(links, bytes)]);
        (ids[0], up)
    }

    /// Remove a completed (or cancelled) flow; returns the rate update.
    pub fn remove(&mut self, now: f64, id: FlowId) -> RateUpdate {
        self.update(now, &[id], Vec::new()).1
    }

    /// Batched add/remove at one timestamp: all removals and additions
    /// are applied, then rates are recomputed **once**, scoped to the
    /// connected component(s) of links reachable from the touched flows.
    /// Returns the new flows' ids (in `adds` order) and the rate update.
    ///
    /// Equivalent to performing the operations one at a time (final
    /// rates depend only on the final flow set), but N simultaneous puts
    /// cost one water-filling pass instead of N.
    pub fn update(
        &mut self,
        now: f64,
        removes: &[FlowId],
        adds: Vec<(Vec<LinkId>, f64)>,
    ) -> (Vec<FlowId>, RateUpdate) {
        debug_assert!(
            now >= self.last_now - 1e-12,
            "time went backwards: {now} < {}",
            self.last_now
        );
        if now > self.last_now {
            self.last_now = now;
        }

        // 1. insert the new flows (into slots + incidence) so they bridge
        //    components during discovery
        let mut new_ids = Vec::with_capacity(adds.len());
        for (links, bytes) in adds {
            debug_assert!(bytes > 0.0, "zero-byte flow");
            debug_assert!(
                links.iter().enumerate().all(|(k, a)| links[..k].iter().all(|b| a != b)),
                "route visits a link twice: {links:?}"
            );
            let flow = Flow {
                links,
                pos: Vec::new(),
                bytes_left: bytes,
                rate: 0.0,
                last_settle: now,
                gen: 0,
                alive: true,
            };
            let i = if let Some(i) = self.free.pop() {
                // preserve the slot's generation across reuse: completion
                // events of the previous occupant must stay stale
                let gen = self.flows[i].gen;
                self.flows[i] = Flow { gen, ..flow };
                i
            } else {
                self.flows.push(flow);
                self.flows.len() - 1
            };
            self.link_into_incidence(i);
            self.n_active += 1;
            new_ids.push(FlowId(i));
        }
        if self.scratch_flow_seen.len() < self.flows.len() {
            self.scratch_flow_seen.resize(self.flows.len(), false);
            self.scratch_frozen.resize(self.flows.len(), false);
        }

        // 2. discover the touched component(s): BFS over the bipartite
        //    flow↔link graph seeded at every removed and added flow
        self.scratch_comp_flows.clear();
        self.scratch_comp_links.clear();
        for id in removes {
            assert!(self.flows[id.0].alive, "double remove of flow {id:?}");
            if !self.scratch_flow_seen[id.0] {
                self.scratch_flow_seen[id.0] = true;
                self.scratch_comp_flows.push(id.0 as u32);
            }
        }
        for id in &new_ids {
            if !self.scratch_flow_seen[id.0] {
                self.scratch_flow_seen[id.0] = true;
                self.scratch_comp_flows.push(id.0 as u32);
            }
        }
        let mut qi = 0;
        while qi < self.scratch_comp_flows.len() {
            let fi = self.scratch_comp_flows[qi] as usize;
            qi += 1;
            for k in 0..self.flows[fi].links.len() {
                let l = self.flows[fi].links[k].0;
                if self.scratch_link_seen[l] {
                    continue;
                }
                self.scratch_link_seen[l] = true;
                self.scratch_comp_links.push(l as u32);
                for j in 0..self.incidence[l].len() {
                    let f2 = self.incidence[l][j] as usize;
                    if !self.scratch_flow_seen[f2] {
                        self.scratch_flow_seen[f2] = true;
                        self.scratch_comp_flows.push(f2 as u32);
                    }
                }
            }
        }

        // 3. apply removals (after discovery: the pre-removal component
        //    is the superset that must be refilled if it splits)
        for id in removes {
            self.flows[id.0].alive = false;
            self.unlink_from_incidence(id.0);
            self.free.push(id.0);
            self.n_active -= 1;
        }

        // 4. lazily accrue progress — only for the touched component
        for k in 0..self.scratch_comp_flows.len() {
            let fi = self.scratch_comp_flows[k] as usize;
            let f = &mut self.flows[fi];
            if !f.alive {
                continue;
            }
            let dt = now - f.last_settle;
            if dt > 0.0 {
                f.bytes_left = (f.bytes_left - f.rate * dt).max(0.0);
            }
            f.last_settle = now;
        }

        // 5. water-fill the component; flows elsewhere keep their rates
        //    and their scheduled completion events
        let mut comp_flows = std::mem::take(&mut self.scratch_comp_flows);
        let mut comp_links = std::mem::take(&mut self.scratch_comp_links);
        comp_flows.sort_unstable();
        comp_links.sort_unstable();
        let etas = self.refill_component(&comp_flows, &comp_links);

        // 6. reset the visit stamps for the next call
        for &fi in &comp_flows {
            self.scratch_flow_seen[fi as usize] = false;
        }
        for &l in &comp_links {
            self.scratch_link_seen[l as usize] = false;
        }
        self.scratch_comp_flows = comp_flows;
        self.scratch_comp_links = comp_links;

        (new_ids, RateUpdate { etas })
    }

    /// Append flow `fi` to the incidence list of each of its links,
    /// recording the swap-remove back-pointers.
    fn link_into_incidence(&mut self, fi: usize) {
        let links = std::mem::take(&mut self.flows[fi].links);
        let mut pos = std::mem::take(&mut self.flows[fi].pos);
        pos.clear();
        for &l in &links {
            let list = &mut self.incidence[l.0];
            pos.push(list.len() as u32);
            list.push(fi as u32);
        }
        self.flows[fi].links = links;
        self.flows[fi].pos = pos;
    }

    /// Remove flow `fi` from every incidence list in O(route length),
    /// patching the back-pointer of whichever flow gets swapped into the
    /// vacated slot.
    fn unlink_from_incidence(&mut self, fi: usize) {
        let links = std::mem::take(&mut self.flows[fi].links);
        let pos = std::mem::take(&mut self.flows[fi].pos);
        for (k, &l) in links.iter().enumerate() {
            let p = pos[k] as usize;
            let list = &mut self.incidence[l.0];
            debug_assert_eq!(list[p] as usize, fi, "incidence index corrupt");
            list.swap_remove(p);
            if p < list.len() {
                let moved = list[p] as usize;
                let mf = &mut self.flows[moved];
                let idx = mf
                    .links
                    .iter()
                    .position(|&ml| ml == l)
                    .expect("incidence index corrupt");
                mf.pos[idx] = p as u32;
            }
        }
        self.flows[fi].links = links;
        self.flows[fi].pos = pos;
    }

    /// Max–min water-filling over one connected component, with the
    /// dirty-set priority refill (see the module doc): bottleneck
    /// selection pops a lazy min-heap keyed `(share, link)` instead of
    /// rescanning every component link per freeze round, and only links
    /// whose fill level changed in a round are re-armed.
    ///
    /// Bit-identity with a from-scratch fill (`reference_rates`) holds
    /// because (a) a validated heap entry's `(cap, unfrozen)` snapshot is
    /// the link's current state, so its share is the very division the
    /// linear scan would compute, and the `(share, link)` order picks the
    /// same link a strict-`<` ascending scan picks; (b) every flow of a
    /// freeze round receives the identical `best_share`, so the chain of
    /// same-valued subtractions any other link sees is order-independent
    /// bit-for-bit.
    ///
    /// Completion events are only re-issued for flows whose rate actually
    /// changed (plus fresh zero-rate flows): an unchanged rate means the
    /// previously scheduled completion time is still exact, so the old
    /// event stays current — this cuts event-queue churn from O(F) to
    /// O(changed) per update.
    fn refill_component(
        &mut self,
        comp_flows: &[u32],
        comp_links: &[u32],
    ) -> Vec<(FlowId, u64, f64)> {
        let mut remaining = 0usize;
        self.scratch_old_rates.clear();
        for &fi in comp_flows {
            let f = &self.flows[fi as usize];
            if f.alive {
                self.scratch_frozen[fi as usize] = false;
                self.scratch_old_rates.push((fi, f.rate));
                remaining += 1;
            } else {
                self.scratch_frozen[fi as usize] = true;
            }
        }
        self.scratch_heap.clear();
        for &l in comp_links {
            let l = l as usize;
            self.scratch_cap[l] = self.link_bw[l];
            let unfrozen = self.incidence[l].len() as u32;
            self.scratch_unfrozen[l] = unfrozen;
            if unfrozen > 0 {
                self.scratch_heap.push(Reverse(ShareEnt {
                    share: self.scratch_cap[l] / unfrozen as f64,
                    cap: self.scratch_cap[l],
                    unfrozen,
                    link: l as u32,
                }));
            }
        }

        while remaining > 0 {
            // bottleneck link = fresh minimum of the lazy heap; stale
            // entries (whose snapshot no longer matches the link) are
            // discarded on pop. Invariant: every link with unfrozen > 0
            // has exactly one fresh entry (armed at init or at its last
            // dirty-set re-arm), so an empty heap means the remaining
            // flows traverse no capacity-constrained link at all.
            let best = loop {
                match self.scratch_heap.pop() {
                    None => break None,
                    Some(Reverse(e)) => {
                        let l = e.link as usize;
                        if self.scratch_unfrozen[l] == e.unfrozen
                            && self.scratch_cap[l].to_bits() == e.cap.to_bits()
                        {
                            break Some(e);
                        }
                    }
                }
            };
            let Some(ent) = best else {
                // flows with no links (shouldn't happen) get infinite rate
                for &fi in comp_flows {
                    if !self.scratch_frozen[fi as usize] {
                        self.flows[fi as usize].rate = f64::INFINITY;
                        self.scratch_frozen[fi as usize] = true;
                    }
                }
                break;
            };
            let best_link = ent.link as usize;
            let best_share = ent.share;
            // freeze the bottleneck link's unfrozen flows at best_share,
            // walking the persistent incidence list directly (taken out
            // of `self` for the borrow, restored after)
            let list = std::mem::take(&mut self.incidence[best_link]);
            for &fi in &list {
                let i = fi as usize;
                if self.scratch_frozen[i] {
                    continue;
                }
                self.flows[i].rate = best_share;
                self.scratch_frozen[i] = true;
                remaining -= 1;
                for l in &self.flows[i].links {
                    let l = l.0;
                    self.scratch_cap[l] = (self.scratch_cap[l] - best_share).max(0.0);
                    self.scratch_unfrozen[l] -= 1;
                    if !self.scratch_dirty_flag[l] {
                        self.scratch_dirty_flag[l] = true;
                        self.scratch_dirty.push(l as u32);
                    }
                }
            }
            self.incidence[best_link] = list;
            // re-arm only the links whose fill level changed this round
            for k in 0..self.scratch_dirty.len() {
                let l = self.scratch_dirty[k] as usize;
                self.scratch_dirty_flag[l] = false;
                let unfrozen = self.scratch_unfrozen[l];
                if unfrozen > 0 {
                    self.scratch_heap.push(Reverse(ShareEnt {
                        share: self.scratch_cap[l] / unfrozen as f64,
                        cap: self.scratch_cap[l],
                        unfrozen,
                        link: l as u32,
                    }));
                }
            }
            self.scratch_dirty.clear();
        }

        // bump generations + produce ETAs only where the rate changed
        let mut etas = Vec::new();
        for k in 0..self.scratch_old_rates.len() {
            let (fi, old) = self.scratch_old_rates[k];
            let f = &mut self.flows[fi as usize];
            if f.rate == old && old > 0.0 {
                continue; // previous completion event is still exact
            }
            f.gen += 1;
            let eta = if f.bytes_left <= 0.0 {
                0.0
            } else if f.rate > 0.0 {
                f.bytes_left / f.rate
            } else {
                f64::INFINITY
            };
            etas.push((FlowId(fi as usize), f.gen, eta));
        }
        etas
    }

    /// Change link capacities mid-run (fault injection: degradation,
    /// down intervals, recovery) and incrementally re-solve **only the
    /// touched component(s)** — the same scoped water-fill as
    /// [`FlowNet::update`], seeded from the flows incident to the
    /// retargeted links. Links with no flows just record their new
    /// capacity. Flows whose rate changes get a bumped generation and a
    /// fresh ETA (`INFINITY` when the new capacity is zero — the caller
    /// must not schedule those; the generation bump already invalidated
    /// the old completion event, so the flow simply stalls until a later
    /// retarget or removal revives its component).
    pub fn retarget(&mut self, now: f64, changes: &[(LinkId, f64)]) -> RateUpdate {
        debug_assert!(
            now >= self.last_now - 1e-12,
            "time went backwards: {now} < {}",
            self.last_now
        );
        if now > self.last_now {
            self.last_now = now;
        }
        self.scratch_comp_flows.clear();
        self.scratch_comp_links.clear();
        for &(l, bw) in changes {
            debug_assert!(bw >= 0.0 && !bw.is_nan(), "negative link capacity");
            self.link_bw[l.0] = bw;
            // seed discovery at the changed link so it is refilled (and
            // its visit stamp reset) even when the BFS reaches it from
            // no flow
            if !self.scratch_link_seen[l.0] {
                self.scratch_link_seen[l.0] = true;
                self.scratch_comp_links.push(l.0 as u32);
                for j in 0..self.incidence[l.0].len() {
                    let fi = self.incidence[l.0][j] as usize;
                    if !self.scratch_flow_seen[fi] {
                        self.scratch_flow_seen[fi] = true;
                        self.scratch_comp_flows.push(fi as u32);
                    }
                }
            }
        }
        // BFS the rest of the component(s), exactly as `update` does
        let mut qi = 0;
        while qi < self.scratch_comp_flows.len() {
            let fi = self.scratch_comp_flows[qi] as usize;
            qi += 1;
            for k in 0..self.flows[fi].links.len() {
                let l = self.flows[fi].links[k].0;
                if self.scratch_link_seen[l] {
                    continue;
                }
                self.scratch_link_seen[l] = true;
                self.scratch_comp_links.push(l as u32);
                for j in 0..self.incidence[l].len() {
                    let f2 = self.incidence[l][j] as usize;
                    if !self.scratch_flow_seen[f2] {
                        self.scratch_flow_seen[f2] = true;
                        self.scratch_comp_flows.push(f2 as u32);
                    }
                }
            }
        }
        // accrue progress at the old rates, then refill with the new caps
        for k in 0..self.scratch_comp_flows.len() {
            let fi = self.scratch_comp_flows[k] as usize;
            let f = &mut self.flows[fi];
            let dt = now - f.last_settle;
            if dt > 0.0 {
                f.bytes_left = (f.bytes_left - f.rate * dt).max(0.0);
            }
            f.last_settle = now;
        }
        let mut comp_flows = std::mem::take(&mut self.scratch_comp_flows);
        let mut comp_links = std::mem::take(&mut self.scratch_comp_links);
        comp_flows.sort_unstable();
        comp_links.sort_unstable();
        let etas = self.refill_component(&comp_flows, &comp_links);
        for &fi in &comp_flows {
            self.scratch_flow_seen[fi as usize] = false;
        }
        for &l in &comp_links {
            self.scratch_link_seen[l as usize] = false;
        }
        self.scratch_comp_flows = comp_flows;
        self.scratch_comp_links = comp_links;
        RateUpdate { etas }
    }

    /// Current capacity of a link (reflects any retargeting).
    pub fn link_capacity(&self, l: LinkId) -> f64 {
        self.link_bw[l.0]
    }

    /// The alive flows currently traversing link `l` (unordered). The
    /// engine uses this to find the victims of a link-down fault.
    pub fn flows_on(&self, l: LinkId) -> Vec<FlowId> {
        self.incidence[l.0]
            .iter()
            .map(|&fi| FlowId(fi as usize))
            .collect()
    }

    /// Is `gen` the current generation of `id`? (Stale-event filter.)
    pub fn is_current(&self, id: FlowId, gen: u64) -> bool {
        let f = &self.flows[id.0];
        f.alive && f.gen == gen
    }

    /// The links an alive flow occupies (its route). The engine uses this
    /// at completion time to release the flow's
    /// [`LinkOccupancy`](crate::topology::LinkOccupancy) share — the
    /// congestion feedback the adaptive rail router reads — without
    /// cloning routes into its per-flow contexts.
    pub fn links_of(&self, id: FlowId) -> &[LinkId] {
        debug_assert!(self.flows[id.0].alive, "links_of on a dead flow");
        &self.flows[id.0].links
    }

    /// Remaining bytes of a flow (diagnostics/tests). Reflects progress
    /// only up to the flow's last settle — see [`Self::remaining_at`].
    pub fn bytes_left(&self, id: FlowId) -> f64 {
        self.flows[id.0].bytes_left
    }

    /// Remaining bytes of a flow projected to time `now` (without
    /// mutating state).
    pub fn remaining_at(&self, id: FlowId, now: f64) -> f64 {
        let f = &self.flows[id.0];
        (f.bytes_left - f.rate * (now - f.last_settle).max(0.0)).max(0.0)
    }

    pub fn rate(&self, id: FlowId) -> f64 {
        self.flows[id.0].rate
    }

    /// Current max–min rates recomputed from scratch over the whole
    /// network, ignoring all incremental state (reference for the
    /// equivalence suite; O(F·L) — never on the hot path).
    pub fn reference_rates(&self) -> Vec<(FlowId, f64)> {
        let nl = self.link_bw.len();
        let mut cap = self.link_bw.clone();
        let mut link_flows: Vec<Vec<u32>> = (0..nl).map(|_| Vec::new()).collect();
        let mut ids = Vec::new();
        for (i, f) in self.flows.iter().enumerate() {
            if !f.alive {
                continue;
            }
            ids.push(i as u32);
            for l in &f.links {
                link_flows[l.0].push(i as u32);
            }
        }
        let mut rates: Vec<f64> = vec![0.0; self.flows.len()];
        let mut frozen = vec![false; self.flows.len()];
        let mut unfrozen: Vec<u32> = link_flows.iter().map(|lf| lf.len() as u32).collect();
        let mut active: Vec<u32> = (0..nl as u32)
            .filter(|&l| !link_flows[l as usize].is_empty())
            .collect();
        let mut remaining = ids.len();
        while remaining > 0 {
            let mut best_share = f64::INFINITY;
            let mut best_link = usize::MAX;
            let mut w = 0;
            for k in 0..active.len() {
                let l = active[k] as usize;
                if unfrozen[l] == 0 {
                    continue;
                }
                active[w] = l as u32;
                w += 1;
                let share = cap[l] / unfrozen[l] as f64;
                if share < best_share {
                    best_share = share;
                    best_link = l;
                }
            }
            active.truncate(w);
            if best_link == usize::MAX {
                for &fi in &ids {
                    if !frozen[fi as usize] {
                        rates[fi as usize] = f64::INFINITY;
                        frozen[fi as usize] = true;
                    }
                }
                break;
            }
            let list = std::mem::take(&mut link_flows[best_link]);
            for &fi in &list {
                let i = fi as usize;
                if frozen[i] {
                    continue;
                }
                rates[i] = best_share;
                frozen[i] = true;
                remaining -= 1;
                for l in &self.flows[i].links {
                    cap[l.0] = (cap[l.0] - best_share).max(0.0);
                    unfrozen[l.0] -= 1;
                }
            }
            link_flows[best_link] = list;
        }
        ids.into_iter()
            .map(|fi| (FlowId(fi as usize), rates[fi as usize]))
            .collect()
    }

    /// Invariant check: total rate through every link <= its capacity
    /// (within fp tolerance). Used by tests and debug assertions.
    pub fn check_capacity(&self) -> Result<(), String> {
        let mut used = vec![0.0f64; self.link_bw.len()];
        for f in self.flows.iter().filter(|f| f.alive) {
            for l in &f.links {
                used[l.0] += f.rate;
            }
        }
        for (l, (&u, &c)) in used.iter().zip(self.link_bw.iter()).enumerate() {
            if u > c * (1.0 + 1e-9) + 1e-9 {
                return Err(format!("link {l} oversubscribed: {u} > {c}"));
            }
        }
        Ok(())
    }

    /// Structural invariant check for the persistent incidence index
    /// (tests only): every alive flow's back-pointers are consistent and
    /// every incidence entry points at an alive flow that lists the link.
    pub fn check_incidence(&self) -> Result<(), String> {
        for (i, f) in self.flows.iter().enumerate() {
            if !f.alive {
                continue;
            }
            if f.links.len() != f.pos.len() {
                return Err(format!("flow {i}: links/pos length mismatch"));
            }
            for (k, &l) in f.links.iter().enumerate() {
                let p = f.pos[k] as usize;
                match self.incidence[l.0].get(p) {
                    Some(&fi) if fi as usize == i => {}
                    _ => return Err(format!("flow {i} pos for link {} is stale", l.0)),
                }
            }
        }
        for (l, list) in self.incidence.iter().enumerate() {
            for &fi in list {
                let f = &self.flows[fi as usize];
                if !f.alive {
                    return Err(format!("link {l} lists dead flow {fi}"));
                }
                if !f.links.contains(&LinkId(l)) {
                    return Err(format!("link {l} lists flow {fi} that doesn't use it"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(caps: &[f64]) -> FlowNet {
        FlowNet::new(caps.to_vec())
    }

    #[test]
    fn single_flow_gets_full_capacity() {
        let mut n = net(&[100.0]);
        let (id, up) = n.add(0.0, vec![LinkId(0)], 1000.0);
        assert_eq!(n.rate(id), 100.0);
        assert_eq!(up.etas.len(), 1);
        assert!((up.etas[0].2 - 10.0).abs() < 1e-9);
    }

    #[test]
    fn two_flows_share_equally() {
        let mut n = net(&[100.0]);
        let (a, _) = n.add(0.0, vec![LinkId(0)], 1000.0);
        let (b, up) = n.add(0.0, vec![LinkId(0)], 1000.0);
        assert_eq!(n.rate(a), 50.0);
        assert_eq!(n.rate(b), 50.0);
        assert_eq!(up.etas.len(), 2);
        n.check_capacity().unwrap();
        n.check_incidence().unwrap();
    }

    #[test]
    fn max_min_gives_leftover_to_unbottlenecked() {
        // flow A uses links 0+1; flow B uses link 0 only.
        // link0 cap 100 shared -> 50 each; link1 cap 30 limits A to 30;
        // B then gets the leftover 70 on link 0.
        let mut n = net(&[100.0, 30.0]);
        let (a, _) = n.add(0.0, vec![LinkId(0), LinkId(1)], 1e9);
        let (b, _) = n.add(0.0, vec![LinkId(0)], 1e9);
        assert!((n.rate(a) - 30.0).abs() < 1e-9, "{}", n.rate(a));
        assert!((n.rate(b) - 70.0).abs() < 1e-9, "{}", n.rate(b));
        n.check_capacity().unwrap();
    }

    #[test]
    fn progress_accrues_between_updates() {
        let mut n = net(&[100.0]);
        let (a, _) = n.add(0.0, vec![LinkId(0)], 1000.0);
        // at t=5 add another flow: A should have 500 bytes left
        let (_b, up) = n.add(5.0, vec![LinkId(0)], 1000.0);
        assert!((n.bytes_left(a) - 500.0).abs() < 1e-9);
        // both now at 50 B/s: A finishes in 10s, B in 20s
        let eta_a = up.etas.iter().find(|e| e.0 == a).unwrap().2;
        assert!((eta_a - 10.0).abs() < 1e-9);
    }

    #[test]
    fn remove_restores_capacity() {
        let mut n = net(&[100.0]);
        let (a, _) = n.add(0.0, vec![LinkId(0)], 1000.0);
        let (b, _) = n.add(0.0, vec![LinkId(0)], 1000.0);
        let up = n.remove(10.0, a); // each did 500 bytes by t=10
        assert_eq!(n.n_active(), 1);
        let eta_b = up.etas.iter().find(|e| e.0 == b).unwrap().2;
        // b has 500 left at 100 B/s
        assert!((eta_b - 5.0).abs() < 1e-9, "{eta_b}");
    }

    #[test]
    fn generation_invalidates_stale_events() {
        let mut n = net(&[100.0]);
        let (a, up1) = n.add(0.0, vec![LinkId(0)], 1000.0);
        let gen1 = up1.etas[0].1;
        assert!(n.is_current(a, gen1));
        let (_b, up2) = n.add(1.0, vec![LinkId(0)], 1000.0);
        let gen2 = up2.etas.iter().find(|e| e.0 == a).unwrap().1;
        assert!(!n.is_current(a, gen1));
        assert!(n.is_current(a, gen2));
    }

    #[test]
    fn untouched_component_keeps_rates_and_events() {
        // flows on disjoint links are separate components: adding or
        // removing on link 1 must not disturb the flow on link 0 at all
        let mut n = net(&[100.0, 80.0]);
        let (a, up_a) = n.add(0.0, vec![LinkId(0)], 1e6);
        let gen_a = up_a.etas[0].1;
        let (b, up_b) = n.add(1.0, vec![LinkId(1)], 1e6);
        assert!(n.is_current(a, gen_a), "a's completion event must survive");
        assert_eq!(n.rate(a), 100.0);
        assert_eq!(n.rate(b), 80.0);
        // b's update must not mention a at all
        assert!(up_b.etas.iter().all(|e| e.0 != a));
        let up_rm = n.remove(2.0, b);
        assert!(up_rm.etas.is_empty(), "removing b touches nobody else");
        assert!(n.is_current(a, gen_a));
        n.check_incidence().unwrap();
    }

    #[test]
    fn bridge_flow_merges_components() {
        let mut n = net(&[100.0, 100.0]);
        let (a, _) = n.add(0.0, vec![LinkId(0)], 1e6);
        let (b, _) = n.add(0.0, vec![LinkId(1)], 1e6);
        // c spans both links: all three now share one component
        let (c, up) = n.add(0.0, vec![LinkId(0), LinkId(1)], 1e6);
        let touched: Vec<FlowId> = up.etas.iter().map(|e| e.0).collect();
        assert!(touched.contains(&a) && touched.contains(&b) && touched.contains(&c));
        assert_eq!(n.rate(a), 50.0);
        assert_eq!(n.rate(b), 50.0);
        assert_eq!(n.rate(c), 50.0);
        n.check_capacity().unwrap();
    }

    #[test]
    fn batched_update_equals_sequential() {
        let links = |v: &[usize]| v.iter().map(|&l| LinkId(l)).collect::<Vec<_>>();
        let mut seq = net(&[100.0, 60.0, 40.0]);
        let mut bat = net(&[100.0, 60.0, 40.0]);
        let (s0, _) = seq.add(0.0, links(&[0, 1]), 500.0);
        let (b0, _) = bat.add(0.0, links(&[0, 1]), 500.0);
        // sequential: two adds + one remove, each with its own recompute
        seq.remove(1.0, s0);
        let (s1, _) = seq.add(1.0, links(&[0]), 300.0);
        let (s2, _) = seq.add(1.0, links(&[1, 2]), 400.0);
        // batched: one update call at the same timestamp
        let (ids, _) = bat.update(1.0, &[b0], vec![(links(&[0]), 300.0), (links(&[1, 2]), 400.0)]);
        assert_eq!(
            seq.rate(s1).to_bits(),
            bat.rate(ids[0]).to_bits(),
            "batched rates must be bit-identical to sequential"
        );
        assert_eq!(seq.rate(s2).to_bits(), bat.rate(ids[1]).to_bits());
        bat.check_capacity().unwrap();
        bat.check_incidence().unwrap();
    }

    #[test]
    fn incremental_matches_reference_fill() {
        let mut n = net(&[100.0, 60.0, 40.0, 80.0]);
        let mut ids = Vec::new();
        for (ls, bytes) in [
            (vec![0usize, 1], 1e5),
            (vec![1, 2], 2e5),
            (vec![3], 3e5),
            (vec![0, 3], 4e5),
            (vec![2], 5e5),
        ] {
            let (id, _) = n.add(0.0, ls.into_iter().map(LinkId).collect(), bytes);
            ids.push(id);
        }
        n.remove(1.0, ids[1]);
        for (id, r) in n.reference_rates() {
            assert_eq!(n.rate(id).to_bits(), r.to_bits(), "flow {id:?}");
        }
    }

    #[test]
    fn flow_slots_are_reused_with_fresh_generations() {
        let mut n = net(&[10.0]);
        let (a, up_a) = n.add(0.0, vec![LinkId(0)], 10.0);
        let gen_a = up_a.etas[0].1;
        n.remove(1.0, a);
        let (b, up_b) = n.add(2.0, vec![LinkId(0)], 10.0);
        assert_eq!(a.0, b.0, "slot should be reused");
        // the old occupant's events must NOT be current for the new flow
        assert!(!n.is_current(b, gen_a));
        let gen_b = up_b.etas[0].1;
        assert!(gen_b > gen_a, "generation must be monotone per slot");
    }

    #[test]
    fn links_of_reports_the_route() {
        let mut n = net(&[10.0, 20.0]);
        let (a, _) = n.add(0.0, vec![LinkId(0), LinkId(1)], 10.0);
        assert_eq!(n.links_of(a), &[LinkId(0), LinkId(1)]);
    }

    #[test]
    #[should_panic]
    fn double_remove_panics() {
        let mut n = net(&[10.0]);
        let (a, _) = n.add(0.0, vec![LinkId(0)], 10.0);
        n.remove(1.0, a);
        n.remove(1.0, a);
    }

    #[test]
    fn retarget_rescales_component_rates() {
        let mut n = net(&[100.0, 80.0]);
        let (a, _) = n.add(0.0, vec![LinkId(0)], 1000.0);
        let (b, up_b) = n.add(0.0, vec![LinkId(1)], 800.0);
        let gen_b = up_b.etas.iter().find(|e| e.0 == b).unwrap().1;
        // halve link 0 at t=2: a has 800 left, now at 50 B/s -> eta 16
        let up = n.retarget(2.0, &[(LinkId(0), 50.0)]);
        assert_eq!(n.rate(a), 50.0);
        let (_, gen_a, eta_a) = *up.etas.iter().find(|e| e.0 == a).unwrap();
        assert!((eta_a - 16.0).abs() < 1e-9, "{eta_a}");
        assert!(n.is_current(a, gen_a));
        // b's component untouched: no eta churn, old event still current
        assert!(up.etas.iter().all(|e| e.0 != b));
        assert!(n.is_current(b, gen_b));
        assert_eq!(n.link_capacity(LinkId(0)), 50.0);
        n.check_capacity().unwrap();
        // rates match a from-scratch fill under the new capacities
        for (id, r) in n.reference_rates() {
            assert_eq!(n.rate(id).to_bits(), r.to_bits());
        }
    }

    #[test]
    fn retarget_to_zero_stalls_then_recovers() {
        let mut n = net(&[100.0]);
        let (a, _) = n.add(0.0, vec![LinkId(0)], 1000.0);
        let up = n.retarget(1.0, &[(LinkId(0), 0.0)]);
        let (_, gen_down, eta) = up.etas[0];
        assert_eq!(n.rate(a), 0.0);
        assert!(eta.is_infinite(), "stalled flow must report eta=inf");
        // 900 bytes remain frozen while the link is down
        assert!((n.bytes_left(a) - 900.0).abs() < 1e-9);
        let up2 = n.retarget(5.0, &[(LinkId(0), 90.0)]);
        let (_, gen_up, eta2) = *up2.etas.iter().find(|e| e.0 == a).unwrap();
        assert!(gen_up > gen_down, "recovery must re-arm with a fresh gen");
        assert!((eta2 - 10.0).abs() < 1e-9, "{eta2}");
        assert!((n.bytes_left(a) - 900.0).abs() < 1e-9, "no progress while down");
        assert!(n.is_current(a, gen_up));
        assert!(!n.is_current(a, gen_down));
    }

    #[test]
    fn retarget_unflowed_link_is_silent() {
        let mut n = net(&[100.0, 50.0]);
        let (a, up_a) = n.add(0.0, vec![LinkId(0)], 1000.0);
        let gen_a = up_a.etas[0].1;
        let up = n.retarget(1.0, &[(LinkId(1), 10.0)]);
        assert!(up.etas.is_empty(), "no flow touches link 1");
        assert!(n.is_current(a, gen_a));
        assert_eq!(n.link_capacity(LinkId(1)), 10.0);
        // a later flow on the retargeted link sees the new capacity
        let (b, _) = n.add(2.0, vec![LinkId(1)], 100.0);
        assert_eq!(n.rate(b), 10.0);
    }

    #[test]
    fn flows_on_reports_incident_flows() {
        let mut n = net(&[10.0, 10.0]);
        let (a, _) = n.add(0.0, vec![LinkId(0), LinkId(1)], 10.0);
        let (b, _) = n.add(0.0, vec![LinkId(1)], 10.0);
        let mut on1 = n.flows_on(LinkId(1));
        on1.sort_by_key(|f| f.0);
        assert_eq!(on1, vec![a, b]);
        assert_eq!(n.flows_on(LinkId(0)), vec![a]);
        n.remove(1.0, a);
        assert_eq!(n.flows_on(LinkId(1)), vec![b]);
    }

    #[test]
    fn many_flows_fair_share_property() {
        crate::util::prop::check("maxmin capacity", 64, |g| {
            let nl = g.usize_in(1, 6);
            let caps: Vec<f64> = (0..nl).map(|_| 10.0 + g.f64() * 90.0).collect();
            let mut n = FlowNet::new(caps);
            let nf = g.usize_in(1, 12);
            for _ in 0..nf {
                let mut links: Vec<LinkId> = (0..nl).filter(|_| g.bool()).map(LinkId).collect();
                if links.is_empty() {
                    links.push(LinkId(g.usize_in(0, nl)));
                }
                n.add(0.0, links, 100.0);
            }
            n.check_capacity().unwrap();
            n.check_incidence().unwrap();
            // every flow got a positive rate
            for i in 0..nf {
                assert!(n.rate(FlowId(i)) > 0.0);
            }
        });
    }
}
