//! Flow network: concurrent transfers sharing link capacity.
//!
//! Every in-flight transfer is a *flow* occupying a set of links (its
//! route). Rates are assigned by **max–min fairness** (progressive
//! water-filling): repeatedly find the most-contended link, give its flows
//! an equal share of its remaining capacity, freeze them, and continue.
//! This is the standard fluid model for switched fabrics and matches how
//! NVSwitch/PCIe/NIC bandwidth degrades under contention closely enough
//! for overlap analysis (the paper's own §3.5 back-of-envelope uses the
//! same linear bandwidth-sharing arithmetic).

use crate::topology::LinkId;

/// Handle to an active flow.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FlowId(pub usize);

#[derive(Debug, Clone)]
struct Flow {
    links: Vec<LinkId>,
    bytes_left: f64,
    rate: f64,
    /// Generation counter: completion events carry the generation they
    /// were scheduled under; rate changes bump it, invalidating stale
    /// events.
    gen: u64,
    alive: bool,
}

/// The set of active flows plus link capacities.
pub struct FlowNet {
    link_bw: Vec<f64>,
    flows: Vec<Flow>,
    free: Vec<usize>,
    /// Time rates were last recomputed; progress accrues between updates.
    last_update: f64,
    n_active: usize,
    // --- reusable scratch for recompute (hot path; avoids per-call allocs)
    scratch_cap: Vec<f64>,
    scratch_link_flows: Vec<Vec<u32>>,
    scratch_frozen: Vec<bool>,
    scratch_active_links: Vec<u32>,
    scratch_unfrozen: Vec<u32>,
}

/// Result of a rate recomputation: each active flow's new completion ETA.
pub struct RateUpdate {
    /// (flow, generation, eta_seconds_from_now)
    pub etas: Vec<(FlowId, u64, f64)>,
}

impl FlowNet {
    pub fn new(link_bw: Vec<f64>) -> Self {
        let nl = link_bw.len();
        FlowNet {
            link_bw,
            flows: Vec::new(),
            free: Vec::new(),
            last_update: 0.0,
            n_active: 0,
            scratch_cap: vec![0.0; nl],
            scratch_link_flows: (0..nl).map(|_| Vec::new()).collect(),
            scratch_frozen: Vec::new(),
            scratch_active_links: Vec::new(),
            scratch_unfrozen: Vec::new(),
        }
    }

    pub fn n_active(&self) -> usize {
        self.n_active
    }

    /// Accrue progress for all flows up to `now` (call before any
    /// add/remove at time `now`).
    fn settle(&mut self, now: f64) {
        let dt = now - self.last_update;
        debug_assert!(dt >= -1e-12, "time went backwards: {dt}");
        if dt > 0.0 {
            for f in self.flows.iter_mut().filter(|f| f.alive) {
                f.bytes_left = (f.bytes_left - f.rate * dt).max(0.0);
            }
        }
        self.last_update = now;
    }

    /// Add a flow at `now`; returns its id and the rate update for ALL
    /// active flows (the caller reschedules completion events).
    pub fn add(&mut self, now: f64, links: Vec<LinkId>, bytes: f64) -> (FlowId, RateUpdate) {
        self.settle(now);
        debug_assert!(bytes > 0.0, "zero-byte flow");
        let flow = Flow {
            links,
            bytes_left: bytes,
            rate: 0.0,
            gen: 0,
            alive: true,
        };
        let id = if let Some(i) = self.free.pop() {
            // preserve the slot's generation across reuse: completion
            // events of the previous occupant must stay stale
            let gen = self.flows[i].gen;
            self.flows[i] = Flow { gen, ..flow };
            i
        } else {
            self.flows.push(flow);
            self.flows.len() - 1
        };
        self.n_active += 1;
        let up = self.recompute();
        (FlowId(id), up)
    }

    /// Remove a completed (or cancelled) flow; returns the rate update.
    pub fn remove(&mut self, now: f64, id: FlowId) -> RateUpdate {
        self.settle(now);
        assert!(self.flows[id.0].alive, "double remove of flow {id:?}");
        self.flows[id.0].alive = false;
        self.free.push(id.0);
        self.n_active -= 1;
        self.recompute()
    }

    /// Is `gen` the current generation of `id`? (Stale-event filter.)
    pub fn is_current(&self, id: FlowId, gen: u64) -> bool {
        let f = &self.flows[id.0];
        f.alive && f.gen == gen
    }

    /// Remaining bytes of a flow (diagnostics/tests). Reflects progress
    /// only up to the last add/remove — see [`Self::remaining_at`].
    pub fn bytes_left(&self, id: FlowId) -> f64 {
        self.flows[id.0].bytes_left
    }

    /// Remaining bytes of a flow projected to time `now` (without
    /// mutating state).
    pub fn remaining_at(&self, id: FlowId, now: f64) -> f64 {
        let f = &self.flows[id.0];
        (f.bytes_left - f.rate * (now - self.last_update).max(0.0)).max(0.0)
    }

    pub fn rate(&self, id: FlowId) -> f64 {
        self.flows[id.0].rate
    }

    /// Max–min water-filling over all alive flows.
    ///
    /// Completion events are only re-issued for flows whose rate actually
    /// changed (plus fresh zero-rate flows): an unchanged rate means the
    /// previously scheduled completion time is still exact, so the old
    /// event stays current — this cuts event-queue churn from O(F) to
    /// O(changed) per add/remove, the engine's hottest path.
    fn recompute(&mut self) -> RateUpdate {
        let nl = self.link_bw.len();
        self.scratch_cap.clear();
        self.scratch_cap.extend_from_slice(&self.link_bw);
        for lf in &mut self.scratch_link_flows {
            lf.clear();
        }
        self.scratch_frozen.clear();
        self.scratch_frozen.resize(self.flows.len(), false);
        let mut old_rates: Vec<(u32, f64)> = Vec::with_capacity(self.n_active);
        for (i, f) in self.flows.iter().enumerate() {
            if !f.alive {
                continue;
            }
            old_rates.push((i as u32, f.rate));
            for l in &f.links {
                self.scratch_link_flows[l.0].push(i as u32);
            }
        }
        self.scratch_active_links.clear();
        for l in 0..nl {
            if !self.scratch_link_flows[l].is_empty() {
                self.scratch_active_links.push(l as u32);
            }
        }
        // per-link unfrozen counts start at list lengths
        self.scratch_unfrozen.clear();
        self.scratch_unfrozen
            .extend((0..nl).map(|l| self.scratch_link_flows[l].len() as u32));
        let mut unfrozen = std::mem::take(&mut self.scratch_unfrozen);
        let mut remaining = self.n_active;
        while remaining > 0 {
            // bottleneck link = min fair share among active links
            let mut best_share = f64::INFINITY;
            let mut best_link = usize::MAX;
            let mut w = 0;
            for k in 0..self.scratch_active_links.len() {
                let l = self.scratch_active_links[k] as usize;
                if unfrozen[l] == 0 {
                    continue; // drop from the active list (compaction)
                }
                self.scratch_active_links[w] = l as u32;
                w += 1;
                let share = self.scratch_cap[l] / unfrozen[l] as f64;
                if share < best_share {
                    best_share = share;
                    best_link = l;
                }
            }
            self.scratch_active_links.truncate(w);
            if best_link == usize::MAX {
                // flows with no links (shouldn't happen) get infinite rate
                for &(i, _) in &old_rates {
                    if !self.scratch_frozen[i as usize] {
                        self.flows[i as usize].rate = f64::INFINITY;
                        self.scratch_frozen[i as usize] = true;
                    }
                }
                break;
            }
            // freeze the bottleneck link's unfrozen flows at best_share
            let list = std::mem::take(&mut self.scratch_link_flows[best_link]);
            for &fi in &list {
                let i = fi as usize;
                if self.scratch_frozen[i] {
                    continue;
                }
                self.flows[i].rate = best_share;
                self.scratch_frozen[i] = true;
                remaining -= 1;
                for l in &self.flows[i].links {
                    self.scratch_cap[l.0] = (self.scratch_cap[l.0] - best_share).max(0.0);
                    unfrozen[l.0] -= 1;
                }
            }
            self.scratch_link_flows[best_link] = list;
        }
        self.scratch_unfrozen = unfrozen;
        // bump generations + produce ETAs only where the rate changed
        let mut etas = Vec::new();
        for &(i, old) in &old_rates {
            let f = &mut self.flows[i as usize];
            if f.rate == old && old > 0.0 {
                continue; // previous completion event is still exact
            }
            f.gen += 1;
            let eta = if f.bytes_left <= 0.0 {
                0.0
            } else if f.rate > 0.0 {
                f.bytes_left / f.rate
            } else {
                f64::INFINITY
            };
            etas.push((FlowId(i as usize), f.gen, eta));
        }
        RateUpdate { etas }
    }

    /// Invariant check: total rate through every link <= its capacity
    /// (within fp tolerance). Used by tests and debug assertions.
    pub fn check_capacity(&self) -> Result<(), String> {
        let mut used = vec![0.0f64; self.link_bw.len()];
        for f in self.flows.iter().filter(|f| f.alive) {
            for l in &f.links {
                used[l.0] += f.rate;
            }
        }
        for (l, (&u, &c)) in used.iter().zip(self.link_bw.iter()).enumerate() {
            if u > c * (1.0 + 1e-9) + 1e-9 {
                return Err(format!("link {l} oversubscribed: {u} > {c}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(caps: &[f64]) -> FlowNet {
        FlowNet::new(caps.to_vec())
    }

    #[test]
    fn single_flow_gets_full_capacity() {
        let mut n = net(&[100.0]);
        let (id, up) = n.add(0.0, vec![LinkId(0)], 1000.0);
        assert_eq!(n.rate(id), 100.0);
        assert_eq!(up.etas.len(), 1);
        assert!((up.etas[0].2 - 10.0).abs() < 1e-9);
    }

    #[test]
    fn two_flows_share_equally() {
        let mut n = net(&[100.0]);
        let (a, _) = n.add(0.0, vec![LinkId(0)], 1000.0);
        let (b, up) = n.add(0.0, vec![LinkId(0)], 1000.0);
        assert_eq!(n.rate(a), 50.0);
        assert_eq!(n.rate(b), 50.0);
        assert_eq!(up.etas.len(), 2);
        n.check_capacity().unwrap();
    }

    #[test]
    fn max_min_gives_leftover_to_unbottlenecked() {
        // flow A uses links 0+1; flow B uses link 0 only.
        // link0 cap 100 shared -> 50 each; link1 cap 30 limits A to 30;
        // B then gets the leftover 70 on link 0.
        let mut n = net(&[100.0, 30.0]);
        let (a, _) = n.add(0.0, vec![LinkId(0), LinkId(1)], 1e9);
        let (b, _) = n.add(0.0, vec![LinkId(0)], 1e9);
        assert!((n.rate(a) - 30.0).abs() < 1e-9, "{}", n.rate(a));
        assert!((n.rate(b) - 70.0).abs() < 1e-9, "{}", n.rate(b));
        n.check_capacity().unwrap();
    }

    #[test]
    fn progress_accrues_between_updates() {
        let mut n = net(&[100.0]);
        let (a, _) = n.add(0.0, vec![LinkId(0)], 1000.0);
        // at t=5 add another flow: A should have 500 bytes left
        let (_b, up) = n.add(5.0, vec![LinkId(0)], 1000.0);
        assert!((n.bytes_left(a) - 500.0).abs() < 1e-9);
        // both now at 50 B/s: A finishes in 10s, B in 20s
        let eta_a = up.etas.iter().find(|e| e.0 == a).unwrap().2;
        assert!((eta_a - 10.0).abs() < 1e-9);
    }

    #[test]
    fn remove_restores_capacity() {
        let mut n = net(&[100.0]);
        let (a, _) = n.add(0.0, vec![LinkId(0)], 1000.0);
        let (b, _) = n.add(0.0, vec![LinkId(0)], 1000.0);
        let up = n.remove(10.0, a); // each did 500 bytes by t=10
        assert_eq!(n.n_active(), 1);
        let eta_b = up.etas.iter().find(|e| e.0 == b).unwrap().2;
        // b has 500 left at 100 B/s
        assert!((eta_b - 5.0).abs() < 1e-9, "{eta_b}");
    }

    #[test]
    fn generation_invalidates_stale_events() {
        let mut n = net(&[100.0]);
        let (a, up1) = n.add(0.0, vec![LinkId(0)], 1000.0);
        let gen1 = up1.etas[0].1;
        assert!(n.is_current(a, gen1));
        let (_b, up2) = n.add(1.0, vec![LinkId(0)], 1000.0);
        let gen2 = up2.etas.iter().find(|e| e.0 == a).unwrap().1;
        assert!(!n.is_current(a, gen1));
        assert!(n.is_current(a, gen2));
    }

    #[test]
    fn flow_slots_are_reused_with_fresh_generations() {
        let mut n = net(&[10.0]);
        let (a, up_a) = n.add(0.0, vec![LinkId(0)], 10.0);
        let gen_a = up_a.etas[0].1;
        n.remove(1.0, a);
        let (b, up_b) = n.add(2.0, vec![LinkId(0)], 10.0);
        assert_eq!(a.0, b.0, "slot should be reused");
        // the old occupant's events must NOT be current for the new flow
        assert!(!n.is_current(b, gen_a));
        let gen_b = up_b.etas[0].1;
        assert!(gen_b > gen_a, "generation must be monotone per slot");
    }

    #[test]
    #[should_panic]
    fn double_remove_panics() {
        let mut n = net(&[10.0]);
        let (a, _) = n.add(0.0, vec![LinkId(0)], 10.0);
        n.remove(1.0, a);
        n.remove(1.0, a);
    }

    #[test]
    fn many_flows_fair_share_property() {
        crate::util::prop::check("maxmin capacity", 64, |g| {
            let nl = g.usize_in(1, 6);
            let caps: Vec<f64> = (0..nl).map(|_| 10.0 + g.f64() * 90.0).collect();
            let mut n = FlowNet::new(caps);
            let nf = g.usize_in(1, 12);
            for _ in 0..nf {
                let mut links: Vec<LinkId> = (0..nl)
                    .filter(|_| g.bool())
                    .map(LinkId)
                    .collect();
                if links.is_empty() {
                    links.push(LinkId(g.usize_in(0, nl)));
                }
                n.add(0.0, links, 100.0);
            }
            n.check_capacity().unwrap();
            // every flow got a positive rate
            for i in 0..nf {
                assert!(n.rate(FlowId(i)) > 0.0);
            }
        });
    }
}
