//! Component-sharded parallel event loop with conservative lookahead.
//!
//! The flow solver's max–min components never span the intra-node /
//! fabric boundary ([`Topology::is_fabric_link`]): an intra-node route
//! uses only one node's NVLink/mesh/PCIe/HBM links and an inter-node
//! route uses only NIC/leaf/spine links. That exact decomposition (the
//! same one `tests/flow_equivalence.rs` pins for the incremental
//! solver) lets the engine split into:
//!
//! * **one shard per node partition** — a full
//!   [`Runner`](super::engine) that starts only its own tasks and
//!   solves only its own intra-node links, advancing *in parallel* with
//!   the other shards; and
//! * **one fabric runner** — a sequential coordinator-side `Runner`
//!   owning every inter-node flow plus the entire fault machinery
//!   (every `FaultTarget` resolves to fabric links).
//!
//! Synchronization is a conservative lookahead barrier. Every
//! shard→anywhere interaction is latency-bounded below by
//! Δ = [`Topology::min_cross_partition_latency`]: an inter-node
//! transfer posted at `t` cannot arm a fabric flow before `t + Δ`
//! (`route_tc` charges at least `inter_lat`), and a world barrier
//! completed at `t` releases at `t + 2·inter_lat`. So while the
//! earliest pending *fabric* event is at `t_fab`, every shard may
//! safely run all events with `t < min(t_fab, t_min + Δ)` without
//! seeing anyone else — that window is executed on a thread pool.
//! Fabric→shard effects, by contrast, are *instantaneous* (a flow's
//! completion applies its signal at completion time), so fabric events
//! are processed one at a time, interleaved in exact `(t, tie)` order
//! with the shard windows, with their task-side effects dispatched
//! synchronously into the owning shard.
//!
//! Determinism: shard-to-fabric messages are merged sorted by
//! `(t, shard index, FIFO)`, flow batches are ordered by the canonical
//! `(task, launch)` key in *both* engines, and partitioning is a pure
//! function of (topology, program) — so the report is bit-identical
//! for every thread count, including `--threads 1` (which *is* the
//! sequential engine).
//!
//! Couplings faster than Δ — a cross-node `SetSignal`, a cross-node
//! `LLWait`, a foreign node-scoped barrier, an intra-node put that
//! signals a third node — would break the bound, so the partition
//! pre-scan unions the involved nodes into one shard: the coupling
//! becomes shard-local and exact. Runs that are not eligible at all
//! (numerics, tracing, adaptive routing's global occupancy feedback,
//! chunk scheduling's global ready queue, latency jitter's global draw
//! order, single-node clusters, programs that collapse to one
//! partition) fall back to the sequential engine.

use std::collections::BTreeMap;

use crate::config::{ChunkSched, RailPolicy};
use crate::mem::SymmetricHeap;
use crate::program::{Op, Program, Scope};
use crate::sim::engine::{
    BarrierState, NoopExecutor, OutMsg, Runner, Sim, SimError, SimReport,
};
use crate::topology::PartitionMap;

/// Decide whether `sim` can run sharded, and if so return the partition
/// map (node partitions coarsened by the program's cross-node
/// couplings). `None` means: run the sequential engine.
pub(crate) fn plan(sim: &Sim, prog: &Program) -> Option<PartitionMap> {
    // Permanent deaths make the run ineligible for sharding: a death
    // retires *intra-node* links (FaultTarget::Rank/Node reach past the
    // fabric boundary the fault machinery otherwise respects), and the
    // recovery that follows — abort with DeadPeer, re-plan over the
    // survivor world — happens above the engine, where the conservative
    // lookahead cannot model it. `--threads N` with a death plan falls
    // back to the sequential engine; reports stay bit-identical either
    // way, as always.
    if sim.threads() <= 1
        || sim.cfg.numerics
        || sim.cfg.trace
        || sim.faults().jitter.is_some()
        || sim.faults().has_deaths()
        || sim.topo.cluster.fabric.rail_policy != RailPolicy::Static
        || sim.topo.cluster.fabric.chunk_sched != ChunkSched::Fifo
        || sim.topo.cluster.nodes < 2
    {
        return None;
    }
    // the lookahead window only makes progress with a strictly positive
    // latency floor (NaN-explicit comparison: any degenerate hw model
    // falls back to the sequential engine)
    let delta = sim.topo.min_cross_partition_latency();
    if !delta.is_finite() || delta <= 0.0 {
        return None;
    }
    let c = &sim.topo.cluster;
    let ws = c.world_size();
    let mut pm = sim.topo.node_partition_map();
    for t in &prog.tasks {
        if t.rank >= ws {
            return None; // malformed program: let the solo engine report
        }
        for op in &t.ops {
            match op {
                Op::SetSignal { sig, .. } => pm.union_ranks(t.rank, sig.rank),
                Op::LLWait { dst } => pm.union_ranks(t.rank, dst.rank),
                Op::Barrier {
                    scope: Scope::Node(n),
                    ..
                } => {
                    let first = n * c.gpus_per_node;
                    if first < ws {
                        pm.union_ranks(t.rank, first);
                    }
                }
                Op::Put {
                    src, dst, signal, ..
                } => {
                    if c.node_of(src.rank) == c.node_of(dst.rank) {
                        // intra-node flow: its effects apply in the
                        // posting shard — pull everything it touches in
                        pm.union_ranks(t.rank, src.rank);
                        if let Some((sig, _, _)) = signal {
                            pm.union_ranks(t.rank, sig.rank);
                        }
                    }
                }
                Op::Get { src, dst, .. } | Op::LLPut { src, dst, .. } => {
                    if c.node_of(src.rank) == c.node_of(dst.rank) {
                        pm.union_ranks(t.rank, src.rank);
                    }
                }
                Op::MultimemSt { src, .. } => pm.union_ranks(t.rank, src.rank),
                _ => {}
            }
        }
    }
    pm.compact();
    if pm.n_parts() < 2 {
        return None;
    }
    Some(pm)
}

/// World-barrier aggregation state, coordinator-side.
struct WorldBarrier {
    arrived: Vec<usize>,
    needed: usize,
    released: bool,
}

/// Run `prog` on the sharded engine. Only called with a `plan()`-vetted
/// configuration; the result is bit-identical to the sequential engine.
pub(crate) fn run_sharded(
    sim: &Sim,
    prog: &Program,
    heap: &mut SymmetricHeap,
    pm: PartitionMap,
) -> Result<SimReport, SimError> {
    let topo = sim.topo;
    let k = pm.n_parts();
    let world = heap.world();
    let pad = heap.signal_pad();
    let delta = topo.min_cross_partition_latency();
    let workers = sim.threads().min(k).max(1);
    let part_of_task = |task: usize| pm.part_of(prog.tasks[task].rank);

    // Scratch heaps: one per shard plus one (untouched) for the fabric.
    // Timing-mode runners only ever read/write signal cells, and the
    // partition map guarantees each rank's cells are touched through
    // exactly one shard — seeded from, and merged back into, the real
    // heap around the run.
    let mut heaps: Vec<SymmetricHeap> = (0..k + 1)
        .map(|_| SymmetricHeap::new(world, pad))
        .collect();
    for h in heaps.iter_mut() {
        for r in 0..world {
            for i in 0..pad {
                let v = heap.signal(r, i);
                if v != 0 {
                    h.signal_set(r, i, v);
                }
            }
        }
    }
    let mut execs: Vec<NoopExecutor> = (0..k + 1).map(|_| NoopExecutor).collect();

    let report = {
        let (fab_heap, shard_heaps) = heaps.split_last_mut().expect("k+1 heaps");
        let (fab_exec, shard_execs) = execs.split_last_mut().expect("k+1 execs");
        let mut shards: Vec<Runner<NoopExecutor>> = shard_heaps
            .iter_mut()
            .zip(shard_execs.iter_mut())
            .enumerate()
            .map(|(p, (h, e))| {
                let mask: Vec<bool> = (0..world).map(|r| pm.part_of(r) == p).collect();
                Runner::shard(sim, prog, h, e, mask)
            })
            .collect();
        let mut fabric = Runner::fabric(sim, prog, fab_heap, fab_exec);
        let mut barriers: BTreeMap<(u64, usize), WorldBarrier> = BTreeMap::new();

        for sh in shards.iter_mut() {
            sh.init()?;
        }
        fabric.init()?;
        merge_outboxes(&mut shards, &mut fabric, &mut barriers, sim)?;

        loop {
            let t_shard = shards
                .iter()
                .map(|s| s.next_time())
                .fold(f64::INFINITY, f64::min);
            let t_fab = fabric.next_time();
            if !t_shard.is_finite() && !t_fab.is_finite() {
                break;
            }
            if t_fab <= t_shard {
                // Fabric turn: one event, sequential (its effects are
                // instantaneous on shard state, so it must interleave in
                // exact time order). Ties go to the fabric — a fabric
                // completion at `t` is visible to shard events at `t`,
                // matching the canonical batch order's task-key tie rule.
                fabric.step_one()?;
                dispatch_effects(&mut fabric, &mut shards, &pm, &part_of_task)?;
                merge_outboxes(&mut shards, &mut fabric, &mut barriers, sim)?;
                continue;
            }
            // Parallel shard window: nothing — not the fabric (earliest
            // event at t_fab ≥ horizon), not another shard (reachable
            // only through the fabric, ≥ t_shard + Δ ≥ horizon) — can
            // affect any shard below the horizon.
            let horizon = t_fab.min(t_shard + delta);
            let per = shards.len().div_ceil(workers);
            std::thread::scope(|scope| -> Result<(), SimError> {
                let mut handles = Vec::with_capacity(workers);
                for chunk in shards.chunks_mut(per) {
                    handles.push(scope.spawn(move || -> Result<(), SimError> {
                        for sh in chunk.iter_mut() {
                            sh.run_window(horizon)?;
                        }
                        Ok(())
                    }));
                }
                for h in handles {
                    h.join().expect("shard worker panicked")?;
                }
                Ok(())
            })?;
            merge_outboxes(&mut shards, &mut fabric, &mut barriers, sim)?;
        }

        // completion / deadlock check over every shard's owned tasks
        let stuck: Vec<String> = shards.iter().flat_map(|s| s.stuck_tasks()).collect();
        if !stuck.is_empty() {
            return Err(SimError::Deadlock(stuck.join("; ")));
        }

        // assemble the report exactly as the solo engine does, pulling
        // each task's span from its owning shard
        let mut makespan = 0.0f64;
        let mut task_spans = Vec::with_capacity(prog.tasks.len());
        for (i, spec) in prog.tasks.iter().enumerate() {
            let rt = &shards[pm.part_of(spec.rank)].tasks[i];
            makespan = makespan.max(rt.t_end);
            task_spans.push((spec.name.clone(), spec.rank, rt.t_start, rt.t_end));
        }
        SimReport {
            makespan,
            task_spans,
            events: fabric.n_events + shards.iter().map(|s| s.n_events).sum::<u64>(),
            flows: fabric.n_flows + shards.iter().map(|s| s.n_flows).sum::<u64>(),
            ledger: fabric.report.ledger,
            ..SimReport::default()
        }
    };

    // fold each rank's final signal state back into the caller's heap
    for r in 0..world {
        let sh = &heaps[pm.part_of(r)];
        for i in 0..pad {
            heap.signal_set(r, i, sh.signal(r, i));
        }
    }
    Ok(report)
}

/// Dispatch the fabric's completion effects into the owning shards, in
/// outbox (= canonical completion) order. This is `finish_flow` /
/// `on_barrier_release` split across the partition boundary: the same
/// helper calls, in the same order, on the shard that owns the state.
fn dispatch_effects(
    fabric: &mut Runner<NoopExecutor>,
    shards: &mut [Runner<NoopExecutor>],
    pm: &PartitionMap,
    part_of_task: &dyn Fn(usize) -> usize,
) -> Result<(), SimError> {
    for msg in fabric.take_outbox() {
        match msg {
            OutMsg::Effects { t, ctx } => {
                let (signal, ll_dsts, nbi_owner, resume) = ctx.into_effects();
                if let Some((sig, op, val)) = signal {
                    let s = &mut shards[pm.part_of(sig.rank)];
                    s.sync_clock(t);
                    s.apply_signal(sig, op, val)?;
                }
                for key in ll_dsts {
                    let s = &mut shards[pm.part_of(key.0)];
                    s.sync_clock(t);
                    s.deliver_ll(key)?;
                }
                if let Some(owner) = nbi_owner {
                    let s = &mut shards[part_of_task(owner)];
                    s.sync_clock(t);
                    s.deliver_nbi(owner)?;
                }
                if let Some(task) = resume {
                    let s = &mut shards[part_of_task(task)];
                    s.sync_clock(t);
                    s.deliver_resume(task)?;
                }
            }
            OutMsg::BarrierWake { t, task } => {
                let s = &mut shards[part_of_task(task)];
                s.sync_clock(t);
                s.deliver_barrier_wake(task)?;
            }
            OutMsg::InterFlow { .. } | OutMsg::BarrierArrive { .. } => {
                unreachable!("fabric runner never posts shard traffic")
            }
        }
    }
    Ok(())
}

/// The lookahead barrier's merge: drain every shard's outbox (sorted by
/// `(t, shard, FIFO)` — each outbox is already time-ordered, so a stable
/// sort by `t` over the shard-ordered concatenation is exactly that) and
/// apply it to the fabric: launch inter-node flows, aggregate world
/// barriers, schedule releases.
fn merge_outboxes(
    shards: &mut [Runner<NoopExecutor>],
    fabric: &mut Runner<NoopExecutor>,
    barriers: &mut BTreeMap<(u64, usize), WorldBarrier>,
    sim: &Sim,
) -> Result<(), SimError> {
    let mut msgs: Vec<OutMsg> = Vec::new();
    for sh in shards.iter_mut() {
        msgs.append(&mut sh.take_outbox());
    }
    if msgs.is_empty() {
        return Ok(());
    }
    msgs.sort_by(|a, b| msg_t(a).total_cmp(&msg_t(b)));
    for msg in msgs {
        match msg {
            OutMsg::InterFlow {
                t,
                route,
                bytes,
                ctx,
            } => {
                fabric.sync_clock(t);
                fabric.launch_flow(route, bytes, ctx);
            }
            OutMsg::BarrierArrive {
                t,
                key,
                task,
                expect,
            } => {
                let st = barriers.entry(key).or_insert(WorldBarrier {
                    arrived: Vec::new(),
                    needed: expect,
                    released: false,
                });
                // mirror the solo engine's program-bug checks verbatim
                assert_eq!(
                    st.needed, expect,
                    "barrier id {} used with inconsistent expect counts",
                    key.1
                );
                if st.released {
                    panic!("barrier id {} reused after release", key.1);
                }
                st.arrived.push(task);
                if st.arrived.len() == st.needed {
                    st.released = true;
                    let hw = sim.topo.cluster.hw;
                    let release_t = t + 2.0 * hw.inter_lat;
                    fabric.sync_clock(t);
                    fabric.barriers.insert(
                        key,
                        BarrierState {
                            arrived: std::mem::take(&mut st.arrived),
                            needed: st.needed,
                            released: false,
                        },
                    );
                    fabric.push_barrier_release(release_t, key);
                }
            }
            OutMsg::Effects { .. } | OutMsg::BarrierWake { .. } => {
                unreachable!("shards apply their own completion effects")
            }
        }
    }
    Ok(())
}

fn msg_t(m: &OutMsg) -> f64 {
    match m {
        OutMsg::InterFlow { t, .. }
        | OutMsg::BarrierArrive { t, .. }
        | OutMsg::Effects { t, .. }
        | OutMsg::BarrierWake { t, .. } => *t,
    }
}
