//! The discrete-event engine: executes a [`Program`] (async-tasks made of
//! ops) over a [`Topology`], producing virtual-time spans and — when
//! numerics are on — really moving the bytes through the symmetric heap
//! and really running the compute through a [`ComputeExecutor`].
//!
//! Determinism: events are ordered by (time, sequence-number); identical
//! programs produce identical timelines and identical numerics. Event
//! times must never be NaN — [`f64::total_cmp`] keeps the heap ordering
//! total and a debug assertion rejects NaN at push time.
//!
//! Hot-path scheduling: consecutive flow events carrying the same
//! virtual timestamp (collectives issue many puts at identical times)
//! are coalesced into a single batched [`FlowNet::update`], so N
//! simultaneous arms/completions cost one component-scoped rate
//! recompute instead of N global ones. Flow contexts and signal waiters
//! are slab/`Vec`-indexed — no hashing on the event path.
//!
//! Congestion feedback: transfers are routed through a
//! [`Router`] that, under `RailPolicy::Adaptive`, resolves
//! `TrafficClass::Auto` to the emptiest NIC plane using the live
//! [`LinkOccupancy`] this engine maintains — committed wire bytes and
//! in-flight flow counts per link, bumped when a transfer is posted
//! (its `FlowArm` is scheduled) and released on `FlowDone`. The
//! occupancy view is pure bookkeeping: the max–min solver is never
//! re-entered, the counters are not even maintained under
//! `RailPolicy::Static` (the default), and static routing is
//! bit-identical to calling [`Topology::route_tc`] directly.
//!
//! Chunk scheduling: under `ChunkSched::Srpf`/`Deadline`, inter-node
//! puts tagged with [`ChunkMeta`] (split dispatch pieces, chunked
//! AG/RS segments) divert into a policy-ordered ready queue instead of
//! posting eagerly. [`Runner::pump`] issues queue heads against the
//! live occupancy view — at most [`CHUNK_DEPTH`] flows per link, so a
//! short latency-critical stream is never fair-shared behind bulk
//! traffic it could overtake — re-resolving each chunk's route at
//! *issue* time (late binding: an adaptive rail pick sees the fabric
//! as it is when the chunk actually goes out, not when the program
//! reached the op). `ChunkSched::Fifo` (the default) never diverts, so
//! it is bit-identical to the pre-scheduler engine by construction.

//!
//! Fault injection: a [`FaultPlan`] (see `config::fault`) schedules
//! first-class `FaultToggle` events that retarget `FlowNet` link
//! capacities (incremental component re-solve), kill-and-retry the puts
//! riding a downed link, steer the adaptive router around dead planes
//! via a live [`FabricHealth`] view, inflate straggler compute, jitter
//! flow latencies, and watchdog LL/signal waits. Every fault branch is
//! gated on the plan being non-empty, so an empty plan is bit-identical
//! to the fault-free engine.

//!
//! Parallelism: `Sim::with_threads(n)` with `n > 1` dispatches eligible
//! timing runs to the component-sharded engine in [`super::par`] — one
//! event queue per node partition advancing concurrently under a
//! conservative lookahead barrier, with the shared inter-node fabric
//! solved by a sequential coordinator. The sharded engine reuses this
//! module's `Runner` verbatim per shard (role-gated at the three points
//! where work crosses a partition), so `--threads 1` *is* this engine
//! and `--threads N` is bit-identical to it by construction. See
//! `docs/ARCHITECTURE.md` §Parallel engine.

use std::cmp::Ordering;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};

use crate::config::{
    ChunkSched, DeathScope, FaultPlan, FaultTarget, HardwareModel, RailPolicy, TrafficClass,
};
use crate::mem::{Slice, SymmetricHeap};
use crate::program::{ChunkMeta, ComputeCost, NumericOp, Op, Program, Scope, SigCond, SigOp, SigRef};
use crate::sim::flow::{FlowId, FlowNet};
use crate::topology::{FabricHealth, LinkId, LinkOccupancy, Route, Router, Topology};
use crate::util::Rng;

/// Pluggable compute backend (XLA/PJRT in `runtime`, native fallback in
/// `kernels::exec`, or nothing for timing-only benches).
pub trait ComputeExecutor {
    fn call(
        &mut self,
        heap: &mut SymmetricHeap,
        entry: &str,
        args: &[Slice],
        outs: &[Slice],
    ) -> anyhow::Result<()>;
}

/// Timing-only executor: numeric calls are no-ops.
pub struct NoopExecutor;

impl ComputeExecutor for NoopExecutor {
    fn call(
        &mut self,
        _heap: &mut SymmetricHeap,
        _entry: &str,
        _args: &[Slice],
        _outs: &[Slice],
    ) -> anyhow::Result<()> {
        Ok(())
    }
}

/// Engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// Apply real data movement + compute (false = pure timing model).
    pub numerics: bool,
    /// Record per-op spans for timelines/chrome traces.
    pub trace: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            numerics: true,
            trace: false,
        }
    }
}

/// One recorded op execution (for traces).
#[derive(Debug, Clone)]
pub struct OpSpan {
    pub task: usize,
    pub rank: usize,
    pub task_name: String,
    pub label: String,
    pub t0: f64,
    pub t1: f64,
}

/// What the fault/recovery machinery did during one run (the fault
/// ledger `metrics::engine_bench_json` emits into `BENCH_engine.json`).
/// All-zero on fault-free runs.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FaultLedger {
    /// Fault begin/end toggles that actually changed a link capacity.
    pub faults_applied: u64,
    /// In-flight flows killed by a link-down fault (diverted to retry).
    pub flows_killed: u64,
    /// Retry attempts fired (including backoff re-schedules).
    pub retries: u64,
    /// Wire bytes relaunched on a different path than originally routed.
    pub rerouted_bytes: f64,
    /// Retries that exhausted their budget and fell back to stalling on
    /// the dead path until recovery.
    pub retries_exhausted: u64,
}

/// What the elastic recovery controller (`coordinator::recover`) did to
/// survive a permanent rank/node death: the detect → drain → re-plan →
/// resume timeline plus exact token accounting. The engine itself never
/// fills this — it aborts with [`SimError::DeadPeer`] and the
/// controller stitches the ledger into the final [`SimReport`] — so
/// fault-free and non-death runs carry `None` and stay bit-identical.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RecoveryLedger {
    /// Ranks that permanently died, ascending.
    pub dead_ranks: Vec<usize>,
    /// Virtual time of the (first) death.
    pub died_at: f64,
    /// When the engine detected it (`detected_at - died_at` is the
    /// detection latency).
    pub detected_at: f64,
    /// How it was detected: `flow-kill`, `launch-to-dead`,
    /// `retry-to-dead`, `watchdog`, or `queue-drain`.
    pub via: String,
    /// When the structured drain of in-flight state finished.
    pub drained_at: f64,
    /// When the survivor-world re-plan was ready.
    pub replanned_at: f64,
    /// When the survivor program resumed executing.
    pub resumed_at: f64,
    /// In-flight flows killed because they touched a dead rank.
    pub flows_drained: u64,
    /// Program steps (tasks) already complete at detection and carried
    /// over instead of re-executed.
    pub steps_checkpointed: u64,
    /// (token, expert-slot) pairs delivered by the survivor plan.
    pub tokens_delivered: u64,
    /// Delivered pairs whose expert moved to a different physical rank
    /// in the re-shard (subset of `tokens_delivered`).
    pub tokens_rerouted: u64,
    /// Pairs lost with the dead ranks (their resident tokens) plus
    /// survivor-side capacity drops. Conservation invariant:
    /// `tokens_delivered + tokens_dropped` = every pair the original
    /// plan owed.
    pub tokens_dropped: u64,
    /// Recovery rounds executed (1 = single death epoch).
    pub epochs: u32,
}

/// Aggregate result of a simulation run.
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    /// Virtual makespan: completion time of the last task, seconds.
    pub makespan: f64,
    /// Per-task (start, end).
    pub task_spans: Vec<(String, usize, f64, f64)>,
    /// Per-op spans (only when `trace`).
    pub op_spans: Vec<OpSpan>,
    /// Events processed (engine-perf metric).
    pub events: u64,
    /// Flows created (diagnostics).
    pub flows: u64,
    /// Fault/recovery activity (all-zero when no faults were injected).
    pub ledger: FaultLedger,
    /// Elastic-recovery timeline + token accounting; `Some` only on
    /// reports stitched by `coordinator::recover` after a permanent
    /// death (`None` preserves empty-plan bit-identity).
    pub recovery: Option<RecoveryLedger>,
    /// Host wall-clock spent inside the engine, nanoseconds. Measured,
    /// not simulated — the one field that is *not* bit-reproducible
    /// across runs (equivalence suites must ignore it).
    pub wall_ns: u64,
}

impl SimReport {
    /// Events processed per host wall-clock second (the `BENCH_engine`
    /// throughput unit). 0.0 when the run was too fast to time.
    pub fn events_per_s(&self) -> f64 {
        if self.wall_ns == 0 {
            0.0
        } else {
            self.events as f64 / (self.wall_ns as f64 * 1e-9)
        }
    }
}

/// Simulation failure.
#[derive(Debug, thiserror::Error)]
pub enum SimError {
    #[error("deadlock: {0}")]
    Deadlock(String),
    #[error("task '{task}' on rank {rank} requests {req} SMs > device {cap}")]
    SmOversubscribed {
        task: String,
        rank: usize,
        req: u32,
        cap: u32,
    },
    #[error("numeric executor failed in '{entry}': {source}")]
    Executor {
        entry: String,
        #[source]
        source: anyhow::Error,
    },
    #[error(
        "watchdog: task '{task}' (rank {rank}) stuck in {waiting} \
         longer than {timeout}s at t={at}"
    )]
    WatchdogTimeout {
        task: String,
        rank: usize,
        waiting: String,
        timeout: f64,
        at: f64,
    },
    #[error(
        "dead peer: rank(s) {:?} died at t={:.6e}s, detected at \
         t={:.6e}s via {} ({} in-flight flows drained, {} steps \
         checkpointed)",
        .0.dead, .0.died_at, .0.detected_at, .0.via,
        .0.flows_drained, .0.checkpoint.len()
    )]
    DeadPeer(Box<DeadPeerInfo>),
}

/// Structured abort a permanent rank/node death produces instead of a
/// hang or a bare [`SimError::Deadlock`]: who died, when, how the
/// engine noticed, what in-flight state was drained, and a checkpoint
/// of every task that had already completed — everything the elastic
/// recovery controller (`coordinator::recover`) needs to re-plan over
/// the survivor world and resume.
#[derive(Debug, Clone)]
pub struct DeadPeerInfo {
    /// Permanently dead ranks, in death order.
    pub dead: Vec<usize>,
    /// Virtual time of the (first) death.
    pub died_at: f64,
    /// Virtual time of detection (= abort time).
    pub detected_at: f64,
    /// Detection path: `flow-kill` (an in-flight transfer touched the
    /// dying rank), `launch-to-dead` (a task posted a transfer to/from a
    /// dead endpoint), `retry-to-dead` (the retry ladder re-routed onto
    /// a dead endpoint), `watchdog` (a liveness watchdog fired with
    /// deaths active), or `queue-drain` (the event queue drained with
    /// stuck tasks — the backstop that guarantees a death can never end
    /// in a bare `Deadlock`).
    pub via: String,
    /// In-flight flows killed because a dead rank terminated them.
    pub flows_drained: u64,
    /// Tasks already `Done` at detection: `(name, rank, t_start,
    /// t_end)`, exactly the `SimReport::task_spans` rows the controller
    /// carries over instead of re-executing.
    pub checkpoint: Vec<(String, usize, f64, f64)>,
}

// ---------------------------------------------------------------------------
// events
// ---------------------------------------------------------------------------

#[derive(Debug)]
enum Ev {
    Start { task: usize },
    FlowArm { pending: usize },
    FlowDone { flow: FlowId, gen: u64 },
    OpDone { task: usize, gen: u64 },
    BarrierRelease { key: (u64, usize) },
    /// A scheduled link fault begins (`begin`) or clears.
    FaultToggle { fault: usize, begin: bool },
    /// Watchdog check on a task blocked in an LL/signal wait; stale when
    /// `gen` no longer matches the task's block generation.
    Watchdog { task: usize, gen: u64 },
    /// Backoff expired for a killed put; re-route and relaunch.
    Retry { entry: usize },
    /// A permanent rank/node death (`FaultPlan::deaths[death]`) fires.
    Death { death: usize },
}

struct QEntry {
    t: f64,
    seq: u64,
    ev: Ev,
}

impl PartialEq for QEntry {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for QEntry {}
impl PartialOrd for QEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap: reverse on (t, seq). total_cmp keeps the order total
        // (NaN would silently break (time, seq) determinism with
        // partial_cmp; push() debug-asserts it never gets here).
        other.t.total_cmp(&self.t).then(other.seq.cmp(&self.seq))
    }
}

// ---------------------------------------------------------------------------
// task runtime state
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum TState {
    NotStarted,
    WaitingSms,
    Running,
    BlockedFlow,
    BlockedSignal { idx: usize, cond: SigCond, value: u64 },
    BlockedLL { key: LLKey },
    BlockedBarrier,
    WaitQuiet,
    Computing { gen: u64 },
    Done,
}

pub(crate) type LLKey = (usize, usize, usize); // (rank, buf, off)

pub(crate) struct TaskRt {
    pc: usize,
    state: TState,
    outstanding_nbi: u32,
    pub(crate) t_start: f64,
    pub(crate) t_end: f64,
    op_t0: f64,
    op_gen: u64,
    /// Flow-launch counter: `(task, launches)` is the canonical flow key
    /// that orders same-timestamp flow batches independently of slab-id
    /// recycling (and therefore identically in the solo and sharded
    /// engines).
    launches: u32,
}

/// Everything needed to re-route and relaunch a transfer whose flow was
/// killed by a link-down fault: the endpoints, the traffic class, and
/// how the op shaped its route latency (Get doubles it, a signaled Put
/// adds the flag-packet overhead).
#[derive(Debug, Clone, Copy)]
struct RetryRoute {
    src: usize,
    dst: usize,
    tc: TrafficClass,
    lat_mult: f64,
    lat_add: f64,
}

pub(crate) struct FlowCtx {
    copies: Vec<(Slice, Slice)>,
    pub(crate) signal: Option<(SigRef, SigOp, u64)>,
    pub(crate) ll_dsts: Vec<LLKey>,
    pub(crate) resume: Option<usize>,
    pub(crate) nbi_owner: Option<usize>,
    span: Option<(usize, &'static str, f64)>,
    /// Canonical batch-ordering key: (task index, per-task launch seq).
    /// Survives retries — a relaunched transfer keeps its original key.
    key: (u32, u32),
    /// Wire bytes committed to `LinkOccupancy` at post time (released
    /// verbatim at completion). Set by `launch_flow`.
    wire_bytes: f64,
    /// How to re-route this transfer if its flow dies on a downed link
    /// (`None` = not retryable, e.g. multimem; the flow then stalls
    /// until the fault clears).
    rt: Option<RetryRoute>,
}

impl FlowCtx {
    /// Tear a fabric-completed flow's context into its shard-side
    /// effects: `(signal, ll_dsts, nbi_owner, resume)`. Used by the
    /// sharded coordinator to replay `finish_flow`'s delivery sequence
    /// on the shard that owns each piece of state.
    pub(crate) fn into_effects(
        self,
    ) -> (
        Option<(SigRef, SigOp, u64)>,
        Vec<LLKey>,
        Option<usize>,
        Option<usize>,
    ) {
        (self.signal, self.ll_dsts, self.nbi_owner, self.resume)
    }
}

struct PendingFlow {
    links: Vec<LinkId>,
    bytes: f64,
    ctx: FlowCtx,
}

/// A killed transfer waiting out its retry backoff.
struct RetryEntry {
    rt: RetryRoute,
    /// Remaining wire bytes at kill time.
    bytes: f64,
    ctx: FlowCtx,
    attempt: u32,
    /// The links the dead flow occupied (reroute detection).
    orig_links: Vec<LinkId>,
}

/// How many flows the chunk scheduler keeps in flight per link before it
/// parks further chunks in the ready queue. `1` would serialize a stream
/// and pay the full route latency between consecutive chunks; `2`
/// pipelines the latency (one chunk on the wire while the next arms)
/// without letting bulk streams rebuild the deep fair-shared backlog the
/// scheduler exists to prevent.
const CHUNK_DEPTH: u32 = 2;

/// One diverted chunk parked in the scheduler's ready queue. The flow
/// context (and with it the canonical `(task, launch)` key and the
/// retry route the issue-time re-route reuses) was built at *enqueue*
/// time in program order; only the wire departure is deferred.
struct ReadyChunk {
    /// Wire bytes (LL doubling already applied).
    bytes: f64,
    meta: ChunkMeta,
    ctx: FlowCtx,
}

pub(crate) struct BarrierState {
    pub(crate) arrived: Vec<usize>,
    pub(crate) needed: usize,
    pub(crate) released: bool,
}

fn scope_key(s: Scope) -> u64 {
    match s {
        Scope::World => u64::MAX,
        Scope::Node(n) => n as u64,
    }
}

// ---------------------------------------------------------------------------
// the engine
// ---------------------------------------------------------------------------

/// Simulator bound to a topology.
pub struct Sim<'a> {
    pub topo: &'a Topology,
    pub cfg: SimConfig,
    /// Deterministic adversarial schedule (default: empty = fault-free).
    faults: FaultPlan,
    /// Worker-thread budget for the sharded engine (1 = the sequential
    /// reference engine, always).
    threads: usize,
}

impl<'a> Sim<'a> {
    pub fn new(topo: &'a Topology) -> Self {
        Sim {
            topo,
            cfg: SimConfig::default(),
            faults: FaultPlan::default(),
            threads: 1,
        }
    }

    pub fn with_config(topo: &'a Topology, cfg: SimConfig) -> Self {
        Sim {
            topo,
            cfg,
            faults: FaultPlan::default(),
            threads: 1,
        }
    }

    /// Attach a fault plan. An empty plan leaves the run bit-identical
    /// to a fault-free simulation.
    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    /// Set the worker-thread budget (clamped to ≥ 1). `1` always runs
    /// the sequential reference engine; `> 1` runs the component-sharded
    /// engine *when the run is eligible* (timing-only, no trace,
    /// `RailPolicy::Static`, no jitter, a multi-node cluster whose
    /// program actually decomposes into >1 partition) and falls back to
    /// the sequential engine otherwise. Either way the `SimReport` is
    /// bit-identical — threads change wall-clock, never results.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// The worker-thread budget.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The attached fault plan.
    pub fn faults(&self) -> &FaultPlan {
        &self.faults
    }

    /// Execute `prog` to completion.
    pub fn run(
        &self,
        prog: &Program,
        heap: &mut SymmetricHeap,
        exec: &mut dyn ComputeExecutor,
    ) -> Result<SimReport, SimError> {
        let wall0 = std::time::Instant::now();
        let mut rep = match crate::sim::par::plan(self, prog) {
            Some(pm) => crate::sim::par::run_sharded(self, prog, heap, pm)?,
            None => Runner::new(self, prog, heap, exec).run()?,
        };
        rep.wall_ns = wall0.elapsed().as_nanos() as u64;
        Ok(rep)
    }
}

/// Which flavor of event loop this `Runner` is.
///
/// The sharded engine (`sim/par.rs`) reuses `Runner` wholesale: each
/// node partition gets a `Shard` runner (full-width state, but it only
/// ever starts its own tasks and solves its own intra-node links) and
/// the shared inter-node fabric gets a `Fabric` runner (no tasks; owns
/// every fabric flow plus all fault machinery). The role gates exactly
/// three behaviors: where inter-node flow posts go (shard → outbox),
/// where world-barrier arrivals go (shard → outbox), and where flow
/// completion effects land (fabric → outbox, dispatched to the owning
/// shard by the coordinator). Everything else — op interpretation,
/// batching, retry ladders, watchdogs — is byte-for-byte the same code
/// the sequential engine runs.
#[derive(Clone, Copy, PartialEq, Eq)]
pub(crate) enum Role {
    Solo,
    Shard,
    Fabric,
}

/// Cross-partition traffic, drained at the lookahead barrier and merged
/// deterministically by the coordinator (sorted by `(t, shard, FIFO)`).
pub(crate) enum OutMsg {
    /// A shard posted an inter-node transfer: the route is already
    /// resolved (static routing is state-free), the fabric launches it
    /// at `t` and its `FlowArm` lands at `t + route.latency ≥ t + Δ`.
    InterFlow {
        t: f64,
        route: Route,
        bytes: f64,
        ctx: FlowCtx,
    },
    /// A task reached a world-scoped barrier at `t`.
    BarrierArrive {
        t: f64,
        key: (u64, usize),
        task: usize,
        expect: usize,
    },
    /// A fabric flow completed at `t`; its task-side effects (signal,
    /// LL flags, nbi/blocking wakeups) belong to shard-owned state.
    Effects { t: f64, ctx: FlowCtx },
    /// World barrier released at `t`: wake `task` on its owning shard.
    BarrierWake { t: f64, task: usize },
}

pub(crate) struct Runner<'s, 'a, 'h, E: ?Sized = dyn ComputeExecutor + 'h> {
    sim: &'s Sim<'a>,
    prog: &'s Program,
    heap: &'h mut SymmetricHeap,
    exec: &'h mut E,
    hw: HardwareModel,

    /// Solo (the sequential engine), or one participant of the sharded
    /// engine.
    role: Role,
    /// `Shard` only: per-rank ownership mask (empty otherwise).
    owned: Vec<bool>,
    /// Cross-partition messages for the coordinator (sharded roles only).
    pub(crate) outbox: Vec<OutMsg>,

    clock: f64,
    seq: u64,
    events: BinaryHeap<QEntry>,
    pub(crate) n_events: u64,
    pub(crate) n_flows: u64,

    pub(crate) tasks: Vec<TaskRt>,
    flows: FlowNet,
    /// Rail resolution for `TrafficClass::Auto` (policy from the fabric).
    router: Router<'a>,
    /// Live per-link committed-bytes / in-flight counters the adaptive
    /// router reads; bumped at post time, released at completion.
    occ: LinkOccupancy,
    /// Occupancy is only ever read under `RailPolicy::Adaptive` or a
    /// non-FIFO `ChunkSched`; skip the per-flow bookkeeping entirely on
    /// the (default) static/eager hot path.
    track_occ: bool,
    /// The fabric's chunk issue policy (`Fifo` = eager, pre-scheduler).
    chunk_sched: ChunkSched,
    /// Divert tagged inter-node puts through the ready queue? Only under
    /// a non-FIFO policy, and only on the solo engine — `sim/par.rs`
    /// routes non-FIFO runs to the sequential fallback, so a sharded
    /// runner never schedules chunks.
    sched_on: bool,
    /// Policy-ordered ready queue, one FIFO stream per `(task, dst)` —
    /// the scheduler reorders *across* streams, never within one, so
    /// per-(src, dst, rail) delivery order is preserved structurally.
    /// BTreeMap: deterministic iteration is a standing invariant.
    ready: BTreeMap<(u32, usize), VecDeque<ReadyChunk>>,
    /// Flow contexts, slab-indexed by `FlowId` (slots are recycled in
    /// lockstep with `FlowNet`'s free list).
    flow_ctx: Vec<Option<FlowCtx>>,
    pending: Vec<Option<PendingFlow>>,
    pending_free: Vec<usize>,
    /// Same-timestamp flow events being coalesced (reused buffers).
    batch_arms: Vec<usize>,
    batch_dones: Vec<(FlowId, u64)>,

    /// Signal waiters, flat-indexed by `rank * sig_pad + idx`.
    sig_waiters: Vec<Vec<usize>>,
    sig_pad: usize,
    // Ordered maps: none of these are iterated on the hot path today,
    // but deterministic iteration order is a standing invariant of the
    // sharded engine (no hasher state anywhere results can observe).
    ll_arrived: BTreeMap<LLKey, u32>,
    ll_waiters: BTreeMap<LLKey, Vec<usize>>,
    pub(crate) barriers: BTreeMap<(u64, usize), BarrierState>,

    sm_used: Vec<u32>,
    sm_queue: Vec<VecDeque<usize>>,

    // -- fault injection state (inert on an empty plan) --------------------
    /// Any scheduled faults at all? Gates every fault branch so the
    /// empty-plan run is bit-identical to the fault-free engine.
    faults_on: bool,
    /// Per fault: the concrete links it covers on this topology.
    fault_links: Vec<Vec<LinkId>>,
    fault_active: Vec<bool>,
    /// Nominal link capacities (retarget math: `base * factor`).
    base_bw: Vec<f64>,
    /// Live capacity factors the adaptive router consults
    /// (`Some` iff `faults_on`).
    health: Option<FabricHealth>,
    /// Per-rank compute inflation (`None` when no stragglers).
    straggle: Option<Vec<f64>>,
    /// Seeded latency jitter stream (`None` when not configured).
    jitter: Option<(Rng, f64)>,
    /// Watchdog block generation per task (stale-event filter).
    wd_gen: Vec<u64>,
    retries: Vec<Option<RetryEntry>>,
    retry_free: Vec<usize>,
    /// Any permanent deaths scheduled? Gates every death-detection
    /// branch (false on death-free plans: zero extra work).
    deaths_on: bool,
    /// Per death: the concrete ranks it retires (empty = out of range,
    /// inert on this cluster like an absent fault target).
    death_ranks: Vec<Vec<usize>>,
    /// Set when the first death fires: `(died_at, dead ranks so far)`.
    dead_since: Option<(f64, Vec<usize>)>,
    /// In-flight flows killed because they touched a dead rank.
    flows_drained: u64,

    pub(crate) report: SimReport,
}

impl<'s, 'a, 'h, E: ComputeExecutor + ?Sized> Runner<'s, 'a, 'h, E> {
    fn new(sim: &'s Sim<'a>, prog: &'s Program, heap: &'h mut SymmetricHeap, exec: &'h mut E) -> Self {
        Self::with_role(sim, prog, heap, exec, Role::Solo, Vec::new())
    }

    /// One node partition of the sharded engine: starts only tasks whose
    /// rank is owned, never schedules fault toggles (the fabric owns
    /// them), and routes cross-partition work through its outbox.
    pub(crate) fn shard(
        sim: &'s Sim<'a>,
        prog: &'s Program,
        heap: &'h mut SymmetricHeap,
        exec: &'h mut E,
        owned: Vec<bool>,
    ) -> Self {
        Self::with_role(sim, prog, heap, exec, Role::Shard, owned)
    }

    /// The shared-fabric runner of the sharded engine: no tasks, all
    /// fault machinery, and flow-completion effects emitted as outbox
    /// messages for the coordinator to dispatch.
    pub(crate) fn fabric(
        sim: &'s Sim<'a>,
        prog: &'s Program,
        heap: &'h mut SymmetricHeap,
        exec: &'h mut E,
    ) -> Self {
        Self::with_role(sim, prog, heap, exec, Role::Fabric, Vec::new())
    }

    fn with_role(
        sim: &'s Sim<'a>,
        prog: &'s Program,
        heap: &'h mut SymmetricHeap,
        exec: &'h mut E,
        role: Role,
        owned: Vec<bool>,
    ) -> Self {
        let ws = sim.topo.cluster.world_size();
        let link_bw: Vec<f64> = (0..sim.topo.link_count())
            .map(|l| sim.topo.link(LinkId(l)).bw)
            .collect();
        let sig_pad = heap.signal_pad();
        let sig_world = heap.world();
        let plan = &sim.faults;
        let faults_on = !plan.is_empty();
        let fault_links: Vec<Vec<LinkId>> = plan
            .link_faults
            .iter()
            .map(|f| sim.topo.fault_links(&f.target))
            .collect();
        let straggle = if faults_on && !plan.stragglers.is_empty() {
            Some((0..ws).map(|r| plan.straggle_factor(r)).collect())
        } else {
            None
        };
        let jitter = plan.jitter.map(|j| (Rng::new(j.seed), j.max_secs));
        let base_bw = link_bw.clone();
        let c = &sim.topo.cluster;
        let chunk_sched = c.fabric.chunk_sched;
        let sched_on = chunk_sched != ChunkSched::Fifo && role == Role::Solo;
        let death_ranks: Vec<Vec<usize>> = plan
            .deaths
            .iter()
            .map(|d| match d.scope {
                DeathScope::Rank(r) if r < ws => vec![r],
                DeathScope::Node(n) if n < c.nodes => {
                    (0..ws).filter(|&r| c.node_of(r) == n).collect()
                }
                _ => Vec::new(), // out of range: inert, like absent targets
            })
            .collect();
        Runner {
            sim,
            prog,
            heap,
            exec,
            hw: sim.topo.cluster.hw,
            role,
            owned,
            outbox: Vec::new(),
            clock: 0.0,
            seq: 0,
            events: BinaryHeap::new(),
            n_events: 0,
            n_flows: 0,
            tasks: prog
                .tasks
                .iter()
                .map(|_| TaskRt {
                    pc: 0,
                    state: TState::NotStarted,
                    outstanding_nbi: 0,
                    t_start: 0.0,
                    t_end: 0.0,
                    op_t0: 0.0,
                    op_gen: 0,
                    launches: 0,
                })
                .collect(),
            flows: FlowNet::new(link_bw),
            router: Router::new(sim.topo),
            occ: LinkOccupancy::new(sim.topo.link_count()),
            track_occ: sim.topo.cluster.fabric.rail_policy == RailPolicy::Adaptive || sched_on,
            chunk_sched,
            sched_on,
            ready: BTreeMap::new(),
            flow_ctx: Vec::new(),
            pending: Vec::new(),
            pending_free: Vec::new(),
            batch_arms: Vec::new(),
            batch_dones: Vec::new(),
            sig_waiters: vec![Vec::new(); sig_world * sig_pad],
            sig_pad,
            ll_arrived: BTreeMap::new(),
            ll_waiters: BTreeMap::new(),
            barriers: BTreeMap::new(),
            sm_used: vec![0; ws],
            sm_queue: (0..ws).map(|_| VecDeque::new()).collect(),
            faults_on,
            fault_active: vec![false; fault_links.len()],
            fault_links,
            health: faults_on.then(|| FabricHealth::healthy(sim.topo.link_count())),
            base_bw,
            straggle,
            jitter,
            wd_gen: vec![0; prog.tasks.len()],
            retries: Vec::new(),
            retry_free: Vec::new(),
            deaths_on: faults_on && death_ranks.iter().any(|r| !r.is_empty()),
            death_ranks,
            dead_since: None,
            flows_drained: 0,
            report: SimReport::default(),
        }
    }

    fn push(&mut self, t: f64, ev: Ev) {
        debug_assert!(!t.is_nan(), "NaN event time for {ev:?}");
        debug_assert!(t >= self.clock - 1e-12, "event in the past: {t} < {}", self.clock);
        self.seq += 1;
        self.events.push(QEntry {
            t: t.max(self.clock),
            seq: self.seq,
            ev,
        });
    }

    fn span(&mut self, task: usize, label: &str, t0: f64, t1: f64) {
        if self.sim.cfg.trace {
            let spec = &self.prog.tasks[task];
            self.report.op_spans.push(OpSpan {
                task,
                rank: spec.rank,
                task_name: spec.name.clone(),
                label: label.to_string(),
                t0,
                t1,
            });
        }
    }

    /// Does this runner start/advance task `i`? Solo owns everything,
    /// a shard owns the tasks of its ranks, the fabric owns none.
    fn owns_task(&self, i: usize) -> bool {
        match self.role {
            Role::Solo => true,
            Role::Shard => self.owned[self.prog.tasks[i].rank],
            Role::Fabric => false,
        }
    }

    /// Schedule the initial event population: `Start` for every owned
    /// task, plus the fault plan's toggles (Solo and Fabric only — a
    /// shard's fabric health never changes; faults live on fabric links).
    pub(crate) fn init(&mut self) -> Result<(), SimError> {
        for (i, t) in self.prog.tasks.iter().enumerate() {
            let mine = match self.role {
                Role::Solo => true,
                Role::Shard => self.owned[t.rank],
                Role::Fabric => false,
            };
            if !mine {
                continue;
            }
            if t.sms > self.hw.sms {
                return Err(SimError::SmOversubscribed {
                    task: t.name.clone(),
                    rank: t.rank,
                    req: t.sms,
                    cap: self.hw.sms,
                });
            }
            self.push(t.start_delay, Ev::Start { task: i });
        }

        // schedule the fault plan as first-class events (none on an
        // empty plan: the event stream is untouched)
        if self.faults_on && self.role != Role::Shard {
            for i in 0..self.fault_links.len() {
                if self.fault_links[i].is_empty() {
                    continue; // target absent on this topology: inert
                }
                let f = &self.sim.faults.link_faults[i];
                self.push(f.t_start, Ev::FaultToggle { fault: i, begin: true });
                if f.t_end.is_finite() {
                    self.push(f.t_end, Ev::FaultToggle { fault: i, begin: false });
                }
            }
            for i in 0..self.death_ranks.len() {
                if self.death_ranks[i].is_empty() {
                    continue; // scope absent on this cluster: inert
                }
                let t = self.sim.faults.deaths[i].t;
                self.push(t, Ev::Death { death: i });
            }
        }
        Ok(())
    }

    fn dispatch(&mut self, t: f64, ev: Ev) -> Result<(), SimError> {
        self.clock = t;
        self.n_events += 1;
        match ev {
            Ev::Start { task } => self.on_start(task)?,
            Ev::FlowArm { pending } => {
                self.batch_arms.push(pending);
                self.drain_flow_events_at(t);
                self.on_flow_batch()?;
            }
            Ev::FlowDone { flow, gen } => {
                self.batch_dones.push((flow, gen));
                self.drain_flow_events_at(t);
                self.on_flow_batch()?;
            }
            Ev::OpDone { task, gen } => self.on_op_done(task, gen)?,
            Ev::BarrierRelease { key } => self.on_barrier_release(key)?,
            Ev::FaultToggle { fault, begin } => self.on_fault_toggle(fault, begin)?,
            Ev::Watchdog { task, gen } => self.on_watchdog(task, gen)?,
            Ev::Retry { entry } => self.on_retry(entry)?,
            Ev::Death { death } => self.on_death(death)?,
        }
        Ok(())
    }

    /// Timestamp of the next queued event (`INFINITY` when drained).
    pub(crate) fn next_time(&self) -> f64 {
        self.events.peek().map_or(f64::INFINITY, |e| e.t)
    }

    /// Process every queued event with `t < horizon` (the conservative
    /// lookahead window: nothing outside this runner can schedule work
    /// below the horizon, so the window is safe to run unsynchronized).
    pub(crate) fn run_window(&mut self, horizon: f64) -> Result<(), SimError> {
        while self.events.peek().is_some_and(|e| e.t < horizon) {
            let QEntry { t, ev, .. } = self.events.pop().expect("peeked entry vanished");
            self.dispatch(t, ev)?;
        }
        Ok(())
    }

    /// Process exactly one event (plus its same-timestamp flow batch).
    /// Returns false when the queue is empty.
    pub(crate) fn step_one(&mut self) -> Result<bool, SimError> {
        match self.events.pop() {
            Some(QEntry { t, ev, .. }) => {
                self.dispatch(t, ev)?;
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Advance the clock to a coordinator-dispatched effect time (never
    /// backwards; the lookahead barrier guarantees `t ≥` every event
    /// this runner already processed).
    pub(crate) fn sync_clock(&mut self, t: f64) {
        debug_assert!(
            t >= self.clock - 1e-12,
            "cross-partition effect in the past: {t} < {}",
            self.clock
        );
        self.clock = self.clock.max(t);
    }

    /// Drain the cross-partition outbox (coordinator barrier).
    pub(crate) fn take_outbox(&mut self) -> Vec<OutMsg> {
        std::mem::take(&mut self.outbox)
    }

    /// Coordinator hook: schedule a world-barrier release on the fabric
    /// queue (the matching `BarrierState` must already be in `barriers`).
    pub(crate) fn push_barrier_release(&mut self, t: f64, key: (u64, usize)) {
        self.push(t, Ev::BarrierRelease { key });
    }

    /// Diagnostic lines for every owned task that is not `Done`.
    pub(crate) fn stuck_tasks(&self) -> Vec<String> {
        self.tasks
            .iter()
            .enumerate()
            .filter(|(i, t)| self.owns_task(*i) && t.state != TState::Done)
            .map(|(i, t)| {
                format!(
                    "task '{}' (rank {}) pc={} state={:?}",
                    self.prog.tasks[i].name, self.prog.tasks[i].rank, t.pc, t.state
                )
            })
            .collect()
    }

    fn run(mut self) -> Result<SimReport, SimError> {
        self.init()?;

        while let Some(QEntry { t, ev, .. }) = self.events.pop() {
            self.dispatch(t, ev)?;
        }

        // completion / deadlock check; with a death on record the stall
        // is attributed to the dead peer (queue-drain backstop: a death
        // never surfaces as a bare Deadlock)
        let stuck = self.stuck_tasks();
        if !stuck.is_empty() {
            if self.dead_since.is_some() {
                return Err(self.dead_peer("queue-drain"));
            }
            return Err(SimError::Deadlock(stuck.join("; ")));
        }

        self.report.makespan = self
            .tasks
            .iter()
            .map(|t| t.t_end)
            .fold(0.0f64, f64::max);
        self.report.task_spans = self
            .prog
            .tasks
            .iter()
            .zip(self.tasks.iter())
            .map(|(s, rt)| (s.name.clone(), s.rank, rt.t_start, rt.t_end))
            .collect();
        self.report.events = self.n_events;
        self.report.flows = self.n_flows;
        Ok(self.report)
    }

    // -- event handlers ----------------------------------------------------

    fn on_start(&mut self, task: usize) -> Result<(), SimError> {
        let spec = &self.prog.tasks[task];
        let rank = spec.rank;
        if spec.sms > 0 && self.sm_used[rank] + spec.sms > self.hw.sms {
            self.tasks[task].state = TState::WaitingSms;
            self.sm_queue[rank].push_back(task);
            return Ok(());
        }
        self.sm_used[rank] += spec.sms;
        self.tasks[task].state = TState::Running;
        self.tasks[task].t_start = self.clock;
        self.advance(task)
    }

    /// Pull every queued flow event that shares timestamp `t` into the
    /// current batch (collectives issue many puts at identical virtual
    /// times; their arms and completions land with equal timestamps).
    /// Stops at the first non-flow event so ordering with Start/OpDone/
    /// BarrierRelease handlers stays deterministic by (t, seq).
    fn drain_flow_events_at(&mut self, t: f64) {
        while let Some(peek) = self.events.peek() {
            if peek.t != t || !matches!(peek.ev, Ev::FlowArm { .. } | Ev::FlowDone { .. }) {
                break;
            }
            let QEntry { ev, .. } = self.events.pop().expect("peeked entry vanished");
            self.n_events += 1;
            match ev {
                Ev::FlowArm { pending } => self.batch_arms.push(pending),
                Ev::FlowDone { flow, gen } => self.batch_dones.push((flow, gen)),
                _ => unreachable!(),
            }
        }
    }

    /// Apply one coalesced batch of flow arms + completions: a single
    /// component-scoped `FlowNet::update`, then the completion
    /// side-effects in event order.
    fn on_flow_batch(&mut self) -> Result<(), SimError> {
        let mut arms = std::mem::take(&mut self.batch_arms);
        let dones = std::mem::take(&mut self.batch_dones);

        // Canonical batch order: (task, per-task launch seq). Slab ids
        // depend on free-list recycling history, which differs between
        // the solo engine (one slab) and the sharded engine (per-shard +
        // fabric slabs); the launch key does not. Sorting both arms and
        // completions by it makes every same-timestamp batch — and thus
        // every signal/LL/SM wake order downstream — identical across
        // engine layouts. Rates are unaffected (the water-fill is
        // order-insensitive); only tie-order observability is pinned.
        arms.sort_by_key(|&p| self.pending[p].as_ref().expect("pending flow armed twice").ctx.key);

        // stale-filter completions against current generations
        let mut remove_ids: Vec<FlowId> = Vec::with_capacity(dones.len());
        for &(flow, gen) in &dones {
            if self.flows.is_current(flow, gen) {
                debug_assert!(
                    self.flows.remaining_at(flow, self.clock) < 1e-3,
                    "current FlowDone with {} bytes left",
                    self.flows.remaining_at(flow, self.clock)
                );
                remove_ids.push(flow);
            }
        }
        remove_ids.sort_by_key(|id| self.flow_ctx[id.0].as_ref().expect("missing flow ctx").key);

        // collect armed flows (recycling their pending slots)
        let mut adds = Vec::with_capacity(arms.len());
        let mut add_ctxs = Vec::with_capacity(arms.len());
        for &p in &arms {
            let pf = self.pending[p].take().expect("pending flow armed twice");
            self.pending_free.push(p);
            // a fault may have downed a link while this transfer sat in
            // its latency window: divert retryable arms straight to the
            // retry machinery instead of entering a zero-rate flow
            if let Some(h) = &self.health {
                if !h.all_healthy()
                    && pf.ctx.rt.is_some()
                    && pf.links.iter().any(|&l| h.is_down(l))
                {
                    if self.track_occ {
                        self.occ.release(&pf.links, pf.ctx.wire_bytes);
                    }
                    self.report.ledger.flows_killed += 1;
                    self.enqueue_retry(RetryEntry {
                        rt: pf.ctx.rt.expect("checked is_some"),
                        bytes: pf.bytes,
                        ctx: pf.ctx,
                        attempt: 1,
                        orig_links: pf.links,
                    });
                    continue;
                }
            }
            adds.push((pf.links, pf.bytes));
            add_ctxs.push(pf.ctx);
        }
        self.n_flows += add_ctxs.len() as u64;

        // take completed contexts BEFORE the update recycles their slots
        let mut done_ctxs = Vec::with_capacity(remove_ids.len());
        for id in &remove_ids {
            done_ctxs.push(self.flow_ctx[id.0].take().expect("missing flow ctx"));
        }
        // release the completed flows' occupancy shares (links are still
        // resolvable until the update below recycles the slots)
        if self.track_occ {
            for (id, ctx) in remove_ids.iter().zip(&done_ctxs) {
                let links: &[crate::topology::LinkId] = self.flows.links_of(*id);
                self.occ.release(links, ctx.wire_bytes);
            }
        }

        let (ids, update) = self.flows.update(self.clock, &remove_ids, adds);
        for (id, ctx) in ids.iter().zip(add_ctxs) {
            if self.flow_ctx.len() <= id.0 {
                self.flow_ctx.resize_with(id.0 + 1, || None);
            }
            debug_assert!(self.flow_ctx[id.0].is_none(), "flow ctx slot collision");
            self.flow_ctx[id.0] = Some(ctx);
        }
        for (f, gen, eta) in update.etas {
            // infinite eta = flow stalled on a zero-capacity (faulted)
            // link; a fresh eta is emitted when the link recovers
            if eta.is_finite() {
                self.push(self.clock + eta, Ev::FlowDone { flow: f, gen });
            }
        }
        for ctx in done_ctxs {
            self.finish_flow(ctx)?;
        }

        // completed flows released link occupancy: parked chunks may
        // now be admissible
        if self.sched_on {
            self.pump();
        }

        // hand the (emptied) batch buffers back for reuse
        let mut arms = arms;
        let mut dones = dones;
        arms.clear();
        dones.clear();
        self.batch_arms = arms;
        self.batch_dones = dones;
        Ok(())
    }

    /// Completion side-effects of one flow: data movement, signal,
    /// LL-flag arrivals, trace span, nbi/blocking wakeups. The fabric
    /// runner's effects belong to shard-owned task state, so it hands
    /// the context to the coordinator instead; the coordinator replays
    /// the exact same helper calls, in the same order, on the owning
    /// shard(s).
    fn finish_flow(&mut self, ctx: FlowCtx) -> Result<(), SimError> {
        if self.role == Role::Fabric {
            let t = self.clock;
            self.outbox.push(OutMsg::Effects { t, ctx });
            return Ok(());
        }
        if self.sim.cfg.numerics {
            for (src, dst) in &ctx.copies {
                self.heap.copy(*src, *dst);
            }
        }
        if let Some((sig, op, val)) = ctx.signal {
            self.apply_signal(sig, op, val)?;
        }
        for key in ctx.ll_dsts {
            self.deliver_ll(key)?;
        }
        if let Some((task, label, t0)) = ctx.span {
            self.span(task, label, t0, self.clock);
        }
        if let Some(owner) = ctx.nbi_owner {
            self.deliver_nbi(owner)?;
        }
        if let Some(t) = ctx.resume {
            self.deliver_resume(t)?;
        }
        Ok(())
    }

    /// An LL payload's in-band flag landed: bump the arrival count and
    /// wake every task parked on that (rank, buf, off) key.
    pub(crate) fn deliver_ll(&mut self, key: LLKey) -> Result<(), SimError> {
        *self.ll_arrived.entry(key).or_insert(0) += 1;
        if let Some(waiters) = self.ll_waiters.remove(&key) {
            for w in waiters {
                self.tasks[w].state = TState::Running;
                self.bump_pc_and_resume(w)?;
            }
        }
        Ok(())
    }

    /// A non-blocking transfer of `owner` completed; wake it if it was
    /// draining its nbi window in `Quiet`.
    pub(crate) fn deliver_nbi(&mut self, owner: usize) -> Result<(), SimError> {
        self.tasks[owner].outstanding_nbi -= 1;
        if self.tasks[owner].state == TState::WaitQuiet && self.tasks[owner].outstanding_nbi == 0 {
            self.tasks[owner].state = TState::Running;
            self.bump_pc_and_resume(owner)?;
        }
        Ok(())
    }

    /// A blocking transfer completed: resume its issuing task.
    pub(crate) fn deliver_resume(&mut self, t: usize) -> Result<(), SimError> {
        debug_assert_eq!(self.tasks[t].state, TState::BlockedFlow);
        self.tasks[t].state = TState::Running;
        self.bump_pc_and_resume(t)
    }

    /// World-barrier release reached this shard: wake one arrived task.
    pub(crate) fn deliver_barrier_wake(&mut self, task: usize) -> Result<(), SimError> {
        self.tasks[task].state = TState::Running;
        self.bump_pc_and_resume(task)
    }

    fn on_op_done(&mut self, task: usize, gen: u64) -> Result<(), SimError> {
        if self.tasks[task].op_gen != gen {
            return Ok(());
        }
        let spec = &self.prog.tasks[task];
        let op = spec.ops[self.tasks[task].pc].clone();
        match &op {
            Op::Compute { numeric, .. } => {
                if self.sim.cfg.numerics {
                    self.apply_numeric(numeric)?;
                }
            }
            Op::Sleep { .. } => {}
            other => unreachable!("OpDone on non-timed op {other:?}"),
        }
        let t0 = self.tasks[task].op_t0;
        self.span(task, op.label(), t0, self.clock);
        self.tasks[task].state = TState::Running;
        self.bump_pc_and_resume(task)
    }

    fn on_barrier_release(&mut self, key: (u64, usize)) -> Result<(), SimError> {
        let st = self.barriers.get_mut(&key).expect("missing barrier");
        st.released = true;
        let arrived = std::mem::take(&mut st.arrived);
        for t in arrived {
            if self.role == Role::Fabric {
                // world barrier on the sharded engine: the arrived tasks
                // live on shards — the coordinator wakes each in the
                // same (arrival) order the solo engine would.
                let now = self.clock;
                self.outbox.push(OutMsg::BarrierWake { t: now, task: t });
            } else {
                self.tasks[t].state = TState::Running;
                self.bump_pc_and_resume(t)?;
            }
        }
        Ok(())
    }

    // -- fault handlers ------------------------------------------------------

    /// A scheduled fault begins or clears: recompute the capacity factor
    /// of every covered link (overlapping faults multiply), retarget the
    /// flow solver on the touched component(s), and kill-and-retry any
    /// retryable flow riding a newly-dead link.
    fn on_fault_toggle(&mut self, fault: usize, begin: bool) -> Result<(), SimError> {
        self.fault_active[fault] = begin;
        let mut changes: Vec<(LinkId, f64)> = Vec::new();
        {
            let plan = &self.sim.faults;
            let health = self.health.as_mut().expect("faults_on without health");
            for li in 0..self.fault_links[fault].len() {
                let l = self.fault_links[fault][li];
                let mut factor = 1.0;
                for j in 0..self.fault_active.len() {
                    if self.fault_active[j] && self.fault_links[j].contains(&l) {
                        factor *= plan.link_faults[j].factor;
                    }
                }
                if health.factor(l) != factor {
                    health.set_factor(l, factor);
                    changes.push((l, self.base_bw[l.0] * factor));
                }
            }
        }
        if changes.is_empty() {
            return Ok(()); // e.g. re-toggle of an already-covered link
        }
        self.report.ledger.faults_applied += 1;

        // kill-and-retry: retryable in-flight flows on a newly-dead link.
        // Non-retryable flows (multimem) stay and stall at rate 0 until
        // the link recovers.
        let mut victims: Vec<FlowId> = Vec::new();
        for &(l, bw) in &changes {
            if bw != 0.0 {
                continue;
            }
            for f in self.flows.flows_on(l) {
                if victims.contains(&f) {
                    continue;
                }
                let retryable = self.flow_ctx[f.0].as_ref().is_some_and(|c| c.rt.is_some());
                // remaining == 0 means its FlowDone is already due: let
                // it complete rather than replaying the transfer
                if retryable && self.flows.remaining_at(f, self.clock) > 0.0 {
                    victims.push(f);
                }
            }
        }
        // canonical victim order (see on_flow_batch): retry scheduling
        // and the ledger's f64 byte sums are insensitive to slab layout
        victims.sort_by_key(|f| self.flow_ctx[f.0].as_ref().expect("victim ctx missing").key);
        let mut parked: Vec<RetryEntry> = Vec::with_capacity(victims.len());
        for &f in &victims {
            let links = self.flows.links_of(f).to_vec();
            let ctx = self.flow_ctx[f.0].take().expect("victim ctx missing");
            if self.track_occ {
                self.occ.release(&links, ctx.wire_bytes);
            }
            self.report.ledger.flows_killed += 1;
            parked.push(RetryEntry {
                rt: ctx.rt.expect("victim without retry route"),
                bytes: self.flows.remaining_at(f, self.clock),
                ctx,
                attempt: 1,
                orig_links: links,
            });
        }
        if !victims.is_empty() {
            let (_ids, upd) = self.flows.update(self.clock, &victims, Vec::new());
            for (f, gen, eta) in upd.etas {
                if eta.is_finite() {
                    self.push(self.clock + eta, Ev::FlowDone { flow: f, gen });
                }
            }
        }
        for e in parked {
            self.enqueue_retry(e);
        }

        // retarget the solver: incremental re-solve of the components
        // touched by the changed links only
        let upd = self.flows.retarget(self.clock, &changes);
        for (f, gen, eta) in upd.etas {
            if eta.is_finite() {
                self.push(self.clock + eta, Ev::FlowDone { flow: f, gen });
            }
        }
        Ok(())
    }

    /// A permanent rank/node death fires: mark the ranks dead in the
    /// health view, zero every link they terminate, drain (kill without
    /// retry — the peer is gone) every in-flight flow riding those
    /// links, and — when anything was actually in flight — abort with a
    /// structured [`SimError::DeadPeer`] right here (`via: flow-kill`).
    /// A death nothing was talking to stays silent until the first
    /// subsequent touch: a transfer posted to/from a dead endpoint, a
    /// retry re-routed onto one, a watchdog firing with deaths active,
    /// or ultimately the queue-drain backstop in [`Runner::run`]. All
    /// paths produce `DeadPeer`, never a hang or a bare `Deadlock`.
    fn on_death(&mut self, death: usize) -> Result<(), SimError> {
        let ranks = self.death_ranks[death].clone();
        let mut changes: Vec<(LinkId, f64)> = Vec::new();
        {
            let health = self.health.as_mut().expect("deaths without health");
            let mut newly: Vec<usize> = Vec::new();
            for &r in &ranks {
                if health.is_alive(r) {
                    health.mark_dead(r);
                    newly.push(r);
                }
            }
            if newly.is_empty() {
                return Ok(()); // overlapping die/nodedead: idempotent
            }
            for &r in &newly {
                for l in self.sim.topo.fault_links(&FaultTarget::Rank { rank: r }) {
                    if health.factor(l) != 0.0 {
                        health.set_factor(l, 0.0);
                        changes.push((l, 0.0));
                    }
                }
            }
            match &mut self.dead_since {
                Some((_, list)) => list.extend(newly),
                None => self.dead_since = Some((self.clock, newly)),
            }
        }
        self.report.ledger.faults_applied += 1;

        // Drain: every in-flight flow terminating at a dead rank is
        // lost — no data movement, no signal, no retry. Flows whose
        // wire transfer already finished (FlowDone due at this instant)
        // are let through, matching the fault-toggle rule.
        let mut victims: Vec<FlowId> = Vec::new();
        for &(l, _) in &changes {
            for f in self.flows.flows_on(l) {
                if !victims.contains(&f) && self.flows.remaining_at(f, self.clock) > 0.0 {
                    victims.push(f);
                }
            }
        }
        victims.sort_by_key(|f| self.flow_ctx[f.0].as_ref().expect("victim ctx missing").key);
        for &f in &victims {
            let links = self.flows.links_of(f).to_vec();
            let ctx = self.flow_ctx[f.0].take().expect("victim ctx missing");
            if self.track_occ {
                self.occ.release(&links, ctx.wire_bytes);
            }
            self.report.ledger.flows_killed += 1;
            self.flows_drained += 1;
        }
        if !victims.is_empty() {
            let (_ids, upd) = self.flows.update(self.clock, &victims, Vec::new());
            for (f, gen, eta) in upd.etas {
                if eta.is_finite() {
                    self.push(self.clock + eta, Ev::FlowDone { flow: f, gen });
                }
            }
        }
        let upd = self.flows.retarget(self.clock, &changes);
        for (f, gen, eta) in upd.etas {
            if eta.is_finite() {
                self.push(self.clock + eta, Ev::FlowDone { flow: f, gen });
            }
        }
        if self.flows_drained > 0 {
            return Err(self.dead_peer("flow-kill"));
        }
        Ok(())
    }

    /// Build the structured death abort: who died, when it was noticed,
    /// and the checkpoint of completed tasks the recovery controller
    /// carries over.
    fn dead_peer(&self, via: &str) -> SimError {
        let (died_at, dead) = self.dead_since.clone().expect("dead_peer without a death");
        let checkpoint: Vec<(String, usize, f64, f64)> = self
            .prog
            .tasks
            .iter()
            .zip(self.tasks.iter())
            .filter(|(_, rt)| rt.state == TState::Done)
            .map(|(s, rt)| (s.name.clone(), s.rank, rt.t_start, rt.t_end))
            .collect();
        SimError::DeadPeer(Box::new(DeadPeerInfo {
            dead,
            died_at,
            detected_at: self.clock,
            via: via.to_string(),
            flows_drained: self.flows_drained,
            checkpoint,
        }))
    }

    /// Death-detection probe on a transfer's endpoints (inert unless
    /// deaths are scheduled): posting to or from a dead rank aborts with
    /// `DeadPeer` instead of launching a flow that can never complete.
    fn check_endpoints_alive(&self, src: usize, dst: usize) -> Result<(), SimError> {
        if !self.deaths_on {
            return Ok(());
        }
        if let Some(h) = &self.health {
            if !h.is_alive(src) || !h.is_alive(dst) {
                return Err(self.dead_peer("launch-to-dead"));
            }
        }
        Ok(())
    }

    fn alloc_retry(&mut self, e: RetryEntry) -> usize {
        if let Some(i) = self.retry_free.pop() {
            self.retries[i] = Some(e);
            i
        } else {
            self.retries.push(Some(e));
            self.retries.len() - 1
        }
    }

    /// Park a killed transfer and schedule its backoff-delayed retry.
    fn enqueue_retry(&mut self, e: RetryEntry) {
        let back = self.sim.faults.backoff(e.attempt);
        let slot = self.alloc_retry(e);
        self.push(self.clock + back, Ev::Retry { entry: slot });
    }

    /// Backoff expired: re-route with the current fabric health and
    /// relaunch, or back off again (capped exponential) while every
    /// candidate path is still dead.
    fn on_retry(&mut self, entry: usize) -> Result<(), SimError> {
        let e = self.retries[entry].take().expect("missing retry entry");
        self.retry_free.push(entry);
        self.report.ledger.retries += 1;
        let mut route =
            self.router
                .route_faulty(e.rt.src, e.rt.dst, e.rt.tc, &self.occ, self.health.as_ref());
        let alive = match &self.health {
            Some(h) => h.route_alive(&route),
            None => true,
        };
        if !alive {
            // a dead endpoint can never come back: abort structured
            // instead of burning the backoff ladder
            if self.deaths_on {
                if let Some(h) = &self.health {
                    if !h.is_alive(e.rt.src) || !h.is_alive(e.rt.dst) {
                        return Err(self.dead_peer("retry-to-dead"));
                    }
                }
            }
            if e.attempt < self.sim.faults.retry_max {
                let attempt = e.attempt + 1;
                let back = self.sim.faults.backoff(attempt);
                let slot = self.alloc_retry(RetryEntry { attempt, ..e });
                self.push(self.clock + back, Ev::Retry { entry: slot });
                return Ok(());
            }
            // budget exhausted: launch on the dead path anyway and stall
            // until the fault clears (or the run deadlocks/watchdogs —
            // the Static-policy failure mode, made visible)
            self.report.ledger.retries_exhausted += 1;
        } else if route.links != e.orig_links {
            self.report.ledger.rerouted_bytes += e.bytes;
        }
        route.latency = route.latency * e.rt.lat_mult + e.rt.lat_add;
        self.launch_flow(route, e.bytes, e.ctx);
        Ok(())
    }

    /// (Re-)arm the liveness watchdog for a task entering an LL/signal
    /// wait. Inert unless the plan sets a finite `lt_timeout`.
    fn arm_watchdog(&mut self, task: usize) {
        let to = self.sim.faults.lt_timeout;
        if to.is_finite() {
            self.wd_gen[task] += 1;
            let gen = self.wd_gen[task];
            self.push(self.clock + to, Ev::Watchdog { task, gen });
        }
    }

    /// Watchdog fired: fatal only if the task is still parked in the
    /// same blocking wait it was armed for.
    fn on_watchdog(&mut self, task: usize, gen: u64) -> Result<(), SimError> {
        if self.wd_gen[task] != gen {
            return Ok(()); // re-armed for a later wait
        }
        let waiting = match &self.tasks[task].state {
            TState::BlockedSignal { idx, cond, value } => {
                format!("wait_signal(idx={idx}, {cond:?} {value})")
            }
            TState::BlockedLL { key } => {
                format!("ll_wait(rank={}, buf={}, off={})", key.0, key.1, key.2)
            }
            _ => return Ok(()), // woke up since; stale
        };
        if self.dead_since.is_some() {
            // the wait will never be satisfied by a dead peer: surface
            // the death, not a generic timeout
            return Err(self.dead_peer("watchdog"));
        }
        let spec = &self.prog.tasks[task];
        Err(SimError::WatchdogTimeout {
            task: spec.name.clone(),
            rank: spec.rank,
            waiting,
            timeout: self.sim.faults.lt_timeout,
            at: self.clock,
        })
    }

    // -- op interpreter ------------------------------------------------------

    fn bump_pc_and_resume(&mut self, task: usize) -> Result<(), SimError> {
        self.tasks[task].pc += 1;
        self.advance(task)
    }

    /// Run ops from the task's pc until it blocks or finishes.
    fn advance(&mut self, task: usize) -> Result<(), SimError> {
        loop {
            let spec = &self.prog.tasks[task];
            let pc = self.tasks[task].pc;
            if pc >= spec.ops.len() {
                return self.finish_task(task);
            }
            let op = spec.ops[pc].clone();
            let rank = spec.rank;
            match op {
                Op::Put {
                    src,
                    dst,
                    bytes,
                    signal,
                    blocking,
                    tc,
                    chunk,
                    label,
                } => {
                    self.check_endpoints_alive(src.rank, dst.rank)?;
                    let lat_add = if signal.is_some() {
                        // flag packet + fence after the payload (§3.4's
                        // "each P2P transfer requires a pair of signal
                        // operations, causing additional overhead")
                        self.hw.signal_overhead
                    } else {
                        0.0
                    };
                    let ctx = FlowCtx {
                        copies: vec![(src, dst)],
                        signal,
                        ll_dsts: Vec::new(),
                        resume: if blocking { Some(task) } else { None },
                        nbi_owner: if blocking { None } else { Some(task) },
                        span: Some((task, label, self.clock)),
                        wire_bytes: 0.0,
                        key: self.next_flow_key(task),
                        rt: Some(RetryRoute {
                            src: src.rank,
                            dst: dst.rank,
                            tc,
                            lat_mult: 1.0,
                            lat_add,
                        }),
                    };
                    if let Some(meta) = self.divert_meta(chunk, src.rank, dst.rank) {
                        self.enqueue_chunk(task, dst.rank, bytes, meta, ctx);
                    } else {
                        let mut route = self.router.route_faulty(
                            src.rank,
                            dst.rank,
                            tc,
                            &self.occ,
                            self.health.as_ref(),
                        );
                        route.latency += lat_add;
                        self.launch_flow(route, bytes, ctx);
                    }
                    if blocking {
                        self.tasks[task].state = TState::BlockedFlow;
                        return Ok(());
                    }
                    self.tasks[task].outstanding_nbi += 1;
                    self.tasks[task].pc += 1;
                }
                Op::Get {
                    src,
                    dst,
                    bytes,
                    blocking,
                    tc,
                    label,
                } => {
                    self.check_endpoints_alive(src.rank, dst.rank)?;
                    let mut route =
                        self.router
                            .route_faulty(src.rank, dst.rank, tc, &self.occ, self.health.as_ref());
                    route.latency *= 2.0; // request/response round trip
                    let ctx = FlowCtx {
                        copies: vec![(src, dst)],
                        signal: None,
                        ll_dsts: Vec::new(),
                        resume: if blocking { Some(task) } else { None },
                        nbi_owner: if blocking { None } else { Some(task) },
                        span: Some((task, label, self.clock)),
                        wire_bytes: 0.0,
                        key: self.next_flow_key(task),
                        rt: Some(RetryRoute {
                            src: src.rank,
                            dst: dst.rank,
                            tc,
                            lat_mult: 2.0,
                            lat_add: 0.0,
                        }),
                    };
                    self.launch_flow(route, bytes, ctx);
                    if blocking {
                        self.tasks[task].state = TState::BlockedFlow;
                        return Ok(());
                    }
                    self.tasks[task].outstanding_nbi += 1;
                    self.tasks[task].pc += 1;
                }
                Op::MultimemSt { src, bytes, ll } => {
                    self.check_endpoints_alive(src.rank, src.rank)?;
                    let route = self
                        .sim
                        .topo
                        .multimem_route(src.rank)
                        .expect("multimem_st unsupported on this hardware");
                    let node = self.sim.topo.cluster.node_of(src.rank);
                    let peers: Vec<usize> = (0..self.heap.world())
                        .filter(|&r| r != src.rank && self.sim.topo.cluster.node_of(r) == node)
                        .collect();
                    let copies: Vec<(Slice, Slice)> =
                        peers.iter().map(|&r| (src, src.on_rank(r))).collect();
                    let ll_dsts: Vec<LLKey> = if ll {
                        peers.iter().map(|&r| (r, src.buf.0, src.off)).collect()
                    } else {
                        Vec::new()
                    };
                    let ctx = FlowCtx {
                        copies,
                        signal: None,
                        ll_dsts,
                        resume: Some(task),
                        nbi_owner: None,
                        span: Some((task, "multimem_st", self.clock)),
                        wire_bytes: 0.0,
                        key: self.next_flow_key(task),
                        // multimem rides the switch broadcast tree: not
                        // re-routable, stalls through faults instead
                        rt: None,
                    };
                    self.launch_flow(route, bytes, ctx);
                    self.tasks[task].state = TState::BlockedFlow;
                    return Ok(());
                }
                Op::LLPut {
                    src,
                    dst,
                    bytes,
                    tc,
                    chunk,
                } => {
                    self.check_endpoints_alive(src.rank, dst.rank)?;
                    let ctx = FlowCtx {
                        copies: vec![(src, dst)],
                        signal: None,
                        ll_dsts: vec![(dst.rank, dst.buf.0, dst.off)],
                        resume: None,
                        nbi_owner: Some(task),
                        span: Some((task, "ll_put", self.clock)),
                        wire_bytes: 0.0,
                        key: self.next_flow_key(task),
                        rt: Some(RetryRoute {
                            src: src.rank,
                            dst: dst.rank,
                            tc,
                            lat_mult: 1.0,
                            lat_add: 0.0,
                        }),
                    };
                    // LL doubles the wire size (flag bytes in-band, §3.4)
                    if let Some(meta) = self.divert_meta(chunk, src.rank, dst.rank) {
                        self.enqueue_chunk(task, dst.rank, bytes * 2.0, meta, ctx);
                    } else {
                        let route = self.router.route_faulty(
                            src.rank,
                            dst.rank,
                            tc,
                            &self.occ,
                            self.health.as_ref(),
                        );
                        self.launch_flow(route, bytes * 2.0, ctx);
                    }
                    self.tasks[task].outstanding_nbi += 1;
                    self.tasks[task].pc += 1;
                }
                Op::LLWait { dst } => {
                    let key: LLKey = (dst.rank, dst.buf.0, dst.off);
                    if self.ll_arrived.get(&key).copied().unwrap_or(0) > 0 {
                        self.tasks[task].pc += 1;
                    } else {
                        self.ll_waiters.entry(key).or_default().push(task);
                        self.tasks[task].state = TState::BlockedLL { key };
                        self.arm_watchdog(task);
                        return Ok(());
                    }
                }
                Op::SetSignal { sig, op, value } => {
                    self.apply_signal(sig, op, value)?;
                    self.tasks[task].pc += 1;
                }
                Op::WaitSignal { idx, cond, value } => {
                    if sig_met(self.heap.signal(rank, idx), cond, value) {
                        self.tasks[task].pc += 1;
                    } else {
                        debug_assert!(idx < self.sig_pad, "signal idx out of pad");
                        self.sig_waiters[rank * self.sig_pad + idx].push(task);
                        self.tasks[task].state = TState::BlockedSignal { idx, cond, value };
                        self.arm_watchdog(task);
                        return Ok(());
                    }
                }
                Op::Quiet => {
                    if self.tasks[task].outstanding_nbi == 0 {
                        self.tasks[task].pc += 1;
                    } else {
                        self.tasks[task].state = TState::WaitQuiet;
                        return Ok(());
                    }
                }
                Op::Barrier { scope, id, expect } => {
                    let key = (scope_key(scope), id);
                    if self.role == Role::Shard && matches!(scope, Scope::World) {
                        // world barriers span partitions: the arrival is
                        // aggregated by the coordinator (which mirrors
                        // the expect/reuse validation below) and the
                        // release comes back as a BarrierWake. Node
                        // barriers stay shard-local — partitioning
                        // guarantees a node never splits across shards.
                        let now = self.clock;
                        self.outbox.push(OutMsg::BarrierArrive {
                            t: now,
                            key,
                            task,
                            expect,
                        });
                        self.tasks[task].state = TState::BlockedBarrier;
                        return Ok(());
                    }
                    let st = self.barriers.entry(key).or_insert(BarrierState {
                        arrived: Vec::new(),
                        needed: expect,
                        released: false,
                    });
                    assert_eq!(
                        st.needed, expect,
                        "barrier id {id} used with inconsistent expect counts"
                    );
                    if st.released {
                        // reuse of a released barrier id is a program bug
                        panic!("barrier id {id} reused after release");
                    }
                    st.arrived.push(task);
                    self.tasks[task].state = TState::BlockedBarrier;
                    if st.arrived.len() == st.needed {
                        let lat = match scope {
                            Scope::World if self.sim.topo.cluster.nodes > 1 => {
                                2.0 * self.hw.inter_lat
                            }
                            _ => 2.0 * self.hw.intra_lat,
                        };
                        self.push(self.clock + lat, Ev::BarrierRelease { key });
                    }
                    return Ok(());
                }
                Op::Compute { ref cost, .. } => {
                    let sms = self.prog.tasks[task].sms;
                    let mut dur = self.cost_time(cost, sms);
                    if let Some(s) = &self.straggle {
                        dur *= s[rank]; // straggler fault: inflated compute
                    }
                    self.tasks[task].op_gen += 1;
                    let gen = self.tasks[task].op_gen;
                    self.tasks[task].op_t0 = self.clock;
                    self.tasks[task].state = TState::Computing { gen };
                    self.push(self.clock + dur, Ev::OpDone { task, gen });
                    return Ok(());
                }
                Op::Sleep { secs } => {
                    self.tasks[task].op_gen += 1;
                    let gen = self.tasks[task].op_gen;
                    self.tasks[task].op_t0 = self.clock;
                    self.tasks[task].state = TState::Computing { gen };
                    self.push(self.clock + secs, Ev::OpDone { task, gen });
                    return Ok(());
                }
            }
        }
    }

    fn finish_task(&mut self, task: usize) -> Result<(), SimError> {
        self.tasks[task].state = TState::Done;
        self.tasks[task].t_end = self.clock;
        let spec = &self.prog.tasks[task];
        let rank = spec.rank;
        if spec.sms > 0 {
            self.sm_used[rank] -= spec.sms;
            // strict-FIFO grant to queued kernels that now fit
            while let Some(&next) = self.sm_queue[rank].front() {
                let need = self.prog.tasks[next].sms;
                if self.sm_used[rank] + need <= self.hw.sms {
                    self.sm_queue[rank].pop_front();
                    self.sm_used[rank] += need;
                    self.tasks[next].state = TState::Running;
                    self.tasks[next].t_start = self.clock;
                    self.advance(next)?;
                } else {
                    break;
                }
            }
        }
        Ok(())
    }

    /// Canonical flow key for the next transfer `task` launches.
    fn next_flow_key(&mut self, task: usize) -> (u32, u32) {
        let n = self.tasks[task].launches;
        self.tasks[task].launches += 1;
        (task as u32, n)
    }

    // -- chunk scheduler (ChunkSched::Srpf / Deadline) -----------------------

    /// Should this transfer divert through the ready queue? Only tagged
    /// pieces, only under a non-FIFO policy, and only inter-node routes
    /// (intra-node NVLink paths are never the contended resource the
    /// scheduler manages). Returns the metadata to order by.
    fn divert_meta(&self, chunk: Option<ChunkMeta>, src: usize, dst: usize) -> Option<ChunkMeta> {
        if !self.sched_on {
            return None;
        }
        let c = &self.sim.topo.cluster;
        if c.node_of(src) == c.node_of(dst) {
            return None;
        }
        chunk
    }

    /// Park a diverted chunk on its `(task, dst)` stream and try to
    /// issue. The stream queue is strict FIFO — the scheduler reorders
    /// across streams only — so per-destination delivery order (which
    /// signal/LL semantics rely on) is preserved by construction.
    fn enqueue_chunk(&mut self, task: usize, dst: usize, bytes: f64, meta: ChunkMeta, ctx: FlowCtx) {
        self.ready
            .entry((task as u32, dst))
            .or_default()
            .push_back(ReadyChunk { bytes, meta, ctx });
        self.pump();
    }

    /// Issue ready chunks in policy order until every remaining stream
    /// head is gated. A head is admissible when every link of its
    /// issue-time route has fewer than [`CHUNK_DEPTH`] flows in flight —
    /// the late-bound route means an adaptive rail pick sees the live
    /// occupancy at departure, and the depth gate keeps short streams
    /// from fair-sharing behind bulk backlogs. Work-conserving: a gated
    /// head never blocks a lower-priority admissible one. Deterministic:
    /// candidate order is a total sort ending in the unique
    /// `(task, launch-counter)` key, and re-evaluation happens at
    /// enqueue and at flow-batch completion only — both deterministic
    /// points of the event loop.
    fn pump(&mut self) {
        if !self.sched_on || self.ready.is_empty() {
            return;
        }
        let pol = self.chunk_sched;
        loop {
            // stream heads, policy-ordered; ctx.key is the stable
            // tie-break (deadline, then task, then launch counter)
            let mut heads: Vec<(ChunkMeta, (u32, u32), (u32, usize))> = self
                .ready
                .iter()
                .map(|(k, q)| {
                    let c = q.front().expect("empty stream queue left in ready map");
                    (c.meta, c.ctx.key, *k)
                })
                .collect();
            heads.sort_by(|a, b| match pol {
                ChunkSched::Srpf => a
                    .0
                    .remaining
                    .total_cmp(&b.0.remaining)
                    .then(a.0.deadline.cmp(&b.0.deadline))
                    .then(a.1.cmp(&b.1)),
                ChunkSched::Deadline => a
                    .0
                    .deadline
                    .cmp(&b.0.deadline)
                    .then(a.0.remaining.total_cmp(&b.0.remaining))
                    .then(a.1.cmp(&b.1)),
                ChunkSched::Fifo => unreachable!("pump under ChunkSched::Fifo"),
            });
            let mut issued = false;
            for &(_, _, key) in &heads {
                let rt = self.ready[&key]
                    .front()
                    .expect("stream head vanished")
                    .ctx
                    .rt
                    .expect("ready chunk without a retry route");
                let mut route = self.router.route_faulty(
                    rt.src,
                    rt.dst,
                    rt.tc,
                    &self.occ,
                    self.health.as_ref(),
                );
                if route
                    .links
                    .iter()
                    .any(|&l| self.occ.in_flight(l) >= CHUNK_DEPTH)
                {
                    continue; // gated; try the next-priority stream
                }
                route.latency = route.latency * rt.lat_mult + rt.lat_add;
                let q = self.ready.get_mut(&key).expect("stream queue vanished");
                let chunk = q.pop_front().expect("stream head vanished");
                if q.is_empty() {
                    self.ready.remove(&key);
                }
                // launch commits occupancy, so the next round's gate and
                // rail picks see this chunk in flight
                self.launch_flow(route, chunk.bytes, chunk.ctx);
                issued = true;
                break;
            }
            if !issued {
                return;
            }
        }
    }

    pub(crate) fn launch_flow(&mut self, mut route: Route, bytes: f64, ctx: FlowCtx) {
        let bytes = bytes.max(64.0); // minimum wire granule
        if self.role == Role::Shard
            && route
                .links
                .first()
                .is_some_and(|&l| self.sim.topo.is_fabric_link(l))
        {
            // Inter-node transfer: fabric links are solved by the shared
            // fabric runner. Hand the fully-resolved route (static
            // routing is pure, so resolving shard-side is exact) to the
            // coordinator; the fabric arms it at `t + latency`, which the
            // lookahead bound keeps at or beyond the barrier horizon.
            let t = self.clock;
            self.outbox.push(OutMsg::InterFlow {
                t,
                route,
                bytes,
                ctx: FlowCtx {
                    wire_bytes: bytes,
                    ..ctx
                },
            });
            return;
        }
        if let Some((rng, max)) = &mut self.jitter {
            // seeded latency noise, drawn in deterministic launch order
            route.latency += rng.f64() * *max;
        }
        // congestion feedback: the transfer holds plane capacity from the
        // moment it is posted (adaptive rail picks see bursts in flight
        // before their first arm)
        if self.track_occ {
            self.occ.commit(&route.links, bytes);
        }
        let pf = PendingFlow {
            links: route.links,
            bytes,
            ctx: FlowCtx { wire_bytes: bytes, ..ctx },
        };
        let idx = if let Some(i) = self.pending_free.pop() {
            self.pending[i] = Some(pf);
            i
        } else {
            self.pending.push(Some(pf));
            self.pending.len() - 1
        };
        self.push(self.clock + route.latency, Ev::FlowArm { pending: idx });
    }

    pub(crate) fn apply_signal(&mut self, sig: SigRef, op: SigOp, value: u64) -> Result<(), SimError> {
        match op {
            SigOp::Set => self.heap.signal_set(sig.rank, sig.idx, value),
            SigOp::Add => {
                self.heap.signal_add(sig.rank, sig.idx, value);
            }
        }
        // wake satisfied waiters (preserving FIFO order among them)
        let key = sig.rank * self.sig_pad + sig.idx;
        if !self.sig_waiters[key].is_empty() {
            let waiters = std::mem::take(&mut self.sig_waiters[key]);
            let mut still = Vec::new();
            for w in waiters {
                let TState::BlockedSignal { idx, cond, value } = self.tasks[w].state else {
                    continue;
                };
                if sig_met(self.heap.signal(sig.rank, idx), cond, value) {
                    self.tasks[w].state = TState::Running;
                    self.bump_pc_and_resume(w)?;
                } else {
                    still.push(w);
                }
            }
            if !still.is_empty() {
                // resumed tasks may have re-blocked on this same signal;
                // keep them (FIFO: previously blocked first)
                let slot = &mut self.sig_waiters[key];
                still.append(slot);
                *slot = still;
            }
        }
        Ok(())
    }

    fn apply_numeric(&mut self, n: &NumericOp) -> Result<(), SimError> {
        match n {
            NumericOp::None => {}
            NumericOp::Copy { src, dst } => self.heap.copy(*src, *dst),
            NumericOp::ReduceAdd {
                srcs,
                dst,
                zero_dst,
            } => {
                if *zero_dst {
                    self.heap.write(*dst, &vec![0.0; dst.len]);
                }
                for s in srcs {
                    self.heap.reduce_add(*s, *dst);
                }
            }
            NumericOp::Call { entry, args, outs } => {
                self.exec
                    .call(self.heap, entry, args, outs)
                    .map_err(|e| SimError::Executor {
                        entry: entry.clone(),
                        source: e,
                    })?;
            }
        }
        Ok(())
    }

    fn cost_time(&self, cost: &ComputeCost, sms: u32) -> f64 {
        match cost {
            ComputeCost::Gemm { flops, vendor } => {
                assert!(sms > 0, "GEMM in a 0-SM task");
                let rate = if *vendor {
                    self.hw.vendor_gemm_flops(sms)
                } else {
                    self.hw.triton_gemm_flops(sms)
                };
                flops / rate
            }
            ComputeCost::Reduce { bytes } => {
                assert!(sms > 0, "reduction in a 0-SM task");
                bytes / self.hw.reduce_bw(sms)
            }
            ComputeCost::MemBound { bytes } => bytes / self.hw.hbm_bw,
            ComputeCost::Fixed { secs } => *secs,
        }
    }
}

fn sig_met(cur: u64, cond: SigCond, value: u64) -> bool {
    match cond {
        SigCond::Eq => cur == value,
        SigCond::Ge => cur >= value,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, FabricSpec, FaultTarget, LinkFault};
    use crate::program::EngineClass;
    use crate::program::TaskBuilder;

    fn setup(nodes: usize, gpn: usize) -> (Topology, SymmetricHeap) {
        let cluster = ClusterSpec::h800(nodes, gpn);
        let topo = Topology::build(cluster);
        let heap = SymmetricHeap::new(cluster.world_size(), 64);
        (topo, heap)
    }

    #[test]
    fn put_moves_data_and_takes_time() {
        let (topo, mut heap) = setup(1, 2);
        let buf = heap.alloc("x", 8);
        heap.write(Slice::new(0, buf, 0, 4), &[1.0, 2.0, 3.0, 4.0]);

        let mut prog = Program::new();
        let mut t = TaskBuilder::new(0, "putter").engine(EngineClass::CopyEngine);
        t.op(Op::Put {
            src: Slice::new(0, buf, 0, 4),
            dst: Slice::new(1, buf, 4, 4),
            bytes: 170e9 * 1e-3, // exactly 1 ms at full NVLink egress
            signal: None,
            blocking: true,
            tc: Default::default(),
            chunk: None,
            label: "put",
        });
        prog.push(t.build());

        let sim = Sim::new(&topo);
        let rep = sim.run(&prog, &mut heap, &mut NoopExecutor).unwrap();
        assert_eq!(heap.read(Slice::new(1, buf, 4, 4)), &[1.0, 2.0, 3.0, 4.0]);
        // 1 ms transfer + 0.5us latency
        assert!((rep.makespan - (1e-3 + 0.5e-6)).abs() < 1e-9, "{}", rep.makespan);
    }

    #[test]
    fn put_signal_wakes_waiter() {
        let (topo, mut heap) = setup(1, 2);
        let buf = heap.alloc("x", 4);
        heap.write(Slice::new(0, buf, 0, 4), &[9.0; 4]);

        let mut prog = Program::new();
        let mut prod = TaskBuilder::new(0, "producer").engine(EngineClass::CopyEngine);
        prod.op(Op::Put {
            src: Slice::new(0, buf, 0, 4),
            dst: Slice::new(1, buf, 0, 4),
            bytes: 1024.0,
            signal: Some((SigRef { rank: 1, idx: 0 }, SigOp::Set, 1)),
            blocking: true,
            tc: Default::default(),
            chunk: None,
            label: "put",
        });
        prog.push(prod.build());

        let mut cons = TaskBuilder::new(1, "consumer").sms(4);
        cons.op(Op::WaitSignal {
            idx: 0,
            cond: SigCond::Eq,
            value: 1,
        });
        cons.op(Op::Compute {
            cost: ComputeCost::Fixed { secs: 1e-6 },
            numeric: NumericOp::None,
            label: "work",
        });
        prog.push(cons.build());

        let sim = Sim::new(&topo);
        let rep = sim.run(&prog, &mut heap, &mut NoopExecutor).unwrap();
        assert!(rep.makespan > 1e-6);
        assert_eq!(heap.signal(1, 0), 1);
        assert_eq!(heap.read(Slice::new(1, buf, 0, 4)), &[9.0; 4]);
    }

    #[test]
    fn deadlock_is_detected_not_hung() {
        let (topo, mut heap) = setup(1, 2);
        let mut prog = Program::new();
        let mut t = TaskBuilder::new(0, "stuck");
        t.op(Op::WaitSignal {
            idx: 5,
            cond: SigCond::Eq,
            value: 1,
        });
        prog.push(t.build());
        let sim = Sim::new(&topo);
        let err = sim.run(&prog, &mut heap, &mut NoopExecutor).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("deadlock"), "{msg}");
        assert!(msg.contains("stuck"), "{msg}");
    }

    #[test]
    fn barrier_synchronizes_all_ranks() {
        let (topo, mut heap) = setup(1, 4);
        let mut prog = Program::new();
        for r in 0..4 {
            let mut t = TaskBuilder::new(r, format!("t{r}"));
            // rank r sleeps r us then barriers
            t.op(Op::Sleep { secs: r as f64 * 1e-6 });
            t.op(Op::Barrier {
                scope: Scope::World,
                id: 0,
                expect: 4,
            });
            prog.push(t.build());
        }
        let sim = Sim::new(&topo);
        let rep = sim.run(&prog, &mut heap, &mut NoopExecutor).unwrap();
        // all tasks end together, after the slowest (3us) + barrier latency
        let ends: Vec<f64> = rep.task_spans.iter().map(|s| s.3).collect();
        for e in &ends {
            assert!((e - ends[0]).abs() < 1e-12);
        }
        assert!(ends[0] >= 3e-6);
    }

    #[test]
    fn sm_oversubscription_queues_fifo() {
        let (topo, mut heap) = setup(1, 1);
        let mut prog = Program::new();
        // two kernels of 100 SMs on a 132-SM device: must serialize
        for i in 0..2 {
            let mut t = TaskBuilder::new(0, format!("k{i}")).sms(100);
            t.op(Op::Compute {
                cost: ComputeCost::Fixed { secs: 1e-3 },
                numeric: NumericOp::None,
                label: "w",
            });
            prog.push(t.build());
        }
        let sim = Sim::new(&topo);
        let rep = sim.run(&prog, &mut heap, &mut NoopExecutor).unwrap();
        assert!((rep.makespan - 2e-3).abs() < 1e-9, "{}", rep.makespan);
    }

    #[test]
    fn sm_request_above_device_errors() {
        let (topo, mut heap) = setup(1, 1);
        let mut prog = Program::new();
        prog.push(TaskBuilder::new(0, "huge").sms(200).build());
        let sim = Sim::new(&topo);
        assert!(matches!(
            sim.run(&prog, &mut heap, &mut NoopExecutor),
            Err(SimError::SmOversubscribed { .. })
        ));
    }

    #[test]
    fn nbi_and_quiet() {
        let (topo, mut heap) = setup(1, 2);
        let buf = heap.alloc("x", 16);
        let mut prog = Program::new();
        let mut t = TaskBuilder::new(0, "nbi").engine(EngineClass::CopyEngine);
        for i in 0..4 {
            t.op(Op::Put {
                src: Slice::new(0, buf, i * 2, 2),
                dst: Slice::new(1, buf, i * 2, 2),
                bytes: 170e9 * 1e-4,
                signal: None,
                blocking: false,
                tc: Default::default(),
                chunk: None,
                label: "nbi_put",
            });
        }
        t.op(Op::Quiet);
        t.op(Op::SetSignal {
            sig: SigRef { rank: 0, idx: 0 },
            op: SigOp::Set,
            value: 1,
        });
        prog.push(t.build());
        let sim = Sim::new(&topo);
        let rep = sim.run(&prog, &mut heap, &mut NoopExecutor).unwrap();
        // 4 concurrent puts share the egress link: 4 * 1e-4 s total
        assert!((rep.makespan - (4e-4 + 0.5e-6)).abs() < 1e-8, "{}", rep.makespan);
        assert_eq!(heap.signal(0, 0), 1);
    }

    #[test]
    fn ll_put_wakes_ll_wait() {
        let (topo, mut heap) = setup(1, 2);
        let buf = heap.alloc("ll", 8);
        heap.write(Slice::new(0, buf, 0, 4), &[7.0; 4]);
        let mut prog = Program::new();
        let mut sender = TaskBuilder::new(0, "s").sms(1);
        sender.op(Op::LLPut {
            src: Slice::new(0, buf, 0, 4),
            dst: Slice::new(1, buf, 0, 4),
            bytes: 1024.0,
            tc: Default::default(),
            chunk: None,
        });
        prog.push(sender.build());
        let mut recv = TaskBuilder::new(1, "r").sms(1);
        recv.op(Op::LLWait {
            dst: Slice::new(1, buf, 0, 4),
        });
        prog.push(recv.build());
        let sim = Sim::new(&topo);
        sim.run(&prog, &mut heap, &mut NoopExecutor).unwrap();
        assert_eq!(heap.read(Slice::new(1, buf, 0, 4)), &[7.0; 4]);
    }

    #[test]
    fn multimem_broadcasts_within_node() {
        let (topo, mut heap) = setup(2, 4); // 2 nodes x 4
        let buf = heap.alloc("b", 4);
        heap.write(Slice::new(1, buf, 0, 4), &[3.0; 4]);
        let mut prog = Program::new();
        let mut t = TaskBuilder::new(1, "bcast").sms(1);
        t.op(Op::MultimemSt {
            src: Slice::new(1, buf, 0, 4),
            bytes: 1024.0,
            ll: false,
        });
        prog.push(t.build());
        let sim = Sim::new(&topo);
        let rep = sim.run(&prog, &mut heap, &mut NoopExecutor).unwrap();
        // node-0 peers got it
        for r in [0usize, 2, 3] {
            assert_eq!(heap.read(Slice::new(r, buf, 0, 4)), &[3.0; 4]);
        }
        // node-1 ranks did not
        for r in [4usize, 5, 6, 7] {
            assert_eq!(heap.read(Slice::new(r, buf, 0, 4)), &[0.0; 4]);
        }
        // multimem latency floor (1.5us)
        assert!(rep.makespan >= 1.5e-6);
    }

    #[test]
    fn trace_records_spans() {
        let (topo, mut heap) = setup(1, 1);
        let mut prog = Program::new();
        let mut t = TaskBuilder::new(0, "k").sms(1);
        t.op(Op::Compute {
            cost: ComputeCost::Fixed { secs: 5e-6 },
            numeric: NumericOp::None,
            label: "tile",
        });
        prog.push(t.build());
        let sim = Sim::with_config(
            &topo,
            SimConfig {
                numerics: true,
                trace: true,
            },
        );
        let rep = sim.run(&prog, &mut heap, &mut NoopExecutor).unwrap();
        assert_eq!(rep.op_spans.len(), 1);
        assert_eq!(rep.op_spans[0].label, "tile");
        assert!((rep.op_spans[0].t1 - rep.op_spans[0].t0 - 5e-6).abs() < 1e-12);
    }

    #[test]
    fn numeric_reduce_add() {
        let (topo, mut heap) = setup(1, 1);
        let buf = heap.alloc("x", 6);
        heap.write(Slice::new(0, buf, 0, 2), &[1.0, 2.0]);
        heap.write(Slice::new(0, buf, 2, 2), &[10.0, 20.0]);
        let mut prog = Program::new();
        let mut t = TaskBuilder::new(0, "red").sms(8);
        t.op(Op::Compute {
            cost: ComputeCost::Reduce { bytes: 1024.0 },
            numeric: NumericOp::ReduceAdd {
                srcs: vec![Slice::new(0, buf, 0, 2), Slice::new(0, buf, 2, 2)],
                dst: Slice::new(0, buf, 4, 2),
                zero_dst: true,
            },
            label: "reduce",
        });
        prog.push(t.build());
        let sim = Sim::new(&topo);
        sim.run(&prog, &mut heap, &mut NoopExecutor).unwrap();
        assert_eq!(heap.read(Slice::new(0, buf, 4, 2)), &[11.0, 22.0]);
    }

    #[test]
    fn deterministic_makespan() {
        // same program twice -> identical report
        let run_once = || {
            let (topo, mut heap) = setup(1, 4);
            let buf = heap.alloc("x", 64);
            let mut prog = Program::new();
            for r in 0..4usize {
                let mut t =
                    TaskBuilder::new(r, format!("t{r}")).engine(EngineClass::CopyEngine);
                for p in 0..4usize {
                    if p != r {
                        t.op(Op::Put {
                            src: Slice::new(r, buf, r * 16, 16),
                            dst: Slice::new(p, buf, r * 16, 16),
                            bytes: 4096.0,
                            signal: None,
                            blocking: false,
                            tc: Default::default(),
                            chunk: None,
                            label: "p",
                        });
                    }
                }
                t.op(Op::Quiet);
                prog.push(t.build());
            }
            let sim = Sim::new(&topo);
            sim.run(&prog, &mut heap, &mut NoopExecutor).unwrap().makespan
        };
        assert_eq!(run_once(), run_once());
    }

    // -- fault injection -----------------------------------------------------

    /// 2 nodes x 2 GPUs on a blocking 2-rail fabric (NIC/leaf/spine links
    /// exist, so fault targets resolve).
    fn railed(policy: RailPolicy) -> (Topology, SymmetricHeap) {
        let cluster = ClusterSpec::h800(2, 2)
            .with_fabric(FabricSpec::rail_optimized(2, 2.0).with_rail_policy(policy));
        let topo = Topology::build(cluster);
        let heap = SymmetricHeap::new(cluster.world_size(), 64);
        (topo, heap)
    }

    /// One pinned-rail inter-node put big enough to still be in flight
    /// when a mid-transfer fault lands.
    fn cross_node_put(heap: &mut SymmetricHeap, bytes: f64) -> Program {
        let buf = heap.alloc("x", 8);
        heap.write(Slice::new(0, buf, 0, 4), &[1.0, 2.0, 3.0, 4.0]);
        let mut prog = Program::new();
        let mut t = TaskBuilder::new(0, "putter").engine(EngineClass::CopyEngine);
        t.op(Op::Put {
            src: Slice::new(0, buf, 0, 4),
            dst: Slice::new(2, buf, 4, 4),
            bytes,
            signal: None,
            blocking: true,
            tc: TrafficClass::Rail(0),
            chunk: None,
            label: "put",
        });
        prog.push(t.build());
        prog
    }

    #[test]
    fn empty_fault_plan_is_bit_identical() {
        let run = |faulted: bool| {
            let (topo, mut heap) = railed(RailPolicy::Static);
            let buf = heap.alloc("x", 64);
            let mut prog = Program::new();
            for r in 0..4usize {
                let mut t =
                    TaskBuilder::new(r, format!("t{r}")).engine(EngineClass::CopyEngine);
                for p in 0..4usize {
                    if p != r {
                        t.op(Op::Put {
                            src: Slice::new(r, buf, r * 16, 16),
                            dst: Slice::new(p, buf, r * 16, 16),
                            bytes: (1u64 << 20) as f64,
                            signal: None,
                            blocking: false,
                            tc: Default::default(),
                            chunk: None,
                            label: "p",
                        });
                    }
                }
                t.op(Op::Quiet);
                prog.push(t.build());
            }
            let sim = if faulted {
                Sim::new(&topo).with_faults(FaultPlan::default())
            } else {
                Sim::new(&topo)
            };
            let rep = sim.run(&prog, &mut heap, &mut NoopExecutor).unwrap();
            (rep.makespan.to_bits(), rep.events, rep.flows, rep.ledger)
        };
        assert_eq!(run(false), run(true));
        assert_eq!(run(true).3, FaultLedger::default());
    }

    #[test]
    fn flap_kills_retries_and_recovers() {
        // a 500us NIC flap lands mid-transfer on the pinned rail: the
        // flow is killed, retries back off (the only candidate path is
        // the pinned dead rail), and the relaunch after recovery still
        // delivers the data
        let run = || {
            let (topo, mut heap) = railed(RailPolicy::Static);
            let prog = cross_node_put(&mut heap, 22.5e9 * 1e-3);
            let plan = FaultPlan {
                link_faults: vec![LinkFault::flap(
                    FaultTarget::Nic { rank: 0, rail: 0 },
                    100e-6,
                    500e-6,
                )],
                ..FaultPlan::default()
            };
            let rep = Sim::new(&topo)
                .with_faults(plan)
                .run(&prog, &mut heap, &mut NoopExecutor)
                .unwrap();
            let buf = crate::mem::BufId(0);
            assert_eq!(heap.read(Slice::new(2, buf, 4, 4)), &[1.0, 2.0, 3.0, 4.0]);
            rep
        };
        let rep = run();
        assert_eq!(rep.ledger.flows_killed, 1);
        assert!(rep.ledger.retries >= 2, "expected backoff retries: {:?}", rep.ledger);
        assert_eq!(rep.ledger.retries_exhausted, 0);
        assert_eq!(rep.ledger.rerouted_bytes, 0.0, "pinned rail cannot reroute");
        // can't finish before the flap clears at 600us
        assert!(rep.makespan > 600e-6, "{}", rep.makespan);
        // replay determinism: same plan, same timeline, same ledger
        let rep2 = run();
        assert_eq!(rep.makespan.to_bits(), rep2.makespan.to_bits());
        assert_eq!(rep.ledger, rep2.ledger);
        assert_eq!(rep.events, rep2.events);
    }

    #[test]
    fn adaptive_retry_reroutes_to_surviving_rail() {
        let (topo, mut heap) = railed(RailPolicy::Adaptive);
        let buf = heap.alloc("x", 8);
        let mut prog = Program::new();
        // background transfer pins occupancy on rail 1 so the victim's
        // Auto route resolves to rail 0
        let mut bg = TaskBuilder::new(1, "bg").engine(EngineClass::CopyEngine);
        bg.op(Op::Put {
            src: Slice::new(1, buf, 0, 4),
            dst: Slice::new(3, buf, 0, 4),
            bytes: 22.5e9 * 2e-3,
            signal: None,
            blocking: true,
            tc: TrafficClass::Rail(1),
            chunk: None,
            label: "bg",
        });
        prog.push(bg.build());
        let mut t = TaskBuilder::new(0, "victim").engine(EngineClass::CopyEngine);
        t.op(Op::Put {
            src: Slice::new(0, buf, 0, 4),
            dst: Slice::new(2, buf, 4, 4),
            bytes: 22.5e9 * 1e-3,
            signal: None,
            blocking: true,
            tc: TrafficClass::Auto,
            chunk: None,
            label: "put",
        });
        prog.push(t.build());
        let plan = FaultPlan {
            link_faults: vec![LinkFault::flap(
                FaultTarget::Nic { rank: 0, rail: 0 },
                100e-6,
                50e-3, // dead long past the end of the run
            )],
            ..FaultPlan::default()
        };
        let rep = Sim::new(&topo)
            .with_faults(plan)
            .run(&prog, &mut heap, &mut NoopExecutor)
            .unwrap();
        assert_eq!(rep.ledger.flows_killed, 1);
        assert!(
            rep.ledger.rerouted_bytes > 0.0,
            "adaptive retry should land on the surviving rail: {:?}",
            rep.ledger
        );
        assert_eq!(rep.ledger.retries, 1, "first retry already finds rail 1");
        // the victim escaped the flap: done long before it clears
        assert!(rep.makespan < 50e-3, "{}", rep.makespan);
    }

    #[test]
    fn degraded_link_slows_transfer_proportionally() {
        let clean = {
            let (topo, mut heap) = railed(RailPolicy::Static);
            let prog = cross_node_put(&mut heap, 22.5e9 * 1e-3);
            Sim::new(&topo)
                .run(&prog, &mut heap, &mut NoopExecutor)
                .unwrap()
                .makespan
        };
        let degraded = {
            let (topo, mut heap) = railed(RailPolicy::Static);
            let prog = cross_node_put(&mut heap, 22.5e9 * 1e-3);
            let plan = FaultPlan::parse("deg,nic,0,0,0,1.0,0.5").unwrap();
            Sim::new(&topo)
                .with_faults(plan)
                .run(&prog, &mut heap, &mut NoopExecutor)
                .unwrap()
                .makespan
        };
        // NIC at half capacity for the whole run: ~2x the wire time
        assert!(
            degraded > 1.5 * clean && degraded < 2.5 * clean,
            "clean {clean}, degraded {degraded}"
        );
    }

    #[test]
    fn straggler_inflates_compute() {
        let (topo, mut heap) = setup(1, 2);
        let mut prog = Program::new();
        for r in 0..2 {
            let mut t = TaskBuilder::new(r, format!("k{r}")).sms(4);
            t.op(Op::Compute {
                cost: ComputeCost::Fixed { secs: 1e-3 },
                numeric: NumericOp::None,
                label: "w",
            });
            prog.push(t.build());
        }
        let plan = FaultPlan::parse("strag,0,2.0").unwrap();
        let rep = Sim::new(&topo)
            .with_faults(plan)
            .run(&prog, &mut heap, &mut NoopExecutor)
            .unwrap();
        let span_of = |r: usize| rep.task_spans.iter().find(|s| s.1 == r).unwrap().3;
        assert!((span_of(0) - 2e-3).abs() < 1e-9, "{}", span_of(0));
        assert!((span_of(1) - 1e-3).abs() < 1e-9, "{}", span_of(1));
    }

    #[test]
    fn watchdog_turns_hang_into_structured_error() {
        let (topo, mut heap) = setup(1, 2);
        let mut prog = Program::new();
        let mut t = TaskBuilder::new(0, "stuck");
        t.op(Op::WaitSignal {
            idx: 3,
            cond: SigCond::Eq,
            value: 1,
        });
        prog.push(t.build());
        let plan = FaultPlan {
            lt_timeout: 250e-6,
            ..FaultPlan::default()
        };
        let err = Sim::new(&topo)
            .with_faults(plan)
            .run(&prog, &mut heap, &mut NoopExecutor)
            .unwrap_err();
        match err {
            SimError::WatchdogTimeout { task, rank, at, .. } => {
                assert_eq!(task, "stuck");
                assert_eq!(rank, 0);
                assert!((at - 250e-6).abs() < 1e-12, "{at}");
            }
            other => panic!("expected watchdog, got {other}"),
        }
    }

    #[test]
    fn jitter_is_seeded_and_replayable() {
        let run = |seed: u64| {
            let (topo, mut heap) = railed(RailPolicy::Static);
            let prog = cross_node_put(&mut heap, 22.5e9 * 1e-4);
            let plan = FaultPlan::parse(&format!("jitter,{seed},5e-6")).unwrap();
            Sim::new(&topo)
                .with_faults(plan)
                .run(&prog, &mut heap, &mut NoopExecutor)
                .unwrap()
                .makespan
        };
        let clean = {
            let (topo, mut heap) = railed(RailPolicy::Static);
            let prog = cross_node_put(&mut heap, 22.5e9 * 1e-4);
            Sim::new(&topo)
                .run(&prog, &mut heap, &mut NoopExecutor)
                .unwrap()
                .makespan
        };
        assert_eq!(run(7).to_bits(), run(7).to_bits(), "same seed, same timeline");
        assert!(run(7) >= clean, "jitter only ever adds latency");
    }
}
