//! Symmetric memory (§2.1).
//!
//! Every rank allocates buffers of identical sizes in the same order, so a
//! `BufId` names "the same" buffer on every rank — exactly the OpenSHMEM /
//! NVSHMEM symmetric-heap contract. There is **no** unified address space:
//! remote data is only reachable through the `shmem` primitives, which the
//! DES engine turns into flows + real `memcpy`s between rank shards.
//!
//! Storage is always `f32` (numerics); the *timing* byte-size of a transfer
//! is `elements * workload-dtype-size`, so bf16 workloads are timed as
//! 2-byte payloads while correctness is checked in f32 (DESIGN.md §2).
//!
//! Each rank also owns a signal pad: a `u64` array in symmetric memory
//! manipulated only through signal ops (§2.1 "Signal Exchange").

/// Identifies a symmetric buffer (same id on every rank).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BufId(pub usize);

/// A contiguous element range of one rank's copy of a symmetric buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Slice {
    pub rank: usize,
    pub buf: BufId,
    /// Element offset.
    pub off: usize,
    /// Element count.
    pub len: usize,
}

impl Slice {
    pub fn new(rank: usize, buf: BufId, off: usize, len: usize) -> Self {
        Slice { rank, buf, off, len }
    }

    /// The whole buffer `buf` on `rank` (length resolved by the heap).
    pub fn sub(&self, off: usize, len: usize) -> Slice {
        assert!(off + len <= self.len, "sub-slice out of range");
        Slice {
            rank: self.rank,
            buf: self.buf,
            off: self.off + off,
            len,
        }
    }

    /// Same range viewed on another rank's copy (symmetric addressing —
    /// the analogue of `remote_ptr`).
    pub fn on_rank(&self, rank: usize) -> Slice {
        Slice { rank, ..*self }
    }
}

/// The symmetric heap for a whole simulated world.
pub struct SymmetricHeap {
    world: usize,
    /// `data[rank][buf]` -> storage.
    data: Vec<Vec<Vec<f32>>>,
    /// Buffer names for diagnostics.
    names: Vec<String>,
    /// `signals[rank][idx]`.
    signals: Vec<Vec<u64>>,
}

impl SymmetricHeap {
    pub fn new(world: usize, signal_pad: usize) -> Self {
        SymmetricHeap {
            world,
            data: (0..world).map(|_| Vec::new()).collect(),
            names: Vec::new(),
            signals: (0..world).map(|_| vec![0u64; signal_pad]).collect(),
        }
    }

    pub fn world(&self) -> usize {
        self.world
    }

    pub fn signal_pad(&self) -> usize {
        self.signals[0].len()
    }

    /// Collective allocation: every rank gets a zero-filled buffer of
    /// `len` elements; returns the symmetric id.
    pub fn alloc(&mut self, name: &str, len: usize) -> BufId {
        for r in 0..self.world {
            self.data[r].push(vec![0.0f32; len]);
        }
        self.names.push(name.to_string());
        BufId(self.names.len() - 1)
    }

    pub fn buf_len(&self, buf: BufId) -> usize {
        self.data[0][buf.0].len()
    }

    pub fn buf_name(&self, buf: BufId) -> &str {
        &self.names[buf.0]
    }

    /// Read-only view of one rank's slice.
    pub fn read(&self, s: Slice) -> &[f32] {
        &self.data[s.rank][s.buf.0][s.off..s.off + s.len]
    }

    /// Overwrite one rank's slice.
    pub fn write(&mut self, s: Slice, values: &[f32]) {
        assert_eq!(values.len(), s.len, "write length mismatch");
        self.data[s.rank][s.buf.0][s.off..s.off + s.len].copy_from_slice(values);
    }

    /// memcpy `src -> dst` across (or within) ranks. This is the numeric
    /// payload of every put/get/copy op.
    pub fn copy(&mut self, src: Slice, dst: Slice) {
        assert_eq!(src.len, dst.len, "copy length mismatch");
        if src.rank == dst.rank && src.buf == dst.buf {
            // same buffer: honour overlap via a temp
            let tmp: Vec<f32> = self.read(src).to_vec();
            self.write(dst, &tmp);
            return;
        }
        // split borrow: ranks or buffers differ
        let tmp: Vec<f32> = self.read(src).to_vec();
        self.write(dst, &tmp);
    }

    /// Accumulate `src` into `dst` (`dst += src`) — the reduction payload.
    pub fn reduce_add(&mut self, src: Slice, dst: Slice) {
        assert_eq!(src.len, dst.len, "reduce length mismatch");
        let tmp: Vec<f32> = self.read(src).to_vec();
        let d = &mut self.data[dst.rank][dst.buf.0][dst.off..dst.off + dst.len];
        for (o, v) in d.iter_mut().zip(tmp.iter()) {
            *o += v;
        }
    }

    // ---- signals ---------------------------------------------------------
    //
    // The signal pad auto-grows: programs compute signal indices from
    // geometry (channels x segments etc.) and sizing every call site is
    // error-prone. Growth is deterministic and zero-initialized.

    fn grow(&mut self, idx: usize) {
        if idx >= self.signals[0].len() {
            for pad in &mut self.signals {
                pad.resize(idx + 1, 0);
            }
        }
    }

    pub fn signal(&self, rank: usize, idx: usize) -> u64 {
        self.signals[rank].get(idx).copied().unwrap_or(0)
    }

    pub fn signal_set(&mut self, rank: usize, idx: usize, v: u64) {
        self.grow(idx);
        self.signals[rank][idx] = v;
    }

    pub fn signal_add(&mut self, rank: usize, idx: usize, v: u64) -> u64 {
        self.grow(idx);
        self.signals[rank][idx] += v;
        self.signals[rank][idx]
    }

    /// Atomic compare-and-swap on a signal; returns the previous value.
    pub fn signal_cas(&mut self, rank: usize, idx: usize, expect: u64, new: u64) -> u64 {
        self.grow(idx);
        let cur = self.signals[rank][idx];
        if cur == expect {
            self.signals[rank][idx] = new;
        }
        cur
    }

    /// Reset every signal on every rank to zero — required between
    /// autotuner trials (§3.8: "we need to reset all the signals every
    /// time we profile the generated code").
    pub fn reset_signals(&mut self) {
        for pad in &mut self.signals {
            pad.iter_mut().for_each(|s| *s = 0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_is_symmetric() {
        let mut h = SymmetricHeap::new(4, 8);
        let b = h.alloc("t", 16);
        for r in 0..4 {
            assert_eq!(h.read(Slice::new(r, b, 0, 16)).len(), 16);
        }
        assert_eq!(h.buf_name(b), "t");
        assert_eq!(h.buf_len(b), 16);
    }

    #[test]
    fn copy_moves_data_between_ranks() {
        let mut h = SymmetricHeap::new(2, 4);
        let b = h.alloc("x", 4);
        h.write(Slice::new(0, b, 0, 4), &[1.0, 2.0, 3.0, 4.0]);
        h.copy(Slice::new(0, b, 1, 2), Slice::new(1, b, 0, 2));
        assert_eq!(h.read(Slice::new(1, b, 0, 4)), &[2.0, 3.0, 0.0, 0.0]);
    }

    #[test]
    fn overlapping_same_buffer_copy_is_safe() {
        let mut h = SymmetricHeap::new(1, 1);
        let b = h.alloc("x", 4);
        h.write(Slice::new(0, b, 0, 4), &[1.0, 2.0, 3.0, 4.0]);
        h.copy(Slice::new(0, b, 0, 2), Slice::new(0, b, 1, 2));
        assert_eq!(h.read(Slice::new(0, b, 0, 4)), &[1.0, 1.0, 2.0, 4.0]);
    }

    #[test]
    fn reduce_add_accumulates() {
        let mut h = SymmetricHeap::new(2, 1);
        let b = h.alloc("x", 2);
        h.write(Slice::new(0, b, 0, 2), &[1.0, 2.0]);
        h.write(Slice::new(1, b, 0, 2), &[10.0, 20.0]);
        h.reduce_add(Slice::new(0, b, 0, 2), Slice::new(1, b, 0, 2));
        assert_eq!(h.read(Slice::new(1, b, 0, 2)), &[11.0, 22.0]);
    }

    #[test]
    fn signal_ops() {
        let mut h = SymmetricHeap::new(2, 4);
        h.signal_set(1, 2, 7);
        assert_eq!(h.signal(1, 2), 7);
        assert_eq!(h.signal_add(1, 2, 3), 10);
        assert_eq!(h.signal_cas(1, 2, 10, 1), 10);
        assert_eq!(h.signal(1, 2), 1);
        assert_eq!(h.signal_cas(1, 2, 10, 5), 1); // no-op, expect mismatch
        assert_eq!(h.signal(1, 2), 1);
        h.reset_signals();
        assert_eq!(h.signal(1, 2), 0);
    }

    #[test]
    fn slice_sub_and_on_rank() {
        let s = Slice::new(0, BufId(3), 10, 20);
        let t = s.sub(5, 10);
        assert_eq!((t.off, t.len), (15, 10));
        let u = t.on_rank(2);
        assert_eq!(u.rank, 2);
        assert_eq!((u.off, u.len, u.buf), (15, 10, BufId(3)));
    }

    #[test]
    #[should_panic]
    fn sub_out_of_range_panics() {
        Slice::new(0, BufId(0), 0, 4).sub(2, 4);
    }
}
