//! Report layer: speedup tables (the figures' rows), Fig.-1-style geomean
//! summaries, ASCII timelines and chrome-trace export.

use std::fmt::Write as _;

use crate::sim::{FaultLedger, OpSpan, RecoveryLedger, SimReport};
use crate::util::stats::{fmt_time, geomean};
use crate::util::Table;

/// One workload's results: ours vs named baselines (latencies in s).
#[derive(Debug, Clone)]
pub struct SpeedupRow {
    pub workload: String,
    pub ours: f64,
    pub baselines: Vec<(String, f64)>,
}

impl SpeedupRow {
    pub fn speedup_vs(&self, name: &str) -> Option<f64> {
        self.baselines
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, t)| t / self.ours)
    }
}

/// A figure/table reproduction: rows + printing.
#[derive(Debug, Clone, Default)]
pub struct FigureReport {
    pub title: String,
    pub rows: Vec<SpeedupRow>,
}

impl FigureReport {
    pub fn new(title: &str) -> Self {
        FigureReport {
            title: title.to_string(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: SpeedupRow) {
        self.rows.push(row);
    }

    /// Baseline names in first-row order.
    pub fn baseline_names(&self) -> Vec<String> {
        self.rows
            .first()
            .map(|r| r.baselines.iter().map(|(n, _)| n.clone()).collect())
            .unwrap_or_default()
    }

    /// Geomean speedup vs one baseline across rows (the paper's "average
    /// speedup").
    pub fn avg_speedup(&self, baseline: &str) -> f64 {
        let s: Vec<f64> = self
            .rows
            .iter()
            .filter_map(|r| r.speedup_vs(baseline))
            .collect();
        geomean(&s)
    }

    /// Render as an aligned table with per-baseline speedup columns.
    pub fn render(&self) -> String {
        let names = self.baseline_names();
        let mut header = vec!["workload".to_string(), "ours".to_string()];
        for n in &names {
            header.push(n.clone());
            header.push(format!("vs {n}"));
        }
        let mut t = Table::new(&self.title).header(&header);
        for row in &self.rows {
            let mut cells = vec![row.workload.clone(), fmt_time(row.ours)];
            for n in &names {
                let b = row.baselines.iter().find(|(bn, _)| bn == n);
                match b {
                    Some((_, lat)) => {
                        cells.push(fmt_time(*lat));
                        cells.push(format!("{:.2}x", lat / row.ours));
                    }
                    None => {
                        cells.push("-".into());
                        cells.push("-".into());
                    }
                }
            }
            t.row(&cells);
        }
        let mut out = t.render();
        for n in &names {
            let _ = writeln!(out, "avg speedup vs {n}: {:.2}x", self.avg_speedup(n));
        }
        out
    }
}

/// Fig. 1: one bar per workload family — geomean speedup vs the
/// PyTorch+NCCL/RCCL baseline.
pub fn fig1_summary(reports: &[(&str, f64)]) -> String {
    let mut t = Table::new("Fig. 1: Average Speedup of Triton-distributed to Baselines")
        .header(&["workload", "avg speedup", "bar"]);
    for (name, s) in reports {
        let bar = "#".repeat(((s.log10() * 20.0).max(1.0)) as usize);
        t.row(&[name.to_string(), format!("{s:.2}x"), bar]);
    }
    t.render()
}

// ---------------------------------------------------------------------------
// engine-perf trajectory (EXPERIMENTS.md §Perf)
// ---------------------------------------------------------------------------

/// Fault-scenario annotations riding one engine-perf record: what the
/// recovery machinery did and how much the faults cost in virtual time.
#[derive(Debug, Clone)]
pub struct FaultBenchInfo {
    pub ledger: FaultLedger,
    /// Faulted makespan / clean makespan of the same workload.
    pub slowdown: f64,
}

/// Elastic-recovery annotations riding one engine-perf record: the
/// controller's detect → drain → re-plan → resume timeline plus the
/// degraded goodput after the survivor re-plan.
#[derive(Debug, Clone)]
pub struct RecoveryBenchInfo {
    pub ledger: RecoveryLedger,
    /// Fraction of the originally-owed (token, expert-slot) pairs the
    /// survivor plan delivered (`tokens_delivered / owed`).
    pub goodput: f64,
}

/// Serving-scenario annotations riding one engine-perf record: the
/// latency distribution and throughput of a trace-driven serving run
/// (`coordinator::serve`). Plain scalars extracted from the
/// `ServingReport` by the caller, so the report layer stays below the
/// coordinator layer.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServingBenchInfo {
    /// Requests in the materialized trace.
    pub requests: u64,
    /// Requests that produced their full output.
    pub completed: u64,
    /// Requests dropped with a reason (`requests == completed + dropped`).
    pub dropped: u64,
    /// Requests that restarted after losing KV-cache to a dead rank
    /// (each still ends in `completed` or `dropped`).
    pub rerouted: u64,
    /// Median time-to-first-token (s).
    pub p50_ttft_s: f64,
    /// 99th-percentile time-to-first-token (s).
    pub p99_ttft_s: f64,
    /// Median time-per-output-token (s).
    pub p50_tpot_s: f64,
    /// 99th-percentile time-per-output-token (s).
    pub p99_tpot_s: f64,
    /// Completed output tokens per virtual second.
    pub goodput_tokens_per_s: f64,
    /// Virtual time from first arrival to last completion (s).
    pub makespan_s: f64,
    /// Peak admission-queue depth over the run.
    pub max_queue_depth: u64,
    /// Mid-serving rank deaths survived by the elastic controller.
    pub recoveries: u32,
}

/// Issue-scheduler annotations riding one engine-perf record: the
/// virtual makespan of the same pinned mixed-traffic workload under each
/// `ChunkSched` policy, so the contention-aware win is tracked across
/// PRs next to the wall-clock numbers (the strict win itself is pinned
/// by `tests/sched_equivalence.rs`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SchedBenchInfo {
    /// Makespan (s) under `ChunkSched::Fifo` — today's issue order.
    pub fifo_s: f64,
    /// Makespan (s) under `ChunkSched::Srpf`.
    pub srpf_s: f64,
    /// Makespan (s) under `ChunkSched::Deadline`.
    pub deadline_s: f64,
}

/// One wall-clock engine measurement: a scenario of `perf_engine` (events
/// processed, median elapsed seconds), optionally with its fault ledger.
#[derive(Debug, Clone)]
pub struct EngineBenchRecord {
    pub scenario: String,
    pub events: u64,
    pub median_wall_s: f64,
    /// Engine-internal wall clock of the representative run
    /// (`SimReport::wall_ns`); 0 when the harness-level median is the
    /// only timing captured.
    pub sim_wall_ns: u64,
    /// `threads -> events/s` sweep for sharded-engine scenarios (empty
    /// for single-thread scenarios). The virtual-time report is
    /// bit-identical across the sweep — only the wall clock moves.
    pub threads: Vec<(usize, f64)>,
    /// `Some` for degraded-fabric scenarios.
    pub fault: Option<FaultBenchInfo>,
    /// `Some` for scenarios that survived a permanent death.
    pub recovery: Option<RecoveryBenchInfo>,
    /// `Some` for trace-driven serving scenarios.
    pub serving: Option<ServingBenchInfo>,
    /// `Some` for scenarios that sweep the chunk issue scheduler.
    pub sched: Option<SchedBenchInfo>,
}

impl EngineBenchRecord {
    /// Same guard as `bench::WallStat::per_sec` so the printed and
    /// JSON-recorded throughput always agree.
    pub fn events_per_s(&self) -> f64 {
        self.events as f64 / self.median_wall_s.max(1e-12)
    }
}

/// Render engine-perf records as a machine-readable JSON document
/// (`BENCH_engine.json`): scenario -> {events, median_wall_s,
/// events_per_s}. Tracked across PRs to catch engine regressions.
pub fn engine_bench_json(records: &[EngineBenchRecord]) -> String {
    use crate::util::json::Json;
    let mut scenarios = std::collections::BTreeMap::new();
    for r in records {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("events".into(), Json::Num(r.events as f64));
        obj.insert("median_wall_s".into(), Json::Num(r.median_wall_s));
        obj.insert("events_per_s".into(), Json::Num(r.events_per_s()));
        if r.sim_wall_ns > 0 {
            obj.insert("wall_ns".into(), Json::Num(r.sim_wall_ns as f64));
        }
        if !r.threads.is_empty() {
            let mut to = std::collections::BTreeMap::new();
            for &(n, eps) in &r.threads {
                // zero-pad so string-keyed maps sort numerically
                to.insert(format!("{n:02}"), Json::Num(eps));
            }
            obj.insert("threads_events_per_s".into(), Json::Obj(to));
        }
        if let Some(fi) = &r.fault {
            let mut fo = std::collections::BTreeMap::new();
            fo.insert("faults_applied".into(), Json::Num(fi.ledger.faults_applied as f64));
            fo.insert("flows_killed".into(), Json::Num(fi.ledger.flows_killed as f64));
            fo.insert("retries".into(), Json::Num(fi.ledger.retries as f64));
            fo.insert(
                "retries_exhausted".into(),
                Json::Num(fi.ledger.retries_exhausted as f64),
            );
            fo.insert("rerouted_bytes".into(), Json::Num(fi.ledger.rerouted_bytes));
            fo.insert("slowdown".into(), Json::Num(fi.slowdown));
            obj.insert("fault".into(), Json::Obj(fo));
        }
        if let Some(ri) = &r.recovery {
            let l = &ri.ledger;
            let mut ro = std::collections::BTreeMap::new();
            ro.insert(
                "dead_ranks".into(),
                Json::Arr(l.dead_ranks.iter().map(|&d| Json::Num(d as f64)).collect()),
            );
            ro.insert("detect_latency_s".into(), Json::Num(l.detected_at - l.died_at));
            ro.insert("drain_s".into(), Json::Num(l.drained_at - l.detected_at));
            ro.insert("replan_s".into(), Json::Num(l.replanned_at - l.drained_at));
            ro.insert("resumed_at_s".into(), Json::Num(l.resumed_at));
            ro.insert("via".into(), Json::Str(l.via.clone()));
            ro.insert("flows_drained".into(), Json::Num(l.flows_drained as f64));
            ro.insert("tokens_delivered".into(), Json::Num(l.tokens_delivered as f64));
            ro.insert("tokens_rerouted".into(), Json::Num(l.tokens_rerouted as f64));
            ro.insert("tokens_dropped".into(), Json::Num(l.tokens_dropped as f64));
            ro.insert("epochs".into(), Json::Num(l.epochs as f64));
            ro.insert("goodput".into(), Json::Num(ri.goodput));
            obj.insert("recovery".into(), Json::Obj(ro));
        }
        if let Some(si) = &r.serving {
            let mut so = std::collections::BTreeMap::new();
            so.insert("requests".into(), Json::Num(si.requests as f64));
            so.insert("completed".into(), Json::Num(si.completed as f64));
            so.insert("dropped".into(), Json::Num(si.dropped as f64));
            so.insert("rerouted".into(), Json::Num(si.rerouted as f64));
            so.insert("p50_ttft_s".into(), Json::Num(si.p50_ttft_s));
            so.insert("p99_ttft_s".into(), Json::Num(si.p99_ttft_s));
            so.insert("p50_tpot_s".into(), Json::Num(si.p50_tpot_s));
            so.insert("p99_tpot_s".into(), Json::Num(si.p99_tpot_s));
            so.insert(
                "goodput_tokens_per_s".into(),
                Json::Num(si.goodput_tokens_per_s),
            );
            so.insert("makespan_s".into(), Json::Num(si.makespan_s));
            so.insert("max_queue_depth".into(), Json::Num(si.max_queue_depth as f64));
            so.insert("recoveries".into(), Json::Num(si.recoveries as f64));
            obj.insert("serving".into(), Json::Obj(so));
        }
        if let Some(sc) = &r.sched {
            let mut sco = std::collections::BTreeMap::new();
            sco.insert("fifo_makespan_s".into(), Json::Num(sc.fifo_s));
            sco.insert("srpf_makespan_s".into(), Json::Num(sc.srpf_s));
            sco.insert("deadline_makespan_s".into(), Json::Num(sc.deadline_s));
            sco.insert("srpf_speedup".into(), Json::Num(sc.fifo_s / sc.srpf_s.max(1e-300)));
            sco.insert(
                "deadline_speedup".into(),
                Json::Num(sc.fifo_s / sc.deadline_s.max(1e-300)),
            );
            obj.insert("sched".into(), Json::Obj(sco));
        }
        scenarios.insert(r.scenario.clone(), Json::Obj(obj));
    }
    let mut root = std::collections::BTreeMap::new();
    root.insert("bench".into(), Json::Str("perf_engine".into()));
    root.insert("unit".into(), Json::Str("events_per_s".into()));
    root.insert("scenarios".into(), Json::Obj(scenarios));
    Json::Obj(root).to_string()
}

/// One-line human rendering of a fault ledger (CLI fault summaries).
pub fn fault_ledger_line(l: &FaultLedger) -> String {
    format!(
        "faults: {} applied, {} flows killed, {} retries ({} exhausted), {:.2} MB rerouted",
        l.faults_applied,
        l.flows_killed,
        l.retries,
        l.retries_exhausted,
        l.rerouted_bytes / 1e6
    )
}

/// One-line human rendering of a recovery ledger (CLI `--recover`
/// summaries): timeline deltas plus the exact token accounting.
pub fn recovery_line(l: &RecoveryLedger) -> String {
    format!(
        "recovery: rank(s) {:?} died at {}, detected via {} after {}, \
         drain {}, re-plan {}, resumed at {}; tokens {} delivered \
         ({} rerouted), {} dropped; {} epoch(s)",
        l.dead_ranks,
        fmt_time(l.died_at),
        l.via,
        fmt_time(l.detected_at - l.died_at),
        fmt_time(l.drained_at - l.detected_at),
        fmt_time(l.replanned_at - l.drained_at),
        fmt_time(l.resumed_at),
        l.tokens_delivered,
        l.tokens_rerouted,
        l.tokens_dropped,
        l.epochs
    )
}

/// One-line human rendering of a serving summary (CLI `serve` output).
pub fn serving_line(s: &ServingBenchInfo) -> String {
    format!(
        "serving: {}/{} completed ({} dropped, {} rerouted); \
         TTFT p50 {} p99 {}; TPOT p50 {} p99 {}; \
         goodput {:.0} tok/s over {}; peak queue {}; {} recovery(ies)",
        s.completed,
        s.requests,
        s.dropped,
        s.rerouted,
        fmt_time(s.p50_ttft_s),
        fmt_time(s.p99_ttft_s),
        fmt_time(s.p50_tpot_s),
        fmt_time(s.p99_tpot_s),
        s.goodput_tokens_per_s,
        fmt_time(s.makespan_s),
        s.max_queue_depth,
        s.recoveries
    )
}

// ---------------------------------------------------------------------------
// timelines
// ---------------------------------------------------------------------------

/// Render an ASCII timeline of op spans (one lane per task), like the
/// paper's Fig. 3/5/9 timing diagrams.
pub fn ascii_timeline(report: &SimReport, width: usize) -> String {
    if report.op_spans.is_empty() {
        return "(no spans; run with trace enabled)".into();
    }
    let t_end = report.makespan.max(1e-12);
    let mut lanes: std::collections::BTreeMap<String, Vec<&OpSpan>> = Default::default();
    for s in &report.op_spans {
        lanes
            .entry(format!("r{} {}", s.rank, s.task_name))
            .or_default()
            .push(s);
    }
    let name_w = lanes.keys().map(|k| k.len()).max().unwrap_or(8).min(28);
    let mut out = String::new();
    let _ = writeln!(
        out,
        "timeline 0 .. {} ({} lanes)",
        fmt_time(t_end),
        lanes.len()
    );
    for (name, spans) in lanes {
        let mut row = vec![' '; width];
        for s in spans {
            let a = ((s.t0 / t_end) * width as f64) as usize;
            let b = (((s.t1 / t_end) * width as f64) as usize).min(width.saturating_sub(1));
            let ch = span_char(&s.label);
            for c in row.iter_mut().take(b + 1).skip(a.min(width - 1)) {
                *c = ch;
            }
        }
        let label: String = name.chars().take(name_w).collect();
        let _ = writeln!(out, "{label:<name_w$} |{}|", row.iter().collect::<String>());
    }
    out.push_str("legend: g=gemm c=copy/put r=reduce l=ll/multimem w=wait .=other\n");
    out
}

fn span_char(label: &str) -> char {
    if label.contains("gemm") || label.contains("moe") || label.contains("decode_partial") {
        'g'
    } else if label.contains("put") || label.contains("copy") || label.contains("get") {
        'c'
    } else if label.contains("reduce") {
        'r'
    } else if label.contains("ll") || label.contains("multimem") {
        'l'
    } else if label.contains("wait") || label.contains("barrier") {
        'w'
    } else {
        '.'
    }
}

/// Export op spans as a chrome://tracing JSON document.
pub fn chrome_trace(report: &SimReport) -> String {
    use crate::util::json::Json;
    let mut events = Vec::new();
    for s in &report.op_spans {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("name".into(), Json::Str(s.label.clone()));
        obj.insert("cat".into(), Json::Str("op".into()));
        obj.insert("ph".into(), Json::Str("X".into()));
        obj.insert("ts".into(), Json::Num(s.t0 * 1e6));
        obj.insert("dur".into(), Json::Num((s.t1 - s.t0) * 1e6));
        obj.insert("pid".into(), Json::Num(s.rank as f64));
        obj.insert("tid".into(), Json::Num(s.task as f64));
        let mut args = std::collections::BTreeMap::new();
        args.insert("task".into(), Json::Str(s.task_name.clone()));
        obj.insert("args".into(), Json::Obj(args));
        events.push(Json::Obj(obj));
    }
    let mut root = std::collections::BTreeMap::new();
    root.insert("traceEvents".into(), Json::Arr(events));
    root.insert("displayTimeUnit".into(), Json::Str("ns".into()));
    Json::Obj(root).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_report() -> SimReport {
        SimReport {
            makespan: 10e-6,
            op_spans: vec![
                OpSpan {
                    task: 0,
                    rank: 0,
                    task_name: "gemm".into(),
                    label: "gemm_chunk".into(),
                    t0: 0.0,
                    t1: 5e-6,
                },
                OpSpan {
                    task: 1,
                    rank: 0,
                    task_name: "scatter".into(),
                    label: "putmem_signal".into(),
                    t0: 2e-6,
                    t1: 8e-6,
                },
            ],
            ..Default::default()
        }
    }

    #[test]
    fn speedup_math() {
        let row = SpeedupRow {
            workload: "w".into(),
            ours: 1.0,
            baselines: vec![("nccl".into(), 2.0), ("flux".into(), 1.5)],
        };
        assert_eq!(row.speedup_vs("nccl"), Some(2.0));
        assert_eq!(row.speedup_vs("none"), None);
    }

    #[test]
    fn figure_report_renders_and_averages() {
        let mut f = FigureReport::new("demo");
        for ours in [1.0, 2.0] {
            f.push(SpeedupRow {
                workload: format!("m{ours}"),
                ours,
                baselines: vec![("nccl".into(), ours * 2.0)],
            });
        }
        assert!((f.avg_speedup("nccl") - 2.0).abs() < 1e-12);
        let s = f.render();
        assert!(s.contains("avg speedup vs nccl: 2.00x"));
        assert!(s.contains("2.00x"));
    }

    #[test]
    fn timeline_renders_lanes() {
        let s = ascii_timeline(&demo_report(), 40);
        assert!(s.contains("r0 gemm"));
        assert!(s.contains('g'));
        assert!(s.contains('c'));
    }

    #[test]
    fn chrome_trace_is_valid_json() {
        let s = chrome_trace(&demo_report());
        let doc = crate::util::json::parse(&s).unwrap();
        assert_eq!(doc.get("traceEvents").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn engine_bench_json_round_trips() {
        let recs = vec![EngineBenchRecord {
            scenario: "alltoall-64rank".into(),
            events: 1000,
            median_wall_s: 0.5,
            sim_wall_ns: 0,
            threads: Vec::new(),
            fault: None,
            recovery: None,
            serving: None,
            sched: None,
        }];
        let s = engine_bench_json(&recs);
        let doc = crate::util::json::parse(&s).unwrap();
        let sc = doc.get("scenarios").get("alltoall-64rank");
        assert_eq!(sc.get("events").as_usize(), Some(1000));
        assert_eq!(sc.get("events_per_s").as_f64(), Some(2000.0));
        // wall_ns / threads sweep omitted when not captured
        assert!(!s.contains("wall_ns"));
        assert!(!s.contains("threads_events_per_s"));
    }

    #[test]
    fn engine_bench_json_carries_threads_sweep() {
        let recs = vec![EngineBenchRecord {
            scenario: "alltoall-4096rank-par".into(),
            events: 4000,
            median_wall_s: 2.0,
            sim_wall_ns: 2_000_000_000,
            threads: vec![(1, 2000.0), (8, 12000.0)],
            fault: None,
            recovery: None,
            serving: None,
            sched: None,
        }];
        let s = engine_bench_json(&recs);
        let doc = crate::util::json::parse(&s).unwrap();
        let sc = doc.get("scenarios").get("alltoall-4096rank-par");
        assert_eq!(sc.get("wall_ns").as_f64(), Some(2e9));
        let tw = sc.get("threads_events_per_s");
        assert_eq!(tw.get("01").as_f64(), Some(2000.0));
        assert_eq!(tw.get("08").as_f64(), Some(12000.0));
    }

    #[test]
    fn engine_bench_json_carries_fault_ledger() {
        let recs = vec![EngineBenchRecord {
            scenario: "alltoall-degraded-rail".into(),
            events: 500,
            median_wall_s: 0.25,
            sim_wall_ns: 0,
            threads: Vec::new(),
            fault: Some(FaultBenchInfo {
                ledger: FaultLedger {
                    faults_applied: 2,
                    flows_killed: 3,
                    retries: 4,
                    rerouted_bytes: 1.5e6,
                    retries_exhausted: 0,
                },
                slowdown: 1.37,
            }),
            recovery: None,
            serving: None,
            sched: None,
        }];
        let s = engine_bench_json(&recs);
        let doc = crate::util::json::parse(&s).unwrap();
        let f = doc.get("scenarios").get("alltoall-degraded-rail").get("fault");
        assert_eq!(f.get("flows_killed").as_usize(), Some(3));
        assert_eq!(f.get("retries").as_usize(), Some(4));
        assert_eq!(f.get("rerouted_bytes").as_f64(), Some(1.5e6));
        assert_eq!(f.get("slowdown").as_f64(), Some(1.37));
        let line = fault_ledger_line(&FaultLedger::default());
        assert!(line.contains("0 retries"), "{line}");
    }

    #[test]
    fn engine_bench_json_carries_recovery() {
        let ledger = RecoveryLedger {
            dead_ranks: vec![3],
            died_at: 1e-4,
            detected_at: 1.5e-4,
            via: "flow-kill".into(),
            drained_at: 1.6e-4,
            replanned_at: 4e-4,
            resumed_at: 4e-4,
            flows_drained: 5,
            steps_checkpointed: 12,
            tokens_delivered: 84,
            tokens_rerouted: 10,
            tokens_dropped: 12,
            epochs: 1,
        };
        let recs = vec![EngineBenchRecord {
            scenario: "moe-ep-rank-death".into(),
            events: 800,
            median_wall_s: 0.1,
            sim_wall_ns: 0,
            threads: Vec::new(),
            fault: None,
            recovery: Some(RecoveryBenchInfo {
                ledger: ledger.clone(),
                goodput: 84.0 / 96.0,
            }),
            serving: None,
            sched: None,
        }];
        let s = engine_bench_json(&recs);
        let doc = crate::util::json::parse(&s).unwrap();
        let r = doc.get("scenarios").get("moe-ep-rank-death").get("recovery");
        assert_eq!(r.get("via").as_str(), Some("flow-kill"));
        assert_eq!(r.get("tokens_delivered").as_usize(), Some(84));
        assert_eq!(r.get("epochs").as_usize(), Some(1));
        assert!((r.get("detect_latency_s").as_f64().unwrap() - 5e-5).abs() < 1e-12);
        assert!((r.get("goodput").as_f64().unwrap() - 0.875).abs() < 1e-12);
        let line = recovery_line(&ledger);
        assert!(line.contains("flow-kill"), "{line}");
        assert!(line.contains("84 delivered"), "{line}");
    }

    #[test]
    fn engine_bench_json_carries_sched_sweep() {
        let recs = vec![EngineBenchRecord {
            scenario: "alltoall-sched-mixed".into(),
            events: 2000,
            median_wall_s: 0.5,
            sim_wall_ns: 0,
            threads: Vec::new(),
            fault: None,
            recovery: None,
            serving: None,
            sched: Some(SchedBenchInfo {
                fifo_s: 2e-3,
                srpf_s: 1.6e-3,
                deadline_s: 1e-3,
            }),
        }];
        let s = engine_bench_json(&recs);
        let doc = crate::util::json::parse(&s).unwrap();
        let sc = doc.get("scenarios").get("alltoall-sched-mixed").get("sched");
        assert_eq!(sc.get("fifo_makespan_s").as_f64(), Some(2e-3));
        assert_eq!(sc.get("srpf_makespan_s").as_f64(), Some(1.6e-3));
        assert_eq!(sc.get("deadline_makespan_s").as_f64(), Some(1e-3));
        assert!((sc.get("srpf_speedup").as_f64().unwrap() - 1.25).abs() < 1e-12);
        assert!((sc.get("deadline_speedup").as_f64().unwrap() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn fig1_summary_renders() {
        let s = fig1_summary(&[("AG+GEMM", 1.42), ("AG+MoE", 44.97)]);
        assert!(s.contains("44.97x"));
    }

    #[test]
    fn engine_bench_json_carries_serving_summary() {
        let recs = vec![EngineBenchRecord {
            scenario: "serve-mixed-1k".into(),
            events: 123456,
            median_wall_s: 1.0,
            sim_wall_ns: 0,
            threads: Vec::new(),
            fault: None,
            recovery: None,
            serving: Some(ServingBenchInfo {
                requests: 1000,
                completed: 990,
                dropped: 10,
                rerouted: 4,
                p50_ttft_s: 2e-4,
                p99_ttft_s: 9e-4,
                p50_tpot_s: 5e-5,
                p99_tpot_s: 2e-4,
                goodput_tokens_per_s: 3.2e5,
                makespan_s: 0.1,
                max_queue_depth: 37,
                recoveries: 1,
            }),
            sched: None,
        }];
        let s = engine_bench_json(&recs);
        let doc = crate::util::json::parse(&s).unwrap();
        let sv = doc.get("scenarios").get("serve-mixed-1k").get("serving");
        assert_eq!(sv.get("requests").as_usize(), Some(1000));
        assert_eq!(sv.get("completed").as_usize(), Some(990));
        assert_eq!(sv.get("p99_ttft_s").as_f64(), Some(9e-4));
        assert_eq!(sv.get("p50_tpot_s").as_f64(), Some(5e-5));
        assert_eq!(sv.get("max_queue_depth").as_usize(), Some(37));
        assert_eq!(sv.get("recoveries").as_usize(), Some(1));
        let line = serving_line(recs[0].serving.as_ref().unwrap());
        assert!(line.contains("990/1000 completed"), "{line}");
        assert!(line.contains("1 recovery"), "{line}");
    }

    // -----------------------------------------------------------------
    // percentile estimator (util::stats::percentile) — the p50/p99
    // machinery every ServingBenchInfo number flows through
    // -----------------------------------------------------------------

    use crate::util::stats::percentile;

    #[test]
    fn percentile_exact_on_known_distributions() {
        // 1..=5: rank = p/100 * 4, linear interpolation between sorted
        // neighbours — all exactly representable
        let xs = [5.0, 3.0, 1.0, 4.0, 2.0]; // unsorted on purpose
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 75.0), 4.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        // interpolated: p62.5 on 1..=5 -> rank 2.5 -> 3.5
        assert_eq!(percentile(&xs, 62.5), 3.5);
        // two samples: p99 interpolates 98% of the way up
        let two = [10.0, 20.0];
        assert_eq!(percentile(&two, 50.0), 15.0);
        assert!((percentile(&two, 99.0) - 19.9).abs() < 1e-12);
    }

    #[test]
    fn percentile_single_sample_is_that_sample() {
        let one = [42.5];
        for p in [0.0, 1.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&one, p), 42.5, "p{p}");
        }
    }

    #[test]
    fn percentile_all_equal_is_constant() {
        let xs = [7.25; 9];
        for p in [0.0, 25.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&xs, p), 7.25, "p{p}");
        }
    }

    #[test]
    fn percentile_is_monotone_in_p_and_bounded() {
        let xs = [0.3, 12.0, 5.5, 5.5, 0.01, 7.0, 100.0, 2.0];
        let mut last = f64::NEG_INFINITY;
        for p in 0..=100 {
            let v = percentile(&xs, p as f64);
            assert!(v >= last, "p{p}: {v} < {last}");
            assert!((0.01..=100.0).contains(&v), "p{p}: {v}");
            last = v;
        }
        // p50 <= p99 is the ServingReport sanity invariant
        assert!(percentile(&xs, 50.0) <= percentile(&xs, 99.0));
    }

    #[test]
    fn percentile_empty_is_zero() {
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
