//! Interconnect topology models (§3.7, Fig. 6) and the routed
//! inter-node fabric graph.
//!
//! Three intra-node fabrics are modeled, matching the paper's testbeds:
//!
//! * **H800 / NVSwitch** — every GPU has one aggregate NVLink egress port
//!   and one ingress port (~170 GB/s each) into a non-blocking switch.
//! * **MI308X / full mesh** — a dedicated 50 GB/s link per ordered GPU
//!   pair; the 350 GB/s aggregate is only reachable by using all seven
//!   peer links simultaneously (this is what drives the Fig. 8 swizzle).
//! * **L20 / PCIe** — per-GPU PCIe up/down links plus a shared per-NUMA
//!   root-complex link that creates the contention the paper's PCIe
//!   scheduling optimization must avoid.
//!
//! # The inter-node fabric graph
//!
//! Inter-node transfers traverse a hierarchical, rail-optimized fabric
//! described by [`crate::config::FabricSpec`]:
//!
//! * **NIC tier** — per `(gpu, rail)` tx/rx links of `nic_bw / rails`
//!   each (GPUDirect-style: no intra-node hop is charged).
//! * **Leaf tier** — per `(node, rail)` up/down links aggregating the
//!   node's NICs of that rail; capacity
//!   `gpus_per_node * rail_bw / oversub` (the oversubscription ratio is
//!   the classic downlink:uplink thinning at the leaf).
//! * **Spine tier** — one plane per rail, capacity
//!   `nodes * leaf_bw / spine_taper`, shared by every same-rail
//!   inter-node flow; cross-rail ("spine-crossing") routes traverse
//!   *both* planes. With the default `spine_taper = 1.0` a plane's
//!   capacity equals the sum of the leaf uplinks feeding it, so by the
//!   mediant inequality it can never be the *strict* max–min bottleneck:
//!   oversubscription contention then materializes at the leaf up/down
//!   links, and the spine's role is merging every node into one flow
//!   component (plus `spine_lat`). Set `spine_taper > 1.0` to make the
//!   spine core itself the binding constraint.
//!
//! The [`Router`] maps `(src_pe, dst_pe, TrafficClass)` to a multi-hop
//! [`Route`]: `TrafficClass::Rail(r)` pins a message to plane `r`
//! end-to-end (the rail-optimized path collectives stripe over);
//! `Rails { tx, rx }` with unequal planes produces a spine-crossing
//! path; `Auto` resolves through the fabric's
//! [`RailPolicy`](crate::config::RailPolicy) — a deterministic rail
//! derived from the endpoints (`Static`), or the **emptiest plane** by
//! live [`LinkOccupancy`] (`Adaptive`): the DES engine feeds per-link
//! committed-bytes / in-flight-flow counters back to the router on every
//! flow post and completion, so rail selection reacts to the congestion
//! the flow solver models without ever re-entering the solver.
//!
//! **Exactness:** on a non-blocking fabric (`oversub <= 1.0`) the switch
//! tiers can never be the max–min bottleneck (each tier's capacity is at
//! least the sum of the NIC endpoint capacities feeding it), so their
//! links are elided from routes. With the default `FabricSpec`
//! (`rails = 1`, `oversub = 1.0`) the link set, routes, and latencies are
//! exactly the seed's flat per-GPU `[nic_tx, nic_rx]` model — makespans
//! are bit-identical.
//!
//! Local (same-rank) copies are charged to a per-GPU HBM read+write link.
//!
//! A [`Route`] is the set of links a flow occupies plus a propagation
//! latency; the DES engine max–min fair-shares link capacity among all
//! concurrent flows (see `sim::flow`).

use crate::config::{ClusterSpec, FaultTarget, HardwareKind, RailPolicy, TrafficClass};

/// Index into the [`Topology`]'s link table (see [`Topology::link`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LinkId(pub usize);

/// What a link physically is (for traces and tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    NvlEgress,
    NvlIngress,
    MeshPair,
    PcieUp,
    PcieDown,
    PcieHost,
    NicTx,
    NicRx,
    /// Leaf-switch uplink toward the spine, per (node, rail).
    LeafUp,
    /// Leaf-switch downlink from the spine, per (node, rail).
    LeafDown,
    /// Spine plane, per rail.
    Spine,
    Hbm,
}

/// A shared, capacity-limited channel.
#[derive(Debug, Clone)]
pub struct Link {
    pub kind: LinkKind,
    /// Capacity in bytes/s.
    pub bw: f64,
    /// Owning rank (NUMA id for PcieHost, `node*rails+rail` for leaf
    /// links, rail for Spine), for diagnostics.
    pub owner: usize,
}

/// The links a transfer occupies and its propagation latency.
#[derive(Debug, Clone)]
pub struct Route {
    pub links: Vec<LinkId>,
    pub latency: f64,
}

/// Live per-link occupancy the DES engine feeds back to the [`Router`]:
/// wire bytes committed (posted but not yet delivered) and in-flight flow
/// counts, indexed by [`LinkId`].
///
/// The engine calls [`LinkOccupancy::commit`] when a transfer is posted
/// (the route is chosen and the flow's arm event is scheduled) and
/// [`LinkOccupancy::release`] when the flow completes, so the view always
/// reflects every transfer currently holding capacity **including** those
/// still in their propagation-latency window — exactly what a sender
/// posting a burst needs to balance its own messages. Updates are O(route
/// length) counter bumps; the max–min solver is never re-entered.
///
/// ```
/// use triton_dist_sim::topology::{LinkId, LinkOccupancy};
///
/// let mut occ = LinkOccupancy::new(4);
/// occ.commit(&[LinkId(0), LinkId(2)], 4096.0);
/// assert_eq!(occ.committed_bytes(LinkId(0)), 4096.0);
/// assert_eq!(occ.in_flight(LinkId(2)), 1);
/// occ.release(&[LinkId(0), LinkId(2)], 4096.0);
/// assert_eq!(occ.in_flight(LinkId(0)), 0);
/// ```
#[derive(Debug, Clone)]
pub struct LinkOccupancy {
    committed: Vec<f64>,
    flows: Vec<u32>,
}

impl LinkOccupancy {
    /// Empty occupancy for a topology with `n_links` links.
    pub fn new(n_links: usize) -> Self {
        LinkOccupancy {
            committed: vec![0.0; n_links],
            flows: vec![0; n_links],
        }
    }

    /// A transfer of `bytes` wire bytes was posted on `links`.
    pub fn commit(&mut self, links: &[LinkId], bytes: f64) {
        for l in links {
            self.committed[l.0] += bytes;
            self.flows[l.0] += 1;
        }
    }

    /// The transfer completed; release its committed bytes. Clamped at
    /// zero: releases replay the exact commit values, but cross-flow
    /// float accumulation may leave dust.
    pub fn release(&mut self, links: &[LinkId], bytes: f64) {
        for l in links {
            self.committed[l.0] = (self.committed[l.0] - bytes).max(0.0);
            self.flows[l.0] = self.flows[l.0].saturating_sub(1);
        }
    }

    /// Wire bytes currently committed to link `l`.
    pub fn committed_bytes(&self, l: LinkId) -> f64 {
        self.committed[l.0]
    }

    /// Transfers currently in flight over link `l`.
    pub fn in_flight(&self, l: LinkId) -> u32 {
        self.flows[l.0]
    }
}

/// Live per-link capacity factors under the active fault set (see
/// `config::fault`): `1.0` = nominal, `(0, 1)` = degraded, `0.0` = down.
/// The DES engine owns one of these when a `FaultPlan` is loaded and
/// updates it as fault begin/end events fire; the [`Router`] consults it
/// (via [`Router::route_faulty`]) so `RailPolicy::Adaptive` steers
/// around dead or degraded planes. Fault-free runs never construct one —
/// the `Option<&FabricHealth>` stays `None` and routing is bit-identical
/// to the health-blind path.
///
/// Besides per-link factors the health view carries a per-rank
/// **alive-mask** for permanent deaths (`die` / `nodedead` fault
/// clauses): [`FabricHealth::mark_dead`] retires a rank forever, and
/// [`FabricHealth::is_alive`] lets the engine and router refuse dead
/// endpoints outright instead of discovering the zeroed links one flow
/// at a time. The mask is lazily grown, so fault plans without deaths
/// allocate nothing and stay bit-identical to the PR-5 behavior.
///
/// ```
/// use triton_dist_sim::topology::{FabricHealth, LinkId};
///
/// let mut h = FabricHealth::healthy(4);
/// assert!(h.all_healthy());
/// h.set_factor(LinkId(2), 0.0);
/// assert!(h.is_down(LinkId(2)));
/// h.set_factor(LinkId(2), 1.0);
/// assert!(h.all_healthy());
/// assert!(h.is_alive(7) && !h.any_dead());
/// h.mark_dead(7);
/// assert!(!h.is_alive(7) && h.any_dead());
/// ```
#[derive(Debug, Clone)]
pub struct FabricHealth {
    factor: Vec<f64>,
    degraded: usize,
    /// Permanently dead ranks; empty (nothing dead) until the first
    /// [`mark_dead`](Self::mark_dead).
    alive: Vec<bool>,
}

impl FabricHealth {
    /// All links at nominal capacity, every rank alive.
    pub fn healthy(n_links: usize) -> Self {
        FabricHealth {
            factor: vec![1.0; n_links],
            degraded: 0,
            alive: Vec::new(),
        }
    }

    /// Current capacity factor of link `l`.
    pub fn factor(&self, l: LinkId) -> f64 {
        self.factor[l.0]
    }

    /// Set link `l`'s capacity factor (the engine recomputes it as the
    /// product over all active faults hitting the link).
    pub fn set_factor(&mut self, l: LinkId, f: f64) {
        let old = self.factor[l.0];
        if old == 1.0 && f != 1.0 {
            self.degraded += 1;
        } else if old != 1.0 && f == 1.0 {
            self.degraded -= 1;
        }
        self.factor[l.0] = f;
    }

    /// Is link `l` completely down?
    pub fn is_down(&self, l: LinkId) -> bool {
        self.factor[l.0] == 0.0
    }

    /// No link deviates from nominal capacity.
    pub fn all_healthy(&self) -> bool {
        self.degraded == 0
    }

    /// Does every link of `route` have nonzero capacity?
    pub fn route_alive(&self, route: &Route) -> bool {
        route.links.iter().all(|l| self.factor[l.0] > 0.0)
    }

    /// Permanently retire `rank`. Idempotent; the engine also zeroes
    /// every link the rank terminates, so `route_alive` refuses its
    /// routes and `is_alive` refuses it as an endpoint.
    pub fn mark_dead(&mut self, rank: usize) {
        if self.alive.len() <= rank {
            self.alive.resize(rank + 1, true);
        }
        self.alive[rank] = false;
    }

    /// Has `rank` not been [`mark_dead`](Self::mark_dead)ed? Ranks the
    /// mask has never seen are alive.
    pub fn is_alive(&self, rank: usize) -> bool {
        self.alive.get(rank).copied().unwrap_or(true)
    }

    /// Any permanent death recorded?
    pub fn any_dead(&self) -> bool {
        self.alive.iter().any(|a| !a)
    }

    /// The dead ranks, ascending.
    pub fn dead_ranks(&self) -> Vec<usize> {
        self.alive
            .iter()
            .enumerate()
            .filter_map(|(r, &a)| (!a).then_some(r))
            .collect()
    }
}

/// Rank → partition assignment for the sharded event loop.
///
/// Produced by [`Topology::node_partition_map`] (one partition per node,
/// the flow-solver's natural component boundary) and then *coarsened* by
/// the engine's program pre-scan: any program-level coupling that would
/// let two partitions interact faster than the fabric latency floor
/// (cross-node `SetSignal`, cross-node `LLWait`, foreign node-scoped
/// barriers) unions the two partitions so the coupling becomes
/// shard-local. Labels are renumbered densely by [`PartitionMap::compact`]
/// so partition indices are deterministic.
#[derive(Debug, Clone)]
pub struct PartitionMap {
    part_of: Vec<usize>,
    n_parts: usize,
}

impl PartitionMap {
    /// Partition index of `rank`.
    pub fn part_of(&self, rank: usize) -> usize {
        self.part_of[rank]
    }

    /// Number of partitions (valid after [`Self::compact`]).
    pub fn n_parts(&self) -> usize {
        self.n_parts
    }

    /// Merge the partitions containing ranks `a` and `b`, keeping the
    /// smaller label. Call [`Self::compact`] once all unions are in.
    pub fn union_ranks(&mut self, a: usize, b: usize) {
        let (pa, pb) = (self.part_of[a], self.part_of[b]);
        if pa == pb {
            return;
        }
        let (keep, drop) = if pa < pb { (pa, pb) } else { (pb, pa) };
        for p in self.part_of.iter_mut() {
            if *p == drop {
                *p = keep;
            }
        }
    }

    /// Renumber labels densely to `0..n_parts` in order of first
    /// appearance by rank (deterministic: no hasher state involved).
    pub fn compact(&mut self) {
        let mut map = std::collections::BTreeMap::new();
        let mut next = 0usize;
        for p in self.part_of.iter_mut() {
            let d = *map.entry(*p).or_insert_with(|| {
                let d = next;
                next += 1;
                d
            });
            *p = d;
        }
        self.n_parts = next;
    }

    /// Ranks owned by `part`, in ascending order.
    pub fn ranks_of(&self, part: usize) -> impl Iterator<Item = usize> + '_ {
        self.part_of
            .iter()
            .enumerate()
            .filter_map(move |(r, &p)| (p == part).then_some(r))
    }
}

/// Immutable interconnect graph for one cluster.
pub struct Topology {
    pub cluster: ClusterSpec,
    links: Vec<Link>,
    // per-rank link ids (usize::MAX = absent)
    intra_egress: Vec<usize>,
    intra_ingress: Vec<usize>,
    /// Per (rank, rail): `rank * rails + rail`.
    nic_tx: Vec<usize>,
    nic_rx: Vec<usize>,
    /// Per (node, rail): `node * rails + rail` (empty on non-blocking
    /// fabrics — see the module doc's exactness note).
    leaf_up: Vec<usize>,
    leaf_down: Vec<usize>,
    /// Per rail (empty on non-blocking fabrics).
    spine: Vec<usize>,
    hbm: Vec<usize>,
    pcie_host: Vec<usize>, // per NUMA domain
    // Ordered so link-id assignment and any iteration over pairs is
    // deterministic regardless of hasher state (cross-thread bit-identity
    // prerequisite — see sim/par.rs).
    mesh: std::collections::BTreeMap<(usize, usize), usize>,
}

impl Topology {
    pub fn build(cluster: ClusterSpec) -> Self {
        let ws = cluster.world_size();
        let hw = cluster.hw;
        let fabric = cluster.fabric;
        let rails = fabric.rails;
        let mut links = Vec::new();
        let push = |kind: LinkKind, bw: f64, owner: usize, links: &mut Vec<Link>| {
            links.push(Link { kind, bw, owner });
            links.len() - 1
        };

        let mut topo = Topology {
            cluster,
            links: Vec::new(),
            intra_egress: vec![usize::MAX; ws],
            intra_ingress: vec![usize::MAX; ws],
            nic_tx: vec![usize::MAX; ws * rails],
            nic_rx: vec![usize::MAX; ws * rails],
            leaf_up: Vec::new(),
            leaf_down: Vec::new(),
            spine: Vec::new(),
            hbm: vec![usize::MAX; ws],
            pcie_host: Vec::new(),
            mesh: Default::default(),
        };

        for r in 0..ws {
            topo.hbm[r] = push(LinkKind::Hbm, hw.hbm_bw / 2.0, r, &mut links);
        }

        match hw.kind {
            HardwareKind::H800 => {
                for r in 0..ws {
                    topo.intra_egress[r] =
                        push(LinkKind::NvlEgress, hw.intra_bw, r, &mut links);
                    topo.intra_ingress[r] =
                        push(LinkKind::NvlIngress, hw.intra_bw, r, &mut links);
                }
            }
            HardwareKind::MI308X => {
                // dedicated link per ordered pair within the node
                for a in 0..ws {
                    for b in 0..ws {
                        if a != b && cluster.node_of(a) == cluster.node_of(b) {
                            let id = push(LinkKind::MeshPair, hw.intra_link_bw, a, &mut links);
                            topo.mesh.insert((a, b), id);
                        }
                    }
                }
            }
            HardwareKind::L20 => {
                for r in 0..ws {
                    topo.intra_egress[r] = push(LinkKind::PcieUp, hw.intra_bw, r, &mut links);
                    topo.intra_ingress[r] =
                        push(LinkKind::PcieDown, hw.intra_bw, r, &mut links);
                }
                // shared per-NUMA root complex: 2x a single device link
                let numa_domains = cluster.nodes * cluster.numa_per_node;
                for d in 0..numa_domains {
                    let id = push(LinkKind::PcieHost, hw.intra_bw * 2.0, d, &mut links);
                    topo.pcie_host.push(id);
                }
            }
        }

        if cluster.nodes > 1 {
            let rail_bw = fabric.rail_bw(hw.nic_bw);
            for r in 0..ws {
                for rail in 0..rails {
                    topo.nic_tx[r * rails + rail] =
                        push(LinkKind::NicTx, rail_bw, r, &mut links);
                    topo.nic_rx[r * rails + rail] =
                        push(LinkKind::NicRx, rail_bw, r, &mut links);
                }
            }
            if fabric.is_blocking() {
                let leaf_bw = cluster.gpus_per_node as f64 * rail_bw / fabric.oversub;
                for node in 0..cluster.nodes {
                    for rail in 0..rails {
                        let owner = node * rails + rail;
                        topo.leaf_up
                            .push(push(LinkKind::LeafUp, leaf_bw, owner, &mut links));
                        topo.leaf_down
                            .push(push(LinkKind::LeafDown, leaf_bw, owner, &mut links));
                    }
                }
                let spine_bw = cluster.nodes as f64 * leaf_bw / fabric.spine_taper;
                for rail in 0..rails {
                    topo.spine
                        .push(push(LinkKind::Spine, spine_bw, rail, &mut links));
                }
            }
        }

        topo.links = links;
        topo
    }

    pub fn link(&self, id: LinkId) -> &Link {
        &self.links[id.0]
    }

    pub fn link_count(&self) -> usize {
        self.links.len()
    }

    /// Resolve a traffic class into concrete (tx_rail, rx_rail) planes.
    fn resolve_rails(&self, src: usize, dst: usize, tc: TrafficClass) -> (usize, usize) {
        let rails = self.cluster.fabric.rails;
        match tc {
            TrafficClass::Auto => {
                let r = (self.cluster.local_rank(src) + self.cluster.local_rank(dst)) % rails;
                (r, r)
            }
            TrafficClass::Rail(r) => {
                let r = r as usize % rails;
                (r, r)
            }
            TrafficClass::Rails { tx, rx } => (tx as usize % rails, rx as usize % rails),
        }
    }

    /// Route for a transfer `src -> dst` (same-rank = local HBM copy),
    /// letting the router pick the rail.
    pub fn route(&self, src: usize, dst: usize) -> Route {
        self.route_tc(src, dst, TrafficClass::Auto)
    }

    /// Route for a transfer `src -> dst` under an explicit traffic class.
    ///
    /// Intra-node paths ignore the class; inter-node paths resolve it to
    /// NIC rails and, on a blocking fabric, thread the leaf/spine tier
    /// links between the endpoints.
    pub fn route_tc(&self, src: usize, dst: usize, tc: TrafficClass) -> Route {
        let c = &self.cluster;
        let hw = c.hw;
        if src == dst {
            return Route {
                links: vec![LinkId(self.hbm[src])],
                latency: 0.0,
            };
        }
        if c.node_of(src) != c.node_of(dst) {
            let fabric = c.fabric;
            let rails = fabric.rails;
            let (rt, rr) = self.resolve_rails(src, dst, tc);
            assert!(
                self.nic_tx[src * rails + rt] != usize::MAX,
                "inter-node route on single-node cluster"
            );
            let mut links = vec![LinkId(self.nic_tx[src * rails + rt])];
            let spine_hops = if rt == rr { 1.0 } else { 2.0 };
            if fabric.is_blocking() {
                links.push(LinkId(self.leaf_up[c.node_of(src) * rails + rt]));
                links.push(LinkId(self.spine[rt]));
                if rr != rt {
                    links.push(LinkId(self.spine[rr]));
                }
                links.push(LinkId(self.leaf_down[c.node_of(dst) * rails + rr]));
            }
            links.push(LinkId(self.nic_rx[dst * rails + rr]));
            return Route {
                links,
                latency: hw.inter_lat + 2.0 * fabric.leaf_lat + spine_hops * fabric.spine_lat,
            };
        }
        match hw.kind {
            HardwareKind::H800 => Route {
                links: vec![
                    LinkId(self.intra_egress[src]),
                    LinkId(self.intra_ingress[dst]),
                ],
                latency: hw.intra_lat,
            },
            HardwareKind::MI308X => Route {
                links: vec![LinkId(self.mesh[&(src, dst)])],
                latency: hw.intra_lat,
            },
            HardwareKind::L20 => {
                let mut links = vec![
                    LinkId(self.intra_egress[src]),
                    LinkId(self.intra_ingress[dst]),
                ];
                let numa_s = c.numa_of(src);
                let numa_d = c.numa_of(dst);
                links.push(LinkId(self.pcie_host[numa_s]));
                if numa_d != numa_s {
                    links.push(LinkId(self.pcie_host[numa_d]));
                }
                Route {
                    links,
                    latency: hw.intra_lat
                        * if numa_s == numa_d { 1.0 } else { 1.6 }, // NUMA penalty
                }
            }
        }
    }

    /// Routed capacity of one serialized inter-node P2P stream: a single
    /// message rides one rail (`nic_bw / rails`) through the thinned
    /// switch tiers. This is the §3.5 bandwidth-balance drain rate —
    /// `rs_inter`'s 1-SM P2P block sends one message per iteration, so
    /// sizing from the all-rail aggregate would overestimate the drain
    /// by a factor of `rails` on multi-rail fabrics.
    pub fn inter_path_bw(&self) -> f64 {
        self.cluster.fabric.rail_path_bw(self.cluster.hw.nic_bw)
    }

    /// Conservative-lookahead bound for the sharded engine (sim/par.rs):
    /// the minimum virtual latency of *any* interaction that crosses a
    /// node partition. Every inter-node route costs at least
    /// `hw.inter_lat` (`route_tc` adds non-negative leaf/spine terms on
    /// top), and the world-barrier release latency is `2 * inter_lat`,
    /// so no event produced by one partition at time `t` can affect
    /// another partition before `t + min_cross_partition_latency()`.
    /// Returns `f64::INFINITY` on single-node clusters (no cross-partition
    /// path exists at all).
    pub fn min_cross_partition_latency(&self) -> f64 {
        if self.cluster.nodes > 1 {
            self.cluster.hw.inter_lat
        } else {
            f64::INFINITY
        }
    }

    /// Is `id` part of the inter-node fabric (NIC / leaf / spine tiers)?
    ///
    /// Fabric links are exactly the links an inter-node route traverses
    /// and exactly the links the *fabric-scoped* [`FaultTarget`]s
    /// (`Nic`/`Spine`/`Rail`) can resolve to; intra-node links (NVLink /
    /// mesh / PCIe / HBM) are everything else. The endpoint-scoped
    /// targets (`Rank`/`Node`, used by permanent deaths) do reach
    /// intra-node links, which is one reason plans with deaths are
    /// excluded from the sharded engine (`sim/par.rs`). The two
    /// sets are disjoint and no route mixes intra-node links of two
    /// different nodes, which is what lets the sharded engine give each
    /// node partition a private [`crate::sim::FlowNet`] over its intra
    /// links and solve the shared fabric separately — the max–min
    /// components never span the boundary.
    pub fn is_fabric_link(&self, id: LinkId) -> bool {
        matches!(
            self.links[id.0].kind,
            LinkKind::NicTx
                | LinkKind::NicRx
                | LinkKind::LeafUp
                | LinkKind::LeafDown
                | LinkKind::Spine
        )
    }

    /// Static partition map for the sharded engine: rank → partition
    /// index, one partition per node. Cross-partition couplings that the
    /// *program* introduces (cross-node `SetSignal`, cross-node `LLWait`,
    /// a task executing a foreign node-scoped barrier) are unioned on top
    /// by [`crate::sim::engine`]'s pre-scan; this is just the topological
    /// floor.
    pub fn node_partition_map(&self) -> PartitionMap {
        let c = &self.cluster;
        PartitionMap {
            part_of: (0..c.world_size()).map(|r| c.node_of(r)).collect(),
            n_parts: c.nodes,
        }
    }

    /// Route for `multimem.st`: one store fans out to every other rank in
    /// the node (H800 only). The flow occupies the source egress and every
    /// peer ingress; latency is the measured multimem cost (§3.4).
    pub fn multimem_route(&self, src: usize) -> Option<Route> {
        let hw = self.cluster.hw;
        if hw.kind != HardwareKind::H800 {
            return None;
        }
        let node = self.cluster.node_of(src);
        let mut links = vec![LinkId(self.intra_egress[src])];
        for r in 0..self.cluster.world_size() {
            if r != src && self.cluster.node_of(r) == node {
                links.push(LinkId(self.intra_ingress[r]));
            }
        }
        Some(Route {
            links,
            latency: hw.multimem_lat,
        })
    }

    /// Local HBM route (used for in-place reductions modeled as copies).
    pub fn hbm_route(&self, rank: usize) -> Route {
        Route {
            links: vec![LinkId(self.hbm[rank])],
            latency: 0.0,
        }
    }

    /// Resolve a [`FaultTarget`] to the concrete links it covers on this
    /// topology. Targets that do not exist here (NIC of an out-of-range
    /// rank, spine on a non-blocking fabric, any inter-node target on a
    /// single-node cluster) resolve to an empty set — the fault is inert
    /// rather than an error, so one plan ports across cluster shapes.
    pub fn fault_links(&self, target: &FaultTarget) -> Vec<LinkId> {
        let rails = self.cluster.fabric.rails;
        let mut out = Vec::new();
        let mut push = |idx: usize| {
            if idx != usize::MAX {
                out.push(LinkId(idx));
            }
        };
        match *target {
            FaultTarget::Nic { rank, rail } => {
                if rank < self.cluster.world_size() && rail < rails {
                    push(self.nic_tx[rank * rails + rail]);
                    push(self.nic_rx[rank * rails + rail]);
                }
            }
            FaultTarget::Spine { rail } => {
                if let Some(&idx) = self.spine.get(rail) {
                    push(idx);
                }
            }
            FaultTarget::Rail { rail } => {
                if rail < rails {
                    for r in 0..self.cluster.world_size() {
                        push(self.nic_tx[r * rails + rail]);
                        push(self.nic_rx[r * rails + rail]);
                    }
                    for node in 0..self.cluster.nodes {
                        if let Some(&idx) = self.leaf_up.get(node * rails + rail) {
                            push(idx);
                        }
                        if let Some(&idx) = self.leaf_down.get(node * rails + rail) {
                            push(idx);
                        }
                    }
                    if let Some(&idx) = self.spine.get(rail) {
                        push(idx);
                    }
                }
            }
            FaultTarget::Rank { rank } => {
                if rank < self.cluster.world_size() {
                    self.rank_links(rank, &mut out);
                }
            }
            FaultTarget::Node { node } => {
                if node < self.cluster.nodes {
                    for r in 0..self.cluster.world_size() {
                        if self.cluster.node_of(r) == node {
                            self.rank_links(r, &mut out);
                        }
                    }
                }
            }
        }
        out
    }

    /// Every link terminating at `rank`: its HBM port, intra-node
    /// egress/ingress (or mesh pairs, either direction), and NIC tx/rx
    /// on every rail. Shared links (PCIe root complexes, leaf/spine
    /// tiers) are *not* included — killing a rank must not take down its
    /// healthy neighbors. Used by [`FaultTarget::Rank`] /
    /// [`FaultTarget::Node`] (and therefore by permanent deaths).
    fn rank_links(&self, rank: usize, out: &mut Vec<LinkId>) {
        let rails = self.cluster.fabric.rails;
        let mut push = |idx: usize| {
            if idx != usize::MAX {
                out.push(LinkId(idx));
            }
        };
        push(self.hbm[rank]);
        push(self.intra_egress[rank]);
        push(self.intra_ingress[rank]);
        for rail in 0..rails {
            push(self.nic_tx[rank * rails + rail]);
            push(self.nic_rx[rank * rails + rail]);
        }
        for (&(a, b), &idx) in self.mesh.iter() {
            if a == rank || b == rank {
                push(idx);
            }
        }
    }
}

/// The rail router: resolves a transfer's [`TrafficClass`] into a
/// concrete [`Route`] under the fabric's
/// [`RailPolicy`](crate::config::RailPolicy).
///
/// * `Static` (the default) delegates straight to
///   [`Topology::route_tc`]: `Auto` hashes the endpoints onto a rail and
///   explicit pins pass through — bit-identical to the policy-less
///   behavior.
/// * `Adaptive` resolves `Auto` inter-node transfers to the **emptiest
///   plane**: each candidate rail's path (NIC tx/rx plus, on blocking
///   fabrics, its leaf up/down and spine links) is scored by its
///   most-loaded link — committed wire bytes normalized by link capacity
///   — from the live [`LinkOccupancy`] the engine maintains; ties fall
///   back to fewest in-flight flows, then lowest rail index, so routing
///   stays fully deterministic. Explicit `Rail`/`Rails` pins are always
///   honored.
///
/// ```
/// use triton_dist_sim::config::{ClusterSpec, FabricSpec, RailPolicy, TrafficClass};
/// use triton_dist_sim::topology::{LinkOccupancy, Router, Topology};
///
/// let cluster = ClusterSpec::h800(2, 8).with_fabric(
///     FabricSpec::rail_optimized(2, 1.0).with_rail_policy(RailPolicy::Adaptive),
/// );
/// let topo = Topology::build(cluster);
/// let router = Router::new(&topo);
/// let mut occ = LinkOccupancy::new(topo.link_count());
///
/// // empty fabric: rail 0 wins the tie
/// let r0 = router.route(0, 9, TrafficClass::Auto, &occ);
/// // load rail 0's NIC pair; the next message balances onto rail 1
/// occ.commit(&r0.links, 1e9);
/// let r1 = router.route(0, 9, TrafficClass::Auto, &occ);
/// assert_ne!(r0.links[0], r1.links[0], "adaptive router moved planes");
/// ```
pub struct Router<'t> {
    topo: &'t Topology,
    policy: RailPolicy,
}

impl<'t> Router<'t> {
    /// Router with the policy recorded in the topology's fabric spec.
    pub fn new(topo: &'t Topology) -> Self {
        Router {
            topo,
            policy: topo.cluster.fabric.rail_policy,
        }
    }

    /// Router with an explicit policy override, independent of what the
    /// topology's fabric spec records (tests and analysis tools compare
    /// policies over one built topology this way; the engine itself
    /// always uses [`Router::new`]).
    pub fn with_policy(topo: &'t Topology, policy: RailPolicy) -> Self {
        Router { topo, policy }
    }

    pub fn policy(&self) -> RailPolicy {
        self.policy
    }

    /// Resolve `tc` and route `src -> dst` under live occupancy.
    pub fn route(&self, src: usize, dst: usize, tc: TrafficClass, occ: &LinkOccupancy) -> Route {
        self.route_faulty(src, dst, tc, occ, None)
    }

    /// [`Router::route`] with an optional fabric-health view: when a
    /// fault plan is active the engine passes `Some(health)` and the
    /// adaptive rail scoring excludes dead planes and deflates degraded
    /// ones (effective capacity `bw * factor`). `None` — the fault-free
    /// engine path — is bit-identical to the health-blind router.
    ///
    /// Pinned classes (`Rail` / `Rails`) are a *performance* hint, not a
    /// correctness requirement: under `RailPolicy::Adaptive` a pinned
    /// inter-node route with a dead link self-heals onto the emptiest
    /// alive plane, while `RailPolicy::Static` honors the pin and lets
    /// the flow stall into the retry machinery — the policy contrast the
    /// degraded-fabric scenarios measure. With every link alive the
    /// pinned route is returned untouched, so an active-but-idle fault
    /// plan stays bit-identical.
    pub fn route_faulty(
        &self,
        src: usize,
        dst: usize,
        tc: TrafficClass,
        occ: &LinkOccupancy,
        health: Option<&FabricHealth>,
    ) -> Route {
        // A permanently dead endpoint is refused outright: no plane can
        // help, so skip the adaptive search and return the static route
        // (all of whose endpoint links are zeroed), which the engine's
        // death detection then converts into a structured `DeadPeer`.
        if let Some(h) = health {
            if !h.is_alive(src) || !h.is_alive(dst) {
                return self.topo.route_tc(src, dst, tc);
            }
        }
        let inter = src != dst
            && self.topo.cluster.fabric.rails > 1
            && self.topo.cluster.node_of(src) != self.topo.cluster.node_of(dst);
        if self.policy == RailPolicy::Adaptive && tc == TrafficClass::Auto && inter {
            let rail = self.pick_rail(src, dst, occ, health);
            return self.topo.route_tc(src, dst, TrafficClass::Rail(rail));
        }
        let route = self.topo.route_tc(src, dst, tc);
        if self.policy == RailPolicy::Adaptive && inter {
            if let Some(h) = health {
                if !h.route_alive(&route) {
                    let rail = self.pick_rail(src, dst, occ, health);
                    return self.topo.route_tc(src, dst, TrafficClass::Rail(rail));
                }
            }
        }
        route
    }

    /// The emptiest plane for `src -> dst`: minimize the candidate path's
    /// bottleneck fill (committed bytes / capacity over its NIC and, on
    /// blocking fabrics, leaf/spine links), breaking ties by in-flight
    /// flow count and then rail index. With a health view, planes with a
    /// downed link on the path are skipped outright (unless *every*
    /// plane is down, when the ordinary scoring decides and the flow
    /// stalls into the retry machinery), and degraded links score with
    /// their reduced effective capacity.
    fn pick_rail(
        &self,
        src: usize,
        dst: usize,
        occ: &LinkOccupancy,
        health: Option<&FabricHealth>,
    ) -> u32 {
        let t = self.topo;
        let c = &t.cluster;
        let fabric = c.fabric;
        let rails = fabric.rails;
        let blocking = fabric.is_blocking();
        // (rail, fill, flows) winners among alive planes and among all
        // planes; prefer the alive winner when one exists.
        let mut best_alive: Option<(u32, f64, u32)> = None;
        let mut best_any = (0u32, f64::INFINITY, u32::MAX);
        for rail in 0..rails {
            let mut fill = 0.0f64;
            let mut flows = 0u32;
            let mut down = false;
            let mut scan = |lid: usize| {
                let id = LinkId(lid);
                let bw = match health {
                    // bw * 1.0 == bw exactly: healthy scoring is
                    // bit-identical to the health-blind path
                    Some(h) => {
                        let factor = h.factor(id);
                        if factor == 0.0 {
                            down = true;
                        }
                        t.links[lid].bw * factor
                    }
                    None => t.links[lid].bw,
                };
                let f = occ.committed_bytes(id) / bw;
                if f > fill {
                    fill = f;
                }
                flows += occ.in_flight(id);
            };
            scan(t.nic_tx[src * rails + rail]);
            if blocking {
                scan(t.leaf_up[c.node_of(src) * rails + rail]);
                scan(t.spine[rail]);
                scan(t.leaf_down[c.node_of(dst) * rails + rail]);
            }
            scan(t.nic_rx[dst * rails + rail]);
            if fill < best_any.1 || (fill == best_any.1 && flows < best_any.2) {
                best_any = (rail as u32, fill, flows);
            }
            if !down {
                let better = match best_alive {
                    None => true,
                    Some((_, bf, bn)) => fill < bf || (fill == bf && flows < bn),
                };
                if better {
                    best_alive = Some((rail as u32, fill, flows));
                }
            }
        }
        match best_alive {
            Some((rail, _, _)) => rail,
            None => best_any.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, FabricSpec};

    #[test]
    fn h800_intra_route_uses_egress_and_ingress() {
        let t = Topology::build(ClusterSpec::h800(1, 8));
        let r = t.route(0, 3);
        assert_eq!(r.links.len(), 2);
        assert_eq!(t.link(r.links[0]).kind, LinkKind::NvlEgress);
        assert_eq!(t.link(r.links[1]).kind, LinkKind::NvlIngress);
        assert_eq!(t.link(r.links[0]).owner, 0);
        assert_eq!(t.link(r.links[1]).owner, 3);
        assert!((r.latency - 0.5e-6).abs() < 1e-12);
    }

    #[test]
    fn h800_inter_route_uses_nics() {
        let t = Topology::build(ClusterSpec::h800(2, 8));
        let r = t.route(1, 9); // rank 1 node 0 -> rank 9 node 1
        assert_eq!(r.links.len(), 2, "non-blocking fabric elides tier links");
        assert_eq!(t.link(r.links[0]).kind, LinkKind::NicTx);
        assert_eq!(t.link(r.links[1]).kind, LinkKind::NicRx);
        assert!(r.latency > 1e-6);
    }

    #[test]
    fn amd_mesh_has_per_pair_links() {
        let t = Topology::build(ClusterSpec::mi308x(8));
        let r01 = t.route(0, 1);
        let r02 = t.route(0, 2);
        assert_eq!(r01.links.len(), 1);
        assert_ne!(r01.links[0], r02.links[0], "pair links must be disjoint");
        assert_eq!(t.link(r01.links[0]).bw, 50e9);
    }

    #[test]
    fn local_route_is_hbm() {
        let t = Topology::build(ClusterSpec::h800(1, 8));
        let r = t.route(5, 5);
        assert_eq!(t.link(r.links[0]).kind, LinkKind::Hbm);
        assert_eq!(r.latency, 0.0);
    }

    #[test]
    fn multimem_covers_all_node_peers() {
        let t = Topology::build(ClusterSpec::h800(2, 8));
        let r = t.multimem_route(2).unwrap();
        // 1 egress + 7 peer ingress links, all same node
        assert_eq!(r.links.len(), 8);
        assert!((r.latency - 1.5e-6).abs() < 1e-12);
        // AMD has no multimem
        let amd = Topology::build(ClusterSpec::mi308x(8));
        assert!(amd.multimem_route(0).is_none());
    }

    #[test]
    fn l20_routes_share_host_link() {
        let t = Topology::build(ClusterSpec::l20(1, 8));
        let r = t.route(0, 1); // same NUMA (ranks 0-3 = NUMA 0)
        assert_eq!(r.links.len(), 3);
        let cross = t.route(0, 5); // cross NUMA
        assert_eq!(cross.links.len(), 4);
        assert!(cross.latency > r.latency);
    }

    #[test]
    #[should_panic]
    fn inter_node_route_panics_on_single_node() {
        let t = Topology::build(ClusterSpec::h800(1, 8));
        // route() with ranks out of the single node is a bug in the caller
        let _ = t.route(0, 12);
    }

    // -- routed fabric ------------------------------------------------------

    fn railed(nodes: usize, gpn: usize, rails: usize, oversub: f64) -> Topology {
        Topology::build(
            ClusterSpec::h800(nodes, gpn)
                .with_fabric(FabricSpec::rail_optimized(rails, oversub)),
        )
    }

    #[test]
    fn blocking_fabric_materializes_tiers() {
        let t = railed(4, 8, 2, 2.0);
        let r = t.route_tc(1, 9, crate::config::TrafficClass::Rail(0));
        let kinds: Vec<LinkKind> = r.links.iter().map(|&l| t.link(l).kind).collect();
        assert_eq!(
            kinds,
            vec![
                LinkKind::NicTx,
                LinkKind::LeafUp,
                LinkKind::Spine,
                LinkKind::LeafDown,
                LinkKind::NicRx,
            ]
        );
        // per-tier capacities: rail_bw = nic_bw/2, leaf = 8*rail_bw/2,
        // spine = 4 nodes * leaf
        let hw = t.cluster.hw;
        let rail_bw = hw.nic_bw / 2.0;
        assert_eq!(t.link(r.links[0]).bw.to_bits(), rail_bw.to_bits());
        let leaf = t.link(r.links[1]);
        assert!((leaf.bw - 8.0 * rail_bw / 2.0).abs() < 1.0, "{}", leaf.bw);
        let spine = t.link(r.links[2]);
        assert!((spine.bw - 4.0 * leaf.bw).abs() < 1.0, "{}", spine.bw);
    }

    #[test]
    fn spine_taper_thins_the_plane() {
        let t = Topology::build(
            ClusterSpec::h800(4, 8)
                .with_fabric(FabricSpec::rail_optimized(2, 2.0).with_spine_taper(2.0)),
        );
        let r = t.route_tc(0, 9, crate::config::TrafficClass::Rail(0));
        let leaf = t.link(r.links[1]);
        let spine = t.link(r.links[2]);
        assert_eq!(spine.kind, LinkKind::Spine);
        assert!((spine.bw - 4.0 * leaf.bw / 2.0).abs() < 1.0, "{}", spine.bw);
    }

    #[test]
    fn cross_rail_route_crosses_both_spines() {
        let t = railed(2, 8, 2, 2.0);
        let same = t.route_tc(0, 8, crate::config::TrafficClass::Rail(1));
        let cross = t.route_tc(
            0,
            8,
            crate::config::TrafficClass::Rails { tx: 0, rx: 1 },
        );
        let spines = |r: &Route| {
            r.links
                .iter()
                .filter(|&&l| t.link(l).kind == LinkKind::Spine)
                .count()
        };
        assert_eq!(spines(&same), 1, "rail-optimized path stays in one plane");
        assert_eq!(spines(&cross), 2, "spine-crossing path pays both planes");
    }

    #[test]
    fn rails_use_disjoint_nic_links() {
        let t = railed(2, 8, 2, 1.0);
        let r0 = t.route_tc(0, 8, crate::config::TrafficClass::Rail(0));
        let r1 = t.route_tc(0, 8, crate::config::TrafficClass::Rail(1));
        assert_ne!(r0.links[0], r1.links[0], "tx rails disjoint");
        assert_ne!(r0.links[1], r1.links[1], "rx rails disjoint");
        // each rail carries half the aggregate NIC bandwidth
        assert_eq!(
            t.link(r0.links[0]).bw.to_bits(),
            (t.cluster.hw.nic_bw / 2.0).to_bits()
        );
    }

    #[test]
    fn nonblocking_fabric_matches_flat_link_set() {
        // rails=1, oversub=1.0 must produce the seed's exact link set and
        // routes: same count, same kinds, same capacities, same latency.
        let flat = Topology::build(ClusterSpec::h800(2, 8));
        let routed = Topology::build(
            ClusterSpec::h800(2, 8).with_fabric(FabricSpec::flat()),
        );
        assert_eq!(flat.link_count(), routed.link_count());
        for (a, b) in [(0usize, 9usize), (3, 12), (1, 1), (0, 5)] {
            let ra = flat.route(a, b);
            let rb = routed.route(a, b);
            assert_eq!(ra.links, rb.links);
            assert_eq!(ra.latency.to_bits(), rb.latency.to_bits());
        }
        assert_eq!(
            flat.inter_path_bw().to_bits(),
            flat.cluster.hw.nic_bw.to_bits()
        );
    }

    #[test]
    fn auto_rail_is_deterministic_and_in_range() {
        let t = railed(2, 8, 4, 1.0);
        for s in 0..8usize {
            for d in 8..16usize {
                let r1 = t.route(s, d);
                let r2 = t.route(s, d);
                assert_eq!(r1.links, r2.links);
            }
        }
    }

    // -- rail router --------------------------------------------------------

    use crate::config::RailPolicy;

    #[test]
    fn static_router_is_route_tc_passthrough() {
        let t = railed(2, 8, 2, 2.0);
        let router = Router::new(&t); // fabric policy defaults to Static
        assert_eq!(router.policy(), RailPolicy::Static);
        let mut occ = LinkOccupancy::new(t.link_count());
        // even under heavy recorded load, Static ignores occupancy
        occ.commit(&t.route_tc(0, 8, TrafficClass::Rail(0)).links, 1e12);
        for tc in [
            TrafficClass::Auto,
            TrafficClass::Rail(1),
            TrafficClass::Rails { tx: 0, rx: 1 },
        ] {
            let a = router.route(1, 9, tc, &occ);
            let b = t.route_tc(1, 9, tc);
            assert_eq!(a.links, b.links);
            assert_eq!(a.latency.to_bits(), b.latency.to_bits());
        }
    }

    #[test]
    fn adaptive_router_moves_off_loaded_plane() {
        let t = railed(2, 8, 2, 1.0);
        let router = Router::with_policy(&t, RailPolicy::Adaptive);
        let mut occ = LinkOccupancy::new(t.link_count());
        // empty fabric: deterministic tie-break to rail 0
        let r0 = router.route(0, 8, TrafficClass::Auto, &occ);
        assert_eq!(t.link(r0.links[0]).kind, LinkKind::NicTx);
        occ.commit(&r0.links, 1e9);
        // rail 0 now carries a committed flow; the next pick balances
        let r1 = router.route(0, 8, TrafficClass::Auto, &occ);
        assert_ne!(r0.links[0], r1.links[0]);
        occ.commit(&r1.links, 1e9);
        // equal fills: tie-break back to rail 0
        let r2 = router.route(0, 8, TrafficClass::Auto, &occ);
        assert_eq!(r2.links[0], r0.links[0]);
        // explicit pins are honored regardless of load
        let pinned = router.route(0, 8, TrafficClass::Rail(0), &occ);
        assert_eq!(pinned.links[0], r0.links[0]);
    }

    #[test]
    fn adaptive_router_sees_shared_tier_congestion() {
        // load rail 0's *spine plane* through a different endpoint pair;
        // the adaptive pick for (0 -> 8) must still avoid plane 0 even
        // though 0's own NIC links are idle.
        let t = railed(4, 8, 2, 2.0);
        let router = Router::with_policy(&t, RailPolicy::Adaptive);
        let mut occ = LinkOccupancy::new(t.link_count());
        let other = t.route_tc(17, 25, TrafficClass::Rail(0));
        occ.commit(&other.links, 1e9);
        let r = router.route(0, 8, TrafficClass::Auto, &occ);
        let spine_owner = r
            .links
            .iter()
            .find(|&&l| t.link(l).kind == LinkKind::Spine)
            .map(|&l| t.link(l).owner)
            .expect("blocking route must cross a spine plane");
        assert_eq!(spine_owner, 1, "router should pick the empty plane 1");
    }

    // -- fabric health / fault resolution ----------------------------------

    use crate::config::FaultTarget;

    #[test]
    fn fault_links_resolve_per_target() {
        let t = railed(2, 8, 2, 2.0);
        // NIC: exactly the tx+rx pair of that (rank, rail)
        let nic = t.fault_links(&FaultTarget::Nic { rank: 3, rail: 1 });
        assert_eq!(nic.len(), 2);
        assert_eq!(t.link(nic[0]).kind, LinkKind::NicTx);
        assert_eq!(t.link(nic[1]).kind, LinkKind::NicRx);
        assert!(nic.iter().all(|&l| t.link(l).owner == 3));
        // spine: the one plane link
        let spine = t.fault_links(&FaultTarget::Spine { rail: 0 });
        assert_eq!(spine.len(), 1);
        assert_eq!(t.link(spine[0]).kind, LinkKind::Spine);
        assert_eq!(t.link(spine[0]).owner, 0);
        // whole rail: every NIC pair + both leaf dirs per node + spine
        let rail = t.fault_links(&FaultTarget::Rail { rail: 1 });
        assert_eq!(rail.len(), 16 * 2 + 2 * 2 + 1);
        // out-of-range / absent targets are inert, not errors
        assert!(t.fault_links(&FaultTarget::Nic { rank: 99, rail: 0 }).is_empty());
        assert!(t.fault_links(&FaultTarget::Spine { rail: 7 }).is_empty());
        let flat = Topology::build(ClusterSpec::h800(2, 8));
        assert!(flat.fault_links(&FaultTarget::Spine { rail: 0 }).is_empty());
        let single = Topology::build(ClusterSpec::h800(1, 8));
        assert!(single
            .fault_links(&FaultTarget::Nic { rank: 0, rail: 0 })
            .is_empty());
    }

    #[test]
    fn health_tracks_degraded_count() {
        let mut h = FabricHealth::healthy(3);
        assert!(h.all_healthy());
        h.set_factor(LinkId(1), 0.5);
        h.set_factor(LinkId(2), 0.0);
        assert!(!h.all_healthy());
        assert!(h.is_down(LinkId(2)));
        assert!(!h.is_down(LinkId(1)));
        let r = Route {
            links: vec![LinkId(0), LinkId(2)],
            latency: 0.0,
        };
        assert!(!h.route_alive(&r));
        h.set_factor(LinkId(2), 1.0);
        assert!(h.route_alive(&r));
        h.set_factor(LinkId(1), 1.0);
        assert!(h.all_healthy());
    }

    #[test]
    fn adaptive_router_excludes_dead_rail() {
        let t = railed(2, 8, 2, 1.0);
        let router = Router::with_policy(&t, RailPolicy::Adaptive);
        let occ = LinkOccupancy::new(t.link_count());
        let mut health = FabricHealth::healthy(t.link_count());
        // kill rank 0's rail-0 NIC: the empty-fabric tie must now break
        // to rail 1 instead of rail 0
        for l in t.fault_links(&FaultTarget::Nic { rank: 0, rail: 0 }) {
            health.set_factor(l, 0.0);
        }
        let r = router.route_faulty(0, 8, TrafficClass::Auto, &occ, Some(&health));
        let r1 = t.route_tc(0, 8, TrafficClass::Rail(1));
        assert_eq!(r.links, r1.links, "dead plane must be excluded");
        // other endpoints are unaffected by rank 0's NIC fault
        let other = router.route_faulty(1, 9, TrafficClass::Auto, &occ, Some(&health));
        let other0 = t.route_tc(1, 9, TrafficClass::Rail(0));
        assert_eq!(other.links, other0.links);
        // all planes dead: fall back to ordinary scoring (flow will
        // stall into the retry machinery rather than panic)
        for l in t.fault_links(&FaultTarget::Rail { rail: 0 }) {
            health.set_factor(l, 0.0);
        }
        for l in t.fault_links(&FaultTarget::Rail { rail: 1 }) {
            health.set_factor(l, 0.0);
        }
        let dead = router.route_faulty(0, 8, TrafficClass::Auto, &occ, Some(&health));
        assert_eq!(dead.links.len(), 2);
    }

    #[test]
    fn pinned_rail_self_heals_under_adaptive_only() {
        let t = railed(2, 8, 2, 1.0);
        let occ = LinkOccupancy::new(t.link_count());
        let mut health = FabricHealth::healthy(t.link_count());
        for l in t.fault_links(&FaultTarget::Nic { rank: 0, rail: 0 }) {
            health.set_factor(l, 0.0);
        }
        // adaptive: the pin is a hint — a dead pinned plane reroutes to
        // the alive one (the EP dispatch/combine pins heal this way)
        let adaptive = Router::with_policy(&t, RailPolicy::Adaptive);
        let healed = adaptive.route_faulty(0, 8, TrafficClass::Rail(0), &occ, Some(&health));
        assert_eq!(healed.links, t.route_tc(0, 8, TrafficClass::Rail(1)).links);
        let rails = adaptive.route_faulty(
            0,
            8,
            TrafficClass::Rails { tx: 0, rx: 0 },
            &occ,
            Some(&health),
        );
        assert_eq!(rails.links, t.route_tc(0, 8, TrafficClass::Rail(1)).links);
        // an alive pin is returned untouched (bit-identity under an
        // active-but-idle plan)
        let alive = adaptive.route_faulty(0, 8, TrafficClass::Rail(1), &occ, Some(&health));
        let blind = t.route_tc(0, 8, TrafficClass::Rail(1));
        assert_eq!(alive.links, blind.links);
        assert_eq!(alive.latency.to_bits(), blind.latency.to_bits());
        // static honors the pin: the flow stalls into the retry machinery
        let stat = Router::with_policy(&t, RailPolicy::Static);
        let pinned = stat.route_faulty(0, 8, TrafficClass::Rail(0), &occ, Some(&health));
        assert_eq!(pinned.links, t.route_tc(0, 8, TrafficClass::Rail(0)).links);
        assert!(!health.route_alive(&pinned));
    }

    #[test]
    fn adaptive_router_deflates_degraded_rail() {
        let t = railed(2, 8, 2, 1.0);
        let router = Router::with_policy(&t, RailPolicy::Adaptive);
        let mut occ = LinkOccupancy::new(t.link_count());
        let mut health = FabricHealth::healthy(t.link_count());
        // equal committed load on both planes; rail 0 at 25% capacity
        // now looks 4x fuller, so the router must pick rail 1 (the
        // healthy-occupancy tie-break would have chosen rail 0)
        occ.commit(&t.route_tc(0, 8, TrafficClass::Rail(0)).links, 1e6);
        occ.commit(&t.route_tc(0, 8, TrafficClass::Rail(1)).links, 1e6);
        for l in t.fault_links(&FaultTarget::Nic { rank: 0, rail: 0 }) {
            health.set_factor(l, 0.25);
        }
        let r = router.route_faulty(0, 8, TrafficClass::Auto, &occ, Some(&health));
        let r1 = t.route_tc(0, 8, TrafficClass::Rail(1));
        assert_eq!(r.links, r1.links, "degraded plane scores fuller");
        // with every factor back at 1.0 the health-aware path is
        // bit-identical to the blind one
        for l in t.fault_links(&FaultTarget::Nic { rank: 0, rail: 0 }) {
            health.set_factor(l, 1.0);
        }
        let a = router.route_faulty(0, 8, TrafficClass::Auto, &occ, Some(&health));
        let b = router.route(0, 8, TrafficClass::Auto, &occ);
        assert_eq!(a.links, b.links);
        assert_eq!(a.latency.to_bits(), b.latency.to_bits());
    }

    #[test]
    fn occupancy_release_clamps_and_counts() {
        let mut occ = LinkOccupancy::new(2);
        occ.commit(&[LinkId(0)], 100.0);
        occ.commit(&[LinkId(0)], 50.0);
        assert_eq!(occ.in_flight(LinkId(0)), 2);
        occ.release(&[LinkId(0)], 100.0);
        occ.release(&[LinkId(0)], 50.0);
        assert_eq!(occ.committed_bytes(LinkId(0)), 0.0);
        assert_eq!(occ.in_flight(LinkId(0)), 0);
        // dust never goes negative
        occ.release(&[LinkId(1)], 1.0);
        assert_eq!(occ.committed_bytes(LinkId(1)), 0.0);
        assert_eq!(occ.in_flight(LinkId(1)), 0);
    }
}
