//! Interconnect topology models (§3.7, Fig. 6).
//!
//! Three intra-node fabrics are modeled, matching the paper's testbeds:
//!
//! * **H800 / NVSwitch** — every GPU has one aggregate NVLink egress port
//!   and one ingress port (~170 GB/s each) into a non-blocking switch.
//! * **MI308X / full mesh** — a dedicated 50 GB/s link per ordered GPU
//!   pair; the 350 GB/s aggregate is only reachable by using all seven
//!   peer links simultaneously (this is what drives the Fig. 8 swizzle).
//! * **L20 / PCIe** — per-GPU PCIe up/down links plus a shared per-NUMA
//!   root-complex link that creates the contention the paper's PCIe
//!   scheduling optimization must avoid.
//!
//! Inter-node transfers go over per-GPU NIC tx/rx links (rail-optimized,
//! GPUDirect-style: no intra-node hop is charged). Local (same-rank)
//! copies are charged to a per-GPU HBM read+write link.
//!
//! A [`Route`] is the set of links a flow occupies plus a propagation
//! latency; the DES engine max–min fair-shares link capacity among all
//! concurrent flows (see `sim::flow`).

use crate::config::{ClusterSpec, HardwareKind};

/// Index into [`Topology::links`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LinkId(pub usize);

/// What a link physically is (for traces and tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    NvlEgress,
    NvlIngress,
    MeshPair,
    PcieUp,
    PcieDown,
    PcieHost,
    NicTx,
    NicRx,
    Hbm,
}

/// A shared, capacity-limited channel.
#[derive(Debug, Clone)]
pub struct Link {
    pub kind: LinkKind,
    /// Capacity in bytes/s.
    pub bw: f64,
    /// Owning rank (or NUMA id for PcieHost), for diagnostics.
    pub owner: usize,
}

/// The links a transfer occupies and its propagation latency.
#[derive(Debug, Clone)]
pub struct Route {
    pub links: Vec<LinkId>,
    pub latency: f64,
}

/// Immutable interconnect graph for one cluster.
pub struct Topology {
    pub cluster: ClusterSpec,
    links: Vec<Link>,
    // per-rank link ids (usize::MAX = absent)
    intra_egress: Vec<usize>,
    intra_ingress: Vec<usize>,
    nic_tx: Vec<usize>,
    nic_rx: Vec<usize>,
    hbm: Vec<usize>,
    pcie_host: Vec<usize>, // per NUMA domain
    mesh: std::collections::HashMap<(usize, usize), usize>,
}

impl Topology {
    pub fn build(cluster: ClusterSpec) -> Self {
        let ws = cluster.world_size();
        let hw = cluster.hw;
        let mut links = Vec::new();
        let push = |kind: LinkKind, bw: f64, owner: usize, links: &mut Vec<Link>| {
            links.push(Link { kind, bw, owner });
            links.len() - 1
        };

        let mut topo = Topology {
            cluster,
            links: Vec::new(),
            intra_egress: vec![usize::MAX; ws],
            intra_ingress: vec![usize::MAX; ws],
            nic_tx: vec![usize::MAX; ws],
            nic_rx: vec![usize::MAX; ws],
            hbm: vec![usize::MAX; ws],
            pcie_host: Vec::new(),
            mesh: Default::default(),
        };

        for r in 0..ws {
            topo.hbm[r] = push(LinkKind::Hbm, hw.hbm_bw / 2.0, r, &mut links);
        }

        match hw.kind {
            HardwareKind::H800 => {
                for r in 0..ws {
                    topo.intra_egress[r] =
                        push(LinkKind::NvlEgress, hw.intra_bw, r, &mut links);
                    topo.intra_ingress[r] =
                        push(LinkKind::NvlIngress, hw.intra_bw, r, &mut links);
                }
            }
            HardwareKind::MI308X => {
                // dedicated link per ordered pair within the node
                for a in 0..ws {
                    for b in 0..ws {
                        if a != b && cluster.node_of(a) == cluster.node_of(b) {
                            let id = push(LinkKind::MeshPair, hw.intra_link_bw, a, &mut links);
                            topo.mesh.insert((a, b), id);
                        }
                    }
                }
            }
            HardwareKind::L20 => {
                for r in 0..ws {
                    topo.intra_egress[r] = push(LinkKind::PcieUp, hw.intra_bw, r, &mut links);
                    topo.intra_ingress[r] =
                        push(LinkKind::PcieDown, hw.intra_bw, r, &mut links);
                }
                // shared per-NUMA root complex: 2x a single device link
                let numa_domains = cluster.nodes * cluster.numa_per_node;
                for d in 0..numa_domains {
                    let id = push(LinkKind::PcieHost, hw.intra_bw * 2.0, d, &mut links);
                    topo.pcie_host.push(id);
                }
            }
        }

        if cluster.nodes > 1 {
            for r in 0..ws {
                topo.nic_tx[r] = push(LinkKind::NicTx, hw.nic_bw, r, &mut links);
                topo.nic_rx[r] = push(LinkKind::NicRx, hw.nic_bw, r, &mut links);
            }
        }

        topo.links = links;
        topo
    }

    pub fn link(&self, id: LinkId) -> &Link {
        &self.links[id.0]
    }

    pub fn link_count(&self) -> usize {
        self.links.len()
    }

    /// Route for a transfer `src -> dst` (same-rank = local HBM copy).
    pub fn route(&self, src: usize, dst: usize) -> Route {
        let c = &self.cluster;
        let hw = c.hw;
        if src == dst {
            return Route {
                links: vec![LinkId(self.hbm[src])],
                latency: 0.0,
            };
        }
        if c.node_of(src) != c.node_of(dst) {
            assert!(
                self.nic_tx[src] != usize::MAX,
                "inter-node route on single-node cluster"
            );
            return Route {
                links: vec![LinkId(self.nic_tx[src]), LinkId(self.nic_rx[dst])],
                latency: hw.inter_lat,
            };
        }
        match hw.kind {
            HardwareKind::H800 => Route {
                links: vec![
                    LinkId(self.intra_egress[src]),
                    LinkId(self.intra_ingress[dst]),
                ],
                latency: hw.intra_lat,
            },
            HardwareKind::MI308X => Route {
                links: vec![LinkId(self.mesh[&(src, dst)])],
                latency: hw.intra_lat,
            },
            HardwareKind::L20 => {
                let mut links = vec![
                    LinkId(self.intra_egress[src]),
                    LinkId(self.intra_ingress[dst]),
                ];
                let numa_s = c.numa_of(src);
                let numa_d = c.numa_of(dst);
                links.push(LinkId(self.pcie_host[numa_s]));
                if numa_d != numa_s {
                    links.push(LinkId(self.pcie_host[numa_d]));
                }
                Route {
                    links,
                    latency: hw.intra_lat
                        * if numa_s == numa_d { 1.0 } else { 1.6 }, // NUMA penalty
                }
            }
        }
    }

    /// Route for `multimem.st`: one store fans out to every other rank in
    /// the node (H800 only). The flow occupies the source egress and every
    /// peer ingress; latency is the measured multimem cost (§3.4).
    pub fn multimem_route(&self, src: usize) -> Option<Route> {
        let hw = self.cluster.hw;
        if hw.kind != HardwareKind::H800 {
            return None;
        }
        let node = self.cluster.node_of(src);
        let mut links = vec![LinkId(self.intra_egress[src])];
        for r in 0..self.cluster.world_size() {
            if r != src && self.cluster.node_of(r) == node {
                links.push(LinkId(self.intra_ingress[r]));
            }
        }
        Some(Route {
            links,
            latency: hw.multimem_lat,
        })
    }

    /// Local HBM route (used for in-place reductions modeled as copies).
    pub fn hbm_route(&self, rank: usize) -> Route {
        Route {
            links: vec![LinkId(self.hbm[rank])],
            latency: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;

    #[test]
    fn h800_intra_route_uses_egress_and_ingress() {
        let t = Topology::build(ClusterSpec::h800(1, 8));
        let r = t.route(0, 3);
        assert_eq!(r.links.len(), 2);
        assert_eq!(t.link(r.links[0]).kind, LinkKind::NvlEgress);
        assert_eq!(t.link(r.links[1]).kind, LinkKind::NvlIngress);
        assert_eq!(t.link(r.links[0]).owner, 0);
        assert_eq!(t.link(r.links[1]).owner, 3);
        assert!((r.latency - 0.5e-6).abs() < 1e-12);
    }

    #[test]
    fn h800_inter_route_uses_nics() {
        let t = Topology::build(ClusterSpec::h800(2, 8));
        let r = t.route(1, 9); // rank 1 node 0 -> rank 9 node 1
        assert_eq!(t.link(r.links[0]).kind, LinkKind::NicTx);
        assert_eq!(t.link(r.links[1]).kind, LinkKind::NicRx);
        assert!(r.latency > 1e-6);
    }

    #[test]
    fn amd_mesh_has_per_pair_links() {
        let t = Topology::build(ClusterSpec::mi308x(8));
        let r01 = t.route(0, 1);
        let r02 = t.route(0, 2);
        assert_eq!(r01.links.len(), 1);
        assert_ne!(r01.links[0], r02.links[0], "pair links must be disjoint");
        assert_eq!(t.link(r01.links[0]).bw, 50e9);
    }

    #[test]
    fn local_route_is_hbm() {
        let t = Topology::build(ClusterSpec::h800(1, 8));
        let r = t.route(5, 5);
        assert_eq!(t.link(r.links[0]).kind, LinkKind::Hbm);
        assert_eq!(r.latency, 0.0);
    }

    #[test]
    fn multimem_covers_all_node_peers() {
        let t = Topology::build(ClusterSpec::h800(2, 8));
        let r = t.multimem_route(2).unwrap();
        // 1 egress + 7 peer ingress links, all same node
        assert_eq!(r.links.len(), 8);
        assert!((r.latency - 1.5e-6).abs() < 1e-12);
        // AMD has no multimem
        let amd = Topology::build(ClusterSpec::mi308x(8));
        assert!(amd.multimem_route(0).is_none());
    }

    #[test]
    fn l20_routes_share_host_link() {
        let t = Topology::build(ClusterSpec::l20(1, 8));
        let r = t.route(0, 1); // same NUMA (ranks 0-3 = NUMA 0)
        assert_eq!(r.links.len(), 3);
        let cross = t.route(0, 5); // cross NUMA
        assert_eq!(cross.links.len(), 4);
        assert!(cross.latency > r.latency);
    }

    #[test]
    #[should_panic]
    fn inter_node_route_panics_on_single_node() {
        let t = Topology::build(ClusterSpec::h800(1, 8));
        // route() with ranks out of the single node is a bug in the caller
        let _ = t.route(0, 12);
    }
}
