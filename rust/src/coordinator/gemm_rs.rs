//! GEMM+RS: GEMM producing partial sums, overlapped with ReduceScatter
//! (Figs. 9, 10, 12, 14, 18) — ours plus PyTorch+NCCL and FLUX baselines.
//!
//! Data model (tensor-parallel row sharding): every rank holds `[M, K/ws]`
//! activations and `[K/ws, N]` weights; its GEMM yields an `[M, N]`
//! *partial* sum. ReduceScatter sums partials and leaves rank `r` with
//! rows `[r*M/ws, (r+1)*M/ws)`.

use crate::collectives::baseline::nccl_reduce_scatter_ring;
use crate::collectives::reduce_scatter::{rs_fused_amd, rs_inter, rs_push_intra};
use crate::collectives::{ProgBuild, RsBufs};
use crate::config::{ClusterSpec, GemmShape};
use crate::kernels::names::Entry;
use crate::mem::{BufId, Slice, SymmetricHeap};
use crate::overlap::swizzle;
use crate::overlap::{plan_inter_rs, plan_intra_ag};
use crate::program::{ComputeCost, NumericOp, Op, Scope, SigCond, SigOp};
use crate::util::Rng;

use super::{setup, BuiltOp};

/// Which GEMM+RS implementation to build.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmRsVariant {
    /// Ours intra-node: producer GEMM chunks + async scatter on the copy
    /// engine + incremental reduction on a small SM budget.
    OursIntra,
    /// Ours inter-node: Alg. 5 heterogeneous pipeline + Fig. 10 swizzle.
    OursInter,
    /// Ours on AMD: scatter fused into the producer (§3.6).
    OursAmd { comm_tiles: usize },
    /// PyTorch+NCCL: full vendor GEMM, sync, ring ReduceScatter.
    Nccl,
    /// FLUX-like: scatter fused into the (vendor) GEMM + global sync
    /// before a full-device reduction (no reduction overlap).
    Flux,
    /// Ablation: ours without the chunk-order swizzle.
    NoSwizzle,
}

impl GemmRsVariant {
    pub fn label(&self) -> String {
        match self {
            GemmRsVariant::OursIntra => "ours(intra)".into(),
            GemmRsVariant::OursInter => "ours(inter)".into(),
            GemmRsVariant::OursAmd { comm_tiles } => format!("ours(amd,ct={comm_tiles})"),
            GemmRsVariant::Nccl => "pytorch+nccl".into(),
            GemmRsVariant::Flux => "flux".into(),
            GemmRsVariant::NoSwizzle => "ours(no-swizzle)".into(),
        }
    }
}

pub struct GemmRsBufs {
    pub act: BufId,
    pub weight: BufId,
    pub rs: RsBufs,
    pub m_per_rank: usize,
    pub k_local: usize,
    pub n: usize,
}

/// Producer signal base floor: chunk `c` ready on this rank. The actual
/// base is raised above every ReduceScatter signal footprint at build
/// time (see [`build`]) so large clusters can't alias producer signals
/// with RS stage/partial signals.
const PROD_SIG_BASE: usize = 100;

/// Build the program. `shape.m` is global M; `shape.k` is the *local* K
/// shard; `shape.n` the full N.
pub fn build(
    cluster: ClusterSpec,
    shape: GemmShape,
    variant: GemmRsVariant,
) -> (BuiltOp, GemmRsBufs) {
    let (ctx, topo) = setup(cluster);
    let ws = ctx.n_pes();
    assert!(shape.m % ws == 0);
    let m_per_rank = shape.m / ws;
    let shard = m_per_rank * shape.n;
    let hw = cluster.hw;

    // chunk-ready signals live above every RS variant's footprint
    let prod_sig_base = PROD_SIG_BASE.max(crate::collectives::rs_sig_span(&ctx));
    let mut heap = SymmetricHeap::new(ws, prod_sig_base + ws + 8);
    let act = heap.alloc("act", shape.m * shape.k);
    let weight = heap.alloc("weight", shape.k * shape.n);
    let rs = RsBufs::alloc(&mut heap, &ctx, shard);
    let bufs = GemmRsBufs {
        act,
        weight,
        rs,
        m_per_rank,
        k_local: shape.k,
        n: shape.n,
    };

    let mut pb = ProgBuild::new();
    pb.claim_sigs("gemm_rs_producer", prod_sig_base, ws);
    let chunk_flops = 2.0 * m_per_rank as f64 * shape.n as f64 * shape.k as f64;
    let gemm_entry = Entry::gemm_name(m_per_rank, shape.k, shape.n);
    // §3.5 balance from the *routed* inter-node path capacity (fair
    // share through the leaf/spine tiers), not the raw NIC speed
    let part = plan_inter_rs(&hw, ctx.local_world_size(), topo.inter_path_bw());

    // ---- producer GEMM -------------------------------------------------------
    let (gemm_sms, vendor, fused_store) = match variant {
        GemmRsVariant::Nccl => (hw.sms, true, false),
        GemmRsVariant::Flux => (hw.sms, true, true),
        // fused stores ride the producer's CUs; reserve the reduction only
        GemmRsVariant::OursAmd { .. } => (hw.sms - 16, false, false),
        GemmRsVariant::OursInter => (part.gemm_sms, false, false),
        _ => (plan_intra_ag(&hw).gemm_sms - 16, false, false), // leave room for the reduce stream
    };

    for r in 0..ws {
        let order: Vec<usize> = match variant {
            GemmRsVariant::OursInter => {
                swizzle::inter_rs_order(r, ctx.n_nodes(), ctx.local_world_size())
            }
            GemmRsVariant::NoSwizzle | GemmRsVariant::Nccl | GemmRsVariant::Flux => {
                swizzle::identity_order(r, ws)
            }
            _ => swizzle::nv_pull_order(r, ws).into_iter().skip(1).chain([r]).collect(),
        };
        let mut t = ctx
            .task(r, format!("producer_gemm[{r}]"))
            .with_sms(gemm_sms)
            .launch_overhead();
        for &chunk in &order {
            t.op(Op::Compute {
                cost: ComputeCost::Gemm {
                    flops: chunk_flops,
                    vendor,
                },
                numeric: NumericOp::Call {
                    entry: gemm_entry.clone(),
                    args: vec![
                        Slice::new(r, act, chunk * m_per_rank * shape.k, m_per_rank * shape.k),
                        Slice::new(r, weight, 0, shape.k * shape.n),
                    ],
                    outs: vec![bufs.rs.in_chunk(chunk, r)],
                },
                label: "gemm_chunk",
            });
            if fused_store {
                // FLUX: the GEMM epilogue stores the chunk remotely.
                // SM-driven stores reach ~70% of copy-engine bandwidth
                // (modeled as inflated wire bytes), and the reduction
                // cannot start until the global sync.
                t.op(Op::Put {
                    src: bufs.rs.in_chunk(chunk, r),
                    dst: bufs.rs.scatter_slot(r, chunk),
                    bytes: ctx.bytes(bufs.rs.shard) / 0.7,
                    signal: Some((
                        crate::program::SigRef {
                            rank: chunk,
                            idx: bufs.rs.scatter_sig(r),
                        },
                        SigOp::Set,
                        1,
                    )),
                    blocking: false,
                    tc: Default::default(),
                    chunk: None,
                    label: "flux_fused_store",
                });
            } else {
                t.notify(r, prod_sig_base + chunk, SigOp::Set, 1);
            }
        }
        pb.prog.push(t.build());
    }

    // ---- reduce-scatter part ---------------------------------------------------
    match variant {
        GemmRsVariant::OursIntra | GemmRsVariant::NoSwizzle => {
            rs_push_intra(&ctx, &bufs.rs, &mut pb, 15, Some(prod_sig_base));
        }
        GemmRsVariant::OursInter => {
            // Alg. 5 pipeline, chunk-gated on the producer GEMM: the Fig. 10
            // swizzle makes the producer emit exactly the chunks the
            // scatter's walk consumes first.
            rs_inter(
                &ctx,
                &bufs.rs,
                &mut pb,
                part.reduce1_sms,
                part.reduce2_sms,
                Some(prod_sig_base),
            );
        }
        GemmRsVariant::OursAmd { comm_tiles } => {
            rs_fused_amd(&ctx, &bufs.rs, &mut pb, comm_tiles, 16, Some(prod_sig_base));
        }
        GemmRsVariant::Nccl => {
            // operator-level: ring RS runs after the full GEMM
            gate_ring_on_producer(&ctx, &bufs, &mut pb, ws, prod_sig_base);
        }
        GemmRsVariant::Flux => {
            // global sync then full-device reduction (no overlap); the
            // fused stores own the scatter-arrival signal range
            pb.claim_sigs("flux_scatter", bufs.rs.sig_base, ws);
            let bid = pb.fresh_barrier();
            for r in 0..ws {
                let mut red = ctx
                    .task(r, format!("flux_reduce[{r}]"))
                    .with_sms(hw.sms)
                    .launch_overhead();
                for s in 0..ws {
                    red.signal_wait_until(bufs.rs.scatter_sig(s), SigCond::Ge, 1);
                }
                red.barrier_group(bid, Scope::World, ws);
                red.op(Op::Compute {
                    cost: ComputeCost::Reduce {
                        bytes: ctx.bytes(bufs.rs.shard) as f64 * ws as f64,
                    },
                    numeric: NumericOp::ReduceAdd {
                        srcs: (0..ws).map(|s| bufs.rs.scatter_slot(s, r)).collect(),
                        dst: bufs.rs.out(r),
                        zero_dst: true,
                    },
                    label: "flux_reduce",
                });
                pb.prog.push(red.build());
            }
        }
    }

    let op = BuiltOp {
        ctx,
        heap,
        prog: pb.prog,
        name: format!("GEMM+RS {}", variant.label()),
    };
    (op, bufs)
}

/// PyTorch+NCCL sequencing: the ring RS kernels wait until every producer
/// chunk signal on their rank is set (the stream-order dependency).
fn gate_ring_on_producer(
    ctx: &crate::shmem::ShmemCtx,
    bufs: &GemmRsBufs,
    pb: &mut ProgBuild,
    ws: usize,
    prod_sig_base: usize,
) {
    // adapter tasks turn "all chunks ready" into one gate signal...
    // simpler: ring tasks themselves wait all producer signals first.
    let before = pb.prog.tasks.len();
    nccl_reduce_scatter_ring(ctx, &bufs.rs, pb, 16);
    for task in pb.prog.tasks.iter_mut().skip(before) {
        let mut gates: Vec<crate::program::Op> = (0..ws)
            .map(|c| crate::program::Op::WaitSignal {
                idx: prod_sig_base + c,
                cond: SigCond::Eq,
                value: 1,
            })
            .collect();
        gates.extend(task.ops.drain(..));
        task.ops = gates;
    }
}

/// Seed activations/weights (distinct per rank — each rank's GEMM output
/// is a genuine partial sum).
pub fn fill_inputs(heap: &mut SymmetricHeap, bufs: &GemmRsBufs, seed: u64) {
    for r in 0..heap.world() {
        let mut rng = Rng::new(seed ^ ((r as u64) << 8));
        let a = rng.normal_vec(heap.buf_len(bufs.act));
        heap.write(Slice::new(r, bufs.act, 0, a.len()), &a);
        let w = rng.normal_vec(heap.buf_len(bufs.weight));
        heap.write(Slice::new(r, bufs.weight, 0, w.len()), &w);
    }
}

/// Reference: sum over ranks of (act_r @ w_r), scattered by rows.
pub fn reference_outputs(heap: &SymmetricHeap, bufs: &GemmRsBufs) -> Vec<Vec<f32>> {
    let ws = heap.world();
    let m = ws * bufs.m_per_rank;
    let mut total = vec![0.0f32; m * bufs.n];
    for r in 0..ws {
        let a = heap.read(Slice::new(r, bufs.act, 0, m * bufs.k_local));
        let w = heap.read(Slice::new(r, bufs.weight, 0, bufs.k_local * bufs.n));
        let partial = crate::kernels::exec::matmul(a, w, m, bufs.k_local, bufs.n);
        for (t, p) in total.iter_mut().zip(partial) {
            *t += p;
        }
    }
    (0..ws)
        .map(|r| total[r * bufs.m_per_rank * bufs.n..(r + 1) * bufs.m_per_rank * bufs.n].to_vec())
        .collect()
}

/// fp-tolerant verification (reduction orders differ by algorithm).
pub fn verify(heap: &SymmetricHeap, bufs: &GemmRsBufs, expected: &[Vec<f32>]) -> Result<(), String> {
    for (r, exp) in expected.iter().enumerate() {
        let got = heap.read(bufs.rs.out(r));
        for (i, (g, e)) in got.iter().zip(exp).enumerate() {
            let tol = 1e-3f32.max(e.abs() * 1e-4);
            if (g - e).abs() > tol {
                return Err(format!(
                    "GEMM+RS mismatch rank {r} elem {i}: got {g} want {e}"
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HybridExecutor;
    use crate::topology::Topology;

    fn run_and_verify(cluster: ClusterSpec, variant: GemmRsVariant) -> f64 {
        let shape = GemmShape::new(8 * cluster.world_size(), 16, 24);
        let (mut op, bufs) = build(cluster, shape, variant);
        fill_inputs(&mut op.heap, &bufs, 7);
        let expected = reference_outputs(&op.heap, &bufs);
        let topo = Topology::build(cluster);
        let mut exec = HybridExecutor::native_only();
        let rep = super::super::run_numeric(&mut op, &topo, &mut exec).unwrap();
        verify(&op.heap, &bufs, &expected).unwrap();
        rep.makespan
    }

    #[test]
    fn ours_intra_correct() {
        run_and_verify(ClusterSpec::h800(1, 8), GemmRsVariant::OursIntra);
    }

    #[test]
    fn ours_inter_correct() {
        run_and_verify(ClusterSpec::h800(2, 4), GemmRsVariant::OursInter);
    }

    #[test]
    fn amd_correct() {
        run_and_verify(ClusterSpec::mi308x(8), GemmRsVariant::OursAmd { comm_tiles: 4 });
    }

    #[test]
    fn nccl_correct() {
        run_and_verify(ClusterSpec::h800(1, 4), GemmRsVariant::Nccl);
    }

    #[test]
    fn flux_correct() {
        run_and_verify(ClusterSpec::h800(1, 4), GemmRsVariant::Flux);
    }

    #[test]
    fn no_swizzle_correct() {
        run_and_verify(ClusterSpec::h800(1, 8), GemmRsVariant::NoSwizzle);
    }

    #[test]
    fn overlap_beats_nccl() {
        let cluster = ClusterSpec::h800(1, 8);
        let shape = GemmShape::new(4096, 12288 / 8, 4096);
        let topo = Topology::build(cluster);
        let t = |v: GemmRsVariant| {
            let (mut op, _b) = build(cluster, shape, v);
            super::super::run_timing(&mut op, &topo).unwrap()
        };
        let ours = t(GemmRsVariant::OursIntra);
        let nccl = t(GemmRsVariant::Nccl);
        assert!(ours < nccl, "ours {ours} vs nccl {nccl}");
        let speedup = nccl / ours;
        assert!(speedup > 1.03 && speedup < 3.0, "speedup {speedup}");
    }
}
