//! Distributed flash decoding (Fig. 15): every rank computes partial
//! attention over its KV-cache shard, partials are gathered with the
//! low-latency AllGather, and a combine kernel merges them. Scales decode
//! to many GPUs; the metric is achieved per-GPU HBM bandwidth.

use crate::collectives::allgather::{ag_ll_inter_gated, ag_ll_intra_gated, ag_ll_pcie};
use crate::collectives::{AgBufs, ProgBuild};
use crate::config::ClusterSpec;
use crate::kernels::names::Entry;
use crate::mem::{BufId, Slice, SymmetricHeap};
use crate::program::{ComputeCost, NumericOp, Op, SigCond, SigOp};
use crate::util::Rng;

use super::{setup, BuiltOp};

/// Flash-decode configuration (batch size 1, per the paper).
#[derive(Debug, Clone, Copy)]
pub struct FlashDecodeCfg {
    pub heads: usize,
    pub head_dim: usize,
    /// KV length held by each rank.
    pub kv_per_rank: usize,
    /// Allocate and run real numerics (tests); timing-only benches use
    /// `false` so 1M-token KV caches don't allocate gigabytes.
    pub numeric: bool,
}

pub struct FlashDecodeBufs {
    pub q: BufId,
    pub k: BufId,
    pub v: BufId,
    /// Gathered partials: per-rank segment = [o(h*d) | m(h) | l(h)].
    pub ag: AgBufs,
    pub out: BufId,
    pub cfg: FlashDecodeCfg,
}

/// Floor for the readiness signal set by the partial kernel; the actual
/// id is raised above the AllGather's `[0, ws)` segment signals at build
/// time so large worlds can't alias it.
const READY_SIG: usize = 90;

/// Segment layout helpers.
impl FlashDecodeBufs {
    pub fn seg_len(cfg: &FlashDecodeCfg) -> usize {
        cfg.heads * (cfg.head_dim + 2)
    }

    fn o_part(&self, r: usize) -> Slice {
        let h = self.cfg.heads;
        let d = self.cfg.head_dim;
        self.ag.seg(r, r).sub(0, h * d)
    }

    fn m_part(&self, r: usize) -> Slice {
        let h = self.cfg.heads;
        let d = self.cfg.head_dim;
        self.ag.seg(r, r).sub(h * d, h)
    }

    fn l_part(&self, r: usize) -> Slice {
        let h = self.cfg.heads;
        let d = self.cfg.head_dim;
        self.ag.seg(r, r).sub(h * d + h, h)
    }
}

/// Build the distributed flash-decode program on any cluster (H800 uses
/// multimem LL AllGather; L20 uses the PCIe LL variant).
pub fn build(cluster: ClusterSpec, cfg: FlashDecodeCfg) -> (BuiltOp, FlashDecodeBufs) {
    let (ctx, _t) = setup(cluster);
    let ws = ctx.n_pes();
    let h = cfg.heads;
    let d = cfg.head_dim;
    let hw = cluster.hw;

    let ready_sig = READY_SIG.max(ws);
    let mut heap = SymmetricHeap::new(ws, ready_sig + 8);
    let kv_elems = if cfg.numeric { h * cfg.kv_per_rank * d } else { 1 };
    let q = heap.alloc("q", h * d);
    let k = heap.alloc("k_cache", kv_elems);
    let v = heap.alloc("v_cache", kv_elems);
    let ag = AgBufs::alloc_ll(&mut heap, &ctx, FlashDecodeBufs::seg_len(&cfg));
    let out = heap.alloc("attn_out", h * d);
    let bufs = FlashDecodeBufs { q, k, v, ag, out, cfg };

    let mut pb = ProgBuild::new();
    // the readiness gate lives above the AG segment signals [0, ws)
    pb.claim_sigs("flash_decode_ready", ready_sig, 1);
    let kv_bytes = (h * cfg.kv_per_rank * d) as f64 * ctx.dtype.bytes() as f64;

    // -- partial attention per rank (bandwidth-bound kernel)
    for r in 0..ws {
        let mut t = ctx
            .task(r, format!("decode_partial[{r}]"))
            .with_sms(hw.sms - (ws as u32).min(hw.sms / 2) - 1)
            .launch_overhead();
        t.op(Op::Compute {
            cost: ComputeCost::MemBound { bytes: kv_bytes * 2.0 },
            numeric: if cfg.numeric {
                NumericOp::Call {
                    entry: Entry::decode_partial_name(h, cfg.kv_per_rank, d),
                    args: vec![
                        Slice::new(r, q, 0, h * d),
                        Slice::new(r, k, 0, kv_elems),
                        Slice::new(r, v, 0, kv_elems),
                    ],
                    outs: vec![bufs.o_part(r), bufs.m_part(r), bufs.l_part(r)],
                }
            } else {
                NumericOp::None
            },
            label: "decode_partial",
        });
        t.notify(r, ready_sig, SigOp::Set, 1);
        pb.prog.push(t.build());
    }

    // -- low-latency AllGather of the partials, gated on readiness
    match (hw.kind, ctx.n_nodes()) {
        (crate::config::HardwareKind::H800, 1) => {
            ag_ll_intra_gated(&ctx, &bufs.ag, &mut pb, Some(ready_sig))
        }
        (crate::config::HardwareKind::H800, _) => {
            ag_ll_inter_gated(&ctx, &bufs.ag, &mut pb, Some(ready_sig))
        }
        _ => {
            // PCIe/AMD path: direct LL puts; gating folded in by making
            // the send task wait first (pcie variant packs immediately, so
            // prepend a wait via a wrapper task is overkill — the pcie
            // variant's send task starts with a pack; add the gate there)
            ag_ll_pcie_gated(&ctx, &bufs.ag, &mut pb, ready_sig)
        }
    }

    // -- combine after all partial segments arrive
    for r in 0..ws {
        let mut t = ctx
            .task(r, format!("decode_combine[{r}]"))
            .with_sms(2)
            .launch_overhead();
        for s in 0..ws {
            t.signal_wait_until(bufs.ag.sig(s), SigCond::Ge, 1);
        }
        t.op(Op::Compute {
            cost: ComputeCost::MemBound {
                bytes: (FlashDecodeBufs::seg_len(&cfg) * ws * ctx.dtype.bytes()) as f64 * 2.0,
            },
            numeric: if cfg.numeric {
                NumericOp::Call {
                    entry: format!("decode_combine_seg_h{h}_p{ws}_d{d}"),
                    args: (0..ws).map(|s| bufs.ag.seg(s, r)).collect(),
                    outs: vec![Slice::new(r, out, 0, h * d)],
                }
            } else {
                NumericOp::None
            },
            label: "decode_combine",
        });
        pb.prog.push(t.build());
    }

    let op = BuiltOp {
        ctx,
        heap,
        prog: pb.prog,
        name: format!("FlashDecode+AG ws={ws} kv={}", cfg.kv_per_rank),
    };
    (op, bufs)
}

/// PCIe LL AllGather with the readiness gate folded into the senders.
fn ag_ll_pcie_gated(
    ctx: &crate::shmem::ShmemCtx,
    bufs: &AgBufs,
    pb: &mut ProgBuild,
    ready_sig: usize,
) {
    let before = pb.prog.tasks.len();
    ag_ll_pcie(ctx, bufs, pb);
    for task in pb.prog.tasks.iter_mut().skip(before) {
        if task.name.starts_with("ag_ll_send") {
            let mut ops = vec![Op::WaitSignal {
                idx: ready_sig,
                cond: SigCond::Ge,
                value: 1,
            }];
            ops.extend(task.ops.drain(..));
            task.ops = ops;
        }
    }
}

/// Seed q/k/v (replicated q, per-rank KV shards).
pub fn fill_inputs(heap: &mut SymmetricHeap, bufs: &FlashDecodeBufs, seed: u64) {
    assert!(bufs.cfg.numeric, "fill_inputs requires numeric buffers");
    let mut rng = Rng::new(seed);
    let qv = rng.normal_vec(heap.buf_len(bufs.q));
    for r in 0..heap.world() {
        heap.write(Slice::new(r, bufs.q, 0, qv.len()), &qv);
        let mut kr = Rng::new(seed ^ ((r as u64) << 9));
        let kv = kr.normal_vec(heap.buf_len(bufs.k));
        heap.write(Slice::new(r, bufs.k, 0, kv.len()), &kv);
        let vv = kr.normal_vec(heap.buf_len(bufs.v));
        heap.write(Slice::new(r, bufs.v, 0, vv.len()), &vv);
    }
}

/// Reference: full attention over the concatenated KV of all ranks.
pub fn reference_output(heap: &SymmetricHeap, bufs: &FlashDecodeBufs) -> Vec<f32> {
    let ws = heap.world();
    let h = bufs.cfg.heads;
    let d = bufs.cfg.head_dim;
    let s_local = bufs.cfg.kv_per_rank;
    let s_total = ws * s_local;
    let q = heap.read(Slice::new(0, bufs.q, 0, h * d)).to_vec();
    // interleave per-rank shards into [h, ws*s_local, d]
    let mut k_all = vec![0.0f32; h * s_total * d];
    let mut v_all = vec![0.0f32; h * s_total * d];
    for r in 0..ws {
        let kr = heap.read(Slice::new(r, bufs.k, 0, h * s_local * d));
        let vr = heap.read(Slice::new(r, bufs.v, 0, h * s_local * d));
        for hh in 0..h {
            let dst = hh * s_total * d + r * s_local * d;
            let src = hh * s_local * d;
            k_all[dst..dst + s_local * d].copy_from_slice(&kr[src..src + s_local * d]);
            v_all[dst..dst + s_local * d].copy_from_slice(&vr[src..src + s_local * d]);
        }
    }
    let (o, m, l) = crate::kernels::exec::decode_partial(&q, &k_all, &v_all, h, s_total, d);
    crate::kernels::exec::decode_combine(&o, &m, &l, h, 1, d)
}

pub fn verify(heap: &SymmetricHeap, bufs: &FlashDecodeBufs, expected: &[f32]) -> Result<(), String> {
    for r in 0..heap.world() {
        let got = heap.read(Slice::new(r, bufs.out, 0, expected.len()));
        for (i, (g, e)) in got.iter().zip(expected).enumerate() {
            if (g - e).abs() > 1e-4_f32.max(e.abs() * 1e-4) {
                return Err(format!("flash decode mismatch rank {r} elem {i}: {g} vs {e}"));
            }
        }
    }
    Ok(())
}

/// Achieved per-GPU HBM bandwidth (the Fig. 15 metric).
pub fn achieved_bw(cfg: &FlashDecodeCfg, _cluster: &ClusterSpec, makespan: f64) -> f64 {
    let kv_bytes = (cfg.heads * cfg.kv_per_rank * cfg.head_dim * 2 * 2) as f64; // K+V bf16
    kv_bytes / makespan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HybridExecutor;
    use crate::topology::Topology;

    fn run_numeric(cluster: ClusterSpec) {
        let cfg = FlashDecodeCfg {
            heads: 4,
            head_dim: 16,
            kv_per_rank: 32,
            numeric: true,
        };
        let (mut op, bufs) = build(cluster, cfg);
        fill_inputs(&mut op.heap, &bufs, 11);
        let exp = reference_output(&op.heap, &bufs);
        let topo = Topology::build(cluster);
        let mut exec = HybridExecutor::native_only();
        super::super::run_numeric(&mut op, &topo, &mut exec).unwrap();
        verify(&op.heap, &bufs, &exp).unwrap();
    }

    #[test]
    fn intra_node_correct() {
        run_numeric(ClusterSpec::h800(1, 8));
    }

    #[test]
    fn inter_node_correct() {
        run_numeric(ClusterSpec::h800(2, 4));
    }

    #[test]
    fn pcie_correct() {
        run_numeric(ClusterSpec::l20(1, 4));
    }

    #[test]
    fn weak_scaling_holds_bandwidth() {
        // Fig. 15 weak scaling: per-GPU KV fixed, bandwidth stays high as
        // ranks grow (comm is tiny vs the KV sweep).
        let cfg = FlashDecodeCfg {
            heads: 8,
            head_dim: 64,
            kv_per_rank: 32 * 1024,
            numeric: false,
        };
        let bw = |ws: usize| {
            let cluster = ClusterSpec::h800(1, ws);
            let (mut op, _b) = build(cluster, cfg);
            let topo = Topology::build(cluster);
            let t = super::super::run_timing(&mut op, &topo).unwrap();
            achieved_bw(&cfg, &cluster, t)
        };
        let b2 = bw(2);
        let b8 = bw(8);
        assert!(b8 > 0.5 * b2, "weak scaling collapsed: {b2} -> {b8}");
    }

    #[test]
    fn strong_scaling_has_crossover() {
        // Fig. 15 strong scaling: for short global KV more GPUs don't
        // help (latency floor); for very long KV they do.
        let t = |ws: usize, kv_total: usize| {
            let cfg = FlashDecodeCfg {
                heads: 8,
                head_dim: 64,
                kv_per_rank: kv_total / ws,
                numeric: false,
            };
            let cluster = ClusterSpec::h800(1, ws);
            let (mut op, _b) = build(cluster, cfg);
            let topo = Topology::build(cluster);
            super::super::run_timing(&mut op, &topo).unwrap()
        };
        // parallel efficiency of 8 GPUs vs 2: poor at short ctx (comm
        // floor dominates), good at very long ctx — the paper's "more
        // GPUs only help beyond ~256K" shape.
        let eff = |kv: usize| (t(2, kv) / t(8, kv)) / 4.0;
        let eff_small = eff(64 * 1024);
        let eff_large = eff(1024 * 1024);
        assert!(
            eff_small < eff_large - 0.15,
            "no crossover contrast: {eff_small} vs {eff_large}"
        );
        assert!(eff_large > 0.75, "long-ctx efficiency too poor: {eff_large}");
        assert!(t(8, 1024 * 1024) < t(2, 1024 * 1024));
    }
}
