//! AG+GEMM: AllGather-then-GEMM with fine-grained overlap (Figs. 4, 7, 8,
//! 11, 13, 17) — ours plus the PyTorch+NCCL and FLUX baselines.
//!
//! Data model (tensor-parallel column sharding): every rank owns an
//! `[M/ws, K]` shard of the activations and a private `[K, N]` weight
//! shard; after AllGather each rank computes `[M, K] x [K, N]`. The
//! consumer GEMM visits per-rank chunks in swizzled order, waiting each
//! chunk's arrival signal — the paper's `wait`/`consume_token` pattern.

use crate::collectives::allgather::{
    ag_amd_mesh, ag_inter, ag_ll_intra, ag_pull_intra, ag_push_intra,
};
use crate::collectives::baseline::nccl_allgather_ring_done;
use crate::collectives::{AgBufs, ProgBuild};
use crate::config::{ClusterSpec, GemmShape};
use crate::kernels::names::Entry;
use crate::mem::{BufId, Slice, SymmetricHeap};
use crate::overlap::swizzle;
use crate::overlap::{plan_inter_ag, plan_intra_ag};
use crate::program::{ComputeCost, NumericOp, Op, SigCond, SigOp};
use crate::shmem::ShmemCtx;
use crate::util::Rng;

use super::{setup, BuiltOp};

/// Which AG+GEMM implementation to build.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AgGemmVariant {
    /// Ours, push-mode AllGather on the copy engine + swizzled consumer.
    OursPush,
    /// Ours, pull-mode (extra barrier, controlled arrival order).
    OursPull,
    /// Ours, low-latency AllGather (multimem + LL) — small-M regimes.
    OursLL,
    /// Ours, inter-node producer/consumer split (Fig. 4). Requires nodes>1.
    OursInter,
    /// Ours on the AMD full mesh with sub-chunked communication (Fig. 8).
    OursAmd { sub_chunks: usize },
    /// PyTorch+NCCL: ring AllGather, sync, then vendor GEMM.
    Nccl,
    /// FLUX-like: vendor GEMM with comm fused into the kernel (SM-driven
    /// copies + per-chunk fused-wait stalls).
    Flux,
    /// Ablation: ours without the rank-shifted swizzle (identity order).
    NoSwizzle,
}

impl AgGemmVariant {
    pub fn label(&self) -> String {
        match self {
            AgGemmVariant::OursPush => "ours(push)".into(),
            AgGemmVariant::OursPull => "ours(pull)".into(),
            AgGemmVariant::OursLL => "ours(ll)".into(),
            AgGemmVariant::OursInter => "ours(inter)".into(),
            AgGemmVariant::OursAmd { sub_chunks } => format!("ours(amd,sub={sub_chunks})"),
            AgGemmVariant::Nccl => "pytorch+nccl".into(),
            AgGemmVariant::Flux => "flux".into(),
            AgGemmVariant::NoSwizzle => "ours(no-swizzle)".into(),
        }
    }
}

/// Buffer handles of a built AG+GEMM (for numeric verification).
pub struct AgGemmBufs {
    pub ag: AgBufs,
    pub weight: BufId,
    pub output: BufId,
    pub m_per_rank: usize,
    pub k: usize,
    pub n: usize,
}

impl AgGemmBufs {
    /// Output rows produced from chunk `c`, on rank `r`.
    pub fn out_chunk(&self, c: usize, r: usize) -> Slice {
        Slice::new(r, self.output, c * self.m_per_rank * self.n, self.m_per_rank * self.n)
    }
}

/// Build the full program. `shape.m` is the global M (must divide by
/// world size); `shape.n` is the per-rank N shard.
pub fn build(cluster: ClusterSpec, shape: GemmShape, variant: AgGemmVariant) -> (BuiltOp, AgGemmBufs) {
    let (ctx, _topo) = setup(cluster);
    let ws = ctx.n_pes();
    assert!(shape.m % ws == 0, "M must divide world size");
    let m_per_rank = shape.m / ws;
    let shard = m_per_rank * shape.k;

    let mut heap = SymmetricHeap::new(ws, 4 * ws.max(16));
    let ag = match variant {
        AgGemmVariant::OursLL => AgBufs::alloc_ll(&mut heap, &ctx, shard),
        _ => AgBufs::alloc(&mut heap, &ctx, shard),
    };
    let weight = heap.alloc("weight", shape.k * shape.n);
    let output = heap.alloc("output", shape.m * shape.n);
    let bufs = AgGemmBufs {
        ag,
        weight,
        output,
        m_per_rank,
        k: shape.k,
        n: shape.n,
    };

    let mut pb = ProgBuild::new();
    let hw = cluster.hw;

    // ---- communication part -------------------------------------------------
    match variant {
        AgGemmVariant::OursPush | AgGemmVariant::NoSwizzle => ag_push_intra(&ctx, &bufs.ag, &mut pb),
        AgGemmVariant::OursPull => ag_pull_intra(&ctx, &bufs.ag, &mut pb),
        AgGemmVariant::OursLL => ag_ll_intra(&ctx, &bufs.ag, &mut pb),
        AgGemmVariant::OursInter => ag_inter(&ctx, &bufs.ag, &mut pb),
        AgGemmVariant::OursAmd { sub_chunks } => ag_amd_mesh(&ctx, &bufs.ag, &mut pb, sub_chunks),
        AgGemmVariant::Nccl => {
            let done = bufs.ag.sig_base + ws;
            nccl_allgather_ring_done(&ctx, &bufs.ag, &mut pb, 16, Some(done));
        }
        AgGemmVariant::Flux => {
            // FLUX pulls chunks with SM-driven copies fused to the GEMM
            // kernel: per-rank comm blocks burn SMs instead of the copy
            // engine.
            flux_sm_pull_ag(&ctx, &bufs.ag, &mut pb, 4);
        }
    }

    // ---- computation part ----------------------------------------------------
    let (gemm_sms, vendor) = match variant {
        AgGemmVariant::Nccl => (hw.sms, true),
        AgGemmVariant::Flux => (hw.sms - 4 * 2, true), // minus fused comm SMs
        AgGemmVariant::OursInter => (
            plan_inter_ag(&hw, ctx.local_world_size(), ctx.n_nodes()).gemm_sms,
            false,
        ),
        _ => (plan_intra_ag(&hw).gemm_sms, false),
    };
    let chunk_flops = 2.0 * m_per_rank as f64 * shape.n as f64 * shape.k as f64;
    let gemm_entry = Entry::gemm_name(m_per_rank, shape.k, shape.n);

    for r in 0..ws {
        // AMD path: Fig. 8 sub-chunk tiles, one GEMM per (chunk, sub)
        if let AgGemmVariant::OursAmd { sub_chunks } = variant {
            assert!(m_per_rank % sub_chunks == 0, "sub_chunks must divide M/ws");
            let m_sub = m_per_rank / sub_chunks;
            let sub_flops = 2.0 * m_sub as f64 * shape.n as f64 * shape.k as f64;
            let sub_entry = Entry::gemm_name(m_sub, shape.k, shape.n);
            let mut t = ctx
                .task(r, format!("consumer_gemm[{r}]"))
                .with_sms(gemm_sms)
                .launch_overhead();
            for (chunk, sub) in swizzle::amd_subchunk_order(r, ws, sub_chunks) {
                if chunk != r {
                    // pull streams Add 1 per delivered sub-chunk, in order
                    t.signal_wait_until(bufs.ag.sig(chunk), SigCond::Ge, (sub + 1) as u64);
                }
                let a = bufs.ag.seg(chunk, r).sub(sub * m_sub * shape.k, m_sub * shape.k);
                let out = Slice::new(
                    r,
                    output,
                    (chunk * m_per_rank + sub * m_sub) * shape.n,
                    m_sub * shape.n,
                );
                t.op(Op::Compute {
                    cost: ComputeCost::Gemm {
                        flops: sub_flops,
                        vendor,
                    },
                    numeric: NumericOp::Call {
                        entry: sub_entry.clone(),
                        args: vec![a, Slice::new(r, weight, 0, shape.k * shape.n)],
                        outs: vec![out],
                    },
                    label: "gemm_subchunk",
                });
            }
            pb.prog.push(t.build());
            continue;
        }
        let order: Vec<usize> = match variant {
            AgGemmVariant::NoSwizzle | AgGemmVariant::Nccl => swizzle::identity_order(r, ws),
            // FLUX swizzles too (Table 2): consumer follows its pull order
            AgGemmVariant::OursPull | AgGemmVariant::Flux => swizzle::nv_pull_order(r, ws),
            AgGemmVariant::OursInter => {
                // follow the Fig. 4 arrival pattern: own column segments
                // arrive early; order by (node distance, local distance)
                swizzle::nv_pull_order(r, ws)
            }
            _ => swizzle::nv_push_order(r, ws),
        };
        let mut t = ctx
            .task(r, format!("consumer_gemm[{r}]"))
            .with_sms(gemm_sms)
            .launch_overhead();
        if matches!(variant, AgGemmVariant::Nccl) {
            // operator-level sync: GEMM starts only after the collective
            t.signal_wait_until(bufs.ag.sig_base + ws, SigCond::Ge, 1);
        }
        for &chunk in &order {
            match variant {
                AgGemmVariant::Nccl => {}
                _ => {
                    t.signal_wait_until(bufs.ag.sig(chunk), SigCond::Ge, 1);
                }
            }
            if matches!(variant, AgGemmVariant::Flux) {
                // fused wait/copy stalls inside the GEMM kernel
                t.op(Op::Sleep {
                    secs: hw.launch_overhead * 0.5,
                });
            }
            t.op(Op::Compute {
                cost: ComputeCost::Gemm {
                    flops: chunk_flops,
                    vendor,
                },
                numeric: NumericOp::Call {
                    entry: gemm_entry.clone(),
                    args: vec![
                        bufs.ag.seg(chunk, r),
                        Slice::new(r, weight, 0, shape.k * shape.n),
                    ],
                    outs: vec![bufs.out_chunk(chunk, r)],
                },
                label: "gemm_chunk",
            });
        }
        pb.prog.push(t.build());
    }

    let op = BuiltOp {
        ctx,
        heap,
        prog: pb.prog,
        name: format!("AG+GEMM {}", variant.label()),
    };
    (op, bufs)
}

/// FLUX-style SM-driven pull AllGather: `pull_sms`-SM blocks per peer
/// getmem the remote shard (burning compute resources, unlike the copy
/// engine), signaling per chunk.
fn flux_sm_pull_ag(ctx: &ShmemCtx, bufs: &AgBufs, pb: &mut ProgBuild, pull_sms: u32) {
    let ws = ctx.n_pes();
    pb.claim_sigs("flux_sm_pull_ag", bufs.sig_base, ws);
    let bid = pb.fresh_barrier();
    for r in 0..ws {
        let mut pub_t = ctx.task(r, format!("flux_pub[{r}]")).on_host();
        pub_t.notify(r, bufs.sig(r), SigOp::Set, 1);
        pub_t.barrier_group(bid, crate::program::Scope::World, ws * 3);
        pb.prog.push(pub_t.build());
        // two puller blocks interleaving the ascending peer walk, so
        // arrivals match the consumer's pull-order swizzle
        for half in 0..2usize {
            let mut t = ctx
                .task(r, format!("flux_pull[{r}.{half}]"))
                .with_sms(pull_sms)
                .launch_overhead();
            t.barrier_group(bid, crate::program::Scope::World, ws * 3);
            for i in (1 + half..ws).step_by(2) {
                let peer = (r + i) % ws;
                t.getmem(bufs.seg(peer, peer), bufs.seg(peer, r));
                t.notify(r, bufs.sig(peer), SigOp::Set, 1);
            }
            pb.prog.push(t.build());
        }
    }
}

/// Seed inputs: distinct activations per rank, shared weight (replicated
/// per rank with identical values — TP weights are rank-local but tests
/// compare against a single-device reference).
pub fn fill_inputs(heap: &mut SymmetricHeap, bufs: &AgGemmBufs, seed: u64) {
    crate::collectives::fill_ag_inputs(heap, &bufs.ag, seed);
    let mut rng = Rng::new(seed ^ 0xDEAD);
    let w = rng.normal_vec(bufs.k * bufs.n);
    for r in 0..heap.world() {
        heap.write(Slice::new(r, bufs.weight, 0, bufs.k * bufs.n), &w);
    }
}

/// Single-device reference: gather all shards (from the heap's own-shard
/// copies) and matmul against the weight.
pub fn reference_output(heap: &SymmetricHeap, bufs: &AgGemmBufs) -> Vec<f32> {
    let ws = heap.world();
    let mut a = Vec::with_capacity(ws * bufs.m_per_rank * bufs.k);
    for s in 0..ws {
        a.extend_from_slice(heap.read(bufs.ag.seg(s, s)));
    }
    let w = heap.read(Slice::new(0, bufs.weight, 0, bufs.k * bufs.n));
    crate::kernels::exec::matmul(&a, w, ws * bufs.m_per_rank, bufs.k, bufs.n)
}

/// Verify every rank's output equals the reference bitwise (identical
/// tile-K order makes f32 results exactly equal).
pub fn verify(heap: &SymmetricHeap, bufs: &AgGemmBufs, reference: &[f32]) -> Result<(), String> {
    for r in 0..heap.world() {
        let got = heap.read(Slice::new(r, bufs.output, 0, reference.len()));
        if got != reference {
            let bad = got
                .iter()
                .zip(reference)
                .position(|(a, b)| a != b)
                .unwrap_or(0);
            return Err(format!(
                "AG+GEMM output mismatch on rank {r} at {bad}: {} vs {}",
                got[bad], reference[bad]
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HybridExecutor;
    use crate::topology::Topology;

    fn run_and_verify(cluster: ClusterSpec, variant: AgGemmVariant) -> f64 {
        let shape = GemmShape::new(8 * cluster.world_size(), 16, 32);
        let (mut op, bufs) = build(cluster, shape, variant);
        fill_inputs(&mut op.heap, &bufs, 42);
        let reference = reference_output(&op.heap, &bufs);
        let topo = Topology::build(cluster);
        let mut exec = HybridExecutor::native_only();
        let rep = super::super::run_numeric(&mut op, &topo, &mut exec).unwrap();
        verify(&op.heap, &bufs, &reference).unwrap();
        rep.makespan
    }

    #[test]
    fn ours_push_correct() {
        run_and_verify(ClusterSpec::h800(1, 8), AgGemmVariant::OursPush);
    }

    #[test]
    fn ours_pull_correct() {
        run_and_verify(ClusterSpec::h800(1, 8), AgGemmVariant::OursPull);
    }

    #[test]
    fn ours_ll_correct() {
        run_and_verify(ClusterSpec::h800(1, 8), AgGemmVariant::OursLL);
    }

    #[test]
    fn ours_inter_correct() {
        run_and_verify(ClusterSpec::h800(2, 4), AgGemmVariant::OursInter);
    }

    #[test]
    fn nccl_correct() {
        run_and_verify(ClusterSpec::h800(1, 8), AgGemmVariant::Nccl);
    }

    #[test]
    fn flux_correct() {
        run_and_verify(ClusterSpec::h800(1, 4), AgGemmVariant::Flux);
    }

    #[test]
    fn amd_correct() {
        run_and_verify(ClusterSpec::mi308x(8), AgGemmVariant::OursAmd { sub_chunks: 4 });
    }

    #[test]
    fn no_swizzle_correct() {
        run_and_verify(ClusterSpec::h800(1, 8), AgGemmVariant::NoSwizzle);
    }

    #[test]
    fn overlap_beats_nccl_on_big_shapes() {
        // Fig. 11's mechanism at timing level: the overlapped version
        // hides the AllGather behind the GEMM.
        let cluster = ClusterSpec::h800(1, 8);
        let shape = GemmShape::new(4096, 2048, 12288 / 8);
        let t = |v: AgGemmVariant| {
            let (mut op, _b) = build(cluster, shape, v);
            let topo = Topology::build(cluster);
            super::super::run_timing(&mut op, &topo).unwrap()
        };
        let ours = t(AgGemmVariant::OursPush);
        let nccl = t(AgGemmVariant::Nccl);
        assert!(
            ours < nccl,
            "overlap should win: ours {ours} vs nccl {nccl}"
        );
        // and the speedup should be in a sane band (paper: ~1.42x avg)
        let speedup = nccl / ours;
        assert!(speedup > 1.05 && speedup < 3.0, "speedup {speedup}");
    }

    #[test]
    fn swizzle_beats_identity_order() {
        let cluster = ClusterSpec::h800(1, 8);
        let shape = GemmShape::new(4096, 2048, 12288 / 8);
        let topo = Topology::build(cluster);
        let t = |v: AgGemmVariant| {
            let (mut op, _b) = build(cluster, shape, v);
            super::super::run_timing(&mut op, &topo).unwrap()
        };
        assert!(t(AgGemmVariant::OursPush) <= t(AgGemmVariant::NoSwizzle));
    }
}
