//! Elastic degraded-world recovery: turn a permanent rank/node death
//! ([`SimError::DeadPeer`]) into a survivor re-plan instead of a failed
//! run.
//!
//! The controller loop is **detect → drain → re-plan → resume**:
//!
//! 1. **detect** — the engine aborts with a structured
//!    [`DeadPeerInfo`](crate::sim::DeadPeerInfo): who died, when, which
//!    of the five detection paths noticed, what was drained, and a
//!    checkpoint of completed steps.
//! 2. **drain** — in-flight flows touching the dead ranks were already
//!    killed by the engine; the controller charges
//!    [`RecoverCfg::drain_per_flow`] virtual seconds per drained flow.
//! 3. **re-plan** — build a [`WorldView::survivors`] over the original
//!    cluster, slice the original routing table down to survivor rows,
//!    re-shard experts over the survivor world (`e_local` grows;
//!    re-homed experts regenerate bit-identical weights from their
//!    per-global-expert seed streams), and rebuild the whole pipeline
//!    with the survivor-indexed builders (`build_ep_moe_view`,
//!    `ag_flat_on`). Charged as a base cost plus a per-survivor term.
//! 4. **resume** — run the survivor program under the *shifted* fault
//!    plan ([`shift_plan`]): consumed deaths are dropped, everything
//!    still pending moves to the survivor run's clock. Another death
//!    starts another epoch.
//!
//! The final [`SimReport`] is stitched: makespan = resume offset +
//! survivor makespan, and [`SimReport::recovery`] carries the
//! [`RecoveryLedger`] with the full timeline plus **exact token
//! accounting** — `tokens_delivered + tokens_dropped` equals every
//! (token, expert-slot) pair the original plan owed, always.
//!
//! Fault-free and non-death runs never enter the loop, so their reports
//! stay bit-identical to the plain runners (`recovery` is `None`).

use crate::collectives::allgather::ag_flat_on;
use crate::collectives::alltoall::{A2aCfg, EpRouting};
use crate::collectives::reduce_scatter::rs_flat_on;
use crate::collectives::{AgBufs, ProgBuild, RsBufs, WorldView};
use crate::config::{ClusterSpec, DeathScope, FaultPlan, GemmShape, MoeShape};
use crate::kernels::exec::FixedPlan;
use crate::kernels::names::{Entry, EpGeom};
use crate::mem::{Slice, SymmetricHeap};
use crate::program::{ComputeCost, NumericOp, Op, SigCond, SigOp};
use crate::runtime::HybridExecutor;
use crate::sim::{RecoveryLedger, SimError, SimReport};
use crate::topology::Topology;

use super::ag_gemm::{self, AgGemmVariant};
use super::flash_decode::{self, FlashDecodeBufs, FlashDecodeCfg};
use super::gemm_rs::{self, GemmRsVariant};
use super::ep_moe::{
    build_ep_moe_cfg, build_ep_moe_view, fill_ep_moe, fill_ep_moe_view, routing_for, EpMoeBufs,
    EpMoeVariant,
};
use super::{run_numeric_faults, run_timing_faults, setup, BuiltOp, CoordError};

/// Virtual-time cost model of one recovery round. All knobs are
/// deterministic constants, so same-seed replays produce identical
/// [`RecoveryLedger`]s.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoverCfg {
    /// Seconds charged per in-flight flow the engine drained (state
    /// teardown + completion-queue flush).
    pub drain_per_flow: f64,
    /// Base re-plan cost: rebuilding programs + buffers for the
    /// survivor world.
    pub replan_base: f64,
    /// Additional re-plan cost per surviving rank (membership
    /// agreement + weight re-shard).
    pub replan_per_rank: f64,
}

impl Default for RecoverCfg {
    fn default() -> Self {
        RecoverCfg {
            drain_per_flow: 2e-6,
            replan_base: 200e-6,
            replan_per_rank: 5e-6,
        }
    }
}

/// Result of an elastic EP MoE run: the stitched report plus the final
/// (possibly degraded) world the pipeline finished on, so callers can
/// verify survivor numerics against the matching references.
pub struct ElasticRun {
    /// Stitched report; `recovery` is `Some` iff at least one death was
    /// survived.
    pub report: SimReport,
    /// The op of the final epoch (holds the heap with the outputs).
    pub op: BuiltOp,
    /// Buffers of the final epoch's build.
    pub bufs: EpMoeBufs,
    /// Survivor routing table of the final epoch.
    pub routing: EpRouting,
    /// Logical→physical map of the final epoch.
    pub view: WorldView,
}

/// Project a fault plan onto the survivor world after a detected death:
/// drop what was consumed or targets the dead, and move everything still
/// pending onto the survivor run's clock (its `t = 0` is the original
/// timeline's `resumed_at`).
pub fn shift_plan(
    plan: &FaultPlan,
    dead: &[usize],
    detected_at: f64,
    resumed_at: f64,
) -> FaultPlan {
    let mut out = plan.clone();
    out.deaths.retain(|d| {
        if d.t <= detected_at {
            return false; // consumed by this epoch
        }
        match d.scope {
            DeathScope::Rank(r) => !dead.contains(&r),
            DeathScope::Node(_) => true,
        }
    });
    for d in &mut out.deaths {
        d.t = (d.t - resumed_at).max(0.0);
    }
    out.link_faults.retain(|f| {
        if f.t_end <= resumed_at {
            return false; // fully elapsed before the resume
        }
        match f.target {
            crate::config::FaultTarget::Rank { rank } => !dead.contains(&rank),
            crate::config::FaultTarget::Nic { rank, .. } => !dead.contains(&rank),
            _ => true,
        }
    });
    for f in &mut out.link_faults {
        f.t_start = (f.t_start - resumed_at).max(0.0);
        f.t_end -= resumed_at; // INFINITY stays INFINITY
    }
    out.stragglers.retain(|s| !dead.contains(&s.rank));
    out
}

/// Run the EP MoE pipeline with full numerics under `faults`, surviving
/// permanent rank/node deaths by re-planning over the survivor world
/// (multi-epoch: each further death starts another recovery round).
///
/// Errors propagate unchanged when recovery is impossible: fewer than
/// two survivors, or a non-death failure.
pub fn run_ep_moe_elastic(
    cluster: ClusterSpec,
    shape: MoeShape,
    seed: u64,
    variant: EpMoeVariant,
    a2a: &A2aCfg,
    faults: FaultPlan,
    rcfg: &RecoverCfg,
) -> Result<ElasticRun, CoordError> {
    let topo = Topology::build(cluster);
    let w0 = cluster.world_size();
    let mut exec = HybridExecutor::native_only();

    let routing0 = routing_for(cluster, &shape, seed);
    let g0 = routing0.geom;
    let idx0 = routing0.idx.clone();
    let gate0 = routing0.gate.clone();
    let e_local0 = g0.e.div_ceil(g0.w);

    let mut view = WorldView::identity(w0);
    let (mut op, mut bufs) = build_ep_moe_cfg(cluster, shape, &routing0, variant, a2a);
    fill_ep_moe(&mut op.heap, &bufs, &routing0, seed);
    let mut routing = routing0;

    let mut faults_cur = faults;
    let mut dead_all: Vec<usize> = Vec::new();
    let mut rec: Option<RecoveryLedger> = None;
    // virtual time of the current epoch's t = 0 on the original clock
    let mut base_t = 0.0f64;

    loop {
        match run_numeric_faults(&mut op, &topo, &mut exec, faults_cur.clone()) {
            Ok(mut rep) => {
                if let Some(mut r) = rec {
                    // stitch the survivor epoch back onto the original
                    // clock and settle the token accounting
                    rep.makespan += base_t;
                    for s in &mut rep.task_spans {
                        s.2 += base_t;
                        s.3 += base_t;
                    }
                    let g = routing.geom;
                    let e_local = bufs.e_local;
                    let owed = (w0 * g0.t * g0.k) as u64;
                    let kept: Vec<bool> = match variant {
                        EpMoeVariant::TokenRouted => {
                            let plan = routing.plan();
                            (0..g.w * g.t * g.k).map(|gi| plan.dst_of(gi).is_some()).collect()
                        }
                        EpMoeVariant::FixedCapacity => {
                            let plan = FixedPlan::build(&routing.idx, g, bufs.cap_src);
                            (0..g.w * g.t * g.k).map(|gi| plan.slot_of(gi).is_some()).collect()
                        }
                    };
                    let mut delivered = 0u64;
                    let mut rerouted = 0u64;
                    for gi in 0..g.w * g.t * g.k {
                        if !kept[gi] {
                            continue;
                        }
                        delivered += 1;
                        let ei = routing.idx[gi];
                        let old_home = ei / e_local0;
                        let new_home = view.phys(ei / e_local);
                        if new_home != old_home {
                            rerouted += 1;
                        }
                    }
                    r.tokens_delivered = delivered;
                    r.tokens_rerouted = rerouted;
                    r.tokens_dropped = owed - delivered;
                    rep.recovery = Some(r);
                }
                return Ok(ElasticRun {
                    report: rep,
                    op,
                    bufs,
                    routing,
                    view,
                });
            }
            Err(e) => {
                let SimError::DeadPeer(info) = &e.source else {
                    return Err(e);
                };
                for &d in &info.dead {
                    if !dead_all.contains(&d) {
                        dead_all.push(d);
                    }
                }
                dead_all.sort_unstable();
                if w0 - dead_all.len() < 2 {
                    return Err(e); // nothing left to re-plan over
                }

                // --- drain + re-plan timeline (deterministic cost model)
                let died_at = base_t + info.died_at;
                let detected_at = base_t + info.detected_at;
                let drained_at = detected_at + rcfg.drain_per_flow * info.flows_drained as f64;
                let survivors = w0 - dead_all.len();
                let replanned_at =
                    drained_at + rcfg.replan_base + rcfg.replan_per_rank * survivors as f64;
                let resumed_at = replanned_at;

                // --- survivor routing: survivor rows of the ORIGINAL
                // table, capacity recomputed for the smaller world
                view = WorldView::survivors(w0, &dead_all);
                let wsur = view.world();
                let tk = g0.t * g0.k;
                let mut idx = Vec::with_capacity(wsur * tk);
                let mut gate = Vec::with_capacity(wsur * tk);
                for l in 0..wsur {
                    let pr = view.phys(l);
                    idx.extend_from_slice(&idx0[pr * tk..(pr + 1) * tk]);
                    gate.extend_from_slice(&gate0[pr * tk..(pr + 1) * tk]);
                }
                let gsur = EpGeom {
                    w: wsur,
                    c: shape.expert_capacity(wsur),
                    ..g0
                };
                routing = EpRouting::from_table(gsur, idx, gate);

                // --- rebuild + restore on the survivor world
                let (op2, bufs2) = build_ep_moe_view(cluster, shape, &routing, variant, a2a, &view);
                op = op2;
                bufs = bufs2;
                fill_ep_moe_view(&mut op.heap, &bufs, &routing, seed, &view);

                let r = rec.get_or_insert_with(RecoveryLedger::default);
                if r.epochs == 0 {
                    r.died_at = died_at;
                }
                r.dead_ranks = dead_all.clone();
                r.detected_at = detected_at;
                r.via = info.via.clone();
                r.drained_at = drained_at;
                r.replanned_at = replanned_at;
                r.resumed_at = resumed_at;
                r.flows_drained += info.flows_drained;
                r.steps_checkpointed += info.checkpoint.len() as u64;
                r.epochs += 1;

                faults_cur =
                    shift_plan(&faults_cur, &dead_all, info.detected_at, resumed_at - base_t);
                base_t = resumed_at;
            }
        }
    }
}

/// Timing-only elastic AG+GEMM: run the chosen overlapped variant; on a
/// permanent death, re-plan with the flat survivor AllGather
/// ([`ag_flat_on`]) feeding a full-SM GEMM per survivor — the degraded,
/// non-overlapped program that stays valid on any survivor set. Single
/// recovery epoch (a further death during the degraded run propagates).
pub fn run_ag_gemm_elastic(
    cluster: ClusterSpec,
    shape: GemmShape,
    variant: AgGemmVariant,
    faults: FaultPlan,
    rcfg: &RecoverCfg,
) -> Result<(SimReport, WorldView), CoordError> {
    let topo = Topology::build(cluster);
    let ws = cluster.world_size();
    let (mut op, _bufs) = ag_gemm::build(cluster, shape, variant);
    let err = match run_timing_faults(&mut op, &topo, faults.clone()) {
        Ok(rep) => return Ok((rep, WorldView::identity(ws))),
        Err(e) => e,
    };
    let SimError::DeadPeer(info) = &err.source else {
        return Err(err);
    };
    let dead = info.dead.clone();
    if ws - dead.len() < 2 {
        return Err(err);
    }
    let view = WorldView::survivors(ws, &dead);
    let died_at = info.died_at;
    let detected_at = info.detected_at;
    let drained_at = detected_at + rcfg.drain_per_flow * info.flows_drained as f64;
    let replanned_at =
        drained_at + rcfg.replan_base + rcfg.replan_per_rank * view.world() as f64;
    let resumed_at = replanned_at;

    // degraded re-plan: flat survivor AllGather + one full-SM GEMM task
    // per survivor over the survivor chunks only
    let (ctx, _t) = setup(cluster);
    assert!(shape.m % ws == 0, "M must divide world size");
    let m_per_rank = shape.m / ws;
    let shard = m_per_rank * shape.k;
    let mut heap = SymmetricHeap::new(ws, 4 * ws.max(16));
    let bufs = AgBufs::alloc(&mut heap, &ctx, shard);
    let weight = heap.alloc("weight", shape.k * shape.n);
    let output = heap.alloc("output", shape.m * shape.n);
    let mut pb = ProgBuild::new();
    ag_flat_on(&ctx, &bufs, &mut pb, &view);
    let chunk_flops = 2.0 * m_per_rank as f64 * shape.n as f64 * shape.k as f64;
    let entry = Entry::gemm_name(m_per_rank, shape.k, shape.n);
    for l in 0..view.world() {
        let pr = view.phys(l);
        let mut t = ctx
            .task(pr, format!("degraded_gemm[{l}]"))
            .with_sms(cluster.hw.sms)
            .launch_overhead();
        for i in 0..view.world() {
            let seg = view.phys((l + i) % view.world());
            t.signal_wait_until(bufs.sig(seg), SigCond::Ge, 1);
            t.op(Op::Compute {
                cost: ComputeCost::Gemm {
                    flops: chunk_flops,
                    vendor: false,
                },
                numeric: NumericOp::Call {
                    entry: entry.clone(),
                    args: vec![
                        bufs.seg(seg, pr),
                        Slice::new(pr, weight, 0, shape.k * shape.n),
                    ],
                    outs: vec![Slice::new(
                        pr,
                        output,
                        seg * m_per_rank * shape.n,
                        m_per_rank * shape.n,
                    )],
                },
                label: "degraded_gemm_chunk",
            });
        }
        pb.prog.push(t.build());
    }
    let mut op2 = BuiltOp {
        ctx,
        heap,
        prog: pb.prog,
        name: format!("{} (degraded)", op.name),
    };
    let fp = shift_plan(&faults, &dead, detected_at, resumed_at);
    let mut rep = run_timing_faults(&mut op2, &topo, fp)?;
    rep.makespan += resumed_at;
    for s in &mut rep.task_spans {
        s.2 += resumed_at;
        s.3 += resumed_at;
    }
    rep.recovery = Some(RecoveryLedger {
        dead_ranks: {
            let mut d = dead;
            d.sort_unstable();
            d
        },
        died_at,
        detected_at,
        via: info.via.clone(),
        drained_at,
        replanned_at,
        resumed_at,
        flows_drained: info.flows_drained,
        steps_checkpointed: info.checkpoint.len() as u64,
        tokens_delivered: 0,
        tokens_rerouted: 0,
        tokens_dropped: 0,
        epochs: 1,
    });
    Ok((rep, view))
}

/// Timing-only elastic GEMM+RS: run the chosen overlapped variant; on a
/// permanent death, re-plan with a full-SM partial GEMM per survivor
/// (survivor destination chunks only) feeding the flat survivor
/// ReduceScatter ([`rs_flat_on`]) — the degraded, non-overlapped
/// program that stays valid on any survivor set. The dead ranks' K
/// shards are gone with them, so the degraded reduction sums survivor
/// partials only. Single recovery epoch (a further death during the
/// degraded run propagates).
pub fn run_gemm_rs_elastic(
    cluster: ClusterSpec,
    shape: GemmShape,
    variant: GemmRsVariant,
    faults: FaultPlan,
    rcfg: &RecoverCfg,
) -> Result<(SimReport, WorldView), CoordError> {
    let topo = Topology::build(cluster);
    let ws = cluster.world_size();
    let (mut op, _bufs) = gemm_rs::build(cluster, shape, variant);
    let err = match run_timing_faults(&mut op, &topo, faults.clone()) {
        Ok(rep) => return Ok((rep, WorldView::identity(ws))),
        Err(e) => e,
    };
    let SimError::DeadPeer(info) = &err.source else {
        return Err(err);
    };
    let dead = info.dead.clone();
    if ws - dead.len() < 2 {
        return Err(err);
    }
    let view = WorldView::survivors(ws, &dead);
    let died_at = info.died_at;
    let detected_at = info.detected_at;
    let drained_at = detected_at + rcfg.drain_per_flow * info.flows_drained as f64;
    let replanned_at =
        drained_at + rcfg.replan_base + rcfg.replan_per_rank * view.world() as f64;
    let resumed_at = replanned_at;

    // degraded re-plan: one full-SM GEMM task per survivor producing the
    // partial chunks for the surviving destinations only, gated into the
    // flat survivor ReduceScatter
    let (ctx, _t) = setup(cluster);
    assert!(shape.m % ws == 0, "M must divide world size");
    let m_per_rank = shape.m / ws;
    let shard = m_per_rank * shape.n;
    let mut heap = SymmetricHeap::new(ws, 4 * ws.max(16));
    let bufs = RsBufs::alloc_flat(&mut heap, &ctx, shard);
    let act = heap.alloc("act", shape.m * shape.k);
    let weight = heap.alloc("weight", shape.k * shape.n);
    let mut pb = ProgBuild::new();
    // chunk-ready signals live above the flat RS footprint [0, ws)
    let prod_base = ctx.n_pes();
    pb.claim_sigs("degraded_gemm_rs", prod_base, ctx.n_pes());
    let chunk_flops = 2.0 * m_per_rank as f64 * shape.n as f64 * shape.k as f64;
    let entry = Entry::gemm_name(m_per_rank, shape.k, shape.n);
    for l in 0..view.world() {
        let pr = view.phys(l);
        let mut t = ctx
            .task(pr, format!("degraded_gemm[{l}]"))
            .with_sms(cluster.hw.sms)
            .launch_overhead();
        for i in 0..view.world() {
            let pm = view.phys((l + 1 + i) % view.world()); // own chunk last
            t.op(Op::Compute {
                cost: ComputeCost::Gemm {
                    flops: chunk_flops,
                    vendor: false,
                },
                numeric: NumericOp::Call {
                    entry: entry.clone(),
                    args: vec![
                        Slice::new(pr, act, pm * m_per_rank * shape.k, m_per_rank * shape.k),
                        Slice::new(pr, weight, 0, shape.k * shape.n),
                    ],
                    outs: vec![bufs.in_chunk(pm, pr)],
                },
                label: "degraded_gemm_chunk",
            });
            t.notify(pr, prod_base + pm, SigOp::Set, 1);
        }
        pb.prog.push(t.build());
    }
    rs_flat_on(&ctx, &bufs, &mut pb, &view, 15, Some(prod_base));
    let mut op2 = BuiltOp {
        ctx,
        heap,
        prog: pb.prog,
        name: format!("{} (degraded)", op.name),
    };
    let fp = shift_plan(&faults, &dead, detected_at, resumed_at);
    let mut rep = run_timing_faults(&mut op2, &topo, fp)?;
    rep.makespan += resumed_at;
    for s in &mut rep.task_spans {
        s.2 += resumed_at;
        s.3 += resumed_at;
    }
    rep.recovery = Some(RecoveryLedger {
        dead_ranks: {
            let mut d = dead;
            d.sort_unstable();
            d
        },
        died_at,
        detected_at,
        via: info.via.clone(),
        drained_at,
        replanned_at,
        resumed_at,
        flows_drained: info.flows_drained,
        steps_checkpointed: info.checkpoint.len() as u64,
        tokens_delivered: 0,
        tokens_rerouted: 0,
        tokens_dropped: 0,
        epochs: 1,
    });
    Ok((rep, view))
}

/// Build the timing-only degraded flash-decode step on the survivor
/// world: each survivor recomputes its partial attention over its local
/// KV shard (the mid-step partials may have been in flight to a dead
/// peer), the flat survivor AllGather ([`ag_flat_on`]) broadcasts the
/// partial segments, and every survivor combines the survivor segments
/// only. Shared by [`run_flash_decode_elastic`] and the serving loop's
/// post-death decode steps (`coordinator::serve`).
pub fn build_flash_decode_degraded(
    cluster: ClusterSpec,
    cfg: FlashDecodeCfg,
    view: &WorldView,
) -> BuiltOp {
    let (ctx, _t) = setup(cluster);
    let ws = cluster.world_size();
    let seg_len = FlashDecodeBufs::seg_len(&cfg);
    let mut heap = SymmetricHeap::new(ws, 4 * ws.max(16));
    let bufs = AgBufs::alloc(&mut heap, &ctx, seg_len);
    let mut pb = ProgBuild::new();
    ag_flat_on(&ctx, &bufs, &mut pb, view);
    let kv_bytes =
        (cfg.heads * cfg.kv_per_rank * cfg.head_dim) as f64 * ctx.dtype.bytes() as f64;
    for l in 0..view.world() {
        let pr = view.phys(l);
        let mut t = ctx
            .task(pr, format!("degraded_decode[{l}]"))
            .with_sms(cluster.hw.sms)
            .launch_overhead();
        t.op(Op::Compute {
            cost: ComputeCost::MemBound { bytes: kv_bytes * 2.0 },
            numeric: NumericOp::None,
            label: "degraded_decode_partial",
        });
        for i in 0..view.world() {
            let seg = view.phys((l + i) % view.world());
            t.signal_wait_until(bufs.sig(seg), SigCond::Ge, 1);
        }
        t.op(Op::Compute {
            cost: ComputeCost::MemBound {
                bytes: (seg_len * view.world() * ctx.dtype.bytes()) as f64 * 2.0,
            },
            numeric: NumericOp::None,
            label: "degraded_decode_combine",
        });
        pb.prog.push(t.build());
    }
    BuiltOp {
        ctx,
        heap,
        prog: pb.prog,
        name: format!("FlashDecode+AG ws={ws} kv={} (degraded)", cfg.kv_per_rank),
    }
}

/// Timing-only elastic flash decode: run the gated-LL decode program;
/// on a permanent death, re-plan the step onto the survivor world — a
/// degraded program where each survivor recomputes its partial
/// attention over its local KV shard, the flat survivor AllGather
/// ([`ag_flat_on`]) broadcasts the partial segments, and every survivor
/// combines the survivor segments only. The dead ranks' KV shards are
/// gone with them: the [`RecoveryLedger`] accounts every KV entry the
/// original step owed as delivered (survivor shards) or dropped (dead
/// shards) — exactly, always. Single recovery epoch (a further death
/// during the degraded run propagates).
pub fn run_flash_decode_elastic(
    cluster: ClusterSpec,
    cfg: FlashDecodeCfg,
    faults: FaultPlan,
    rcfg: &RecoverCfg,
) -> Result<(SimReport, WorldView), CoordError> {
    let topo = Topology::build(cluster);
    let ws = cluster.world_size();
    let (mut op, _bufs) = flash_decode::build(cluster, cfg);
    let err = match run_timing_faults(&mut op, &topo, faults.clone()) {
        Ok(rep) => return Ok((rep, WorldView::identity(ws))),
        Err(e) => e,
    };
    let SimError::DeadPeer(info) = &err.source else {
        return Err(err);
    };
    let dead = info.dead.clone();
    if ws - dead.len() < 2 {
        return Err(err);
    }
    let view = WorldView::survivors(ws, &dead);
    let died_at = info.died_at;
    let detected_at = info.detected_at;
    let drained_at = detected_at + rcfg.drain_per_flow * info.flows_drained as f64;
    let replanned_at =
        drained_at + rcfg.replan_base + rcfg.replan_per_rank * view.world() as f64;
    let resumed_at = replanned_at;

    let mut op2 = build_flash_decode_degraded(cluster, cfg, &view);
    let fp = shift_plan(&faults, &dead, detected_at, resumed_at);
    let mut rep = run_timing_faults(&mut op2, &topo, fp)?;
    rep.makespan += resumed_at;
    for s in &mut rep.task_spans {
        s.2 += resumed_at;
        s.3 += resumed_at;
    }
    // exact KV accounting: owed = ws * kv_per_rank entries attended by
    // the original step; survivor shards are delivered, dead shards
    // dropped — delivered + dropped == owed by construction
    let kv = cfg.kv_per_rank as u64;
    rep.recovery = Some(RecoveryLedger {
        dead_ranks: {
            let mut d = dead;
            d.sort_unstable();
            d
        },
        died_at,
        detected_at,
        via: info.via.clone(),
        drained_at,
        replanned_at,
        resumed_at,
        flows_drained: info.flows_drained,
        steps_checkpointed: info.checkpoint.len() as u64,
        tokens_delivered: view.world() as u64 * kv,
        tokens_rerouted: 0,
        tokens_dropped: (ws - view.world()) as u64 * kv,
        epochs: 1,
    });
    Ok((rep, view))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Death, FaultTarget, LinkFault, Straggler};

    #[test]
    fn shift_plan_consumes_and_rebases() {
        let plan = FaultPlan {
            deaths: vec![
                Death { scope: DeathScope::Rank(3), t: 1e-4 },  // consumed
                Death { scope: DeathScope::Rank(3), t: 9e-3 },  // dead target
                Death { scope: DeathScope::Rank(1), t: 6e-3 },  // pending
                Death { scope: DeathScope::Node(1), t: 8e-3 },  // pending
            ],
            link_faults: vec![
                LinkFault::flap(FaultTarget::Nic { rank: 3, rail: 0 }, 2e-3, 1e-3), // dead
                LinkFault::flap(FaultTarget::Spine { rail: 0 }, 1e-3, 1e-3),        // elapsed
                LinkFault::flap(FaultTarget::Spine { rail: 1 }, 4e-3, 4e-3),        // pending
            ],
            stragglers: vec![
                Straggler { rank: 3, factor: 2.0 },
                Straggler { rank: 0, factor: 2.0 },
            ],
            ..FaultPlan::default()
        };
        let out = shift_plan(&plan, &[3], 2e-4, 5e-3);
        assert_eq!(out.deaths.len(), 2);
        assert_eq!(out.deaths[0].scope, DeathScope::Rank(1));
        assert!((out.deaths[0].t - 1e-3).abs() < 1e-12);
        assert_eq!(out.deaths[1].scope, DeathScope::Node(1));
        assert_eq!(out.link_faults.len(), 1);
        assert_eq!(out.link_faults[0].target, FaultTarget::Spine { rail: 1 });
        assert!(out.link_faults[0].t_start.abs() < 1e-12); // clamped to 0
        assert!((out.link_faults[0].t_end - 3e-3).abs() < 1e-12);
        assert_eq!(out.stragglers, vec![Straggler { rank: 0, factor: 2.0 }]);
    }

    #[test]
    fn shift_plan_keeps_recovery_knobs() {
        let mut plan = FaultPlan::default();
        plan.lt_timeout = 1e-3;
        plan.retry_max = 7;
        let out = shift_plan(&plan, &[0], 0.0, 1e-3);
        assert_eq!(out.lt_timeout, 1e-3);
        assert_eq!(out.retry_max, 7);
        assert!(out.is_empty());
    }
}
