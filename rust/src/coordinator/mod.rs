//! The L3 coordinator: builds whole-world programs for the paper's fused
//! overlapping kernels (Table 3) — ours and every baseline — and runs
//! them on the DES with optional real numerics through PJRT/native
//! executors.

pub mod ag_gemm;
pub mod ep_moe;
pub mod flash_decode;
pub mod gemm_rs;
pub mod moe;
pub mod recover;
pub mod serve;

use crate::config::{ClusterSpec, DType, FaultPlan};
use crate::mem::SymmetricHeap;
use crate::program::Program;
use crate::shmem::ShmemCtx;
use crate::sim::{ComputeExecutor, NoopExecutor, Sim, SimConfig, SimError, SimReport};
use crate::topology::Topology;

/// Everything needed to execute one built program.
pub struct BuiltOp {
    pub ctx: ShmemCtx,
    pub heap: SymmetricHeap,
    pub prog: Program,
    /// Human name for reports ("AG+GEMM ours (push)" etc.)
    pub name: String,
}

/// A coordinator-built program failed in the engine: which op died, the
/// virtual failure time when the engine error carries one (watchdog
/// timeouts do; deadlocks are detected after the event queue drains and
/// are timeless), and the underlying [`SimError`].
#[derive(Debug)]
pub struct CoordError {
    /// Human name of the failed op ("AG+GEMM ours (push)" etc.).
    pub op: String,
    /// Virtual failure time (s), when known.
    pub at: Option<f64>,
    pub source: SimError,
}

impl CoordError {
    fn new(op: &str, source: SimError) -> Self {
        let at = match &source {
            SimError::WatchdogTimeout { at, .. } => Some(*at),
            SimError::DeadPeer(info) => Some(info.detected_at),
            _ => None,
        };
        CoordError {
            op: op.to_string(),
            at,
            source,
        }
    }
}

impl std::fmt::Display for CoordError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "op '{}' failed", self.op)?;
        if let Some(at) = self.at {
            write!(f, " at t={at:.6e}s")?;
        }
        write!(f, ": {}", self.source)
    }
}

impl std::error::Error for CoordError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        Some(&self.source)
    }
}

/// Run a built op in timing-only mode; returns the virtual makespan (s).
pub fn run_timing(op: &mut BuiltOp, topo: &Topology) -> Result<f64, CoordError> {
    Ok(run_timing_faults(op, topo, FaultPlan::default())?.makespan)
}

/// Timing-only run under a fault plan; returns the full report so the
/// fault ledger rides along for degraded-fabric scenarios. An empty
/// plan is bit-identical to [`run_timing`].
pub fn run_timing_faults(
    op: &mut BuiltOp,
    topo: &Topology,
    faults: FaultPlan,
) -> Result<SimReport, CoordError> {
    run_timing_threads(op, topo, faults, 1)
}

/// [`run_timing_faults`] on the sharded engine (`--threads N`). The
/// report is bit-identical for every `threads` value — `1` runs the
/// sequential event loop, `N > 1` the component-sharded one — so callers
/// pick purely on host wall-clock (`SimReport::wall_ns`).
pub fn run_timing_threads(
    op: &mut BuiltOp,
    topo: &Topology,
    faults: FaultPlan,
    threads: usize,
) -> Result<SimReport, CoordError> {
    let sim = Sim::with_config(
        topo,
        SimConfig {
            numerics: false,
            trace: false,
        },
    )
    .with_faults(faults)
    .with_threads(threads);
    sim.run(&op.prog, &mut op.heap, &mut NoopExecutor)
        .map_err(|e| CoordError::new(&op.name, e))
}

/// Run with numerics through the given executor.
pub fn run_numeric(
    op: &mut BuiltOp,
    topo: &Topology,
    exec: &mut dyn ComputeExecutor,
) -> Result<SimReport, CoordError> {
    let sim = Sim::new(topo);
    sim.run(&op.prog, &mut op.heap, exec)
        .map_err(|e| CoordError::new(&op.name, e))
}

/// Run with numerics under a fault plan. An empty plan is bit-identical
/// to [`run_numeric`]; with death entries the run may end in
/// [`SimError::DeadPeer`], which the elastic recovery controller
/// ([`recover::run_ep_moe_elastic`]) turns into a survivor re-plan.
pub fn run_numeric_faults(
    op: &mut BuiltOp,
    topo: &Topology,
    exec: &mut dyn ComputeExecutor,
    faults: FaultPlan,
) -> Result<SimReport, CoordError> {
    let sim = Sim::with_config(
        topo,
        SimConfig {
            numerics: true,
            trace: false,
        },
    )
    .with_faults(faults);
    sim.run(&op.prog, &mut op.heap, exec)
        .map_err(|e| CoordError::new(&op.name, e))
}

/// Run with numerics + tracing (timeline extraction).
pub fn run_traced(
    op: &mut BuiltOp,
    topo: &Topology,
    exec: &mut dyn ComputeExecutor,
) -> Result<SimReport, CoordError> {
    let sim = Sim::with_config(
        topo,
        SimConfig {
            numerics: true,
            trace: true,
        },
    );
    sim.run(&op.prog, &mut op.heap, exec)
        .map_err(|e| CoordError::new(&op.name, e))
}

/// Convenience: context + topology for a cluster at bf16.
pub fn setup(cluster: ClusterSpec) -> (ShmemCtx, Topology) {
    (
        ShmemCtx::new(cluster, DType::BF16),
        Topology::build(cluster),
    )
}
