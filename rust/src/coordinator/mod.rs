//! The L3 coordinator: builds whole-world programs for the paper's fused
//! overlapping kernels (Table 3) — ours and every baseline — and runs
//! them on the DES with optional real numerics through PJRT/native
//! executors.

pub mod ag_gemm;
pub mod ep_moe;
pub mod flash_decode;
pub mod gemm_rs;
pub mod moe;

use crate::config::{ClusterSpec, DType};
use crate::mem::SymmetricHeap;
use crate::program::Program;
use crate::shmem::ShmemCtx;
use crate::sim::{ComputeExecutor, NoopExecutor, Sim, SimConfig, SimReport};
use crate::topology::Topology;

/// Everything needed to execute one built program.
pub struct BuiltOp {
    pub ctx: ShmemCtx,
    pub heap: SymmetricHeap,
    pub prog: Program,
    /// Human name for reports ("AG+GEMM ours (push)" etc.)
    pub name: String,
}

/// Run a built op in timing-only mode; returns the virtual makespan (s).
pub fn run_timing(op: &mut BuiltOp, topo: &Topology) -> f64 {
    let sim = Sim::with_config(
        topo,
        SimConfig {
            numerics: false,
            trace: false,
        },
    );
    sim.run(&op.prog, &mut op.heap, &mut NoopExecutor)
        .unwrap_or_else(|e| panic!("{} failed: {e}", op.name))
        .makespan
}

/// Run with numerics through the given executor.
pub fn run_numeric(
    op: &mut BuiltOp,
    topo: &Topology,
    exec: &mut dyn ComputeExecutor,
) -> SimReport {
    let sim = Sim::new(topo);
    sim.run(&op.prog, &mut op.heap, exec)
        .unwrap_or_else(|e| panic!("{} failed: {e}", op.name))
}

/// Run with numerics + tracing (timeline extraction).
pub fn run_traced(
    op: &mut BuiltOp,
    topo: &Topology,
    exec: &mut dyn ComputeExecutor,
) -> SimReport {
    let sim = Sim::with_config(
        topo,
        SimConfig {
            numerics: true,
            trace: true,
        },
    );
    sim.run(&op.prog, &mut op.heap, exec)
        .unwrap_or_else(|e| panic!("{} failed: {e}", op.name))
}

/// Convenience: context + topology for a cluster at bf16.
pub fn setup(cluster: ClusterSpec) -> (ShmemCtx, Topology) {
    (
        ShmemCtx::new(cluster, DType::BF16),
        Topology::build(cluster),
    )
}
