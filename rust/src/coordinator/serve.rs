//! Trace-driven serving simulator: a continuously-batched inference
//! fleet fed by a [`TracePlan`](crate::config::TracePlan) arrival
//! process, reporting request-level latency percentiles instead of a
//! single makespan.
//!
//! The outer loop advances *serving* virtual time over discrete steps:
//!
//! * **admit** — arrivals (Poisson/bursty/diurnal/explicit) queue up and
//!   join the batch while slots are free; each admitted request's
//!   KV-cache is homed on the least-loaded rank.
//! * **prefill** — a shared per-step token budget (`prefill_chunk`)
//!   processed FCFS across prompt-phase requests; GEMM-bound, priced
//!   analytically on the prefill SM share.
//! * **decode** — one token per decode-phase request per step, priced
//!   by actually *running* the `flash_decode` (+ optional `ep_moe`)
//!   coordinator programs on the railed fabric through the DES engine,
//!   with KV length and MoE token load bucketed to powers of two so
//!   repeated steps reuse memoized program runs (memoization is only
//!   valid — and only enabled — when no link faults are in play).
//! * **partition** — when both phases are live they compete for SMs via
//!   the §3.5-style [`plan_serving`] split: prefill is priced on its
//!   share, the decode programs' makespan is scaled by the ratio of the
//!   full device to the decode share (a deliberate first-order model:
//!   collective time doesn't scale with SMs, compute does), and the
//!   step advances by the *max* of the two — the phases overlap.
//!
//! **Elastic recovery is folded in, not bolted on**: rank/node deaths
//! from the fault plan are applied on the serving clock — the
//! [`RecoverCfg`] detect → drain → re-plan pause is charged, the world
//! shrinks to a [`WorldView`] of survivors, decode steps switch to the
//! degraded survivor programs ([`build_flash_decode_degraded`],
//! `build_ep_moe_view` over survivor-sliced routing), and requests
//! whose KV-cache lived on a dead rank are *rerouted* (re-queued to
//! re-prefill on a new home) once, dropped with a reason on a second
//! loss. A mid-serving death therefore surfaces as a p99 latency spike
//! in the [`ServingReport`] — never a failed run (pinned by
//! `tests/serving.rs`).
//!
//! Everything is deterministic: same `(trace, fault plan, config)` ⇒
//! the same report, bit for bit. Link faults and stragglers (the
//! non-death residual of the plan) are projected onto each inner DES
//! run's clock with [`shift_plan`], so a spine flap mid-trace slows the
//! decode steps it overlaps and nothing else.

use std::collections::{BTreeMap, VecDeque};

use crate::collectives::alltoall::{A2aCfg, EpRouting};
use crate::collectives::WorldView;
use crate::config::{
    ArrivalTrace, ClusterSpec, DType, DeathScope, FaultPlan, MoeShape, TracePlan,
};
use crate::kernels::names::EpGeom;
use crate::overlap::partition::plan_serving;
use crate::topology::Topology;
use crate::util::stats::percentile;

use super::ep_moe::{build_ep_moe_cfg, build_ep_moe_view, routing_for, EpMoeVariant};
use super::flash_decode::{self, FlashDecodeCfg};
use super::recover::{build_flash_decode_degraded, shift_plan, RecoverCfg};
use super::{run_timing_threads, CoordError};

/// Serving-fleet configuration: model geometry, batching knobs, and the
/// recovery cost model. All deterministic constants.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeCfg {
    /// Attention heads (with `head_dim`, fixes the model width).
    pub heads: usize,
    /// Head dimension.
    pub head_dim: usize,
    /// Transformer layers the analytic prefill cost is scaled by.
    pub layers: usize,
    /// Max requests resident in the batch (prefill + decode phases).
    pub max_batch: usize,
    /// Shared prefill token budget per step, FCFS across requests.
    pub prefill_chunk: usize,
    /// Tokens per KV-cache block (the migration granularity).
    pub kv_block: usize,
    /// Run the EP-MoE FFN per decode step (in addition to attention).
    pub moe: bool,
    /// Experts of the per-step MoE.
    pub moe_experts: usize,
    /// Hidden width of the per-step MoE.
    pub moe_hidden: usize,
    /// Seed of the per-step MoE routing table.
    pub moe_seed: u64,
    /// Engine threads for the inner DES runs (`--threads`).
    pub threads: usize,
    /// Recovery cost model applied on a mid-serving death.
    pub rcfg: RecoverCfg,
    /// Death detection latency when the plan's watchdog is disabled.
    pub detect_latency: f64,
    /// Queue cap: arrivals beyond it are dropped as `queue-full`.
    pub max_queue: usize,
    /// Rebalance trigger: max-min KV block spread that migrates one
    /// request's blocks to the least-loaded rank.
    pub migrate_spread: u64,
    /// Max KV migrations per serving step (`--migrate-batch`). Each one
    /// is charged on the step clock and counted exactly in
    /// `kv_migrations` / `kv_blocks_moved`; 1 reproduces the
    /// one-migration-per-step behavior bit for bit.
    pub migrate_batch: usize,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            heads: 16,
            head_dim: 128,
            layers: 8,
            max_batch: 32,
            prefill_chunk: 256,
            kv_block: 64,
            moe: true,
            moe_experts: 32,
            moe_hidden: 256,
            moe_seed: 11,
            threads: 1,
            rcfg: RecoverCfg::default(),
            detect_latency: 10e-6,
            max_queue: 4096,
            migrate_spread: 8,
            migrate_batch: 1,
        }
    }
}

/// One completed request's latency record.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReqStat {
    /// Trace id.
    pub id: usize,
    /// Arrival time (s).
    pub t_arrive: f64,
    /// Time to first token (s).
    pub ttft: f64,
    /// Total latency, arrival to last token (s).
    pub latency: f64,
    /// Output tokens produced.
    pub tokens: usize,
}

/// One survived mid-serving death.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServeRecovery {
    /// Ranks that died in this event (sorted).
    pub dead: Vec<usize>,
    /// Death time on the serving clock (s).
    pub died_at: f64,
    /// Serving time after the detect + drain + re-plan pause (s).
    pub resumed_at: f64,
    /// Requests whose KV died with the ranks and were re-queued.
    pub rerouted: usize,
    /// Requests dropped (second KV loss).
    pub dropped: usize,
}

/// The serving run's result: request conservation counters, latency
/// percentiles, throughput, queue pressure, KV migration traffic, and
/// the recovery log. `Default` is the empty-trace no-op report.
/// Deterministic bit-for-bit: `PartialEq` compares exactly.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServingReport {
    /// Requests in the trace (`== completed + dropped`, always).
    pub requests: usize,
    /// Requests that produced their full output.
    pub completed: usize,
    /// Requests dropped; every drop has a reason in `drop_reasons`.
    pub dropped: usize,
    /// Drop reason → count (sorted by reason; counts sum to `dropped`).
    pub drop_reasons: Vec<(String, usize)>,
    /// Requests re-queued after losing their KV to a dead rank (each
    /// still ends in `completed` or `dropped`).
    pub rerouted: usize,
    /// Median / 99th-percentile time-to-first-token (s).
    pub p50_ttft: f64,
    pub p99_ttft: f64,
    /// Median / 99th-percentile time-per-output-token (s).
    pub p50_tpot: f64,
    pub p99_tpot: f64,
    /// Median / 99th-percentile total latency (s).
    pub p50_latency: f64,
    pub p99_latency: f64,
    /// Completed output tokens.
    pub tokens_out: u64,
    /// Completed output tokens per virtual second.
    pub goodput: f64,
    /// Virtual time from t=0 to the last completion or drop (s).
    pub makespan: f64,
    /// Queue-depth samples over time, downsampled to ≤ 256 points.
    pub queue_depth: Vec<(f64, usize)>,
    /// Peak queue depth over the whole run (pre-downsampling).
    pub max_queue_depth: usize,
    /// KV rebalance events and blocks moved over the fabric.
    pub kv_migrations: u64,
    pub kv_blocks_moved: u64,
    /// Survived mid-serving deaths, in order.
    pub recoveries: Vec<ServeRecovery>,
    /// DES events processed across all inner coordinator runs
    /// (memoized steps count their cached run's events).
    pub events: u64,
    /// Per-completed-request records, in completion order.
    pub per_request: Vec<ReqStat>,
}

impl ServingReport {
    /// Flatten into the scalar summary the report layer records in
    /// `BENCH_engine.json` (`metrics::ServingBenchInfo`).
    pub fn bench_info(&self) -> crate::metrics::ServingBenchInfo {
        crate::metrics::ServingBenchInfo {
            requests: self.requests as u64,
            completed: self.completed as u64,
            dropped: self.dropped as u64,
            rerouted: self.rerouted as u64,
            p50_ttft_s: self.p50_ttft,
            p99_ttft_s: self.p99_ttft,
            p50_tpot_s: self.p50_tpot,
            p99_tpot_s: self.p99_tpot,
            goodput_tokens_per_s: self.goodput,
            makespan_s: self.makespan,
            max_queue_depth: self.max_queue_depth as u64,
            recoveries: self.recoveries.len() as u32,
        }
    }
}

/// One resident or queued request.
#[derive(Debug, Clone)]
struct Slot {
    id: usize,
    t_arrive: f64,
    prompt: usize,
    output: usize,
    prefill_done: usize,
    decoded: usize,
    t_first: Option<f64>,
    /// Physical rank homing this request's KV blocks.
    home: usize,
    kv_blocks: u64,
    /// Already survived one KV loss; a second drops it.
    rerouted: bool,
}

impl Slot {
    fn new(id: usize, t_arrive: f64, prompt: usize, output: usize) -> Self {
        Slot {
            id,
            t_arrive,
            // a request always has at least one prompt and one output
            // token, whatever an explicit trace clause claims — a
            // zero-length phase could never leave the batch
            prompt: prompt.max(1),
            output: output.max(1),
            prefill_done: 0,
            decoded: 0,
            t_first: None,
            home: 0,
            kv_blocks: 0,
            rerouted: false,
        }
    }

    fn decoding(&self) -> bool {
        self.prefill_done >= self.prompt
    }
}

/// Run the serving loop: `trace` against `cluster` under `faults`.
///
/// Completes (never errors) on any recoverable plan: deaths shrink the
/// world and show up as latency spikes + reroutes/drops; only a
/// world-collapse (fewer than two survivors) drops the remaining
/// requests — still a completed run with exact accounting. Inner DES
/// failures other than the handled death path propagate as
/// [`CoordError`].
pub fn run_serve(
    cluster: ClusterSpec,
    trace: &ArrivalTrace,
    faults: FaultPlan,
    cfg: &ServeCfg,
) -> Result<ServingReport, CoordError> {
    if trace.is_empty() {
        // no-op contract: nothing arrives, nothing runs, default report
        return Ok(ServingReport::default());
    }
    let topo = Topology::build(cluster);
    let hw = cluster.hw;
    let w0 = cluster.world_size();

    // deaths run on the serving clock; the residual plan (link faults,
    // stragglers, jitter, knobs) is projected onto each inner DES run
    let mut deaths: Vec<(f64, Vec<usize>)> = faults
        .deaths
        .iter()
        .map(|d| {
            let ranks = match d.scope {
                DeathScope::Rank(r) => vec![r],
                DeathScope::Node(n) => (0..w0).filter(|&r| cluster.node_of(r) == n).collect(),
            };
            (d.t, ranks)
        })
        .collect();
    deaths.sort_by(|a, b| a.0.total_cmp(&b.0));
    let residual = FaultPlan {
        deaths: Vec::new(),
        ..faults.clone()
    };
    let detect_lat = if residual.lt_timeout.is_finite() {
        residual.lt_timeout
    } else {
        cfg.detect_latency
    };

    let mut view = WorldView::identity(w0);
    let mut dead_all: Vec<usize> = Vec::new();

    let reqs = &trace.requests;
    let total = reqs.len();
    let mut next_arr = 0usize;
    let mut queue: VecDeque<Slot> = VecDeque::new();
    let mut active: Vec<Slot> = Vec::new();

    let mut per_request: Vec<ReqStat> = Vec::new();
    let mut drop_reasons: BTreeMap<&'static str, usize> = BTreeMap::new();
    let mut done = 0usize;
    let mut rerouted_total = 0usize;
    let mut tokens_out = 0u64;
    let mut events = 0u64;
    let mut kv_migrations = 0u64;
    let mut kv_blocks_moved = 0u64;
    let mut qsamples: Vec<(f64, usize)> = Vec::new();
    let mut max_q = 0usize;
    let mut recoveries: Vec<ServeRecovery> = Vec::new();
    // phys rank -> resident KV blocks (survivor ranks only)
    let mut kv_load: BTreeMap<usize, u64> = (0..w0).map(|r| (r, 0)).collect();
    // (world, kv bucket, moe bucket) -> (step cost, DES events)
    let mut memo: BTreeMap<(usize, u64, u64), (f64, u64)> = BTreeMap::new();

    // analytic prefill cost: attention + FFN GEMMs of a dense block,
    // ~12 * hidden^2 MACs/token/layer
    let hidden = (cfg.heads * cfg.head_dim) as f64;
    let flops_per_token = 12.0 * hidden * hidden * cfg.layers as f64;
    let bytes_per_token = 2.0 * hidden * DType::BF16.bytes() as f64; // K + V
    fn drop_req(reasons: &mut BTreeMap<&'static str, usize>, why: &'static str) {
        *reasons.entry(why).or_insert(0) += 1;
    }

    let mut t = 0.0f64;
    'serve: while done < total {
        // --- world collapse: fewer than two survivors can't host the
        // collectives; drop everything remaining, exactly accounted
        if w0 - dead_all.len() < 2 {
            for _ in active.drain(..).chain(queue.drain(..)) {
                drop_req(&mut drop_reasons, "world-collapsed");
                done += 1;
            }
            while next_arr < total {
                drop_req(&mut drop_reasons, "world-collapsed");
                done += 1;
                next_arr += 1;
            }
            break 'serve;
        }

        // --- apply any death due at or before the current time
        if deaths.first().is_some_and(|d| d.0 <= t) {
            let (died_at, ranks) = deaths.remove(0);
            let newly: Vec<usize> = ranks
                .into_iter()
                .filter(|r| !dead_all.contains(r))
                .collect();
            if newly.is_empty() {
                continue;
            }
            dead_all.extend(newly.iter().copied());
            dead_all.sort_unstable();
            let survivors = w0 - dead_all.len();
            // detect -> drain -> re-plan pause on the serving clock
            let drained = active.iter().filter(|s| s.decoding()).count();
            t = t.max(died_at)
                + detect_lat
                + cfg.rcfg.drain_per_flow * drained as f64
                + cfg.rcfg.replan_base
                + cfg.rcfg.replan_per_rank * survivors as f64;
            if survivors >= 2 {
                view = WorldView::survivors(w0, &dead_all);
            }
            for r in &newly {
                kv_load.remove(r);
            }
            // KV on the dead ranks is gone: reroute once, drop twice
            let mut rec = ServeRecovery {
                dead: newly,
                died_at,
                resumed_at: t,
                rerouted: 0,
                dropped: 0,
            };
            let mut keep = Vec::with_capacity(active.len());
            for mut s in active.drain(..) {
                if !dead_all.contains(&s.home) {
                    keep.push(s);
                } else if s.rerouted {
                    drop_req(&mut drop_reasons, "kv-lost");
                    done += 1;
                    rec.dropped += 1;
                } else {
                    s.rerouted = true;
                    s.prefill_done = 0;
                    s.decoded = 0;
                    s.kv_blocks = 0;
                    rerouted_total += 1;
                    rec.rerouted += 1;
                    queue.push_front(s);
                }
            }
            active = keep;
            recoveries.push(rec);
            continue;
        }

        // --- admit arrivals and fill the batch
        while next_arr < total && reqs[next_arr].t_arrive <= t {
            let r = reqs[next_arr];
            next_arr += 1;
            if queue.len() >= cfg.max_queue {
                drop_req(&mut drop_reasons, "queue-full");
                done += 1;
            } else {
                queue.push_back(Slot::new(r.id, r.t_arrive, r.prompt_tokens, r.output_tokens));
            }
        }
        while active.len() < cfg.max_batch {
            let Some(mut s) = queue.pop_front() else { break };
            // home the KV on the least-loaded survivor (ties -> lowest)
            // and reserve the prompt's blocks up front, so concurrent
            // admissions spread instead of piling onto one rank
            s.home = kv_load
                .iter()
                .min_by_key(|&(r, load)| (*load, *r))
                .map(|(r, _)| *r)
                .expect("at least two survivors");
            s.kv_blocks = (s.prompt as u64).div_ceil(cfg.kv_block as u64);
            *kv_load.get_mut(&s.home).expect("home is a survivor") += s.kv_blocks;
            active.push(s);
        }

        // --- idle: jump to the next arrival or death
        if active.is_empty() {
            let ta = (next_arr < total).then(|| reqs[next_arr].t_arrive);
            let td = deaths.first().map(|d| d.0);
            match (ta, td) {
                (Some(a), Some(d)) => t = t.max(a.min(d)),
                (Some(a), None) => t = t.max(a),
                (None, Some(d)) => t = t.max(d),
                (None, None) => break 'serve, // all accounted
            }
            continue;
        }

        max_q = max_q.max(queue.len());
        qsamples.push((t, queue.len()));

        // --- price the step: §3.5 partition, analytic prefill, DES decode
        let prefill_remaining: usize = active
            .iter()
            .filter(|s| !s.decoding())
            .map(|s| s.prompt - s.prefill_done)
            .sum();
        let prefill_tokens = prefill_remaining.min(cfg.prefill_chunk);
        let decode_batch = active.iter().filter(|s| s.decoding()).count();
        let part = plan_serving(&hw, decode_batch, prefill_tokens);
        let prefill_cost = if prefill_tokens > 0 {
            prefill_tokens as f64 * flops_per_token
                / hw.triton_gemm_flops(part.prefill_sms.max(1))
        } else {
            0.0
        };
        let decode_cost = if decode_batch > 0 {
            let world = view.world();
            let kv_tokens: usize = active
                .iter()
                .filter(|s| s.decoding())
                .map(|s| s.prompt + s.decoded)
                .sum();
            let kvb = ((kv_tokens / world).max(1) as u64).next_power_of_two();
            let moeb = if cfg.moe {
                (decode_batch.div_ceil(world).max(1) as u64).next_power_of_two()
            } else {
                0
            };
            let (base, ev) = decode_step_cost(
                cluster, &topo, cfg, &residual, &dead_all, &view, t, kvb, moeb, &mut memo,
            )?;
            events += ev;
            // decode compute slows when prefill holds part of the device
            base * (hw.sms as f64 / part.decode_sms.max(1) as f64)
        } else {
            0.0
        };
        let mut step = prefill_cost.max(decode_cost); // phases overlap

        // --- KV rebalance: up to `migrate_batch` migrations per step
        // while the spread stays wide, each charged at the routed
        // inter-node path bandwidth and exactly accounted
        for _ in 0..cfg.migrate_batch {
            let Some(moved) = rebalance_kv(&mut active, &mut kv_load, cfg.migrate_spread) else {
                break;
            };
            kv_migrations += 1;
            kv_blocks_moved += moved;
            step += moved as f64 * cfg.kv_block as f64 * bytes_per_token / topo.inter_path_bw();
        }

        debug_assert!(step > 0.0, "a live batch must make progress");
        t += step;

        // --- account the step's work. Decode first: only sequences
        // that were decode-phase when the step was priced emit a token
        // (a request finishing prefill this step decodes from the next
        // step, once its KV has landed).
        let mut finished: Vec<usize> = Vec::new();
        for (i, s) in active.iter_mut().enumerate() {
            if !s.decoding() {
                continue;
            }
            s.decoded += 1;
            s.t_first.get_or_insert(t);
            let grown = ((s.prompt + s.decoded) as u64).div_ceil(cfg.kv_block as u64);
            if grown > s.kv_blocks {
                *kv_load.get_mut(&s.home).expect("home is a survivor") += grown - s.kv_blocks;
                s.kv_blocks = grown;
            }
            if s.decoded >= s.output {
                finished.push(i);
            }
        }
        for i in finished.into_iter().rev() {
            let s = active.swap_remove(i);
            *kv_load.get_mut(&s.home).expect("home is a survivor") -= s.kv_blocks;
            let ttft = s.t_first.expect("completed => first token") - s.t_arrive;
            per_request.push(ReqStat {
                id: s.id,
                t_arrive: s.t_arrive,
                ttft,
                latency: t - s.t_arrive,
                tokens: s.output,
            });
            tokens_out += s.output as u64;
            done += 1;
        }
        let mut budget = prefill_tokens;
        for s in active.iter_mut() {
            if s.decoding() || budget == 0 {
                continue;
            }
            let take = budget.min(s.prompt - s.prefill_done);
            s.prefill_done += take;
            budget -= take;
        }
    }

    // --- distill the report
    let ttfts: Vec<f64> = per_request.iter().map(|r| r.ttft).collect();
    let lats: Vec<f64> = per_request.iter().map(|r| r.latency).collect();
    let tpots: Vec<f64> = per_request
        .iter()
        .map(|r| {
            if r.tokens > 1 {
                (r.latency - r.ttft) / (r.tokens - 1) as f64
            } else {
                0.0
            }
        })
        .collect();
    let completed = per_request.len();
    let dropped: usize = drop_reasons.values().sum();
    debug_assert_eq!(completed + dropped, total, "request conservation");
    Ok(ServingReport {
        requests: total,
        completed,
        dropped,
        drop_reasons: drop_reasons
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
        rerouted: rerouted_total,
        p50_ttft: percentile(&ttfts, 50.0),
        p99_ttft: percentile(&ttfts, 99.0),
        p50_tpot: percentile(&tpots, 50.0),
        p99_tpot: percentile(&tpots, 99.0),
        p50_latency: percentile(&lats, 50.0),
        p99_latency: percentile(&lats, 99.0),
        tokens_out,
        goodput: tokens_out as f64 / t.max(1e-12),
        makespan: t,
        queue_depth: downsample(&qsamples, 256),
        max_queue_depth: max_q,
        kv_migrations,
        kv_blocks_moved,
        recoveries,
        events,
        per_request,
    })
}

/// Convenience: materialize a [`TracePlan`] and serve it.
pub fn run_serve_plan(
    cluster: ClusterSpec,
    plan: &TracePlan,
    faults: FaultPlan,
    cfg: &ServeCfg,
) -> Result<ServingReport, CoordError> {
    run_serve(cluster, &plan.materialize(), faults, cfg)
}

/// Price one decode step by running the coordinator programs through
/// the engine: flash-decode attention (+ the EP-MoE FFN) on the current
/// world, under the residual plan projected onto this step's clock.
/// Memoized per `(world, kv bucket, moe bucket)` when the residual is
/// empty — the dead set grows monotonically, so within a run the world
/// size uniquely identifies the survivor view.
#[allow(clippy::too_many_arguments)]
fn decode_step_cost(
    cluster: ClusterSpec,
    topo: &Topology,
    cfg: &ServeCfg,
    residual: &FaultPlan,
    dead_all: &[usize],
    view: &WorldView,
    t: f64,
    kvb: u64,
    moeb: u64,
    memo: &mut BTreeMap<(usize, u64, u64), (f64, u64)>,
) -> Result<(f64, u64), CoordError> {
    let key = (view.world(), kvb, moeb);
    if residual.is_empty() {
        if let Some(&hit) = memo.get(&key) {
            return Ok(hit);
        }
    }
    let fp = shift_plan(residual, dead_all, t, t);
    let fcfg = FlashDecodeCfg {
        heads: cfg.heads,
        head_dim: cfg.head_dim,
        kv_per_rank: kvb as usize,
        numeric: false,
    };
    let mut op = if view.is_identity() {
        flash_decode::build(cluster, fcfg).0
    } else {
        build_flash_decode_degraded(cluster, fcfg, view)
    };
    let rep = run_timing_threads(&mut op, topo, fp.clone(), cfg.threads)?;
    let mut cost = rep.makespan;
    let mut ev = rep.events;
    if cfg.moe {
        let shape = MoeShape {
            tokens_per_rank: moeb as usize,
            in_hidden: cfg.moe_hidden,
            out_hidden: cfg.moe_hidden,
            experts: cfg.moe_experts,
            topk: 2,
            ..MoeShape::default()
        };
        let routing0 = routing_for(cluster, &shape, cfg.moe_seed);
        let a2a = A2aCfg::ours();
        let (mut mop, _bufs) = if view.is_identity() {
            build_ep_moe_cfg(cluster, shape, &routing0, EpMoeVariant::TokenRouted, &a2a)
        } else {
            let routing = survivor_routing(&shape, &routing0, view);
            build_ep_moe_view(
                cluster,
                shape,
                &routing,
                EpMoeVariant::TokenRouted,
                &a2a,
                view,
            )
        };
        let mrep = run_timing_threads(&mut mop, topo, fp, cfg.threads)?;
        cost += mrep.makespan;
        ev += mrep.events;
    }
    if residual.is_empty() {
        memo.insert(key, (cost, ev));
    }
    Ok((cost, ev))
}

/// Slice a full-world routing table down to survivor rows with capacity
/// recomputed for the smaller world (the same re-plan the elastic EP
/// MoE controller performs).
fn survivor_routing(shape: &MoeShape, routing0: &EpRouting, view: &WorldView) -> EpRouting {
    let g0 = routing0.geom;
    let wsur = view.world();
    let tk = g0.t * g0.k;
    let mut idx = Vec::with_capacity(wsur * tk);
    let mut gate = Vec::with_capacity(wsur * tk);
    for l in 0..wsur {
        let pr = view.phys(l);
        idx.extend_from_slice(&routing0.idx[pr * tk..(pr + 1) * tk]);
        gate.extend_from_slice(&routing0.gate[pr * tk..(pr + 1) * tk]);
    }
    let gsur = EpGeom {
        w: wsur,
        c: shape.expert_capacity(wsur),
        ..g0
    };
    EpRouting::from_table(gsur, idx, gate)
}

/// Move one request's KV blocks from the most- to the least-loaded rank
/// when the spread exceeds the trigger; returns blocks moved. One
/// migration per call keeps each choice deterministic (ties break
/// toward the lowest rank); the serving loop calls this up to
/// [`ServeCfg::migrate_batch`] times per step.
fn rebalance_kv(
    active: &mut [Slot],
    kv_load: &mut BTreeMap<usize, u64>,
    spread: u64,
) -> Option<u64> {
    let (&hot, &hot_load) = kv_load.iter().max_by_key(|&(r, load)| (*load, std::cmp::Reverse(*r)))?;
    let (&cold, &cold_load) = kv_load.iter().min_by_key(|&(r, load)| (*load, *r))?;
    if hot == cold || hot_load - cold_load < spread {
        return None;
    }
    // migrate the smallest resident request on the hot rank that still
    // narrows the spread (deterministic: lowest id among candidates)
    let mv = active
        .iter_mut()
        .filter(|s| s.home == hot && s.kv_blocks > 0)
        .min_by_key(|s| (s.kv_blocks, s.id))?;
    let blocks = mv.kv_blocks;
    mv.home = cold;
    *kv_load.get_mut(&hot).expect("hot rank exists") -= blocks;
    *kv_load.get_mut(&cold).expect("cold rank exists") += blocks;
    Some(blocks)
}

/// Keep at most `n` evenly spaced samples (deterministic).
fn downsample(xs: &[(f64, usize)], n: usize) -> Vec<(f64, usize)> {
    if xs.len() <= n {
        return xs.to_vec();
    }
    (0..n).map(|i| xs[i * xs.len() / n]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cluster() -> ClusterSpec {
        ClusterSpec::h800(1, 4)
    }

    fn small_cfg() -> ServeCfg {
        ServeCfg {
            max_batch: 8,
            moe_experts: 8,
            ..ServeCfg::default()
        }
    }

    #[test]
    fn empty_trace_is_a_noop() {
        let rep = run_serve(
            small_cluster(),
            &ArrivalTrace::default(),
            FaultPlan::default(),
            &small_cfg(),
        )
        .unwrap();
        assert_eq!(rep, ServingReport::default());
    }

    #[test]
    fn batched_migration_conserves_and_replays() {
        // an aggressive spread trigger forces migrations; batching must
        // keep exact accounting and bit-identical replays
        let plan = TracePlan::parse("poisson,1e5,24,3; lens,512,16").unwrap();
        let trace = plan.materialize();
        let batched_cfg = ServeCfg {
            migrate_spread: 1,
            migrate_batch: 4,
            ..small_cfg()
        };
        let a = run_serve(small_cluster(), &trace, FaultPlan::default(), &batched_cfg).unwrap();
        let b = run_serve(small_cluster(), &trace, FaultPlan::default(), &batched_cfg).unwrap();
        assert_eq!(a, b, "batched migration must replay bit-for-bit");
        assert!(a.kv_migrations > 0, "spread 1 must trigger migrations");
        assert!(a.kv_blocks_moved >= a.kv_migrations, "every migration moves >= 1 block");
        assert_eq!(a.completed + a.dropped, a.requests);
    }

    #[test]
    fn tiny_trace_conserves_and_replays() {
        let plan = TracePlan::parse("poisson,2e4,12,7; lens,64,8").unwrap();
        let trace = plan.materialize();
        let cfg = small_cfg();
        let a = run_serve(small_cluster(), &trace, FaultPlan::default(), &cfg).unwrap();
        let b = run_serve(small_cluster(), &trace, FaultPlan::default(), &cfg).unwrap();
        assert_eq!(a, b, "same trace + plan must replay bit-for-bit");
        assert_eq!(a.requests, 12);
        assert_eq!(a.completed + a.dropped, a.requests);
        assert_eq!(a.completed, a.per_request.len());
        assert!(a.p50_ttft <= a.p99_ttft);
        assert!(a.p50_latency <= a.p99_latency);
        for r in &a.per_request {
            assert!(r.ttft <= r.latency, "req {}: ttft > latency", r.id);
        }
        assert!(a.makespan > 0.0 && a.events > 0);
        assert!(a.goodput > 0.0);
    }
}
