//! AG+MoE and MoE+RS (Tables 4 and 5): tensor-parallel MoE GroupGEMM
//! overlapped with AllGather / ReduceScatter, plus the PyTorch+NCCL
//! baseline ("Python loops for GroupGEMMs", §4.1).

use crate::collectives::allgather::ag_push_intra;
use crate::collectives::allgather::ag_inter;
use crate::collectives::baseline::{nccl_allgather_ring_done, nccl_reduce_scatter_ring};
use crate::collectives::reduce_scatter::{rs_inter, rs_push_intra};
use crate::collectives::{AgBufs, ProgBuild, RsBufs};
use crate::config::{ClusterSpec, MoeShape};
use crate::kernels::names::Entry;
use crate::mem::{BufId, Slice, SymmetricHeap};
use crate::overlap::plan_inter_rs;
use crate::overlap::swizzle;
use crate::program::{ComputeCost, NumericOp, Op, SigCond, SigOp};
use crate::util::Rng;

use super::{setup, BuiltOp};

/// PyTorch eager-mode per-expert dispatch overhead (python op dispatch +
/// cuBLAS setup per small GEMM). Calibrated so Table 4's PyTorch column
/// lands in the paper's millisecond range.
const TORCH_PER_EXPERT_OVERHEAD: f64 = 0.35e-3;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MoeVariant {
    /// Ours: overlapped AllGather + per-chunk GroupGEMM.
    Ours,
    /// PyTorch+NCCL: ring AG, then a Python loop of per-expert GEMMs.
    Torch,
}

/// Small-expert GEMM utilization: grouped GEMMs with few rows per expert
/// underfeed the tensor cores. Rows below ~128 scale throughput down
/// linearly (the effect behind the paper's absolute Table-4 latencies).
/// Shared with the expert-parallel pipeline (`coordinator::ep_moe`).
pub(crate) fn group_gemm_utilization(rows_per_expert: f64) -> f64 {
    // row-count term x grouped-kernel term (per-expert tile tails,
    // routing-dependent loads keep grouped GEMMs well below dense rate)
    (rows_per_expert / 128.0).min(1.0).max(0.05) * 0.45
}

/// Fixed routing cost per chunk (topk gather/scatter + offsets kernel).
pub(crate) const ROUTING_OVERHEAD: f64 = 12.0e-6;

/// Expert capacity used throughout (tokens routed per expert chunk).
pub fn capacity(t_per_chunk: usize, topk: usize, experts: usize) -> usize {
    // 2x the balanced load, matching the generous-buffer policy the
    // paper adopts over DeepEP's queue management
    (2 * t_per_chunk * topk).div_ceil(experts).max(1)
}

pub struct AgMoeBufs {
    pub ag: AgBufs,
    pub idx: BufId,
    pub gate: BufId,
    pub weight: BufId,
    pub output: BufId,
    pub t_per_rank: usize,
    pub shape: MoeShape,
    pub f_local: usize,
    pub cap: usize,
}

/// Build AG+MoE. `shape.out_hidden` is split across ranks (TP).
pub fn build_ag_moe(cluster: ClusterSpec, shape: MoeShape, variant: MoeVariant) -> (BuiltOp, AgMoeBufs) {
    let (ctx, _t) = setup(cluster);
    let ws = ctx.n_pes();
    let t_pr = shape.tokens_per_rank;
    let t_total = t_pr * ws;
    let h = shape.in_hidden;
    let f_local = shape.out_hidden / ws.min(shape.out_hidden);
    let cap = capacity(t_pr, shape.topk, shape.experts);
    let hw = cluster.hw;

    let mut heap = SymmetricHeap::new(ws, 4 * ws.max(16) + 8);
    let ag = AgBufs::alloc(&mut heap, &ctx, t_pr * h);
    let idx = heap.alloc("topk_idx", t_total * shape.topk);
    let gate = heap.alloc("topk_gate", t_total * shape.topk);
    let weight = heap.alloc("w_experts", shape.experts * h * f_local);
    let output = heap.alloc("moe_out", t_total * f_local);
    let bufs = AgMoeBufs {
        ag,
        idx,
        gate,
        weight,
        output,
        t_per_rank: t_pr,
        shape,
        f_local,
        cap,
    };

    let mut pb = ProgBuild::new();
    let util = group_gemm_utilization((t_pr * shape.topk) as f64 / shape.experts as f64);
    let chunk_flops = 2.0 * (t_pr * shape.topk) as f64 * h as f64 * f_local as f64 / util;
    let entry = Entry::moe_ffn_name(t_pr, h, f_local, shape.experts, shape.topk, cap);

    match variant {
        MoeVariant::Ours => {
            if ctx.n_nodes() > 1 {
                ag_inter(&ctx, &bufs.ag, &mut pb);
            } else {
                ag_push_intra(&ctx, &bufs.ag, &mut pb);
            }
            for r in 0..ws {
                let mut t = ctx
                    .task(r, format!("moe_group_gemm[{r}]"))
                    .with_sms(hw.sms - if ctx.n_nodes() > 1 { 8 } else { 0 })
                    .launch_overhead();
                for &chunk in &swizzle::nv_push_order(r, ws) {
                    t.signal_wait_until(bufs.ag.sig(chunk), SigCond::Ge, 1);
                    t.op(Op::Sleep { secs: ROUTING_OVERHEAD });
                    t.op(moe_chunk_op(&bufs, &entry, chunk, r, chunk_flops, false));
                }
                pb.prog.push(t.build());
            }
        }
        MoeVariant::Torch => {
            let done = bufs.ag.sig_base + ws;
            nccl_allgather_ring_done(&ctx, &bufs.ag, &mut pb, 16, Some(done));
            for r in 0..ws {
                let mut t = ctx
                    .task(r, format!("torch_moe[{r}]"))
                    .with_sms(hw.sms)
                    .launch_overhead();
                t.signal_wait_until(done, SigCond::Ge, 1);
                // Python loop: per-expert launch overhead + vendor GEMM
                let per_expert_flops =
                    2.0 * (t_total * shape.topk / shape.experts) as f64 * h as f64 * f_local as f64;
                for _e in 0..shape.experts {
                    t.op(Op::Sleep {
                        secs: TORCH_PER_EXPERT_OVERHEAD,
                    });
                    t.op(Op::Compute {
                        cost: ComputeCost::Gemm {
                            flops: per_expert_flops,
                            vendor: true,
                        },
                        numeric: NumericOp::None,
                        label: "torch_expert_gemm",
                    });
                }
                // numerics once over each gathered chunk (same math)
                for chunk in 0..ws {
                    t.op(moe_chunk_op(&bufs, &entry, chunk, r, 0.0, true));
                }
                pb.prog.push(t.build());
            }
        }
    }

    let op = BuiltOp {
        ctx,
        heap,
        prog: pb.prog,
        name: format!("AG+MoE {variant:?}"),
    };
    (op, bufs)
}

fn moe_chunk_op(
    bufs: &AgMoeBufs,
    entry: &str,
    chunk: usize,
    r: usize,
    flops: f64,
    free: bool,
) -> Op {
    let t_pr = bufs.t_per_rank;
    let k = bufs.shape.topk;
    let f = bufs.f_local;
    Op::Compute {
        cost: if free {
            ComputeCost::Fixed { secs: 0.0 }
        } else {
            ComputeCost::Gemm { flops, vendor: false }
        },
        numeric: NumericOp::Call {
            entry: entry.to_string(),
            args: vec![
                bufs.ag.seg(chunk, r),
                Slice::new(r, bufs.idx, chunk * t_pr * k, t_pr * k),
                Slice::new(r, bufs.gate, chunk * t_pr * k, t_pr * k),
                Slice::new(r, bufs.weight, 0, bufs.shape.experts * bufs.shape.in_hidden * f),
            ],
            outs: vec![Slice::new(r, bufs.output, chunk * t_pr * f, t_pr * f)],
        },
        label: "moe_group_gemm_chunk",
    }
}

/// Seed: tokens per rank, routing replicated across ranks, weights
/// rank-local (each rank owns its out-hidden shard).
pub fn fill_ag_moe(heap: &mut SymmetricHeap, bufs: &AgMoeBufs, seed: u64) {
    crate::collectives::fill_ag_inputs(heap, &bufs.ag, seed);
    let ws = heap.world();
    let t_total = bufs.t_per_rank * ws;
    let k = bufs.shape.topk;
    let mut rng = Rng::new(seed ^ 0xBEEF);
    let idx: Vec<f32> = (0..t_total * k)
        .map(|_| rng.usize_in(0, bufs.shape.experts) as f32)
        .collect();
    let gate: Vec<f32> = (0..t_total * k).map(|_| rng.f32().max(0.05)).collect();
    for r in 0..ws {
        heap.write(Slice::new(r, bufs.idx, 0, idx.len()), &idx);
        heap.write(Slice::new(r, bufs.gate, 0, gate.len()), &gate);
        let mut wrng = Rng::new(seed ^ ((r as u64) << 21));
        let w = wrng.normal_vec(heap.buf_len(bufs.weight));
        heap.write(Slice::new(r, bufs.weight, 0, w.len()), &w);
    }
}

/// Reference per rank: moe over the concatenated tokens with that rank's
/// weight shard.
pub fn reference_ag_moe(heap: &SymmetricHeap, bufs: &AgMoeBufs) -> Vec<Vec<f32>> {
    let ws = heap.world();
    let t_pr = bufs.t_per_rank;
    let h = bufs.shape.in_hidden;
    let k = bufs.shape.topk;
    (0..ws)
        .map(|r| {
            let w = heap.read(Slice::new(r, bufs.weight, 0, heap.buf_len(bufs.weight)));
            let mut out = Vec::new();
            for chunk in 0..ws {
                let tokens = heap.read(bufs.ag.seg(chunk, chunk));
                let idx = heap.read(Slice::new(r, bufs.idx, chunk * t_pr * k, t_pr * k));
                let gate = heap.read(Slice::new(r, bufs.gate, chunk * t_pr * k, t_pr * k));
                out.extend(crate::kernels::exec::moe_ffn(
                    tokens,
                    idx,
                    gate,
                    w,
                    t_pr,
                    h,
                    bufs.f_local,
                    bufs.shape.experts,
                    k,
                    bufs.cap,
                ));
            }
            out
        })
        .collect()
}

pub fn verify_ag_moe(heap: &SymmetricHeap, bufs: &AgMoeBufs, expected: &[Vec<f32>]) -> Result<(), String> {
    for (r, exp) in expected.iter().enumerate() {
        let got = heap.read(Slice::new(r, bufs.output, 0, exp.len()));
        for (i, (g, e)) in got.iter().zip(exp).enumerate() {
            if (g - e).abs() > 1e-3_f32.max(e.abs() * 1e-4) {
                return Err(format!("AG+MoE mismatch rank {r} elem {i}: {g} vs {e}"));
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// MoE+RS
// ---------------------------------------------------------------------------

pub struct MoeRsBufs {
    pub tokens: BufId,
    pub idx: BufId,
    pub gate: BufId,
    pub weight: BufId,
    pub rs: RsBufs,
    pub t_per_rank: usize,
    pub h_local: usize,
    pub shape: MoeShape,
    pub cap: usize,
}

const PROD_SIG_BASE: usize = 100;

/// Build MoE+RS: each rank computes partial expert outputs for all tokens
/// with its in-hidden weight shard; ReduceScatter sums and scatters.
pub fn build_moe_rs(cluster: ClusterSpec, shape: MoeShape, variant: MoeVariant) -> (BuiltOp, MoeRsBufs) {
    let (ctx, topo) = setup(cluster);
    let ws = ctx.n_pes();
    let t_pr = shape.tokens_per_rank;
    let t_total = t_pr * ws;
    let h_local = shape.in_hidden / ws.min(shape.in_hidden);
    let f = shape.out_hidden;
    let cap = capacity(t_pr, shape.topk, shape.experts);
    let hw = cluster.hw;

    // chunk-ready signals live above every RS variant's footprint
    let prod_sig_base = PROD_SIG_BASE.max(crate::collectives::rs_sig_span(&ctx));
    let mut heap = SymmetricHeap::new(ws, prod_sig_base + ws + 8);
    let tokens = heap.alloc("tokens", t_total * h_local);
    let idx = heap.alloc("topk_idx", t_total * shape.topk);
    let gate = heap.alloc("topk_gate", t_total * shape.topk);
    let weight = heap.alloc("w_experts", shape.experts * h_local * f);
    let rs = RsBufs::alloc(&mut heap, &ctx, t_pr * f);
    let bufs = MoeRsBufs {
        tokens,
        idx,
        gate,
        weight,
        rs,
        t_per_rank: t_pr,
        h_local,
        shape,
        cap,
    };

    let mut pb = ProgBuild::new();
    pb.claim_sigs("moe_rs_producer", prod_sig_base, ws);
    let util = group_gemm_utilization((t_pr * shape.topk) as f64 / shape.experts as f64);
    let chunk_flops = 2.0 * (t_pr * shape.topk) as f64 * h_local as f64 * f as f64 / util;
    let entry = Entry::moe_ffn_name(t_pr, h_local, f, shape.experts, shape.topk, cap);
    let part = plan_inter_rs(&hw, ctx.local_world_size(), topo.inter_path_bw());

    // producer GroupGEMM per chunk
    for r in 0..ws {
        let order: Vec<usize> = match variant {
            MoeVariant::Ours if ctx.n_nodes() > 1 => {
                swizzle::inter_rs_order(r, ctx.n_nodes(), ctx.local_world_size())
            }
            MoeVariant::Ours => swizzle::nv_pull_order(r, ws).into_iter().skip(1).chain([r]).collect(),
            MoeVariant::Torch => swizzle::identity_order(r, ws),
        };
        let gemm_sms = match variant {
            MoeVariant::Ours => hw.sms - part.reduce1_sms - 1,
            MoeVariant::Torch => hw.sms,
        };
        let mut t = ctx
            .task(r, format!("moe_producer[{r}]"))
            .with_sms(gemm_sms)
            .launch_overhead();
        for &chunk in &order {
            t.op(Op::Sleep {
                secs: if matches!(variant, MoeVariant::Torch) {
                    // python-loop overhead amortized over chunks
                    TORCH_PER_EXPERT_OVERHEAD * shape.experts as f64 / ws as f64
                } else {
                    ROUTING_OVERHEAD
                },
            });
            t.op(Op::Compute {
                cost: ComputeCost::Gemm {
                    flops: chunk_flops,
                    vendor: matches!(variant, MoeVariant::Torch),
                },
                numeric: NumericOp::Call {
                    entry: entry.clone(),
                    args: vec![
                        Slice::new(r, tokens, chunk * t_pr * h_local, t_pr * h_local),
                        Slice::new(r, idx, chunk * t_pr * shape.topk, t_pr * shape.topk),
                        Slice::new(r, gate, chunk * t_pr * shape.topk, t_pr * shape.topk),
                        Slice::new(r, weight, 0, shape.experts * h_local * f),
                    ],
                    outs: vec![bufs.rs.in_chunk(chunk, r)],
                },
                label: "moe_chunk",
            });
            t.notify(r, prod_sig_base + chunk, SigOp::Set, 1);
        }
        pb.prog.push(t.build());
    }

    match variant {
        MoeVariant::Ours => {
            if ctx.n_nodes() > 1 {
                rs_inter(
                    &ctx,
                    &bufs.rs,
                    &mut pb,
                    part.reduce1_sms,
                    part.reduce2_sms,
                    Some(prod_sig_base),
                );
            } else {
                rs_push_intra(&ctx, &bufs.rs, &mut pb, part.reduce1_sms, Some(prod_sig_base));
            }
        }
        MoeVariant::Torch => {
            let before = pb.prog.tasks.len();
            nccl_reduce_scatter_ring(&ctx, &bufs.rs, &mut pb, 16);
            for task in pb.prog.tasks.iter_mut().skip(before) {
                let mut gates: Vec<Op> = (0..ws)
                    .map(|c| Op::WaitSignal {
                        idx: prod_sig_base + c,
                        cond: SigCond::Eq,
                        value: 1,
                    })
                    .collect();
                gates.extend(task.ops.drain(..));
                task.ops = gates;
            }
        }
    }

    let op = BuiltOp {
        ctx,
        heap,
        prog: pb.prog,
        name: format!("MoE+RS {variant:?}"),
    };
    (op, bufs)
}

pub fn fill_moe_rs(heap: &mut SymmetricHeap, bufs: &MoeRsBufs, seed: u64) {
    let ws = heap.world();
    let t_total = bufs.t_per_rank * ws;
    let k = bufs.shape.topk;
    let mut rng = Rng::new(seed ^ 0xF00D);
    let idx: Vec<f32> = (0..t_total * k)
        .map(|_| rng.usize_in(0, bufs.shape.experts) as f32)
        .collect();
    let gate: Vec<f32> = (0..t_total * k).map(|_| rng.f32().max(0.05)).collect();
    for r in 0..ws {
        heap.write(Slice::new(r, bufs.idx, 0, idx.len()), &idx);
        heap.write(Slice::new(r, bufs.gate, 0, gate.len()), &gate);
        let mut lrng = Rng::new(seed ^ ((r as u64) << 13));
        let toks = lrng.normal_vec(heap.buf_len(bufs.tokens));
        heap.write(Slice::new(r, bufs.tokens, 0, toks.len()), &toks);
        let w = lrng.normal_vec(heap.buf_len(bufs.weight));
        heap.write(Slice::new(r, bufs.weight, 0, w.len()), &w);
    }
}

/// Reference: sum over ranks of each rank's partial MoE, scattered.
pub fn reference_moe_rs(heap: &SymmetricHeap, bufs: &MoeRsBufs) -> Vec<Vec<f32>> {
    let ws = heap.world();
    let t_pr = bufs.t_per_rank;
    let f = bufs.shape.out_hidden;
    let k = bufs.shape.topk;
    let mut total = vec![0.0f32; t_pr * ws * f];
    for r in 0..ws {
        let w = heap.read(Slice::new(r, bufs.weight, 0, heap.buf_len(bufs.weight)));
        for chunk in 0..ws {
            let toks = heap.read(Slice::new(r, bufs.tokens, chunk * t_pr * bufs.h_local, t_pr * bufs.h_local));
            let idx = heap.read(Slice::new(r, bufs.idx, chunk * t_pr * k, t_pr * k));
            let gate = heap.read(Slice::new(r, bufs.gate, chunk * t_pr * k, t_pr * k));
            let partial = crate::kernels::exec::moe_ffn(
                toks, idx, gate, w, t_pr, bufs.h_local, f, bufs.shape.experts, k, bufs.cap,
            );
            for (o, p) in total[chunk * t_pr * f..(chunk + 1) * t_pr * f]
                .iter_mut()
                .zip(partial)
            {
                *o += p;
            }
        }
    }
    (0..ws)
        .map(|r| total[r * t_pr * f..(r + 1) * t_pr * f].to_vec())
        .collect()
}

pub fn verify_moe_rs(heap: &SymmetricHeap, bufs: &MoeRsBufs, expected: &[Vec<f32>]) -> Result<(), String> {
    for (r, exp) in expected.iter().enumerate() {
        let got = heap.read(bufs.rs.out(r));
        for (i, (g, e)) in got.iter().zip(exp).enumerate() {
            if (g - e).abs() > 1e-3_f32.max(e.abs() * 1e-4) {
                return Err(format!("MoE+RS mismatch rank {r} elem {i}: {g} vs {e}"));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HybridExecutor;
    use crate::topology::Topology;

    fn small_shape() -> MoeShape {
        MoeShape {
            tokens_per_rank: 8,
            in_hidden: 16,
            out_hidden: 32,
            experts: 4,
            topk: 2,
            ..MoeShape::default()
        }
    }

    #[test]
    fn ag_moe_ours_correct() {
        let cluster = ClusterSpec::h800(1, 4);
        let (mut op, bufs) = build_ag_moe(cluster, small_shape(), MoeVariant::Ours);
        fill_ag_moe(&mut op.heap, &bufs, 1);
        let exp = reference_ag_moe(&op.heap, &bufs);
        let topo = Topology::build(cluster);
        let mut exec = HybridExecutor::native_only();
        super::super::run_numeric(&mut op, &topo, &mut exec).unwrap();
        verify_ag_moe(&op.heap, &bufs, &exp).unwrap();
    }

    #[test]
    fn ag_moe_torch_correct() {
        let cluster = ClusterSpec::h800(1, 4);
        let (mut op, bufs) = build_ag_moe(cluster, small_shape(), MoeVariant::Torch);
        fill_ag_moe(&mut op.heap, &bufs, 2);
        let exp = reference_ag_moe(&op.heap, &bufs);
        let topo = Topology::build(cluster);
        let mut exec = HybridExecutor::native_only();
        super::super::run_numeric(&mut op, &topo, &mut exec).unwrap();
        verify_ag_moe(&op.heap, &bufs, &exp).unwrap();
    }

    #[test]
    fn ag_moe_ours_inter_correct() {
        let cluster = ClusterSpec::h800(2, 2);
        let (mut op, bufs) = build_ag_moe(cluster, small_shape(), MoeVariant::Ours);
        fill_ag_moe(&mut op.heap, &bufs, 3);
        let exp = reference_ag_moe(&op.heap, &bufs);
        let topo = Topology::build(cluster);
        let mut exec = HybridExecutor::native_only();
        super::super::run_numeric(&mut op, &topo, &mut exec).unwrap();
        verify_ag_moe(&op.heap, &bufs, &exp).unwrap();
    }

    #[test]
    fn moe_rs_ours_correct() {
        let cluster = ClusterSpec::h800(1, 4);
        let (mut op, bufs) = build_moe_rs(cluster, small_shape(), MoeVariant::Ours);
        fill_moe_rs(&mut op.heap, &bufs, 4);
        let exp = reference_moe_rs(&op.heap, &bufs);
        let topo = Topology::build(cluster);
        let mut exec = HybridExecutor::native_only();
        super::super::run_numeric(&mut op, &topo, &mut exec).unwrap();
        verify_moe_rs(&op.heap, &bufs, &exp).unwrap();
    }

    #[test]
    fn moe_rs_ours_inter_correct() {
        let cluster = ClusterSpec::h800(2, 2);
        let (mut op, bufs) = build_moe_rs(cluster, small_shape(), MoeVariant::Ours);
        fill_moe_rs(&mut op.heap, &bufs, 5);
        let exp = reference_moe_rs(&op.heap, &bufs);
        let topo = Topology::build(cluster);
        let mut exec = HybridExecutor::native_only();
        super::super::run_numeric(&mut op, &topo, &mut exec).unwrap();
        verify_moe_rs(&op.heap, &bufs, &exp).unwrap();
    }

    #[test]
    fn moe_rs_torch_correct() {
        let cluster = ClusterSpec::h800(1, 4);
        let (mut op, bufs) = build_moe_rs(cluster, small_shape(), MoeVariant::Torch);
        fill_moe_rs(&mut op.heap, &bufs, 6);
        let exp = reference_moe_rs(&op.heap, &bufs);
        let topo = Topology::build(cluster);
        let mut exec = HybridExecutor::native_only();
        super::super::run_numeric(&mut op, &topo, &mut exec).unwrap();
        verify_moe_rs(&op.heap, &bufs, &exp).unwrap();
    }

    #[test]
    fn ours_much_faster_than_torch_timing() {
        // Table 4's mechanism: the python expert loop dominates.
        let cluster = ClusterSpec::h800(1, 8);
        let shape = MoeShape {
            tokens_per_rank: 256,
            in_hidden: 2048,
            out_hidden: 1408,
            experts: 60,
            topk: 4,
            ..MoeShape::default()
        };
        let topo = Topology::build(cluster);
        let t = |v| {
            let (mut op, _b) = build_ag_moe(cluster, shape, v);
            super::super::run_timing(&mut op, &topo).unwrap()
        };
        let speedup = t(MoeVariant::Torch) / t(MoeVariant::Ours);
        assert!(speedup > 5.0, "speedup {speedup}");
    }
}
