//! Expert-parallel MoE over the railed fabric (§3.5–§3.7's flagship
//! multi-node workload): routing-metadata **counts exchange** (a small
//! AllToAll carried as real fabric traffic) → topk routing table →
//! **token-routed** railed dispatch (`a2a_ep_rails_var`,
//! sender-plane-pinned) → grouped expert FFN sized by the *actual*
//! received token counts → combine crossing into each receiver's home
//! plane (`TrafficClass::Rails { tx, rx }`) → gate-weighted per-token
//! reduction.
//!
//! Unlike `coordinator::moe` (tensor-parallel, fixed `capacity()`
//! padding), every wire message and every FFN here is sized from the
//! [`EpRouting`] summary — the DeepEP-style "routing drives the wire"
//! design. [`EpMoeVariant::FixedCapacity`] keeps the old policy as the
//! baseline: every (src, dst) message and every expert buffer padded to
//! the capacity-factor slot count, whatever the routing says.
//!
//! Numerics are exact end to end: the `ep_dispatch` / `ep_ffn` /
//! `ep_combine` kernels and [`reference_ep_moe`] replay the identical
//! f32 operation order, so [`verify_ep_moe`] compares outputs with `==`
//! (no tolerance) and additionally checks that every kept (token, k)
//! pair's row crossed the dispatch wire exactly once — the token
//! conservation proof.

use crate::collectives::alltoall::{
    a2a_ep_rails_var_on, A2aCfg, A2aEpDir, A2aSizes, A2aVarBufs, EpRouting,
};
use crate::collectives::{ProgBuild, WorldView};
use crate::config::{ClusterSpec, MoeShape};
use crate::kernels::exec::{matmul, FixedPlan};
use crate::kernels::names::EpGeom;
use crate::mem::{BufId, Slice, SymmetricHeap};
use crate::program::{ComputeCost, NumericOp, Op, SigCond, SigOp};
use crate::util::Rng;

use super::moe::{group_gemm_utilization, ROUTING_OVERHEAD};
use super::{setup, BuiltOp};

/// Which wire/compute sizing policy the EP pipeline uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EpMoeVariant {
    /// Token-routed: every message and FFN sized by the actual routing
    /// counts (the tentpole path; full numerics).
    TokenRouted,
    /// Fixed-capacity baseline: every (src, dst) message padded to
    /// `e_local * cap_src` rows and the FFN to the matching padded row
    /// count, independent of routing. Carries full numerics through the
    /// `ep_*_fixed` kernel family (zero-padded slots, deterministic
    /// overflow drop beyond `cap_src` per (source, expert)); with
    /// generous caps its outputs are **bitwise equal** to
    /// [`EpMoeVariant::TokenRouted`].
    FixedCapacity,
}

/// Buffers + geometry of one built EP MoE pipeline.
pub struct EpMoeBufs {
    /// This rank's own tokens, `[t, h]`.
    pub tokens: BufId,
    /// Replicated topk expert-index table (f32-carried), `[w * t * k]`.
    pub idx: BufId,
    /// Replicated gate table, `[w * t * k]`.
    pub gate: BufId,
    /// Rank-local expert weights, `[e_local, h, f]`.
    pub weight: BufId,
    /// Final per-token output, `[t, f]`.
    pub output: BufId,
    /// Routing-metadata landing zone, `[w, e_local]`: slot `s` holds the
    /// per-local-expert row counts rank `s` announced before dispatch
    /// (token-routed variant only; the fixed-capacity baseline needs no
    /// exchange — its sizes are static).
    pub counts: BufId,
    /// Dispatch wire (token rows to expert ranks).
    pub disp: A2aVarBufs,
    /// Combine wire (FFN rows back to token owners).
    pub comb: A2aVarBufs,
    pub geom: EpGeom,
    pub e_local: usize,
    pub variant: EpMoeVariant,
    /// Per-(source, expert) slot cap of the fixed-capacity wire (also
    /// computed for the token-routed variant, where it is unused).
    pub cap_src: usize,
}

/// Generate the routing summary for `cluster`/`shape` (the step that, on
/// real hardware, the metadata exchange before dispatch performs): topk
/// sampled with the shape's popularity skew, capacity from its
/// capacity factor.
pub fn routing_for(cluster: ClusterSpec, shape: &MoeShape, seed: u64) -> EpRouting {
    let ws = cluster.world_size();
    let geom = EpGeom {
        t: shape.tokens_per_rank,
        h: shape.in_hidden,
        f: shape.out_hidden,
        e: shape.experts,
        k: shape.topk,
        c: shape.expert_capacity(ws),
        w: ws,
    };
    EpRouting::generate(geom, shape.skew, seed)
}

/// Build the EP MoE pipeline with the default transport knobs
/// ([`A2aCfg::ours`]). The routing summary must match the cluster's
/// world size (see [`routing_for`]); it sizes every wire message, the
/// grouped FFN, and the numeric kernel entries.
pub fn build_ep_moe(
    cluster: ClusterSpec,
    shape: MoeShape,
    routing: &EpRouting,
    variant: EpMoeVariant,
) -> (BuiltOp, EpMoeBufs) {
    build_ep_moe_cfg(cluster, shape, routing, variant, &A2aCfg::ours())
}

/// [`build_ep_moe`] with explicit transport knobs — notably
/// [`A2aCfg::split`], the dispatch-chunking factor the §3.8 tuner
/// explores (`autotune::tune_dispatch_chunking`, CLI `--split`).
pub fn build_ep_moe_cfg(
    cluster: ClusterSpec,
    shape: MoeShape,
    routing: &EpRouting,
    variant: EpMoeVariant,
    a2a: &A2aCfg,
) -> (BuiltOp, EpMoeBufs) {
    let view = WorldView::identity(cluster.world_size());
    build_ep_moe_view(cluster, shape, routing, variant, a2a, &view)
}

/// [`build_ep_moe_cfg`] over an explicit [`WorldView`] — the
/// survivor-indexed form the elastic recovery controller re-plans with
/// after a permanent rank/node death. The routing table, size tables,
/// and signal map are *logical* (`view.world()` wide, which must equal
/// `routing.geom.w`); tasks, slices, and rail homes land on the
/// surviving **physical** ranks of the original cluster. The identity
/// view is bit-identical to [`build_ep_moe_cfg`].
pub fn build_ep_moe_view(
    cluster: ClusterSpec,
    shape: MoeShape,
    routing: &EpRouting,
    variant: EpMoeVariant,
    a2a: &A2aCfg,
    view: &WorldView,
) -> (BuiltOp, EpMoeBufs) {
    let (ctx, _t) = setup(cluster);
    let ws = view.world();
    assert!(
        (0..ws).all(|l| view.phys(l) < ctx.n_pes()),
        "world view addresses ranks outside the cluster"
    );
    let geom = routing.geom;
    assert_eq!(geom.w, ws, "routing table built for a different world");
    let EpGeom { t, h, f, e, k, .. } = geom;
    let e_local = e.div_ceil(ws);
    let hw = cluster.hw;

    // fixed-capacity baseline: DeepEP-style static per-(source, expert)
    // slots at the shape's capacity factor
    let cap_src = ((shape.capacity_factor * (t * k) as f64 / e as f64).ceil() as usize).max(1);
    let (disp_sizes, comb_sizes) = match variant {
        EpMoeVariant::TokenRouted => (routing.dispatch_sizes(), routing.combine_sizes()),
        EpMoeVariant::FixedCapacity => (
            A2aSizes::uniform(ws, e_local * cap_src * h),
            A2aSizes::uniform(ws, e_local * cap_src * f),
        ),
    };

    // signal map: [0, ws) dispatch arrivals | ws pack gate |
    // [ws+1, 2ws+1) combine arrivals | 2ws+1 FFN gate |
    // [2ws+2, 3ws+2) counts arrivals (routing-metadata exchange)
    let disp_gate = ws;
    let comb_base = ws + 1;
    let comb_gate = 2 * ws + 1;
    let counts_base = 2 * ws + 2;

    // the heap stays physical-world-sized: a survivor re-plan keeps the
    // dead ranks' heap space but never addresses it
    let mut heap = SymmetricHeap::new(ctx.n_pes(), 3 * ws + 8);
    let tokens = heap.alloc("ep_tokens", t * h);
    let idx = heap.alloc("ep_topk_idx", ws * t * k);
    let gate = heap.alloc("ep_topk_gate", ws * t * k);
    let weight = heap.alloc("ep_w_experts", e_local * h * f);
    let counts = heap.alloc("ep_counts", ws * e_local);
    let disp = A2aVarBufs::alloc(&mut heap, disp_sizes);
    let mut comb = A2aVarBufs::alloc(&mut heap, comb_sizes);
    comb.sig_base = comb_base;
    let output = heap.alloc("ep_out", t * f);

    let mut pb = ProgBuild::new();
    pb.claim_sigs("ep_moe_pack_gate", disp_gate, 1);
    pb.claim_sigs("ep_moe_ffn_gate", comb_gate, 1);
    pb.claim_sigs("ep_moe_counts", counts_base, ws);
    let cfg = *a2a;

    // Static SM budget per rank (§3.8 partition discipline): the two a2a
    // send tasks, 2*(ws-1) receive blocks, the pack task, the counts
    // exchange (1 SM, retires before dispatch opens), and the final
    // reduction all hold their reservation concurrently; the FFN takes
    // the rest (floored so very wide worlds still fit — excess receive
    // blocks then queue FIFO behind completed ones, which cannot
    // deadlock because receives never wait on later-launched tasks).
    let reserved = 2 * ws as i64 + 6;
    let ffn_sms = ((hw.sms as i64) - reserved).max(8) as u32;

    // 0. routing-metadata exchange (token-routed only): every receiver
    // must learn how many rows each peer will land on it before dispatch
    // can begin. On real hardware this is the counts AllToAll DeepEP runs
    // ahead of dispatch; here it is actual fabric traffic — tiny
    // per-expert count rows pushed with putmem_signal — so its latency
    // is part of the makespan instead of build-time omniscience. It
    // overlaps the dispatch pack; the pack gate below only opens once
    // both have finished.
    if variant == EpMoeVariant::TokenRouted {
        for r in 0..ws {
            let pr = view.phys(r);
            let mut cnt = ctx
                .task(pr, format!("ep_counts[{r}]"))
                .with_sms(1)
                .launch_overhead();
            let row = Slice::new(pr, counts, r * e_local, e_local);
            for i in 1..ws {
                let dst = (r + i) % ws;
                cnt.putmem_signal_nbi(
                    row,
                    row.on_rank(view.phys(dst)),
                    counts_base + r,
                    SigOp::Set,
                    1,
                );
            }
            // own counts are locally available immediately
            cnt.notify(pr, counts_base + r, SigOp::Set, 1);
            cnt.quiet();
            pb.prog.push(cnt.build());
        }
    }

    // 1. per-rank routing + dispatch pack into the packed send buffer
    for r in 0..ws {
        let pr = view.phys(r);
        let send_elems = disp.sizes.send_total(r);
        let mut pack = ctx
            .task(pr, format!("ep_pack[{r}]"))
            .with_sms(1)
            .launch_overhead();
        pack.op(Op::Sleep {
            secs: ROUTING_OVERHEAD,
        });
        pack.op(Op::Compute {
            cost: ComputeCost::MemBound {
                bytes: ctx.bytes(2 * send_elems),
            },
            numeric: NumericOp::Call {
                entry: match variant {
                    EpMoeVariant::TokenRouted => geom.dispatch_name(r),
                    EpMoeVariant::FixedCapacity => geom.dispatch_fixed_name(cap_src, r),
                },
                args: vec![
                    Slice::new(pr, tokens, 0, t * h),
                    Slice::new(pr, idx, 0, ws * t * k),
                ],
                outs: (0..ws).map(|d| disp.send_chunk(d, r).on_rank(pr)).collect(),
            },
            label: "ep_dispatch_pack",
        });
        // dispatch may not start until every peer's counts have landed:
        // the wait sits after the pack compute so the metadata exchange
        // overlaps it rather than serializing ahead of it
        if variant == EpMoeVariant::TokenRouted {
            for src in 0..ws {
                pack.signal_wait_until(counts_base + src, SigCond::Ge, 1);
            }
        }
        pack.notify(pr, disp_gate, SigOp::Set, 1);
        pb.prog.push(pack.build());
    }

    // 2. railed dispatch: every message pinned to the sender's home
    // plane end to end, sized by the routing summary
    a2a_ep_rails_var_on(&ctx, &disp, &mut pb, &cfg, A2aEpDir::Dispatch, Some(disp_gate), view);

    // 3. grouped expert FFN sized by the *actual* received token counts
    for r in 0..ws {
        let pr = view.phys(r);
        let n_rows = disp.sizes.recv_total(r) / h.max(1);
        let util = group_gemm_utilization(n_rows as f64 / e_local as f64);
        let flops = 2.0 * n_rows as f64 * h as f64 * f as f64 / util;
        let mut ffn = ctx
            .task(pr, format!("ep_ffn[{r}]"))
            .with_sms(ffn_sms)
            .launch_overhead();
        for src in 0..ws {
            ffn.signal_wait_until(disp.sig(src), SigCond::Ge, 1);
        }
        ffn.op(Op::Sleep {
            secs: ROUTING_OVERHEAD,
        });
        ffn.op(Op::Compute {
            cost: ComputeCost::Gemm {
                flops,
                vendor: false,
            },
            numeric: NumericOp::Call {
                entry: match variant {
                    EpMoeVariant::TokenRouted => geom.ffn_name(r),
                    EpMoeVariant::FixedCapacity => geom.ffn_fixed_name(cap_src, r),
                },
                args: vec![
                    Slice::new(pr, disp.recv, 0, disp.sizes.recv_total(r)),
                    Slice::new(pr, idx, 0, ws * t * k),
                    Slice::new(pr, weight, 0, e_local * h * f),
                ],
                outs: vec![Slice::new(pr, comb.send, 0, comb.sizes.send_total(r))],
            },
            label: "ep_group_ffn",
        });
        ffn.notify(pr, comb_gate, SigOp::Set, 1);
        pb.prog.push(ffn.build());
    }

    // 4. combine: each message leaves on the expert rank's home plane
    // and crosses into the token owner's plane (Rails { tx, rx }).
    // Deadline 0 marks these pieces as gating — their arrival releases
    // the weighted-reduction consumer, so the chunk scheduler lets them
    // overtake bulk dispatch backlogs from concurrent collectives.
    let comb_cfg = cfg.with_deadline(0);
    a2a_ep_rails_var_on(&ctx, &comb, &mut pb, &comb_cfg, A2aEpDir::Combine, Some(comb_gate), view);

    // 5. gate-weighted reduction into the token owner's output
    for r in 0..ws {
        let pr = view.phys(r);
        let m_elems = comb.sizes.recv_total(r);
        let mut red = ctx
            .task(pr, format!("ep_combine[{r}]"))
            .with_sms(4)
            .launch_overhead();
        for src in 0..ws {
            red.signal_wait_until(comb.sig(src), SigCond::Ge, 1);
        }
        red.op(Op::Compute {
            cost: ComputeCost::Reduce {
                bytes: ctx.bytes(m_elems + t * f),
            },
            numeric: NumericOp::Call {
                entry: match variant {
                    EpMoeVariant::TokenRouted => geom.combine_name(r),
                    EpMoeVariant::FixedCapacity => geom.combine_fixed_name(cap_src, r),
                },
                args: vec![
                    Slice::new(pr, comb.recv, 0, m_elems),
                    Slice::new(pr, idx, 0, ws * t * k),
                    Slice::new(pr, gate, 0, ws * t * k),
                ],
                outs: vec![Slice::new(pr, output, 0, t * f)],
            },
            label: "ep_token_combine",
        });
        pb.prog.push(red.build());
    }

    let bufs = EpMoeBufs {
        tokens,
        idx,
        gate,
        weight,
        output,
        counts,
        disp,
        comb,
        geom,
        e_local,
        variant,
        cap_src,
    };
    let op = BuiltOp {
        ctx,
        heap,
        prog: pb.prog,
        name: format!("EP MoE {variant:?}"),
    };
    (op, bufs)
}

/// Seed tokens and expert weights (rank-local) and replicate the routing
/// tables — the state the metadata exchange distributes before dispatch.
pub fn fill_ep_moe(heap: &mut SymmetricHeap, bufs: &EpMoeBufs, routing: &EpRouting, seed: u64) {
    fill_ep_moe_view(heap, bufs, routing, seed, &WorldView::identity(bufs.geom.w))
}

/// [`fill_ep_moe`] over an explicit [`WorldView`]. Seeding is chosen so a
/// survivor re-plan restores exactly the state a real elastic system
/// recovers:
/// * **tokens** come from a per-*physical*-rank stream — each survivor
///   keeps its own tokens unchanged across the re-shard;
/// * **expert weights** come from one stream per *global expert*, so an
///   expert re-homed to a survivor regenerates bit-identical weights
///   (the checkpoint/replica-restore a re-shard performs).
pub fn fill_ep_moe_view(
    heap: &mut SymmetricHeap,
    bufs: &EpMoeBufs,
    routing: &EpRouting,
    seed: u64,
    view: &WorldView,
) {
    let g = bufs.geom;
    let idx_f: Vec<f32> = routing.idx.iter().map(|&i| i as f32).collect();
    for l in 0..g.w {
        let pr = view.phys(l);
        heap.write(Slice::new(pr, bufs.idx, 0, idx_f.len()), &idx_f);
        heap.write(Slice::new(pr, bufs.gate, 0, routing.gate.len()), &routing.gate);
        let mut rng = Rng::new(seed ^ ((pr as u64) << 17) ^ 0xE9);
        let toks = rng.normal_vec(heap.buf_len(bufs.tokens));
        heap.write(Slice::new(pr, bufs.tokens, 0, toks.len()), &toks);
        for el in 0..bufs.e_local {
            let ei = l * bufs.e_local + el;
            if ei >= g.e {
                break;
            }
            let mut wrng = Rng::new(seed ^ ((ei as u64) << 29) ^ 0x77E1);
            heap.write(
                Slice::new(pr, bufs.weight, el * g.h * g.f, g.h * g.f),
                &wrng.normal_vec(g.h * g.f),
            );
        }
    }
}

/// Reference output per token-owner rank, replaying the pipeline's exact
/// f32 operation order (row GEMM per kept pair, gate-weighted
/// accumulation in (token, k) order) — bitwise comparable.
pub fn reference_ep_moe(
    heap: &SymmetricHeap,
    bufs: &EpMoeBufs,
    routing: &EpRouting,
) -> Vec<Vec<f32>> {
    reference_ep_moe_view(heap, bufs, routing, &WorldView::identity(bufs.geom.w))
}

/// [`reference_ep_moe`] over an explicit [`WorldView`]: logical rank
/// `r`'s tokens and logical expert rank `d`'s weights are read from
/// their physical homes.
pub fn reference_ep_moe_view(
    heap: &SymmetricHeap,
    bufs: &EpMoeBufs,
    routing: &EpRouting,
    view: &WorldView,
) -> Vec<Vec<f32>> {
    let g = bufs.geom;
    let plan = routing.plan();
    let e_local = bufs.e_local;
    (0..g.w)
        .map(|r| {
            let toks = heap.read(Slice::new(view.phys(r), bufs.tokens, 0, g.t * g.h));
            let mut out = vec![0.0f32; g.t * g.f];
            for ti in 0..g.t {
                for ki in 0..g.k {
                    let gi = (r * g.t + ti) * g.k + ki;
                    let Some(d) = plan.dst_of(gi) else { continue };
                    let el = routing.idx[gi] - d * e_local;
                    let w = heap
                        .read(Slice::new(view.phys(d), bufs.weight, el * g.h * g.f, g.h * g.f));
                    let row = matmul(&toks[ti * g.h..(ti + 1) * g.h], w, 1, g.h, g.f);
                    let gv = routing.gate[gi];
                    for (o, &v) in out[ti * g.f..(ti + 1) * g.f].iter_mut().zip(&row) {
                        *o += gv * v;
                    }
                }
            }
            out
        })
        .collect()
}

/// Reference output of the **fixed-capacity** pipeline: same walk as
/// [`reference_ep_moe`] but gated on the [`FixedPlan`] slot claim
/// (per-(source, expert) cap, overflow dropped) instead of the global
/// capacity claim — bitwise comparable to the `ep_*_fixed` kernels.
pub fn reference_ep_moe_fixed(
    heap: &SymmetricHeap,
    bufs: &EpMoeBufs,
    routing: &EpRouting,
) -> Vec<Vec<f32>> {
    let g = bufs.geom;
    let plan = FixedPlan::build(&routing.idx, g, bufs.cap_src);
    let e_local = bufs.e_local;
    (0..g.w)
        .map(|r| {
            let toks = heap.read(Slice::new(r, bufs.tokens, 0, g.t * g.h));
            let mut out = vec![0.0f32; g.t * g.f];
            for ti in 0..g.t {
                for ki in 0..g.k {
                    let gi = (r * g.t + ti) * g.k + ki;
                    if plan.slot_of(gi).is_none() {
                        continue;
                    }
                    let (d, el) = (routing.idx[gi] / e_local, routing.idx[gi] % e_local);
                    let w = heap.read(Slice::new(d, bufs.weight, el * g.h * g.f, g.h * g.f));
                    let row = matmul(&toks[ti * g.h..(ti + 1) * g.h], w, 1, g.h, g.f);
                    let gv = routing.gate[gi];
                    for (o, &v) in out[ti * g.f..(ti + 1) * g.f].iter_mut().zip(&row) {
                        *o += gv * v;
                    }
                }
            }
            out
        })
        .collect()
}

/// Verify the pipeline numerics for either variant: (1) exact token
/// conservation — every expert rank's dispatch landing zone holds
/// precisely the kept routed rows (packed in plan order for
/// [`EpMoeVariant::TokenRouted`]; zero-padded per-(source, expert)
/// slots for [`EpMoeVariant::FixedCapacity`]); (2) the final outputs
/// equal the matching reference with **no tolerance** (identical f32
/// operation order end to end).
pub fn verify_ep_moe(
    heap: &SymmetricHeap,
    bufs: &EpMoeBufs,
    routing: &EpRouting,
    expected: &[Vec<f32>],
) -> Result<(), String> {
    verify_ep_moe_view(heap, bufs, routing, expected, &WorldView::identity(bufs.geom.w))
}

/// [`verify_ep_moe`] over an explicit [`WorldView`]: logical rank `r`'s
/// buffers are read from their physical homes, so a survivor re-plan
/// can be verified on the original (larger) physical heap.
pub fn verify_ep_moe_view(
    heap: &SymmetricHeap,
    bufs: &EpMoeBufs,
    routing: &EpRouting,
    expected: &[Vec<f32>],
    view: &WorldView,
) -> Result<(), String> {
    let g = bufs.geom;
    match bufs.variant {
        EpMoeVariant::TokenRouted => {
            let plan = routing.plan();
            for d in 0..g.w {
                let mut exp = Vec::new();
                for src in 0..g.w {
                    let toks =
                        heap.read(Slice::new(view.phys(src), bufs.tokens, 0, g.t * g.h));
                    for p in 0..g.t * g.k {
                        let gi = src * g.t * g.k + p;
                        if plan.dst_of(gi) == Some(d) {
                            let ti = p / g.k;
                            exp.extend_from_slice(&toks[ti * g.h..(ti + 1) * g.h]);
                        }
                    }
                }
                let got = heap.read(Slice::new(view.phys(d), bufs.disp.recv, 0, exp.len()));
                if got != exp {
                    return Err(format!(
                        "token conservation violated: expert rank {d} landing zone \
                         does not match the routed rows"
                    ));
                }
                if exp.len() != plan.recv_total(d) * g.h {
                    return Err(format!("expert rank {d} received a wrong row count"));
                }
            }
        }
        EpMoeVariant::FixedCapacity => {
            // fixed wire: each (src -> d) chunk is e_local slot blocks of
            // cap_src zero-padded rows; verify the padded layout exactly
            let plan = FixedPlan::build(&routing.idx, g, bufs.cap_src);
            let e_local = bufs.e_local;
            let cs = bufs.cap_src;
            let chunk = e_local * cs * g.h;
            for d in 0..g.w {
                let mut exp = vec![0.0f32; g.w * chunk];
                for src in 0..g.w {
                    let toks =
                        heap.read(Slice::new(view.phys(src), bufs.tokens, 0, g.t * g.h));
                    for p in 0..g.t * g.k {
                        let gi = src * g.t * g.k + p;
                        let Some(s) = plan.slot_of(gi) else { continue };
                        if routing.idx[gi] / e_local != d {
                            continue;
                        }
                        let el = routing.idx[gi] % e_local;
                        let ti = p / g.k;
                        let off = src * chunk + (el * cs + s) * g.h;
                        exp[off..off + g.h]
                            .copy_from_slice(&toks[ti * g.h..(ti + 1) * g.h]);
                    }
                }
                let got = heap.read(Slice::new(view.phys(d), bufs.disp.recv, 0, exp.len()));
                if got != exp {
                    return Err(format!(
                        "token conservation violated: expert rank {d} fixed landing \
                         zone does not match the padded slot layout"
                    ));
                }
            }
        }
    }
    for (r, exp) in expected.iter().enumerate() {
        let got = heap.read(Slice::new(view.phys(r), bufs.output, 0, exp.len()));
        if got != exp.as_slice() {
            let i = got
                .iter()
                .zip(exp)
                .position(|(a, b)| a != b)
                .unwrap_or(0);
            return Err(format!(
                "EP MoE mismatch rank {r} elem {i}: {} vs {} (exact compare)",
                got[i], exp[i]
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FabricSpec;
    use crate::coordinator::{run_numeric, run_timing};
    use crate::runtime::HybridExecutor;
    use crate::topology::Topology;

    fn small_shape() -> MoeShape {
        MoeShape {
            tokens_per_rank: 6,
            in_hidden: 8,
            out_hidden: 8,
            experts: 8,
            topk: 2,
            ..MoeShape::default()
        }
    }

    fn run_and_verify(cluster: ClusterSpec, shape: MoeShape, seed: u64) {
        let routing = routing_for(cluster, &shape, seed);
        let (mut op, bufs) = build_ep_moe(cluster, shape, &routing, EpMoeVariant::TokenRouted);
        fill_ep_moe(&mut op.heap, &bufs, &routing, seed);
        let exp = reference_ep_moe(&op.heap, &bufs, &routing);
        let topo = Topology::build(cluster);
        let mut exec = HybridExecutor::native_only();
        run_numeric(&mut op, &topo, &mut exec).unwrap();
        verify_ep_moe(&op.heap, &bufs, &routing, &exp).unwrap();
    }

    #[test]
    fn ep_moe_intra_node_exact() {
        run_and_verify(ClusterSpec::h800(1, 4), small_shape(), 1);
    }

    #[test]
    fn ep_moe_inter_node_exact() {
        run_and_verify(ClusterSpec::h800(2, 2), small_shape(), 2);
    }

    #[test]
    fn ep_moe_exact_under_skew_and_drops_on_railed_fabric() {
        // skewed popularity + a tight capacity factor force real drops;
        // conservation and exact numerics must hold regardless, on a
        // blocking railed fabric with a thinned spine
        let cluster = ClusterSpec::h800(2, 4)
            .with_fabric(FabricSpec::rail_optimized(2, 2.0).with_spine_taper(2.0));
        let shape = small_shape().with_skew(1.5).with_capacity_factor(0.75);
        let routing = routing_for(cluster, &shape, 9);
        assert!(routing.dropped() > 0, "tight capacity must drop pairs");
        run_and_verify(cluster, shape, 9);
    }

    #[test]
    fn token_routed_beats_fixed_capacity_under_skew() {
        // the acceptance race: skewed popularity on the railed fabric —
        // sizing wire + FFN from actual routed tokens beats the padded
        // fixed-capacity baseline
        let cluster = ClusterSpec::h800(2, 8)
            .with_fabric(FabricSpec::rail_optimized(2, 2.0).with_spine_taper(2.0));
        let shape = MoeShape {
            tokens_per_rank: 64,
            in_hidden: 256,
            out_hidden: 256,
            experts: 16,
            topk: 2,
            ..MoeShape::default()
        }
        .with_skew(1.2);
        let routing = routing_for(cluster, &shape, 7);
        let topo = Topology::build(cluster);
        let time = |variant| {
            let (mut op, _b) = build_ep_moe(cluster, shape, &routing, variant);
            run_timing(&mut op, &topo).unwrap()
        };
        let routed = time(EpMoeVariant::TokenRouted);
        let fixed = time(EpMoeVariant::FixedCapacity);
        assert!(
            routed < fixed,
            "token-routed {routed} must beat fixed-capacity {fixed}"
        );
    }

    #[test]
    fn fixed_capacity_numerics_match_token_routed_bitwise() {
        // generous caps (factor 8 == e, so cap_src >= t*k and the global
        // cap never drops): the padded fixed-capacity pipeline must be
        // bit-for-bit identical to the token-routed one
        let cluster = ClusterSpec::h800(2, 2);
        let shape = small_shape().with_capacity_factor(8.0);
        let routing = routing_for(cluster, &shape, 5);
        assert_eq!(routing.dropped(), 0, "generous cap must not drop");
        let topo = Topology::build(cluster);
        let run = |variant| {
            let (mut op, bufs) = build_ep_moe(cluster, shape, &routing, variant);
            fill_ep_moe(&mut op.heap, &bufs, &routing, 5);
            let exp = match variant {
                EpMoeVariant::TokenRouted => reference_ep_moe(&op.heap, &bufs, &routing),
                EpMoeVariant::FixedCapacity => {
                    assert!(bufs.cap_src >= shape.tokens_per_rank * shape.topk);
                    reference_ep_moe_fixed(&op.heap, &bufs, &routing)
                }
            };
            let mut exec = HybridExecutor::native_only();
            run_numeric(&mut op, &topo, &mut exec).unwrap();
            verify_ep_moe(&op.heap, &bufs, &routing, &exp).unwrap();
            (0..bufs.geom.w)
                .map(|r| op.heap.read(Slice::new(r, bufs.output, 0, exp[r].len())))
                .collect::<Vec<_>>()
        };
        assert_eq!(
            run(EpMoeVariant::TokenRouted),
            run(EpMoeVariant::FixedCapacity),
            "fixed-capacity outputs must be bitwise equal under generous caps"
        );
    }

    #[test]
    fn capacity_factor_drop_accounting() {
        let cluster = ClusterSpec::h800(1, 4);
        // factor 8 means capacity == total routed pairs: a drop is
        // impossible whatever the draw
        let generous = routing_for(cluster, &small_shape().with_capacity_factor(8.0), 3);
        assert_eq!(generous.dropped(), 0, "full capacity never drops");
        let tight = routing_for(cluster, &small_shape().with_capacity_factor(0.5), 3);
        assert!(tight.dropped() > 0);
        let g = tight.geom;
        assert_eq!(tight.kept() + tight.dropped(), g.w * g.t * g.k);
    }
}
