//! Low-latency expert-parallel AllToAll (§4.2 "Low-latency AllToAll",
//! Fig. 16): token dispatch/combine for inference MoE.
//!
//! Our kernel (the paper's): LL protocol everywhere, NVLink for intra-node
//! peers, IBRC for inter-node peers (each inter message pays a proxy-thread
//! post overhead, serialized per rank — IBRC's scaling tax).
//!
//! The DeepEP-like baseline: IBGDA (GPU-initiated, much cheaper per
//! message, no proxy serialization) but IB for *all* peers including
//! intra-node ones, plus a memory-queue management cost per message. This
//! encodes exactly the structural trade the paper describes: we win up to
//! ~32 ranks on NVLink + simplicity, IBGDA wins at 64+.

use crate::mem::{BufId, Slice, SymmetricHeap};
use crate::program::{ComputeCost, NumericOp, Op, SigOp};
use crate::shmem::{ShmemCtx, ShmemTask};
use crate::topology::Topology;

use super::ProgBuild;

/// AllToAll working set: `send` holds one chunk per destination rank;
/// `recv` holds one slot per source rank.
#[derive(Debug, Clone, Copy)]
pub struct A2aBufs {
    pub send: BufId,
    pub recv: BufId,
    /// LL staging on the receive side.
    pub ll: BufId,
    /// Elements per (src, dst) chunk.
    pub chunk: usize,
    pub sig_base: usize,
}

impl A2aBufs {
    pub fn alloc(heap: &mut SymmetricHeap, ctx: &ShmemCtx, chunk: usize) -> Self {
        let ws = ctx.n_pes();
        A2aBufs {
            send: heap.alloc("a2a_send", ws * chunk),
            recv: heap.alloc("a2a_recv", ws * chunk),
            ll: heap.alloc("a2a_ll", ws * chunk),
            chunk,
            sig_base: 0,
        }
    }

    pub fn send_chunk(&self, dst: usize, on: usize) -> Slice {
        Slice::new(on, self.send, dst * self.chunk, self.chunk)
    }

    pub fn recv_slot(&self, src: usize, on: usize) -> Slice {
        Slice::new(on, self.recv, src * self.chunk, self.chunk)
    }

    pub fn ll_slot(&self, src: usize, on: usize) -> Slice {
        Slice::new(on, self.ll, src * self.chunk, self.chunk)
    }

    /// Arrival signal for the chunk from `src`.
    pub fn sig(&self, src: usize) -> usize {
        self.sig_base + src
    }
}

/// Transport/runtime knobs distinguishing our kernel from DeepEP.
#[derive(Debug, Clone, Copy)]
pub struct A2aCfg {
    /// Per-inter-node-message CPU/GPU post overhead, serialized in the
    /// sending task (IBRC proxy ≈ 1 µs; IBGDA ≈ 0.2 µs).
    pub inter_msg_overhead: f64,
    /// Route intra-node traffic over the NIC instead of NVLink
    /// (DeepEP's IB-only data path).
    pub intra_via_nic: bool,
    /// Per-message memory-queue management cost (DeepEP's queue logic;
    /// we "allocate a much larger buffer and omit the control logic").
    pub queue_overhead: f64,
}

impl A2aCfg {
    /// Our Triton-distributed kernel: NVLink intra, IBRC inter, no queue.
    /// The IBRC proxy-thread post cost (~1.2 us, serialized per rank) is
    /// the scaling tax that lets IBGDA win at 64 GPUs (§4.2).
    pub fn ours() -> Self {
        A2aCfg {
            inter_msg_overhead: 1.45e-6,
            intra_via_nic: false,
            queue_overhead: 0.0,
        }
    }

    /// DeepEP-like: IBGDA posts, IB-only path, memory-queue bookkeeping.
    pub fn deepep() -> Self {
        A2aCfg {
            inter_msg_overhead: 0.15e-6,
            intra_via_nic: true,
            queue_overhead: 0.2e-6,
        }
    }
}

/// Shared LL AllToAll program body: per rank, a self-copy, a shifted send
/// walk whose inter-node messages get a per-message plane assignment via
/// `plane(task, src, dst, inter_idx)`, a quiet fence, and `ws - 1`
/// receive/unpack blocks. [`a2a_ll`] stripes through the fabric's rail
/// policy; [`a2a_ep_rails`] pins explicit (possibly asymmetric) planes.
fn a2a_ll_body(
    ctx: &ShmemCtx,
    bufs: &A2aBufs,
    pb: &mut ProgBuild,
    cfg: &A2aCfg,
    who: &'static str,
    prefix: &str,
    mut plane: impl FnMut(&mut ShmemTask, usize, usize, usize),
) {
    let ws = ctx.n_pes();
    pb.claim_sigs(who, bufs.sig_base, ws);
    let chunk_bytes = ctx.bytes(bufs.chunk);

    for r in 0..ws {
        let node = ctx.node_of(r);
        let mut send = ctx
            .task(r, format!("{prefix}_send[{r}]"))
            .with_sms(1)
            .launch_overhead();
        // self chunk: local copy, immediately available
        send.op(Op::Compute {
            cost: ComputeCost::MemBound {
                bytes: chunk_bytes * 2.0,
            },
            numeric: NumericOp::Copy {
                src: bufs.send_chunk(r, r),
                dst: bufs.recv_slot(r, r),
            },
            label: "a2a_self_copy",
        });
        send.notify(r, bufs.sig(r), SigOp::Set, 1);
        let mut inter_idx = 0usize;
        for i in 1..ws {
            let dst = (r + i) % ws;
            if ctx.node_of(dst) != node {
                // IBRC/IBGDA post cost, serialized in the sender, then
                // the message's fabric plane assignment
                send.op(Op::Sleep {
                    secs: cfg.inter_msg_overhead,
                });
                plane(&mut send, r, dst, inter_idx);
                inter_idx += 1;
            }
            if cfg.queue_overhead > 0.0 {
                send.op(Op::Sleep {
                    secs: cfg.queue_overhead,
                });
            }
            send.ll_put(bufs.send_chunk(dst, r), bufs.ll_slot(r, dst));
        }
        send.quiet();
        pb.prog.push(send.build());

        // receive blocks: unpack LL slots into the recv buffer
        for src in 0..ws {
            if src == r {
                continue;
            }
            let mut t = ctx
                .task(r, format!("{prefix}_recv[{r}<-{src}]"))
                .with_sms(1)
                .launch_overhead();
            t.recv_ll(bufs.ll_slot(src, r));
            t.op(Op::Compute {
                cost: ComputeCost::MemBound {
                    bytes: chunk_bytes * 2.0,
                },
                numeric: NumericOp::Copy {
                    src: bufs.ll_slot(src, r),
                    dst: bufs.recv_slot(src, r),
                },
                label: "a2a_unpack",
            });
            if cfg.queue_overhead > 0.0 {
                t.op(Op::Sleep {
                    secs: cfg.queue_overhead,
                });
            }
            t.notify(r, bufs.sig(src), SigOp::Set, 1);
            pb.prog.push(t.build());
        }
    }
}

/// Build one direction of the low-latency AllToAll (dispatch; combine is
/// the same program with swapped buffers). Every rank LL-sends its chunk
/// to every peer (shifted walk) and hosts `ws-1` receive blocks.
/// Inter-node messages stripe across NIC rails (round-robin, or by live
/// congestion under `RailPolicy::Adaptive`).
pub fn a2a_ll(ctx: &ShmemCtx, bufs: &A2aBufs, pb: &mut ProgBuild, cfg: &A2aCfg) {
    a2a_ll_body(ctx, bufs, pb, cfg, "a2a_ll", "a2a", |t, _src, _dst, idx| {
        t.stripe_rail(idx);
    })
}

/// Force-intra-via-NIC variant used by the DeepEP baseline: identical
/// program, but intra-node chunks are routed over the NIC by sending to a
/// same-node peer *through the IB loopback*. The DES has no notion of
/// "forced transport", so we model it with an explicit relay topology
/// trick: the timing size is unchanged but the flow is charged to the NIC
/// links by targeting the inter-node route of a sibling rank pair when one
/// exists; on a single node we add the equivalent serialization delay.
pub fn a2a_deepep(ctx: &ShmemCtx, bufs: &A2aBufs, pb: &mut ProgBuild) {
    a2a_deepep_cfg(ctx, bufs, pb, &A2aCfg::deepep())
}

/// [`a2a_deepep`] with explicit knobs (the combine direction pays ~3x the
/// queue cost: topk partials per token flow through the memory queue).
pub fn a2a_deepep_cfg(ctx: &ShmemCtx, bufs: &A2aBufs, pb: &mut ProgBuild, cfg: &A2aCfg) {
    let cfg = *cfg;
    let ws = ctx.n_pes();
    pb.claim_sigs("a2a_deepep", bufs.sig_base, ws);
    let chunk_bytes = ctx.bytes(bufs.chunk);
    let hw = ctx.cluster.hw;

    for r in 0..ws {
        let node = ctx.node_of(r);
        let mut send = ctx
            .task(r, format!("deepep_send[{r}]"))
            .with_sms(1)
            .launch_overhead();
        send.op(Op::Compute {
            cost: ComputeCost::MemBound {
                bytes: chunk_bytes * 2.0,
            },
            numeric: NumericOp::Copy {
                src: bufs.send_chunk(r, r),
                dst: bufs.recv_slot(r, r),
            },
            label: "a2a_self_copy",
        });
        send.notify(r, bufs.sig(r), SigOp::Set, 1);
        let mut inter_idx = 0usize;
        for i in 1..ws {
            let dst = (r + i) % ws;
            let inter = ctx.node_of(dst) != node;
            send.op(Op::Sleep {
                secs: cfg.inter_msg_overhead + cfg.queue_overhead,
            });
            if inter {
                // IBGDA posts stripe across rails like ours does
                send.stripe_rail(inter_idx);
                inter_idx += 1;
                send.ll_put(bufs.send_chunk(dst, r), bufs.ll_slot(r, dst));
            } else {
                // intra chunk forced through the IB loopback: charge the
                // NIC bandwidth + latency difference as *extra wire bytes*
                // on the flow (concurrent with other messages, unlike a
                // serialized sleep — DMA engines pipeline these)
                let penalty_bytes =
                    chunk_bytes * (hw.intra_bw / hw.nic_bw - 1.0).max(0.0)
                        + (hw.inter_lat - hw.intra_lat) * hw.intra_bw;
                send.op(Op::LLPut {
                    src: bufs.send_chunk(dst, r),
                    dst: bufs.ll_slot(r, dst),
                    bytes: chunk_bytes + penalty_bytes,
                    tc: Default::default(),
                });
            }
        }
        send.quiet();
        pb.prog.push(send.build());

        for src in 0..ws {
            if src == r {
                continue;
            }
            let mut t = ctx
                .task(r, format!("deepep_recv[{r}<-{src}]"))
                .with_sms(1)
                .launch_overhead();
            t.recv_ll(bufs.ll_slot(src, r));
            t.op(Op::Compute {
                cost: ComputeCost::MemBound {
                    bytes: chunk_bytes * 2.0,
                },
                numeric: NumericOp::Copy {
                    src: bufs.ll_slot(src, r),
                    dst: bufs.recv_slot(src, r),
                },
                label: "a2a_unpack",
            });
            t.op(Op::Sleep {
                secs: cfg.queue_overhead,
            });
            t.notify(r, bufs.sig(src), SigOp::Set, 1);
            pb.prog.push(t.build());
        }
    }
}

/// Direction of the expert-parallel AllToAll (token routing to experts
/// vs gathering partials back).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum A2aEpDir {
    /// Tokens to experts: every message pinned to the **sender's** home
    /// plane end-to-end (rail-optimized, no spine crossing).
    Dispatch,
    /// Expert outputs back to token owners: sender's home plane out,
    /// **receiver's** home plane in — a `TrafficClass::Rails { tx, rx }`
    /// spine-crossing path whenever the two home planes differ.
    Combine,
}

/// Expert-parallel AllToAll with **asymmetric tx/rx plane assignment** —
/// the first collective to emit `TrafficClass::Rails { tx, rx }`
/// end-to-end (ROADMAP open item).
///
/// A GPU's *home plane* is `local_rank % rails` (the NIC plane its
/// rail-optimized leaf port belongs to). Dispatch pins each message to
/// the sender's home plane on both ends: messages from different senders
/// leave on disjoint planes and never cross the spine. Combine routes
/// each message out of the sender's home plane *into the receiver's home
/// plane*, so any pair whose local ranks land on different planes takes
/// the spine-crossing path — under a tapered spine
/// (`FabricSpec::with_spine_taper`) those transfers contend on **both**
/// planes' cores, which is exactly the asymmetry this variant exists to
/// model.
///
/// Program structure (LL protocol, send/recv blocks, overheads) matches
/// [`a2a_ll`] exactly — they share one program builder; only the
/// per-message plane assignment differs.
pub fn a2a_ep_rails(
    ctx: &ShmemCtx,
    bufs: &A2aBufs,
    pb: &mut ProgBuild,
    cfg: &A2aCfg,
    dir: A2aEpDir,
) {
    let rails = ctx.cluster.fabric.rails;
    let home = |pe: usize| ctx.local_rank_of(pe) % rails;
    a2a_ll_body(ctx, bufs, pb, cfg, "a2a_ep_rails", "a2a_ep", |t, src, dst, _idx| {
        match dir {
            A2aEpDir::Dispatch => t.on_rails(home(src), home(src)),
            A2aEpDir::Combine => t.on_rails(home(src), home(dst)),
        };
    })
}

/// Deliberately **skewed** inter-node traffic (timing-only senders, no
/// receive blocks): in every sender's shifted destination walk, each
/// even-indexed message is `skew`x bigger than an odd-indexed one, so
/// message *size correlates with destination parity*. Static round-robin
/// striping maps parity straight onto planes — every big message of a
/// sender lands on plane 0 while plane 1 drains the small ones — whereas
/// the adaptive router sees the committed bytes and re-balances, cutting
/// the makespan. This is the `alltoall-adaptive-skew` scenario of the
/// perf suite and the workload `autotune::tune_rail_policy` tunes over.
pub fn a2a_skew(ctx: &ShmemCtx, bufs: &A2aBufs, pb: &mut ProgBuild, cfg: &A2aCfg, skew: f64) {
    let ws = ctx.n_pes();
    assert!(ctx.n_nodes() > 1, "a2a_skew is an inter-node scenario");
    assert!(skew >= 1.0, "skew is a size multiplier");
    let chunk_bytes = ctx.bytes(bufs.chunk);

    for r in 0..ws {
        let node = ctx.node_of(r);
        let mut send = ctx
            .task(r, format!("a2a_skew_send[{r}]"))
            .with_sms(1)
            .launch_overhead();
        let mut inter_idx = 0usize;
        for i in 1..ws {
            let dst = (r + i) % ws;
            if ctx.node_of(dst) == node {
                continue;
            }
            send.op(Op::Sleep {
                secs: cfg.inter_msg_overhead,
            });
            send.stripe_rail(inter_idx);
            let bytes = if inter_idx % 2 == 0 {
                chunk_bytes * skew
            } else {
                chunk_bytes
            };
            let tc = send.tc();
            send.op(Op::LLPut {
                src: bufs.send_chunk(dst, r),
                dst: bufs.ll_slot(r, dst),
                bytes,
                tc,
            });
            inter_idx += 1;
        }
        send.quiet();
        pb.prog.push(send.build());
    }
}

/// Seed send chunks with rank/destination-tagged data.
pub fn fill_a2a_inputs(heap: &mut SymmetricHeap, bufs: &A2aBufs, seed: u64) {
    let ws = heap.world();
    for r in 0..ws {
        let mut rng = crate::util::Rng::new(seed ^ ((r as u64) << 17));
        let data = rng.normal_vec(ws * bufs.chunk);
        heap.write(Slice::new(r, bufs.send, 0, ws * bufs.chunk), &data);
    }
}

/// Verify: recv_slot(src) on rank r equals send_chunk(r) on rank src.
pub fn verify_alltoall(heap: &SymmetricHeap, bufs: &A2aBufs) -> Result<(), String> {
    let ws = heap.world();
    for r in 0..ws {
        for src in 0..ws {
            let got = heap.read(bufs.recv_slot(src, r));
            let want = heap.read(bufs.send_chunk(r, src));
            if got != want {
                return Err(format!("alltoall mismatch: rank {r} slot {src}"));
            }
        }
    }
    Ok(())
}

/// Run `dispatch` then `combine` (reversed buffers) and check round-trip
/// identity — the invariant behind expert-parallel token routing.
pub fn roundtrip_check(
    ctx: &ShmemCtx,
    topo: &Topology,
    chunk: usize,
    cfg: &A2aCfg,
) -> Result<(f64, f64), String> {
    use crate::sim::{NoopExecutor, Sim};
    let ws = ctx.n_pes();
    let mut heap = SymmetricHeap::new(ws, 4 * ws.max(16));
    let bufs = A2aBufs::alloc(&mut heap, ctx, chunk);
    fill_a2a_inputs(&mut heap, &bufs, 99);

    let mut pb = ProgBuild::new();
    a2a_ll(ctx, &bufs, &mut pb, cfg);
    let sim = Sim::new(topo);
    let rep1 = sim
        .run(&pb.prog, &mut heap, &mut NoopExecutor)
        .map_err(|e| e.to_string())?;
    verify_alltoall(&heap, &bufs)?;

    // combine: send back what we received; a second buffer set
    heap.reset_signals();
    let back = A2aBufs {
        send: bufs.recv,
        recv: heap.alloc("a2a_back", ws * chunk),
        ll: heap.alloc("a2a_back_ll", ws * chunk),
        chunk,
        sig_base: ws,
    };
    let mut pb2 = ProgBuild::new();
    a2a_ll(ctx, &back, &mut pb2, cfg);
    let rep2 = sim
        .run(&pb2.prog, &mut heap, &mut NoopExecutor)
        .map_err(|e| e.to_string())?;
    // round trip: rank r's slot src in `back.recv` == original send chunk
    // send_chunk(src) of r? back sends recv_slot(dst-indexed)... after two
    // hops, rank r's back.recv slot s = what s received from r = r's
    // original send chunk s.
    for r in 0..ws {
        for s in 0..ws {
            let got = heap.read(Slice::new(r, back.recv, s * chunk, chunk));
            let want = heap.read(bufs.send_chunk(s, r));
            if got != want {
                return Err(format!("roundtrip mismatch rank {r} slot {s}"));
            }
        }
    }
    Ok((rep1.makespan, rep2.makespan))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, DType};
    use crate::sim::{NoopExecutor, Sim};
    use crate::topology::Topology;

    fn run_a2a(cluster: ClusterSpec, chunk: usize, build: impl Fn(&ShmemCtx, &A2aBufs, &mut ProgBuild)) -> f64 {
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes().max(16));
        let bufs = A2aBufs::alloc(&mut heap, &ctx, chunk);
        fill_a2a_inputs(&mut heap, &bufs, 5);
        let mut pb = ProgBuild::new();
        build(&ctx, &bufs, &mut pb);
        let sim = Sim::new(&topo);
        let rep = sim.run(&pb.prog, &mut heap, &mut NoopExecutor).unwrap();
        verify_alltoall(&heap, &bufs).unwrap();
        rep.makespan
    }

    #[test]
    fn ours_intra_node_correct() {
        run_a2a(ClusterSpec::h800(1, 8), 32, |c, b, p| {
            a2a_ll(c, b, p, &A2aCfg::ours())
        });
    }

    #[test]
    fn ours_inter_node_correct() {
        run_a2a(ClusterSpec::h800(2, 8), 32, |c, b, p| {
            a2a_ll(c, b, p, &A2aCfg::ours())
        });
    }

    #[test]
    fn deepep_correct() {
        run_a2a(ClusterSpec::h800(2, 8), 32, a2a_deepep);
    }

    #[test]
    fn roundtrip_identity() {
        let cluster = ClusterSpec::h800(1, 4);
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        roundtrip_check(&ctx, &topo, 16, &A2aCfg::ours()).unwrap();
    }

    #[test]
    fn ep_rails_dispatch_and_combine_correct_on_railed_fabric() {
        use crate::config::FabricSpec;
        let cluster = ClusterSpec::h800(2, 8)
            .with_fabric(FabricSpec::rail_optimized(2, 2.0).with_spine_taper(2.0));
        for dir in [A2aEpDir::Dispatch, A2aEpDir::Combine] {
            run_a2a(cluster, 32, |c, b, p| {
                a2a_ep_rails(c, b, p, &A2aCfg::ours(), dir)
            });
        }
    }

    #[test]
    fn ep_combine_emits_asymmetric_rails() {
        use crate::config::{FabricSpec, TrafficClass};
        let cluster = ClusterSpec::h800(2, 8).with_fabric(FabricSpec::rail_optimized(2, 2.0));
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes());
        let bufs = A2aBufs::alloc(&mut heap, &ctx, 16);
        let collect_tcs = |dir: A2aEpDir| {
            let mut pb = ProgBuild::new();
            a2a_ep_rails(&ctx, &bufs, &mut pb, &A2aCfg::ours(), dir);
            pb.prog
                .tasks
                .iter()
                .flat_map(|t| &t.ops)
                .filter_map(|o| match o {
                    crate::program::Op::LLPut { tc, .. } => Some(*tc),
                    _ => None,
                })
                .collect::<Vec<_>>()
        };
        // dispatch: every explicit assignment stays in one plane
        assert!(collect_tcs(A2aEpDir::Dispatch)
            .iter()
            .all(|tc| !matches!(tc, TrafficClass::Rails { tx, rx } if tx != rx)));
        // combine: differing home planes produce spine-crossing classes
        let crossing = collect_tcs(A2aEpDir::Combine)
            .iter()
            .filter(|tc| matches!(tc, TrafficClass::Rails { tx, rx } if tx != rx))
            .count();
        assert!(crossing > 0, "combine must emit Rails{{tx != rx}}");
    }

    #[test]
    fn ours_beats_deepep_at_small_scale() {
        // Fig. 16 shape: at 16 ranks (2 nodes) the NVLink intra path wins.
        let ours = run_a2a(ClusterSpec::h800(2, 8), 1024, |c, b, p| {
            a2a_ll(c, b, p, &A2aCfg::ours())
        });
        let deepep = run_a2a(ClusterSpec::h800(2, 8), 1024, a2a_deepep);
        assert!(ours < deepep, "ours {ours} vs deepep {deepep}");
    }
}
