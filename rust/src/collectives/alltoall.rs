//! Low-latency expert-parallel AllToAll (§4.2 "Low-latency AllToAll",
//! Fig. 16): token dispatch/combine for inference MoE.
//!
//! Our kernel (the paper's): LL protocol everywhere, NVLink for intra-node
//! peers, IBRC for inter-node peers (each inter message pays a proxy-thread
//! post overhead, serialized per rank — IBRC's scaling tax).
//!
//! The DeepEP-like baseline: IBGDA (GPU-initiated, much cheaper per
//! message, no proxy serialization) but IB for *all* peers including
//! intra-node ones, plus a memory-queue management cost per message. This
//! encodes exactly the structural trade the paper describes: we win up to
//! ~32 ranks on NVLink + simplicity, IBGDA wins at 64+.
//!
//! **Variable-size (token-routed) family:** expert-parallel traffic is
//! data-dependent — message sizes follow the topk routing table, not a
//! uniform capacity. [`EpRouting`] summarizes a routing table into
//! per-(src, dst) kept-token counts, [`A2aSizes`]/[`A2aVarBufs`] turn
//! those into a packed wire layout, and [`a2a_ll_var`] /
//! [`a2a_ep_rails_var`] build the same LL program over it (one shared
//! body, [`A2aLayout`]). A uniform size table reproduces the classic
//! fixed-chunk programs bit-identically.

use crate::kernels::exec::EpPlan;
use crate::kernels::names::EpGeom;
use crate::mem::{BufId, Slice, SymmetricHeap};
use crate::program::{ComputeCost, NumericOp, Op, SigCond, SigOp};
use crate::shmem::{ShmemCtx, ShmemTask};
use crate::topology::Topology;
use crate::util::Rng;

use super::{ProgBuild, WorldView};

/// AllToAll working set: `send` holds one chunk per destination rank;
/// `recv` holds one slot per source rank.
#[derive(Debug, Clone, Copy)]
pub struct A2aBufs {
    pub send: BufId,
    pub recv: BufId,
    /// LL staging on the receive side.
    pub ll: BufId,
    /// Elements per (src, dst) chunk.
    pub chunk: usize,
    pub sig_base: usize,
}

impl A2aBufs {
    pub fn alloc(heap: &mut SymmetricHeap, ctx: &ShmemCtx, chunk: usize) -> Self {
        let ws = ctx.n_pes();
        A2aBufs {
            send: heap.alloc("a2a_send", ws * chunk),
            recv: heap.alloc("a2a_recv", ws * chunk),
            ll: heap.alloc("a2a_ll", ws * chunk),
            chunk,
            sig_base: 0,
        }
    }

    pub fn send_chunk(&self, dst: usize, on: usize) -> Slice {
        Slice::new(on, self.send, dst * self.chunk, self.chunk)
    }

    pub fn recv_slot(&self, src: usize, on: usize) -> Slice {
        Slice::new(on, self.recv, src * self.chunk, self.chunk)
    }

    pub fn ll_slot(&self, src: usize, on: usize) -> Slice {
        Slice::new(on, self.ll, src * self.chunk, self.chunk)
    }

    /// Arrival signal for the chunk from `src`.
    pub fn sig(&self, src: usize) -> usize {
        self.sig_base + src
    }
}

// ---------------------------------------------------------------------------
// variable-size (token-routed) AllToAll
// ---------------------------------------------------------------------------

/// Per-(src, dst) message-size table for the variable-size AllToAll
/// family: the wire is sized by *actual* routed elements instead of one
/// uniform `chunk`. [`A2aSizes::uniform`] reproduces the uniform layout
/// exactly (same offsets, same lengths), which is what keeps the uniform
/// path bit-identical to [`a2a_ll`].
#[derive(Debug, Clone)]
pub struct A2aSizes {
    ws: usize,
    /// Elements per (src, dst) message, indexed `src * ws + dst`.
    elems: Vec<usize>,
}

impl A2aSizes {
    /// Explicit per-(src, dst) element counts (`elems[src * ws + dst]`).
    pub fn new(ws: usize, elems: Vec<usize>) -> Self {
        assert_eq!(elems.len(), ws * ws, "size table must be ws x ws");
        A2aSizes { ws, elems }
    }

    /// Every message `chunk` elements — the classic fixed-capacity wire.
    pub fn uniform(ws: usize, chunk: usize) -> Self {
        A2aSizes {
            ws,
            elems: vec![chunk; ws * ws],
        }
    }

    /// World size this table describes.
    pub fn world(&self) -> usize {
        self.ws
    }

    /// Elements routed from `src` to `dst`.
    pub fn elems(&self, src: usize, dst: usize) -> usize {
        self.elems[src * self.ws + dst]
    }

    /// Offset of the (src -> dst) chunk in `src`'s send buffer
    /// (dst-ascending packing; `dst * chunk` on a uniform table).
    pub fn send_off(&self, src: usize, dst: usize) -> usize {
        (0..dst).map(|d| self.elems(src, d)).sum()
    }

    /// Offset of the slot for `src`'s data in `dst`'s receive buffer
    /// (src-ascending packing; `src * chunk` on a uniform table).
    pub fn recv_off(&self, src: usize, dst: usize) -> usize {
        (0..src).map(|s| self.elems(s, dst)).sum()
    }

    /// Total elements `src` sends (its packed send-buffer length).
    pub fn send_total(&self, src: usize) -> usize {
        self.send_off(src, self.ws)
    }

    /// Total elements `dst` receives (its packed recv-buffer length).
    pub fn recv_total(&self, dst: usize) -> usize {
        self.recv_off(self.ws, dst)
    }

    fn max_send_total(&self) -> usize {
        (0..self.ws).map(|s| self.send_total(s)).max().unwrap_or(0)
    }

    fn max_recv_total(&self) -> usize {
        (0..self.ws).map(|d| self.recv_total(d)).max().unwrap_or(0)
    }
}

/// Working set of the variable-size AllToAll: packed send/recv/LL
/// buffers whose per-(src, dst) chunk offsets come from an [`A2aSizes`]
/// table. The symmetric heap requires identical buffer lengths on every
/// rank, so buffers are sized for the largest rank's packed total.
#[derive(Debug, Clone)]
pub struct A2aVarBufs {
    pub send: BufId,
    pub recv: BufId,
    /// LL staging on the receive side (recv-packed layout).
    pub ll: BufId,
    pub sizes: A2aSizes,
    pub sig_base: usize,
}

impl A2aVarBufs {
    pub fn alloc(heap: &mut SymmetricHeap, sizes: A2aSizes) -> Self {
        // `<=`, not `==`: a survivor re-plan builds a logical size table
        // smaller than the physical heap world (dead ranks keep their
        // heap space but are never addressed)
        assert!(sizes.world() <= heap.world(), "size table world mismatch");
        let send_len = sizes.max_send_total().max(1);
        let recv_len = sizes.max_recv_total().max(1);
        A2aVarBufs {
            send: heap.alloc("a2a_var_send", send_len),
            recv: heap.alloc("a2a_var_recv", recv_len),
            ll: heap.alloc("a2a_var_ll", recv_len),
            sizes,
            sig_base: 0,
        }
    }

    pub fn send_chunk(&self, dst: usize, on: usize) -> Slice {
        Slice::new(on, self.send, self.sizes.send_off(on, dst), self.sizes.elems(on, dst))
    }

    pub fn recv_slot(&self, src: usize, on: usize) -> Slice {
        Slice::new(on, self.recv, self.sizes.recv_off(src, on), self.sizes.elems(src, on))
    }

    pub fn ll_slot(&self, src: usize, on: usize) -> Slice {
        Slice::new(on, self.ll, self.sizes.recv_off(src, on), self.sizes.elems(src, on))
    }

    /// Arrival signal for the chunk from `src`.
    pub fn sig(&self, src: usize) -> usize {
        self.sig_base + src
    }
}

/// Buffer-layout view the shared LL program body builds against: the
/// uniform [`A2aBufs`] and the routed [`A2aVarBufs`] expose identical
/// chunk/slot/signal accessors, so one builder serves both (and the
/// uniform case stays bit-identical by construction).
pub trait A2aLayout {
    /// Elements routed from `src` to `dst` (0 = no message on the wire).
    fn elems(&self, src: usize, dst: usize) -> usize;
    /// The (on -> dst) chunk in `on`'s send buffer.
    fn send_chunk(&self, dst: usize, on: usize) -> Slice;
    /// The slot for `src`'s data in `on`'s receive buffer.
    fn recv_slot(&self, src: usize, on: usize) -> Slice;
    /// The LL staging slot paired with [`Self::recv_slot`].
    fn ll_slot(&self, src: usize, on: usize) -> Slice;
    /// Arrival signal index for the chunk from `src`.
    fn sig(&self, src: usize) -> usize;
}

impl A2aLayout for A2aBufs {
    fn elems(&self, _src: usize, _dst: usize) -> usize {
        self.chunk
    }
    fn send_chunk(&self, dst: usize, on: usize) -> Slice {
        A2aBufs::send_chunk(self, dst, on)
    }
    fn recv_slot(&self, src: usize, on: usize) -> Slice {
        A2aBufs::recv_slot(self, src, on)
    }
    fn ll_slot(&self, src: usize, on: usize) -> Slice {
        A2aBufs::ll_slot(self, src, on)
    }
    fn sig(&self, src: usize) -> usize {
        A2aBufs::sig(self, src)
    }
}

impl A2aLayout for A2aVarBufs {
    fn elems(&self, src: usize, dst: usize) -> usize {
        self.sizes.elems(src, dst)
    }
    fn send_chunk(&self, dst: usize, on: usize) -> Slice {
        A2aVarBufs::send_chunk(self, dst, on)
    }
    fn recv_slot(&self, src: usize, on: usize) -> Slice {
        A2aVarBufs::recv_slot(self, src, on)
    }
    fn ll_slot(&self, src: usize, on: usize) -> Slice {
        A2aVarBufs::ll_slot(self, src, on)
    }
    fn sig(&self, src: usize) -> usize {
        A2aVarBufs::sig(self, src)
    }
}

/// Expert-parallel routing summary: the topk table + gate weights that
/// drive the variable-size wire. `tokens(src, expert)` counts the kept
/// routed pairs, [`Self::dispatch_sizes`] / [`Self::combine_sizes`] turn
/// the per-(src, dst) kept counts into [`A2aSizes`] tables, and the
/// underlying [`EpPlan`] is the same one the `ep_*` kernels rebuild from
/// the replicated table — sender, receiver, and verifier agree on every
/// chunk size by construction.
#[derive(Debug, Clone)]
pub struct EpRouting {
    /// Pipeline geometry (world, experts, topk, capacity, dims).
    pub geom: EpGeom,
    /// topk expert index per (rank-owned token, k) slot, `[w * t * k]`.
    pub idx: Vec<usize>,
    /// Gate weight per slot, same indexing.
    pub gate: Vec<f32>,
    plan: EpPlan,
}

impl EpRouting {
    /// Sample a routing table with Zipf-like expert popularity
    /// (`weight_e ∝ 1 / (e + 1)^skew`; `skew = 0` is uniform), then run
    /// the capacity claim (`geom.c` slots per expert, global scan order).
    pub fn generate(geom: EpGeom, skew: f64, seed: u64) -> Self {
        assert!(geom.w > 0 && geom.t > 0 && geom.k > 0 && geom.e > 0, "empty EP geometry");
        assert!(skew >= 0.0, "skew exponent must be >= 0");
        let mut rng = Rng::new(seed ^ 0xE9C0_77A1);
        let weights: Vec<f64> = (0..geom.e).map(|i| 1.0 / ((i + 1) as f64).powf(skew)).collect();
        let total: f64 = weights.iter().sum();
        let mut cum = Vec::with_capacity(geom.e);
        let mut acc = 0.0;
        for w in &weights {
            acc += w / total;
            cum.push(acc);
        }
        let slots = geom.w * geom.t * geom.k;
        let mut idx = Vec::with_capacity(slots);
        let mut gate = Vec::with_capacity(slots);
        for _ in 0..slots {
            let u = rng.f64();
            idx.push(cum.partition_point(|&c| c < u).min(geom.e - 1));
            gate.push(rng.f32().max(0.05));
        }
        let plan = EpPlan::build(&idx, geom);
        EpRouting { geom, idx, gate, plan }
    }

    /// Build from an explicit topk table (`idx[(src*t + ti)*k + ki]`).
    pub fn from_table(geom: EpGeom, idx: Vec<usize>, gate: Vec<f32>) -> Self {
        assert_eq!(gate.len(), idx.len(), "gate/idx size mismatch");
        let plan = EpPlan::build(&idx, geom);
        EpRouting { geom, idx, gate, plan }
    }

    /// The capacity-claimed routing plan (shared with the `ep_*` kernels).
    pub fn plan(&self) -> &EpPlan {
        &self.plan
    }

    /// Kept routed (token, k) pairs from `src` to `expert`.
    pub fn tokens(&self, src: usize, expert: usize) -> usize {
        let (t, k) = (self.geom.t, self.geom.k);
        (0..t * k)
            .filter(|p| {
                let gi = src * t * k + p;
                self.idx[gi] == expert && self.plan.dst_of(gi).is_some()
            })
            .count()
    }

    /// Dispatch wire sizes: kept pairs x token hidden per (src, dst).
    pub fn dispatch_sizes(&self) -> A2aSizes {
        let (w, h) = (self.geom.w, self.geom.h);
        A2aSizes::new(w, (0..w * w).map(|i| self.plan.count(i / w, i % w) * h).collect())
    }

    /// Combine wire sizes: the dispatch transpose x FFN output dim —
    /// expert rank `d` returns `count(owner, d)` rows of `f` to `owner`.
    pub fn combine_sizes(&self) -> A2aSizes {
        let (w, f) = (self.geom.w, self.geom.f);
        A2aSizes::new(w, (0..w * w).map(|i| self.plan.count(i % w, i / w) * f).collect())
    }

    /// Total kept pairs across the world.
    pub fn kept(&self) -> usize {
        self.plan.kept()
    }

    /// Pairs dropped by the capacity claim (`--capacity-factor`
    /// accounting).
    pub fn dropped(&self) -> usize {
        self.plan.dropped()
    }
}

/// Ceil-balanced sub-ranges of `[0, elems)` for the split-factor wire
/// (at most one piece per element; every piece non-empty).
fn split_ranges(elems: usize, split: usize) -> Vec<(usize, usize)> {
    let pieces = split.clamp(1, elems.max(1));
    let base = elems / pieces;
    let rem = elems % pieces;
    let mut out = Vec::with_capacity(pieces);
    let mut off = 0;
    for i in 0..pieces {
        let len = base + usize::from(i < rem);
        out.push((off, len));
        off += len;
    }
    out
}

/// Transport/runtime knobs distinguishing our kernel from DeepEP.
#[derive(Debug, Clone, Copy)]
pub struct A2aCfg {
    /// Per-inter-node-message CPU/GPU post overhead, serialized in the
    /// sending task (IBRC proxy ≈ 1 µs; IBGDA ≈ 0.2 µs).
    pub inter_msg_overhead: f64,
    /// Route intra-node traffic over the NIC instead of NVLink
    /// (DeepEP's IB-only data path).
    pub intra_via_nic: bool,
    /// Per-message memory-queue management cost (DeepEP's queue logic;
    /// we "allocate a much larger buffer and omit the control logic").
    pub queue_overhead: f64,
    /// Sub-messages each (src, dst) chunk is split into by the LL builder
    /// family (`a2a_ll`, `a2a_ep_rails`, and their `_var` variable-size
    /// forms). Every piece pays the per-message post overhead and gets
    /// its own plane assignment, so splitting can engage several rails
    /// per logical message at the cost of extra posts — the dispatch
    /// chunking the §3.8 tuner explores
    /// (`autotune::tune_dispatch_chunking`). `1` (the default) is the
    /// unsplit wire, bit-identical to the pre-split builders.
    pub split: usize,
    /// Consumer-deadline class stamped on every inter-node piece (the
    /// [`crate::program::ChunkMeta::deadline`] the chunk scheduler
    /// orders by under `ChunkSched::Deadline`). `u32::MAX` (the
    /// default) marks bulk traffic with no downstream consumer; `0`
    /// marks gating traffic — the combine leg whose arrival releases
    /// an FFN/GEMM consumer. Inert under `ChunkSched::Fifo`.
    pub deadline: u32,
}

impl A2aCfg {
    /// Our Triton-distributed kernel: NVLink intra, IBRC inter, no queue.
    /// The IBRC proxy-thread post cost (~1.2 us, serialized per rank) is
    /// the scaling tax that lets IBGDA win at 64 GPUs (§4.2).
    pub fn ours() -> Self {
        A2aCfg {
            inter_msg_overhead: 1.45e-6,
            intra_via_nic: false,
            queue_overhead: 0.0,
            split: 1,
            deadline: u32::MAX,
        }
    }

    /// DeepEP-like: IBGDA posts, IB-only path, memory-queue bookkeeping.
    pub fn deepep() -> Self {
        A2aCfg {
            inter_msg_overhead: 0.15e-6,
            intra_via_nic: true,
            queue_overhead: 0.2e-6,
            split: 1,
            deadline: u32::MAX,
        }
    }

    /// DeepEP-like knobs for the **combine** direction: ~3x the queue
    /// cost, because topk partials per token flow through the memory
    /// queue (the ad-hoc config the CLI and fig16 bench previously built
    /// inline).
    pub fn deepep_combine() -> Self {
        let base = Self::deepep();
        A2aCfg {
            queue_overhead: base.queue_overhead * 3.0,
            ..base
        }
    }

    /// Set the sub-message split factor (see [`A2aCfg::split`]).
    pub fn with_split(mut self, split: usize) -> Self {
        assert!(split >= 1, "split factor must be >= 1");
        self.split = split;
        self
    }

    /// Set the consumer-deadline class (see [`A2aCfg::deadline`]):
    /// `0` = gating (combine legs feeding FFN/GEMM), `u32::MAX` = bulk.
    pub fn with_deadline(mut self, deadline: u32) -> Self {
        self.deadline = deadline;
        self
    }
}

/// Shared LL AllToAll program body, generic over the buffer layout
/// ([`A2aLayout`]: uniform [`A2aBufs`] or token-routed [`A2aVarBufs`]):
/// per rank, a self-copy, a shifted send walk whose inter-node messages
/// get a per-message plane assignment via `plane(task, src, dst,
/// inter_idx)`, a quiet fence, and `ws - 1` receive/unpack blocks.
/// [`a2a_ll`] stripes through the fabric's rail policy; [`a2a_ep_rails`]
/// pins explicit (possibly asymmetric) planes.
///
/// Variable-size extensions (all inert on the uniform path):
/// * `gate` — the send walk first waits for local signal `gate` to reach
///   1 (the producer's "chunks are packed" handoff).
/// * zero-element messages put nothing on the wire; their receive block
///   still fires the arrival signal so consumers wait uniformly.
/// * `cfg.split > 1` splits every chunk into that many LL pieces, each
///   paying the post overhead and taking its own plane assignment.
///
/// **Survivor indexing** (elastic recovery): all loop indices `r`, `dst`,
/// `src` are *logical* ranks of `view`; tasks, slices, and signal targets
/// are re-homed onto `view.phys(..)`. The `plane` callback receives
/// logical indices — callers needing physical rail homes map through the
/// view themselves. [`WorldView::identity`] makes every re-homing a
/// field-preserving no-op, so the classic builders stay bit-identical.
#[allow(clippy::too_many_arguments)]
fn a2a_ll_body<L: A2aLayout>(
    ctx: &ShmemCtx,
    bufs: &L,
    pb: &mut ProgBuild,
    cfg: &A2aCfg,
    who: &'static str,
    prefix: &str,
    gate: Option<usize>,
    view: &WorldView,
    mut plane: impl FnMut(&mut ShmemTask, usize, usize, usize),
) {
    let ws = view.world();
    assert!(
        (0..ws).all(|l| view.phys(l) < ctx.n_pes()),
        "world view addresses ranks outside the cluster"
    );
    pb.claim_sigs(who, bufs.sig(0), ws);

    for r in 0..ws {
        let pr = view.phys(r);
        let node = ctx.node_of(pr);
        let mut send = ctx
            .task(pr, format!("{prefix}_send[{r}]"))
            .with_sms(1)
            .launch_overhead();
        if let Some(g) = gate {
            send.signal_wait_until(g, SigCond::Ge, 1);
        }
        // self chunk: local copy, immediately available
        let self_elems = bufs.elems(r, r);
        if self_elems > 0 {
            send.op(Op::Compute {
                cost: ComputeCost::MemBound {
                    bytes: ctx.bytes(self_elems) * 2.0,
                },
                numeric: NumericOp::Copy {
                    src: bufs.send_chunk(r, r).on_rank(pr),
                    dst: bufs.recv_slot(r, r).on_rank(pr),
                },
                label: "a2a_self_copy",
            });
        }
        send.notify(pr, bufs.sig(r), SigOp::Set, 1);
        // remaining inter-node payload of this sender's walk — the
        // shrinking "remaining work" the Srpf chunk scheduler orders by
        let mut inter_remaining = 0.0;
        for i in 1..ws {
            let dst = (r + i) % ws;
            if ctx.node_of(view.phys(dst)) != node {
                inter_remaining += ctx.bytes(bufs.elems(r, dst));
            }
        }
        let mut inter_idx = 0usize;
        for i in 1..ws {
            let dst = (r + i) % ws;
            let pd = view.phys(dst);
            let elems = bufs.elems(r, dst);
            if elems == 0 {
                continue;
            }
            let inter = ctx.node_of(pd) != node;
            for (off, len) in split_ranges(elems, cfg.split) {
                if inter {
                    // IBRC/IBGDA post cost, serialized in the sender,
                    // then the piece's fabric plane assignment
                    send.op(Op::Sleep {
                        secs: cfg.inter_msg_overhead,
                    });
                    plane(&mut send, r, dst, inter_idx);
                    send.chunk_meta(inter_remaining, cfg.deadline);
                    inter_idx += 1;
                } else {
                    send.clear_chunk();
                }
                if cfg.queue_overhead > 0.0 {
                    send.op(Op::Sleep {
                        secs: cfg.queue_overhead,
                    });
                }
                send.ll_put(
                    bufs.send_chunk(dst, r).sub(off, len).on_rank(pr),
                    bufs.ll_slot(r, dst).sub(off, len).on_rank(pd),
                );
                if inter {
                    inter_remaining -= ctx.bytes(len);
                }
            }
        }
        send.quiet();
        pb.prog.push(send.build());

        // receive blocks: unpack LL slots into the recv buffer
        for src in 0..ws {
            if src == r {
                continue;
            }
            let elems = bufs.elems(src, r);
            if elems == 0 {
                // nothing on the wire — the arrival signal still fires
                let mut t = ctx
                    .task(pr, format!("{prefix}_recv[{r}<-{src}]"))
                    .with_sms(1);
                t.notify(pr, bufs.sig(src), SigOp::Set, 1);
                pb.prog.push(t.build());
                continue;
            }
            let mut t = ctx
                .task(pr, format!("{prefix}_recv[{r}<-{src}]"))
                .with_sms(1)
                .launch_overhead();
            for (off, len) in split_ranges(elems, cfg.split) {
                t.recv_ll(bufs.ll_slot(src, r).sub(off, len).on_rank(pr));
            }
            t.op(Op::Compute {
                cost: ComputeCost::MemBound {
                    bytes: ctx.bytes(elems) * 2.0,
                },
                numeric: NumericOp::Copy {
                    src: bufs.ll_slot(src, r).on_rank(pr),
                    dst: bufs.recv_slot(src, r).on_rank(pr),
                },
                label: "a2a_unpack",
            });
            if cfg.queue_overhead > 0.0 {
                t.op(Op::Sleep {
                    secs: cfg.queue_overhead,
                });
            }
            t.notify(pr, bufs.sig(src), SigOp::Set, 1);
            pb.prog.push(t.build());
        }
    }
}

/// Build one direction of the low-latency AllToAll (dispatch; combine is
/// the same program with swapped buffers). Every rank LL-sends its chunk
/// to every peer (shifted walk) and hosts `ws-1` receive blocks.
/// Inter-node messages stripe across NIC rails (round-robin, or by live
/// congestion under `RailPolicy::Adaptive`).
pub fn a2a_ll(ctx: &ShmemCtx, bufs: &A2aBufs, pb: &mut ProgBuild, cfg: &A2aCfg) {
    let view = WorldView::identity(ctx.n_pes());
    a2a_ll_body(ctx, bufs, pb, cfg, "a2a_ll", "a2a", None, &view, |t, _src, _dst, idx| {
        t.stripe_rail(idx);
    })
}

/// [`a2a_ll`] over a **variable-size** layout: per-(src, dst) message
/// sizes come from the [`A2aSizes`] table inside `bufs` (typically an
/// [`EpRouting`] summary). With a uniform table this emits a program
/// bit-identical to [`a2a_ll`] on an equally-sized [`A2aBufs`]. `gate`
/// optionally defers each rank's send walk until its local signal `gate`
/// reaches 1 (producer handoff).
pub fn a2a_ll_var(
    ctx: &ShmemCtx,
    bufs: &A2aVarBufs,
    pb: &mut ProgBuild,
    cfg: &A2aCfg,
    gate: Option<usize>,
) {
    a2a_ll_var_on(ctx, bufs, pb, cfg, gate, &WorldView::identity(ctx.n_pes()))
}

/// [`a2a_ll_var`] over an explicit [`WorldView`] — the survivor-indexed
/// form the elastic recovery controller re-plans with after a permanent
/// rank/node death. The size table is logical (`view.world()` wide);
/// tasks and buffers land on `view.phys(..)`. The identity view is
/// bit-identical to [`a2a_ll_var`].
pub fn a2a_ll_var_on(
    ctx: &ShmemCtx,
    bufs: &A2aVarBufs,
    pb: &mut ProgBuild,
    cfg: &A2aCfg,
    gate: Option<usize>,
    view: &WorldView,
) {
    a2a_ll_body(ctx, bufs, pb, cfg, "a2a_ll", "a2a", gate, view, |t, _src, _dst, idx| {
        t.stripe_rail(idx);
    })
}

/// Force-intra-via-NIC variant used by the DeepEP baseline: identical
/// program, but intra-node chunks are routed over the NIC by sending to a
/// same-node peer *through the IB loopback*. The DES has no notion of
/// "forced transport", so we model it with an explicit relay topology
/// trick: the timing size is unchanged but the flow is charged to the NIC
/// links by targeting the inter-node route of a sibling rank pair when one
/// exists; on a single node we add the equivalent serialization delay.
pub fn a2a_deepep(ctx: &ShmemCtx, bufs: &A2aBufs, pb: &mut ProgBuild) {
    a2a_deepep_cfg(ctx, bufs, pb, &A2aCfg::deepep())
}

/// [`a2a_deepep`] with explicit knobs (the combine direction pays ~3x the
/// queue cost: topk partials per token flow through the memory queue).
pub fn a2a_deepep_cfg(ctx: &ShmemCtx, bufs: &A2aBufs, pb: &mut ProgBuild, cfg: &A2aCfg) {
    let cfg = *cfg;
    let ws = ctx.n_pes();
    pb.claim_sigs("a2a_deepep", bufs.sig_base, ws);
    let chunk_bytes = ctx.bytes(bufs.chunk);
    let hw = ctx.cluster.hw;

    for r in 0..ws {
        let node = ctx.node_of(r);
        let mut send = ctx
            .task(r, format!("deepep_send[{r}]"))
            .with_sms(1)
            .launch_overhead();
        send.op(Op::Compute {
            cost: ComputeCost::MemBound {
                bytes: chunk_bytes * 2.0,
            },
            numeric: NumericOp::Copy {
                src: bufs.send_chunk(r, r),
                dst: bufs.recv_slot(r, r),
            },
            label: "a2a_self_copy",
        });
        send.notify(r, bufs.sig(r), SigOp::Set, 1);
        let mut inter_idx = 0usize;
        for i in 1..ws {
            let dst = (r + i) % ws;
            let inter = ctx.node_of(dst) != node;
            send.op(Op::Sleep {
                secs: cfg.inter_msg_overhead + cfg.queue_overhead,
            });
            if inter {
                // IBGDA posts stripe across rails like ours does
                send.stripe_rail(inter_idx);
                inter_idx += 1;
                send.ll_put(bufs.send_chunk(dst, r), bufs.ll_slot(r, dst));
            } else {
                // intra chunk forced through the IB loopback: charge the
                // NIC bandwidth + latency difference as *extra wire bytes*
                // on the flow (concurrent with other messages, unlike a
                // serialized sleep — DMA engines pipeline these)
                let penalty_bytes =
                    chunk_bytes * (hw.intra_bw / hw.nic_bw - 1.0).max(0.0)
                        + (hw.inter_lat - hw.intra_lat) * hw.intra_bw;
                send.op(Op::LLPut {
                    src: bufs.send_chunk(dst, r),
                    dst: bufs.ll_slot(r, dst),
                    bytes: chunk_bytes + penalty_bytes,
                    tc: Default::default(),
                    chunk: None,
                });
            }
        }
        send.quiet();
        pb.prog.push(send.build());

        for src in 0..ws {
            if src == r {
                continue;
            }
            let mut t = ctx
                .task(r, format!("deepep_recv[{r}<-{src}]"))
                .with_sms(1)
                .launch_overhead();
            t.recv_ll(bufs.ll_slot(src, r));
            t.op(Op::Compute {
                cost: ComputeCost::MemBound {
                    bytes: chunk_bytes * 2.0,
                },
                numeric: NumericOp::Copy {
                    src: bufs.ll_slot(src, r),
                    dst: bufs.recv_slot(src, r),
                },
                label: "a2a_unpack",
            });
            t.op(Op::Sleep {
                secs: cfg.queue_overhead,
            });
            t.notify(r, bufs.sig(src), SigOp::Set, 1);
            pb.prog.push(t.build());
        }
    }
}

/// Direction of the expert-parallel AllToAll (token routing to experts
/// vs gathering partials back).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum A2aEpDir {
    /// Tokens to experts: every message pinned to the **sender's** home
    /// plane end-to-end (rail-optimized, no spine crossing).
    Dispatch,
    /// Expert outputs back to token owners: sender's home plane out,
    /// **receiver's** home plane in — a `TrafficClass::Rails { tx, rx }`
    /// spine-crossing path whenever the two home planes differ.
    Combine,
}

/// Expert-parallel AllToAll with **asymmetric tx/rx plane assignment** —
/// the first collective to emit `TrafficClass::Rails { tx, rx }`
/// end-to-end (ROADMAP open item).
///
/// A GPU's *home plane* is `local_rank % rails` (the NIC plane its
/// rail-optimized leaf port belongs to). Dispatch pins each message to
/// the sender's home plane on both ends: messages from different senders
/// leave on disjoint planes and never cross the spine. Combine routes
/// each message out of the sender's home plane *into the receiver's home
/// plane*, so any pair whose local ranks land on different planes takes
/// the spine-crossing path — under a tapered spine
/// (`FabricSpec::with_spine_taper`) those transfers contend on **both**
/// planes' cores, which is exactly the asymmetry this variant exists to
/// model.
///
/// Program structure (LL protocol, send/recv blocks, overheads) matches
/// [`a2a_ll`] exactly — they share one program builder; only the
/// per-message plane assignment differs.
pub fn a2a_ep_rails(
    ctx: &ShmemCtx,
    bufs: &A2aBufs,
    pb: &mut ProgBuild,
    cfg: &A2aCfg,
    dir: A2aEpDir,
) {
    let rails = ctx.cluster.fabric.rails;
    let home = |pe: usize| ctx.local_rank_of(pe) % rails;
    let view = WorldView::identity(ctx.n_pes());
    a2a_ll_body(ctx, bufs, pb, cfg, "a2a_ep_rails", "a2a_ep", None, &view, |t, src, dst, _idx| {
        match dir {
            A2aEpDir::Dispatch => t.on_rails(home(src), home(src)),
            A2aEpDir::Combine => t.on_rails(home(src), home(dst)),
        };
    })
}

/// [`a2a_ep_rails`] over a **variable-size** layout — the token-routed
/// EP dispatch/combine the MoE coordinator drives: message sizes follow
/// the routing summary ([`EpRouting::dispatch_sizes`] /
/// [`EpRouting::combine_sizes`]), plane assignment follows `dir`
/// (dispatch sender-plane-pinned; combine `Rails { tx, rx }` crossing
/// into the receiver's home plane), and `gate` defers each rank's send
/// walk until its producer (the dispatch pack or the grouped FFN) has
/// filled the packed send buffer.
pub fn a2a_ep_rails_var(
    ctx: &ShmemCtx,
    bufs: &A2aVarBufs,
    pb: &mut ProgBuild,
    cfg: &A2aCfg,
    dir: A2aEpDir,
    gate: Option<usize>,
) {
    a2a_ep_rails_var_on(ctx, bufs, pb, cfg, dir, gate, &WorldView::identity(ctx.n_pes()))
}

/// [`a2a_ep_rails_var`] over an explicit [`WorldView`] — the
/// survivor-indexed EP dispatch/combine wire of the elastic recovery
/// controller. Home planes are computed from **physical** local ranks
/// (`view.phys`), so a survivor keeps its NIC plane even after logical
/// renumbering; the identity view is bit-identical to
/// [`a2a_ep_rails_var`].
pub fn a2a_ep_rails_var_on(
    ctx: &ShmemCtx,
    bufs: &A2aVarBufs,
    pb: &mut ProgBuild,
    cfg: &A2aCfg,
    dir: A2aEpDir,
    gate: Option<usize>,
    view: &WorldView,
) {
    let rails = ctx.cluster.fabric.rails;
    let home = |l: usize| ctx.local_rank_of(view.phys(l)) % rails;
    a2a_ll_body(ctx, bufs, pb, cfg, "a2a_ep_rails", "a2a_ep", gate, view, |t, src, dst, _idx| {
        match dir {
            A2aEpDir::Dispatch => t.on_rails(home(src), home(src)),
            A2aEpDir::Combine => t.on_rails(home(src), home(dst)),
        };
    })
}

/// Deliberately **skewed** inter-node traffic (timing-only senders, no
/// receive blocks): in every sender's shifted destination walk, each
/// even-indexed message is `skew`x bigger than an odd-indexed one, so
/// message *size correlates with destination parity*. Static round-robin
/// striping maps parity straight onto planes — every big message of a
/// sender lands on plane 0 while plane 1 drains the small ones — whereas
/// the adaptive router sees the committed bytes and re-balances, cutting
/// the makespan. This is the `alltoall-adaptive-skew` scenario of the
/// perf suite and the workload `autotune::tune_rail_policy` tunes over.
pub fn a2a_skew(ctx: &ShmemCtx, bufs: &A2aBufs, pb: &mut ProgBuild, cfg: &A2aCfg, skew: f64) {
    let ws = ctx.n_pes();
    assert!(ctx.n_nodes() > 1, "a2a_skew is an inter-node scenario");
    assert!(skew >= 1.0, "skew is a size multiplier");
    let chunk_bytes = ctx.bytes(bufs.chunk);

    for r in 0..ws {
        let node = ctx.node_of(r);
        let mut send = ctx
            .task(r, format!("a2a_skew_send[{r}]"))
            .with_sms(1)
            .launch_overhead();
        let mut inter_idx = 0usize;
        for i in 1..ws {
            let dst = (r + i) % ws;
            if ctx.node_of(dst) == node {
                continue;
            }
            send.op(Op::Sleep {
                secs: cfg.inter_msg_overhead,
            });
            send.stripe_rail(inter_idx);
            let bytes = if inter_idx % 2 == 0 {
                chunk_bytes * skew
            } else {
                chunk_bytes
            };
            let tc = send.tc();
            send.op(Op::LLPut {
                src: bufs.send_chunk(dst, r),
                dst: bufs.ll_slot(r, dst),
                bytes,
                tc,
                chunk: send.chunk(),
            });
            inter_idx += 1;
        }
        send.quiet();
        pb.prog.push(send.build());
    }
}

/// Pinned **mixed-traffic** contention scenario for the chunk scheduler
/// (the `alltoall-sched-mixed` perf scenario, the workload
/// `autotune::tune_chunk_sched` tunes over, and the strict-win pin of
/// `tests/sched_equivalence.rs`). Rank 0 runs two concurrent senders:
///
/// * an AllGather-style **gating stream** — `gate_pieces` small nbi
///   segments (signal on delivery, deadline `0`) to one node-1 GPU,
///   whose last arrival releases a GEMM consumer of `gemm_secs` there;
/// * an EP-dispatch-style **bulk backlog** — `bulk_pieces` nbi pieces
///   to the *other* node-1 GPU, tagged `ChunkMeta` bulk (deadline
///   `u32::MAX`, descending remaining work).
///
/// Both streams leave through rank 0's two NIC planes and cross the
/// (tapered) spine. Posted eagerly (`ChunkSched::Fifo`), every piece is
/// in flight at once and the gating segments fair-share every link
/// against the whole backlog, starting the GEMM late; under
/// `Srpf`/`Deadline` the backlog parks — gating segments issue first at
/// a near-exclusive share (the per-link depth gate admits at most one
/// bulk companion) — so the GEMM overlaps the bulk remainder. The chunk
/// tags are inert under `Fifo`, which therefore reproduces the eager
/// engine bit-identically.
pub fn sched_mixed(
    ctx: &ShmemCtx,
    heap: &mut SymmetricHeap,
    pb: &mut ProgBuild,
    bulk_pieces: usize,
    bulk_elems: usize,
    gate_pieces: usize,
    gate_elems: usize,
    gemm_secs: f64,
) {
    assert!(ctx.n_nodes() >= 2, "sched_mixed is an inter-node scenario");
    assert!(
        ctx.local_world_size() >= 2,
        "sched_mixed needs two GPUs per node"
    );
    assert!(bulk_pieces >= 1 && gate_pieces >= 1);
    let lws = ctx.local_world_size();
    let src = 0usize;
    let gate_dst = lws; // node-1 GPU 0
    let bulk_dst = lws + 1; // node-1 GPU 1
    let bulk = heap.alloc("sched_mixed_bulk", bulk_pieces * bulk_elems);
    let gate = heap.alloc("sched_mixed_gate", gate_pieces * gate_elems);
    let sig = 0usize;
    pb.claim_sigs("sched_mixed", sig, 1);

    // gating first in program order under BOTH policies — the contrast
    // below is purely the issue discipline, not op order
    let mut g = ctx
        .task(src, format!("sched_gate[{src}->{gate_dst}]"))
        .with_sms(1)
        .launch_overhead();
    for p in 0..gate_pieces {
        g.chunk_meta(ctx.bytes((gate_pieces - p) * gate_elems), 0);
        g.putmem_signal_nbi(
            Slice::new(src, gate, p * gate_elems, gate_elems),
            Slice::new(gate_dst, gate, p * gate_elems, gate_elems),
            sig,
            SigOp::Add,
            1,
        );
    }
    g.quiet();
    pb.prog.push(g.build());

    let mut t = ctx
        .task(src, format!("sched_bulk[{src}->{bulk_dst}]"))
        .with_sms(1)
        .launch_overhead();
    for p in 0..bulk_pieces {
        t.chunk_meta(ctx.bytes((bulk_pieces - p) * bulk_elems), u32::MAX);
        t.putmem_nbi(
            Slice::new(src, bulk, p * bulk_elems, bulk_elems),
            Slice::new(bulk_dst, bulk, p * bulk_elems, bulk_elems),
        );
    }
    t.quiet();
    pb.prog.push(t.build());

    let mut c = ctx
        .task(gate_dst, format!("sched_gemm[{gate_dst}]"))
        .with_sms(8)
        .launch_overhead();
    c.signal_wait_until(sig, SigCond::Ge, gate_pieces as u64);
    c.op(Op::Compute {
        cost: ComputeCost::Fixed { secs: gemm_secs },
        numeric: NumericOp::None,
        label: "sched_gemm",
    });
    pb.prog.push(c.build());
}

/// Build and run the **pinned** [`sched_mixed`] shape — h800 2x2 on a
/// 2-rail oversubscribed fabric with a 2x-tapered spine and adaptive
/// routing; 32 x 1 MiB bulk pieces against 4 x 256 KiB gating segments,
/// GEMM sized to the ideal bulk drain time — under chunk policy `sched`;
/// returns the makespan. Every chunk-scheduler caller (the
/// `alltoall-sched-mixed` perf scenario, `autotune::tune_chunk_sched`'s
/// workload test, the strict-win pin of `tests/sched_equivalence.rs`,
/// README's worked example) goes through this one function, so the
/// acceptance comparison is always apples to apples.
pub fn run_sched_mixed(sched: crate::config::ChunkSched) -> Result<f64, String> {
    run_sched_mixed_report(sched).map(|rep| rep.makespan)
}

/// [`run_sched_mixed`] returning the full [`SimReport`] — the
/// `alltoall-sched-mixed` perf scenario records events alongside the
/// makespan, everyone else only needs the scalar.
pub fn run_sched_mixed_report(
    sched: crate::config::ChunkSched,
) -> Result<crate::sim::SimReport, String> {
    use crate::config::{ClusterSpec, DType, FabricSpec, RailPolicy};
    use crate::sim::{NoopExecutor, Sim, SimConfig};

    let cluster = ClusterSpec::h800(2, 2).with_fabric(
        FabricSpec::rail_optimized(2, 2.0)
            .with_spine_taper(2.0)
            .with_rail_policy(RailPolicy::Adaptive)
            .with_chunk_sched(sched),
    );
    let ctx = ShmemCtx::new(cluster, DType::BF16);
    let topo = Topology::build(cluster);
    let mut heap = SymmetricHeap::new(ctx.n_pes(), 16);
    let mut pb = ProgBuild::new();
    let (bulk_pieces, bulk_elems) = (32usize, 1usize << 19); // 32 x 1 MiB
    let (gate_pieces, gate_elems) = (4usize, 1usize << 17); // 4 x 256 KiB
    // the GEMM covers the ideal two-plane bulk drain, so the makespan is
    // gated by *when the gating segments land*, not by the backlog
    let gemm_secs = ctx.bytes(bulk_pieces * bulk_elems) / cluster.hw.nic_bw;
    sched_mixed(
        &ctx, &mut heap, &mut pb, bulk_pieces, bulk_elems, gate_pieces, gate_elems, gemm_secs,
    );
    let sim = Sim::with_config(
        &topo,
        SimConfig {
            numerics: false,
            trace: false,
        },
    );
    sim.run(&pb.prog, &mut heap, &mut NoopExecutor)
        .map_err(|e| e.to_string())
}

/// Seed send chunks with rank/destination-tagged data.
pub fn fill_a2a_inputs(heap: &mut SymmetricHeap, bufs: &A2aBufs, seed: u64) {
    let ws = heap.world();
    for r in 0..ws {
        let mut rng = crate::util::Rng::new(seed ^ ((r as u64) << 17));
        let data = rng.normal_vec(ws * bufs.chunk);
        heap.write(Slice::new(r, bufs.send, 0, ws * bufs.chunk), &data);
    }
}

/// Verify: recv_slot(src) on rank r equals send_chunk(r) on rank src.
pub fn verify_alltoall(heap: &SymmetricHeap, bufs: &A2aBufs) -> Result<(), String> {
    let ws = heap.world();
    for r in 0..ws {
        for src in 0..ws {
            let got = heap.read(bufs.recv_slot(src, r));
            let want = heap.read(bufs.send_chunk(r, src));
            if got != want {
                return Err(format!("alltoall mismatch: rank {r} slot {src}"));
            }
        }
    }
    Ok(())
}

/// Run `dispatch` then `combine` (reversed buffers) and check round-trip
/// identity — the invariant behind expert-parallel token routing.
pub fn roundtrip_check(
    ctx: &ShmemCtx,
    topo: &Topology,
    chunk: usize,
    cfg: &A2aCfg,
) -> Result<(f64, f64), String> {
    use crate::sim::{NoopExecutor, Sim};
    let ws = ctx.n_pes();
    let mut heap = SymmetricHeap::new(ws, 4 * ws.max(16));
    let bufs = A2aBufs::alloc(&mut heap, ctx, chunk);
    fill_a2a_inputs(&mut heap, &bufs, 99);

    let mut pb = ProgBuild::new();
    a2a_ll(ctx, &bufs, &mut pb, cfg);
    let sim = Sim::new(topo);
    let rep1 = sim
        .run(&pb.prog, &mut heap, &mut NoopExecutor)
        .map_err(|e| e.to_string())?;
    verify_alltoall(&heap, &bufs)?;

    // combine: send back what we received; a second buffer set
    heap.reset_signals();
    let back = A2aBufs {
        send: bufs.recv,
        recv: heap.alloc("a2a_back", ws * chunk),
        ll: heap.alloc("a2a_back_ll", ws * chunk),
        chunk,
        sig_base: ws,
    };
    let mut pb2 = ProgBuild::new();
    a2a_ll(ctx, &back, &mut pb2, cfg);
    let rep2 = sim
        .run(&pb2.prog, &mut heap, &mut NoopExecutor)
        .map_err(|e| e.to_string())?;
    // round trip: rank r's slot src in `back.recv` == original send chunk
    // send_chunk(src) of r? back sends recv_slot(dst-indexed)... after two
    // hops, rank r's back.recv slot s = what s received from r = r's
    // original send chunk s.
    for r in 0..ws {
        for s in 0..ws {
            let got = heap.read(Slice::new(r, back.recv, s * chunk, chunk));
            let want = heap.read(bufs.send_chunk(s, r));
            if got != want {
                return Err(format!("roundtrip mismatch rank {r} slot {s}"));
            }
        }
    }
    Ok((rep1.makespan, rep2.makespan))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, DType};
    use crate::sim::{NoopExecutor, Sim};
    use crate::topology::Topology;

    fn run_a2a(cluster: ClusterSpec, chunk: usize, build: impl Fn(&ShmemCtx, &A2aBufs, &mut ProgBuild)) -> f64 {
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes().max(16));
        let bufs = A2aBufs::alloc(&mut heap, &ctx, chunk);
        fill_a2a_inputs(&mut heap, &bufs, 5);
        let mut pb = ProgBuild::new();
        build(&ctx, &bufs, &mut pb);
        let sim = Sim::new(&topo);
        let rep = sim.run(&pb.prog, &mut heap, &mut NoopExecutor).unwrap();
        verify_alltoall(&heap, &bufs).unwrap();
        rep.makespan
    }

    #[test]
    fn ours_intra_node_correct() {
        run_a2a(ClusterSpec::h800(1, 8), 32, |c, b, p| {
            a2a_ll(c, b, p, &A2aCfg::ours())
        });
    }

    #[test]
    fn ours_inter_node_correct() {
        run_a2a(ClusterSpec::h800(2, 8), 32, |c, b, p| {
            a2a_ll(c, b, p, &A2aCfg::ours())
        });
    }

    #[test]
    fn deepep_correct() {
        run_a2a(ClusterSpec::h800(2, 8), 32, a2a_deepep);
    }

    #[test]
    fn roundtrip_identity() {
        let cluster = ClusterSpec::h800(1, 4);
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        roundtrip_check(&ctx, &topo, 16, &A2aCfg::ours()).unwrap();
    }

    #[test]
    fn ep_rails_dispatch_and_combine_correct_on_railed_fabric() {
        use crate::config::FabricSpec;
        let cluster = ClusterSpec::h800(2, 8)
            .with_fabric(FabricSpec::rail_optimized(2, 2.0).with_spine_taper(2.0));
        for dir in [A2aEpDir::Dispatch, A2aEpDir::Combine] {
            run_a2a(cluster, 32, |c, b, p| {
                a2a_ep_rails(c, b, p, &A2aCfg::ours(), dir)
            });
        }
    }

    #[test]
    fn ep_combine_emits_asymmetric_rails() {
        use crate::config::{FabricSpec, TrafficClass};
        let cluster = ClusterSpec::h800(2, 8).with_fabric(FabricSpec::rail_optimized(2, 2.0));
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes());
        let bufs = A2aBufs::alloc(&mut heap, &ctx, 16);
        let collect_tcs = |dir: A2aEpDir| {
            let mut pb = ProgBuild::new();
            a2a_ep_rails(&ctx, &bufs, &mut pb, &A2aCfg::ours(), dir);
            pb.prog
                .tasks
                .iter()
                .flat_map(|t| &t.ops)
                .filter_map(|o| match o {
                    crate::program::Op::LLPut { tc, .. } => Some(*tc),
                    _ => None,
                })
                .collect::<Vec<_>>()
        };
        // dispatch: every explicit assignment stays in one plane
        assert!(collect_tcs(A2aEpDir::Dispatch)
            .iter()
            .all(|tc| !matches!(tc, TrafficClass::Rails { tx, rx } if tx != rx)));
        // combine: differing home planes produce spine-crossing classes
        let crossing = collect_tcs(A2aEpDir::Combine)
            .iter()
            .filter(|tc| matches!(tc, TrafficClass::Rails { tx, rx } if tx != rx))
            .count();
        assert!(crossing > 0, "combine must emit Rails{{tx != rx}}");
    }

    #[test]
    fn split_ranges_partition_exactly() {
        for (elems, split) in [(10usize, 1usize), (10, 3), (7, 7), (3, 8), (1, 2)] {
            let pieces = split_ranges(elems, split);
            assert!(pieces.len() <= split.max(1));
            assert!(pieces.iter().all(|&(_, len)| len > 0), "no empty pieces");
            let mut off = 0;
            for &(o, len) in &pieces {
                assert_eq!(o, off, "pieces must be contiguous");
                off += len;
            }
            assert_eq!(off, elems, "pieces must cover the chunk");
        }
        // split = 1 is the identity piece (the bit-identical fast path)
        assert_eq!(split_ranges(64, 1), vec![(0, 64)]);
    }

    #[test]
    fn uniform_sizes_reproduce_fixed_chunk_layout() {
        let sizes = A2aSizes::uniform(4, 8);
        for src in 0..4 {
            for dst in 0..4 {
                assert_eq!(sizes.elems(src, dst), 8);
                assert_eq!(sizes.send_off(src, dst), dst * 8);
                assert_eq!(sizes.recv_off(src, dst), src * 8);
            }
            assert_eq!(sizes.send_total(src), 32);
            assert_eq!(sizes.recv_total(src), 32);
        }
    }

    #[test]
    fn var_uniform_bit_identical_to_a2a_ll() {
        // the acceptance identity: a uniform size table through the
        // variable-size builder == the fixed-chunk builder, bit for bit
        let cluster = ClusterSpec::h800(2, 4);
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let run = |var: bool| -> f64 {
            let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes().max(16));
            let mut pb = ProgBuild::new();
            if var {
                let bufs = A2aVarBufs::alloc(&mut heap, A2aSizes::uniform(ctx.n_pes(), 64));
                a2a_ll_var(&ctx, &bufs, &mut pb, &A2aCfg::ours(), None);
            } else {
                let bufs = A2aBufs::alloc(&mut heap, &ctx, 64);
                a2a_ll(&ctx, &bufs, &mut pb, &A2aCfg::ours());
            }
            let sim = Sim::new(&topo);
            sim.run(&pb.prog, &mut heap, &mut NoopExecutor).unwrap().makespan
        };
        assert_eq!(run(false).to_bits(), run(true).to_bits());
    }

    #[test]
    fn var_routed_dispatch_delivers_and_conserves() {
        // randomized routing: every kept (token, k) pair's row crosses
        // the wire exactly once, zero-size pairs still signal
        let cluster = ClusterSpec::h800(2, 2);
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let ws = ctx.n_pes();
        let geom = EpGeom {
            t: 6,
            h: 3,
            f: 2,
            e: 8,
            k: 2,
            c: 9,
            w: ws,
        };
        let routing = EpRouting::generate(geom, 1.0, 42);
        let mut heap = SymmetricHeap::new(ws, 4 * ws.max(16));
        let bufs = A2aVarBufs::alloc(&mut heap, routing.dispatch_sizes());
        for r in 0..ws {
            let n = bufs.sizes.send_total(r);
            let vals: Vec<f32> = (0..n).map(|i| (r * 1_000_000 + i) as f32).collect();
            heap.write(Slice::new(r, bufs.send, 0, n), &vals);
        }
        let mut pb = ProgBuild::new();
        a2a_ep_rails_var(&ctx, &bufs, &mut pb, &A2aCfg::ours(), A2aEpDir::Dispatch, None);
        let sim = Sim::new(&topo);
        sim.run(&pb.prog, &mut heap, &mut NoopExecutor).unwrap();
        let mut delivered = 0usize;
        for on in 0..ws {
            for src in 0..ws {
                let got = heap.read(bufs.recv_slot(src, on)).to_vec();
                let want = heap.read(bufs.send_chunk(on, src)).to_vec();
                assert_eq!(got, want, "chunk {src}->{on} must arrive verbatim");
                delivered += got.len();
            }
            // arrival signals fire even for empty chunks
            for src in 0..ws {
                assert_eq!(heap.signal(on, bufs.sig(src)), 1, "sig {src} on {on}");
            }
        }
        assert_eq!(
            delivered,
            routing.kept() * geom.h,
            "every kept token delivered exactly once"
        );
    }

    #[test]
    fn ep_routing_sizes_are_consistent_with_the_plan() {
        let geom = EpGeom {
            t: 16,
            h: 4,
            f: 8,
            e: 6,
            k: 3,
            c: 40,
            w: 3,
        };
        let routing = EpRouting::generate(geom, 1.5, 7);
        let disp = routing.dispatch_sizes();
        let comb = routing.combine_sizes();
        let plan = routing.plan();
        let mut kept = 0usize;
        for src in 0..geom.w {
            for dst in 0..geom.w {
                assert_eq!(disp.elems(src, dst), plan.count(src, dst) * geom.h);
                // combine is the transpose, in FFN-output elements
                assert_eq!(comb.elems(dst, src), plan.count(src, dst) * geom.f);
                kept += plan.count(src, dst);
            }
        }
        assert_eq!(kept + routing.dropped(), geom.w * geom.t * geom.k);
        // tokens(src, e) aggregates to the per-destination counts
        let e_local = plan.e_local();
        for src in 0..geom.w {
            for dst in 0..geom.w {
                let by_expert: usize = (dst * e_local..((dst + 1) * e_local).min(geom.e))
                    .map(|e| routing.tokens(src, e))
                    .sum();
                assert_eq!(by_expert, plan.count(src, dst));
            }
        }
    }

    #[test]
    fn skew_concentrates_expert_load() {
        let geom = EpGeom {
            t: 64,
            h: 1,
            f: 1,
            e: 8,
            k: 2,
            c: usize::MAX,
            w: 4,
        };
        let uniform = EpRouting::generate(geom, 0.0, 3);
        let skewed = EpRouting::generate(geom, 2.0, 3);
        let load = |r: &EpRouting, e: usize| -> usize {
            (0..geom.w).map(|s| r.tokens(s, e)).sum()
        };
        // expert 0 is the popular one under Zipf skew
        assert!(
            load(&skewed, 0) > 2 * load(&uniform, 0),
            "skew must concentrate load: {} vs {}",
            load(&skewed, 0),
            load(&uniform, 0)
        );
        // no drops with unbounded capacity
        assert_eq!(skewed.dropped(), 0);
    }

    #[test]
    fn deepep_combine_is_the_3x_queue_config() {
        let base = A2aCfg::deepep();
        let comb = A2aCfg::deepep_combine();
        assert_eq!(comb.queue_overhead, base.queue_overhead * 3.0);
        assert_eq!(comb.inter_msg_overhead, base.inter_msg_overhead);
        assert_eq!(comb.split, 1);
        assert_eq!(A2aCfg::ours().with_split(4).split, 4);
    }

    #[test]
    fn ours_beats_deepep_at_small_scale() {
        // Fig. 16 shape: at 16 ranks (2 nodes) the NVLink intra path wins.
        let ours = run_a2a(ClusterSpec::h800(2, 8), 1024, |c, b, p| {
            a2a_ll(c, b, p, &A2aCfg::ours())
        });
        let deepep = run_a2a(ClusterSpec::h800(2, 8), 1024, a2a_deepep);
        assert!(ours < deepep, "ours {ours} vs deepep {deepep}");
    }
}
