//! One-sided AllGather variants (§3.2, §3.4, Fig. 4, Alg. 1/2/4).
//!
//! All variants announce segment arrival through `sig_base + seg` on the
//! receiving rank, so any consumer kernel (e.g. the AG+GEMM consumer) can
//! overlap with any AllGather flavor by waiting per-segment signals.

use crate::program::{ComputeCost, NumericOp, Op, Scope, SigCond, SigOp};
use crate::shmem::ShmemCtx;

use super::{AgBufs, ProgBuild, WorldView};

/// Alg. 1 — push-mode intra-node AllGather on the copy engine.
///
/// Each rank walks its peers in rank-shifted order (`r+1, r+2, ...`) and
/// pushes its own shard with a delivery signal. Blocking copies model the
/// DMA queue: arrivals at a given receiver are pipelined, which is what
/// the Fig. 7 consumer swizzle exploits.
pub fn ag_push_intra(ctx: &ShmemCtx, bufs: &AgBufs, pb: &mut ProgBuild) {
    let ws = ctx.n_pes();
    pb.claim_sigs("ag_push_intra", bufs.sig_base, ws);
    for r in 0..ws {
        let mut t = ctx.task(r, format!("ag_push[{r}]")).on_copy_engine().launch_overhead();
        // local shard is ready by definition
        t.notify(r, bufs.sig(r), SigOp::Set, 1);
        for i in 1..ws {
            let peer = (r + i) % ws;
            t.putmem_signal(
                bufs.seg(r, r),
                bufs.seg(r, peer),
                bufs.sig(r),
                SigOp::Set,
                1,
            );
        }
        pb.prog.push(t.build());
    }
}

/// Alg. 2 — pull-mode intra-node AllGather on the copy engine.
///
/// One extra `barrier_all` (to publish local shards) buys controlled
/// arrival *order*: rank `r` pulls `r+1, r+2, ...`, which is exactly the
/// order its swizzled consumer wants.
pub fn ag_pull_intra(ctx: &ShmemCtx, bufs: &AgBufs, pb: &mut ProgBuild) {
    let ws = ctx.n_pes();
    pb.claim_sigs("ag_pull_intra", bufs.sig_base, ws);
    let bid = pb.fresh_barrier();
    for r in 0..ws {
        let mut t = ctx.task(r, format!("ag_pull[{r}]")).on_copy_engine().launch_overhead();
        t.notify(r, bufs.sig(r), SigOp::Set, 1);
        t.barrier_all(bid); // make local shards visible (Alg. 2 line 5)
        for i in 1..ws {
            let peer = (r + i) % ws;
            t.getmem(bufs.seg(peer, peer), bufs.seg(peer, r));
            t.notify(r, bufs.sig(peer), SigOp::Set, 1);
        }
        pb.prog.push(t.build());
    }
}

/// Fig. 4 — inter-node AllGather: `local_world_size - 1` intra-forward
/// blocks and `n_nodes - 1` inter-send blocks per rank, running in
/// parallel so NVLink forwarding hides NIC transfers. Inter-node sends
/// are striped across NIC rails (one rail per peer-node stream under
/// `RailPolicy::Static`, emptiest-plane-per-message under `Adaptive`)
/// so a multi-rail fabric runs all planes concurrently.
pub fn ag_inter(ctx: &ShmemCtx, bufs: &AgBufs, pb: &mut ProgBuild) {
    let ws = ctx.n_pes();
    let lws = ctx.local_world_size();
    let n_nodes = ctx.n_nodes();
    assert!(n_nodes > 1, "ag_inter requires multiple nodes");
    pb.claim_sigs("ag_inter", bufs.sig_base, ws);

    for r in 0..ws {
        let node = ctx.node_of(r);
        let lr = ctx.local_rank_of(r);

        // mark own segment ready
        let mut init = ctx.task(r, format!("ag_init[{r}]")).on_host();
        init.notify(r, bufs.sig(r), SigOp::Set, 1);
        pb.prog.push(init.build());

        // inter-node senders: own segment to the same local rank of every
        // other node (Fig. 4 "inter-node send" blocks), one rail each
        for pid in 0..n_nodes - 1 {
            let peer_node = (node + pid + 1) % n_nodes;
            let peer = peer_node * lws + lr;
            let mut t = ctx
                .task(r, format!("ag_inter_send[{r}->{peer}]"))
                .with_sms(1)
                .launch_overhead();
            t.stripe_rail(pid);
            // gating piece: its arrival releases the peer's consumer
            // (the GEMM wave in ag_gemm), so the chunk scheduler lets it
            // overtake bulk backlogs; one shard left in this stream
            t.chunk_meta(ctx.bytes(bufs.shard), 0);
            t.signal_wait_until(bufs.sig(r), SigCond::Eq, 1);
            t.putmem_signal(bufs.seg(r, r), bufs.seg(r, peer), bufs.sig(r), SigOp::Set, 1);
            pb.prog.push(t.build());
        }

        // intra-node forwarders: this rank's column (same local rank,
        // every node) to one node peer each (Fig. 4 "intra-node send")
        for pid in 0..lws - 1 {
            let peer = (lr + pid + 1) % lws + node * lws;
            let mut t = ctx
                .task(r, format!("ag_intra_fwd[{r}->{peer}]"))
                .with_sms(1)
                .launch_overhead();
            for i in 0..n_nodes {
                let seg = lr + ((node + i) % n_nodes) * lws;
                t.signal_wait_until(bufs.sig(seg), SigCond::Eq, 1);
                t.putmem_signal(
                    bufs.seg(seg, r),
                    bufs.seg(seg, peer),
                    bufs.sig(seg),
                    SigOp::Set,
                    1,
                );
            }
            pb.prog.push(t.build());
        }
    }
}

/// Pack/unpack between the data buffer and the LL staging buffer: a
/// memory-bound local kernel (flags interleaved at 8-byte granularity).
fn ll_repack(
    t: &mut crate::shmem::ShmemTask,
    src: crate::mem::Slice,
    dst: crate::mem::Slice,
    bytes: f64,
    label: &'static str,
) {
    t.op(Op::Compute {
        cost: ComputeCost::MemBound { bytes: bytes * 2.0 },
        numeric: NumericOp::Copy { src, dst },
        label,
    });
}

/// Alg. 4 — low-latency cross-node AllGather: LL protocol over the NIC +
/// `multimem.st` NVLink broadcast, `WORLD_SIZE` blocks per rank.
pub fn ag_ll_inter(ctx: &ShmemCtx, bufs: &AgBufs, pb: &mut ProgBuild) {
    ag_ll_inter_gated(ctx, bufs, pb, None)
}

/// [`ag_ll_inter`] with an optional per-rank readiness gate (see
/// [`ag_ll_intra_gated`]).
pub fn ag_ll_inter_gated(
    ctx: &ShmemCtx,
    bufs: &AgBufs,
    pb: &mut ProgBuild,
    ready_sig: Option<usize>,
) {
    let ws = ctx.n_pes();
    let lws = ctx.local_world_size();
    let n_nodes = ctx.n_nodes();
    assert!(n_nodes > 1, "ag_ll_inter requires multiple nodes");
    assert!(bufs.ll.is_some(), "LL AllGather needs an LL staging buffer");
    pb.claim_sigs("ag_ll_inter", bufs.sig_base, ws);
    let shard_bytes = ctx.bytes(bufs.shard);

    for r in 0..ws {
        let node = ctx.node_of(r);
        let lr = ctx.local_rank_of(r);
        for b in 0..ws {
            let peer_node = b / lws;
            let peer_lr = b % lws;
            if peer_lr == lr && peer_node == node {
                // own segment: pack, LL-send to every other node's same
                // local rank, NVLink-broadcast to node peers (lines 11-18)
                let mut t = ctx
                    .task(r, format!("ag_ll_own[{r}]"))
                    .with_sms(1)
                    .launch_overhead();
                if let Some(sig) = ready_sig {
                    t.signal_wait_until(sig, SigCond::Ge, 1);
                }
                ll_repack(&mut t, bufs.seg(r, r), bufs.ll_seg(r, r), shard_bytes, "ll_pack");
                for i in 1..n_nodes {
                    let pn = (node + i) % n_nodes;
                    let peer = pn * lws + lr;
                    // stripe the LL sends across NIC rails (round-robin,
                    // or adaptively under RailPolicy::Adaptive)
                    t.stripe_rail(i - 1);
                    t.ll_put(bufs.ll_seg(r, r), bufs.ll_seg(r, peer));
                }
                t.multimem_st_ll(bufs.ll_seg(r, r));
                t.notify(r, bufs.sig(r), SigOp::Set, 1);
                t.quiet();
                pb.prog.push(t.build());
            } else if peer_lr == lr {
                // inter-node receive of segment (peer_node, lr), then
                // NVLink broadcast + unpack (lines 5-9)
                let seg = peer_node * lws + lr;
                let mut t = ctx
                    .task(r, format!("ag_ll_recv_fwd[{r},{seg}]"))
                    .with_sms(1)
                    .launch_overhead();
                t.recv_ll(bufs.ll_seg(seg, r));
                t.multimem_st_ll(bufs.ll_seg(seg, r));
                ll_repack(&mut t, bufs.ll_seg(seg, r), bufs.seg(seg, r), shard_bytes, "ll_unpack");
                t.notify(r, bufs.sig(seg), SigOp::Set, 1);
                pb.prog.push(t.build());
            } else {
                // intra-node receive of segment (peer_node, peer_lr)
                // broadcast by the node peer owning that column (21-22)
                let seg = peer_node * lws + peer_lr;
                let mut t = ctx
                    .task(r, format!("ag_ll_recv[{r},{seg}]"))
                    .with_sms(1)
                    .launch_overhead();
                t.recv_ll(bufs.ll_seg(seg, r));
                ll_repack(&mut t, bufs.ll_seg(seg, r), bufs.seg(seg, r), shard_bytes, "ll_unpack");
                t.notify(r, bufs.sig(seg), SigOp::Set, 1);
                pb.prog.push(t.build());
            }
        }
    }
}

/// Intra-node low-latency AllGather: every rank LL-packs its shard and
/// `multimem.st`-broadcasts it; `ws-1` receive blocks unpack. The
/// single-node core of Alg. 4.
pub fn ag_ll_intra(ctx: &ShmemCtx, bufs: &AgBufs, pb: &mut ProgBuild) {
    ag_ll_intra_gated(ctx, bufs, pb, None)
}

/// [`ag_ll_intra`] with an optional per-rank readiness gate: the own-
/// segment broadcast waits for local signal `ready_sig` (set by a
/// producer such as the flash-decode partial kernel) before packing.
pub fn ag_ll_intra_gated(
    ctx: &ShmemCtx,
    bufs: &AgBufs,
    pb: &mut ProgBuild,
    ready_sig: Option<usize>,
) {
    let ws = ctx.n_pes();
    assert_eq!(ctx.n_nodes(), 1, "ag_ll_intra is single-node");
    pb.claim_sigs("ag_ll_intra", bufs.sig_base, ws);
    let shard_bytes = ctx.bytes(bufs.shard);
    for r in 0..ws {
        let mut own = ctx
            .task(r, format!("ag_ll_own[{r}]"))
            .with_sms(1)
            .launch_overhead();
        if let Some(sig) = ready_sig {
            own.signal_wait_until(sig, SigCond::Ge, 1);
        }
        ll_repack(&mut own, bufs.seg(r, r), bufs.ll_seg(r, r), shard_bytes, "ll_pack");
        own.multimem_st_ll(bufs.ll_seg(r, r));
        own.notify(r, bufs.sig(r), SigOp::Set, 1);
        pb.prog.push(own.build());

        for seg in 0..ws {
            if seg == r {
                continue;
            }
            let mut t = ctx
                .task(r, format!("ag_ll_recv[{r},{seg}]"))
                .with_sms(1)
                .launch_overhead();
            t.recv_ll(bufs.ll_seg(seg, r));
            ll_repack(&mut t, bufs.ll_seg(seg, r), bufs.seg(seg, r), shard_bytes, "ll_unpack");
            t.notify(r, bufs.sig(seg), SigOp::Set, 1);
            pb.prog.push(t.build());
        }
    }
}

/// Low-latency AllGather for PCIe-only clusters (L20, Fig. 19): no
/// multimem, no NVLink — every rank LL-puts its shard directly to every
/// peer (NIC for remote nodes), receivers spin on in-band flags. The
/// PCIe-scheduling optimization is the peer *order*: walks are
/// rank-shifted so no two senders target the same receiver's down-link in
/// the same step.
pub fn ag_ll_pcie(ctx: &ShmemCtx, bufs: &AgBufs, pb: &mut ProgBuild) {
    let ws = ctx.n_pes();
    pb.claim_sigs("ag_ll_pcie", bufs.sig_base, ws);
    let shard_bytes = ctx.bytes(bufs.shard);
    for r in 0..ws {
        let mut send = ctx
            .task(r, format!("ag_ll_send[{r}]"))
            .with_sms(1)
            .launch_overhead();
        ll_repack(&mut send, bufs.seg(r, r), bufs.ll_seg(r, r), shard_bytes, "ll_pack");
        send.notify(r, bufs.sig(r), SigOp::Set, 1);
        let mut inter_idx = 0usize;
        for i in 1..ws {
            let peer = (r + i) % ws;
            if ctx.node_of(peer) != ctx.node_of(r) {
                // stripe inter-node LL sends across rails (intra-node
                // routes ignore the rail pin)
                send.stripe_rail(inter_idx);
                inter_idx += 1;
            }
            send.ll_put(bufs.ll_seg(r, r), bufs.ll_seg(r, peer));
        }
        send.quiet();
        pb.prog.push(send.build());

        for seg in 0..ws {
            if seg == r {
                continue;
            }
            let mut t = ctx
                .task(r, format!("ag_ll_recv[{r},{seg}]"))
                .with_sms(1)
                .launch_overhead();
            t.recv_ll(bufs.ll_seg(seg, r));
            ll_repack(&mut t, bufs.ll_seg(seg, r), bufs.seg(seg, r), shard_bytes, "ll_unpack");
            t.notify(r, bufs.sig(seg), SigOp::Set, 1);
            pb.prog.push(t.build());
        }
    }
}

/// Flat survivor-indexed AllGather: every logical rank pushes its own
/// shard to every other logical peer with a delivery signal. This is the
/// **degraded-world re-plan path** of the elastic recovery controller:
/// unlike [`ag_inter`] it assumes nothing about the node grid being
/// rectangular, so it stays valid on any survivor set after rank or node
/// death. Segment slots and signals are indexed by *physical* rank (a
/// survivor's shard stays in its original heap slot; dead ranks' slots
/// are simply never gathered), so it composes with the original
/// [`AgBufs`] allocation. Non-overlapped and rail-striped only — the
/// price of generality; the overlapped builders remain the fault-free
/// fast path.
pub fn ag_flat_on(ctx: &ShmemCtx, bufs: &AgBufs, pb: &mut ProgBuild, view: &WorldView) {
    let ws = view.world();
    pb.claim_sigs("ag_flat", bufs.sig_base, ctx.n_pes());
    for l in 0..ws {
        let pr = view.phys(l);
        assert!(pr < ctx.n_pes(), "view physical rank out of range");
        let mut t = ctx
            .task(pr, format!("ag_flat[{l}]"))
            .with_sms(1)
            .launch_overhead();
        t.notify(pr, bufs.sig(pr), SigOp::Set, 1);
        let mut inter_idx = 0usize;
        for i in 1..ws {
            let m = (l + i) % ws;
            let pm = view.phys(m);
            if ctx.node_of(pm) != ctx.node_of(pr) {
                t.stripe_rail(inter_idx);
                inter_idx += 1;
            }
            t.putmem_signal(
                bufs.seg(pr, pr),
                bufs.seg(pr, pm),
                bufs.sig(pr),
                SigOp::Set,
                1,
            );
        }
        pb.prog.push(t.build());
    }
}

/// AMD full-mesh AllGather (§3.6 + Fig. 8): communication is tiled into
/// sub-chunks and each step pulls the next sub-chunk from *all* peers
/// simultaneously — the only way to reach the 350 GB/s aggregate of the
/// 7x50 GB/s mesh. `sub_chunks` is the communication tile factor
/// (autotunable, decoupled from the compute tile).
pub fn ag_amd_mesh(ctx: &ShmemCtx, bufs: &AgBufs, pb: &mut ProgBuild, sub_chunks: usize) {
    let ws = ctx.n_pes();
    pb.claim_sigs("ag_amd_mesh", bufs.sig_base, ws);
    assert!(sub_chunks >= 1 && bufs.shard % sub_chunks == 0,
            "sub_chunks must divide the shard");
    let sub = bufs.shard / sub_chunks;
    let bid = pb.fresh_barrier();
    // participants: per rank 1 publisher + (ws-1) pull streams
    let expect = ws * ws;
    for r in 0..ws {
        // One stream per peer so all 7 links run concurrently (the copy
        // engine count on MI308X supports this, §3.6).
        let mut first = ctx.task(r, format!("ag_amd_pub[{r}]")).on_host();
        first.notify(r, bufs.sig(r), SigOp::Set, 1);
        first.barrier_group(bid, Scope::World, expect);
        pb.prog.push(first.build());

        for i in 1..ws {
            let peer = (r + i) % ws;
            let mut t = ctx
                .task(r, format!("ag_amd_pull[{r}<-{peer}]"))
                .on_copy_engine()
                .launch_overhead();
            t.barrier_group(bid, Scope::World, expect);
            for s in 0..sub_chunks {
                let src = bufs.seg(peer, peer).sub(s * sub, sub);
                let dst = bufs.seg(peer, r).sub(s * sub, sub);
                t.getmem(src, dst);
                // per-sub-chunk arrival counter: consumer waits GE count
                t.notify(r, bufs.sig(peer), SigOp::Add, 1);
            }
            pb.prog.push(t.build());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{expected_allgather, fill_ag_inputs, verify_allgather};
    use crate::config::{ClusterSpec, DType};
    use crate::mem::SymmetricHeap;
    use crate::sim::{NoopExecutor, Sim};
    use crate::topology::Topology;

    fn run_variant(
        cluster: ClusterSpec,
        shard: usize,
        build: impl Fn(&ShmemCtx, &AgBufs, &mut ProgBuild),
        ll: bool,
    ) -> f64 {
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes().max(16));
        let bufs = if ll {
            AgBufs::alloc_ll(&mut heap, &ctx, shard)
        } else {
            AgBufs::alloc(&mut heap, &ctx, shard)
        };
        fill_ag_inputs(&mut heap, &bufs, 7);
        let expected = expected_allgather(&heap, &bufs);
        let mut pb = ProgBuild::new();
        build(&ctx, &bufs, &mut pb);
        let sim = Sim::new(&topo);
        let rep = sim.run(&pb.prog, &mut heap, &mut NoopExecutor).unwrap();
        verify_allgather(&heap, &bufs, &expected).unwrap();
        // all arrival signals present
        for r in 0..ctx.n_pes() {
            for s in 0..ctx.n_pes() {
                assert!(heap.signal(r, bufs.sig(s)) >= 1, "missing sig {s} on {r}");
            }
        }
        rep.makespan
    }

    #[test]
    fn push_intra_gathers() {
        run_variant(ClusterSpec::h800(1, 8), 64, ag_push_intra, false);
    }

    #[test]
    fn pull_intra_gathers() {
        run_variant(ClusterSpec::h800(1, 8), 64, ag_pull_intra, false);
    }

    #[test]
    fn pull_has_a_barrier_push_does_not() {
        // Alg. 2's defining cost: one barrier_all to publish local shards
        // before any pull can start (Alg. 1 needs none).
        let ctx = ShmemCtx::new(ClusterSpec::h800(1, 8), crate::config::DType::BF16);
        let mut heap = crate::mem::SymmetricHeap::new(8, 32);
        let bufs = AgBufs::alloc(&mut heap, &ctx, 8);
        let count_barriers = |pb: &ProgBuild| {
            pb.prog
                .tasks
                .iter()
                .flat_map(|t| &t.ops)
                .filter(|o| matches!(o, crate::program::Op::Barrier { .. }))
                .count()
        };
        let mut push_pb = ProgBuild::new();
        ag_push_intra(&ctx, &bufs, &mut push_pb);
        let mut pull_pb = ProgBuild::new();
        ag_pull_intra(&ctx, &bufs, &mut pull_pb);
        assert_eq!(count_barriers(&push_pb), 0);
        assert_eq!(count_barriers(&pull_pb), 8);
    }

    #[test]
    fn inter_node_gathers() {
        run_variant(ClusterSpec::h800(2, 4), 32, ag_inter, false);
    }

    #[test]
    fn inter_node_gathers_4_nodes() {
        run_variant(ClusterSpec::h800(4, 4), 16, ag_inter, false);
    }

    #[test]
    fn ll_inter_gathers() {
        run_variant(ClusterSpec::h800(2, 4), 32, ag_ll_inter, true);
    }

    #[test]
    fn ll_inter_4_nodes_gathers() {
        run_variant(ClusterSpec::h800(4, 8), 16, ag_ll_inter, true);
    }

    #[test]
    fn ll_intra_gathers() {
        run_variant(ClusterSpec::h800(1, 8), 32, ag_ll_intra, true);
    }

    #[test]
    fn ll_pcie_gathers() {
        run_variant(ClusterSpec::l20(1, 8), 32, ag_ll_pcie, true);
    }

    #[test]
    fn ll_pcie_two_nodes_gathers() {
        run_variant(ClusterSpec::l20(2, 8), 32, ag_ll_pcie, true);
    }

    #[test]
    fn flat_identity_gathers() {
        run_variant(
            ClusterSpec::h800(2, 4),
            32,
            |c, b, p| ag_flat_on(c, b, p, &WorldView::identity(c.n_pes())),
            false,
        );
    }

    #[test]
    fn flat_survivor_view_gathers_survivor_shards() {
        // after rank 5 dies, the flat re-plan gathers every *survivor*
        // shard onto every survivor; the dead slot stays untouched
        let cluster = ClusterSpec::h800(2, 4);
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes().max(16));
        let bufs = AgBufs::alloc(&mut heap, &ctx, 16);
        fill_ag_inputs(&mut heap, &bufs, 11);
        let view = WorldView::survivors(ctx.n_pes(), &[5]);
        let mut pb = ProgBuild::new();
        ag_flat_on(&ctx, &bufs, &mut pb, &view);
        let sim = Sim::new(&topo);
        sim.run(&pb.prog, &mut heap, &mut NoopExecutor).unwrap();
        for l in 0..view.world() {
            let on = view.phys(l);
            for s in 0..view.world() {
                let seg = view.phys(s);
                let got = heap.read(bufs.seg(seg, on));
                let own = heap.read(bufs.seg(seg, seg));
                assert_eq!(got, own, "segment {seg} missing on rank {on}");
                assert!(heap.signal(on, bufs.sig(seg)) >= 1);
            }
        }
    }

    #[test]
    fn amd_mesh_gathers() {
        run_variant(
            ClusterSpec::mi308x(8),
            64,
            |c, b, p| ag_amd_mesh(c, b, p, 4),
            false,
        );
    }

    #[test]
    fn amd_subchunking_beats_single_peer_pulls() {
        // Sanity: on the mesh, the total time approaches shard*(ws-1)/350GBs
        // rather than /50GBs. With sub-chunks the links run concurrently.
        let shard = 1 << 20; // 1M elements = 2 MB bf16
        let t = run_variant(
            ClusterSpec::mi308x(8),
            shard,
            |c, b, p| ag_amd_mesh(c, b, p, 4),
            false,
        );
        let bytes = (shard * 2 * 7) as f64;
        let serial = bytes / 50e9; // one link at a time
        let parallel = bytes / 350e9; // all links
        assert!(t < serial * 0.6, "t={t} serial={serial}");
        assert!(t > parallel * 0.9, "t={t} parallel={parallel}");
    }

    #[test]
    fn ll_latency_beats_push_for_small_messages() {
        // Fig. 5's point: for small segments the LL+multimem path cuts
        // latency vs the signal-pair push path.
        let small = 64; // 128 B bf16 per shard
        let push = run_variant(ClusterSpec::h800(1, 8), small, ag_push_intra, false);
        let ll = run_variant(ClusterSpec::h800(1, 8), small, ag_ll_intra, true);
        assert!(ll < push, "ll {ll} should beat push {push} at small size");
    }
}
