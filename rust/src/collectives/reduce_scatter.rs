//! One-sided ReduceScatter variants (§3.3, §3.5, Alg. 3/5, Fig. 9/10).

use crate::program::{ComputeCost, NumericOp, Op, Scope, SigCond, SigOp};
use crate::shmem::ShmemCtx;

use super::{ProgBuild, RsBufs};

/// Alg. 3 — push-mode intra-node ReduceScatter.
///
/// Two parallel parts per rank: a copy-engine stream pushing each input
/// chunk to its destination rank's scatter slot (with a delivery signal),
/// and an SM reduction task that accumulates slots as they arrive. The
/// reduction runs on `reduce_sms` SMs (§3.5 sizing: ~15 on H800).
///
/// `producer_sig`: if `Some(base)`, chunk `dst` may only be pushed after
/// local signal `base + dst` is set (the producer-GEMM linkage of
/// GEMM+RS); `None` treats inputs as ready.
pub fn rs_push_intra(
    ctx: &ShmemCtx,
    bufs: &RsBufs,
    pb: &mut ProgBuild,
    reduce_sms: u32,
    producer_sig: Option<usize>,
) {
    let ws = ctx.n_pes();
    assert_eq!(ctx.n_nodes(), 1, "rs_push_intra is single-node");
    pb.claim_sigs("rs_push_intra", bufs.sig_base, ws);

    for r in 0..ws {
        // Stream 1: scatter each chunk to its destination (shifted walk).
        let mut scat = ctx.task(r, format!("rs_scatter[{r}]")).on_copy_engine().launch_overhead();
        for i in 0..ws {
            let dst = (r + 1 + i) % ws; // own chunk lands last (overlap-friendly)
            if let Some(base) = producer_sig {
                scat.signal_wait_until(base + dst, SigCond::Eq, 1);
            }
            scat.putmem_signal(
                bufs.in_chunk(dst, r),
                bufs.scatter_slot(r, dst),
                bufs.scatter_sig(r),
                SigOp::Set,
                1,
            );
        }
        pb.prog.push(scat.build());

        // Stream 2: local reduction, incremental as slots arrive.
        let mut red = ctx
            .task(r, format!("rs_reduce[{r}]"))
            .with_sms(reduce_sms)
            .launch_overhead();
        for src in 0..ws {
            red.signal_wait_until(bufs.scatter_sig(src), SigCond::Eq, 1);
            red.op(Op::Compute {
                cost: ComputeCost::Reduce {
                    bytes: ctx.bytes(bufs.shard) as f64 * 2.0,
                },
                numeric: NumericOp::ReduceAdd {
                    srcs: vec![bufs.scatter_slot(src, r)],
                    dst: bufs.out(r),
                    zero_dst: src == 0,
                },
                label: "rs_local_reduce",
            });
        }
        pb.prog.push(red.build());
    }
}

/// Flat survivor-indexed ReduceScatter: every logical rank pushes the
/// chunk destined for each logical peer straight to that peer's landing
/// slot (with a delivery signal), and each peer reduces survivor slots
/// incrementally as they arrive. This is the **degraded-world re-plan
/// path** of the elastic recovery controller (the ReduceScatter twin of
/// [`ag_flat_on`](crate::collectives::allgather::ag_flat_on)): unlike
/// [`rs_inter`] it assumes nothing about the node grid being
/// rectangular, so it stays valid on any survivor set after rank or
/// node death. Landing slots and signals are indexed by *physical*
/// rank, so dead ranks' slots are simply never written — allocate via
/// [`RsBufs::alloc_flat`], which sizes the scatter area at one slot per
/// physical rank. Non-overlapped and rail-striped only — the price of
/// generality; the overlapped builders remain the fault-free fast path.
///
/// `producer_sig`: if `Some(base)`, the chunk destined for physical
/// rank `pm` may only be pushed after local signal `base + pm` is set
/// (the producer-GEMM linkage of degraded GEMM+RS); `None` treats
/// inputs as ready.
pub fn rs_flat_on(
    ctx: &ShmemCtx,
    bufs: &RsBufs,
    pb: &mut ProgBuild,
    view: &crate::collectives::WorldView,
    reduce_sms: u32,
    producer_sig: Option<usize>,
) {
    let ws = view.world();
    pb.claim_sigs("rs_flat", bufs.sig_base, ctx.n_pes());
    for l in 0..ws {
        let pr = view.phys(l);
        assert!(pr < ctx.n_pes(), "view physical rank out of range");

        // Stream 1: push each survivor peer's chunk to its landing slot
        // (shifted walk, own chunk last; inter-node pieces rail-striped).
        let mut scat = ctx
            .task(pr, format!("rs_flat_scatter[{l}]"))
            .on_copy_engine()
            .launch_overhead();
        let mut inter_idx = 0usize;
        for i in 0..ws {
            let m = (l + 1 + i) % ws;
            let pm = view.phys(m);
            if let Some(base) = producer_sig {
                scat.signal_wait_until(base + pm, SigCond::Eq, 1);
            }
            if ctx.node_of(pm) != ctx.node_of(pr) {
                scat.stripe_rail(inter_idx);
                inter_idx += 1;
            }
            scat.putmem_signal(
                bufs.in_chunk(pm, pr),
                bufs.scatter_slot(pr, pm),
                bufs.scatter_sig(pr),
                SigOp::Set,
                1,
            );
        }
        pb.prog.push(scat.build());

        // Stream 2: local reduction over survivor slots, incremental as
        // they arrive (survivor walk order for determinism).
        let mut red = ctx
            .task(pr, format!("rs_flat_reduce[{l}]"))
            .with_sms(reduce_sms)
            .launch_overhead();
        for src in 0..ws {
            let ps = view.phys(src);
            red.signal_wait_until(bufs.scatter_sig(ps), SigCond::Eq, 1);
            red.op(Op::Compute {
                cost: ComputeCost::Reduce {
                    bytes: ctx.bytes(bufs.shard) as f64 * 2.0,
                },
                numeric: NumericOp::ReduceAdd {
                    srcs: vec![bufs.scatter_slot(ps, pr)],
                    dst: bufs.out(pr),
                    zero_dst: src == 0,
                },
                label: "rs_flat_reduce",
            });
        }
        pb.prog.push(red.build());
    }
}

/// §3.6 — AMD fused-scatter ReduceScatter: the *producer* stores each
/// output tile directly to the destination rank (fused into the producer
/// kernel to avoid hipStreamWriteValue interference), then a barrier and
/// a local reduction produce the final output. Communication tiling
/// (`comm_tiles` sub-chunks per chunk) is decoupled from compute tiling
/// so all mesh links are used.
pub fn rs_fused_amd(
    ctx: &ShmemCtx,
    bufs: &RsBufs,
    pb: &mut ProgBuild,
    comm_tiles: usize,
    reduce_sms: u32,
    producer_sig: Option<usize>,
) {
    let ws = ctx.n_pes();
    assert_eq!(ctx.n_nodes(), 1);
    assert!(comm_tiles >= 1 && bufs.shard % comm_tiles == 0);
    let sub = bufs.shard / comm_tiles;
    let bid = pb.fresh_barrier();
    // participants: ws store streams + 1 reduce task per rank
    let expect = ws * (ws + 1);

    for r in 0..ws {
        // fused scatter: producer stores tiles remotely as they complete;
        // one task per destination so all 7 mesh links run concurrently.
        for i in 0..ws {
            let dst = (r + 1 + i) % ws;
            // fused into the producer's epilogue: stores issue from the
            // producer's own CUs (no extra reservation, §3.6)
            let mut t = ctx
                .task(r, format!("rs_fused_store[{r}->{dst}]"))
                .on_copy_engine()
                .launch_overhead();
            if let Some(base) = producer_sig {
                t.signal_wait_until(base + dst, SigCond::Eq, 1);
            }
            for s in 0..comm_tiles {
                t.putmem_nbi(
                    bufs.in_chunk(dst, r).sub(s * sub, sub),
                    bufs.scatter_slot(r, dst).sub(s * sub, sub),
                );
            }
            t.quiet();
            t.barrier_group(bid, Scope::World, expect);
            pb.prog.push(t.build());
        }

        // reduction after the barrier
        let mut red = ctx
            .task(r, format!("rs_reduce[{r}]"))
            .with_sms(reduce_sms)
            .launch_overhead();
        red.barrier_group(bid, Scope::World, expect);
        red.op(Op::Compute {
            cost: ComputeCost::Reduce {
                bytes: ctx.bytes(bufs.shard) as f64 * ws as f64,
            },
            numeric: NumericOp::ReduceAdd {
                srcs: (0..ws).map(|s| bufs.scatter_slot(s, r)).collect(),
                dst: bufs.out(r),
                zero_dst: true,
            },
            label: "rs_reduce_all",
        });
        pb.prog.push(red.build());
    }
}

/// Alg. 5 + Fig. 10 — inter-node ReduceScatter with heterogeneous
/// communication: intra-node scatter on the copy engine, local reduction
/// on a small SM budget, inter-node P2P on one SM, final reduction on the
/// full device. The §3.5 balance: scatter moves `(lws-1)/lws` of the data
/// at NVLink bandwidth while P2P moves `1/n_nodes` at NIC bandwidth, so
/// the reduction only needs ~470 GB/s => ~15 SMs on H800.
///
/// Buffer roles (see [`RsBufs`]):
///   input[dst chunk] -> scatter_slot[src local rank] (intra-node, per iter)
///   reduce(scatter slots) -> partial_slot[src node]  (P2P inter-node)
///   reduce(partial slots) -> out
///
/// Iterations walk target nodes other-nodes-first (Fig. 10 shift) so the
/// NIC sends start as early as possible; scatter slots are recycled per
/// iteration behind a node-scoped barrier joined by all three streams.
pub fn rs_inter(
    ctx: &ShmemCtx,
    bufs: &RsBufs,
    pb: &mut ProgBuild,
    reduce1_sms: u32,
    reduce2_sms: u32,
    producer_sig: Option<usize>,
) {
    let ws = ctx.n_pes();
    let lws = ctx.local_world_size();
    let n_nodes = ctx.n_nodes();
    assert!(n_nodes > 1, "rs_inter requires multiple nodes");
    // footprint: scatter sigs [0, lws), partial sigs [lws, lws+n), stage
    // sigs [lws+n, lws+2n)
    pb.claim_sigs("rs_inter", bufs.sig_base, lws + 2 * n_nodes);

    // one barrier id per iteration; joined by scatter + reduce + p2p of
    // every rank in the node (3 tasks per rank)
    let iter_bids: Vec<usize> = (0..n_nodes).map(|_| pb.fresh_barrier()).collect();
    let iter_expect = 3 * lws;

    for r in 0..ws {
        let node = ctx.node_of(r);
        let lr = ctx.local_rank_of(r);
        let scope = Scope::Node(node);

        // -- Stream 0: intra-node scatter (copy engine).
        let mut scat = ctx
            .task(r, format!("rs_scatter[{r}]"))
            .on_copy_engine()
            .launch_overhead();
        // -- Stream 1a: per-iteration local reduction (small SM budget).
        let mut red = ctx
            .task(r, format!("rs_reduce1[{r}]"))
            .with_sms(reduce1_sms)
            .launch_overhead();
        // -- Stream 1b: inter-node P2P (1 SM).
        let mut p2p = ctx
            .task(r, format!("rs_p2p[{r}]"))
            .with_sms(1)
            .launch_overhead();

        for it in 0..n_nodes {
            let tn = (node + 1 + it) % n_nodes; // other nodes first (Fig. 10)

            // scatter: chunk destined for (tn, tlr) lands on node peer tlr,
            // slot indexed by the *source* local rank; local copy last.
            for j in 0..lws {
                let tlr = (lr + 1 + j) % lws;
                let dst_global = tn * lws + tlr;
                let land_on = node * lws + tlr;
                if let Some(base) = producer_sig {
                    // gate on the producer GEMM finishing this chunk
                    scat.signal_wait_until(base + dst_global, SigCond::Eq, 1);
                }
                scat.putmem_signal(
                    bufs.in_chunk(dst_global, r),
                    bufs.scatter_slot(lr, land_on),
                    bufs.scatter_sig(lr),
                    SigOp::Set,
                    (it + 1) as u64,
                );
            }
            scat.barrier_group(iter_bids[it], scope, iter_expect);

            // reduce: wait all lws slots of this iteration, then reduce
            // into the partial for *this* node's contribution.
            for s in 0..lws {
                red.signal_wait_until(bufs.scatter_sig(s), SigCond::Ge, (it + 1) as u64);
            }
            red.op(Op::Compute {
                cost: ComputeCost::Reduce {
                    bytes: ctx.bytes(bufs.shard) as f64 * lws as f64,
                },
                numeric: NumericOp::ReduceAdd {
                    srcs: (0..lws).map(|s| bufs.scatter_slot(s, r)).collect(),
                    dst: if tn == node {
                        bufs.partial_slot(node, r)
                    } else {
                        bufs.stage_slot(tn, r) // staging for the send to node tn
                    },
                    zero_dst: true,
                },
                label: "rs_reduce_node",
            });
            if tn == node {
                // own-node partial is final in place
                red.notify(r, bufs.partial_sig(node, lws), SigOp::Set, 1);
            } else {
                // hand the staged partial to the P2P stream
                red.notify(r, bufs.stage_sig(tn, lws, n_nodes), SigOp::Set, 1);
            }
            red.barrier_group(iter_bids[it], scope, iter_expect);

            // p2p: ship the staged partial to the peer rank of node tn;
            // delivery sets the *arrival* signal for this sender's node.
            // Iterations stripe across NIC rails (round-robin, or
            // adaptively) so the serialized P2P stream still exercises
            // every plane.
            if tn != node {
                let target = tn * lws + lr;
                p2p.stripe_rail(it);
                // gating piece: the staged partial releases the target
                // node's final cross-node reduction; remaining counts the
                // iterations of this serialized P2P stream still to ship
                p2p.chunk_meta((n_nodes - 1 - it) as f64 * ctx.bytes(bufs.shard), 0);
                p2p.signal_wait_until(bufs.stage_sig(tn, lws, n_nodes), SigCond::Ge, 1);
                p2p.putmem_signal(
                    bufs.stage_slot(tn, r),
                    bufs.partial_slot(node, target),
                    bufs.partial_sig(node, lws),
                    SigOp::Set,
                    1,
                );
            }
            p2p.barrier_group(iter_bids[it], scope, iter_expect);
        }
        pb.prog.push(scat.build());
        pb.prog.push(red.build());
        pb.prog.push(p2p.build());

        // -- Final: all partials present, reduce across nodes (132 SMs).
        let mut fin = ctx
            .task(r, format!("rs_reduce2[{r}]"))
            .with_sms(reduce2_sms)
            .launch_overhead();
        for n in 0..n_nodes {
            fin.signal_wait_until(bufs.partial_sig(n, lws), SigCond::Eq, 1);
        }
        fin.op(Op::Compute {
            cost: ComputeCost::Reduce {
                bytes: ctx.bytes(bufs.shard) as f64 * n_nodes as f64,
            },
            numeric: NumericOp::ReduceAdd {
                srcs: (0..n_nodes).map(|n| bufs.partial_slot(n, r)).collect(),
                dst: bufs.out(r),
                zero_dst: true,
            },
            label: "rs_reduce_final",
        });
        pb.prog.push(fin.build());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{expected_reduce_scatter, fill_rs_inputs, verify_reduce_scatter};
    use crate::config::{ClusterSpec, DType};
    use crate::mem::SymmetricHeap;
    use crate::sim::{NoopExecutor, Sim};
    use crate::topology::Topology;

    fn run_rs(
        cluster: ClusterSpec,
        shard: usize,
        build: impl Fn(&ShmemCtx, &RsBufs, &mut ProgBuild),
    ) -> f64 {
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let mut heap = SymmetricHeap::new(ctx.n_pes(), 8 * ctx.n_pes().max(16));
        let bufs = RsBufs::alloc(&mut heap, &ctx, shard);
        fill_rs_inputs(&mut heap, &bufs, 3);
        let expected = expected_reduce_scatter(&heap, &bufs);
        let mut pb = ProgBuild::new();
        build(&ctx, &bufs, &mut pb);
        let sim = Sim::new(&topo);
        let rep = sim.run(&pb.prog, &mut heap, &mut NoopExecutor).unwrap();
        verify_reduce_scatter(&heap, &bufs, &expected).unwrap();
        rep.makespan
    }

    #[test]
    fn push_intra_reduces() {
        run_rs(ClusterSpec::h800(1, 8), 64, |c, b, p| {
            rs_push_intra(c, b, p, 15, None)
        });
    }

    #[test]
    fn push_intra_two_ranks() {
        run_rs(ClusterSpec::h800(1, 2), 16, |c, b, p| {
            rs_push_intra(c, b, p, 15, None)
        });
    }

    #[test]
    fn fused_amd_reduces() {
        run_rs(ClusterSpec::mi308x(8), 64, |c, b, p| {
            rs_fused_amd(c, b, p, 4, 16, None)
        });
    }

    #[test]
    fn inter_node_reduces() {
        run_rs(ClusterSpec::h800(2, 4), 32, |c, b, p| {
            rs_inter(c, b, p, 15, 120, None)
        });
    }

    #[test]
    fn inter_node_reduces_4x4() {
        run_rs(ClusterSpec::h800(4, 4), 16, |c, b, p| {
            rs_inter(c, b, p, 15, 120, None)
        });
    }

    #[test]
    fn flat_identity_reduces() {
        // full-world view: rs_flat_on must produce the same reduction as
        // any other variant (flat alloc, physical-rank landing slots)
        let cluster = ClusterSpec::h800(2, 4);
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let mut heap = SymmetricHeap::new(ctx.n_pes(), 8 * ctx.n_pes().max(16));
        let bufs = RsBufs::alloc_flat(&mut heap, &ctx, 16);
        fill_rs_inputs(&mut heap, &bufs, 5);
        let expected = expected_reduce_scatter(&heap, &bufs);
        let mut pb = ProgBuild::new();
        let view = crate::collectives::WorldView::identity(ctx.n_pes());
        rs_flat_on(&ctx, &bufs, &mut pb, &view, 15, None);
        let sim = Sim::new(&topo);
        sim.run(&pb.prog, &mut heap, &mut NoopExecutor).unwrap();
        verify_reduce_scatter(&heap, &bufs, &expected).unwrap();
    }

    #[test]
    fn flat_survivors_reduce_over_survivors_only() {
        // degraded world: each survivor's output is the sum over the
        // SURVIVING sources only; the dead rank's chunk is gone with it
        let cluster = ClusterSpec::h800(2, 4);
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let ws = ctx.n_pes();
        let shard = 16usize;
        let mut heap = SymmetricHeap::new(ws, 8 * ws.max(16));
        let bufs = RsBufs::alloc_flat(&mut heap, &ctx, shard);
        fill_rs_inputs(&mut heap, &bufs, 7);
        let view = crate::collectives::WorldView::survivors(ws, &[3]);
        let mut pb = ProgBuild::new();
        rs_flat_on(&ctx, &bufs, &mut pb, &view, 15, None);
        let sim = Sim::new(&topo);
        sim.run(&pb.prog, &mut heap, &mut NoopExecutor).unwrap();
        for l in 0..view.world() {
            let pr = view.phys(l);
            let mut exp = vec![0.0f32; shard];
            for s in 0..view.world() {
                let ps = view.phys(s);
                for (a, v) in exp.iter_mut().zip(heap.read(bufs.in_chunk(pr, ps))) {
                    *a += v;
                }
            }
            let got = heap.read(bufs.out(pr));
            for (i, (g, e)) in got.iter().zip(exp.iter()).enumerate() {
                let tol = 1e-4f32.max(e.abs() * 1e-5);
                assert!(
                    (g - e).abs() <= tol,
                    "survivor {pr} element {i}: got {g} want {e}"
                );
            }
        }
    }

    #[test]
    fn producer_gated_scatter_waits() {
        // With a producer signal that is set late by a helper task, the
        // result must still be correct (scatter waits for production).
        let cluster = ClusterSpec::h800(1, 4);
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let mut heap = SymmetricHeap::new(4, 64);
        let bufs = RsBufs::alloc(&mut heap, &ctx, 8);
        fill_rs_inputs(&mut heap, &bufs, 11);
        let expected = expected_reduce_scatter(&heap, &bufs);
        let mut pb = ProgBuild::new();
        let base = 32; // producer signal base
        rs_push_intra(&ctx, &bufs, &mut pb, 15, Some(base));
        // producer: sets chunk-ready signals after simulated compute time
        for r in 0..4 {
            let mut prod = ctx.task(r, format!("producer[{r}]")).with_sms(64);
            for dst in 0..4 {
                prod.op(crate::program::Op::Sleep { secs: 2e-6 });
                prod.notify(r, base + dst, SigOp::Set, 1);
            }
            pb.prog.push(prod.build());
        }
        let sim = Sim::new(&topo);
        sim.run(&pb.prog, &mut heap, &mut NoopExecutor).unwrap();
        verify_reduce_scatter(&heap, &bufs, &expected).unwrap();
    }
}
