//! One-sided collective kernels (§3.2–§3.6) and their baselines.
//!
//! Each collective is expressed as *programs*: per-rank async-tasks built
//! from the Table-1 primitives, exactly mirroring the paper's pseudo-code
//! (Algorithms 1–5). The same program runs in timing mode (benches) and in
//! numeric mode (tests verify AG = concat, RS = reduce, A2A round-trip).

pub mod allgather;
pub mod allreduce;
pub mod alltoall;
pub mod baseline;
pub mod reduce_scatter;

use crate::mem::{BufId, Slice, SymmetricHeap};
use crate::program::Program;
use crate::shmem::ShmemCtx;
use crate::util::Rng;

/// A program under construction plus collision-free barrier-id allocation
/// and a signal-range collision audit.
pub struct ProgBuild {
    pub prog: Program,
    next_barrier: usize,
    /// Claimed signal-id ranges `[start, end)` with the claiming builder's
    /// name. Signal ids live in one flat per-rank pad, so two collectives
    /// composed on the same heap alias each other's synchronization if
    /// their ranges overlap — a silent-corruption class of bug (a stray
    /// `Set` satisfies someone else's wait). Builders declare their
    /// footprint via [`Self::claim_sigs`], which panics on overlap.
    sig_claims: Vec<(usize, usize, &'static str)>,
}

impl Default for ProgBuild {
    fn default() -> Self {
        Self::new()
    }
}

impl ProgBuild {
    pub fn new() -> Self {
        ProgBuild {
            prog: Program::new(),
            next_barrier: 0,
            sig_claims: Vec::new(),
        }
    }

    /// A barrier id no other call site got. One id per *collective use*:
    /// all ranks participating in the same barrier must share the id, so
    /// builders take ids from here once and reuse across their ranks.
    pub fn fresh_barrier(&mut self) -> usize {
        self.next_barrier += 1;
        self.next_barrier - 1
    }

    /// Declare that `who` owns the signal ids `[base, base + count)` on
    /// this program's heap. Panics if the range collides with one claimed
    /// earlier — the latent aliasing hazard when a coordinator composes
    /// multiple collectives (each with its own `sig_base`) on one heap.
    pub fn claim_sigs(&mut self, who: &'static str, base: usize, count: usize) {
        if count == 0 {
            return;
        }
        let end = base + count;
        for &(b, e, w) in &self.sig_claims {
            assert!(
                end <= b || e <= base,
                "signal-id range collision: {who} claims [{base}, {end}) but \
                 {w} already owns [{b}, {e}) on this heap"
            );
        }
        self.sig_claims.push((base, end, who));
    }
}

/// Logical→physical rank map for **survivor-indexed** program builders
/// (elastic degraded-world recovery): a program is constructed over a
/// dense *logical* world `0..world()` whose rank `l` is placed on
/// physical rank `phys(l)` of the original (possibly larger) cluster.
///
/// The identity view is the normal case — every view-threaded builder
/// called with [`WorldView::identity`] emits a program bit-identical to
/// its un-viewed form (`phys(l) == l` makes every re-homing a no-op).
/// After a permanent rank/node death the recovery controller builds a
/// [`WorldView::survivors`] view and re-plans the collective over it:
/// tasks, slices, and signals land only on surviving physical ranks, on
/// the *original* topology and heap (dead ranks keep their heap space
/// but are never addressed).
///
/// Logical indices drive program *structure* (size tables, signal ids,
/// shifted send walks); physical ranks drive *placement* (task homes,
/// slice ranks, rail planes).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorldView {
    phys: Vec<usize>,
}

impl WorldView {
    /// The trivial view: logical rank `l` is physical rank `l`.
    pub fn identity(world: usize) -> Self {
        WorldView {
            phys: (0..world).collect(),
        }
    }

    /// Survivor view over a `world`-sized cluster: logical ranks are the
    /// physical ranks **not** listed in `dead`, in ascending order.
    /// Panics if nobody survives — an unrecoverable plan is the caller's
    /// error to surface, not a silent empty program.
    pub fn survivors(world: usize, dead: &[usize]) -> Self {
        let phys: Vec<usize> = (0..world).filter(|r| !dead.contains(r)).collect();
        assert!(!phys.is_empty(), "no survivors: cannot build a world view");
        WorldView { phys }
    }

    /// Logical world size (number of participating ranks).
    pub fn world(&self) -> usize {
        self.phys.len()
    }

    /// Physical rank hosting logical rank `l`.
    pub fn phys(&self, l: usize) -> usize {
        self.phys[l]
    }

    /// Logical index of physical rank `p`, `None` if `p` is not in the
    /// view (dead, or outside the original world).
    pub fn logical(&self, p: usize) -> Option<usize> {
        self.phys.iter().position(|&q| q == p)
    }

    /// True when `phys(l) == l` for every logical rank (the bit-identity
    /// fast path).
    pub fn is_identity(&self) -> bool {
        self.phys.iter().enumerate().all(|(l, &p)| l == p)
    }
}

/// Upper bound of the signal footprint any ReduceScatter variant claims
/// above [`RsBufs::sig_base`]: the intra scatter claims `ws`
/// (`rs_push_intra`), `rs_inter` claims `lws + 2 * n_nodes`, and the
/// NCCL ring baseline claims 8 signals per channel (at most
/// `baseline::MAX_RING_CHANNELS`). Coordinators that gate a
/// ReduceScatter on producer signals place their range at or above
/// `rs.sig_base + rs_sig_span(ctx)`.
pub fn rs_sig_span(ctx: &ShmemCtx) -> usize {
    ctx.n_pes()
        .max(ctx.local_world_size() + 2 * ctx.n_nodes())
        .max(8 * baseline::MAX_RING_CHANNELS)
}

/// AllGather working set: symmetric buffer of `world * shard` elements;
/// rank `r`'s own shard lives at offset `r * shard`. Signal `sig_base + s`
/// on rank `r` means "segment `s` has arrived at rank `r`".
#[derive(Debug, Clone, Copy)]
pub struct AgBufs {
    pub data: BufId,
    /// Elements per rank shard.
    pub shard: usize,
    pub sig_base: usize,
    /// LL staging buffer (2x data for flags), used by the LL variants.
    pub ll: Option<BufId>,
}

impl AgBufs {
    pub fn alloc(heap: &mut SymmetricHeap, ctx: &ShmemCtx, shard: usize) -> Self {
        let data = heap.alloc("ag_data", ctx.n_pes() * shard);
        AgBufs {
            data,
            shard,
            sig_base: 0,
            ll: None,
        }
    }

    pub fn alloc_ll(heap: &mut SymmetricHeap, ctx: &ShmemCtx, shard: usize) -> Self {
        let data = heap.alloc("ag_data", ctx.n_pes() * shard);
        let ll = heap.alloc("ag_ll", ctx.n_pes() * shard); // flags modeled via 2x wire size
        AgBufs {
            data,
            shard,
            sig_base: 0,
            ll: Some(ll),
        }
    }

    /// Segment `seg` (the shard owned by rank `seg`) as seen on `on_rank`.
    pub fn seg(&self, seg: usize, on_rank: usize) -> Slice {
        Slice::new(on_rank, self.data, seg * self.shard, self.shard)
    }

    /// LL-staging slot for segment `seg` on `on_rank`.
    pub fn ll_seg(&self, seg: usize, on_rank: usize) -> Slice {
        Slice::new(
            on_rank,
            self.ll.expect("no LL buffer allocated"),
            seg * self.shard,
            self.shard,
        )
    }

    /// Signal index announcing segment `seg`.
    pub fn sig(&self, seg: usize) -> usize {
        self.sig_base + seg
    }
}

/// Fill every rank's own shard with seeded data (distinct across ranks).
pub fn fill_ag_inputs(heap: &mut SymmetricHeap, bufs: &AgBufs, seed: u64) {
    let ws = heap.world();
    for r in 0..ws {
        let mut rng = Rng::new(seed ^ (r as u64).wrapping_mul(0x9E37));
        let data = rng.normal_vec(bufs.shard);
        heap.write(bufs.seg(r, r), &data);
    }
}

/// Reference AllGather result: the concatenation of every rank's shard.
pub fn expected_allgather(heap: &SymmetricHeap, bufs: &AgBufs) -> Vec<f32> {
    let ws = heap.world();
    let mut out = Vec::with_capacity(ws * bufs.shard);
    for s in 0..ws {
        out.extend_from_slice(heap.read(bufs.seg(s, s)));
    }
    out
}

/// Check every rank holds the full gathered buffer.
pub fn verify_allgather(
    heap: &SymmetricHeap,
    bufs: &AgBufs,
    expected: &[f32],
) -> Result<(), String> {
    let ws = heap.world();
    for r in 0..ws {
        let got = heap.read(Slice::new(r, bufs.data, 0, ws * bufs.shard));
        if got != expected {
            let first_bad = got
                .iter()
                .zip(expected.iter())
                .position(|(a, b)| a != b)
                .unwrap_or(0);
            return Err(format!(
                "allgather mismatch on rank {r} (first diff at element {first_bad}: \
                 got {} want {})",
                got[first_bad], expected[first_bad]
            ));
        }
    }
    Ok(())
}

/// ReduceScatter working set: each rank's *input* is `world * shard`
/// elements (one chunk per destination); the output is `shard` elements
/// (the sum over all ranks of the chunk addressed to this rank).
#[derive(Debug, Clone, Copy)]
pub struct RsBufs {
    /// Per-rank input, `world * shard` elements.
    pub input: BufId,
    /// Intra-node scatter landing area, `local_world * shard`.
    pub scatter: BufId,
    /// Inter-node partial landing area, `nodes * shard`.
    pub partial: BufId,
    /// Final output, `shard` elements.
    pub output: BufId,
    pub shard: usize,
    pub sig_base: usize,
    /// Node count at alloc time (sizes the partial landing/staging areas).
    pub n_nodes: usize,
}

impl RsBufs {
    pub fn alloc(heap: &mut SymmetricHeap, ctx: &ShmemCtx, shard: usize) -> Self {
        let ws = ctx.n_pes();
        RsBufs {
            input: heap.alloc("rs_input", ws * shard),
            scatter: heap.alloc("rs_scatter", ctx.local_world_size() * shard),
            // first n_nodes slots: landing area for incoming partials;
            // second n_nodes slots: staging area for outgoing partials
            // (disjoint so an incoming transfer never races a staging
            // reduction for the same peer node)
            partial: heap.alloc("rs_partial", 2 * ctx.n_nodes() * shard),
            output: heap.alloc("rs_output", shard),
            shard,
            sig_base: 0,
            n_nodes: ctx.n_nodes(),
        }
    }

    /// Like [`RsBufs::alloc`], but sizes the scatter landing area at one
    /// slot per rank of the *full world* (physical-rank indexed) so the
    /// flat survivor ReduceScatter
    /// ([`reduce_scatter::rs_flat_on`](crate::collectives::reduce_scatter::rs_flat_on))
    /// can land a chunk from any surviving source; dead ranks' slots are
    /// simply never written.
    pub fn alloc_flat(heap: &mut SymmetricHeap, ctx: &ShmemCtx, shard: usize) -> Self {
        let ws = ctx.n_pes();
        RsBufs {
            input: heap.alloc("rs_input", ws * shard),
            scatter: heap.alloc("rs_scatter", ws * shard),
            partial: heap.alloc("rs_partial", 2 * ctx.n_nodes() * shard),
            output: heap.alloc("rs_output", shard),
            shard,
            sig_base: 0,
            n_nodes: ctx.n_nodes(),
        }
    }

    /// Input chunk destined for rank `dst`, on rank `on`.
    pub fn in_chunk(&self, dst: usize, on: usize) -> Slice {
        Slice::new(on, self.input, dst * self.shard, self.shard)
    }

    /// Scatter slot for source local-rank `slot` on rank `on`.
    pub fn scatter_slot(&self, slot: usize, on: usize) -> Slice {
        Slice::new(on, self.scatter, slot * self.shard, self.shard)
    }

    /// Landing slot for the partial from source node `n` on rank `on`.
    pub fn partial_slot(&self, n: usize, on: usize) -> Slice {
        Slice::new(on, self.partial, n * self.shard, self.shard)
    }

    /// Staging slot for the outgoing partial destined to node `n`
    /// (disjoint from the landing area). Requires alloc'ing via
    /// [`RsBufs::alloc`], which sizes `partial` at `2 * n_nodes` slots.
    pub fn stage_slot(&self, n: usize, on: usize) -> Slice {
        Slice::new(on, self.partial, (self.n_nodes + n) * self.shard, self.shard)
    }

    pub fn out(&self, on: usize) -> Slice {
        Slice::new(on, self.output, 0, self.shard)
    }

    /// Signal: arrival of scatter slot `slot` on the destination.
    pub fn scatter_sig(&self, slot: usize) -> usize {
        self.sig_base + slot
    }

    /// Signal: arrival of the inter-node partial from node `n` (ready for
    /// the final reduction).
    pub fn partial_sig(&self, n: usize, lws: usize) -> usize {
        self.sig_base + lws + n
    }

    /// Signal: the staged partial destined for node `n` is reduced and
    /// ready for the P2P stream to ship (rs_inter handoff).
    pub fn stage_sig(&self, n: usize, lws: usize, n_nodes: usize) -> usize {
        self.sig_base + lws + n_nodes + n
    }
}

/// Seed every rank's RS input chunks.
pub fn fill_rs_inputs(heap: &mut SymmetricHeap, bufs: &RsBufs, seed: u64) {
    let ws = heap.world();
    for r in 0..ws {
        let mut rng = Rng::new(seed ^ (r as u64).wrapping_mul(0x51ED));
        let data = rng.normal_vec(ws * bufs.shard);
        heap.write(Slice::new(r, bufs.input, 0, ws * bufs.shard), &data);
    }
}

/// Reference ReduceScatter: output of rank `r` = sum over source ranks of
/// each source's chunk `r`.
pub fn expected_reduce_scatter(heap: &SymmetricHeap, bufs: &RsBufs) -> Vec<Vec<f32>> {
    let ws = heap.world();
    (0..ws)
        .map(|dst| {
            let mut acc = vec![0.0f32; bufs.shard];
            for src in 0..ws {
                for (a, v) in acc.iter_mut().zip(heap.read(bufs.in_chunk(dst, src))) {
                    *a += v;
                }
            }
            acc
        })
        .collect()
}

/// Compare rank outputs against the reference within fp tolerance
/// (reduction orders differ across algorithms).
pub fn verify_reduce_scatter(
    heap: &SymmetricHeap,
    bufs: &RsBufs,
    expected: &[Vec<f32>],
) -> Result<(), String> {
    for (r, exp) in expected.iter().enumerate() {
        let got = heap.read(bufs.out(r));
        for (i, (g, e)) in got.iter().zip(exp.iter()).enumerate() {
            let tol = 1e-4f32.max(e.abs() * 1e-5);
            if (g - e).abs() > tol {
                return Err(format!(
                    "reduce_scatter mismatch on rank {r} element {i}: got {g} want {e}"
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, DType};

    #[test]
    fn ag_bufs_layout() {
        let ctx = ShmemCtx::new(ClusterSpec::h800(1, 4), DType::BF16);
        let mut heap = SymmetricHeap::new(4, 16);
        let bufs = AgBufs::alloc(&mut heap, &ctx, 8);
        assert_eq!(heap.buf_len(bufs.data), 32);
        let s = bufs.seg(2, 1);
        assert_eq!((s.rank, s.off, s.len), (1, 16, 8));
        assert_eq!(bufs.sig(3), 3);
    }

    #[test]
    fn fill_and_expected_roundtrip() {
        let ctx = ShmemCtx::new(ClusterSpec::h800(1, 4), DType::BF16);
        let mut heap = SymmetricHeap::new(4, 16);
        let bufs = AgBufs::alloc(&mut heap, &ctx, 8);
        fill_ag_inputs(&mut heap, &bufs, 1);
        let exp = expected_allgather(&heap, &bufs);
        assert_eq!(exp.len(), 32);
        // shards differ across ranks
        assert_ne!(exp[0..8], exp[8..16]);
        // verification fails before the collective ran
        assert!(verify_allgather(&heap, &bufs, &exp).is_err());
    }

    #[test]
    fn rs_reference_sums_chunks() {
        let ctx = ShmemCtx::new(ClusterSpec::h800(1, 2), DType::BF16);
        let mut heap = SymmetricHeap::new(2, 16);
        let bufs = RsBufs::alloc(&mut heap, &ctx, 2);
        heap.write(Slice::new(0, bufs.input, 0, 4), &[1.0, 2.0, 3.0, 4.0]);
        heap.write(Slice::new(1, bufs.input, 0, 4), &[10.0, 20.0, 30.0, 40.0]);
        let exp = expected_reduce_scatter(&heap, &bufs);
        assert_eq!(exp[0], vec![11.0, 22.0]);
        assert_eq!(exp[1], vec![33.0, 44.0]);
    }

    #[test]
    fn barrier_ids_are_unique() {
        let mut pb = ProgBuild::new();
        let a = pb.fresh_barrier();
        let b = pb.fresh_barrier();
        assert_ne!(a, b);
    }

    #[test]
    fn disjoint_sig_claims_compose() {
        let mut pb = ProgBuild::new();
        pb.claim_sigs("ag", 0, 8);
        pb.claim_sigs("producer", 8, 4);
        pb.claim_sigs("empty", 100, 0); // zero-width claims are free
        pb.claim_sigs("above", 12, 1); // adjacent ranges don't collide
    }

    #[test]
    fn rs_sig_span_covers_every_variant() {
        // single node: the intra scatter's ws and the ring's 8/channel
        let intra = ShmemCtx::new(ClusterSpec::h800(1, 8), DType::BF16);
        assert!(rs_sig_span(&intra) >= 8);
        assert!(rs_sig_span(&intra) >= 8 * baseline::MAX_RING_CHANNELS);
        // many nodes: rs_inter's lws + 2 * n_nodes dominates
        let wide = ShmemCtx::new(ClusterSpec::h800(64, 8), DType::BF16);
        assert!(rs_sig_span(&wide) >= 8 + 2 * 64);
    }

    #[test]
    #[should_panic(expected = "signal-id range collision")]
    fn overlapping_sig_claims_panic() {
        let mut pb = ProgBuild::new();
        pb.claim_sigs("ag", 0, 8);
        pb.claim_sigs("rs", 4, 2);
    }

    #[test]
    fn world_view_identity_and_survivors() {
        let id = WorldView::identity(4);
        assert!(id.is_identity());
        assert_eq!(id.world(), 4);
        assert_eq!(id.phys(3), 3);
        assert_eq!(id.logical(2), Some(2));

        let sv = WorldView::survivors(4, &[1]);
        assert!(!sv.is_identity());
        assert_eq!(sv.world(), 3);
        assert_eq!((sv.phys(0), sv.phys(1), sv.phys(2)), (0, 2, 3));
        assert_eq!(sv.logical(1), None, "dead rank has no logical index");
        assert_eq!(sv.logical(3), Some(2));
    }

    #[test]
    #[should_panic(expected = "no survivors")]
    fn world_view_requires_survivors() {
        let _ = WorldView::survivors(2, &[0, 1]);
    }
}
