//! One-sided push AllReduce = ReduceScatter + broadcast of the reduced
//! chunks, with producer gating and per-chunk completion signals. This is
//! the collective a tensor-parallel transformer layer needs after every
//! row-sharded GEMM (attention out-proj, MLP down-proj): each rank's
//! `[T, H]` partial sums are reduced and the full tensor re-materialized
//! on every rank. Used by the end-to-end TP serving example.

use crate::mem::{BufId, Slice, SymmetricHeap};
use crate::program::{ComputeCost, NumericOp, Op, SigCond, SigOp};
use crate::shmem::ShmemCtx;

use super::ProgBuild;

/// AllReduce working set. The input is `world * shard` elements per rank
/// (chunk `c` = the rows ReduceScatter assigns to rank `c`); the result
/// buffer holds the full reduced tensor on every rank.
#[derive(Debug, Clone, Copy)]
pub struct ArBufs {
    /// Per-rank partial input, `world * shard`.
    pub input: BufId,
    /// Scatter landing area, `world * shard` (slot per source rank).
    pub scatter: BufId,
    /// Full reduced result, `world * shard`, valid on every rank.
    pub result: BufId,
    pub shard: usize,
    /// Signals: `sig_base + slot` = scatter arrivals;
    /// `sig_base + world + chunk` = reduced chunk present in `result`.
    pub sig_base: usize,
}

impl ArBufs {
    pub fn alloc(heap: &mut SymmetricHeap, ctx: &ShmemCtx, shard: usize, sig_base: usize) -> Self {
        let ws = ctx.n_pes();
        ArBufs {
            input: heap.alloc("ar_input", ws * shard),
            scatter: heap.alloc("ar_scatter", ws * shard),
            result: heap.alloc("ar_result", ws * shard),
            shard,
            sig_base,
        }
    }

    pub fn in_chunk(&self, c: usize, on: usize) -> Slice {
        Slice::new(on, self.input, c * self.shard, self.shard)
    }

    pub fn scatter_slot(&self, s: usize, on: usize) -> Slice {
        Slice::new(on, self.scatter, s * self.shard, self.shard)
    }

    pub fn result_chunk(&self, c: usize, on: usize) -> Slice {
        Slice::new(on, self.result, c * self.shard, self.shard)
    }

    pub fn scatter_sig(&self, s: usize) -> usize {
        self.sig_base + s
    }

    /// Completion: reduced chunk `c` present locally.
    pub fn done_sig(&self, c: usize, ws: usize) -> usize {
        self.sig_base + ws + c
    }
}

/// Build the AllReduce. `producer_sig`: chunk `c` of the local input is
/// ready when local signal `producer_sig + c` is set (None = ready at
/// t=0). Completion is announced per chunk through `done_sig`.
pub fn allreduce_push(
    ctx: &ShmemCtx,
    bufs: &ArBufs,
    pb: &mut ProgBuild,
    reduce_sms: u32,
    producer_sig: Option<usize>,
) {
    let ws = ctx.n_pes();
    // footprint: scatter sigs [0, ws), done sigs [ws, 2*ws)
    pb.claim_sigs("allreduce_push", bufs.sig_base, 2 * ws);
    for r in 0..ws {
        // scatter stream: push chunk c to rank c's scatter slot
        let mut scat = ctx
            .task(r, format!("ar_scatter[{r}]"))
            .on_copy_engine()
            .launch_overhead();
        for i in 0..ws {
            let dst = (r + 1 + i) % ws;
            if let Some(base) = producer_sig {
                scat.signal_wait_until(base + dst, SigCond::Ge, 1);
            }
            scat.putmem_signal(
                bufs.in_chunk(dst, r),
                bufs.scatter_slot(r, dst),
                bufs.scatter_sig(r),
                SigOp::Set,
                1,
            );
        }
        pb.prog.push(scat.build());

        // reduce + broadcast: accumulate slots, then push the reduced
        // chunk into every rank's result buffer with the done signal
        let mut red = ctx
            .task(r, format!("ar_reduce_bcast[{r}]"))
            .with_sms(reduce_sms)
            .launch_overhead();
        for s in 0..ws {
            red.signal_wait_until(bufs.scatter_sig(s), SigCond::Ge, 1);
            red.op(Op::Compute {
                cost: ComputeCost::Reduce {
                    bytes: ctx.bytes(bufs.shard) as f64 * 2.0,
                },
                numeric: NumericOp::ReduceAdd {
                    srcs: vec![bufs.scatter_slot(s, r)],
                    dst: bufs.result_chunk(r, r),
                    zero_dst: s == 0,
                },
                label: "ar_reduce",
            });
        }
        red.notify(r, bufs.done_sig(r, ws), SigOp::Set, 1);
        for i in 1..ws {
            let peer = (r + i) % ws;
            red.putmem_signal_nbi(
                bufs.result_chunk(r, r),
                bufs.result_chunk(r, peer),
                bufs.done_sig(r, ws),
                SigOp::Set,
                1,
            );
        }
        red.quiet();
        pb.prog.push(red.build());
    }
}

/// Reference: elementwise sum of all ranks' inputs.
pub fn expected_allreduce(heap: &SymmetricHeap, bufs: &ArBufs) -> Vec<f32> {
    let ws = heap.world();
    let n = ws * bufs.shard;
    let mut acc = vec![0.0f32; n];
    for r in 0..ws {
        for (a, v) in acc.iter_mut().zip(heap.read(Slice::new(r, bufs.input, 0, n))) {
            *a += v;
        }
    }
    acc
}

/// fp-tolerant check on every rank's result.
pub fn verify_allreduce(heap: &SymmetricHeap, bufs: &ArBufs, expected: &[f32]) -> Result<(), String> {
    for r in 0..heap.world() {
        let got = heap.read(Slice::new(r, bufs.result, 0, expected.len()));
        for (i, (g, e)) in got.iter().zip(expected).enumerate() {
            if (g - e).abs() > 1e-4_f32.max(e.abs() * 1e-5) {
                return Err(format!("allreduce mismatch rank {r} elem {i}: {g} vs {e}"));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, DType};
    use crate::sim::{NoopExecutor, Sim};
    use crate::topology::Topology;
    use crate::util::Rng;

    fn fill(heap: &mut SymmetricHeap, bufs: &ArBufs, seed: u64) {
        let ws = heap.world();
        for r in 0..ws {
            let mut rng = Rng::new(seed ^ (r as u64 * 31));
            let v = rng.normal_vec(ws * bufs.shard);
            heap.write(Slice::new(r, bufs.input, 0, v.len()), &v);
        }
    }

    #[test]
    fn allreduce_sums_on_every_rank() {
        for ws in [2usize, 4, 8] {
            let cluster = ClusterSpec::h800(1, ws);
            let ctx = ShmemCtx::new(cluster, DType::BF16);
            let topo = Topology::build(cluster);
            let mut heap = SymmetricHeap::new(ws, 4 * ws);
            let bufs = ArBufs::alloc(&mut heap, &ctx, 24, 0);
            fill(&mut heap, &bufs, 5);
            let expected = expected_allreduce(&heap, &bufs);
            let mut pb = ProgBuild::new();
            allreduce_push(&ctx, &bufs, &mut pb, 15, None);
            Sim::new(&topo)
                .run(&pb.prog, &mut heap, &mut NoopExecutor)
                .unwrap();
            verify_allreduce(&heap, &bufs, &expected).unwrap();
            // done signals all set
            for r in 0..ws {
                for c in 0..ws {
                    assert_eq!(heap.signal(r, bufs.done_sig(c, ws)), 1);
                }
            }
        }
    }

    #[test]
    fn producer_gated_allreduce_waits() {
        let ws = 4;
        let cluster = ClusterSpec::h800(1, ws);
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let mut heap = SymmetricHeap::new(ws, 64);
        let bufs = ArBufs::alloc(&mut heap, &ctx, 8, 0);
        fill(&mut heap, &bufs, 6);
        let expected = expected_allreduce(&heap, &bufs);
        let base = 32;
        let mut pb = ProgBuild::new();
        allreduce_push(&ctx, &bufs, &mut pb, 15, Some(base));
        for r in 0..ws {
            let mut prod = ctx.task(r, format!("prod[{r}]")).with_sms(32);
            for c in 0..ws {
                prod.op(Op::Sleep { secs: 1e-6 });
                prod.notify(r, base + c, SigOp::Set, 1);
            }
            pb.prog.push(prod.build());
        }
        Sim::new(&topo)
            .run(&pb.prog, &mut heap, &mut NoopExecutor)
            .unwrap();
        verify_allreduce(&heap, &bufs, &expected).unwrap();
    }
}
