//! Baseline collectives the paper compares against.
//!
//! * **NCCL-like ring AllGather / ReduceScatter** — the PyTorch+NCCL
//!   baseline: SM-channel kernels, operator-level synchronization (a
//!   barrier before and after), no fine-grained overlap hooks.
//! * **NVSHMEM `fcollect`-like AllGather** — one-shot nbi puts + barrier,
//!   with 32/64-bit granule overhead (Fig. 19 comparators).
//! * **NCCL in-place / out-of-place AllGather** — ring plus protocol
//!   overhead; out-of-place pays an extra local copy (Fig. 19).

use crate::program::{ComputeCost, NumericOp, Op, SigCond, SigOp};
use crate::shmem::ShmemCtx;

use super::{AgBufs, ProgBuild, RsBufs};

/// Ring AllGather with per-step signal synchronization, as NCCL's ring
/// protocol does. `sms` models the NCCL channel SM usage (blocks the
/// GEMM from using the full device while running).
pub fn nccl_allgather_ring(ctx: &ShmemCtx, bufs: &AgBufs, pb: &mut ProgBuild, sms: u32) {
    nccl_allgather_ring_done(ctx, bufs, pb, sms, None)
}

/// Hard cap on `nccl_channels`: bounds the ring baselines' signal
/// footprint (8 signals per channel for the RS ring, `ws` per channel
/// for the AG ring) so coordinators can place producer signal ranges
/// above it — see `collectives::rs_sig_span`.
pub(crate) const MAX_RING_CHANNELS: usize = 4;

/// NCCL channel count: multiple parallel rings so multi-node traffic uses
/// every NIC and full-mesh traffic uses several links — modeling NCCL's
/// multi-channel rings (a single ring would unfairly bottleneck the
/// baseline on one NIC / one mesh link).
fn nccl_channels(ctx: &ShmemCtx) -> usize {
    if ctx.n_nodes() > 1 {
        ctx.local_world_size().min(MAX_RING_CHANNELS)
    } else {
        MAX_RING_CHANNELS.min(ctx.n_pes() - 1).max(1)
    }
}

/// Position -> rank mapping of ring `c` (see `nccl_channels`): rotated
/// local ranks across nodes (distinct NIC crossing pairs), or stride
/// rings on a single node (distinct mesh links).
fn ring_perm(ctx: &ShmemCtx, c: usize) -> Vec<usize> {
    let ws = ctx.n_pes();
    let lws = ctx.local_world_size();
    if ctx.n_nodes() > 1 {
        (0..ws)
            .map(|i| (i / lws) * lws + (c + i % lws) % lws)
            .collect()
    } else {
        let mut stride = 2 * c + 1;
        if gcd(stride, ws) != 1 {
            stride = 1;
        }
        (0..ws).map(|i| (i * stride) % ws).collect()
    }
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 { a } else { gcd(b, a % b) }
}

/// Ring AllGather with an optional completion signal (`done_sig` set on
/// every rank after the exit barrier) for callers that chain work.
/// Segment-arrival signals count one increment per channel; consumers
/// should wait `Ge 1` (partial) or rely on `done_sig` (full).
pub fn nccl_allgather_ring_done(
    ctx: &ShmemCtx,
    bufs: &AgBufs,
    pb: &mut ProgBuild,
    sms: u32,
    done_sig: Option<usize>,
) {
    let ws = ctx.n_pes();
    let channels = nccl_channels(ctx).min(bufs.shard); // sub-shard must be non-empty
    // footprint: per-segment counters [0, ws), the done slot at ws, and
    // the per-channel spaces [ws + 1, ws + 1 + channels*ws)
    pb.claim_sigs("nccl_ag_ring", bufs.sig_base, ws + 1 + channels * ws);
    let enter = pb.fresh_barrier();
    let exit = pb.fresh_barrier();
    let expect = ws * channels;
    let sub = bufs.shard / channels;
    for c in 0..channels {
        let perm = ring_perm(ctx, c);
        let pos_of = {
            let mut inv = vec![0usize; ws];
            for (i, &r) in perm.iter().enumerate() {
                inv[r] = i;
            }
            inv
        };
        // channel c owns elements [c*sub, c*sub+len) of every segment
        let len = if c == channels - 1 { bufs.shard - c * sub } else { sub };
        // per-channel signal space above the per-segment ones
        let sig = |seg: usize| bufs.sig_base + ws + 1 + c * ws + seg;
        for r in 0..ws {
            let p = pos_of[r];
            let right = perm[(p + 1) % ws];
            let mut t = ctx
                .task(r, format!("nccl_ag_ring[{r}.{c}]"))
                .with_sms(sms.div_ceil(channels as u32).max(1))
                .launch_overhead();
            t.barrier_group(enter, crate::program::Scope::World, expect);
            for s in 0..ws - 1 {
                // ring positions: at step s position p forwards the segment
                // owned by position (p - s)
                let send_seg = perm[(p + ws - s) % ws];
                let recv_seg = perm[(p + ws - s - 1) % ws];
                t.putmem_signal_nbi(
                    bufs.seg(send_seg, r).sub(c * sub, len),
                    bufs.seg(send_seg, right).sub(c * sub, len),
                    sig(send_seg),
                    SigOp::Set,
                    1,
                );
                t.signal_wait_until(sig(recv_seg), SigCond::Ge, 1);
                // publish progress on the shared per-segment counter
                t.notify(r, bufs.sig(recv_seg), SigOp::Add, 1);
            }
            t.quiet();
            t.notify(r, bufs.sig(r), SigOp::Add, 1);
            t.barrier_group(exit, crate::program::Scope::World, expect);
            if let Some(d) = done_sig {
                t.notify(r, d, SigOp::Set, 1);
            }
            pb.prog.push(t.build());
        }
    }
}

/// Ring ReduceScatter (NCCL-like): partial sums travel the ring, each
/// hop adds the local contribution. Rank `r` plays ring-role `r-1` so the
/// fully-reduced chunk `r` lands on rank `r`.
///
/// Flow control matches NCCL's FIFO-credit protocol: two parity slots,
/// counting arrival signals (`Add 1`, waited with `Ge`), and explicit
/// consume-acks back to the sender before a slot is rewritten — a
/// set/reset scheme deadlocks once the ring pipeline gets deep enough.
pub fn nccl_reduce_scatter_ring(ctx: &ShmemCtx, bufs: &RsBufs, pb: &mut ProgBuild, sms: u32) {
    let ws = ctx.n_pes();
    assert!(ws >= 2);
    let channels = nccl_channels(ctx).min(bufs.shard);
    // footprint: 8-wide arr/ack block per channel
    pb.claim_sigs("nccl_rs_ring", bufs.sig_base, 8 * channels);
    let enter = pb.fresh_barrier();
    let exit = pb.fresh_barrier();
    let expect = ws * channels;
    let sub = bufs.shard / channels;
    for c in 0..channels {
        let perm = ring_perm(ctx, c);
        let mut pos_of = vec![0usize; ws];
        for (i, &rr) in perm.iter().enumerate() {
            pos_of[rr] = i;
        }
        let len = if c == channels - 1 { bufs.shard - c * sub } else { sub };
        let chunk_bytes = ctx.bytes(len);
        // per-channel signal space: arr(p) / ack(p)
        let arr = |p: usize| bufs.sig_base + 8 * c + p;
        let ack = |p: usize| bufs.sig_base + 8 * c + 2 + p;
        for r in 0..ws {
            let p = pos_of[r];
            let right = perm[(p + 1) % ws];
            let left = perm[(p + ws - 1) % ws];
            // roles are ring positions; fully-reduced chunk for rank at
            // position q is chunk perm[q]; play role q-1 so chunk r lands
            // on rank r
            let role = (p + ws - 1) % ws;
            let chunk_at = |role_pos: usize| perm[role_pos % ws];
            let mut t = ctx
                .task(r, format!("nccl_rs_ring[{r}.{c}]"))
                .with_sms(sms.div_ceil(channels as u32).max(1))
                .launch_overhead();
            t.barrier_group(enter, crate::program::Scope::World, expect);
            for s in 0..ws - 1 {
                let par = s % 2;
                let src = if s == 0 {
                    bufs.in_chunk(chunk_at(role), r).sub(c * sub, len)
                } else {
                    let pp = (s - 1) % 2;
                    let chn = chunk_at(role + ws - s);
                    t.signal_wait_until(arr(pp), SigCond::Ge, ((s - 1) / 2 + 1) as u64);
                    t.op(Op::Compute {
                        cost: ComputeCost::Reduce {
                            bytes: chunk_bytes * 2.0,
                        },
                        numeric: NumericOp::ReduceAdd {
                            srcs: vec![bufs.in_chunk(chn, r).sub(c * sub, len)],
                            dst: bufs.scatter_slot(pp, r).sub(c * sub, len),
                            zero_dst: false,
                        },
                        label: "ring_add",
                    });
                    bufs.scatter_slot(pp, r).sub(c * sub, len)
                };
                if s >= 2 {
                    t.signal_wait_until(ack(par), SigCond::Ge, (s / 2) as u64);
                }
                t.op(Op::Put {
                    src,
                    dst: bufs.scatter_slot(par, right).sub(c * sub, len),
                    bytes: chunk_bytes,
                    signal: Some((
                        crate::program::SigRef {
                            rank: right,
                            idx: arr(par),
                        },
                        SigOp::Add,
                        1,
                    )),
                    blocking: true,
                    tc: Default::default(),
                    chunk: None,
                    label: "ring_fwd",
                });
                if s > 0 {
                    t.notify(left, ack((s - 1) % 2), SigOp::Add, 1);
                }
            }
            let last_p = (ws - 2) % 2;
            t.signal_wait_until(arr(last_p), SigCond::Ge, ((ws - 2) / 2 + 1) as u64);
            t.op(Op::Compute {
                cost: ComputeCost::Reduce {
                    bytes: chunk_bytes * 2.0,
                },
                numeric: NumericOp::ReduceAdd {
                    srcs: vec![
                        bufs.scatter_slot(last_p, r).sub(c * sub, len),
                        bufs.in_chunk(r, r).sub(c * sub, len),
                    ],
                    dst: bufs.out(r).sub(c * sub, len),
                    zero_dst: true,
                },
                label: "ring_final_add",
            });
            t.notify(left, ack(last_p), SigOp::Add, 1);
            t.barrier_group(exit, crate::program::Scope::World, expect);
            pb.prog.push(t.build());
        }
    }
}

/// NVSHMEM `fcollect`-like AllGather: every rank nbi-puts its shard to all
/// peers at once, bracketed by barriers. `granule_overhead` models the
/// per-put protocol cost difference between the 32-bit and 64-bit
/// datatype paths (Fig. 19's NVSHMEM-32bit vs NVSHMEM-64bit).
pub fn nvshmem_fcollect(
    ctx: &ShmemCtx,
    bufs: &AgBufs,
    pb: &mut ProgBuild,
    granule_overhead: f64,
) {
    let ws = ctx.n_pes();
    pb.claim_sigs("nvshmem_fcollect", bufs.sig_base, ws);
    let enter = pb.fresh_barrier();
    let exit = pb.fresh_barrier();
    for r in 0..ws {
        let mut t = ctx
            .task(r, format!("fcollect[{r}]"))
            .with_sms(1)
            .launch_overhead();
        t.barrier_all(enter);
        t.notify(r, bufs.sig(r), SigOp::Set, 1);
        for i in 1..ws {
            let peer = (r + i) % ws;
            t.op(Op::Sleep {
                secs: granule_overhead,
            });
            t.putmem_nbi(bufs.seg(r, r), bufs.seg(r, peer));
        }
        t.quiet();
        t.barrier_all(exit);
        // fcollect gives no per-segment signals; publish all at the end
        for s in 0..ws {
            t.notify(r, bufs.sig(s), SigOp::Set, 1);
        }
        pb.prog.push(t.build());
    }
}

/// NCCL AllGather as launched by PyTorch (Fig. 19): ring + protocol
/// launch cost; `out_of_place` adds the result copy NCCL performs when
/// the user buffer differs from the communication buffer.
pub fn nccl_allgather_smallmsg(
    ctx: &ShmemCtx,
    bufs: &AgBufs,
    pb: &mut ProgBuild,
    out_of_place: bool,
) {
    let ws = ctx.n_pes();
    let done = bufs.sig_base + ws; // past the per-segment signals
    nccl_allgather_ring_done(ctx, bufs, pb, 16, out_of_place.then_some(done));
    if out_of_place {
        for r in 0..ws {
            let mut t = ctx
                .task(r, format!("nccl_oop_copy[{r}]"))
                .on_copy_engine()
                .start_delay(ctx.cluster.hw.launch_overhead * 2.0);
            t.signal_wait_until(done, SigCond::Ge, 1);
            // local copy of the whole gathered buffer
            let whole = crate::mem::Slice::new(r, bufs.data, 0, ws * bufs.shard);
            t.copy_local(whole, whole);
            pb.prog.push(t.build());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{
        expected_allgather, expected_reduce_scatter, fill_ag_inputs, fill_rs_inputs,
        verify_allgather, verify_reduce_scatter,
    };
    use crate::config::{ClusterSpec, DType};
    use crate::mem::SymmetricHeap;
    use crate::sim::{NoopExecutor, Sim};
    use crate::topology::Topology;

    #[test]
    fn ring_allgather_correct() {
        for ws in [2usize, 4, 8] {
            let cluster = ClusterSpec::h800(1, ws);
            let ctx = ShmemCtx::new(cluster, DType::BF16);
            let topo = Topology::build(cluster);
            let mut heap = SymmetricHeap::new(ws, 4 * ws.max(8));
            let bufs = AgBufs::alloc(&mut heap, &ctx, 16);
            fill_ag_inputs(&mut heap, &bufs, 2);
            let expected = expected_allgather(&heap, &bufs);
            let mut pb = ProgBuild::new();
            nccl_allgather_ring(&ctx, &bufs, &mut pb, 16);
            Sim::new(&topo)
                .run(&pb.prog, &mut heap, &mut NoopExecutor)
                .unwrap();
            verify_allgather(&heap, &bufs, &expected).unwrap();
        }
    }

    #[test]
    fn ring_allgather_inter_node_correct() {
        let cluster = ClusterSpec::h800(2, 4);
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let mut heap = SymmetricHeap::new(8, 32);
        let bufs = AgBufs::alloc(&mut heap, &ctx, 16);
        fill_ag_inputs(&mut heap, &bufs, 4);
        let expected = expected_allgather(&heap, &bufs);
        let mut pb = ProgBuild::new();
        nccl_allgather_ring(&ctx, &bufs, &mut pb, 16);
        Sim::new(&topo)
            .run(&pb.prog, &mut heap, &mut NoopExecutor)
            .unwrap();
        verify_allgather(&heap, &bufs, &expected).unwrap();
    }

    #[test]
    fn ring_reduce_scatter_correct() {
        for ws in [2usize, 4, 8] {
            let cluster = ClusterSpec::h800(1, ws);
            let ctx = ShmemCtx::new(cluster, DType::BF16);
            let topo = Topology::build(cluster);
            let mut heap = SymmetricHeap::new(ws, 4 * ws.max(8));
            let bufs = RsBufs::alloc(&mut heap, &ctx, 8);
            fill_rs_inputs(&mut heap, &bufs, 6);
            let expected = expected_reduce_scatter(&heap, &bufs);
            let mut pb = ProgBuild::new();
            nccl_reduce_scatter_ring(&ctx, &bufs, &mut pb, 16);
            Sim::new(&topo)
                .run(&pb.prog, &mut heap, &mut NoopExecutor)
                .unwrap();
            verify_reduce_scatter(&heap, &bufs, &expected).unwrap();
        }
    }

    #[test]
    fn fcollect_correct() {
        let cluster = ClusterSpec::h800(1, 8);
        let ctx = ShmemCtx::new(cluster, DType::BF16);
        let topo = Topology::build(cluster);
        let mut heap = SymmetricHeap::new(8, 32);
        let bufs = AgBufs::alloc(&mut heap, &ctx, 16);
        fill_ag_inputs(&mut heap, &bufs, 8);
        let expected = expected_allgather(&heap, &bufs);
        let mut pb = ProgBuild::new();
        nvshmem_fcollect(&ctx, &bufs, &mut pb, 0.2e-6);
        Sim::new(&topo)
            .run(&pb.prog, &mut heap, &mut NoopExecutor)
            .unwrap();
        verify_allgather(&heap, &bufs, &expected).unwrap();
    }

    #[test]
    fn oop_costs_more_than_inplace() {
        let run = |oop: bool| {
            let cluster = ClusterSpec::l20(1, 8);
            let ctx = ShmemCtx::new(cluster, DType::BF16);
            let topo = Topology::build(cluster);
            let mut heap = SymmetricHeap::new(8, 32);
            let bufs = AgBufs::alloc(&mut heap, &ctx, 4096);
            fill_ag_inputs(&mut heap, &bufs, 8);
            let mut pb = ProgBuild::new();
            nccl_allgather_smallmsg(&ctx, &bufs, &mut pb, oop);
            Sim::new(&topo)
                .run(&pb.prog, &mut heap, &mut NoopExecutor)
                .unwrap()
                .makespan
        };
        assert!(run(true) > run(false));
    }

    #[test]
    fn ring_is_latency_bound_for_small_messages() {
        // (ws-1) serial hops: ring latency should scale with world size
        // while the LL direct path does not — Fig. 19's mechanism.
        let ring_t = |ws: usize| {
            let cluster = ClusterSpec::h800(1, ws);
            let ctx = ShmemCtx::new(cluster, DType::BF16);
            let topo = Topology::build(cluster);
            let mut heap = SymmetricHeap::new(ws, 4 * ws.max(8));
            let bufs = AgBufs::alloc(&mut heap, &ctx, 64);
            fill_ag_inputs(&mut heap, &bufs, 1);
            let mut pb = ProgBuild::new();
            nccl_allgather_ring(&ctx, &bufs, &mut pb, 16);
            Sim::new(&topo)
                .run(&pb.prog, &mut heap, &mut NoopExecutor)
                .unwrap()
                .makespan
        };
        assert!(ring_t(8) > ring_t(2));
    }
}
