//! triton-dist-sim CLI: run any overlapping kernel (and its baselines) on
//! a simulated cluster, print timelines and figure-style reports.

use triton_dist_sim::cli::Args;
use triton_dist_sim::collectives::alltoall::{a2a_deepep_cfg, a2a_ll, A2aBufs, A2aCfg};
use triton_dist_sim::collectives::ProgBuild;
use triton_dist_sim::config::{
    ChunkSched, ClusterSpec, DType, FabricSpec, FaultPlan, GemmShape, MoeShape, RailPolicy,
    TracePlan,
};
use triton_dist_sim::coordinator::{self, ag_gemm, ep_moe, flash_decode, gemm_rs, moe, recover};
use triton_dist_sim::mem::SymmetricHeap;
use triton_dist_sim::metrics;
use triton_dist_sim::overlap::features;
use triton_dist_sim::runtime::HybridExecutor;
use triton_dist_sim::topology::Topology;
use triton_dist_sim::util::stats::fmt_time;

const USAGE: &str = "\
triton-dist-sim — Triton-distributed reproduction on a simulated cluster

USAGE: triton-dist-sim <command> [options]

COMMANDS:
  features                    print the Table-2 optimization matrix
  ag-gemm                     run AG+GEMM (ours vs nccl vs flux)
  gemm-rs                     run GEMM+RS (ours vs nccl vs flux)
  ag-moe                      run AG+MoE (ours vs pytorch)
  ep-moe                      run token-routed expert-parallel MoE
                              (railed dispatch/combine vs fixed capacity)
  alltoall                    run low-latency EP AllToAll (ours vs deepep)
  flash-decode                run distributed flash decoding
  serve                       trace-driven continuous-batching serving:
                              arrivals -> prefill/decode SM partition ->
                              per-step flash-decode + EP-MoE, reporting
                              p50/p99 TTFT & TPOT into BENCH_engine.json
  timeline                    print an ASCII timeline of AG+GEMM
  artifacts                   list loaded AOT artifacts (PJRT manifest)

COMMON OPTIONS:
  --nodes N       (default 1)        --gpus N   per node (default 8)
  --hw  h800|mi308x|l20 (default h800)
  --rails N       NIC rails per GPU (default 1)
  --oversub R     leaf/spine oversubscription ratio (default 1.0)
  --spine-taper R spine-core thinning vs its leaf feed (default 1.0)
  --router static|adaptive   rail selection for un-pinned traffic
                  (default static: deterministic round-robin striping;
                  adaptive: emptiest plane per message by live occupancy)
  --sched fifo|srpf|deadline chunk-issue scheduling across in-flight
                  collectives (default fifo: issue in program order,
                  bit-identical to the pre-scheduler engine; srpf:
                  shortest-remaining-path-first; deadline: consumer-
                  gating pieces first, e.g. combine legs feeding GEMMs)
  --m/--n/--k     GEMM dims          --trace    write chrome trace JSON
  --numeric       run real numerics through PJRT/native executors
  --threads N     host threads for the sharded event loop (default 1;
                  timing runs only — results are bit-identical for every
                  N, so this is purely a wall-clock knob. Needs >= 2
                  nodes and --router static to engage; otherwise the
                  engine falls back to the sequential loop)

FAULT INJECTION (timing runs; empty plan = bit-identical to fault-free):
  --faults SPEC   semicolon-separated plan, e.g.
                  \"flap,nic,3,0,1e-3,2e-3; deg,spine,0,0,5e-3,0.5;
                  raildead,1,4e-3; strag,5,1.5; jitter,42,1e-6\"
                  permanent deaths: \"die,<rank>,<t0>\" kills one GPU
                  forever; \"nodedead,<node>,<t0>\" kills a whole node.
                  A run touching a dead rank aborts with a structured
                  DeadPeer error — pass --recover (ep-moe, flash-decode,
                  ag-gemm, gemm-rs) to survive it; `serve` always
                  recovers.
  --fault-seed N  synthesize a deterministic random plan (with --fault-rate)
  --fault-rate R  faults per rank for the synthesized plan (default 0)
  --fault-severe  synthesized plan draws from the severe tier too
                  (die/nodedead/raildead); without it every synthesized
                  plan is recoverable by retry/reroute alone
  --lt-timeout S  watchdog on LL/signal waits, seconds (default: off)
  --retry-max N   retry budget for puts killed on a downed link (default 8)

ELASTIC RECOVERY (ep-moe, flash-decode, ag-gemm, gemm-rs):
  --recover       survive permanent deaths: detect -> drain -> re-plan
                  over the survivors -> resume (ep-moe verifies numerics
                  on the survivor world; all print the recovery ledger
                  with exact accounting. ag-gemm re-plans onto the flat
                  survivor AllGather, gemm-rs onto the flat survivor
                  ReduceScatter)
  worked example — kill rank 3 at t=10us mid-dispatch and recover:
    triton-dist-sim ep-moe --nodes 2 --rails 2 \\
        --faults \"die,3,1e-5\" --recover

SERVING (serve):
  --trace SPEC    explicit trace DSL (wins over --arrival), e.g.
                  \"poisson,2e4,512,7; bursty,1e4,256,9,4,2e-3; lens,128,32\"
  --arrival K     poisson|bursty|diurnal arrival process (default poisson)
  --rate R        mean arrivals/s of virtual time (default 2e4)
  --requests N    requests to generate (default 256)
  --seed N        arrival-trace seed (default 1)
  --prompt/--output  mean prompt/output tokens (default 128/32)
  --max-batch N   continuous-batching slots (default 32)
  --prefill-chunk N  prefill token budget per step (default 256)
  --kv-block N    tokens per KV-cache block (default 64)
  --migrate-batch N  max KV rebalance migrations per serving step
                  (default 1; each is charged and exactly accounted)
  --no-moe        skip the per-decode-step EP-MoE FFN
  deaths in --faults are absorbed: the fleet re-plans onto survivors
  and the report shows the p99 spike. Writes the serving record to
  BENCH_engine.json ($BENCH_ENGINE_JSON overrides the path).
  worked example — diurnal load with a mid-trace rank death:
    triton-dist-sim serve --nodes 2 --arrival diurnal --rate 3e4 \\
        --requests 512 --seed 7 --faults \"die,3,2e-3\"

EP-MOE OPTIONS:
  --tokens/--in-hidden/--out-hidden/--experts/--topk   MoE shape
  --skew S            expert-popularity skew exponent (default 0 =
                      uniform; higher concentrates topk on low experts)
  --capacity-factor F per-expert capacity over the balanced load
                      (default 2.0; overflow pairs are dropped)
  --split N           LL sub-messages per routed dispatch chunk
                      (default 1; see autotune::tune_dispatch_chunking)
  --seed N            routing-table seed (default 1)
";

fn cluster_from(args: &Args) -> Result<ClusterSpec, String> {
    let nodes = args.usize_or("nodes", 1)?;
    let gpus = args.usize_or("gpus", 8)?;
    let rails = args.usize_or("rails", 1)?;
    let oversub = args.f64_or("oversub", 1.0)?;
    let spine_taper = args.f64_or("spine-taper", 1.0)?;
    if rails == 0 {
        return Err("--rails must be >= 1".into());
    }
    // explicit NaN checks: `x < 1.0` alone would let NaN through
    if oversub.is_nan() || oversub < 1.0 {
        return Err("--oversub must be >= 1.0".into());
    }
    if spine_taper.is_nan() || spine_taper < 1.0 {
        return Err("--spine-taper must be >= 1.0".into());
    }
    let policy = match args.choice_or("router", "static", &["static", "adaptive"])? {
        "adaptive" => RailPolicy::Adaptive,
        _ => RailPolicy::Static,
    };
    let sched = match args.choice_or("sched", "fifo", &["fifo", "srpf", "deadline"])? {
        "srpf" => ChunkSched::Srpf,
        "deadline" => ChunkSched::Deadline,
        _ => ChunkSched::Fifo,
    };
    let cluster = match args.choice_or("hw", "h800", &["h800", "mi308x", "l20"])? {
        "mi308x" => ClusterSpec::mi308x(gpus),
        "l20" => ClusterSpec::l20(nodes, gpus),
        _ => ClusterSpec::h800(nodes, gpus),
    };
    Ok(cluster.with_fabric(
        FabricSpec::rail_optimized(rails, oversub)
            .with_spine_taper(spine_taper)
            .with_rail_policy(policy)
            .with_chunk_sched(sched),
    ))
}

/// Resolve the fault plan: explicit `--faults` DSL wins, else a plan
/// synthesized from `--fault-seed`/`--fault-rate`, else empty. The
/// recovery knobs (`--lt-timeout`, `--retry-max`) apply either way.
fn fault_plan_from(args: &Args, cluster: &ClusterSpec) -> Result<FaultPlan, String> {
    let mut plan = match args.get("faults") {
        Some(spec) => FaultPlan::parse(spec)?,
        None => {
            let rate = args.f64_or("fault-rate", 0.0)?;
            if rate.is_nan() || rate < 0.0 {
                return Err("--fault-rate must be >= 0".into());
            }
            if rate > 0.0 {
                let seed = args.usize_or("fault-seed", 0)? as u64;
                let horizon = 10e-3; // covers every CLI workload's makespan
                if args.flag("fault-severe") {
                    FaultPlan::synthesize_severe(
                        seed,
                        rate,
                        cluster.world_size(),
                        cluster.nodes,
                        cluster.fabric.rails,
                        horizon,
                    )
                } else {
                    FaultPlan::synthesize(
                        seed,
                        rate,
                        cluster.world_size(),
                        cluster.fabric.rails,
                        horizon,
                    )
                }
            } else {
                FaultPlan::default()
            }
        }
    };
    let lt = args.f64_or("lt-timeout", f64::INFINITY)?;
    if lt.is_nan() || lt <= 0.0 {
        return Err("--lt-timeout must be > 0".into());
    }
    plan.lt_timeout = lt;
    plan.retry_max = args.usize_or("retry-max", plan.retry_max as usize)? as u32;
    Ok(plan)
}

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<(), String> {
    match args.subcommand.as_deref() {
        None | Some("help") => {
            println!("{USAGE}");
            Ok(())
        }
        Some("features") => {
            println!("{}", features::render_table2());
            Ok(())
        }
        Some("artifacts") => {
            match triton_dist_sim::runtime::XlaRuntime::try_default() {
                Some(rt) => {
                    println!("loaded artifacts:");
                    for n in rt.entry_names() {
                        println!("  {n}");
                    }
                }
                None => println!("no artifacts found (run `make artifacts`)"),
            }
            Ok(())
        }
        Some("ag-gemm") => {
            let cluster = cluster_from(args)?;
            let ws = cluster.world_size();
            let m = args.usize_or("m", 512 * ws)?;
            let n = args.usize_or("n", 1024)?;
            let k = args.usize_or("k", 2048)?;
            let shape = GemmShape::new(m, n, k);
            let plan = fault_plan_from(args, &cluster)?;
            if args.flag("recover") || plan.has_deaths() {
                // Elastic path: detect the death, drain, re-plan onto
                // the flat survivor AllGather + full-SM GEMM, resume.
                let variant = if cluster.nodes > 1 {
                    ag_gemm::AgGemmVariant::OursInter
                } else {
                    ag_gemm::AgGemmVariant::OursPush
                };
                let (rep, view) = recover::run_ag_gemm_elastic(
                    cluster,
                    shape,
                    variant,
                    plan,
                    &recover::RecoverCfg::default(),
                )
                .map_err(|e| e.to_string())?;
                match &rep.recovery {
                    Some(rec) => println!("{}", metrics::recovery_line(rec)),
                    None => println!("no deaths fired; completed at full world"),
                }
                println!(
                    "AG+GEMM latency={} (world {} of {})",
                    fmt_time(rep.makespan),
                    view.world(),
                    ws
                );
                return Ok(());
            }
            let threads = args.positive_usize_or("threads", 1)?;
            let topo = Topology::build(cluster);
            let mut report = metrics::FigureReport::new("AG+GEMM");
            let variants: Vec<ag_gemm::AgGemmVariant> = if cluster.nodes > 1 {
                vec![ag_gemm::AgGemmVariant::OursInter, ag_gemm::AgGemmVariant::Nccl]
            } else if matches!(cluster.hw.kind, triton_dist_sim::config::HardwareKind::MI308X) {
                vec![
                    ag_gemm::AgGemmVariant::OursAmd { sub_chunks: 4 },
                    ag_gemm::AgGemmVariant::Nccl,
                ]
            } else {
                vec![
                    ag_gemm::AgGemmVariant::OursPush,
                    ag_gemm::AgGemmVariant::Nccl,
                    ag_gemm::AgGemmVariant::Flux,
                ]
            };
            let mut ours = 0.0;
            let mut baselines = Vec::new();
            for v in variants {
                let (mut op, bufs) = ag_gemm::build(cluster, shape, v);
                let t = if args.flag("numeric") {
                    ag_gemm::fill_inputs(&mut op.heap, &bufs, 1);
                    let reference = ag_gemm::reference_output(&op.heap, &bufs);
                    let mut exec = HybridExecutor::auto();
                    let rep = coordinator::run_numeric(&mut op, &topo, &mut exec)
                        .map_err(|e| e.to_string())?;
                    ag_gemm::verify(&op.heap, &bufs, &reference)?;
                    println!(
                        "numerics OK ({} xla calls, {} native)",
                        exec.xla_calls, exec.native_calls
                    );
                    rep.makespan
                } else {
                    let rep =
                        coordinator::run_timing_threads(&mut op, &topo, plan.clone(), threads)
                            .map_err(|e| e.to_string())?;
                    if !plan.is_empty() {
                        println!("  {}", metrics::fault_ledger_line(&rep.ledger));
                    }
                    rep.makespan
                };
                println!("{:<24} {}", op.name, fmt_time(t));
                if op.name.contains("ours") && ours == 0.0 {
                    ours = t;
                } else {
                    baselines.push((op.name.clone(), t));
                }
            }
            report.push(metrics::SpeedupRow {
                workload: format!("M{m} N{n} K{k} ws{ws}"),
                ours,
                baselines,
            });
            println!("{}", report.render());
            Ok(())
        }
        Some("gemm-rs") => {
            let cluster = cluster_from(args)?;
            let ws = cluster.world_size();
            let m = args.usize_or("m", 512 * ws)?;
            let n = args.usize_or("n", 1024)?;
            let k = args.usize_or("k", 2048)?;
            let shape = GemmShape::new(m, n, k);
            let topo = Topology::build(cluster);
            let variants = if cluster.nodes > 1 {
                vec![gemm_rs::GemmRsVariant::OursInter, gemm_rs::GemmRsVariant::Nccl]
            } else {
                vec![
                    gemm_rs::GemmRsVariant::OursIntra,
                    gemm_rs::GemmRsVariant::Nccl,
                    gemm_rs::GemmRsVariant::Flux,
                ]
            };
            let plan = fault_plan_from(args, &cluster)?;
            if args.flag("recover") || plan.has_deaths() {
                // Elastic path: detect the death, drain, re-plan onto a
                // full-SM partial GEMM per survivor feeding the flat
                // survivor ReduceScatter, resume.
                let variant = if cluster.nodes > 1 {
                    gemm_rs::GemmRsVariant::OursInter
                } else {
                    gemm_rs::GemmRsVariant::OursIntra
                };
                let (rep, view) = recover::run_gemm_rs_elastic(
                    cluster,
                    shape,
                    variant,
                    plan,
                    &recover::RecoverCfg::default(),
                )
                .map_err(|e| e.to_string())?;
                match &rep.recovery {
                    Some(rec) => println!("{}", metrics::recovery_line(rec)),
                    None => println!("no deaths fired; completed at full world"),
                }
                println!(
                    "GEMM+RS latency={} (world {} of {})",
                    fmt_time(rep.makespan),
                    view.world(),
                    ws
                );
                return Ok(());
            }
            let threads = args.positive_usize_or("threads", 1)?;
            for v in variants {
                let (mut op, _b) = gemm_rs::build(cluster, shape, v);
                let rep = coordinator::run_timing_threads(&mut op, &topo, plan.clone(), threads)
                    .map_err(|e| e.to_string())?;
                println!("{:<24} {}", op.name, fmt_time(rep.makespan));
                if !plan.is_empty() {
                    println!("  {}", metrics::fault_ledger_line(&rep.ledger));
                }
            }
            Ok(())
        }
        Some("ag-moe") => {
            let cluster = cluster_from(args)?;
            let shape = MoeShape {
                tokens_per_rank: args.usize_or("tokens", 256)?,
                in_hidden: args.usize_or("in-hidden", 2048)?,
                out_hidden: args.usize_or("out-hidden", 1408)?,
                experts: args.usize_or("experts", 60)?,
                topk: args.usize_or("topk", 4)?,
                ..MoeShape::default()
            };
            let topo = Topology::build(cluster);
            let plan = fault_plan_from(args, &cluster)?;
            let threads = args.positive_usize_or("threads", 1)?;
            for v in [moe::MoeVariant::Ours, moe::MoeVariant::Torch] {
                let (mut op, _b) = moe::build_ag_moe(cluster, shape, v);
                let rep = coordinator::run_timing_threads(&mut op, &topo, plan.clone(), threads)
                    .map_err(|e| e.to_string())?;
                println!("{:<24} {}", op.name, fmt_time(rep.makespan));
                if !plan.is_empty() {
                    println!("  {}", metrics::fault_ledger_line(&rep.ledger));
                }
            }
            Ok(())
        }
        Some("ep-moe") => {
            // The flagship multi-node workload: token-routed EP dispatch
            // -> grouped FFN sized by actual received tokens -> combine
            // crossing into the receiver's plane, vs the fixed-capacity
            // padded baseline.
            let cluster = cluster_from(args)?;
            let ws = cluster.world_size();
            let shape = MoeShape {
                tokens_per_rank: args.usize_or("tokens", 256)?,
                in_hidden: args.usize_or("in-hidden", 2048)?,
                out_hidden: args.usize_or("out-hidden", 1408)?,
                experts: args.usize_or("experts", 64)?,
                topk: args.usize_or("topk", 4)?,
                skew: args.f64_or("skew", 0.0)?,
                capacity_factor: args.f64_or("capacity-factor", 2.0)?,
            };
            if shape.skew.is_nan() || shape.skew < 0.0 {
                return Err("--skew must be >= 0".into());
            }
            if shape.capacity_factor.is_nan() || shape.capacity_factor <= 0.0 {
                return Err("--capacity-factor must be > 0".into());
            }
            let split = args.usize_or("split", 1)?;
            if split == 0 {
                return Err("--split must be >= 1".into());
            }
            let seed = args.usize_or("seed", 1)? as u64;
            let cfg = A2aCfg::ours().with_split(split);
            let routing = ep_moe::routing_for(cluster, &shape, seed);
            let geom = routing.geom;
            println!(
                "routing: {}/{} (token, k) pairs kept, {} dropped \
                 (capacity {} slots/expert, skew {})",
                routing.kept(),
                geom.w * geom.t * geom.k,
                routing.dropped(),
                geom.c,
                shape.skew,
            );
            let plan = fault_plan_from(args, &cluster)?;
            if args.flag("recover") || plan.has_deaths() {
                // Elastic path: detect the death, drain, re-plan over the
                // survivor world, resume, and verify survivor numerics.
                let run = recover::run_ep_moe_elastic(
                    cluster,
                    shape,
                    seed,
                    ep_moe::EpMoeVariant::TokenRouted,
                    &cfg,
                    plan,
                    &recover::RecoverCfg::default(),
                )
                .map_err(|e| e.to_string())?;
                match &run.report.recovery {
                    Some(rec) => println!("{}", metrics::recovery_line(rec)),
                    None => println!("no deaths fired; completed at full world"),
                }
                let reference = ep_moe::reference_ep_moe_view(
                    &run.op.heap,
                    &run.bufs,
                    &run.routing,
                    &run.view,
                );
                ep_moe::verify_ep_moe_view(
                    &run.op.heap,
                    &run.bufs,
                    &run.routing,
                    &reference,
                    &run.view,
                )?;
                println!(
                    "survivor numerics OK (exact, world {} of {})",
                    run.view.world(),
                    ws
                );
                println!("{:<28} {}", run.op.name, fmt_time(run.report.makespan));
                return Ok(());
            }
            let threads = args.positive_usize_or("threads", 1)?;
            let topo = Topology::build(cluster);
            let mut report = metrics::FigureReport::new("EP MoE (token-routed)");
            let mut row = metrics::SpeedupRow {
                workload: format!(
                    "t{} h{} f{} E{} k{} ws{ws} skew{}",
                    shape.tokens_per_rank,
                    shape.in_hidden,
                    shape.out_hidden,
                    shape.experts,
                    shape.topk,
                    shape.skew
                ),
                ours: 0.0,
                baselines: Vec::new(),
            };
            for variant in [
                ep_moe::EpMoeVariant::TokenRouted,
                ep_moe::EpMoeVariant::FixedCapacity,
            ] {
                let (mut op, bufs) =
                    ep_moe::build_ep_moe_cfg(cluster, shape, &routing, variant, &cfg);
                let t = if args.flag("numeric")
                    && variant == ep_moe::EpMoeVariant::TokenRouted
                {
                    ep_moe::fill_ep_moe(&mut op.heap, &bufs, &routing, seed);
                    let reference = ep_moe::reference_ep_moe(&op.heap, &bufs, &routing);
                    let mut exec = HybridExecutor::auto();
                    let rep = coordinator::run_numeric(&mut op, &topo, &mut exec)
                        .map_err(|e| e.to_string())?;
                    ep_moe::verify_ep_moe(&op.heap, &bufs, &routing, &reference)?;
                    println!("numerics OK (exact token conservation verified)");
                    rep.makespan
                } else {
                    let rep =
                        coordinator::run_timing_threads(&mut op, &topo, plan.clone(), threads)
                            .map_err(|e| e.to_string())?;
                    if !plan.is_empty() {
                        println!("  {}", metrics::fault_ledger_line(&rep.ledger));
                    }
                    rep.makespan
                };
                println!("{:<28} {}", op.name, fmt_time(t));
                match variant {
                    ep_moe::EpMoeVariant::TokenRouted => row.ours = t,
                    ep_moe::EpMoeVariant::FixedCapacity => {
                        row.baselines.push(("fixed-capacity".into(), t));
                    }
                }
            }
            report.push(row);
            println!("{}", report.render());
            Ok(())
        }
        Some("alltoall") => {
            // Fig. 16's workload, reachable from the CLI: low-latency EP
            // dispatch/combine vs the DeepEP-like baseline.
            let cluster = cluster_from(args)?;
            let ws = cluster.world_size();
            let chunk = args.usize_or("chunk", (128 * 7168 / ws).max(64))?;
            let plan = fault_plan_from(args, &cluster)?;
            let threads = args.positive_usize_or("threads", 1)?;
            let topo = Topology::build(cluster);
            let run = |deepep: Option<A2aCfg>, chunk_elems: usize| -> Result<f64, String> {
                let ctx = triton_dist_sim::shmem::ShmemCtx::new(cluster, DType::BF16);
                let mut heap = SymmetricHeap::new(ws, 4 * ws.max(16));
                let bufs = A2aBufs::alloc(&mut heap, &ctx, chunk_elems);
                let mut pb = ProgBuild::new();
                match deepep {
                    Some(cfg) => a2a_deepep_cfg(&ctx, &bufs, &mut pb, &cfg),
                    None => a2a_ll(&ctx, &bufs, &mut pb, &A2aCfg::ours()),
                }
                let rep = coordinator::run_timing_threads(
                    &mut coordinator::BuiltOp {
                        ctx,
                        heap,
                        prog: pb.prog,
                        name: "AllToAll".into(),
                    },
                    &topo,
                    plan.clone(),
                    threads,
                )
                .map_err(|e| e.to_string())?;
                if !plan.is_empty() {
                    println!("  {}", metrics::fault_ledger_line(&rep.ledger));
                }
                Ok(rep.makespan)
            };
            let mut report = metrics::FigureReport::new("Low-latency AllToAll");
            for (tag, chunk_elems, base_cfg) in [
                ("dispatch", chunk, A2aCfg::deepep()),
                ("combine", chunk * 2, A2aCfg::deepep_combine()),
            ] {
                let ours = run(None, chunk_elems)?;
                let deepep = run(Some(base_cfg), chunk_elems)?;
                println!("{tag:<10} ours {:<12} deepep {}", fmt_time(ours), fmt_time(deepep));
                report.push(metrics::SpeedupRow {
                    workload: format!("{tag} {ws} GPUs chunk={chunk_elems}"),
                    ours,
                    baselines: vec![("deepep".into(), deepep)],
                });
            }
            println!("{}", report.render());
            Ok(())
        }
        Some("flash-decode") => {
            let cluster = cluster_from(args)?;
            let cfg = flash_decode::FlashDecodeCfg {
                heads: args.usize_or("heads", 8)?,
                head_dim: args.usize_or("head-dim", 64)?,
                kv_per_rank: args.usize_or("kv", 32 * 1024)?,
                numeric: false,
            };
            let plan = fault_plan_from(args, &cluster)?;
            if args.flag("recover") || plan.has_deaths() {
                // Elastic path: detect the death, drain, re-plan the
                // decode onto the survivors' flat combine, resume.
                let (rep, view) =
                    recover::run_flash_decode_elastic(
                        cluster,
                        cfg,
                        plan,
                        &recover::RecoverCfg::default(),
                    )
                    .map_err(|e| e.to_string())?;
                match &rep.recovery {
                    Some(rec) => println!("{}", metrics::recovery_line(rec)),
                    None => println!("no deaths fired; completed at full world"),
                }
                println!(
                    "flash-decode latency={} (world {} of {})",
                    fmt_time(rep.makespan),
                    view.world(),
                    cluster.world_size()
                );
                return Ok(());
            }
            let threads = args.positive_usize_or("threads", 1)?;
            let topo = Topology::build(cluster);
            let (mut op, _b) = flash_decode::build(cluster, cfg);
            let rep = coordinator::run_timing_threads(&mut op, &topo, plan.clone(), threads)
                .map_err(|e| e.to_string())?;
            if !plan.is_empty() {
                println!("{}", metrics::fault_ledger_line(&rep.ledger));
            }
            let t = rep.makespan;
            let bw = flash_decode::achieved_bw(&cfg, &cluster, t);
            println!(
                "{} latency={} achieved-bw={:.2} TB/s per GPU",
                op.name,
                fmt_time(t),
                bw / 1e12
            );
            Ok(())
        }
        Some("serve") => {
            let cluster = cluster_from(args)?;
            // explicit --trace DSL wins; else synthesize one arrival
            // process from --arrival/--rate/--requests/--seed
            let mut plan = match args.get("trace") {
                Some(spec) => TracePlan::parse(spec)?,
                None => {
                    let kind =
                        args.choice_or("arrival", "poisson", &["poisson", "bursty", "diurnal"])?;
                    let rate = args.f64_or("rate", 2e4)?;
                    let n = args.usize_or("requests", 256)?;
                    let seed = args.usize_or("seed", 1)? as u64;
                    TracePlan::arrival(kind, rate, n, seed)?
                }
            };
            plan.prompt_mean = args.usize_or("prompt", plan.prompt_mean)?;
            plan.output_mean = args.usize_or("output", plan.output_mean)?;
            let faults = fault_plan_from(args, &cluster)?;
            let cfg = coordinator::serve::ServeCfg {
                max_batch: args.usize_or("max-batch", 32)?,
                prefill_chunk: args.usize_or("prefill-chunk", 256)?,
                kv_block: args.usize_or("kv-block", 64)?,
                moe: !args.flag("no-moe"),
                threads: args.positive_usize_or("threads", 1)?,
                migrate_batch: args.positive_usize_or("migrate-batch", 1)?,
                ..coordinator::serve::ServeCfg::default()
            };
            if cfg.max_batch == 0 || cfg.prefill_chunk == 0 || cfg.kv_block == 0 {
                return Err("--max-batch/--prefill-chunk/--kv-block must be >= 1".into());
            }
            let trace = plan.materialize();
            println!("trace: {plan}");
            println!("requests: {}", trace.len());
            let wall = std::time::Instant::now();
            let rep = coordinator::serve::run_serve(cluster, &trace, faults, &cfg)
                .map_err(|e| e.to_string())?;
            let wall_s = wall.elapsed().as_secs_f64();
            let info = rep.bench_info();
            println!("{}", metrics::serving_line(&info));
            for (why, n) in &rep.drop_reasons {
                println!("  dropped {n}: {why}");
            }
            for r in &rep.recoveries {
                println!(
                    "  death of rank(s) {:?} at {} -> resumed {} \
                     ({} request(s) rerouted, {} dropped)",
                    r.dead,
                    fmt_time(r.died_at),
                    fmt_time(r.resumed_at),
                    r.rerouted,
                    r.dropped
                );
            }
            if rep.kv_migrations > 0 {
                println!(
                    "  kv rebalance: {} migration(s), {} block(s) moved",
                    rep.kv_migrations, rep.kv_blocks_moved
                );
            }
            let record = metrics::EngineBenchRecord {
                scenario: "serve-cli".into(),
                events: rep.events,
                median_wall_s: wall_s,
                sim_wall_ns: 0,
                threads: Vec::new(),
                fault: None,
                recovery: None,
                serving: Some(info),
                sched: None,
            };
            let path = std::env::var("BENCH_ENGINE_JSON")
                .unwrap_or_else(|_| "BENCH_engine.json".into());
            std::fs::write(&path, metrics::engine_bench_json(&[record]))
                .map_err(|e| e.to_string())?;
            println!("wrote {path}");
            Ok(())
        }
        Some("timeline") => {
            let cluster = cluster_from(args)?;
            let shape = GemmShape::new(
                args.usize_or("m", 64 * cluster.world_size())?,
                args.usize_or("n", 64)?,
                args.usize_or("k", 64)?,
            );
            let topo = Topology::build(cluster);
            let (mut op, bufs) = ag_gemm::build(cluster, shape, ag_gemm::AgGemmVariant::OursPush);
            ag_gemm::fill_inputs(&mut op.heap, &bufs, 3);
            let mut exec = HybridExecutor::auto();
            let rep = coordinator::run_traced(&mut op, &topo, &mut exec)
                .map_err(|e| e.to_string())?;
            println!("{}", metrics::ascii_timeline(&rep, 100));
            if args.flag("trace") {
                let path = "trace.json";
                std::fs::write(path, metrics::chrome_trace(&rep)).map_err(|e| e.to_string())?;
                println!("wrote {path}");
            }
            Ok(())
        }
        Some(other) => Err(format!("unknown command '{other}'\n{USAGE}")),
    }
}
