//! In-tree bench harness (offline replacement for criterion).
//!
//! Two kinds of benchmarks coexist here:
//!
//! * **virtual-time** — DES makespans are deterministic, so one run per
//!   configuration is exact; the "benchmark" is the figure/table printer.
//! * **wall-clock** — engine-performance benches (events/s) that measure
//!   real elapsed time with warmup + repetitions.

use std::time::Instant;

use crate::util::stats::{fmt_time, mean, median, stddev};

/// Wall-clock measurement result.
#[derive(Debug, Clone)]
pub struct WallStat {
    pub name: String,
    pub iters: u32,
    pub mean_s: f64,
    pub median_s: f64,
    pub stddev_s: f64,
}

impl WallStat {
    /// Throughput of `count` items against the median elapsed time
    /// (e.g. events/s for the engine-perf scenarios).
    pub fn per_sec(&self, count: u64) -> f64 {
        count as f64 / self.median_s.max(1e-12)
    }

    pub fn render(&self) -> String {
        format!(
            "{:<40} iters={:<4} mean={:<10} median={:<10} stddev={}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.median_s),
            fmt_time(self.stddev_s)
        )
    }
}

/// Measure `f` for `iters` repetitions after `warmup` runs.
pub fn bench_wall<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> WallStat {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    WallStat {
        name: name.to_string(),
        iters,
        mean_s: mean(&samples),
        median_s: median(&samples),
        stddev_s: stddev(&samples),
    }
}

/// Banner for bench binaries (harness = false).
pub fn banner(title: &str) {
    println!("\n##### {title} #####");
}

/// Run-or-skip helper: benches accept a filter via BENCH_FILTER.
pub fn enabled(name: &str) -> bool {
    match std::env::var("BENCH_FILTER") {
        Ok(f) if !f.is_empty() => name.contains(&f),
        _ => true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_bench_collects_stats() {
        let s = bench_wall("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.iters, 5);
        assert!(s.mean_s >= 0.0);
        assert!(s.render().contains("noop"));
        assert!(s.per_sec(100) > 0.0);
    }

    #[test]
    fn filter_matches_substring() {
        std::env::remove_var("BENCH_FILTER");
        assert!(enabled("anything"));
    }
}
