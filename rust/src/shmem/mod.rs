//! Table-1 primitives: the OpenSHMEM (+ auxiliary) programming surface.
//!
//! [`ShmemCtx`] carries the world geometry (`my_pe`, `n_pes`, node/local
//! rank math) and [`ShmemTask`] wraps a [`TaskBuilder`] with methods named
//! after the paper's primitives, so the collective implementations in
//! `crate::collectives` read like the paper's pseudo-code (Algorithms
//! 1–5). Each primitive appends ops to the task's program; the DES engine
//! gives them their timing and (optionally) numeric semantics.
//!
//! Fabric path selection is stream-modal, like a CUDA stream's NIC
//! binding: [`ShmemTask::on_rail`] / [`ShmemTask::on_rails`] pin
//! subsequent transfers to explicit plane(s), [`ShmemTask::auto_rail`]
//! defers to the fabric's [`RailPolicy`], and collectives stripe through
//! [`ShmemTask::stripe_rail`] so one call site serves both the static
//! round-robin and the congestion-aware adaptive router:
//!
//! ```
//! use triton_dist_sim::config::{ClusterSpec, DType};
//! use triton_dist_sim::shmem::ShmemCtx;
//!
//! let ctx = ShmemCtx::new(ClusterSpec::h800(2, 8), DType::BF16);
//! let mut t = ctx.task(0, "sender");
//! t.on_rails(0, 1); // asymmetric planes: the spine-crossing path
//! t.auto_rail();    // back to policy-resolved routing
//! let spec = t.build();
//! assert_eq!(spec.rank, 0);
//! ```

use crate::config::{ClusterSpec, DType, RailPolicy, TrafficClass};
use crate::mem::Slice;
use crate::program::{
    ChunkMeta, ComputeCost, EngineClass, NumericOp, Op, Scope, SigCond, SigOp, SigRef,
    TaskBuilder, TaskSpec,
};

/// World geometry, shared by every rank's builder (the "host side").
#[derive(Debug, Clone, Copy)]
pub struct ShmemCtx {
    pub cluster: ClusterSpec,
    /// Simulated payload dtype (timing only; numerics are f32).
    pub dtype: DType,
}

impl ShmemCtx {
    pub fn new(cluster: ClusterSpec, dtype: DType) -> Self {
        ShmemCtx { cluster, dtype }
    }

    /// `n_pes` — world size.
    pub fn n_pes(&self) -> usize {
        self.cluster.world_size()
    }

    pub fn local_world_size(&self) -> usize {
        self.cluster.gpus_per_node
    }

    pub fn n_nodes(&self) -> usize {
        self.cluster.nodes
    }

    pub fn node_of(&self, pe: usize) -> usize {
        self.cluster.node_of(pe)
    }

    pub fn local_rank_of(&self, pe: usize) -> usize {
        self.cluster.local_rank(pe)
    }

    /// Timing bytes for `elems` elements of the workload dtype.
    pub fn bytes(&self, elems: usize) -> f64 {
        (elems * self.dtype.bytes()) as f64
    }

    /// Start building a task for `pe`.
    pub fn task(&self, pe: usize, name: impl Into<String>) -> ShmemTask {
        ShmemTask {
            ctx: *self,
            pe,
            b: TaskBuilder::new(pe, name),
            tc: TrafficClass::Auto,
            chunk: None,
        }
    }
}

/// A task under construction, with primitive-level methods.
pub struct ShmemTask {
    ctx: ShmemCtx,
    pe: usize,
    b: TaskBuilder,
    /// Fabric path for subsequent data-movement ops (stream-modal, like a
    /// CUDA stream's NIC binding): set with [`Self::on_rail`], cleared
    /// with [`Self::auto_rail`].
    tc: TrafficClass,
    /// Chunk-scheduler metadata for subsequent puts (stream-modal, like
    /// `tc`): set with [`Self::chunk_meta`], cleared with
    /// [`Self::clear_chunk`]. `None` (the default) leaves pieces
    /// untagged, which every `ChunkSched` policy posts eagerly.
    chunk: Option<ChunkMeta>,
}

impl ShmemTask {
    /// `my_pe`.
    pub fn my_pe(&self) -> usize {
        self.pe
    }

    pub fn ctx(&self) -> &ShmemCtx {
        &self.ctx
    }

    // -- task attributes -----------------------------------------------------

    pub fn on_copy_engine(mut self) -> Self {
        self.b = self.b.engine(EngineClass::CopyEngine);
        self
    }

    pub fn on_host(mut self) -> Self {
        self.b = self.b.engine(EngineClass::Host);
        self
    }

    /// Reserve `n` SMs for the task's lifetime (§3.8 resource partition).
    pub fn with_sms(mut self, n: u32) -> Self {
        self.b = self.b.sms(n);
        self
    }

    /// Model kernel-launch overhead before the first op.
    pub fn launch_overhead(mut self) -> Self {
        let oh = self.ctx.cluster.hw.launch_overhead;
        self.b = self.b.start_delay(oh);
        self
    }

    pub fn start_delay(mut self, d: f64) -> Self {
        self.b = self.b.start_delay(d);
        self
    }

    pub fn build(self) -> TaskSpec {
        self.b.build()
    }

    // -- fabric path selection -------------------------------------------------
    //
    // These are stream-modal, like a CUDA stream's NIC binding: the chosen
    // `TrafficClass` applies to every subsequent data-movement op of this
    // task until changed.
    //
    // ```
    // use triton_dist_sim::config::{ClusterSpec, DType};
    // use triton_dist_sim::shmem::ShmemCtx;
    //
    // let ctx = ShmemCtx::new(ClusterSpec::h800(2, 8), DType::BF16);
    // let mut t = ctx.task(0, "sender");
    // t.on_rail(1);      // pin to plane 1 end-to-end
    // t.on_rails(0, 1);  // asymmetric planes: spine-crossing path
    // t.auto_rail();     // defer to the fabric's RailPolicy
    // ```

    /// Pin subsequent transfers to NIC rail `rail % rails` (rail-optimized
    /// same-rail path), regardless of the fabric's `RailPolicy`. No-op on
    /// intra-node routes and single-rail fabrics. Collectives should
    /// prefer [`Self::stripe_rail`], which defers to the congestion-aware
    /// router when the fabric asks for it.
    pub fn on_rail(&mut self, rail: usize) -> &mut Self {
        self.tc = TrafficClass::Rail(rail as u32);
        self
    }

    /// Explicit tx/rx rail planes (unequal planes take the spine-crossing
    /// path), regardless of the fabric's `RailPolicy`. This is how the
    /// expert-parallel `a2a_ep_rails` pins its combine direction into the
    /// receiver's home plane.
    pub fn on_rails(&mut self, tx: usize, rx: usize) -> &mut Self {
        self.tc = TrafficClass::Rails {
            tx: tx as u32,
            rx: rx as u32,
        };
        self
    }

    /// Let the router pick the rail again (the default). Resolution
    /// happens per message at simulation time under the fabric's
    /// [`RailPolicy`]: a deterministic endpoint hash under
    /// `RailPolicy::Static`, the emptiest plane by live link occupancy
    /// under `RailPolicy::Adaptive`.
    pub fn auto_rail(&mut self) -> &mut Self {
        self.tc = TrafficClass::Auto;
        self
    }

    /// Rail striping hint for collective builders: under
    /// `RailPolicy::Static` this pins to `rail % rails` exactly like
    /// [`Self::on_rail`] (the deterministic round-robin stripe), while
    /// under `RailPolicy::Adaptive` it defers to the congestion-aware
    /// router ([`Self::auto_rail`]) so the plane is chosen per message
    /// from live occupancy. Every hard-striping collective
    /// (`ag_inter`, `ag_ll_inter`, `ag_ll_pcie`, `rs_inter`, `a2a_ll`,
    /// `a2a_deepep`) routes its inter-node segments through this.
    pub fn stripe_rail(&mut self, rail: usize) -> &mut Self {
        match self.ctx.cluster.fabric.rail_policy {
            RailPolicy::Static => self.on_rail(rail),
            RailPolicy::Adaptive => self.auto_rail(),
        }
    }

    /// The traffic class subsequent data-movement ops will carry (for
    /// builders assembling raw [`Op`]s alongside the primitives).
    pub fn tc(&self) -> TrafficClass {
        self.tc
    }

    // -- chunk-scheduler tagging ----------------------------------------------
    //
    // Stream-modal like the rail selection above: collective builders tag
    // the pieces of a split dispatch / chunked segment walk with how many
    // wire bytes remain in the stream and whether a consumer is already
    // gated on them, and the engine's `ChunkSched` ready queue orders
    // tagged pieces across *all* in-flight collectives. Untagged ops
    // always post eagerly, so tagging is purely additive.

    /// Tag subsequent puts with chunk-scheduler metadata (see
    /// [`ChunkMeta`]): `remaining` wire bytes still unsent in this stream
    /// including the next piece, and the consumer `deadline` class.
    pub fn chunk_meta(&mut self, remaining: f64, deadline: u32) -> &mut Self {
        self.chunk = Some(ChunkMeta {
            remaining,
            deadline,
        });
        self
    }

    /// Stop tagging: subsequent puts post eagerly under every policy.
    pub fn clear_chunk(&mut self) -> &mut Self {
        self.chunk = None;
        self
    }

    /// The chunk metadata subsequent data-movement ops will carry (for
    /// builders assembling raw [`Op`]s alongside the primitives).
    pub fn chunk(&self) -> Option<ChunkMeta> {
        self.chunk
    }

    // -- OpenSHMEM data movement ----------------------------------------------

    /// `putmem`: blocking one-sided write of `src` (local) to `dst`
    /// (remote symmetric address).
    pub fn putmem(&mut self, src: Slice, dst: Slice) -> &mut Self {
        assert_eq!(src.rank, self.pe, "putmem source must be local");
        let bytes = self.ctx.bytes(src.len);
        self.b.op(Op::Put {
            src,
            dst,
            bytes,
            signal: None,
            blocking: true,
            tc: self.tc,
            chunk: self.chunk,
            label: "putmem",
        });
        self
    }

    /// `putmem_nbi`: non-blocking put (fence with [`Self::quiet`]).
    pub fn putmem_nbi(&mut self, src: Slice, dst: Slice) -> &mut Self {
        assert_eq!(src.rank, self.pe);
        let bytes = self.ctx.bytes(src.len);
        self.b.op(Op::Put {
            src,
            dst,
            bytes,
            signal: None,
            blocking: false,
            tc: self.tc,
            chunk: self.chunk,
            label: "putmem_nbi",
        });
        self
    }

    /// `putmem_signal`: blocking put + remote signal update on delivery.
    pub fn putmem_signal(
        &mut self,
        src: Slice,
        dst: Slice,
        sig_idx: usize,
        op: SigOp,
        value: u64,
    ) -> &mut Self {
        assert_eq!(src.rank, self.pe);
        let bytes = self.ctx.bytes(src.len);
        let sig = SigRef {
            rank: dst.rank,
            idx: sig_idx,
        };
        self.b.op(Op::Put {
            src,
            dst,
            bytes,
            signal: Some((sig, op, value)),
            blocking: true,
            tc: self.tc,
            chunk: self.chunk,
            label: "putmem_signal",
        });
        self
    }

    /// `putmem_signal_nbi`.
    pub fn putmem_signal_nbi(
        &mut self,
        src: Slice,
        dst: Slice,
        sig_idx: usize,
        op: SigOp,
        value: u64,
    ) -> &mut Self {
        assert_eq!(src.rank, self.pe);
        let bytes = self.ctx.bytes(src.len);
        let sig = SigRef {
            rank: dst.rank,
            idx: sig_idx,
        };
        self.b.op(Op::Put {
            src,
            dst,
            bytes,
            signal: Some((sig, op, value)),
            blocking: false,
            tc: self.tc,
            chunk: self.chunk,
            label: "putmem_signal_nbi",
        });
        self
    }

    /// `getmem`: blocking one-sided read from remote `src` into local `dst`.
    pub fn getmem(&mut self, src: Slice, dst: Slice) -> &mut Self {
        assert_eq!(dst.rank, self.pe, "getmem destination must be local");
        let bytes = self.ctx.bytes(src.len);
        self.b.op(Op::Get {
            src,
            dst,
            bytes,
            blocking: true,
            tc: self.tc,
            label: "getmem",
        });
        self
    }

    /// `getmem_nbi`.
    pub fn getmem_nbi(&mut self, src: Slice, dst: Slice) -> &mut Self {
        assert_eq!(dst.rank, self.pe);
        let bytes = self.ctx.bytes(src.len);
        self.b.op(Op::Get {
            src,
            dst,
            bytes,
            blocking: false,
            tc: self.tc,
            label: "getmem_nbi",
        });
        self
    }

    /// `broadcast` to all other PEs (loop of puts; the optimized NVLink
    /// path is [`Self::multimem_st`]).
    pub fn broadcast(&mut self, src: Slice) -> &mut Self {
        for r in 0..self.ctx.n_pes() {
            if r != self.pe {
                self.putmem_nbi(src, src.on_rank(r));
            }
        }
        self.quiet()
    }

    // -- synchronization -------------------------------------------------------

    /// `quiet`: fence all outstanding non-blocking transfers of this task.
    pub fn quiet(&mut self) -> &mut Self {
        self.b.op(Op::Quiet);
        self
    }

    /// `fence`: ordering fence. Our DES delivers a task's transfers in
    /// issue order per destination, so fence == quiet (conservative).
    pub fn fence(&mut self) -> &mut Self {
        self.quiet()
    }

    /// `barrier_all`: one task per rank participates.
    pub fn barrier_all(&mut self, id: usize) -> &mut Self {
        let expect = self.ctx.n_pes();
        self.barrier_group(id, Scope::World, expect)
    }

    /// Barrier with an explicit participating-task count (several
    /// async-tasks per rank may join one barrier).
    pub fn barrier_group(&mut self, id: usize, scope: Scope, expect: usize) -> &mut Self {
        self.b.op(Op::Barrier { scope, id, expect });
        self
    }

    /// `sync_all` — identical timing model to barrier_all here.
    pub fn sync_all(&mut self, id: usize) -> &mut Self {
        self.barrier_all(id)
    }

    /// Node-scoped barrier (`barrier_all_intra_node`, Alg. 5): one task
    /// per rank of this node participates.
    pub fn barrier_node(&mut self, id: usize) -> &mut Self {
        let expect = self.ctx.local_world_size();
        self.barrier_group(id, Scope::Node(self.ctx.node_of(self.pe)), expect)
    }

    // -- signals ---------------------------------------------------------------

    /// `int_p` / `notify` / `signal_op`: update a (possibly remote) signal.
    pub fn notify(&mut self, pe: usize, sig_idx: usize, op: SigOp, value: u64) -> &mut Self {
        self.b.op(Op::SetSignal {
            sig: SigRef { rank: pe, idx: sig_idx },
            op,
            value,
        });
        self
    }

    /// `signal_wait_until(sig, EQ/GE, v)` on a local signal.
    pub fn signal_wait_until(&mut self, sig_idx: usize, cond: SigCond, value: u64) -> &mut Self {
        self.b.op(Op::WaitSignal {
            idx: sig_idx,
            cond,
            value,
        });
        self
    }

    /// `wait` (+ implicit `consume_token`): local spin until equality.
    /// The data dependency the paper builds with `consume_token` is
    /// enforced structurally here: ops after the wait cannot start early
    /// because tasks are sequential.
    pub fn wait(&mut self, sig_idx: usize, value: u64) -> &mut Self {
        self.signal_wait_until(sig_idx, SigCond::Eq, value)
    }

    /// `atomic_add` on a remote signal (used as arrival counters).
    pub fn atomic_add(&mut self, pe: usize, sig_idx: usize, value: u64) -> &mut Self {
        self.notify(pe, sig_idx, SigOp::Add, value)
    }

    // -- low-latency & multimem (§3.4) ------------------------------------------

    /// LL-protocol send: data+flag in 8-byte granules, double wire size,
    /// no signal round-trip. Receiver pairs with [`Self::recv_ll`].
    pub fn ll_put(&mut self, src: Slice, dst: Slice) -> &mut Self {
        assert_eq!(src.rank, self.pe);
        let bytes = self.ctx.bytes(src.len);
        self.b.op(Op::LLPut {
            src,
            dst,
            bytes,
            tc: self.tc,
            chunk: self.chunk,
        });
        self
    }

    /// LL-protocol receive: spin on the in-band flags of `dst`
    /// (`recv_LL_pack` / `recv_LL_unpack`; the unpack cost is folded into
    /// the doubled send size).
    pub fn recv_ll(&mut self, dst: Slice) -> &mut Self {
        assert_eq!(dst.rank, self.pe);
        self.b.op(Op::LLWait { dst });
        self
    }

    /// `multimem_st`: NVLink broadcast of `src` to all node peers (§3.4).
    pub fn multimem_st(&mut self, src: Slice) -> &mut Self {
        assert_eq!(src.rank, self.pe);
        let bytes = self.ctx.bytes(src.len);
        self.b.op(Op::MultimemSt { src, bytes, ll: false });
        self
    }

    /// `multimem_st` of an LL-staged slice: payload carries in-band flags,
    /// so receivers' [`Self::recv_ll`] on the same symmetric slice
    /// observes arrival (Alg. 4 lines 8/18). Wire size doubles.
    pub fn multimem_st_ll(&mut self, src: Slice) -> &mut Self {
        assert_eq!(src.rank, self.pe);
        let bytes = self.ctx.bytes(src.len) * 2.0;
        self.b.op(Op::MultimemSt { src, bytes, ll: true });
        self
    }

    /// `multimem_ld_reduce`: load the same symmetric slice from all node
    /// peers and reduce locally. Modeled as a compute-side reduction that
    /// reads peers over NVLink ingress: we charge a get of (peers-1) slices
    /// plus the local add.
    pub fn multimem_ld_reduce(&mut self, symm: Slice, dst: Slice) -> &mut Self {
        assert_eq!(dst.rank, self.pe);
        let node = self.ctx.node_of(self.pe);
        let mut srcs = Vec::new();
        for r in 0..self.ctx.n_pes() {
            if self.ctx.node_of(r) == node {
                srcs.push(symm.on_rank(r));
            }
        }
        for s in &srcs {
            if s.rank != self.pe {
                self.getmem_nbi(*s, dst); // timing: pull peers' copies
            }
        }
        self.quiet();
        let bytes = self.ctx.bytes(symm.len) * srcs.len() as f64;
        self.b.op(Op::Compute {
            cost: ComputeCost::Reduce { bytes },
            numeric: NumericOp::ReduceAdd {
                srcs,
                dst,
                zero_dst: true,
            },
            label: "multimem_ld_reduce",
        });
        self
    }

    // -- compute ------------------------------------------------------------------

    /// Raw op escape hatch (compute tiles, sleeps).
    pub fn op(&mut self, op: Op) -> &mut Self {
        self.b.op(op);
        self
    }

    /// Local copy on the copy engine (cudaMemcpyAsync D2D local).
    pub fn copy_local(&mut self, src: Slice, dst: Slice) -> &mut Self {
        assert_eq!(src.rank, self.pe);
        assert_eq!(dst.rank, self.pe);
        let bytes = self.ctx.bytes(src.len);
        self.b.op(Op::Put {
            src,
            dst,
            bytes,
            signal: None,
            blocking: true,
            tc: self.tc,
            chunk: None,
            label: "copy_local",
        });
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::mem::{BufId, SymmetricHeap};
    use crate::sim::{NoopExecutor, Sim};
    use crate::topology::Topology;

    fn ctx() -> ShmemCtx {
        ShmemCtx::new(ClusterSpec::h800(1, 4), DType::BF16)
    }

    #[test]
    fn geometry_helpers() {
        let c = ShmemCtx::new(ClusterSpec::h800(2, 8), DType::BF16);
        assert_eq!(c.n_pes(), 16);
        assert_eq!(c.local_world_size(), 8);
        assert_eq!(c.n_nodes(), 2);
        assert_eq!(c.node_of(10), 1);
        assert_eq!(c.local_rank_of(10), 2);
        assert_eq!(c.bytes(100), 200.0); // bf16
    }

    #[test]
    fn stripe_rail_follows_the_fabric_policy() {
        use crate::config::{FabricSpec, RailPolicy};
        let static_ctx = ShmemCtx::new(
            ClusterSpec::h800(2, 8).with_fabric(FabricSpec::rail_optimized(2, 1.0)),
            DType::BF16,
        );
        let mut t = static_ctx.task(0, "t");
        t.stripe_rail(1);
        assert_eq!(t.tc(), TrafficClass::Rail(1), "static policy pins");

        let adaptive_ctx = ShmemCtx::new(
            ClusterSpec::h800(2, 8).with_fabric(
                FabricSpec::rail_optimized(2, 1.0).with_rail_policy(RailPolicy::Adaptive),
            ),
            DType::BF16,
        );
        let mut t = adaptive_ctx.task(0, "t");
        t.stripe_rail(1);
        assert_eq!(
            t.tc(),
            TrafficClass::Auto,
            "adaptive policy defers to the router"
        );
        // explicit pins are never rewritten by the policy
        t.on_rails(0, 1);
        assert_eq!(t.tc(), TrafficClass::Rails { tx: 0, rx: 1 });
    }

    #[test]
    fn chunk_tagging_is_stream_modal() {
        let c = ctx();
        let mut t = c.task(0, "t");
        assert_eq!(t.chunk(), None, "untagged by default");
        t.chunk_meta(4096.0, 0);
        let src = Slice::new(0, BufId(0), 0, 4);
        let dst = Slice::new(1, BufId(0), 0, 4);
        t.putmem_nbi(src, dst);
        t.clear_chunk();
        t.putmem_nbi(src, dst);
        let spec = t.build();
        match (&spec.ops[0], &spec.ops[1]) {
            (Op::Put { chunk: Some(m), .. }, Op::Put { chunk: None, .. }) => {
                assert_eq!(m.remaining, 4096.0);
                assert_eq!(m.deadline, 0);
            }
            other => panic!("chunk tag must follow the modal state: {other:?}"),
        }
    }

    #[test]
    fn putmem_asserts_local_source() {
        let c = ctx();
        let mut t = c.task(0, "t");
        let src = Slice::new(1, BufId(0), 0, 4); // wrong rank
        let dst = Slice::new(2, BufId(0), 0, 4);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.putmem(src, dst);
        }));
        assert!(r.is_err());
    }

    #[test]
    fn primitives_compose_into_working_program() {
        // push-mode exchange: rank 0 puts with signal; rank 1 waits then
        // pulls back. Exercises putmem_signal, signal_wait_until, getmem.
        let c = ctx();
        let topo = Topology::build(c.cluster);
        let mut heap = SymmetricHeap::new(4, 16);
        let buf = heap.alloc("x", 8);
        heap.write(Slice::new(0, buf, 0, 4), &[5.0; 4]);

        let mut prog = crate::program::Program::new();
        let mut t0 = c.task(0, "t0").on_copy_engine();
        t0.putmem_signal(
            Slice::new(0, buf, 0, 4),
            Slice::new(1, buf, 0, 4),
            0,
            SigOp::Set,
            1,
        );
        prog.push(t0.build());

        let mut t1 = c.task(1, "t1").with_sms(1);
        t1.signal_wait_until(0, SigCond::Eq, 1);
        t1.getmem(Slice::new(0, buf, 0, 4), Slice::new(1, buf, 4, 4));
        prog.push(t1.build());

        let sim = Sim::new(&topo);
        sim.run(&prog, &mut heap, &mut NoopExecutor).unwrap();
        assert_eq!(heap.read(Slice::new(1, buf, 0, 4)), &[5.0; 4]);
        assert_eq!(heap.read(Slice::new(1, buf, 4, 4)), &[5.0; 4]);
    }

    #[test]
    fn broadcast_reaches_all_ranks() {
        let c = ctx();
        let topo = Topology::build(c.cluster);
        let mut heap = SymmetricHeap::new(4, 16);
        let buf = heap.alloc("x", 4);
        heap.write(Slice::new(2, buf, 0, 4), &[8.0; 4]);
        let mut prog = crate::program::Program::new();
        let mut t = c.task(2, "bcast").on_copy_engine();
        t.broadcast(Slice::new(2, buf, 0, 4));
        prog.push(t.build());
        let sim = Sim::new(&topo);
        sim.run(&prog, &mut heap, &mut NoopExecutor).unwrap();
        for r in 0..4 {
            assert_eq!(heap.read(Slice::new(r, buf, 0, 4)), &[8.0; 4]);
        }
    }

    #[test]
    fn multimem_ld_reduce_sums_node_copies() {
        let c = ctx();
        let topo = Topology::build(c.cluster);
        let mut heap = SymmetricHeap::new(4, 16);
        let partial = heap.alloc("partial", 2);
        let out = heap.alloc("out", 2);
        for r in 0..4 {
            heap.write(Slice::new(r, partial, 0, 2), &[r as f32, 1.0]);
        }
        let mut prog = crate::program::Program::new();
        let mut t = c.task(1, "ldred").with_sms(16);
        t.multimem_ld_reduce(Slice::new(1, partial, 0, 2), Slice::new(1, out, 0, 2));
        prog.push(t.build());
        let sim = Sim::new(&topo);
        sim.run(&prog, &mut heap, &mut NoopExecutor).unwrap();
        assert_eq!(heap.read(Slice::new(1, out, 0, 2)), &[6.0, 4.0]);
    }
}
