//! The async-task op IR (§2.1's three concepts made executable).
//!
//! Every *async-task* — a communication kernel, a compute kernel, a copy
//! stream — is a [`TaskSpec`]: a straight-line sequence of [`Op`]s bound to
//! a rank and a resource reservation (SMs / copy engine). Collectives and
//! overlapped kernels are *programs*: one or more tasks per rank, launched
//! concurrently, synchronizing only through signals and barriers — exactly
//! the paper's MPMD model.
//!
//! The builders in `crate::shmem` provide the Table-1 primitive names; this
//! module is the IR they lower to and the DES engine executes.

use crate::config::TrafficClass;
use crate::mem::Slice;

/// How a signal is updated (`signal_op` semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SigOp {
    Set,
    Add,
}

/// Wait condition (`signal_wait_until` / `wait`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SigCond {
    Eq,
    Ge,
}

/// Chunk-scheduler metadata carried by split/chunked inter-node pieces
/// (`config::ChunkSched`): how much of the owning stream is still
/// unsent after this piece, and how urgently a consumer is waiting.
/// Pieces without metadata (`chunk: None`) always post eagerly — the
/// scheduler only ever reorders tagged pieces, so untagged programs are
/// bit-identical under every policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChunkMeta {
    /// Wire bytes remaining in this piece's stream *including* this
    /// piece — the SRPF key (shortest remaining path first).
    pub remaining: f64,
    /// Consumer urgency class: `0` for pieces that gate a blocked
    /// FFN/GEMM consumer (combine legs, AG segments feeding tiles),
    /// `u32::MAX` for bulk traffic nothing is waiting on yet. The
    /// `Deadline` policy orders by this first.
    pub deadline: u32,
}

impl ChunkMeta {
    /// Bulk piece: nothing blocks on it yet (deadline `u32::MAX`).
    pub fn bulk(remaining: f64) -> Self {
        ChunkMeta {
            remaining,
            deadline: u32::MAX,
        }
    }

    /// Consumer-gating piece: a compute tile waits on it (deadline 0).
    pub fn gating(remaining: f64) -> Self {
        ChunkMeta {
            remaining,
            deadline: 0,
        }
    }
}

/// A signal cell in symmetric memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SigRef {
    pub rank: usize,
    pub idx: usize,
}

/// Barrier scope.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scope {
    World,
    Node(usize),
}

/// Analytic duration model for a compute op, evaluated against the
/// hardware model and the owning task's SM reservation.
#[derive(Debug, Clone)]
pub enum ComputeCost {
    /// Dense GEMM; `vendor` selects cuBLAS/rocBLAS efficiency instead of
    /// Triton's (~0.95x) — used by the PyTorch and FLUX baselines.
    Gemm { flops: f64, vendor: bool },
    /// Elementwise reduction over `bytes` (read+add+write), SM-scaled.
    Reduce { bytes: f64 },
    /// Memory-bandwidth-bound kernel streaming `bytes` from HBM
    /// (flash decoding).
    MemBound { bytes: f64 },
    /// Fixed duration (host-side work, protocol overheads).
    Fixed { secs: f64 },
}

/// The real data operation attached to an op, applied by the engine at op
/// completion when numerics are enabled.
#[derive(Debug, Clone)]
pub enum NumericOp {
    None,
    /// `dst = src` (already implied for transfer ops; explicit for local
    /// compute-engine copies).
    Copy { src: Slice, dst: Slice },
    /// `dst += sum(srcs)`; if `zero_dst`, `dst` is cleared first.
    ReduceAdd {
        srcs: Vec<Slice>,
        dst: Slice,
        zero_dst: bool,
    },
    /// Executor call (XLA artifact or native fallback): outs = entry(args).
    Call {
        entry: String,
        args: Vec<Slice>,
        outs: Vec<Slice>,
    },
}

/// One instruction of an async-task.
#[derive(Debug, Clone)]
pub enum Op {
    /// One-sided write `src -> dst` (ranks may differ). `bytes` is the
    /// *timing* size (dtype-scaled; doubled for LL). Optional remote
    /// signal update on delivery (putmem_signal). `blocking=false` is the
    /// `_nbi` variant: the task continues immediately and `Quiet` fences.
    Put {
        src: Slice,
        dst: Slice,
        bytes: f64,
        signal: Option<(SigRef, SigOp, u64)>,
        blocking: bool,
        /// Fabric path selection for inter-node routes: explicit rail
        /// pins pass through the router verbatim; `Auto` is resolved per
        /// message at simulation time under the fabric's `RailPolicy`
        /// (deterministic hash, or emptiest plane by live occupancy).
        tc: TrafficClass,
        /// Chunk-scheduler metadata; `None` (untagged) posts eagerly
        /// under every [`crate::config::ChunkSched`] policy.
        chunk: Option<ChunkMeta>,
        label: &'static str,
    },
    /// One-sided read `src -> dst` where `src` is remote (getmem).
    Get {
        src: Slice,
        dst: Slice,
        bytes: f64,
        blocking: bool,
        tc: TrafficClass,
        label: &'static str,
    },
    /// `multimem.st`: broadcast `src` to the same symmetric slice on all
    /// other ranks of the source's node in a single hardware op (§3.4).
    /// With `ll`, the payload carries LL flags so receivers' `LLWait` on
    /// the destination slice observes arrival (Alg. 4 lines 8/18).
    MultimemSt { src: Slice, bytes: f64, ll: bool },
    /// LL-protocol send: data+flag packed in 8-byte words, 2x payload, no
    /// separate signal; the receiver spin-waits with `LLWait` keyed by the
    /// destination slice.
    LLPut {
        src: Slice,
        dst: Slice,
        bytes: f64,
        tc: TrafficClass,
        /// Chunk-scheduler metadata; `None` (untagged) posts eagerly
        /// under every [`crate::config::ChunkSched`] policy.
        chunk: Option<ChunkMeta>,
    },
    /// Spin until the LL flags for `dst` indicate arrival.
    LLWait { dst: Slice },
    /// Update a (possibly remote) signal: `notify` / `signal_op` /
    /// `atomic_add` / `red_release`.
    SetSignal {
        sig: SigRef,
        op: SigOp,
        value: u64,
    },
    /// Spin on a *local* signal until the condition holds (`wait`,
    /// `signal_wait_until`, `ld_acquire` loops).
    WaitSignal {
        idx: usize,
        cond: SigCond,
        value: u64,
    },
    /// Fence completion of this task's outstanding non-blocking transfers
    /// (OpenSHMEM `quiet`).
    Quiet,
    /// Barrier over a scope (`barrier_all` / node barrier). `expect` is
    /// the number of participating *tasks* (several async-tasks per rank
    /// may join one barrier); the scope sets the release latency.
    Barrier {
        scope: Scope,
        id: usize,
        expect: usize,
    },
    /// Occupy the task's SMs for the modeled duration, then apply the
    /// numeric op. Every tile of the consumer GEMM is one of these.
    Compute {
        cost: ComputeCost,
        numeric: NumericOp,
        label: &'static str,
    },
    /// Pure time (host logic, protocol constants).
    Sleep { secs: f64 },
}

impl Op {
    /// Short label for traces.
    pub fn label(&self) -> &'static str {
        match self {
            Op::Put { label, .. } => label,
            Op::Get { label, .. } => label,
            Op::MultimemSt { .. } => "multimem_st",
            Op::LLPut { .. } => "ll_put",
            Op::LLWait { .. } => "ll_wait",
            Op::SetSignal { .. } => "set_signal",
            Op::WaitSignal { .. } => "wait_signal",
            Op::Quiet => "quiet",
            Op::Barrier { .. } => "barrier",
            Op::Compute { label, .. } => label,
            Op::Sleep { .. } => "sleep",
        }
    }
}

/// Which execution engine an async-task is mapped onto (§3.8 resource
/// partition): copy-engine streams need no SMs; kernels reserve SMs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineClass {
    /// DMA stream (cudaMemcpyAsync / hipMemcpyAsync): data movement only.
    CopyEngine,
    /// Device kernel holding an SM reservation for its lifetime.
    SmKernel,
    /// Host-side logic (launch loops, stream waits).
    Host,
}

/// One async-task: a rank-bound op sequence with a resource reservation.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub rank: usize,
    pub name: String,
    pub engine: EngineClass,
    /// SMs reserved for the task's lifetime (0 for CopyEngine/Host).
    pub sms: u32,
    /// Launch delay before the first op (kernel-launch overhead).
    pub start_delay: f64,
    pub ops: Vec<Op>,
}

/// A whole-world program: every rank's tasks, launched together at t=0.
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub tasks: Vec<TaskSpec>,
}

impl Program {
    pub fn new() -> Self {
        Program::default()
    }

    pub fn push(&mut self, t: TaskSpec) -> usize {
        self.tasks.push(t);
        self.tasks.len() - 1
    }

    /// Total op count (diagnostics).
    pub fn op_count(&self) -> usize {
        self.tasks.iter().map(|t| t.ops.len()).sum()
    }

    /// Largest signal index referenced — the required signal-pad size.
    pub fn max_signal_idx(&self) -> usize {
        let mut max = 0usize;
        for t in &self.tasks {
            for op in &t.ops {
                let idx = match op {
                    Op::Put {
                        signal: Some((s, _, _)),
                        ..
                    } => s.idx,
                    Op::SetSignal { sig, .. } => sig.idx,
                    Op::WaitSignal { idx, .. } => *idx,
                    _ => 0,
                };
                max = max.max(idx);
            }
        }
        max
    }

    /// SM oversubscription check per rank: the *static* reservations of
    /// concurrently-launched kernels must fit the device (the §3.8
    /// partition discipline).
    pub fn peak_sm_demand(&self, rank: usize) -> u32 {
        self.tasks
            .iter()
            .filter(|t| t.rank == rank)
            .map(|t| t.sms)
            .sum()
    }
}

/// Fluent builder for one task.
pub struct TaskBuilder {
    spec: TaskSpec,
}

impl TaskBuilder {
    pub fn new(rank: usize, name: impl Into<String>) -> Self {
        TaskBuilder {
            spec: TaskSpec {
                rank,
                name: name.into(),
                engine: EngineClass::SmKernel,
                sms: 0,
                start_delay: 0.0,
                ops: Vec::new(),
            },
        }
    }

    pub fn engine(mut self, e: EngineClass) -> Self {
        self.spec.engine = e;
        self
    }

    pub fn sms(mut self, n: u32) -> Self {
        self.spec.sms = n;
        self
    }

    pub fn start_delay(mut self, d: f64) -> Self {
        self.spec.start_delay = d;
        self
    }

    pub fn op(&mut self, op: Op) -> &mut Self {
        self.spec.ops.push(op);
        self
    }

    pub fn rank(&self) -> usize {
        self.spec.rank
    }

    pub fn build(self) -> TaskSpec {
        self.spec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::BufId;

    fn slice(rank: usize) -> Slice {
        Slice::new(rank, BufId(0), 0, 8)
    }

    #[test]
    fn builder_collects_ops() {
        let mut b = TaskBuilder::new(2, "t").sms(16).start_delay(1e-6);
        b.op(Op::Sleep { secs: 1.0 });
        b.op(Op::WaitSignal {
            idx: 3,
            cond: SigCond::Eq,
            value: 1,
        });
        let t = b.build();
        assert_eq!(t.rank, 2);
        assert_eq!(t.sms, 16);
        assert_eq!(t.ops.len(), 2);
    }

    #[test]
    fn program_signal_pad_requirement() {
        let mut p = Program::new();
        let mut b = TaskBuilder::new(0, "a");
        b.op(Op::SetSignal {
            sig: SigRef { rank: 1, idx: 17 },
            op: SigOp::Set,
            value: 1,
        });
        p.push(b.build());
        assert_eq!(p.max_signal_idx(), 17);
    }

    #[test]
    fn peak_sm_demand_sums_static_reservations() {
        let mut p = Program::new();
        p.push(TaskBuilder::new(0, "gemm").sms(116).build());
        p.push(TaskBuilder::new(0, "p2p").sms(1).build());
        p.push(TaskBuilder::new(1, "gemm").sms(116).build());
        assert_eq!(p.peak_sm_demand(0), 117);
        assert_eq!(p.peak_sm_demand(1), 116);
    }

    #[test]
    fn op_labels() {
        assert_eq!(
            Op::Put {
                src: slice(0),
                dst: slice(1),
                bytes: 1.0,
                signal: None,
                blocking: true,
                tc: Default::default(),
                chunk: None,
                label: "put_chunk",
            }
            .label(),
            "put_chunk"
        );
        assert_eq!(Op::Quiet.label(), "quiet");
    }
}
