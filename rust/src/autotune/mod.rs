//! Distributed autotuner (§3.8).
//!
//! Unlike single-device autotuners, tuning an *overlapping* kernel means
//! profiling whole multi-rank programs: every trial must (1) wrap the
//! complete target function — communication + computation + host launch —
//! (2) reset all signals between trials (a stale signal would satisfy the
//! next trial's waits and corrupt both timing and semantics), and
//! (3) aggregate a single globally-unified best configuration across
//! ranks. This module implements those semantics over the DES.

use crate::config::{ChunkSched, RailPolicy};
use crate::mem::SymmetricHeap;

/// One evaluated configuration.
#[derive(Debug, Clone)]
pub struct Trial<C> {
    pub config: C,
    /// Virtual latency of the whole target function (s).
    pub latency: f64,
}

/// Tuning outcome.
#[derive(Debug, Clone)]
pub struct TuneResult<C> {
    pub best: Trial<C>,
    pub trials: Vec<Trial<C>>,
    pub name: String,
}

impl<C: std::fmt::Debug> TuneResult<C> {
    /// Render a small report table.
    pub fn render(&self) -> String {
        let mut t = crate::util::Table::new(&format!("autotune: {}", self.name))
            .header(&["config", "latency", "best"]);
        for tr in &self.trials {
            t.row(&[
                format!("{:?}", tr.config),
                crate::util::stats::fmt_time(tr.latency),
                if tr.latency == self.best.latency { "*" } else { "" }.to_string(),
            ]);
        }
        t.render()
    }
}

/// Per-rank measurement: the simulated world reports one latency per rank
/// (on real hardware each rank profiles locally; makespans can differ by
/// rank-local noise). The *global* best is chosen on the aggregated
/// worst-rank latency — the paper's "globally unified best configuration".
#[derive(Debug, Clone)]
pub struct RankMeasurements {
    pub per_rank: Vec<f64>,
}

impl RankMeasurements {
    /// The latency the collective actually exhibits: the slowest rank.
    pub fn aggregate(&self) -> f64 {
        self.per_rank.iter().cloned().fold(0.0, f64::max)
    }
}

/// Tune over `configs`. The evaluator builds + runs the whole target
/// function for one config and returns per-rank latencies. Signals are
/// reset in the shared heap before every trial.
pub fn tune<C: Clone + std::fmt::Debug>(
    name: &str,
    configs: &[C],
    heap: &mut SymmetricHeap,
    mut eval: impl FnMut(&C, &mut SymmetricHeap) -> Result<RankMeasurements, String>,
) -> Result<TuneResult<C>, String> {
    if configs.is_empty() {
        return Err(format!("autotune '{name}': empty config space"));
    }
    let mut trials = Vec::with_capacity(configs.len());
    for cfg in configs {
        // §3.8: reset every signal before re-profiling the target
        heap.reset_signals();
        let meas = eval(cfg, heap)?;
        trials.push(Trial {
            config: cfg.clone(),
            latency: meas.aggregate(),
        });
    }
    let best = trials
        .iter()
        .min_by(|a, b| a.latency.partial_cmp(&b.latency).unwrap())
        .unwrap()
        .clone();
    Ok(TuneResult {
        best,
        trials,
        name: name.to_string(),
    })
}

/// Convenience: tune a rebuild-per-trial program (the common case where
/// each config produces a fresh program + heap, e.g. tile sizes).
pub fn tune_rebuild<C: Clone + std::fmt::Debug>(
    name: &str,
    configs: &[C],
    mut eval: impl FnMut(&C) -> Result<f64, String>,
) -> Result<TuneResult<C>, String> {
    if configs.is_empty() {
        return Err(format!("autotune '{name}': empty config space"));
    }
    let mut trials = Vec::with_capacity(configs.len());
    for cfg in configs {
        let latency = eval(cfg)?;
        trials.push(Trial {
            config: cfg.clone(),
            latency,
        });
    }
    let best = trials
        .iter()
        .min_by(|a, b| a.latency.partial_cmp(&b.latency).unwrap())
        .unwrap()
        .clone();
    Ok(TuneResult {
        best,
        trials,
        name: name.to_string(),
    })
}

/// Tune the fabric's rail-selection policy (§3.8 made fabric-aware): the
/// [`RailPolicy`] is a tunable axis exactly like tile sizes or the SM
/// partition — the evaluator rebuilds and runs the whole target function
/// under each policy (rebuilding the cluster with
/// `FabricSpec::with_rail_policy`) and the globally-best configuration
/// wins. Static round-robin striping wins on uniform traffic (no
/// occupancy tracking noise, perfect balance by construction); the
/// congestion-aware router wins when message sizes or destinations are
/// skewed (see `collectives::alltoall::a2a_skew`).
pub fn tune_rail_policy(
    name: &str,
    mut eval: impl FnMut(RailPolicy) -> Result<f64, String>,
) -> Result<TuneResult<RailPolicy>, String> {
    tune_rebuild(name, &[RailPolicy::Static, RailPolicy::Adaptive], |p| {
        eval(*p)
    })
}

/// Tune the EP **dispatch chunking** jointly with the rail policy: the
/// grid is `{Static, Adaptive} x splits`, where the split factor is how
/// many LL sub-messages each routed dispatch chunk is cut into
/// (`A2aCfg::split` / `A2aCfg::with_split`). Splitting engages several
/// NIC planes per logical message — a win when a sender has fewer large
/// messages than rails — at the cost of one post overhead per piece; the
/// tuner rebuilds and profiles the whole target function per grid point
/// exactly like [`tune_rail_policy`] does per policy.
pub fn tune_dispatch_chunking(
    name: &str,
    splits: &[usize],
    mut eval: impl FnMut(RailPolicy, usize) -> Result<f64, String>,
) -> Result<TuneResult<(RailPolicy, usize)>, String> {
    assert!(splits.iter().all(|&s| s >= 1), "split factors must be >= 1");
    let mut grid = Vec::with_capacity(2 * splits.len());
    for policy in [RailPolicy::Static, RailPolicy::Adaptive] {
        for &s in splits {
            grid.push((policy, s));
        }
    }
    tune_rebuild(name, &grid, |&(p, s)| eval(p, s))
}

/// Tune the chunk-issue scheduling policy (§3.8 over the *when* of
/// communication, where [`tune_rail_policy`] tunes the *where*): the
/// [`ChunkSched`] is a tunable axis like any other — the evaluator
/// rebuilds the cluster with `FabricSpec::with_chunk_sched` and profiles
/// the whole target function under each policy. Eager FIFO wins when
/// nothing contends (no reorder bookkeeping, maximal pipelining);
/// `Srpf`/`Deadline` win mixed-traffic shapes where bulk backlogs delay
/// small consumer-gating pieces (see
/// `collectives::alltoall::sched_mixed`).
pub fn tune_chunk_sched(
    name: &str,
    mut eval: impl FnMut(ChunkSched) -> Result<f64, String>,
) -> Result<TuneResult<ChunkSched>, String> {
    tune_rebuild(
        name,
        &[ChunkSched::Fifo, ChunkSched::Srpf, ChunkSched::Deadline],
        |s| eval(*s),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_minimum() {
        let r = tune_rebuild("t", &[1u32, 2, 3], |c| Ok(10.0 / *c as f64)).unwrap();
        assert_eq!(r.best.config, 3);
        assert_eq!(r.trials.len(), 3);
    }

    #[test]
    fn empty_space_errors() {
        assert!(tune_rebuild::<u32>("t", &[], |_| Ok(0.0)).is_err());
    }

    #[test]
    fn signals_reset_between_trials() {
        let mut heap = SymmetricHeap::new(2, 4);
        let mut seen_dirty = false;
        let configs = [1u32, 2];
        tune("t", &configs, &mut heap, |_c, h| {
            // every trial must observe clean signals
            for r in 0..2 {
                for i in 0..4 {
                    if h.signal(r, i) != 0 {
                        seen_dirty = true;
                    }
                }
            }
            // dirty them for the next trial
            h.signal_set(0, 1, 99);
            Ok(RankMeasurements {
                per_rank: vec![1.0, 2.0],
            })
        })
        .unwrap();
        assert!(!seen_dirty, "a trial saw stale signals");
    }

    #[test]
    fn aggregate_is_worst_rank() {
        let m = RankMeasurements {
            per_rank: vec![1.0, 5.0, 2.0],
        };
        assert_eq!(m.aggregate(), 5.0);
    }

    #[test]
    fn render_marks_best() {
        let r = tune_rebuild("demo", &[4u32, 8], |c| Ok(*c as f64)).unwrap();
        let s = r.render();
        assert!(s.contains('*'));
        assert!(s.contains("demo"));
    }

    #[test]
    fn rail_policy_is_a_tunable_axis() {
        // On the deliberately skewed AllToAll the congestion-aware router
        // must win; the tuner should discover that from the trials alone.
        use crate::collectives::alltoall::{a2a_skew, A2aBufs, A2aCfg};
        use crate::collectives::ProgBuild;
        use crate::config::{ClusterSpec, DType, FabricSpec};
        use crate::mem::SymmetricHeap;
        use crate::shmem::ShmemCtx;
        use crate::sim::{NoopExecutor, Sim, SimConfig};
        use crate::topology::Topology;
        let r = tune_rail_policy("rail policy (skewed a2a)", |policy| {
            let cluster = ClusterSpec::h800(2, 8)
                .with_fabric(FabricSpec::rail_optimized(2, 1.0).with_rail_policy(policy));
            let ctx = ShmemCtx::new(cluster, DType::BF16);
            let topo = Topology::build(cluster);
            let mut heap = SymmetricHeap::new(ctx.n_pes(), 4 * ctx.n_pes());
            let bufs = A2aBufs::alloc(&mut heap, &ctx, 8192);
            let mut pb = ProgBuild::new();
            a2a_skew(&ctx, &bufs, &mut pb, &A2aCfg::ours(), 8.0);
            let sim = Sim::with_config(
                &topo,
                SimConfig {
                    numerics: false,
                    trace: false,
                },
            );
            sim.run(&pb.prog, &mut heap, &mut NoopExecutor)
                .map(|rep| rep.makespan)
                .map_err(|e| e.to_string())
        })
        .unwrap();
        assert_eq!(r.trials.len(), 2);
        assert_eq!(
            r.best.config,
            RailPolicy::Adaptive,
            "adaptive must win the skewed workload: {:?}",
            r.trials
        );
    }

    #[test]
    fn dispatch_chunking_is_a_tunable_axis() {
        // one big inter-node message per sender on a 2-rail fabric: an
        // unsplit stream rides a single plane; splitting engages both,
        // so the tuner must discover a split factor > 1
        use crate::collectives::alltoall::{a2a_ll, A2aBufs, A2aCfg};
        use crate::collectives::ProgBuild;
        use crate::config::{ClusterSpec, DType, FabricSpec};
        use crate::shmem::ShmemCtx;
        use crate::sim::{NoopExecutor, Sim, SimConfig};
        use crate::topology::Topology;
        let r = tune_dispatch_chunking("dispatch chunking (2-rail)", &[1, 2, 4], |policy, split| {
            let cluster = ClusterSpec::h800(2, 1)
                .with_fabric(FabricSpec::rail_optimized(2, 1.0).with_rail_policy(policy));
            let ctx = ShmemCtx::new(cluster, DType::BF16);
            let topo = Topology::build(cluster);
            let mut heap = SymmetricHeap::new(ctx.n_pes(), 16);
            let bufs = A2aBufs::alloc(&mut heap, &ctx, 1 << 16);
            let mut pb = ProgBuild::new();
            a2a_ll(&ctx, &bufs, &mut pb, &A2aCfg::ours().with_split(split));
            let sim = Sim::with_config(
                &topo,
                SimConfig {
                    numerics: false,
                    trace: false,
                },
            );
            sim.run(&pb.prog, &mut heap, &mut NoopExecutor)
                .map(|rep| rep.makespan)
                .map_err(|e| e.to_string())
        })
        .unwrap();
        assert_eq!(r.trials.len(), 6);
        assert!(
            r.best.config.1 > 1,
            "splitting must engage the second plane: {:?}",
            r.trials
        );
    }

    #[test]
    fn chunk_sched_is_a_tunable_axis() {
        // On the pinned mixed-traffic scenario (bulk EP-style backlog
        // contending with small consumer-gating segments over a tapered
        // spine) a contention-aware issue order must win; the tuner
        // should discover that from the trials alone.
        use crate::collectives::alltoall::run_sched_mixed;
        let r = tune_chunk_sched("chunk sched (mixed traffic)", run_sched_mixed).unwrap();
        assert_eq!(r.trials.len(), 3);
        assert_ne!(
            r.best.config,
            ChunkSched::Fifo,
            "a contention-aware policy must win the mixed workload: {:?}",
            r.trials
        );
    }

    #[test]
    fn tunes_a_real_overlapping_kernel() {
        // AMD AG+GEMM sub-chunk factor: the autotuner should prefer
        // multi-sub-chunk configs (they engage all mesh links).
        use crate::config::{ClusterSpec, GemmShape};
        use crate::coordinator::ag_gemm::{build, AgGemmVariant};
        use crate::topology::Topology;
        let cluster = ClusterSpec::mi308x(8);
        let topo = Topology::build(cluster);
        let shape = GemmShape::new(4096, 2048, 1024);
        let r = tune_rebuild("amd sub_chunks", &[1usize, 2, 4, 8], |&sc| {
            let (mut op, _b) = build(cluster, shape, AgGemmVariant::OursAmd { sub_chunks: sc });
            crate::coordinator::run_timing(&mut op, &topo).map_err(|e| e.to_string())
        })
        .unwrap();
        assert!(r.best.config >= 2, "expected sub-chunking to win: {:?}", r.best);
    }
}
