//! Tile-swizzle planners (§3.7, Figs. 7, 8, 10).
//!
//! A swizzle is the order in which a consumer kernel visits data *chunks*
//! (per-rank segments of the gathered/ scattered buffer). The right order
//! makes each chunk's computation start the moment its communication
//! lands, so the kernel never stalls: the paper's core overlap mechanism.

/// Chunk visit order for intra-node AG+GEMM on NVSwitch (Fig. 7, push
/// mode): start from the local chunk, then follow the *arrival* order of
/// the push AllGather — peer `r-1`'s shard arrives first (it sends to
/// `r` in its first step), then `r-2`, etc.
pub fn nv_push_order(rank: usize, ws: usize) -> Vec<usize> {
    let mut order = Vec::with_capacity(ws);
    for i in 0..ws {
        order.push((rank + ws - i) % ws);
    }
    order
}

/// Chunk visit order for pull-mode AG+GEMM (Fig. 7, pull): rank `r`
/// pulls `r+1, r+2, ...` itself, so compute follows ascending order.
pub fn nv_pull_order(rank: usize, ws: usize) -> Vec<usize> {
    (0..ws).map(|i| (rank + i) % ws).collect()
}

/// No-swizzle baseline: every rank walks chunks `0, 1, 2, ...` — what a
/// topology-unaware consumer does (head-of-line blocking on chunk 0).
pub fn identity_order(_rank: usize, ws: usize) -> Vec<usize> {
    (0..ws).collect()
}

/// AMD full-mesh AG+GEMM swizzle (Fig. 8): chunks are split into
/// `sub_chunks`; step 0 computes the local chunk while step-1 sub-chunks
/// are gathered from *all* peers at once; each later step computes one
/// sub-chunk slice across all peers. Returns `(chunk, sub)` pairs in
/// visit order.
pub fn amd_subchunk_order(rank: usize, ws: usize, sub_chunks: usize) -> Vec<(usize, usize)> {
    let mut order = Vec::with_capacity(ws * sub_chunks);
    // local chunk first (all its sub-chunks are resident)
    for s in 0..sub_chunks {
        order.push((rank, s));
    }
    // then sub-chunk s of every peer, peers rank-shifted for link balance
    for s in 0..sub_chunks {
        for i in 1..ws {
            order.push(((rank + i) % ws, s));
        }
    }
    order
}

/// Inter-node GEMM+RS chunk order (Fig. 10): each rank starts computing
/// the chunks *the other node needs* (so inter-node P2P starts early) and
/// within a node group starts at `local_rank + 1` (so the local copy of
/// the intra-node scatter lands last). Returns global chunk ids in
/// compute order.
pub fn inter_rs_order(rank: usize, nodes: usize, lws: usize) -> Vec<usize> {
    let node = rank / lws;
    let lr = rank % lws;
    let mut order = Vec::with_capacity(nodes * lws);
    for i in 0..nodes {
        let tn = (node + 1 + i) % nodes; // other nodes first
        for j in 0..lws {
            let tlr = (lr + 1 + j) % lws; // own chunk last within the group
            order.push(tn * lws + tlr);
        }
    }
    order
}

/// Inter-NUMA swizzle (Table 2 row 3): reorder a peer walk so consecutive
/// transfers alternate NUMA domains, spreading load across host links
/// (PCIe systems). `numa_of` maps rank -> NUMA domain.
pub fn numa_interleave(peers: &[usize], numa_of: impl Fn(usize) -> usize) -> Vec<usize> {
    let mut by_numa: std::collections::BTreeMap<usize, std::collections::VecDeque<usize>> =
        Default::default();
    for &p in peers {
        by_numa.entry(numa_of(p)).or_default().push_back(p);
    }
    let mut out = Vec::with_capacity(peers.len());
    while out.len() < peers.len() {
        for q in by_numa.values_mut() {
            if let Some(p) = q.pop_front() {
                out.push(p);
            }
        }
    }
    out
}

/// Validity check used by property tests: a swizzle must visit every
/// chunk exactly once.
pub fn is_permutation(order: &[usize], n: usize) -> bool {
    if order.len() != n {
        return false;
    }
    let mut seen = vec![false; n];
    for &c in order {
        if c >= n || seen[c] {
            return false;
        }
        seen[c] = true;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn push_order_starts_local_follows_arrivals() {
        assert_eq!(nv_push_order(0, 4), vec![0, 3, 2, 1]);
        assert_eq!(nv_push_order(2, 4), vec![2, 1, 0, 3]);
    }

    #[test]
    fn pull_order_ascends_from_local() {
        assert_eq!(nv_pull_order(1, 4), vec![1, 2, 3, 0]);
    }

    #[test]
    fn orders_are_permutations() {
        check("swizzle permutations", 128, |g| {
            let ws = g.usize_in(1, 17);
            let r = g.usize_in(0, ws);
            assert!(is_permutation(&nv_push_order(r, ws), ws));
            assert!(is_permutation(&nv_pull_order(r, ws), ws));
            assert!(is_permutation(&identity_order(r, ws), ws));
        });
    }

    #[test]
    fn first_chunk_is_always_local() {
        check("local first", 64, |g| {
            let ws = g.usize_in(1, 17);
            let r = g.usize_in(0, ws);
            assert_eq!(nv_push_order(r, ws)[0], r);
            assert_eq!(nv_pull_order(r, ws)[0], r);
        });
    }

    #[test]
    fn amd_order_covers_all_pairs_local_first() {
        let order = amd_subchunk_order(1, 4, 2);
        assert_eq!(order.len(), 8);
        assert_eq!(&order[..2], &[(1, 0), (1, 1)]);
        let mut set: Vec<_> = order.clone();
        set.sort_unstable();
        set.dedup();
        assert_eq!(set.len(), 8);
        // each later step touches all 3 peers (parallel links)
        let step1: Vec<usize> = order[2..5].iter().map(|&(c, _)| c).collect();
        assert_eq!(step1, vec![2, 3, 0]);
    }

    #[test]
    fn inter_rs_order_matches_fig10() {
        // 2 nodes x 4: rank 0 (node 0, lr 0) starts with node 1's chunks,
        // beginning at local rank 1 -> global chunk 5 (the Fig. 10 text:
        // "rank 0 starts its GEMM for the data required by rank 5")
        let order = inter_rs_order(0, 2, 4);
        assert_eq!(order[0], 5);
        assert!(is_permutation(&order, 8));
        // own chunk (0) is visited last
        assert_eq!(*order.last().unwrap(), 0);
        // all of node 1's chunks precede node 0's
        let pos = |c: usize| order.iter().position(|&x| x == c).unwrap();
        for remote in 4..8 {
            for local in 0..4 {
                assert!(pos(remote) < pos(local));
            }
        }
    }

    #[test]
    fn numa_interleave_alternates() {
        let peers = vec![1, 2, 3, 5, 6, 7];
        let order = numa_interleave(&peers, |r| if r < 4 { 0 } else { 1 });
        // alternating 0-domain, 1-domain
        assert_eq!(order, vec![1, 5, 2, 6, 3, 7]);
    }

    #[test]
    fn numa_interleave_is_permutation_property() {
        check("numa interleave", 64, |g| {
            let n = g.usize_in(1, 20);
            let peers: Vec<usize> = g.permutation(n);
            let out = numa_interleave(&peers, |r| r % 3);
            let mut a = out.clone();
            let mut b = peers.clone();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b);
        });
    }
}
