//! Resource partition (§3.8): spatially mapping compute and communication
//! onto disjoint processing units so every async-task finishes together
//! ("avoid long tails").

use crate::config::HardwareModel;

/// SM budget split for an inter-node GEMM+RS-style overlapping kernel
/// (Fig. 9's 116/copy-engine/1/16/132 assignment on H800).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Partition {
    /// SMs for the producer/consumer GEMM.
    pub gemm_sms: u32,
    /// SMs for the inter-node P2P block.
    pub p2p_sms: u32,
    /// SMs for the per-iteration local reduction.
    pub reduce1_sms: u32,
    /// SMs for the final reduction (after GEMM completes, full device).
    pub reduce2_sms: u32,
}

/// §3.5's bandwidth-balance sizing: the local reduction must keep up with
/// the intra-node scatter minus the P2P drain:
///
/// ```text
/// scatter time  = (lws-1) * B / intra_bw
/// p2p time      = B / inter_bw
/// reduce budget = scatter - p2p  =>  reduce_bw >= bytes_red / budget
/// ```
///
/// `inter_bw` is the *routed* capacity of one serialized P2P stream
/// (`Topology::inter_path_bw` / `FabricSpec::rail_path_bw`), not the raw
/// NIC speed: the Alg. 5 P2P block sends one message at a time, so on a
/// multi-rail fabric it only sees one rail's share (`nic_bw / rails`),
/// further thinned by leaf/spine oversubscription. Sizing the budget
/// from the scalar `hw.nic_bw` would mis-provision the reduction on
/// exactly those fabrics. On a flat single-rail fabric the two are
/// bit-identical.
///
/// On H800 (flat fabric) that threshold is ~470 GB/s => <= 15 SMs.
pub fn reduce_sms_for_balance(hw: &HardwareModel, lws: usize, inter_bw: f64) -> u32 {
    let b = 1.0; // per-rank chunk volume cancels out
    let scatter_t = (lws as f64 - 1.0) * b / hw.intra_bw;
    let p2p_t = b / inter_bw;
    // When scatter dominates (the paper's 8xH800 case) the reduction must
    // fit in scatter_t - p2p_t. When the NIC dominates, the reduction only
    // needs to hide under a fraction of the P2P window.
    let budget = (scatter_t - p2p_t).max(0.3 * p2p_t);
    // the reduction reads lws copies and writes one (~lws * B bytes moved)
    let need_bw = lws as f64 * b / budget;
    let sms = (need_bw / hw.sm_reduce_bw).ceil() as u32;
    sms.clamp(1, hw.sms / 4)
}

/// The paper's inter-node GEMM+RS partition on a given device.
/// `inter_bw` is the routed inter-node path capacity (see
/// [`reduce_sms_for_balance`]).
pub fn plan_inter_rs(hw: &HardwareModel, lws: usize, inter_bw: f64) -> Partition {
    let reduce1 = reduce_sms_for_balance(hw, lws, inter_bw);
    let p2p = 1;
    let gemm = hw.sms - reduce1 - p2p;
    Partition {
        gemm_sms: gemm,
        p2p_sms: p2p,
        reduce1_sms: reduce1,
        reduce2_sms: hw.sms,
    }
}

/// Intra-node AG+GEMM partition: communication is entirely on the copy
/// engine, so the GEMM owns the whole device.
pub fn plan_intra_ag(hw: &HardwareModel) -> Partition {
    Partition {
        gemm_sms: hw.sms,
        p2p_sms: 0,
        reduce1_sms: 0,
        reduce2_sms: 0,
    }
}

/// Inter-node AG+GEMM: `lws-1 + n_nodes-1` one-SM comm blocks (Fig. 4
/// grid) + the GEMM on the rest.
pub fn plan_inter_ag(hw: &HardwareModel, lws: usize, n_nodes: usize) -> Partition {
    let comm = (lws - 1 + n_nodes - 1) as u32;
    Partition {
        gemm_sms: hw.sms - comm,
        p2p_sms: comm,
        reduce1_sms: 0,
        reduce2_sms: 0,
    }
}

impl Partition {
    /// Concurrent phase-1 demand must fit the device (§3.8's constraint).
    pub fn fits(&self, hw: &HardwareModel) -> bool {
        self.gemm_sms + self.p2p_sms + self.reduce1_sms <= hw.sms
            && self.reduce2_sms <= hw.sms
    }
}

/// SM budget split between the two serving phases of a continuously
/// batched step (`coordinator::serve`): prompt prefill (GEMM-bound) and
/// token decode (memory/collective-bound) run concurrently and compete
/// for the device, the same §3.5 tradeoff as GEMM vs reduction above.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServePartition {
    /// SMs granted to the prefill GEMM.
    pub prefill_sms: u32,
    /// SMs granted to the decode partial-attention + collective tasks.
    pub decode_sms: u32,
}

impl ServePartition {
    /// Concurrent demand must fit the device.
    pub fn fits(&self, hw: &HardwareModel) -> bool {
        self.prefill_sms + self.decode_sms <= hw.sms
    }
}

/// Split the device between a decode batch and pending prefill tokens.
///
/// A lone phase owns the whole device. When both are live, the split is
/// proportional to their work — decode weighs each in-flight sequence
/// as one unit, prefill weighs `prefill_tokens` at one unit per
/// [`SERVE_PREFILL_TOKENS_PER_UNIT`] tokens (a prefill token is
/// GEMM-dense; a decode step is memory-bound) — with each side clamped
/// to at least a quarter of the device so neither phase starves
/// (§3.8's "avoid long tails": the slower phase gates the step).
/// Deterministic: integer arithmetic only.
pub fn plan_serving(
    hw: &HardwareModel,
    decode_batch: usize,
    prefill_tokens: usize,
) -> ServePartition {
    match (decode_batch, prefill_tokens) {
        (0, _) => {
            return ServePartition {
                prefill_sms: hw.sms,
                decode_sms: 0,
            }
        }
        (_, 0) => {
            return ServePartition {
                prefill_sms: 0,
                decode_sms: hw.sms,
            }
        }
        _ => {}
    }
    let decode_w = decode_batch as u64;
    let prefill_w = (prefill_tokens as u64).div_ceil(SERVE_PREFILL_TOKENS_PER_UNIT);
    let total = decode_w + prefill_w;
    let floor = hw.sms / 4;
    let decode = ((hw.sms as u64 * decode_w) / total) as u32;
    let decode = decode.clamp(floor, hw.sms - floor);
    ServePartition {
        prefill_sms: hw.sms - decode,
        decode_sms: decode,
    }
}

/// Prefill tokens weighing as much as one decode sequence in
/// [`plan_serving`]'s proportional split.
pub const SERVE_PREFILL_TOKENS_PER_UNIT: u64 = 8;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareModel;

    #[test]
    fn h800_matches_paper_numbers() {
        let hw = HardwareModel::h800();
        let p = plan_inter_rs(&hw, 8, hw.nic_bw);
        // §3.5/§3.8: no more than 15 SMs for the overlapped reduction,
        // 1 SM for P2P, GEMM keeps ~116.
        assert!(p.reduce1_sms <= 15, "{p:?}");
        assert_eq!(p.p2p_sms, 1);
        assert!(p.gemm_sms >= 116, "{p:?}");
        assert_eq!(p.reduce2_sms, 132);
        assert!(p.fits(&hw));
    }

    #[test]
    fn balance_budget_always_positive_and_fits() {
        for hw in [
            HardwareModel::h800(),
            HardwareModel::mi308x(),
            HardwareModel::l20(),
        ] {
            for lws in [2usize, 4, 8, 16] {
                for oversub in [1.0, 2.0, 4.0] {
                    let sms = reduce_sms_for_balance(&hw, lws, hw.nic_bw / oversub);
                    assert!(sms >= 1 && sms <= hw.sms / 4, "{:?} lws={lws}: {sms}", hw.kind);
                }
            }
        }
    }

    #[test]
    fn oversubscribed_fabric_resizes_reduce_budget() {
        // The §3.5 balance must be computed from the *routed* path
        // capacity: quartering the effective inter-node bandwidth moves
        // the P2P drain into the dominant regime and changes the SM split.
        let hw = HardwareModel::h800();
        let flat = reduce_sms_for_balance(&hw, 8, hw.nic_bw);
        let contended = reduce_sms_for_balance(&hw, 8, hw.nic_bw / 4.0);
        assert_ne!(flat, contended);
    }

    #[test]
    fn intra_ag_gives_gemm_everything() {
        let hw = HardwareModel::h800();
        let p = plan_intra_ag(&hw);
        assert_eq!(p.gemm_sms, 132);
        assert_eq!(p.p2p_sms, 0);
    }

    #[test]
    fn inter_ag_matches_fig4_grid() {
        let hw = HardwareModel::h800();
        let p = plan_inter_ag(&hw, 8, 2);
        assert_eq!(p.p2p_sms, 8); // lws-1 + n_nodes-1 = 7 + 1
        assert_eq!(p.gemm_sms, 124);
        assert!(p.fits(&hw));
    }

    #[test]
    fn serving_split_solo_phase_owns_device() {
        let hw = HardwareModel::h800();
        assert_eq!(
            plan_serving(&hw, 0, 4096),
            ServePartition {
                prefill_sms: 132,
                decode_sms: 0
            }
        );
        assert_eq!(
            plan_serving(&hw, 64, 0),
            ServePartition {
                prefill_sms: 0,
                decode_sms: 132
            }
        );
    }

    #[test]
    fn serving_split_is_proportional_clamped_and_fits() {
        for hw in [
            HardwareModel::h800(),
            HardwareModel::mi308x(),
            HardwareModel::l20(),
        ] {
            let floor = hw.sms / 4;
            let mut last_decode = 0;
            for batch in [1usize, 4, 16, 64, 256] {
                let p = plan_serving(&hw, batch, 1024);
                assert!(p.fits(&hw), "{:?} batch={batch}: {p:?}", hw.kind);
                assert_eq!(p.prefill_sms + p.decode_sms, hw.sms);
                assert!(p.decode_sms >= floor && p.prefill_sms >= floor, "{p:?}");
                // more decode work never shrinks the decode share
                assert!(p.decode_sms >= last_decode, "{:?} batch={batch}", hw.kind);
                last_decode = p.decode_sms;
            }
        }
    }

    #[test]
    fn partitions_fit_all_hw() {
        for hw in [
            HardwareModel::h800(),
            HardwareModel::mi308x(),
            HardwareModel::l20(),
        ] {
            assert!(plan_inter_rs(&hw, 8, hw.nic_bw).fits(&hw), "{:?}", hw.kind);
            assert!(plan_inter_rs(&hw, 8, hw.nic_bw / 2.0).fits(&hw), "{:?}", hw.kind);
            assert!(plan_intra_ag(&hw).fits(&hw));
        }
    }
}
