//! Overlapping machinery: tile swizzles (§3.7), resource partition
//! (§3.8), and the Table-2 optimization matrix.

pub mod features;
pub mod partition;
pub mod swizzle;

pub use partition::{
    plan_inter_ag, plan_inter_rs, plan_intra_ag, plan_serving, Partition, ServePartition,
};
