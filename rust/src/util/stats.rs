//! Small statistics helpers for the bench harness and report layer.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean; the paper's "average speedup" aggregation. 0.0 if empty.
/// Panics on non-positive entries (speedups are ratios > 0).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean of non-positive value {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile with linear interpolation, `p` in `[0, 100]`.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p), "percentile {p}");
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (s[hi] - s[lo]) * (rank - lo as f64)
    }
}

/// Median (p50).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn fmt_time(secs: f64) -> String {
    let a = secs.abs();
    if a < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if a < 1e-3 {
        format!("{:.2}us", secs * 1e6)
    } else if a < 1.0 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{:.3}s", secs)
    }
}

/// Format bytes/sec as GB/s (decimal GB, matching NIC/link spec sheets).
pub fn fmt_bw(bytes_per_sec: f64) -> String {
    format!("{:.1}GB/s", bytes_per_sec / 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_of_ratios() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn median_odd() {
        assert_eq!(median(&[5.0, 1.0, 3.0]), 3.0);
    }

    #[test]
    fn stddev_constant_is_zero() {
        assert_eq!(stddev(&[2.0, 2.0, 2.0]), 0.0);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(1.5e-9).ends_with("ns"));
        assert!(fmt_time(2.5e-6).ends_with("us"));
        assert!(fmt_time(3.5e-3).ends_with("ms"));
        assert!(fmt_time(1.5).ends_with('s'));
    }
}
