//! In-tree property-testing harness (offline replacement for `proptest`).
//!
//! A property is a closure over a [`Gen`]; the runner executes it for many
//! random cases and, on failure, reports the failing case number and seed so
//! the case can be replayed deterministically:
//!
//! ```no_run
//! use triton_dist_sim::util::prop::{check, Gen};
//! check("addition commutes", 256, |g: &mut Gen| {
//!     let a = g.usize_in(0, 100);
//!     let b = g.usize_in(0, 100);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Rng;

/// Case-local generator handed to every property execution.
pub struct Gen {
    rng: Rng,
    /// Seed of this particular case (printed on failure for replay).
    pub case_seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.usize_in(lo, hi)
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f32(&mut self) -> f32 {
        self.rng.f32()
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Pick one element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.usize_in(0, xs.len());
        &xs[i]
    }

    /// Vector of normal-ish f32 values.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        self.rng.normal_vec(n)
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.rng.shuffle(&mut v);
        v
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        self.rng.shuffle(xs)
    }

    /// Raw RNG access for custom distributions.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Base seed: fixed for reproducible CI, overridable with `PROP_SEED`.
fn base_seed() -> u64 {
    std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `cases` random executions of `prop`. Panics (with replay info) on
/// the first failing case.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: u32, prop: F) {
    let base = base_seed();
    for case in 0..cases {
        let case_seed = base ^ ((case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen {
                rng: Rng::new(case_seed),
                case_seed,
            };
            prop(&mut g);
        });
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay: PROP_SEED={base}, case_seed={case_seed:#x}): {msg}"
            );
        }
    }
}

/// Like [`check`] but the property returns `Result`, for properties that
/// prefer error values over panics.
pub fn check_res<F>(name: &str, cases: u32, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String> + std::panic::RefUnwindSafe,
{
    check(name, cases, |g| {
        if let Err(e) = prop(g) {
            panic!("{e}");
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0u32;
        // interior mutability via a cell is overkill; use an atomic
        use std::sync::atomic::{AtomicU32, Ordering};
        static COUNT: AtomicU32 = AtomicU32::new(0);
        COUNT.store(0, Ordering::SeqCst);
        check("count", 17, |_g| {
            COUNT.fetch_add(1, Ordering::SeqCst);
        });
        ran += COUNT.load(Ordering::SeqCst);
        assert_eq!(ran, 17);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check("always-fails", 4, |_g| panic!("boom"));
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("always-fails"), "{msg}");
        assert!(msg.contains("case_seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn gen_is_deterministic_per_case() {
        let mut first: Vec<u64> = vec![];
        check("collect", 3, |g| {
            let _ = g.u64();
        });
        // replaying with the same env gives identical case seeds
        let base = base_seed();
        for case in 0..3u64 {
            first.push(base ^ (case.wrapping_mul(0x9E3779B97F4A7C15)));
        }
        assert_eq!(first.len(), 3);
    }

    #[test]
    fn permutation_is_valid() {
        check("perm", 32, |g| {
            let n = g.usize_in(1, 20);
            let mut p = g.permutation(n);
            p.sort_unstable();
            assert_eq!(p, (0..n).collect::<Vec<_>>());
        });
    }
}
