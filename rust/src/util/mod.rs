//! Shared utilities: PRNG, statistics, text tables, property testing, JSON.
//!
//! These are offline replacements for `rand`, `criterion`'s stats,
//! `proptest`, and `serde_json` (none of which are vendored in this image).

pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use rng::Rng;
pub use table::Table;
