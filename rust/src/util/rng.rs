//! Deterministic PRNG (xoshiro256** seeded via splitmix64).
//!
//! The vendored crate set has no `rand`; this is the project-wide source of
//! randomness for tests, property tests, workload generators and the
//! autotuner. Fully deterministic from the seed.

/// xoshiro256** — fast, high-quality, 64-bit state PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        // Lemire's multiply-shift rejection method for unbiased sampling.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "usize_in empty range {lo}..{hi}");
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard-normal-ish float via sum of uniforms (Irwin–Hall, 12 terms).
    /// Good enough for test data; exact distribution is irrelevant here.
    pub fn normal_f32(&mut self) -> f32 {
        let mut acc = 0.0f64;
        for _ in 0..12 {
            acc += self.f64();
        }
        (acc - 6.0) as f32
    }

    /// Fill a slice with `normal_f32` samples.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal_f32();
        }
    }

    /// A fresh Vec of `n` normal samples.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        self.fill_normal(&mut v);
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_in(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.gen_range(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_roughly_centered() {
        let mut r = Rng::new(9);
        let n = 10_000;
        let mean: f64 = (0..n).map(|_| r.normal_f32() as f64).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
