//! Aligned plain-text table printer used by every figure/table bench to
//! regenerate the paper's rows in a terminal.

/// A simple column-aligned table builder.
#[derive(Default, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Table {
            title: title.to_string(),
            ..Default::default()
        }
    }

    pub fn header<S: ToString>(mut self, cols: &[S]) -> Self {
        self.header = cols.iter().map(|c| c.to_string()).collect();
        self
    }

    pub fn row<S: ToString>(&mut self, cells: &[S]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        assert!(
            self.header.is_empty() || cells.len() == self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    /// Number of data rows so far.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render the table with per-column alignment.
    pub fn render(&self) -> String {
        let ncols = self
            .header
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", c, width = widths[i]));
            }
            line.trim_end().to_string()
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(&["name", "value"]);
        t.row(&["a", "1"]);
        t.row(&["longer", "2.5"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("name"));
        let lines: Vec<&str> = s.lines().collect();
        // "a" row and "longer" row have the value column aligned
        let pos_a = lines[3].find('1').unwrap();
        let pos_b = lines[4].find('2').unwrap();
        assert_eq!(pos_a, pos_b);
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x").header(&["a", "b"]);
        t.row(&["only-one"]);
    }

    #[test]
    fn len_tracks_rows() {
        let mut t = Table::new("x").header(&["a"]);
        assert!(t.is_empty());
        t.row(&["1"]);
        assert_eq!(t.len(), 1);
    }
}
