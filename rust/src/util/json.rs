//! Minimal JSON parser + writer (offline replacement for `serde_json`).
//!
//! Used to read `artifacts/manifest.json` (written by python/compile/aot.py)
//! and to emit chrome-trace timelines. Supports the full JSON grammar except
//! exotic number forms; numbers are parsed as f64 (integers round-trip
//! exactly up to 2^53, far beyond any shape we handle).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` convenience; Null if missing or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError {
            pos: self.pos,
            msg: msg.into(),
        })
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", c as char))
        }
    }

    fn parse_value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.parse_obj(),
            Some(b'[') => self.parse_arr(),
            Some(b'"') => Ok(Json::Str(self.parse_string()?)),
            Some(b't') => self.parse_lit("true", Json::Bool(true)),
            Some(b'f') => self.parse_lit("false", Json::Bool(false)),
            Some(b'n') => self.parse_lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.parse_num(),
            _ => self.err("unexpected character"),
        }
    }

    fn parse_lit(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            self.err(format!("expected literal '{lit}'"))
        }
    }

    fn parse_num(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => self.err(format!("bad number '{s}'")),
        }
    }

    fn parse_string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5]).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError {
                                    pos: self.pos,
                                    msg: "bad \\u escape".into(),
                                })?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.pos]).map_err(
                        |_| JsonError {
                            pos: start,
                            msg: "invalid utf8".into(),
                        },
                    )?);
                }
            }
        }
    }

    fn parse_arr(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = vec![];
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.parse_value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn parse_obj(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.parse_string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.parse_value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        b: text.as_bytes(),
        pos: 0,
    };
    let v = p.parse_value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_value(self, &mut s);
        f.write_str(&s)
    }
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{"entries": [{"name": "gemm_8x8x8", "args":
            [{"shape": [8, 8], "dtype": "float32"}], "outputs":
            [{"shape": [8, 8], "dtype": "float32"}]}]}"#;
        let v = parse(doc).unwrap();
        let entries = v.get("entries").as_arr().unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].get("name").as_str().unwrap(), "gemm_8x8x8");
        let shape = entries[0].get("args").as_arr().unwrap()[0].get("shape");
        assert_eq!(shape.as_arr().unwrap()[0].as_usize().unwrap(), 8);
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,-3],"b":"x\ny","c":true,"d":null}"#;
        let v = parse(doc).unwrap();
        let s = v.to_string();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("123abc").is_err());
        assert!(parse("{} {}").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A");
    }

    #[test]
    fn nested_structures() {
        let v = parse(r#"[[[1]],{"k":{"j":[true]}}]"#).unwrap();
        assert_eq!(
            v.as_arr().unwrap()[1].get("k").get("j").as_arr().unwrap()[0],
            Json::Bool(true)
        );
    }
}
