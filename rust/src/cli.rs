//! Minimal CLI arg parsing (offline replacement for clap).
//!
//! Grammar: `triton-dist-sim <subcommand> [--key value]... [--flag]...`

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw argument strings (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    return Err("bad argument '--'".into());
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.opts.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// Positive-integer option (e.g. `--threads N`): parses like
    /// [`Args::usize_or`] but rejects zero.
    pub fn positive_usize_or(&self, name: &str, default: usize) -> Result<usize, String> {
        let v = self.usize_or(name, default)?;
        if v == 0 {
            return Err(format!("--{name} must be >= 1"));
        }
        Ok(v)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Constrained-choice option: the value (or `default` when absent)
    /// must be one of `allowed`, otherwise a usage error names the valid
    /// choices (e.g. `--router static|adaptive`).
    pub fn choice_or<'a>(
        &'a self,
        name: &str,
        default: &'a str,
        allowed: &[&str],
    ) -> Result<&'a str, String> {
        let v = self.get_or(name, default);
        if allowed.contains(&v) {
            Ok(v)
        } else {
            Err(format!(
                "--{name} expects one of {}, got '{v}'",
                allowed.join("|")
            ))
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = parse(&["run", "--ws", "8", "--trace", "--hw=h800", "extra"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("ws"), Some("8"));
        assert_eq!(a.get("hw"), Some("h800"));
        assert!(a.flag("trace"));
        assert_eq!(a.positional(), &["extra".to_string()]);
    }

    #[test]
    fn usize_parsing_and_defaults() {
        let a = parse(&["x", "--n", "12"]);
        assert_eq!(a.usize_or("n", 1).unwrap(), 12);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        let b = parse(&["x", "--n", "abc"]);
        assert!(b.usize_or("n", 1).is_err());
    }

    #[test]
    fn positive_usize_rejects_zero() {
        let a = parse(&["x", "--threads", "4"]);
        assert_eq!(a.positive_usize_or("threads", 1).unwrap(), 4);
        assert_eq!(a.positive_usize_or("missing", 1).unwrap(), 1);
        let b = parse(&["x", "--threads", "0"]);
        assert!(b.positive_usize_or("threads", 1).is_err());
    }

    #[test]
    fn f64_parsing_and_defaults() {
        let a = parse(&["x", "--oversub", "2.5"]);
        assert_eq!(a.f64_or("oversub", 1.0).unwrap(), 2.5);
        assert_eq!(a.f64_or("missing", 1.0).unwrap(), 1.0);
        let b = parse(&["x", "--oversub", "xyz"]);
        assert!(b.f64_or("oversub", 1.0).is_err());
    }

    #[test]
    fn choice_validates_against_allowed_set() {
        let allowed = ["static", "adaptive"];
        let a = parse(&["x", "--router", "adaptive"]);
        assert_eq!(a.choice_or("router", "static", &allowed).unwrap(), "adaptive");
        assert_eq!(a.choice_or("missing", "static", &allowed).unwrap(), "static");
        let b = parse(&["x", "--router", "sometimes"]);
        let err = b.choice_or("router", "static", &allowed);
        assert!(err.is_err());
        assert!(err.unwrap_err().contains("static|adaptive"));
    }

    #[test]
    fn trailing_flag_not_eaten_as_value() {
        let a = parse(&["x", "--verbose", "--n", "3"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("n"), Some("3"));
    }
}
