//! Deterministic fault plans: seeded, replayable adversarial schedules
//! for the railed fabric (ROADMAP "self-healing transport").
//!
//! A [`FaultPlan`] is pure configuration — a list of link faults
//! (flaps/degradations on NIC or spine links, whole-rail death),
//! straggler ranks, optional latency jitter, and the recovery knobs
//! (watchdog timeout, retry budget). The DES engine turns each link
//! fault into a pair of first-class events that retarget `FlowNet`
//! capacities; nothing here touches simulation state.
//!
//! The non-negotiable invariant: [`FaultPlan::default`] (empty) leaves
//! the engine bit-identical to the fault-free build, and the same
//! `(workload seed, fault seed)` pair replays the identical timeline.
//!
//! ```
//! use triton_dist_sim::config::fault::FaultPlan;
//!
//! let plan = FaultPlan::parse("flap,nic,3,0,1e-3,2e-3; strag,5,1.5").unwrap();
//! assert_eq!(plan.link_faults.len(), 1);
//! assert_eq!(plan.stragglers.len(), 1);
//! assert!(!plan.is_empty());
//! assert!(FaultPlan::default().is_empty());
//! ```

use crate::util::Rng;

/// What piece of the fabric a [`LinkFault`] hits. Resolution to concrete
/// `LinkId`s is the topology's job (`Topology::fault_links`), so plans
/// stay portable across cluster shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultTarget {
    /// Both directions (tx + rx) of one GPU's NIC on one rail.
    Nic { rank: usize, rail: usize },
    /// The shared spine-core link of one rail plane (blocking fabrics
    /// only; resolves to nothing on a non-blocking fabric).
    Spine { rail: usize },
    /// Every link on one rail plane: all NICs, leaf tiers, and spine.
    Rail { rail: usize },
}

/// One scheduled capacity change on part of the fabric.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkFault {
    pub target: FaultTarget,
    /// Virtual time the fault begins (s).
    pub t_start: f64,
    /// Virtual time the fault clears (s); `f64::INFINITY` = permanent.
    pub t_end: f64,
    /// Capacity multiplier while active: `0.0` = link down (flows on it
    /// are killed and retried), `(0, 1)` = degraded bandwidth.
    pub factor: f64,
}

impl LinkFault {
    /// A full down interval (flap) on `target`.
    pub fn flap(target: FaultTarget, t_start: f64, dur: f64) -> Self {
        LinkFault {
            target,
            t_start,
            t_end: t_start + dur,
            factor: 0.0,
        }
    }

    /// A bandwidth degradation to `factor` of nominal on `target`.
    pub fn degrade(target: FaultTarget, t_start: f64, dur: f64, factor: f64) -> Self {
        LinkFault {
            target,
            t_start,
            t_end: t_start + dur,
            factor,
        }
    }
}

/// A rank whose compute kernels run `factor`x slower (factor > 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Straggler {
    pub rank: usize,
    pub factor: f64,
}

/// Seeded per-message latency jitter: each flow launch adds a uniform
/// extra latency in `[0, max_secs)` drawn from a dedicated stream, so
/// jitter replays identically for a given seed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Jitter {
    pub seed: u64,
    pub max_secs: f64,
}

/// The complete, deterministic adversarial schedule plus recovery knobs.
///
/// `lt_timeout`, `retry_max`, and `retry_backoff` are recovery
/// configuration rather than faults; they do not affect
/// [`is_empty`](Self::is_empty) (a finite watchdog on a clean run never
/// fires and never perturbs the timeline).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Scheduled capacity changes, applied as DES events.
    pub link_faults: Vec<LinkFault>,
    /// Ranks with inflated compute durations.
    pub stragglers: Vec<Straggler>,
    /// Optional seeded latency jitter on every flow launch.
    pub jitter: Option<Jitter>,
    /// Watchdog timeout on LL/signal waits (s). `INFINITY` = disabled.
    /// CLI: `--lt-timeout`.
    pub lt_timeout: f64,
    /// Max retry attempts for a put whose flow dies on a downed link
    /// before the run errors out. CLI: `--retry-max`.
    pub retry_max: u32,
    /// Base retry backoff (s); attempt `k` waits
    /// `retry_backoff * 2^(k-1)`, capped at [`Self::BACKOFF_CAP`].
    pub retry_backoff: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            link_faults: Vec::new(),
            stragglers: Vec::new(),
            jitter: None,
            lt_timeout: f64::INFINITY,
            retry_max: 8,
            retry_backoff: 20e-6,
        }
    }
}

impl FaultPlan {
    /// Retry backoff ceiling (s): exponential growth stops here.
    pub const BACKOFF_CAP: f64 = 5e-3;

    /// No scheduled faults at all. Recovery knobs are ignored: a
    /// watchdog or retry budget with nothing to trigger it cannot
    /// perturb the timeline.
    pub fn is_empty(&self) -> bool {
        self.link_faults.is_empty() && self.stragglers.is_empty() && self.jitter.is_none()
    }

    /// Backoff before retry attempt `attempt` (1-based), exponential and
    /// capped.
    pub fn backoff(&self, attempt: u32) -> f64 {
        let exp = attempt.saturating_sub(1).min(30);
        (self.retry_backoff * (1u64 << exp) as f64).min(Self::BACKOFF_CAP)
    }

    /// Compute-duration multiplier for `rank` (1.0 when not a straggler;
    /// stacked stragglers multiply).
    pub fn straggle_factor(&self, rank: usize) -> f64 {
        let mut f = 1.0;
        for s in &self.stragglers {
            if s.rank == rank {
                f *= s.factor;
            }
        }
        f
    }

    /// Parse a semicolon-separated fault DSL (the `--faults` flag):
    ///
    /// * `flap,nic,<rank>,<rail>,<t0>,<dur>` — NIC down interval
    /// * `flap,spine,<rail>,<t0>,<dur>` — spine-plane down interval
    /// * `deg,nic,<rank>,<rail>,<t0>,<dur>,<factor>` — NIC degraded
    /// * `deg,spine,<rail>,<t0>,<dur>,<factor>` — spine degraded
    /// * `raildead,<rail>,<t0>` — permanent whole-rail death
    /// * `strag,<rank>,<factor>` — straggler rank
    /// * `jitter,<seed>,<max_secs>` — seeded latency jitter
    ///
    /// Whitespace around separators is ignored; empty clauses are
    /// skipped, so a trailing `;` is fine.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for clause in s.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let f: Vec<&str> = clause.split(',').map(str::trim).collect();
            let usize_at = |i: usize| -> Result<usize, String> {
                f.get(i)
                    .ok_or_else(|| format!("fault clause '{clause}': missing field {i}"))?
                    .parse::<usize>()
                    .map_err(|e| format!("fault clause '{clause}' field {i}: {e}"))
            };
            let f64_at = |i: usize| -> Result<f64, String> {
                f.get(i)
                    .ok_or_else(|| format!("fault clause '{clause}': missing field {i}"))?
                    .parse::<f64>()
                    .map_err(|e| format!("fault clause '{clause}' field {i}: {e}"))
            };
            let target_at = |kind: &str, base: usize| -> Result<(FaultTarget, usize), String> {
                match kind {
                    "nic" => Ok((
                        FaultTarget::Nic {
                            rank: usize_at(base)?,
                            rail: usize_at(base + 1)?,
                        },
                        base + 2,
                    )),
                    "spine" => Ok((
                        FaultTarget::Spine {
                            rail: usize_at(base)?,
                        },
                        base + 1,
                    )),
                    other => Err(format!(
                        "fault clause '{clause}': unknown target '{other}' (nic|spine)"
                    )),
                }
            };
            match f[0] {
                "flap" => {
                    let kind = f
                        .get(1)
                        .ok_or_else(|| format!("fault clause '{clause}': missing target"))?;
                    let (target, i) = target_at(kind, 2)?;
                    let (t0, dur) = (f64_at(i)?, f64_at(i + 1)?);
                    check_time(clause, t0, dur)?;
                    plan.link_faults.push(LinkFault::flap(target, t0, dur));
                }
                "deg" => {
                    let kind = f
                        .get(1)
                        .ok_or_else(|| format!("fault clause '{clause}': missing target"))?;
                    let (target, i) = target_at(kind, 2)?;
                    let (t0, dur, factor) = (f64_at(i)?, f64_at(i + 1)?, f64_at(i + 2)?);
                    check_time(clause, t0, dur)?;
                    if !(0.0..1.0).contains(&factor) {
                        return Err(format!(
                            "fault clause '{clause}': degradation factor must be in [0, 1)"
                        ));
                    }
                    plan.link_faults
                        .push(LinkFault::degrade(target, t0, dur, factor));
                }
                "raildead" => {
                    let (rail, t0) = (usize_at(1)?, f64_at(2)?);
                    check_time(clause, t0, 0.0)?;
                    plan.link_faults.push(LinkFault {
                        target: FaultTarget::Rail { rail },
                        t_start: t0,
                        t_end: f64::INFINITY,
                        factor: 0.0,
                    });
                }
                "strag" => {
                    let (rank, factor) = (usize_at(1)?, f64_at(2)?);
                    if !(factor >= 1.0) {
                        return Err(format!(
                            "fault clause '{clause}': straggler factor must be >= 1"
                        ));
                    }
                    plan.stragglers.push(Straggler { rank, factor });
                }
                "jitter" => {
                    let seed = f
                        .get(1)
                        .ok_or_else(|| format!("fault clause '{clause}': missing seed"))?
                        .parse::<u64>()
                        .map_err(|e| format!("fault clause '{clause}' seed: {e}"))?;
                    let max_secs = f64_at(2)?;
                    if !(max_secs > 0.0) || !max_secs.is_finite() {
                        return Err(format!(
                            "fault clause '{clause}': jitter bound must be finite and > 0"
                        ));
                    }
                    plan.jitter = Some(Jitter { seed, max_secs });
                }
                other => {
                    return Err(format!(
                        "unknown fault kind '{other}' \
                         (flap|deg|raildead|strag|jitter)"
                    ))
                }
            }
        }
        Ok(plan)
    }

    /// Synthesize a random-but-deterministic plan from a seed: roughly
    /// `rate` faults per rank over `[0, horizon)`, mixing NIC flaps,
    /// NIC/spine degradations, and the occasional straggler. The same
    /// `(seed, rate, world, rails, horizon)` always yields the same
    /// plan (CLI: `--fault-seed` / `--fault-rate`).
    pub fn synthesize(seed: u64, rate: f64, world: usize, rails: usize, horizon: f64) -> FaultPlan {
        assert!(rate >= 0.0 && rate.is_finite(), "fault rate must be >= 0");
        assert!(
            horizon > 0.0 && horizon.is_finite(),
            "fault horizon must be finite and > 0"
        );
        let mut rng = Rng::new(seed);
        let mut plan = FaultPlan::default();
        let n = (rate * world as f64).round() as usize;
        for _ in 0..n {
            let t0 = rng.f64() * horizon * 0.8;
            let dur = (0.05 + 0.25 * rng.f64()) * horizon;
            let rail = rng.usize_in(0, rails.max(1));
            match rng.gen_range(8) {
                // NIC flaps dominate: the common real-world failure
                0..=3 => {
                    let rank = rng.usize_in(0, world);
                    plan.link_faults
                        .push(LinkFault::flap(FaultTarget::Nic { rank, rail }, t0, dur));
                }
                4..=5 => {
                    let rank = rng.usize_in(0, world);
                    let factor = 0.1 + 0.7 * rng.f64();
                    plan.link_faults.push(LinkFault::degrade(
                        FaultTarget::Nic { rank, rail },
                        t0,
                        dur,
                        factor,
                    ));
                }
                6 => {
                    let factor = 0.1 + 0.7 * rng.f64();
                    plan.link_faults.push(LinkFault::degrade(
                        FaultTarget::Spine { rail },
                        t0,
                        dur,
                        factor,
                    ));
                }
                _ => {
                    let rank = rng.usize_in(0, world);
                    plan.stragglers.push(Straggler {
                        rank,
                        factor: 1.1 + rng.f64(),
                    });
                }
            }
        }
        plan
    }
}

fn check_time(clause: &str, t0: f64, dur: f64) -> Result<(), String> {
    if !(t0 >= 0.0) || !t0.is_finite() {
        return Err(format!(
            "fault clause '{clause}': start time must be finite and >= 0"
        ));
    }
    if !(dur >= 0.0) {
        return Err(format!("fault clause '{clause}': duration must be >= 0"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_empty() {
        let p = FaultPlan::default();
        assert!(p.is_empty());
        assert!(p.lt_timeout.is_infinite());
        // a finite watchdog alone does not make the plan non-empty
        let watch = FaultPlan {
            lt_timeout: 1.0,
            ..FaultPlan::default()
        };
        assert!(watch.is_empty());
    }

    #[test]
    fn parse_full_dsl() {
        let p = FaultPlan::parse(
            "flap,nic,3,1,1e-3,2e-3; deg,spine,0,0.5e-3,1e-3,0.25; \
             raildead,1,4e-3; strag,5,1.5; jitter,42,1e-6;",
        )
        .unwrap();
        assert_eq!(p.link_faults.len(), 3);
        assert_eq!(
            p.link_faults[0],
            LinkFault {
                target: FaultTarget::Nic { rank: 3, rail: 1 },
                t_start: 1e-3,
                t_end: 3e-3,
                factor: 0.0,
            }
        );
        assert_eq!(p.link_faults[1].factor, 0.25);
        assert_eq!(p.link_faults[1].target, FaultTarget::Spine { rail: 0 });
        assert!(p.link_faults[2].t_end.is_infinite());
        assert_eq!(p.link_faults[2].target, FaultTarget::Rail { rail: 1 });
        assert_eq!(p.stragglers, vec![Straggler { rank: 5, factor: 1.5 }]);
        assert_eq!(
            p.jitter,
            Some(Jitter {
                seed: 42,
                max_secs: 1e-6
            })
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("explode,everything").is_err());
        assert!(FaultPlan::parse("flap,nic,3").is_err());
        assert!(FaultPlan::parse("deg,nic,0,0,0,1e-3,1.5").is_err()); // factor >= 1
        assert!(FaultPlan::parse("strag,0,0.5").is_err()); // speedup, not straggle
        assert!(FaultPlan::parse("flap,nic,0,0,-1,1e-3").is_err()); // negative start
        assert!(FaultPlan::parse("jitter,1,0").is_err());
        // empty clauses / whitespace tolerated
        assert!(FaultPlan::parse(" ; ;").unwrap().is_empty());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn backoff_is_exponential_and_capped() {
        let p = FaultPlan::default();
        assert_eq!(p.backoff(1), p.retry_backoff);
        assert_eq!(p.backoff(2), 2.0 * p.retry_backoff);
        assert_eq!(p.backoff(3), 4.0 * p.retry_backoff);
        assert!(p.backoff(40) <= FaultPlan::BACKOFF_CAP);
        assert_eq!(p.backoff(40), FaultPlan::BACKOFF_CAP);
    }

    #[test]
    fn straggle_factor_stacks() {
        let p = FaultPlan::parse("strag,2,1.5; strag,2,2.0; strag,3,1.25").unwrap();
        assert_eq!(p.straggle_factor(0), 1.0);
        assert_eq!(p.straggle_factor(3), 1.25);
        assert!((p.straggle_factor(2) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn synthesize_is_deterministic() {
        let a = FaultPlan::synthesize(7, 0.5, 16, 2, 1e-2);
        let b = FaultPlan::synthesize(7, 0.5, 16, 2, 1e-2);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        let c = FaultPlan::synthesize(8, 0.5, 16, 2, 1e-2);
        assert_ne!(a, c, "different seeds should differ");
        // every synthesized fault is inside the horizon and well-formed
        for lf in &a.link_faults {
            assert!(lf.t_start >= 0.0 && lf.t_start < 1e-2);
            assert!(lf.t_end > lf.t_start);
            assert!((0.0..1.0).contains(&lf.factor));
        }
        for s in &a.stragglers {
            assert!(s.factor > 1.0);
            assert!(s.rank < 16);
        }
        // zero rate: empty plan
        assert!(FaultPlan::synthesize(1, 0.0, 16, 2, 1e-2).is_empty());
    }
}
