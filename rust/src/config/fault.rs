//! Deterministic fault plans: seeded, replayable adversarial schedules
//! for the railed fabric (ROADMAP "self-healing transport").
//!
//! A [`FaultPlan`] is pure configuration — a list of link faults
//! (flaps/degradations on NIC or spine links, whole-rail death),
//! straggler ranks, optional latency jitter, and the recovery knobs
//! (watchdog timeout, retry budget). The DES engine turns each link
//! fault into a pair of first-class events that retarget `FlowNet`
//! capacities; nothing here touches simulation state.
//!
//! The non-negotiable invariant: [`FaultPlan::default`] (empty) leaves
//! the engine bit-identical to the fault-free build, and the same
//! `(workload seed, fault seed)` pair replays the identical timeline.
//!
//! ```
//! use triton_dist_sim::config::fault::FaultPlan;
//!
//! let plan = FaultPlan::parse("flap,nic,3,0,1e-3,2e-3; strag,5,1.5").unwrap();
//! assert_eq!(plan.link_faults.len(), 1);
//! assert_eq!(plan.stragglers.len(), 1);
//! assert!(!plan.is_empty());
//! assert!(FaultPlan::default().is_empty());
//! ```

use crate::util::Rng;

/// What piece of the fabric a [`LinkFault`] hits. Resolution to concrete
/// `LinkId`s is the topology's job (`Topology::fault_links`), so plans
/// stay portable across cluster shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultTarget {
    /// Both directions (tx + rx) of one GPU's NIC on one rail.
    Nic { rank: usize, rail: usize },
    /// The shared spine-core link of one rail plane (blocking fabrics
    /// only; resolves to nothing on a non-blocking fabric).
    Spine { rail: usize },
    /// Every link on one rail plane: all NICs, leaf tiers, and spine.
    Rail { rail: usize },
    /// Every link terminating at one GPU: NICs on all rails *plus* its
    /// intra-node links and HBM port. Unlike the fabric-only targets
    /// above this reaches intra-node links, so plans containing it are
    /// excluded from the sharded engine (see `sim/par.rs`).
    Rank { rank: usize },
    /// Every link of every rank hosted on one node.
    Node { node: usize },
}

/// Scope of a permanent endpoint death ([`Death`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeathScope {
    /// One GPU dies (DSL: `die,<rank>,<t0>`).
    Rank(usize),
    /// A whole node dies — every rank it hosts at once
    /// (DSL: `nodedead,<node>,<t0>`).
    Node(usize),
}

/// A permanent endpoint failure: at `t` the scope's ranks stop forever —
/// every link they terminate drops to zero capacity, in-flight flows
/// touching them are killed, and their waiters are released with a
/// structured `DeadPeer` error instead of hanging. Unlike a
/// [`LinkFault`] there is no `t_end`: recovery means *re-planning over
/// the survivor world* (the elastic controller in
/// `coordinator::recover`), not waiting the fault out.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Death {
    pub scope: DeathScope,
    /// Virtual time of death (s).
    pub t: f64,
}

/// One scheduled capacity change on part of the fabric.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkFault {
    pub target: FaultTarget,
    /// Virtual time the fault begins (s).
    pub t_start: f64,
    /// Virtual time the fault clears (s); `f64::INFINITY` = permanent.
    pub t_end: f64,
    /// Capacity multiplier while active: `0.0` = link down (flows on it
    /// are killed and retried), `(0, 1)` = degraded bandwidth.
    pub factor: f64,
}

impl LinkFault {
    /// A full down interval (flap) on `target`.
    pub fn flap(target: FaultTarget, t_start: f64, dur: f64) -> Self {
        LinkFault {
            target,
            t_start,
            t_end: t_start + dur,
            factor: 0.0,
        }
    }

    /// A bandwidth degradation to `factor` of nominal on `target`.
    pub fn degrade(target: FaultTarget, t_start: f64, dur: f64, factor: f64) -> Self {
        LinkFault {
            target,
            t_start,
            t_end: t_start + dur,
            factor,
        }
    }
}

/// A rank whose compute kernels run `factor`x slower (factor > 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Straggler {
    pub rank: usize,
    pub factor: f64,
}

/// Seeded per-message latency jitter: each flow launch adds a uniform
/// extra latency in `[0, max_secs)` drawn from a dedicated stream, so
/// jitter replays identically for a given seed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Jitter {
    pub seed: u64,
    pub max_secs: f64,
}

/// The complete, deterministic adversarial schedule plus recovery knobs.
///
/// `lt_timeout`, `retry_max`, and `retry_backoff` are recovery
/// configuration rather than faults; they do not affect
/// [`is_empty`](Self::is_empty) (a finite watchdog on a clean run never
/// fires and never perturbs the timeline).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Scheduled capacity changes, applied as DES events.
    pub link_faults: Vec<LinkFault>,
    /// Permanent rank/node deaths, applied as DES events; a run that
    /// touches a dead rank ends in a structured `DeadPeer` error that
    /// the elastic recovery controller turns into a survivor re-plan.
    pub deaths: Vec<Death>,
    /// Ranks with inflated compute durations.
    pub stragglers: Vec<Straggler>,
    /// Optional seeded latency jitter on every flow launch.
    pub jitter: Option<Jitter>,
    /// Watchdog timeout on LL/signal waits (s). `INFINITY` = disabled.
    /// CLI: `--lt-timeout`.
    pub lt_timeout: f64,
    /// Max retry attempts for a put whose flow dies on a downed link
    /// before the run errors out. CLI: `--retry-max`.
    pub retry_max: u32,
    /// Base retry backoff (s); attempt `k` waits
    /// `retry_backoff * 2^(k-1)`, capped at [`Self::BACKOFF_CAP`].
    pub retry_backoff: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            link_faults: Vec::new(),
            deaths: Vec::new(),
            stragglers: Vec::new(),
            jitter: None,
            lt_timeout: f64::INFINITY,
            retry_max: 8,
            retry_backoff: 20e-6,
        }
    }
}

impl FaultPlan {
    /// Retry backoff ceiling (s): exponential growth stops here.
    pub const BACKOFF_CAP: f64 = 5e-3;

    /// No scheduled faults at all. Recovery knobs are ignored: a
    /// watchdog or retry budget with nothing to trigger it cannot
    /// perturb the timeline.
    pub fn is_empty(&self) -> bool {
        self.link_faults.is_empty()
            && self.deaths.is_empty()
            && self.stragglers.is_empty()
            && self.jitter.is_none()
    }

    /// Does the plan schedule any permanent rank/node death? Such plans
    /// are ineligible for the sharded engine (the survivor re-plan
    /// crosses the lookahead barrier) and are routed to the elastic
    /// recovery controller by `--recover`.
    pub fn has_deaths(&self) -> bool {
        !self.deaths.is_empty()
    }

    /// Backoff before retry attempt `attempt` (1-based), exponential and
    /// capped.
    pub fn backoff(&self, attempt: u32) -> f64 {
        let exp = attempt.saturating_sub(1).min(30);
        (self.retry_backoff * (1u64 << exp) as f64).min(Self::BACKOFF_CAP)
    }

    /// Compute-duration multiplier for `rank` (1.0 when not a straggler;
    /// stacked stragglers multiply).
    pub fn straggle_factor(&self, rank: usize) -> f64 {
        let mut f = 1.0;
        for s in &self.stragglers {
            if s.rank == rank {
                f *= s.factor;
            }
        }
        f
    }

    /// Parse a semicolon-separated fault DSL (the `--faults` flag):
    ///
    /// * `flap,nic,<rank>,<rail>,<t0>,<dur>` — NIC down interval
    /// * `flap,spine,<rail>,<t0>,<dur>` — spine-plane down interval
    /// * `flap,rail,<rail>,<t0>,<dur>` — whole-rail down interval
    /// * `deg,nic,<rank>,<rail>,<t0>,<dur>,<factor>` — NIC degraded
    /// * `deg,spine,<rail>,<t0>,<dur>,<factor>` — spine degraded
    /// * `deg,rail,<rail>,<t0>,<dur>,<factor>` — whole rail degraded
    /// * `raildead,<rail>,<t0>` — permanent whole-rail death
    /// * `die,<rank>,<t0>` — permanent GPU death (rank leaves the world)
    /// * `nodedead,<node>,<t0>` — permanent node death (all its ranks)
    /// * `strag,<rank>,<factor>` — straggler rank
    /// * `jitter,<seed>,<max_secs>` — seeded latency jitter
    ///
    /// Whitespace around separators is ignored; empty clauses are
    /// skipped, so a trailing `;` is fine. Malformed clauses (wrong
    /// arity, unknown kind, non-numeric or negative fields) return a
    /// structured `Err` naming the clause — never a panic.
    ///
    /// `parse` is the exact inverse of the [`Display`](struct.FaultPlan.html#impl-Display-for-FaultPlan)
    /// rendering for the scheduled faults, provided the plan's interval
    /// arithmetic is exact in f64 (`t_start + dur == t_end`); the
    /// recovery knobs are not part of the DSL and come back as defaults.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for clause in s.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let f: Vec<&str> = clause.split(',').map(str::trim).collect();
            let usize_at = |i: usize| -> Result<usize, String> {
                f.get(i)
                    .ok_or_else(|| format!("fault clause '{clause}': missing field {i}"))?
                    .parse::<usize>()
                    .map_err(|e| format!("fault clause '{clause}' field {i}: {e}"))
            };
            let f64_at = |i: usize| -> Result<f64, String> {
                f.get(i)
                    .ok_or_else(|| format!("fault clause '{clause}': missing field {i}"))?
                    .parse::<f64>()
                    .map_err(|e| format!("fault clause '{clause}' field {i}: {e}"))
            };
            let target_at = |kind: &str, base: usize| -> Result<(FaultTarget, usize), String> {
                match kind {
                    "nic" => Ok((
                        FaultTarget::Nic {
                            rank: usize_at(base)?,
                            rail: usize_at(base + 1)?,
                        },
                        base + 2,
                    )),
                    "spine" => Ok((
                        FaultTarget::Spine {
                            rail: usize_at(base)?,
                        },
                        base + 1,
                    )),
                    "rail" => Ok((
                        FaultTarget::Rail {
                            rail: usize_at(base)?,
                        },
                        base + 1,
                    )),
                    "rank" => Ok((
                        FaultTarget::Rank {
                            rank: usize_at(base)?,
                        },
                        base + 1,
                    )),
                    "node" => Ok((
                        FaultTarget::Node {
                            node: usize_at(base)?,
                        },
                        base + 1,
                    )),
                    other => Err(format!(
                        "fault clause '{clause}': unknown target '{other}' \
                         (nic|spine|rail|rank|node)"
                    )),
                }
            };
            match f[0] {
                "flap" => {
                    let kind = f
                        .get(1)
                        .ok_or_else(|| format!("fault clause '{clause}': missing target"))?;
                    let (target, i) = target_at(kind, 2)?;
                    let (t0, dur) = (f64_at(i)?, f64_at(i + 1)?);
                    check_time(clause, t0, dur)?;
                    plan.link_faults.push(LinkFault::flap(target, t0, dur));
                }
                "deg" => {
                    let kind = f
                        .get(1)
                        .ok_or_else(|| format!("fault clause '{clause}': missing target"))?;
                    let (target, i) = target_at(kind, 2)?;
                    let (t0, dur, factor) = (f64_at(i)?, f64_at(i + 1)?, f64_at(i + 2)?);
                    check_time(clause, t0, dur)?;
                    if !(0.0..1.0).contains(&factor) {
                        return Err(format!(
                            "fault clause '{clause}': degradation factor must be in [0, 1)"
                        ));
                    }
                    plan.link_faults
                        .push(LinkFault::degrade(target, t0, dur, factor));
                }
                "raildead" => {
                    let (rail, t0) = (usize_at(1)?, f64_at(2)?);
                    check_time(clause, t0, 0.0)?;
                    plan.link_faults.push(LinkFault {
                        target: FaultTarget::Rail { rail },
                        t_start: t0,
                        t_end: f64::INFINITY,
                        factor: 0.0,
                    });
                }
                "die" => {
                    let (rank, t0) = (usize_at(1)?, f64_at(2)?);
                    check_time(clause, t0, 0.0)?;
                    plan.deaths.push(Death {
                        scope: DeathScope::Rank(rank),
                        t: t0,
                    });
                }
                "nodedead" => {
                    let (node, t0) = (usize_at(1)?, f64_at(2)?);
                    check_time(clause, t0, 0.0)?;
                    plan.deaths.push(Death {
                        scope: DeathScope::Node(node),
                        t: t0,
                    });
                }
                "strag" => {
                    let (rank, factor) = (usize_at(1)?, f64_at(2)?);
                    if !(factor >= 1.0) {
                        return Err(format!(
                            "fault clause '{clause}': straggler factor must be >= 1"
                        ));
                    }
                    plan.stragglers.push(Straggler { rank, factor });
                }
                "jitter" => {
                    let seed = f
                        .get(1)
                        .ok_or_else(|| format!("fault clause '{clause}': missing seed"))?
                        .parse::<u64>()
                        .map_err(|e| format!("fault clause '{clause}' seed: {e}"))?;
                    let max_secs = f64_at(2)?;
                    if !(max_secs > 0.0) || !max_secs.is_finite() {
                        return Err(format!(
                            "fault clause '{clause}': jitter bound must be finite and > 0"
                        ));
                    }
                    plan.jitter = Some(Jitter { seed, max_secs });
                }
                other => {
                    return Err(format!(
                        "unknown fault kind '{other}' \
                         (flap|deg|raildead|die|nodedead|strag|jitter)"
                    ))
                }
            }
        }
        Ok(plan)
    }

    /// Synthesize a random-but-deterministic plan from a seed: roughly
    /// `rate` faults per rank over `[0, horizon)`, mixing NIC flaps,
    /// NIC/spine degradations, and the occasional straggler. The same
    /// `(seed, rate, world, rails, horizon)` always yields the same
    /// plan (CLI: `--fault-seed` / `--fault-rate`).
    ///
    /// **Recoverability contract**: this default tier never emits
    /// permanent faults — no `die`, no `nodedead`, no `raildead`, and
    /// every link fault has a finite `t_end` — so any program that
    /// completes fault-free also completes under a synthesized plan
    /// (possibly slower, via the kill-and-retry ladder). Plans that may
    /// *not* recover without a survivor re-plan come only from
    /// [`synthesize_severe`](Self::synthesize_severe) or an explicit
    /// DSL string.
    pub fn synthesize(seed: u64, rate: f64, world: usize, rails: usize, horizon: f64) -> FaultPlan {
        assert!(rate >= 0.0 && rate.is_finite(), "fault rate must be >= 0");
        assert!(
            horizon > 0.0 && horizon.is_finite(),
            "fault horizon must be finite and > 0"
        );
        let mut rng = Rng::new(seed);
        let mut plan = FaultPlan::default();
        let n = (rate * world as f64).round() as usize;
        for _ in 0..n {
            let t0 = rng.f64() * horizon * 0.8;
            let dur = (0.05 + 0.25 * rng.f64()) * horizon;
            let rail = rng.usize_in(0, rails.max(1));
            match rng.gen_range(8) {
                // NIC flaps dominate: the common real-world failure
                0..=3 => {
                    let rank = rng.usize_in(0, world);
                    plan.link_faults
                        .push(LinkFault::flap(FaultTarget::Nic { rank, rail }, t0, dur));
                }
                4..=5 => {
                    let rank = rng.usize_in(0, world);
                    let factor = 0.1 + 0.7 * rng.f64();
                    plan.link_faults.push(LinkFault::degrade(
                        FaultTarget::Nic { rank, rail },
                        t0,
                        dur,
                        factor,
                    ));
                }
                6 => {
                    let factor = 0.1 + 0.7 * rng.f64();
                    plan.link_faults.push(LinkFault::degrade(
                        FaultTarget::Spine { rail },
                        t0,
                        dur,
                        factor,
                    ));
                }
                _ => {
                    let rank = rng.usize_in(0, world);
                    plan.stragglers.push(Straggler {
                        rank,
                        factor: 1.1 + rng.f64(),
                    });
                }
            }
        }
        plan
    }

    /// The severe tier of [`synthesize`](Self::synthesize): same bounded
    /// fault mix, but roughly a fifth of the draws escalate to
    /// *permanent* faults — a rank `die`, a `nodedead`, or a `raildead`.
    ///
    /// **Recoverability contract**: severe plans may require the elastic
    /// recovery controller (`coordinator::recover`) to complete, but
    /// they are always *recoverable by it*: at most **one** rank/node
    /// death is emitted per plan (so the survivor world is never empty
    /// and single-epoch re-planning suffices), a node death is only
    /// drawn when `nodes > 1`, and `raildead` is only drawn when
    /// `rails > 1` (an alive plane always remains for adaptive rerouting
    /// or retries). Deterministic in
    /// `(seed, rate, world, nodes, rails, horizon)`
    /// (CLI: `--fault-severe`).
    pub fn synthesize_severe(
        seed: u64,
        rate: f64,
        world: usize,
        nodes: usize,
        rails: usize,
        horizon: f64,
    ) -> FaultPlan {
        assert!(rate >= 0.0 && rate.is_finite(), "fault rate must be >= 0");
        assert!(
            horizon > 0.0 && horizon.is_finite(),
            "fault horizon must be finite and > 0"
        );
        let mut rng = Rng::new(seed ^ 0x0D1E_5EED_u64.rotate_left(13));
        let mut plan = FaultPlan::default();
        let n = (rate * world as f64).round() as usize;
        let mut death_spent = false;
        for _ in 0..n {
            let t0 = rng.f64() * horizon * 0.8;
            let dur = (0.05 + 0.25 * rng.f64()) * horizon;
            let rail = rng.usize_in(0, rails.max(1));
            match rng.gen_range(10) {
                0..=3 => {
                    let rank = rng.usize_in(0, world);
                    plan.link_faults
                        .push(LinkFault::flap(FaultTarget::Nic { rank, rail }, t0, dur));
                }
                4..=5 => {
                    let rank = rng.usize_in(0, world);
                    let factor = 0.1 + 0.7 * rng.f64();
                    plan.link_faults.push(LinkFault::degrade(
                        FaultTarget::Nic { rank, rail },
                        t0,
                        dur,
                        factor,
                    ));
                }
                6 => {
                    let factor = 0.1 + 0.7 * rng.f64();
                    plan.link_faults.push(LinkFault::degrade(
                        FaultTarget::Spine { rail },
                        t0,
                        dur,
                        factor,
                    ));
                }
                7 => {
                    let rank = rng.usize_in(0, world);
                    plan.stragglers.push(Straggler {
                        rank,
                        factor: 1.1 + rng.f64(),
                    });
                }
                // permanent faults: one death budget per plan, rail
                // death only where another plane survives
                _ => {
                    if !death_spent && world > 1 {
                        death_spent = true;
                        let scope = if nodes > 1 && rng.gen_range(2) == 1 {
                            DeathScope::Node(rng.usize_in(0, nodes))
                        } else {
                            DeathScope::Rank(rng.usize_in(0, world))
                        };
                        plan.deaths.push(Death { scope, t: t0 });
                    } else if rails > 1 {
                        plan.link_faults.push(LinkFault {
                            target: FaultTarget::Rail { rail },
                            t_start: t0,
                            t_end: f64::INFINITY,
                            factor: 0.0,
                        });
                    } else {
                        let rank = rng.usize_in(0, world);
                        plan.link_faults
                            .push(LinkFault::flap(FaultTarget::Nic { rank, rail }, t0, dur));
                    }
                }
            }
        }
        plan
    }
}

/// Render the plan back into the `--faults` DSL it parses from. The
/// scheduled faults round-trip exactly —
/// `FaultPlan::parse(&plan.to_string())` reproduces `link_faults`,
/// `deaths`, `stragglers`, and `jitter` bit-for-bit — whenever the
/// interval arithmetic is exact in f64 (`t_start + (t_end - t_start) ==
/// t_end`; always true for dyadic-rational times and for permanent
/// `t_end = inf`). The recovery knobs (`lt_timeout`, `retry_max`,
/// `retry_backoff`) are CLI flags, not DSL clauses, and are not
/// rendered.
impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut sep = "";
        let mut clause = |f: &mut std::fmt::Formatter<'_>, s: String| {
            let r = write!(f, "{sep}{s}");
            sep = "; ";
            r
        };
        for lf in &self.link_faults {
            let target = match lf.target {
                FaultTarget::Nic { rank, rail } => format!("nic,{rank},{rail}"),
                FaultTarget::Spine { rail } => format!("spine,{rail}"),
                FaultTarget::Rail { rail } => format!("rail,{rail}"),
                FaultTarget::Rank { rank } => format!("rank,{rank}"),
                FaultTarget::Node { node } => format!("node,{node}"),
            };
            let s = if lf.factor == 0.0
                && lf.t_end.is_infinite()
                && matches!(lf.target, FaultTarget::Rail { .. })
            {
                let rail = match lf.target {
                    FaultTarget::Rail { rail } => rail,
                    _ => unreachable!(),
                };
                format!("raildead,{rail},{}", lf.t_start)
            } else if lf.factor == 0.0 {
                format!("flap,{target},{},{}", lf.t_start, lf.t_end - lf.t_start)
            } else {
                format!(
                    "deg,{target},{},{},{}",
                    lf.t_start,
                    lf.t_end - lf.t_start,
                    lf.factor
                )
            };
            clause(f, s)?;
        }
        for d in &self.deaths {
            let s = match d.scope {
                DeathScope::Rank(rank) => format!("die,{rank},{}", d.t),
                DeathScope::Node(node) => format!("nodedead,{node},{}", d.t),
            };
            clause(f, s)?;
        }
        for s in &self.stragglers {
            clause(f, format!("strag,{},{}", s.rank, s.factor))?;
        }
        if let Some(j) = &self.jitter {
            clause(f, format!("jitter,{},{}", j.seed, j.max_secs))?;
        }
        Ok(())
    }
}

fn check_time(clause: &str, t0: f64, dur: f64) -> Result<(), String> {
    if !(t0 >= 0.0) || !t0.is_finite() {
        return Err(format!(
            "fault clause '{clause}': start time must be finite and >= 0"
        ));
    }
    if !(dur >= 0.0) {
        return Err(format!("fault clause '{clause}': duration must be >= 0"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_empty() {
        let p = FaultPlan::default();
        assert!(p.is_empty());
        assert!(p.lt_timeout.is_infinite());
        // a finite watchdog alone does not make the plan non-empty
        let watch = FaultPlan {
            lt_timeout: 1.0,
            ..FaultPlan::default()
        };
        assert!(watch.is_empty());
    }

    #[test]
    fn parse_full_dsl() {
        let p = FaultPlan::parse(
            "flap,nic,3,1,1e-3,2e-3; deg,spine,0,0.5e-3,1e-3,0.25; \
             raildead,1,4e-3; strag,5,1.5; jitter,42,1e-6;",
        )
        .unwrap();
        assert_eq!(p.link_faults.len(), 3);
        assert_eq!(
            p.link_faults[0],
            LinkFault {
                target: FaultTarget::Nic { rank: 3, rail: 1 },
                t_start: 1e-3,
                t_end: 3e-3,
                factor: 0.0,
            }
        );
        assert_eq!(p.link_faults[1].factor, 0.25);
        assert_eq!(p.link_faults[1].target, FaultTarget::Spine { rail: 0 });
        assert!(p.link_faults[2].t_end.is_infinite());
        assert_eq!(p.link_faults[2].target, FaultTarget::Rail { rail: 1 });
        assert_eq!(p.stragglers, vec![Straggler { rank: 5, factor: 1.5 }]);
        assert_eq!(
            p.jitter,
            Some(Jitter {
                seed: 42,
                max_secs: 1e-6
            })
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("explode,everything").is_err());
        assert!(FaultPlan::parse("flap,nic,3").is_err());
        assert!(FaultPlan::parse("deg,nic,0,0,0,1e-3,1.5").is_err()); // factor >= 1
        assert!(FaultPlan::parse("strag,0,0.5").is_err()); // speedup, not straggle
        assert!(FaultPlan::parse("flap,nic,0,0,-1,1e-3").is_err()); // negative start
        assert!(FaultPlan::parse("jitter,1,0").is_err());
        // empty clauses / whitespace tolerated
        assert!(FaultPlan::parse(" ; ;").unwrap().is_empty());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn backoff_is_exponential_and_capped() {
        let p = FaultPlan::default();
        assert_eq!(p.backoff(1), p.retry_backoff);
        assert_eq!(p.backoff(2), 2.0 * p.retry_backoff);
        assert_eq!(p.backoff(3), 4.0 * p.retry_backoff);
        assert!(p.backoff(40) <= FaultPlan::BACKOFF_CAP);
        assert_eq!(p.backoff(40), FaultPlan::BACKOFF_CAP);
    }

    #[test]
    fn straggle_factor_stacks() {
        let p = FaultPlan::parse("strag,2,1.5; strag,2,2.0; strag,3,1.25").unwrap();
        assert_eq!(p.straggle_factor(0), 1.0);
        assert_eq!(p.straggle_factor(3), 1.25);
        assert!((p.straggle_factor(2) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn parse_permanent_deaths() {
        let p = FaultPlan::parse("die,3,1e-3; nodedead,1,2e-3").unwrap();
        assert!(p.has_deaths());
        assert_eq!(
            p.deaths,
            vec![
                Death {
                    scope: DeathScope::Rank(3),
                    t: 1e-3
                },
                Death {
                    scope: DeathScope::Node(1),
                    t: 2e-3
                },
            ]
        );
        // deaths alone make the plan non-empty (bit-identity gate)
        assert!(!p.is_empty());
        // malformed death clauses: structured errors, never panics
        assert!(FaultPlan::parse("die,3").is_err());
        assert!(FaultPlan::parse("die,x,1e-3").is_err());
        assert!(FaultPlan::parse("die,3,-1").is_err());
        assert!(FaultPlan::parse("nodedead,0,nan").is_err());
    }

    #[test]
    fn display_roundtrips_through_parse() {
        let spec = "flap,nic,3,1,0.001,0.002; deg,spine,0,0.0005,0.001,0.25; \
                    raildead,1,0.004; flap,rail,0,0.001,0.002; \
                    die,3,0.001; nodedead,1,0.002; strag,5,1.5; jitter,42,0.000001";
        let p = FaultPlan::parse(spec).unwrap();
        let q = FaultPlan::parse(&p.to_string()).unwrap();
        assert_eq!(p, q, "display must round-trip:\n  {p}");
        // rank/node-scoped link faults render and parse too
        let p = FaultPlan::parse("flap,rank,2,0.001,0.002; deg,node,1,0.001,0.002,0.5").unwrap();
        assert_eq!(
            p.link_faults[0].target,
            FaultTarget::Rank { rank: 2 }
        );
        assert_eq!(
            p.link_faults[1].target,
            FaultTarget::Node { node: 1 }
        );
        assert_eq!(FaultPlan::parse(&p.to_string()).unwrap(), p);
    }

    #[test]
    fn synthesize_default_tier_never_emits_permanent_faults() {
        for seed in 0..64u64 {
            let p = FaultPlan::synthesize(seed, 1.0, 16, 2, 1e-2);
            assert!(p.deaths.is_empty(), "seed {seed} emitted a death");
            for lf in &p.link_faults {
                assert!(
                    lf.t_end.is_finite(),
                    "seed {seed} emitted a permanent link fault"
                );
            }
        }
    }

    #[test]
    fn synthesize_severe_caps_deaths_and_is_deterministic() {
        let a = FaultPlan::synthesize_severe(7, 1.0, 16, 2, 2, 1e-2);
        let b = FaultPlan::synthesize_severe(7, 1.0, 16, 2, 2, 1e-2);
        assert_eq!(a, b);
        let mut saw_death = false;
        for seed in 0..64u64 {
            let p = FaultPlan::synthesize_severe(seed, 1.0, 16, 2, 2, 1e-2);
            assert!(p.deaths.len() <= 1, "seed {seed}: more than one death");
            saw_death |= !p.deaths.is_empty();
            for d in &p.deaths {
                match d.scope {
                    DeathScope::Rank(r) => assert!(r < 16),
                    DeathScope::Node(n) => assert!(n < 2),
                }
            }
            // permanent rail faults only with an alive plane remaining
            let single_rail = FaultPlan::synthesize_severe(seed, 1.0, 16, 2, 1, 1e-2);
            for lf in &single_rail.link_faults {
                assert!(
                    !(lf.t_end.is_infinite()
                        && matches!(lf.target, FaultTarget::Rail { .. })),
                    "seed {seed}: raildead on a single-rail fabric"
                );
            }
        }
        assert!(saw_death, "severe tier never escalated in 64 seeds");
        // a 1-rank world cannot lose its only rank
        for seed in 0..16u64 {
            assert!(FaultPlan::synthesize_severe(seed, 4.0, 1, 1, 1, 1e-2)
                .deaths
                .is_empty());
        }
    }

    #[test]
    fn synthesize_is_deterministic() {
        let a = FaultPlan::synthesize(7, 0.5, 16, 2, 1e-2);
        let b = FaultPlan::synthesize(7, 0.5, 16, 2, 1e-2);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        let c = FaultPlan::synthesize(8, 0.5, 16, 2, 1e-2);
        assert_ne!(a, c, "different seeds should differ");
        // every synthesized fault is inside the horizon and well-formed
        for lf in &a.link_faults {
            assert!(lf.t_start >= 0.0 && lf.t_start < 1e-2);
            assert!(lf.t_end > lf.t_start);
            assert!((0.0..1.0).contains(&lf.factor));
        }
        for s in &a.stragglers {
            assert!(s.factor > 1.0);
            assert!(s.rank < 16);
        }
        // zero rate: empty plan
        assert!(FaultPlan::synthesize(1, 0.0, 16, 2, 1e-2).is_empty());
    }
}
