//! Serving workload traces: a deterministic request arrival process for
//! the trace-driven serving simulator (`coordinator::serve`).
//!
//! A [`TracePlan`] mirrors the [`FaultPlan`](super::FaultPlan) design:
//! a semicolon-separated clause DSL (`--trace`), an exact
//! [`Display`](struct.TracePlan.html#impl-Display-for-TracePlan)
//! round-trip, a seeded [`synthesize`](TracePlan::synthesize), and an
//! [`is_empty`](TracePlan::is_empty) contract — an empty plan admits no
//! requests and the serving loop degenerates to a no-op, bit-identical
//! to never having invoked it.
//!
//! [`TracePlan::materialize`] expands the plan into an
//! [`ArrivalTrace`]: a time-sorted list of [`Request`]s with seeded
//! prompt/output lengths. Same plan, same trace, bit-for-bit — every
//! draw comes from the clause's own [`Rng`] stream, so two processes in
//! one plan never perturb each other.

use crate::util::Rng;

/// Shape of one arrival process clause.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalKind {
    /// Homogeneous Poisson arrivals at `rate` requests/s
    /// (DSL: `poisson,<rate>,<n>,<seed>`).
    Poisson,
    /// On/off bursts: the first half of every `period` runs at
    /// `rate * factor`, the second at `rate / factor`
    /// (DSL: `bursty,<rate>,<n>,<seed>,<factor>,<period>`).
    Bursty {
        /// Peak-to-mean rate multiplier (>= 1).
        factor: f64,
        /// Burst cycle length (s).
        period: f64,
    },
    /// Sinusoidal day/night cycle: instantaneous rate
    /// `rate * (1 + depth * sin(2*pi*t/period))`
    /// (DSL: `diurnal,<rate>,<n>,<seed>,<period>,<depth>`).
    Diurnal {
        /// Cycle length (s).
        period: f64,
        /// Modulation depth in `[0, 1)` — the trough rate stays > 0.
        depth: f64,
    },
}

/// One seeded arrival process: `n` requests at a mean `rate`, shaped by
/// `kind`, every draw from the process's own `seed` stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArrivalProc {
    pub kind: ArrivalKind,
    /// Mean arrival rate (requests/s), finite and > 0.
    pub rate: f64,
    /// Number of requests this process contributes.
    pub n: usize,
    /// Seed for inter-arrival and length draws.
    pub seed: u64,
}

/// One explicitly scheduled request (DSL: `req,<t>,<prompt>,<output>`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceReq {
    /// Arrival time (s).
    pub t: f64,
    /// Prompt length (tokens, >= 1).
    pub prompt: usize,
    /// Output length (tokens, >= 1).
    pub output: usize,
}

/// The complete, deterministic workload schedule plus length knobs.
///
/// `prompt_mean` / `output_mean` parameterize the seeded length draws
/// of the arrival processes; like `FaultPlan`'s recovery knobs they do
/// not affect [`is_empty`](Self::is_empty) (means with no arrivals to
/// apply them to cannot produce a request) — but unlike those knobs
/// they *are* part of the DSL (`lens,<prompt>,<output>`, rendered only
/// when non-default) so plans round-trip exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct TracePlan {
    /// Seeded arrival processes, expanded in order.
    pub procs: Vec<ArrivalProc>,
    /// Explicitly scheduled requests, merged after the processes.
    pub explicit: Vec<TraceReq>,
    /// Mean prompt length (tokens) for generated requests.
    pub prompt_mean: usize,
    /// Mean output length (tokens) for generated requests.
    pub output_mean: usize,
}

impl Default for TracePlan {
    fn default() -> Self {
        TracePlan {
            procs: Vec::new(),
            explicit: Vec::new(),
            prompt_mean: Self::DEFAULT_PROMPT_MEAN,
            output_mean: Self::DEFAULT_OUTPUT_MEAN,
        }
    }
}

/// One request of a materialized trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    /// Trace-wide id, dense `0..len` in arrival order.
    pub id: usize,
    /// Arrival time (s).
    pub t_arrive: f64,
    /// Prompt length (tokens, >= 1).
    pub prompt_tokens: usize,
    /// Output length (tokens, >= 1).
    pub output_tokens: usize,
}

/// A materialized, time-sorted request trace.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ArrivalTrace {
    /// Requests sorted by `t_arrive` (stable on ties), ids dense.
    pub requests: Vec<Request>,
}

impl ArrivalTrace {
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// Arrival time of the last request (0 for an empty trace).
    pub fn horizon(&self) -> f64 {
        self.requests.last().map_or(0.0, |r| r.t_arrive)
    }
}

impl TracePlan {
    /// Default mean prompt length (tokens).
    pub const DEFAULT_PROMPT_MEAN: usize = 128;
    /// Default mean output length (tokens).
    pub const DEFAULT_OUTPUT_MEAN: usize = 32;
    /// Default burst peak-to-mean factor for `--arrival bursty`.
    pub const DEFAULT_BURST_FACTOR: f64 = 4.0;
    /// Default burst cycle (s) for `--arrival bursty`.
    pub const DEFAULT_BURST_PERIOD: f64 = 2e-3;
    /// Default day/night cycle (s) for `--arrival diurnal`.
    pub const DEFAULT_DIURNAL_PERIOD: f64 = 8e-3;
    /// Default modulation depth for `--arrival diurnal`.
    pub const DEFAULT_DIURNAL_DEPTH: f64 = 0.75;

    /// No requests at all: the serving loop is a no-op. The length
    /// means are ignored — with nothing arriving they cannot perturb
    /// anything.
    pub fn is_empty(&self) -> bool {
        self.procs.is_empty() && self.explicit.is_empty()
    }

    /// Total number of requests the plan will materialize.
    pub fn total_requests(&self) -> usize {
        self.procs.iter().map(|p| p.n).sum::<usize>() + self.explicit.len()
    }

    /// Single-process plan for the CLI's
    /// `--arrival poisson|bursty|diurnal` shorthand; bursty/diurnal get
    /// the default shape constants (use the `--trace` DSL for custom
    /// shapes).
    pub fn arrival(kind: &str, rate: f64, n: usize, seed: u64) -> Result<TracePlan, String> {
        let kind = match kind {
            "poisson" => ArrivalKind::Poisson,
            "bursty" => ArrivalKind::Bursty {
                factor: Self::DEFAULT_BURST_FACTOR,
                period: Self::DEFAULT_BURST_PERIOD,
            },
            "diurnal" => ArrivalKind::Diurnal {
                period: Self::DEFAULT_DIURNAL_PERIOD,
                depth: Self::DEFAULT_DIURNAL_DEPTH,
            },
            other => {
                return Err(format!(
                    "unknown arrival kind '{other}' (poisson|bursty|diurnal)"
                ))
            }
        };
        check_rate("--arrival", rate)?;
        Ok(TracePlan {
            procs: vec![ArrivalProc { kind, rate, n, seed }],
            ..TracePlan::default()
        })
    }

    /// Parse a semicolon-separated trace DSL (the `--trace` flag):
    ///
    /// * `poisson,<rate>,<n>,<seed>` — homogeneous Poisson arrivals
    /// * `bursty,<rate>,<n>,<seed>,<factor>,<period>` — on/off bursts
    /// * `diurnal,<rate>,<n>,<seed>,<period>,<depth>` — sinusoidal cycle
    /// * `req,<t>,<prompt>,<output>` — one explicit request
    /// * `lens,<prompt_mean>,<output_mean>` — length means for the
    ///   seeded draws (last clause wins)
    ///
    /// Whitespace around separators is ignored; empty clauses are
    /// skipped, so a trailing `;` is fine. Malformed clauses (wrong
    /// arity, unknown kind, non-numeric, non-positive rate, depth
    /// outside `[0, 1)`, …) return a structured `Err` naming the clause
    /// — never a panic. `parse` is the exact inverse of the `Display`
    /// rendering.
    pub fn parse(s: &str) -> Result<TracePlan, String> {
        let mut plan = TracePlan::default();
        for clause in s.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let f: Vec<&str> = clause.split(',').map(str::trim).collect();
            let usize_at = |i: usize| -> Result<usize, String> {
                f.get(i)
                    .ok_or_else(|| format!("trace clause '{clause}': missing field {i}"))?
                    .parse::<usize>()
                    .map_err(|e| format!("trace clause '{clause}' field {i}: {e}"))
            };
            let u64_at = |i: usize| -> Result<u64, String> {
                f.get(i)
                    .ok_or_else(|| format!("trace clause '{clause}': missing field {i}"))?
                    .parse::<u64>()
                    .map_err(|e| format!("trace clause '{clause}' field {i}: {e}"))
            };
            let f64_at = |i: usize| -> Result<f64, String> {
                f.get(i)
                    .ok_or_else(|| format!("trace clause '{clause}': missing field {i}"))?
                    .parse::<f64>()
                    .map_err(|e| format!("trace clause '{clause}' field {i}: {e}"))
            };
            match f[0] {
                "poisson" => {
                    let (rate, n, seed) = (f64_at(1)?, usize_at(2)?, u64_at(3)?);
                    check_rate(clause, rate)?;
                    plan.procs.push(ArrivalProc {
                        kind: ArrivalKind::Poisson,
                        rate,
                        n,
                        seed,
                    });
                }
                "bursty" => {
                    let (rate, n, seed) = (f64_at(1)?, usize_at(2)?, u64_at(3)?);
                    let (factor, period) = (f64_at(4)?, f64_at(5)?);
                    check_rate(clause, rate)?;
                    if !(factor >= 1.0) || !factor.is_finite() {
                        return Err(format!(
                            "trace clause '{clause}': burst factor must be finite and >= 1"
                        ));
                    }
                    check_period(clause, period)?;
                    plan.procs.push(ArrivalProc {
                        kind: ArrivalKind::Bursty { factor, period },
                        rate,
                        n,
                        seed,
                    });
                }
                "diurnal" => {
                    let (rate, n, seed) = (f64_at(1)?, usize_at(2)?, u64_at(3)?);
                    let (period, depth) = (f64_at(4)?, f64_at(5)?);
                    check_rate(clause, rate)?;
                    check_period(clause, period)?;
                    if !(0.0..1.0).contains(&depth) {
                        return Err(format!(
                            "trace clause '{clause}': diurnal depth must be in [0, 1)"
                        ));
                    }
                    plan.procs.push(ArrivalProc {
                        kind: ArrivalKind::Diurnal { period, depth },
                        rate,
                        n,
                        seed,
                    });
                }
                "req" => {
                    let (t, prompt, output) = (f64_at(1)?, usize_at(2)?, usize_at(3)?);
                    if !(t >= 0.0) || !t.is_finite() {
                        return Err(format!(
                            "trace clause '{clause}': arrival time must be finite and >= 0"
                        ));
                    }
                    check_len(clause, prompt, "prompt")?;
                    check_len(clause, output, "output")?;
                    plan.explicit.push(TraceReq { t, prompt, output });
                }
                "lens" => {
                    let (p, o) = (usize_at(1)?, usize_at(2)?);
                    check_len(clause, p, "prompt mean")?;
                    check_len(clause, o, "output mean")?;
                    plan.prompt_mean = p;
                    plan.output_mean = o;
                }
                other => {
                    return Err(format!(
                        "unknown trace kind '{other}' (poisson|bursty|diurnal|req|lens)"
                    ))
                }
            }
        }
        Ok(plan)
    }

    /// Synthesize a random-but-deterministic plan from a seed: `n`
    /// requests total across 1–3 processes of mixed kinds around the
    /// given mean `rate`, occasionally with explicit requests and
    /// non-default length means. Same `(seed, rate, n)`, same plan.
    pub fn synthesize(seed: u64, rate: f64, n: usize) -> TracePlan {
        assert!(rate > 0.0 && rate.is_finite(), "trace rate must be > 0");
        let mut rng = Rng::new(seed ^ 0x7ACE_5EED_u64.rotate_left(17));
        let mut plan = TracePlan::default();
        let procs = 1 + rng.usize_in(0, 3);
        let per = (n / procs).max(1);
        for _ in 0..procs {
            let r = rate * (0.5 + rng.f64());
            let kind = match rng.gen_range(3) {
                0 => ArrivalKind::Poisson,
                1 => ArrivalKind::Bursty {
                    factor: 2.0 + (rng.gen_range(6) as f64) / 2.0,
                    period: Self::DEFAULT_BURST_PERIOD,
                },
                _ => ArrivalKind::Diurnal {
                    period: Self::DEFAULT_DIURNAL_PERIOD,
                    depth: (rng.gen_range(15) as f64) / 16.0,
                },
            };
            plan.procs.push(ArrivalProc {
                kind,
                rate: r,
                n: per,
                seed: rng.next_u64(),
            });
        }
        if rng.gen_range(2) == 1 {
            plan.explicit.push(TraceReq {
                t: (rng.usize_in(0, 1 << 12) as f64) / (1u64 << 20) as f64,
                prompt: 1 + rng.usize_in(0, 512),
                output: 1 + rng.usize_in(0, 128),
            });
        }
        if rng.gen_range(2) == 1 {
            plan.prompt_mean = 16 + rng.usize_in(0, 512);
            plan.output_mean = 4 + rng.usize_in(0, 128);
        }
        plan
    }

    /// Expand the plan into a time-sorted [`ArrivalTrace`].
    ///
    /// Each process draws its inter-arrival gaps sequentially from its
    /// own seed stream — exponential with the *instantaneous* rate at
    /// the current time (the standard next-gap approximation of an
    /// inhomogeneous Poisson process) — then its prompt/output lengths
    /// (exponential around the plan means, floored at 1 token). The
    /// merge is a stable sort on arrival time, so equal-time requests
    /// keep (process order, draw order) and ids are dense in arrival
    /// order. Deterministic: same plan, same trace, bit-for-bit.
    pub fn materialize(&self) -> ArrivalTrace {
        let mut reqs: Vec<Request> = Vec::with_capacity(self.total_requests());
        for p in &self.procs {
            let mut rng = Rng::new(p.seed);
            let mut t = 0.0f64;
            for _ in 0..p.n {
                let r = instantaneous_rate(&p.kind, p.rate, t);
                t += -(1.0 - rng.f64()).ln() / r;
                let prompt = draw_len(&mut rng, self.prompt_mean);
                let output = draw_len(&mut rng, self.output_mean);
                reqs.push(Request {
                    id: 0,
                    t_arrive: t,
                    prompt_tokens: prompt,
                    output_tokens: output,
                });
            }
        }
        for e in &self.explicit {
            reqs.push(Request {
                id: 0,
                t_arrive: e.t,
                prompt_tokens: e.prompt,
                output_tokens: e.output,
            });
        }
        // stable: equal arrival times keep generation order
        reqs.sort_by(|a, b| a.t_arrive.partial_cmp(&b.t_arrive).unwrap());
        for (i, r) in reqs.iter_mut().enumerate() {
            r.id = i;
        }
        ArrivalTrace { requests: reqs }
    }
}

/// Instantaneous arrival rate of a process at time `t`; always finite
/// and > 0 for a validated plan.
fn instantaneous_rate(kind: &ArrivalKind, rate: f64, t: f64) -> f64 {
    match *kind {
        ArrivalKind::Poisson => rate,
        ArrivalKind::Bursty { factor, period } => {
            let phase = (t / period).rem_euclid(1.0);
            if phase < 0.5 {
                rate * factor
            } else {
                rate / factor
            }
        }
        ArrivalKind::Diurnal { period, depth } => {
            rate * (1.0 + depth * (std::f64::consts::TAU * t / period).sin())
        }
    }
}

/// Exponential length draw around `mean`, floored at one token.
fn draw_len(rng: &mut Rng, mean: usize) -> usize {
    let x = -(1.0 - rng.f64()).ln() * mean as f64;
    (x.round() as usize).max(1)
}

fn check_rate(clause: &str, rate: f64) -> Result<(), String> {
    if !(rate > 0.0) || !rate.is_finite() {
        return Err(format!(
            "trace clause '{clause}': rate must be finite and > 0"
        ));
    }
    Ok(())
}

fn check_period(clause: &str, period: f64) -> Result<(), String> {
    if !(period > 0.0) || !period.is_finite() {
        return Err(format!(
            "trace clause '{clause}': period must be finite and > 0"
        ));
    }
    Ok(())
}

fn check_len(clause: &str, v: usize, what: &str) -> Result<(), String> {
    if v == 0 {
        return Err(format!("trace clause '{clause}': {what} must be >= 1"));
    }
    Ok(())
}

/// Render the plan back into the `--trace` DSL it parses from. The
/// round-trip is exact — `TracePlan::parse(&plan.to_string()) ==
/// *plan`, bit-for-bit, for any validated plan (every numeric field is
/// rendered with Rust's shortest-round-trip float formatting and parsed
/// straight back; there is no interval arithmetic to lose bits to).
/// The length means are rendered as a `lens` clause only when
/// non-default.
impl std::fmt::Display for TracePlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut sep = "";
        let mut clause = |f: &mut std::fmt::Formatter<'_>, s: String| {
            let r = write!(f, "{sep}{s}");
            sep = "; ";
            r
        };
        for p in &self.procs {
            let s = match p.kind {
                ArrivalKind::Poisson => {
                    format!("poisson,{},{},{}", p.rate, p.n, p.seed)
                }
                ArrivalKind::Bursty { factor, period } => {
                    format!("bursty,{},{},{},{factor},{period}", p.rate, p.n, p.seed)
                }
                ArrivalKind::Diurnal { period, depth } => {
                    format!("diurnal,{},{},{},{period},{depth}", p.rate, p.n, p.seed)
                }
            };
            clause(f, s)?;
        }
        for e in &self.explicit {
            clause(f, format!("req,{},{},{}", e.t, e.prompt, e.output))?;
        }
        if self.prompt_mean != Self::DEFAULT_PROMPT_MEAN
            || self.output_mean != Self::DEFAULT_OUTPUT_MEAN
        {
            clause(f, format!("lens,{},{}", self.prompt_mean, self.output_mean))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn default_plan_is_empty() {
        let p = TracePlan::default();
        assert!(p.is_empty());
        assert_eq!(p.total_requests(), 0);
        assert!(p.materialize().is_empty());
        // non-default length means alone do not make the plan non-empty
        let lens_only = TracePlan::parse("lens,64,8").unwrap();
        assert!(lens_only.is_empty());
        assert!(lens_only.materialize().is_empty());
    }

    #[test]
    fn parse_full_dsl() {
        let p = TracePlan::parse(
            "poisson,5e4,100,7; bursty,2e4,50,11,4,2e-3; \
             diurnal,1e4,25,13,8e-3,0.75; req,1e-3,256,16; lens,64,8;",
        )
        .unwrap();
        assert_eq!(p.procs.len(), 3);
        assert_eq!(
            p.procs[0],
            ArrivalProc {
                kind: ArrivalKind::Poisson,
                rate: 5e4,
                n: 100,
                seed: 7
            }
        );
        assert_eq!(
            p.procs[1].kind,
            ArrivalKind::Bursty {
                factor: 4.0,
                period: 2e-3
            }
        );
        assert_eq!(
            p.procs[2].kind,
            ArrivalKind::Diurnal {
                period: 8e-3,
                depth: 0.75
            }
        );
        assert_eq!(
            p.explicit,
            vec![TraceReq {
                t: 1e-3,
                prompt: 256,
                output: 16
            }]
        );
        assert_eq!((p.prompt_mean, p.output_mean), (64, 8));
        assert_eq!(p.total_requests(), 176);
    }

    #[test]
    fn malformed_clauses_error_never_panic() {
        for s in [
            "gaussian,1e4,10,7",          // unknown kind
            "poisson,1e4,10",             // missing seed
            "poisson,0,10,7",             // zero rate
            "poisson,-5,10,7",            // negative rate
            "poisson,inf,10,7",           // non-finite rate
            "poisson,abc,10,7",           // non-numeric
            "bursty,1e4,10,7,0.5,2e-3",   // factor < 1
            "bursty,1e4,10,7,4,0",        // zero period
            "diurnal,1e4,10,7,8e-3,1.0",  // depth out of range
            "diurnal,1e4,10,7,8e-3,-0.1", // depth negative
            "req,-1,10,10",               // negative time
            "req,1e-3,0,10",              // zero prompt
            "req,1e-3,10,0",              // zero output
            "lens,0,8",                   // zero mean
        ] {
            let e = TracePlan::parse(s).expect_err(s);
            assert!(e.contains("clause") || e.contains("kind"), "{s}: {e}");
        }
    }

    #[test]
    fn display_round_trips_exactly() {
        check("trace_display_round_trip", 128, |g| {
            let plan = TracePlan::synthesize(g.u64(), 1e4 * (0.1 + g.f64()), 1 + g.usize_in(0, 64));
            let rendered = plan.to_string();
            let back = TracePlan::parse(&rendered)
                .unwrap_or_else(|e| panic!("'{rendered}' failed to re-parse: {e}"));
            assert_eq!(back, plan, "round-trip mismatch for '{rendered}'");
        });
    }

    #[test]
    fn materialize_is_deterministic_and_sorted() {
        let plan = TracePlan::parse(
            "poisson,5e4,64,7; bursty,2e4,32,11,4,2e-3; diurnal,1e4,16,13,8e-3,0.5",
        )
        .unwrap();
        let a = plan.materialize();
        let b = plan.materialize();
        assert_eq!(a, b);
        assert_eq!(a.len(), plan.total_requests());
        for w in a.requests.windows(2) {
            assert!(w[0].t_arrive <= w[1].t_arrive);
        }
        for (i, r) in a.requests.iter().enumerate() {
            assert_eq!(r.id, i);
            assert!(r.t_arrive.is_finite() && r.t_arrive >= 0.0);
            assert!(r.prompt_tokens >= 1 && r.output_tokens >= 1);
        }
        // a different seed on one process perturbs the trace
        let plan2 = TracePlan::parse(
            "poisson,5e4,64,8; bursty,2e4,32,11,4,2e-3; diurnal,1e4,16,13,8e-3,0.5",
        )
        .unwrap();
        assert_ne!(plan2.materialize(), a);
    }

    #[test]
    fn poisson_mean_rate_is_plausible() {
        // 4096 arrivals at 1e4/s should span ~0.41 s; allow a wide band
        let plan = TracePlan::parse("poisson,1e4,4096,42").unwrap();
        let trace = plan.materialize();
        let span = trace.horizon();
        let rate = trace.len() as f64 / span;
        assert!(
            (0.5e4..2e4).contains(&rate),
            "empirical rate {rate:.0}/s too far from 1e4/s"
        );
    }

    #[test]
    fn explicit_requests_merge_in_time_order() {
        let plan = TracePlan::parse("req,2e-3,8,4; req,1e-3,16,2; poisson,1e5,4,3").unwrap();
        let trace = plan.materialize();
        assert_eq!(trace.len(), 6);
        let explicit: Vec<_> = trace
            .requests
            .iter()
            .filter(|r| r.prompt_tokens == 8 || r.prompt_tokens == 16)
            .collect();
        assert_eq!(explicit.len(), 2);
        assert!(explicit[0].prompt_tokens == 16 && explicit[0].t_arrive == 1e-3);
        assert!(explicit[1].prompt_tokens == 8 && explicit[1].t_arrive == 2e-3);
    }
}
