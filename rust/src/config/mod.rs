//! Cluster + workload configuration and calibrated hardware presets.
//!
//! Parameters come from the paper (§3.4, §3.5, §3.7, Fig. 15) and public
//! spec sheets — see DESIGN.md §5 for the calibration table. Absolute
//! numbers are estimates; every benchmark reports the *relative* shape
//! (who wins, by what factor), which is what the reproduction targets.

pub mod fault;
pub mod workload;

pub use fault::{Death, DeathScope, FaultPlan, FaultTarget, Jitter, LinkFault, Straggler};
pub use workload::{ArrivalKind, ArrivalProc, ArrivalTrace, Request, TracePlan, TraceReq};

/// Accelerator family being simulated.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HardwareKind {
    /// Nvidia H800: NVSwitch intra-node, CX7 IB inter-node.
    H800,
    /// AMD MI308X: full-mesh xGMI intra-node.
    MI308X,
    /// Nvidia L20: PCIe-only intra-node (no NVLink).
    L20,
}

/// Calibrated per-device hardware model.
#[derive(Debug, Clone, Copy)]
pub struct HardwareModel {
    pub kind: HardwareKind,
    /// Dense bf16 peak, FLOP/s.
    pub peak_flops: f64,
    /// Sustained GEMM efficiency of the vendor library (cuBLAS/rocBLAS).
    pub vendor_gemm_eff: f64,
    /// Triton(-generated) GEMM efficiency relative to the vendor library
    /// (the paper reports ~0.95 on Nvidia, slightly lower on AMD).
    pub triton_vs_vendor: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// Number of SMs / CUs.
    pub sms: u32,
    /// Per-SM sustained reduction (read+add+write) bandwidth, bytes/s.
    /// §3.5: ~15 SMs must reach >= 470 GB/s on H800.
    pub sm_reduce_bw: f64,
    /// Intra-node per-GPU aggregate egress bandwidth, bytes/s.
    pub intra_bw: f64,
    /// Intra-node per-link (per-peer) bandwidth for mesh topologies, bytes/s.
    pub intra_link_bw: f64,
    /// Intra-node P2P latency, s.
    pub intra_lat: f64,
    /// Inter-node NIC bandwidth per GPU, bytes/s.
    pub nic_bw: f64,
    /// Inter-node small-message latency, s.
    pub inter_lat: f64,
    /// `multimem.st` broadcast latency within a node, s (H800 only).
    pub multimem_lat: f64,
    /// Extra latency of a put that carries a remote signal update (the
    /// separate flag packet + memory fence the LL protocol eliminates), s.
    pub signal_overhead: f64,
    /// Fixed kernel-launch / runtime-API overhead per launched kernel, s.
    pub launch_overhead: f64,
    /// Number of independent copy-engine (DMA) channels per GPU.
    pub copy_engines: u32,
}

impl HardwareModel {
    pub fn h800() -> Self {
        HardwareModel {
            kind: HardwareKind::H800,
            peak_flops: 989e12,
            vendor_gemm_eff: 0.62,
            triton_vs_vendor: 0.95,
            hbm_bw: 3.0e12,
            sms: 132,
            // 15 SMs ~= 500 GB/s >= the paper's 470 GB/s threshold (§3.5)
            sm_reduce_bw: 33.5e9,
            intra_bw: 170e9,     // §3.5 "around 170 GB/s NVLink maximum"
            intra_link_bw: 200e9, // §3.7 per-pair through NVSwitch
            intra_lat: 0.5e-6,   // §3.4 "NVLink takes approximately 0.5us"
            nic_bw: 45e9,        // §3.5 CX7 400Gb/s -> ~45 GB/s
            inter_lat: 5.0e-6,
            multimem_lat: 1.5e-6, // §3.4
            signal_overhead: 0.8e-6,
            launch_overhead: 4.0e-6,
            copy_engines: 4,
        }
    }

    pub fn mi308x() -> Self {
        HardwareModel {
            kind: HardwareKind::MI308X,
            peak_flops: 1150e12,
            vendor_gemm_eff: 0.58,
            triton_vs_vendor: 0.93, // "slightly lower than rocBLAS" (§4.3)
            hbm_bw: 5.3e12,
            sms: 80,
            sm_reduce_bw: 60e9,
            intra_bw: 350e9,     // §3.7 aggregated 7 x 50 GB/s
            intra_link_bw: 50e9, // §3.7 per-link full mesh
            intra_lat: 0.8e-6,
            nic_bw: 45e9,
            inter_lat: 5.0e-6,
            multimem_lat: f64::INFINITY, // no multimem on AMD
            signal_overhead: 1.2e-6,     // hipStreamWriteValue interference (§3.6)
            launch_overhead: 6.0e-6,     // hip runtime APIs are costlier (§3.6)
            copy_engines: 8,             // one per peer link effectively
        }
    }

    pub fn l20() -> Self {
        HardwareModel {
            kind: HardwareKind::L20,
            peak_flops: 119e12,
            vendor_gemm_eff: 0.60,
            triton_vs_vendor: 0.95,
            hbm_bw: 864e9,
            sms: 92,
            sm_reduce_bw: 20e9,
            intra_bw: 26e9,     // PCIe Gen4 x16 effective
            intra_link_bw: 26e9,
            intra_lat: 1.8e-6,  // PCIe P2P latency
            nic_bw: 25e9,
            inter_lat: 6.0e-6,
            multimem_lat: f64::INFINITY, // no NVLink -> no multimem
            signal_overhead: 0.9e-6,
            launch_overhead: 4.0e-6,
            copy_engines: 2,
        }
    }

    /// Effective Triton GEMM throughput (FLOP/s) when given `sms` SMs.
    pub fn triton_gemm_flops(&self, sms: u32) -> f64 {
        self.peak_flops * self.vendor_gemm_eff * self.triton_vs_vendor * (sms as f64)
            / (self.sms as f64)
    }

    /// Effective vendor-library GEMM throughput (cuBLAS / CUTLASS / rocBLAS).
    pub fn vendor_gemm_flops(&self, sms: u32) -> f64 {
        self.peak_flops * self.vendor_gemm_eff * (sms as f64) / (self.sms as f64)
    }

    /// Local-reduction bandwidth with `sms` SMs (HBM-capped). §3.5.
    pub fn reduce_bw(&self, sms: u32) -> f64 {
        (self.sm_reduce_bw * sms as f64).min(self.hbm_bw / 3.0 * 2.0)
    }
}

/// How `TrafficClass::Auto` messages are mapped onto NIC rails — the
/// router's rail-selection policy (ROADMAP "adaptive rail selection").
///
/// * [`RailPolicy::Static`] (the default) resolves `Auto` to a
///   deterministic rail derived from the endpoints' local ranks, and the
///   collective builders stripe their inter-node segments round-robin
///   (`shmem::ShmemTask::stripe_rail` pins each stream). This reproduces
///   the pre-policy behavior bit-identically.
/// * [`RailPolicy::Adaptive`] defers the decision to simulation time:
///   the router picks the *emptiest* plane per message from the live
///   per-link committed-bytes / in-flight-flow occupancy the DES engine
///   feeds back on every flow post and completion
///   (`topology::LinkOccupancy`). Collective builders emit `Auto`
///   instead of hard rail pins, closing the model→decision feedback
///   loop the §3.8 autotuner can then tune over
///   (`autotune::tune_rail_policy`).
///
/// Explicit pins (`TrafficClass::Rail` / `TrafficClass::Rails`) are
/// always honored regardless of policy.
///
/// ```
/// use triton_dist_sim::config::{ClusterSpec, FabricSpec, RailPolicy};
///
/// let fabric = FabricSpec::rail_optimized(2, 2.0)
///     .with_rail_policy(RailPolicy::Adaptive);
/// let cluster = ClusterSpec::h800(4, 8).with_fabric(fabric);
/// assert_eq!(cluster.fabric.rail_policy, RailPolicy::Adaptive);
/// // the default policy is Static — PR-2 behavior, bit-identical
/// assert_eq!(FabricSpec::default().rail_policy, RailPolicy::Static);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum RailPolicy {
    /// Deterministic round-robin striping decided at program-build time.
    #[default]
    Static,
    /// Congestion-aware: pick the emptiest plane per message at
    /// simulation time from live link occupancy.
    Adaptive,
}

/// When and in what order chunk-level inter-node pieces *issue* — the
/// engine's chunk scheduler (ROADMAP "contention-aware issue order").
/// Where [`RailPolicy`] decides *where* a message goes, `ChunkSched`
/// decides *when*: split dispatch pieces (`A2aCfg::split`) and chunked
/// `ag_inter`/`rs_inter` segments enter a policy-ordered ready queue in
/// `sim/engine.rs` instead of posting eagerly, and the scheduler issues
/// them against the live `topology::LinkOccupancy` view.
///
/// * [`ChunkSched::Fifo`] (the default) bypasses the ready queue
///   entirely: every piece posts the moment its task reaches it, which
///   reproduces the pre-scheduler engine bit-identically.
/// * [`ChunkSched::Srpf`] is shortest-remaining-path-first: the stream
///   with the least remaining bytes issues first, so short latency-bound
///   collectives slip ahead of bulk transfers sharing a thinned tier.
/// * [`ChunkSched::Deadline`] is deadline-aware: pieces whose consumers
///   block on them (combine-leg pieces gating FFN tiles, AG segments
///   gating GEMM tiles) carry deadline 0 and preempt bulk traffic with
///   deadline `u32::MAX`; ties fall back to remaining bytes.
///
/// All three are deterministic — the ready queue breaks ties on the
/// stable `(deadline, task, launch-counter)` key, never on wall-clock or
/// map order — so same-seed replays are bit-identical and the policy is
/// a §3.8 autotune axis (`autotune::tune_chunk_sched`).
///
/// ```
/// use triton_dist_sim::config::{ChunkSched, ClusterSpec, FabricSpec};
///
/// let fabric = FabricSpec::rail_optimized(2, 2.0)
///     .with_chunk_sched(ChunkSched::Srpf);
/// let cluster = ClusterSpec::h800(2, 8).with_fabric(fabric);
/// assert_eq!(cluster.fabric.chunk_sched, ChunkSched::Srpf);
/// // the default policy is Fifo — eager posting, bit-identical
/// assert_eq!(FabricSpec::default().chunk_sched, ChunkSched::Fifo);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum ChunkSched {
    /// Eager issue in program order (pre-scheduler behavior,
    /// bit-identical).
    #[default]
    Fifo,
    /// Shortest-remaining-path-first: least remaining stream bytes wins.
    Srpf,
    /// Deadline-aware: consumer-gating pieces preempt bulk traffic.
    Deadline,
}

/// Inter-node fabric description: how the per-GPU NIC bandwidth is
/// physically organized into rails and switch tiers.
///
/// The default (`rails = 1`, `oversub = 1.0`) is a flat, non-blocking
/// fabric: every GPU owns one NIC pair and the switch can never be a
/// bottleneck — exactly the model the seed topology hard-coded. Routes on
/// a non-blocking fabric contain only the NIC endpoint links, so the
/// default reproduces the old flat-NIC makespans bit-identically.
///
/// With `rails > 1` each GPU's `nic_bw` is split across `rails`
/// rail-optimized NIC planes (per-rail bandwidth `nic_bw / rails`); a
/// message pinned to one rail only gets that rail's share, so collectives
/// must stripe (see [`TrafficClass`]) or let the router balance
/// (see [`RailPolicy`]). With `oversub > 1.0` the leaf→spine
/// uplinks are thinner than the sum of their downlinks by that ratio and
/// the switch tiers are materialized as shared links contended by every
/// inter-node flow of the same (node, rail) / rail.
///
/// ```
/// use triton_dist_sim::config::FabricSpec;
///
/// // 2 NIC rails per GPU behind a 2:1 oversubscribed leaf tier
/// let f = FabricSpec::rail_optimized(2, 2.0);
/// assert!(f.is_blocking());
/// assert_eq!(f.rail_bw(400e9), 200e9); // each rail gets half the NIC
/// // the flat default can never bottleneck below the NIC endpoints
/// assert!(!FabricSpec::flat().is_blocking());
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FabricSpec {
    /// NIC rails per GPU (>= 1). Per-rail bandwidth is `nic_bw / rails`.
    pub rails: usize,
    /// Leaf→spine oversubscription ratio (>= 1.0; 1.0 = non-blocking).
    pub oversub: f64,
    /// Spine-core thinning relative to the sum of the leaf uplinks
    /// feeding each plane (>= 1.0). At 1.0 (the default) the spine is a
    /// non-blocking core: it merges every node into one flow component
    /// and adds `spine_lat`, but the max–min bottleneck is always a leaf
    /// or NIC link (a plane's capacity equals the sum of its feeds, so by
    /// the mediant inequality its fair share never undercuts every
    /// leaf's). Above 1.0 the spine itself becomes a genuine bottleneck.
    pub spine_taper: f64,
    /// Extra propagation latency per leaf-switch hop, s (default 0: the
    /// calibrated `inter_lat` already covers the default switched path).
    pub leaf_lat: f64,
    /// Extra propagation latency per spine-plane traversal, s.
    pub spine_lat: f64,
    /// How `TrafficClass::Auto` messages are mapped onto rails (static
    /// round-robin vs congestion-aware; see [`RailPolicy`]).
    pub rail_policy: RailPolicy,
    /// When chunk-level pieces issue (eager FIFO vs contention-aware
    /// reordering; see [`ChunkSched`]).
    pub chunk_sched: ChunkSched,
}

impl Default for FabricSpec {
    fn default() -> Self {
        FabricSpec {
            rails: 1,
            oversub: 1.0,
            spine_taper: 1.0,
            leaf_lat: 0.0,
            spine_lat: 0.0,
            rail_policy: RailPolicy::Static,
            chunk_sched: ChunkSched::Fifo,
        }
    }
}

impl FabricSpec {
    /// The seed's flat per-GPU NIC model (non-blocking, single rail).
    pub fn flat() -> Self {
        FabricSpec::default()
    }

    /// A rail-optimized multi-rail fabric with a given leaf→spine
    /// oversubscription ratio.
    pub fn rail_optimized(rails: usize, oversub: f64) -> Self {
        assert!(rails >= 1, "fabric needs at least one rail");
        assert!(oversub > 0.0, "oversubscription ratio must be positive");
        FabricSpec {
            rails,
            oversub,
            ..FabricSpec::default()
        }
    }

    /// Thin the spine core by `taper` relative to its leaf-uplink feed
    /// (makes the spine plane itself a genuine max–min bottleneck).
    pub fn with_spine_taper(mut self, taper: f64) -> Self {
        assert!(taper >= 1.0, "spine taper must be >= 1.0");
        self.spine_taper = taper;
        self
    }

    /// Select the rail-selection policy for `TrafficClass::Auto` traffic
    /// (see [`RailPolicy`]). `Static` — the default — is bit-identical to
    /// the pre-policy round-robin striping.
    pub fn with_rail_policy(mut self, policy: RailPolicy) -> Self {
        self.rail_policy = policy;
        self
    }

    /// Select the chunk issue scheduler (see [`ChunkSched`]). `Fifo` —
    /// the default — is bit-identical to the pre-scheduler eager engine.
    pub fn with_chunk_sched(mut self, sched: ChunkSched) -> Self {
        self.chunk_sched = sched;
        self
    }

    /// Does the switch tier constrain traffic at all? Non-blocking
    /// fabrics (`oversub <= 1.0` and no spine taper) provably never
    /// bottleneck below the NIC endpoints, so their tier links are
    /// elided from routes.
    pub fn is_blocking(&self) -> bool {
        self.oversub > 1.0 || self.spine_taper > 1.0
    }

    /// Per-rail NIC bandwidth given the device's aggregate `nic_bw`.
    pub fn rail_bw(&self, nic_bw: f64) -> f64 {
        nic_bw / self.rails as f64
    }

    /// Effective per-GPU inter-node bandwidth under uniform all-rail
    /// load: the most-thinned tier caps each GPU's fair share —
    /// `nic_bw / oversub` at the leaf uplink, further divided by
    /// `spine_taper` when the spine core is thinned. Assumes the sender
    /// keeps *every* rail busy simultaneously.
    pub fn effective_inter_bw(&self, nic_bw: f64) -> f64 {
        nic_bw / (self.oversub.max(1.0) * self.spine_taper.max(1.0))
    }

    /// Drain rate of a *serialized* inter-node stream: one message in
    /// flight at a time, pinned to a single rail (what `rs_inter`'s
    /// 1-SM P2P block does), through the thinned tiers. This — not
    /// [`Self::effective_inter_bw`] — is what the §3.5 bandwidth-balance
    /// budgets must use: a single message only ever sees one rail's
    /// share of the NIC (see `Topology::inter_path_bw`).
    pub fn rail_path_bw(&self, nic_bw: f64) -> f64 {
        self.effective_inter_bw(nic_bw) / self.rails as f64
    }
}

/// Which fabric path a message should take (the router's input alongside
/// the endpoints). Under [`RailPolicy::Static`] collectives stripe
/// inter-node traffic by pinning messages round-robin across rails;
/// under [`RailPolicy::Adaptive`] they emit [`TrafficClass::Auto`] and
/// the router balances planes per message from live occupancy.
///
/// ```
/// use triton_dist_sim::config::TrafficClass;
///
/// // rail-optimized same-plane path vs spine-crossing asymmetric path
/// let pinned = TrafficClass::Rail(1);
/// let crossing = TrafficClass::Rails { tx: 0, rx: 1 };
/// assert_ne!(pinned, crossing);
/// assert_eq!(TrafficClass::default(), TrafficClass::Auto);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TrafficClass {
    /// Defer rail selection to the router's [`RailPolicy`]: a
    /// deterministic rail from the endpoints' local ranks (`Static`), or
    /// the emptiest plane by live link occupancy (`Adaptive`).
    #[default]
    Auto,
    /// Pin the message to rail `r % rails` end-to-end (rail-optimized
    /// same-rail path). Always honored, regardless of policy.
    Rail(u32),
    /// Explicit tx/rx rails; unequal planes cross both spines
    /// (spine-crossing path). Always honored, regardless of policy.
    Rails { tx: u32, rx: u32 },
}

/// A cluster: `nodes` x `gpus_per_node` devices of one hardware kind.
#[derive(Debug, Clone, Copy)]
pub struct ClusterSpec {
    pub hw: HardwareModel,
    pub nodes: usize,
    pub gpus_per_node: usize,
    /// NUMA domains per node (affects PCIe/NIC locality; §3.1 inter-NUMA).
    pub numa_per_node: usize,
    /// Inter-node fabric organization (rails + switch tiers).
    pub fabric: FabricSpec,
}

impl ClusterSpec {
    pub fn h800(nodes: usize, gpus_per_node: usize) -> Self {
        ClusterSpec {
            hw: HardwareModel::h800(),
            nodes,
            gpus_per_node,
            numa_per_node: 2,
            fabric: FabricSpec::default(),
        }
    }

    pub fn mi308x(gpus_per_node: usize) -> Self {
        ClusterSpec {
            hw: HardwareModel::mi308x(),
            nodes: 1,
            gpus_per_node,
            numa_per_node: 2,
            fabric: FabricSpec::default(),
        }
    }

    pub fn l20(nodes: usize, gpus_per_node: usize) -> Self {
        ClusterSpec {
            hw: HardwareModel::l20(),
            nodes,
            gpus_per_node,
            numa_per_node: 2,
            fabric: FabricSpec::default(),
        }
    }

    /// Replace the inter-node fabric description.
    pub fn with_fabric(mut self, fabric: FabricSpec) -> Self {
        assert!(fabric.rails >= 1, "fabric needs at least one rail");
        assert!(fabric.oversub > 0.0, "oversubscription must be positive");
        assert!(fabric.spine_taper >= 1.0, "spine taper must be >= 1.0");
        self.fabric = fabric;
        self
    }

    pub fn world_size(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.gpus_per_node
    }

    pub fn local_rank(&self, rank: usize) -> usize {
        rank % self.gpus_per_node
    }

    pub fn numa_of(&self, rank: usize) -> usize {
        let per_numa = self.gpus_per_node.div_ceil(self.numa_per_node);
        self.node_of(rank) * self.numa_per_node + self.local_rank(rank) / per_numa
    }
}

/// Element type of the *simulated* payload (numerics always run in f32;
/// the byte size feeds the timing model — see DESIGN.md §2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    BF16,
    F16,
}

impl DType {
    pub fn bytes(&self) -> usize {
        match self {
            DType::F32 => 4,
            DType::BF16 | DType::F16 => 2,
        }
    }
}

/// GEMM problem: `[M, K] x [K, N]`, M is the global (pre-shard) dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmShape {
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl GemmShape {
    pub fn new(m: usize, n: usize, k: usize) -> Self {
        GemmShape { m, n, k }
    }

    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }
}

/// MoE problem, following the Table 4/5 column names, plus the routing
/// knobs of the expert-parallel pipeline (`coordinator::ep_moe`): expert
/// popularity skew and the capacity factor that bounds per-expert load.
///
/// ```
/// use triton_dist_sim::config::MoeShape;
///
/// let shape = MoeShape::default().with_skew(1.2).with_capacity_factor(1.5);
/// assert_eq!(shape.skew, 1.2);
/// // balanced load is tokens*ws*topk/experts; the factor scales it
/// assert_eq!(shape.expert_capacity(8), {
///     let routed = (shape.tokens_per_rank * 8 * shape.topk) as f64;
///     (1.5 * routed / shape.experts as f64).ceil() as usize
/// });
/// ```
#[derive(Debug, Clone, Copy)]
pub struct MoeShape {
    pub tokens_per_rank: usize,
    pub in_hidden: usize,
    pub out_hidden: usize,
    pub experts: usize,
    pub topk: usize,
    /// Expert-popularity skew exponent: topk choices are drawn with
    /// probability proportional to `1 / (expert + 1)^skew` (Zipf-like).
    /// `0.0` (the default) is uniform routing.
    pub skew: f64,
    /// Per-expert capacity as a multiple of the balanced load
    /// (`tokens * ws * topk / experts`); routed pairs beyond the capacity
    /// are dropped in deterministic claim order (see
    /// [`expert_capacity`](Self::expert_capacity)). The default `2.0`
    /// matches the paper's generous-buffer policy.
    pub capacity_factor: f64,
}

impl Default for MoeShape {
    /// Table 4 row 1 (the Qwen-MoE shape), uniform routing, 2x capacity.
    fn default() -> Self {
        MoeShape {
            tokens_per_rank: 256,
            in_hidden: 2048,
            out_hidden: 1408,
            experts: 60,
            topk: 4,
            skew: 0.0,
            capacity_factor: 2.0,
        }
    }
}

impl MoeShape {
    /// Total GroupGEMM FLOPs across a world of `ws` ranks after AllGather:
    /// every routed token row costs 2*in*out.
    pub fn flops(&self, ws: usize) -> f64 {
        2.0 * (self.tokens_per_rank * ws * self.topk) as f64
            * self.in_hidden as f64
            * self.out_hidden as f64
    }

    /// Set the expert-popularity skew exponent (see [`MoeShape::skew`]).
    pub fn with_skew(mut self, skew: f64) -> Self {
        assert!(skew >= 0.0, "skew exponent must be >= 0");
        self.skew = skew;
        self
    }

    /// Set the capacity factor (see [`MoeShape::capacity_factor`]).
    pub fn with_capacity_factor(mut self, factor: f64) -> Self {
        assert!(factor > 0.0, "capacity factor must be positive");
        self.capacity_factor = factor;
        self
    }

    /// Global per-expert slot count under the capacity factor: the
    /// balanced per-expert load across a `ws`-rank world, scaled by
    /// [`capacity_factor`](Self::capacity_factor), at least 1.
    pub fn expert_capacity(&self, ws: usize) -> usize {
        let routed = (self.tokens_per_rank * ws * self.topk) as f64;
        ((self.capacity_factor * routed / self.experts as f64).ceil() as usize).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h800_reduction_threshold_matches_paper() {
        // §3.5: no more than 15 SMs should be needed to exceed 470 GB/s.
        let hw = HardwareModel::h800();
        assert!(hw.reduce_bw(15) >= 470e9, "{}", hw.reduce_bw(15));
        assert!(hw.reduce_bw(10) < 470e9);
    }

    #[test]
    fn amd_aggregate_bandwidth_is_seven_links() {
        let hw = HardwareModel::mi308x();
        assert!((hw.intra_bw - 7.0 * hw.intra_link_bw).abs() < 1e-9 * hw.intra_bw);
    }

    #[test]
    fn cluster_rank_math() {
        let c = ClusterSpec::h800(2, 8);
        assert_eq!(c.world_size(), 16);
        assert_eq!(c.node_of(11), 1);
        assert_eq!(c.local_rank(11), 3);
        // 2 NUMA domains of 4 GPUs each per node
        assert_eq!(c.numa_of(0), 0);
        assert_eq!(c.numa_of(3), 0);
        assert_eq!(c.numa_of(4), 1);
        assert_eq!(c.numa_of(8), 2);
        assert_eq!(c.numa_of(15), 3);
    }

    #[test]
    fn triton_gemm_slower_than_vendor() {
        let hw = HardwareModel::h800();
        assert!(hw.triton_gemm_flops(132) < hw.vendor_gemm_flops(132));
        let ratio = hw.triton_gemm_flops(132) / hw.vendor_gemm_flops(132);
        assert!((ratio - 0.95).abs() < 1e-12);
    }

    #[test]
    fn gemm_flops() {
        assert_eq!(GemmShape::new(2, 3, 4).flops(), 48.0);
    }

    #[test]
    fn dtype_bytes() {
        assert_eq!(DType::BF16.bytes(), 2);
        assert_eq!(DType::F32.bytes(), 4);
    }

    #[test]
    fn default_fabric_is_flat_and_exact() {
        let f = FabricSpec::default();
        assert_eq!(f.rails, 1);
        assert!(!f.is_blocking());
        let hw = HardwareModel::h800();
        // bit-exact identities the flat-NIC equivalence relies on
        assert_eq!(f.rail_bw(hw.nic_bw).to_bits(), hw.nic_bw.to_bits());
        assert_eq!(
            f.effective_inter_bw(hw.nic_bw).to_bits(),
            hw.nic_bw.to_bits()
        );
    }

    #[test]
    fn rail_fabric_splits_and_oversub_caps() {
        let f = FabricSpec::rail_optimized(4, 2.0);
        assert!(f.is_blocking());
        assert!((f.rail_bw(400e9) - 100e9).abs() < 1.0);
        assert!((f.effective_inter_bw(400e9) - 200e9).abs() < 1.0);
    }

    #[test]
    fn spine_taper_thins_the_core() {
        let f = FabricSpec::rail_optimized(1, 1.0).with_spine_taper(2.0);
        assert!(f.is_blocking(), "a tapered spine is a blocking fabric");
        assert!((f.effective_inter_bw(400e9) - 200e9).abs() < 1.0);
        // taper composes with leaf oversubscription
        let g = FabricSpec::rail_optimized(1, 2.0).with_spine_taper(2.0);
        assert!((g.effective_inter_bw(400e9) - 100e9).abs() < 1.0);
    }

    #[test]
    fn serialized_stream_sees_one_rail() {
        // a single in-flight message rides one of 4 rails through a 2:1
        // leaf: 400 / 4 / 2 = 50 GB/s
        let f = FabricSpec::rail_optimized(4, 2.0);
        assert!((f.rail_path_bw(400e9) - 50e9).abs() < 1.0);
        // flat single-rail fabric: bit-identical to the raw NIC speed
        let flat = FabricSpec::default();
        assert_eq!(flat.rail_path_bw(400e9).to_bits(), 400e9_f64.to_bits());
    }

    #[test]
    fn rail_policy_defaults_static_and_threads_through() {
        assert_eq!(RailPolicy::default(), RailPolicy::Static);
        assert_eq!(FabricSpec::default().rail_policy, RailPolicy::Static);
        // the policy is orthogonal to the blocking/bandwidth math
        let f = FabricSpec::rail_optimized(2, 2.0).with_rail_policy(RailPolicy::Adaptive);
        assert_eq!(f.rail_policy, RailPolicy::Adaptive);
        assert!(f.is_blocking());
        assert_eq!(
            f.rail_bw(400e9).to_bits(),
            FabricSpec::rail_optimized(2, 2.0).rail_bw(400e9).to_bits(),
            "policy must not perturb per-rail bandwidth"
        );
        let c = ClusterSpec::h800(2, 8).with_fabric(f);
        assert_eq!(c.fabric.rail_policy, RailPolicy::Adaptive);
    }

    #[test]
    fn chunk_sched_defaults_fifo_and_threads_through() {
        assert_eq!(ChunkSched::default(), ChunkSched::Fifo);
        assert_eq!(FabricSpec::default().chunk_sched, ChunkSched::Fifo);
        // the scheduler is orthogonal to the blocking/bandwidth math
        let f = FabricSpec::rail_optimized(2, 2.0).with_chunk_sched(ChunkSched::Deadline);
        assert_eq!(f.chunk_sched, ChunkSched::Deadline);
        assert!(f.is_blocking());
        assert_eq!(
            f.rail_bw(400e9).to_bits(),
            FabricSpec::rail_optimized(2, 2.0).rail_bw(400e9).to_bits(),
            "scheduler must not perturb per-rail bandwidth"
        );
        // and orthogonal to the rail policy — both compose on one fabric
        let g = f.with_rail_policy(RailPolicy::Adaptive);
        assert_eq!(g.chunk_sched, ChunkSched::Deadline);
        assert_eq!(g.rail_policy, RailPolicy::Adaptive);
        let c = ClusterSpec::h800(2, 8).with_fabric(g);
        assert_eq!(c.fabric.chunk_sched, ChunkSched::Deadline);
    }

    #[test]
    fn moe_shape_routing_knobs() {
        let s = MoeShape::default();
        assert_eq!(s.skew, 0.0);
        assert_eq!(s.capacity_factor, 2.0);
        // the default factor reproduces the generous 2x balanced load
        assert_eq!(s.expert_capacity(1), (2 * 256 * 4usize).div_ceil(60));
        assert!(s.with_capacity_factor(0.5).expert_capacity(1) < s.expert_capacity(1));
        assert_eq!(s.with_skew(2.0).skew, 2.0);
        // capacity never collapses to zero
        assert!(s.with_capacity_factor(1e-9).expert_capacity(1) >= 1);
    }

    #[test]
    #[should_panic]
    fn zero_rail_fabric_rejected() {
        let _ = ClusterSpec::h800(2, 8).with_fabric(FabricSpec {
            rails: 0,
            ..FabricSpec::default()
        });
    }
}
